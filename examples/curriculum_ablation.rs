//! Curriculum ablation (the Table 13 / Fig. 14 scenario): sweep the
//! curriculum fraction κ from 0 (pure WRE/disparity-min) to 1 (pure
//! SGE/graph-cut) and show the interior optimum the paper finds at κ=1/6.
//!
//! One `MiloSession` = one pre-processing pass serving every κ arm; each
//! arm is a `session.train` call with a different `StrategyKind::Milo`.
//!
//! Run: `cargo run --release --example curriculum_ablation [-- --epochs 40]`

use milo::prelude::*;
use milo::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let epochs = args.get_usize("epochs", 40)?;
    let fraction = args.get_f64("fraction", 0.05)?;
    let seed = args.get_u64("seed", 1)?;

    let rt = Runtime::open(args.get_or("artifacts", "artifacts"))?;
    let session = MiloSession::builder()
        .runtime(&rt)
        .dataset(DatasetId::Cifar10Like.generate(seed))
        .fraction(fraction)
        .seed(seed)
        .build()?;

    // one pre-processing pass serves every kappa
    let meta = session.metadata()?;
    println!("pre-processing: {:.2}s", meta.preprocess_secs);

    let mut table = Table::new(
        format!(
            "Curriculum sweep on {} @ {:.0}% ({} epochs)",
            session.dataset().name(),
            fraction * 100.0,
            epochs
        ),
        &["kappa", "phase_split", "test_acc_%"],
    );
    for kappa in [0.0, 1.0 / 12.0, 1.0 / 8.0, 1.0 / 6.0, 0.25, 0.5, 1.0] {
        // ask the strategy itself where the curriculum flips, so the
        // printed phase split can never drift from what training does
        let switch = meta.milo_strategy(kappa).switch_epoch(epochs);
        let cfg = TrainConfig {
            epochs,
            eval_every: 0,
            seed,
            ..TrainConfig::recipe_for(session.dataset(), epochs)
        };
        let out = session.train(StrategyKind::Milo { kappa }, cfg)?;
        table.push(vec![
            format!("{kappa:.4}"),
            format!("SGE {} / WRE {}", switch, epochs - switch),
            format!("{:.2}", 100.0 * out.test_accuracy),
        ]);
        println!(
            "kappa {kappa:.3}: switch at epoch {switch}, test acc {:.2}%",
            100.0 * out.test_accuracy
        );
    }
    println!("{}", table.to_markdown());
    table.save("results", "example_curriculum_ablation")?;
    Ok(())
}
