//! Quickstart: the whole MILO workflow through the session builder.
//!
//! 1. open the AOT artifact runtime (`make artifacts` first);
//! 2. build a `MiloSession`: dataset + metadata source + fraction;
//! 3. the session resolves pre-processing once (SGE subsets + WRE
//!    distribution — the paper's model-agnostic step);
//! 4. train the MILO curriculum and the full-data reference off the same
//!    session — one `train` call each.
//!
//! Run: `cargo run --release --example quickstart`

use milo::prelude::*;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    let fraction = 0.1;
    let session = MiloSession::builder()
        .runtime(&rt)
        .dataset(DatasetId::Cifar10Like.generate(1))
        .source(MetaSource::inline(PreprocessOptions::default()))
        .fraction(fraction)
        .build()?;

    let ds = session.dataset();
    println!(
        "dataset {}: {} train / {} val / {} test, {} classes",
        ds.name(),
        ds.n_train(),
        ds.val_y.len(),
        ds.test_y.len(),
        ds.classes()
    );

    // Resolve once: this is MILO's entire selection cost, paid before any
    // model exists — every consumer below shares it.
    let meta = session.metadata()?;
    println!(
        "pre-processing: {:.2}s ({} SGE subsets of {}, WRE over {} classes)",
        meta.preprocess_secs,
        meta.sge_subsets.len(),
        meta.sge_subsets[0].len(),
        meta.wre_classes.len()
    );

    // Train with the easy-to-hard curriculum (kappa = 1/6), then the
    // full-data reference — the session wires fraction and strategy.
    let epochs = 40;
    let cfg = TrainConfig {
        epochs,
        eval_every: 10,
        ..TrainConfig::recipe_for(session.dataset(), epochs)
    };
    let milo_run = session.train(StrategyKind::Milo { kappa: 1.0 / 6.0 }, cfg.clone())?;
    let full_run = session.train(StrategyKind::Full, cfg)?;

    println!(
        "MILO  (10%): test acc {:.2}%  train {:.2}s",
        100.0 * milo_run.test_accuracy,
        milo_run.train_secs
    );
    println!(
        "FULL (100%): test acc {:.2}%  train {:.2}s",
        100.0 * full_run.test_accuracy,
        full_run.train_secs
    );
    println!(
        "=> speedup {:.2}x at {:.2}% accuracy degradation",
        milo_run.speedup_vs(full_run.train_secs),
        100.0 * (full_run.test_accuracy - milo_run.test_accuracy)
    );
    Ok(())
}
