//! Quickstart: the whole MILO workflow in ~40 lines.
//!
//! 1. open the AOT artifact runtime (`make artifacts` first);
//! 2. generate a dataset;
//! 3. pre-process once (SGE subsets + WRE distribution — the paper's
//!    model-agnostic step);
//! 4. train a downstream model on the MILO curriculum;
//! 5. compare with full-data training.
//!
//! Run: `cargo run --release --example quickstart`

use milo::prelude::*;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    let ds = DatasetId::Cifar10Like.generate(1);
    println!(
        "dataset {}: {} train / {} val / {} test, {} classes",
        ds.name(),
        ds.n_train(),
        ds.val_y.len(),
        ds.test_y.len(),
        ds.classes()
    );

    // Pre-process once: this is MILO's entire selection cost, paid before
    // any model exists.
    let fraction = 0.1;
    let pre = Preprocessor::with_options(
        &rt,
        PreprocessOptions { fraction, ..Default::default() },
    );
    let meta = pre.run(&ds)?;
    println!(
        "pre-processing: {:.2}s ({} SGE subsets of {}, WRE over {} classes)",
        meta.preprocess_secs,
        meta.sge_subsets.len(),
        meta.sge_subsets[0].len(),
        meta.wre_classes.len()
    );

    // Train with the easy-to-hard curriculum (kappa = 1/6).
    let epochs = 40;
    let cfg = TrainConfig {
        epochs,
        fraction,
        eval_every: 10,
        ..TrainConfig::recipe_for(&ds, epochs)
    };
    let mut strategy = meta.milo_strategy(1.0 / 6.0);
    let milo_run = Trainer::new(&rt, &ds, cfg.clone())?.run(&mut strategy)?;

    // Reference: full-data training.
    let full_cfg = TrainConfig { fraction: 1.0, ..cfg };
    let full_run = Trainer::new(&rt, &ds, full_cfg)?.run(&mut FullStrategy)?;

    println!(
        "MILO  (10%): test acc {:.2}%  train {:.2}s",
        100.0 * milo_run.test_accuracy,
        milo_run.train_secs
    );
    println!(
        "FULL (100%): test acc {:.2}%  train {:.2}s",
        100.0 * full_run.test_accuracy,
        full_run.train_secs
    );
    println!(
        "=> speedup {:.2}x at {:.2}% accuracy degradation",
        milo_run.speedup_vs(full_run.train_secs),
        100.0 * (full_run.test_accuracy - milo_run.test_accuracy)
    );
    Ok(())
}
