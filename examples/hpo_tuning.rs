//! Hyper-parameter tuning with MILO (the Fig. 7 scenario): tune an MLP on
//! the TREC6-like dataset with Random-Search×Hyperband and TPE×Hyperband,
//! evaluating every configuration on MILO subsets vs full data.
//!
//! Tuners are handed out by one `MiloSession`, so the pre-processing
//! metadata is resolved once and shared by every trial of every tuner —
//! the amortization that gives the paper its 20–75× tuning speedups.
//!
//! Run: `cargo run --release --example hpo_tuning [-- --fraction 0.1 --max-epochs 9]`

use milo::prelude::*;
use milo::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let fraction = args.get_f64("fraction", 0.1)?;
    let max_epochs = args.get_usize("max-epochs", 9)?;
    let seed = args.get_u64("seed", 1)?;

    let rt = Runtime::open(args.get_or("artifacts", "artifacts"))?;
    // native backend: same preprocessing recipe the standalone Tuner used
    let session = MiloSession::builder()
        .runtime(&rt)
        .dataset(DatasetId::Trec6Like.generate(seed))
        .source(MetaSource::inline(PreprocessOptions {
            backend: SimilarityBackend::Native,
            ..Default::default()
        }))
        .fraction(fraction)
        .seed(seed)
        .build()?;

    let mut table = Table::new(
        format!(
            "HPO on {} (Hyperband R={max_epochs}, eta=3)",
            session.dataset().name()
        ),
        &["search", "strategy", "best_test_acc_%", "trials", "tuning_secs", "speedup"],
    );
    for algo in [SearchAlgo::Random, SearchAlgo::Tpe] {
        // FULL-data tuning reference
        let full_out = session
            .tuner(HpoConfig {
                algo,
                strategy: StrategyKind::Full,
                fraction: 1.0,
                max_epochs,
                eta: 3,
                seed,
            })?
            .run()?;
        table.push(vec![
            algo.name().into(),
            "full".into(),
            format!("{:.2}", 100.0 * full_out.best_test_accuracy),
            full_out.trials.len().to_string(),
            format!("{:.2}", full_out.tuning_secs),
            "1.00".into(),
        ]);
        for kind in [
            StrategyKind::Milo { kappa: 1.0 / 6.0 },
            StrategyKind::AdaptiveRandom,
            StrategyKind::Random,
        ] {
            let out = session
                .tuner(HpoConfig {
                    algo,
                    strategy: kind,
                    fraction,
                    max_epochs,
                    eta: 3,
                    seed,
                })?
                .run()?;
            table.push(vec![
                algo.name().into(),
                kind.name().into(),
                format!("{:.2}", 100.0 * out.best_test_accuracy),
                out.trials.len().to_string(),
                format!("{:.2}", out.tuning_secs),
                format!("{:.2}", full_out.tuning_secs / out.tuning_secs.max(1e-9)),
            ]);
        }
    }
    println!("{}", table.to_markdown());
    table.save("results", "example_hpo_tuning")?;
    Ok(())
}
