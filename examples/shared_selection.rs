//! Shared selection: one preprocessing pass, N concurrent consumers —
//! expressed entirely through the session API.
//!
//! The paper's amortization claim as a running topology:
//!
//! 1. a store-backed `MiloSession` resolves pre-processing once into the
//!    content-addressed metadata store (`milo::store`) — the build counter
//!    proves the pass ran exactly once;
//! 2. a `milo::serve` subset server exposes that resolution on an
//!    ephemeral port;
//! 3. four concurrent clients draw their own deterministic SGE-subset
//!    cycles and WRE sample streams — two over dedicated sockets (one
//!    JSON-line, one framed), two as multiplexed streams sharing a
//!    single pooled connection (the stream a client sees depends only on
//!    its id, never on the transport underneath);
//! 4. a *remote* `MiloSession` pointed at the server resolves the very
//!    same metadata (validated dataset/seed/fraction) and — with
//!    artifacts present — trains a downstream model off the live stream.
//!
//! Run: `cargo run --release --example shared_selection`
//! Works without AOT artifacts too: it then serves synthetic metadata and
//! skips the training step.

use milo::prelude::*;

const N_CLIENTS: usize = 4;
const SEED: u64 = 1;
const FRACTION: f64 = 0.1;

fn main() -> anyhow::Result<()> {
    let store_dir = std::env::temp_dir()
        .join(format!("milo_shared_selection_{}", std::process::id()));
    let store = MetaStore::open(&store_dir)?;
    let opts = PreprocessOptions {
        fraction: FRACTION,
        backend: SimilarityBackend::Native,
        seed: SEED,
        ..Default::default()
    };

    // --- 1. one preprocessing pass, resolved through a store session ----
    let rt = Runtime::open("artifacts").ok();
    let meta = match &rt {
        Some(rt) => {
            let session = MiloSession::builder()
                .runtime(rt)
                .dataset(DatasetId::Trec6Like.generate(SEED))
                .source(MetaSource::store_handle(store.clone(), opts.clone()))
                .build()?;
            session.metadata()?
        }
        None => {
            // dataset generation is procedural — only *preprocessing*
            // needs the AOT artifacts, so serve synthetic selections over
            // the real dataset instead
            println!("artifacts missing -> serving synthetic metadata");
            let ds = DatasetId::Trec6Like.generate(SEED);
            let key = MetaKey::from_options(ds.name(), &opts);
            store.get_or_build(&key, || {
                Ok(milo::testkit::synthetic_metadata(&ds, FRACTION))
            })?
        }
    };
    println!(
        "store: builds {} (must be 1), {} SGE subsets",
        store.stats().builds,
        meta.sge_subsets.len(),
    );

    // --- 2. serve it on an ephemeral port -------------------------------
    let server = SubsetServer::bind("127.0.0.1:0", meta.clone(), Some(store.clone()), SEED)?;
    let addr = server.addr().to_string();
    println!("serving on {addr}");

    // --- 3. four concurrent clients draw deterministic streams ----------
    // two get dedicated sockets (one JSON-line, one framed); the other two
    // lease multiplexed streams from a shared `ConnectionPool`, riding a
    // single TCP connection together. The stream a client sees depends
    // only on its id, never on the transport underneath.
    let pool = ConnectionPool::new(&addr);
    let streams: Vec<(String, Vec<usize>, usize)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..N_CLIENTS)
            .map(|c| {
                let addr = addr.clone();
                let pool = pool.clone();
                scope.spawn(move || -> anyhow::Result<(String, Vec<usize>, usize)> {
                    let id = format!("trainer-{c}");
                    let mut client = match c {
                        0 => ServeClient::connect_with(
                            &addr,
                            &id,
                            ClientOptions { wire: WireMode::Json, ..Default::default() },
                        )?,
                        1 => ServeClient::connect_with(
                            &addr,
                            &id,
                            ClientOptions { wire: WireMode::Frame, ..Default::default() },
                        )?,
                        _ => ServeClient::connect_pooled(
                            &pool,
                            &id,
                            ClientOptions { wire: WireMode::Frame, ..Default::default() },
                        )?,
                    };
                    let mut cycle = Vec::new();
                    for _ in 0..6 {
                        cycle.push(client.next_subset()?.0);
                    }
                    let wre = client.sample_wre(10)?;
                    Ok((id, cycle, wre.len()))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread panicked"))
            .collect::<anyhow::Result<Vec<_>>>()
    })?;
    for (id, cycle, wre_len) in &streams {
        println!("  {id}: SGE cycle {cycle:?}, WRE draw of {wre_len}");
    }
    println!(
        "  pool: 2 multiplexed trainers shared {} TCP connection(s)",
        pool.connections()
    );

    // --- 4. a remote session trains off the served stream ---------------
    if let Some(rt) = &rt {
        let remote = MiloSession::builder()
            .runtime(rt)
            .dataset(DatasetId::Trec6Like.generate(SEED))
            .source(MetaSource::remote_expecting(&addr, SEED, FRACTION))
            .build()?;
        // the remote resolution is the same pass the store session paid for
        assert_eq!(remote.metadata()?.sge_subsets, meta.sge_subsets);
        let epochs = 6;
        // served_strategy bypasses session.train's fraction wiring, so
        // size the trainer's k to the served fraction explicitly
        let cfg = TrainConfig {
            epochs,
            fraction: remote.fraction(),
            eval_every: 0,
            ..TrainConfig::recipe_for(remote.dataset(), epochs)
        };
        let mut strategy = remote.served_strategy("trainer-main", 1.0 / 6.0)?;
        let out = remote.trainer(cfg)?.run(&mut strategy)?;
        println!(
            "served training: test acc {:.2}% in {:.2}s (preprocess amortized to 0)",
            100.0 * out.test_accuracy,
            out.train_secs
        );
    }

    let stats = server.stats();
    println!(
        "server: {} connections, {} requests, {} subsets served, {} WRE samples; \
         store builds {} (the one pass everyone shared)",
        stats.connections,
        stats.requests,
        stats.subsets_served,
        stats.wre_samples,
        store.stats().builds,
    );
    server.shutdown();
    std::fs::remove_dir_all(&store_dir).ok();
    Ok(())
}
