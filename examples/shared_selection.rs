//! Shared selection: one preprocessing pass, N concurrent consumers.
//!
//! The paper's amortization claim as a running topology:
//!
//! 1. pre-process once into the content-addressed metadata store
//!    (`milo::store`) — the build counter proves the pass ran exactly once;
//! 2. start a `milo::serve` subset server on an ephemeral port;
//! 3. connect 4 concurrent clients, each drawing its own deterministic
//!    SGE-subset cycle and WRE sample stream;
//! 4. (with artifacts present) train a downstream model per client via
//!    `ServedMiloStrategy`, sharing the single pass.
//!
//! Run: `cargo run --release --example shared_selection`
//! Works without AOT artifacts too: it then serves synthetic metadata and
//! skips the training step.

use milo::coordinator::{Metadata, PreprocessOptions, Preprocessor};
use milo::data::DatasetId;
use milo::selection::milo::ClassProbs;
use milo::serve::{ServeClient, ServedMiloStrategy, SubsetServer};
use milo::store::{MetaKey, MetaStore};
use milo::train::{TrainConfig, Trainer};

const N_CLIENTS: usize = 4;

fn synthetic_metadata() -> Metadata {
    // 2 classes × 100 points, 3 SGE subsets of 20 — enough structure to
    // exercise every protocol command without the AOT artifacts.
    let n_per = 100;
    Metadata {
        dataset: "synthetic".into(),
        fraction: 0.1,
        sge_subsets: (0..3)
            .map(|r| (0..20).map(|i| (i * 10 + r) % (2 * n_per)).collect())
            .collect(),
        wre_classes: (0..2)
            .map(|c| ClassProbs {
                indices: (c * n_per..(c + 1) * n_per).collect(),
                probs: (0..n_per).map(|i| 1.0 + (i % 7) as f64).collect(),
            })
            .collect(),
        fixed_dm: (0..20).map(|i| i * 9).collect(),
        preprocess_secs: 0.0,
    }
}

fn main() -> anyhow::Result<()> {
    let store_dir = std::env::temp_dir()
        .join(format!("milo_shared_selection_{}", std::process::id()));
    let store = MetaStore::open(&store_dir)?;
    let seed = 1u64;

    // --- 1. one preprocessing pass, content-addressed -------------------
    let rt = milo::runtime::Runtime::open("artifacts").ok();
    let (key, meta) = match &rt {
        Some(rt) => {
            let ds = DatasetId::Trec6Like.generate(seed);
            let pre = Preprocessor::with_options(
                rt,
                PreprocessOptions {
                    fraction: 0.1,
                    backend: milo::kernel::SimilarityBackend::Native,
                    seed,
                    ..Default::default()
                },
            );
            let key = MetaKey::from_options(ds.name(), &pre.opts);
            let meta = store.get_or_build(&key, || pre.run(&ds))?;
            (key, meta)
        }
        None => {
            println!("artifacts missing -> serving synthetic metadata");
            let mut key = MetaKey::from_options("synthetic", &PreprocessOptions::default());
            key.seed = seed;
            let meta = store.get_or_build(&key, || Ok(synthetic_metadata()))?;
            (key, meta)
        }
    };
    println!(
        "store: fingerprint {}, builds {} (must be 1), {} SGE subsets",
        key.fingerprint(),
        store.stats().builds,
        meta.sge_subsets.len(),
    );

    // --- 2. serve it on an ephemeral port -------------------------------
    let server = SubsetServer::bind("127.0.0.1:0", meta.clone(), Some(store.clone()), seed)?;
    let addr = server.addr().to_string();
    println!("serving on {addr}");

    // --- 3. four concurrent clients draw deterministic streams ----------
    let streams: Vec<(String, Vec<usize>, usize)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..N_CLIENTS)
            .map(|c| {
                let addr = addr.clone();
                scope.spawn(move || -> anyhow::Result<(String, Vec<usize>, usize)> {
                    let id = format!("trainer-{c}");
                    let mut client = ServeClient::connect(&addr, &id)?;
                    let mut cycle = Vec::new();
                    for _ in 0..6 {
                        cycle.push(client.next_subset()?.0);
                    }
                    let wre = client.sample_wre(10)?;
                    Ok((id, cycle, wre.len()))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread panicked"))
            .collect::<anyhow::Result<Vec<_>>>()
    })?;
    for (id, cycle, wre_len) in &streams {
        println!("  {id}: SGE cycle {cycle:?}, WRE draw of {wre_len}");
    }

    // --- 4. train off the served stream when artifacts exist ------------
    if let Some(rt) = &rt {
        let ds = DatasetId::Trec6Like.generate(seed);
        let epochs = 6;
        let cfg = TrainConfig {
            epochs,
            fraction: 0.1,
            eval_every: 0,
            ..TrainConfig::recipe_for(&ds, epochs)
        };
        let mut strategy =
            ServedMiloStrategy::connect(&addr, "trainer-main", 1.0 / 6.0)?;
        let out = Trainer::new(rt, &ds, cfg)?.run(&mut strategy)?;
        println!(
            "served training: test acc {:.2}% in {:.2}s (preprocess amortized to 0)",
            100.0 * out.test_accuracy,
            out.train_secs
        );
    }

    let stats = server.stats();
    println!(
        "server: {} connections, {} requests, {} subsets served, {} WRE samples; \
         store builds {} (the one pass everyone shared)",
        stats.connections,
        stats.requests,
        stats.subsets_served,
        stats.wre_samples,
        store.stats().builds,
    );
    server.shutdown();
    std::fs::remove_dir_all(&store_dir).ok();
    Ok(())
}
