//! Kernel-free MILO: the conclusion's future-work path, end to end.
//!
//! The paper's stated limitation is the m×m similarity kernel ("the
//! requirement for a large amount of memory to construct similarity
//! kernels, even with class-wise partitioning"); its proposed fix is
//! feature-based submodular functions. This example runs both paths on
//! the same dataset and reports accuracy, pre-processing time, and the
//! working-memory footprint of each:
//!
//! 1. kernel path — class-wise cosine kernels + graph-cut/disparity-min;
//! 2. feature path — [`FeatureCoverage`] over non-negative coverage
//!    features (O(n·2E) memory, no kernel ever materialized).
//!
//! Run: `cargo run --release --example kernel_free`

use milo::prelude::*;
use milo::submod::FeatureCoverage;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    let ds = DatasetId::Trec6Like.generate(1);
    let fraction = 0.1;
    let epochs = 40;
    println!(
        "dataset {}: {} train samples, {} classes, {:.0}% subsets\n",
        ds.name(),
        ds.n_train(),
        ds.classes(),
        100.0 * fraction
    );

    let pre = Preprocessor::with_options(
        &rt,
        PreprocessOptions { fraction, ..Default::default() },
    );

    // ---- kernel path -----------------------------------------------------
    let emb = pre.encode(&ds, Split::Train)?;
    let kernels = pre.kernels(&ds, &emb)?;
    let kernel_bytes = kernels.total_elements() * std::mem::size_of::<f32>();
    let meta_kernel = pre.run(&ds)?;

    // ---- feature path ------------------------------------------------------
    let feature_bytes = FeatureCoverage::memory_bytes(ds.n_train(), 2 * emb.cols);
    let meta_feature = pre.run_featurebased(&ds)?;

    let cfg = TrainConfig {
        epochs,
        fraction,
        eval_every: 0,
        ..TrainConfig::recipe_for(&ds, epochs)
    };

    for (name, meta, bytes) in [
        ("kernel (class-wise cosine)", &meta_kernel, kernel_bytes),
        ("feature-based (kernel-free)", &meta_feature, feature_bytes),
    ] {
        let mut strategy = meta.milo_strategy(1.0 / 6.0);
        let out = Trainer::new(&rt, &ds, cfg.clone())?.run(&mut strategy)?;
        println!(
            "{name:28}  acc {:>6.2}%  prep {:>6.3}s  selection memory {:>9} B",
            100.0 * out.test_accuracy,
            meta.preprocess_secs,
            bytes
        );
    }

    println!(
        "\nnote: with c={} classes the class-wise kernel is Σ n_c² floats; the \
         feature path is n·2E floats regardless of c — it wins when classes \
         are few or imbalanced, which is exactly the regime the paper's \
         conclusion worries about.",
        ds.classes()
    );
    Ok(())
}
