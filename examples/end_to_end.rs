//! End-to-end driver: the full three-layer system on a *real* small
//! workload — the procedural glyph dataset (rendered 16×16 digit images,
//! a genuine pixel-space recognition task, not a Gaussian toy).
//!
//! This proves every layer composes on the request path:
//!   L1 Pallas cosine-similarity kernel (via the PJRT `sim_cosine_e32`
//!   artifact) → L2 encoder / train / eval graphs → L3 coordinator
//!   (SGE + WRE pre-processing, curriculum trainer, baselines).
//!
//! It reports the paper's headline metric — speedup vs accuracy
//! degradation of MILO against FULL training and the baselines — and is
//! the run recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example end_to_end [-- --epochs 60 --fraction 0.1]`

use milo::prelude::*;
use milo::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["quiet"])?;
    let epochs = args.get_usize("epochs", 60)?;
    let fraction = args.get_f64("fraction", 0.1)?;
    let seed = args.get_u64("seed", 1)?;

    let rt = Runtime::open(args.get_or("artifacts", "artifacts"))?;
    let session = MiloSession::builder()
        .runtime(&rt)
        .dataset(DatasetId::Glyphs.generate(seed))
        .source(MetaSource::inline(PreprocessOptions {
            backend: SimilarityBackend::Pjrt,
            ..Default::default()
        }))
        .fraction(fraction)
        .seed(seed)
        .build()?;
    let ds = session.dataset();
    println!(
        "glyphs: {} rendered 16x16 digit images (train), {} test",
        ds.n_train(),
        ds.test_y.len()
    );

    // Pre-processing through the PJRT/Pallas path — the architecture's L1;
    // the grid runner below inherits the session's source and backend.
    let mut runner = session.runner(epochs)?;
    runner.verbose = !args.flag("quiet");

    let t0 = std::time::Instant::now();
    let meta = runner.preprocess(fraction, seed)?;
    println!(
        "pre-processing (Pallas similarity kernel via PJRT): {:.2}s",
        t0.elapsed().as_secs_f64()
    );
    drop(meta);

    let full = runner.run_full(seed)?;
    println!(
        "FULL: test acc {:.2}%, train {:.2}s ({} epochs)",
        100.0 * full.test_accuracy,
        full.train_secs,
        epochs
    );

    let mut table = Table::new(
        format!("End-to-end: glyphs @ {:.0}% subset, {} epochs", fraction * 100.0, epochs),
        &["strategy", "test_acc_%", "train_secs", "speedup", "degradation_%"],
    );
    for kind in [
        StrategyKind::Milo { kappa: 1.0 / 6.0 },
        StrategyKind::MiloFixed,
        StrategyKind::AdaptiveRandom,
        StrategyKind::Random,
        StrategyKind::CraigPb,
        StrategyKind::GradMatchPb,
        StrategyKind::FullEarlyStop,
    ] {
        let rec = runner.run_cell(kind, fraction, seed, &full)?;
        table.push(vec![
            kind.name().to_string(),
            format!("{:.2}", 100.0 * rec.outcome.test_accuracy),
            format!("{:.2}", rec.outcome.train_secs),
            format!("{:.2}", rec.speedup()),
            format!("{:.2}", rec.degradation_pct()),
        ]);
    }
    println!("{}", table.to_markdown());
    table.save("results", "end_to_end_glyphs")?;
    println!("saved results/end_to_end_glyphs.{{csv,md}}");
    Ok(())
}
