"""AOT compiler: lower every L1/L2 graph to HLO *text* artifacts.

Run once via ``make artifacts`` (no-op when inputs are unchanged); the Rust
coordinator is self-contained afterwards — Python never runs on the
training path.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs, under ``artifacts/``:
  * ``<name>.hlo.txt``   — one per compiled graph (see DESIGN.md §4);
  * ``params/<ds>_h<h>_s<seed>.bin`` — He-init downstream-model parameters,
    all six arrays concatenated row-major f32 LE in W1,b1,W2,b2,W3,b3
    order (shapes derivable from the spec in the manifest);
  * ``manifest.json``    — datasets, shapes, artifact index, digest.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels import gains as G
from compile.kernels import similarity as S
from compile.kernels import topk as TK

# ---------------------------------------------------------------------------
# Global shape configuration (mirrored into manifest.json for Rust)
# ---------------------------------------------------------------------------

BATCH = 128  # training/eval/meta mini-batch size (padded + masked)
EMBED_DIM = 32  # encoder output dimensionality
SIM_TILE = 256  # Pallas similarity/gain tile edge
PARAM_SEEDS = [1, 2, 3, 4, 5]  # per-trial init seeds (paper: 5 runs)

# Synthetic dataset registry. ``input_dim``/``classes`` fix artifact shapes;
# the generators themselves live in rust/src/data (they only need to agree
# on these dims). Hidden lists define the downstream-model capacity tiers
# compiled for each dataset (incl. the HPO hidden-size search space).
DATASETS = {
    # vision-like (Gaussian-mixture manifolds standing in for CIFAR et al.)
    "cifar10": {"input_dim": 64, "classes": 10, "hidden": [64, 128, 256]},
    "cifar100": {"input_dim": 64, "classes": 100, "hidden": [128]},
    "tinyimagenet": {"input_dim": 64, "classes": 200, "hidden": [128]},
    # specialized-domain (App. H.1/H.2 stand-ins: OrganCMNIST / DermaMNIST)
    "organa": {"input_dim": 64, "classes": 11, "hidden": [128]},
    "derma": {"input_dim": 64, "classes": 7, "hidden": [128]},
    # text-like (topic mixtures standing in for TREC6/IMDB/Rotten Tomatoes)
    "trec6": {"input_dim": 48, "classes": 6, "hidden": [64, 128, 256]},
    "imdb": {"input_dim": 48, "classes": 2, "hidden": [128]},
    "rotten": {"input_dim": 48, "classes": 2, "hidden": [128]},
    # real small end-to-end workload: procedurally rendered 16x16 glyphs
    "glyphs": {"input_dim": 256, "classes": 10, "hidden": [128]},
}

# Datasets that additionally get a proxy-feature artifact (App. H.2 path).
PROXY_DATASETS = ["cifar100", "organa"]

# Datasets that additionally get Fig-11 encoder-variant artifacts.
ENCODER_ABLATION_DATASETS = ["cifar100", "trec6"]

F32 = jnp.float32
I32 = jnp.int32


def f32(shape):
    return jax.ShapeDtypeStruct(shape, F32)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, I32)


def scalar():
    return f32(())


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so Rust
    always unpacks one tuple literal, regardless of output arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the frozen encoder weights are baked into
    # the graph as constants; the default printer elides them as
    # `constant({...})`, which the text parser silently reads back as zeros.
    return comp.as_hlo_text(print_large_constants=True)


class Builder:
    def __init__(self, out_dir: str, verbose: bool = True):
        self.out_dir = out_dir
        self.verbose = verbose
        self.artifacts = []  # manifest entries
        os.makedirs(out_dir, exist_ok=True)
        os.makedirs(os.path.join(out_dir, "params"), exist_ok=True)

    def emit(self, name: str, fn, in_specs, kind: str, meta: dict):
        path = f"{name}.hlo.txt"
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": path,
            "kind": kind,
            "inputs": [
                {"shape": list(s.shape), "dtype": str(np.dtype(s.dtype))}
                for s in in_specs
            ],
            **meta,
        }
        self.artifacts.append(entry)
        if self.verbose:
            print(f"  [aot] {name}: {len(text)} chars, {len(in_specs)} inputs")
        return entry


def emit_kernels(b: Builder, embed_dims):
    """L1 Pallas artifacts: similarity (per embed dim) + gain reductions."""
    t = SIM_TILE
    for e in embed_dims:
        b.emit(
            f"sim_cosine_e{e}",
            lambda a, bb: (S.cosine_similarity(a, bb, tile=t),),
            [f32((t, e)), f32((t, e))],
            "similarity",
            {"metric": "cosine", "embed_dim": e, "tile": t},
        )
    e = EMBED_DIM
    b.emit(
        f"sim_dot_e{e}",
        lambda a, bb: (S.dot_similarity(a, bb, tile=t),),
        [f32((t, e)), f32((t, e))],
        "similarity",
        {"metric": "dot", "embed_dim": e, "tile": t},
    )
    b.emit(
        f"sim_rbf_e{e}",
        lambda a, bb, g: (S.rbf_similarity(a, bb, g, tile=t),),
        [f32((t, e)), f32((t, e)), f32((1,))],
        "similarity",
        {"metric": "rbf", "embed_dim": e, "tile": t},
    )
    b.emit(
        f"fl_gain_t{t}",
        lambda s, mx: (G.facility_location_gains(s, mx, ti=t, tj=t),),
        [f32((t, t)), f32((t,))],
        "fl_gain",
        {"tile": t},
    )
    b.emit(
        f"colsum_t{t}",
        lambda s: (G.column_sums(s, ti=t, tj=t),),
        [f32((t, t))],
        "colsum",
        {"tile": t},
    )
    b.emit(
        f"colmax_t{t}",
        lambda s: (G.column_maxes(s, ti=t, tj=t),),
        [f32((t, t))],
        "colmax",
        {"tile": t},
    )
    # fused similarity + on-device top-K candidate cut (see kernels/topk.py);
    # `k` in the meta gates the Rust side's device path (`knn <= k`).
    e, k = EMBED_DIM, TK.DEFAULT_K
    for base in ("cosine", "dot"):
        sim_topk = TK.cosine_topk if base == "cosine" else TK.dot_topk
        b.emit(
            f"topk_{base}_e{e}",
            lambda a, bb, v, f=sim_topk: f(a, bb, v, tile=t, k=k),
            [f32((t, e)), f32((t, e)), f32((1,))],
            "topk",
            {"metric": base, "embed_dim": e, "tile": t, "k": k},
        )
    b.emit(
        f"topk_rbf_e{e}",
        lambda a, bb, v, g: TK.rbf_topk(a, bb, v, g, tile=t, k=k),
        [f32((t, e)), f32((t, e)), f32((1,)), f32((1,))],
        "topk",
        {"metric": "rbf", "embed_dim": e, "tile": t, "k": k},
    )


def emit_dataset(b: Builder, ds: str, cfg: dict):
    d, c = cfg["input_dim"], cfg["classes"]
    # frozen zero-shot encoder (weights baked in as constants)
    b.emit(
        f"encoder_{ds}",
        M.make_encoder(d, EMBED_DIM),
        [f32((BATCH, d))],
        "encoder",
        {"dataset": ds, "embed_dim": EMBED_DIM},
    )
    # whole-chain fusion: raw feature tiles -> encoder -> cosine -> top-K
    # in one execution (the Rust cosine/Pjrt fast path when knn <= k)
    t = SIM_TILE
    b.emit(
        f"embed_sim_topk_{ds}",
        TK.make_embed_cosine_topk(M.make_encoder(d, EMBED_DIM), tile=t, k=TK.DEFAULT_K),
        [f32((t, d)), f32((t, d)), f32((1,))],
        "fused_topk",
        {
            "dataset": ds,
            "metric": "cosine",
            "embed_dim": EMBED_DIM,
            "tile": t,
            "k": TK.DEFAULT_K,
        },
    )
    if ds in ENCODER_ABLATION_DATASETS:
        for variant, (e, _, _, _) in M.ENCODER_VARIANTS.items():
            if variant == "cls32":
                continue  # identical to the default encoder_{ds}
            b.emit(
                f"encoder_{ds}__{variant}",
                M.make_encoder_variant(d, variant),
                [f32((BATCH, d))],
                "encoder",
                {"dataset": ds, "embed_dim": e, "variant": variant},
            )
    for h in cfg["hidden"]:
        spec = M.MlpSpec(d, h, c)
        pshapes = [f32(s) for s in spec.param_shapes]
        batch = [f32((BATCH, d)), i32((BATCH,)), f32((BATCH,))]
        hp = [scalar(), scalar(), scalar(), scalar()]
        tag = f"{ds}_h{h}"
        meta = {"dataset": ds, "hidden": h, "classes": c, "input_dim": d}
        b.emit(
            f"train_step_{tag}",
            M.make_train_step(spec),
            pshapes + pshapes + batch + hp,
            "train_step",
            meta,
        )
        b.emit(f"eval_{tag}", M.make_eval_batch(spec), pshapes + batch, "eval", meta)
        b.emit(f"meta_{tag}", M.make_meta_batch(spec), pshapes + batch, "meta", meta)
        if ds in PROXY_DATASETS and h == 128:
            b.emit(
                f"proxy_{tag}",
                M.make_proxy_features(spec),
                pshapes[:4] + [f32((BATCH, d))],
                "proxy",
                meta,
            )
        # He-init parameter sets, one file per seed
        for seed in PARAM_SEEDS:
            params = M.init_params(spec, seed)
            blob = b"".join(np.ascontiguousarray(p).tobytes() for p in params)
            fname = f"params/{tag}_s{seed}.bin"
            with open(os.path.join(b.out_dir, fname), "wb") as f:
                f.write(blob)


def input_digest() -> str:
    """Hash of the compile-path sources; lets `make artifacts` no-op."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    b = Builder(args.out, verbose=not args.quiet)
    print(f"[aot] lowering artifacts into {os.path.abspath(args.out)}")
    emit_kernels(b, embed_dims=[EMBED_DIM, 128])
    for ds, cfg in DATASETS.items():
        emit_dataset(b, ds, cfg)

    manifest = {
        "version": 1,
        "batch": BATCH,
        "embed_dim": EMBED_DIM,
        "sim_tile": SIM_TILE,
        "param_seeds": PARAM_SEEDS,
        "param_order": M.PARAM_NAMES,
        "encoder_hidden": M.ENCODER_HIDDEN,
        "datasets": DATASETS,
        "proxy_datasets": PROXY_DATASETS,
        "artifacts": b.artifacts,
        "digest": input_digest(),
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(b.artifacts)} artifacts + manifest.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
