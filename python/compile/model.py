"""L2: JAX compute graphs compiled AOT for the Rust coordinator.

Five graph families, all lowered to HLO text by ``aot.py``:

  * ``encoder(x)``          — the "pre-trained zero-shot feature encoder"
    (paper: DINO-ViTB16 / all-distilroberta-v1). Here: a frozen 2-layer
    random-feature map whose weights are sampled once at AOT time with a
    fixed seed and baked into the HLO as constants — the moral equivalent
    of downloading frozen pretrained weights. L2-normalized output so the
    cosine kernel is a pure matmul downstream.
  * ``train_step(params, mom, x, y, wt, hp)`` — one mini-batch SGD step of
    the downstream MLP classifier (the paper's downstream model is a black
    box to MILO; capacity tiers stand in for ResNet18/50/101). Masked
    softmax cross-entropy, weight decay, classical/Nesterov momentum
    selected by a runtime flag, learning rate as a runtime scalar so LR
    schedules live in Rust.
  * ``eval_batch(params, x, y, wt)`` — summed loss + correct count.
  * ``meta_batch(params, x, y, wt)`` — per-sample losses, EL2N scores
    (Paul et al., used for Tables 1-2) and last-layer gradient embeddings
    ``softmax(logits) - onehot(y)`` (the per-batch "PB" gradient
    approximation CraigPB / GradMatchPB / Glister use in CORDS).
  * ``proxy_features(params, x)`` — penultimate-layer activations, the
    App. H.2 proxy-encoder path.

The similarity kernels that consume encoder outputs are Pallas kernels
(``kernels/similarity.py``); they are lowered as separate artifacts because
the Rust coordinator streams class partitions through them tile by tile.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Frozen encoder
# ---------------------------------------------------------------------------

ENCODER_SEED = 0x5EEDC0DE % (2**31)
ENCODER_HIDDEN = 128


def make_encoder_weights(input_dim: int, embed_dim: int, seed: int = ENCODER_SEED):
    """Sample the frozen encoder weights (numpy, fixed seed -> deterministic
    artifacts). Two-layer tanh random-feature map: this is the standard
    random-features approximation of a smooth kernel, which is all the
    downstream submodular machinery needs from "a pretrained encoder"
    (DESIGN.md, substitutions table)."""
    rng = np.random.default_rng(seed + 1000003 * input_dim + embed_dim)
    w1 = rng.normal(0.0, 1.0 / np.sqrt(input_dim), (input_dim, ENCODER_HIDDEN))
    b1 = rng.uniform(-0.1, 0.1, (ENCODER_HIDDEN,))
    w2 = rng.normal(0.0, 1.0 / np.sqrt(ENCODER_HIDDEN), (ENCODER_HIDDEN, embed_dim))
    return (
        w1.astype(np.float32),
        b1.astype(np.float32),
        w2.astype(np.float32),
    )


def encoder_fn(x, w1, b1, w2):
    """x[B, D] -> z[B, E], L2-normalized."""
    h = jnp.tanh(x @ w1 + b1)
    z = h @ w2
    n = jnp.sqrt(jnp.sum(z * z, axis=1, keepdims=True) + 1e-12)
    return z / n


def make_encoder(input_dim: int, embed_dim: int, seed: int = ENCODER_SEED):
    """Return ``f(x) -> (z,)`` with the frozen weights closed over (they
    lower to HLO constants — the artifact is self-contained)."""
    w1, b1, w2 = make_encoder_weights(input_dim, embed_dim, seed)
    w1 = jnp.asarray(w1)
    b1 = jnp.asarray(b1)
    w2 = jnp.asarray(w2)

    def encode(x):
        return (encoder_fn(x, w1, b1, w2),)

    return encode


# ---------------------------------------------------------------------------
# Encoder variants (Fig 11 ablation)
# ---------------------------------------------------------------------------
#
# The paper compares pre-trained encoders (DINO CLS/mean, ViT, CLIP for
# vision; distilroberta vs mpnet for text). Our analog: variants of the
# frozen random-feature encoder that differ in pooling, depth, width and
# initialisation stream — each yields a *different* fixed feature geometry,
# which is exactly the degree of freedom the paper's Fig 11 sweeps.

ENCODER_VARIANTS = {
    # name     (embed_dim, depth, pooling, seed offset)
    "cls32": (32, 2, "cls", 0),  # default — DINO (CLS) analog
    "mean32": (32, 1, "mean", 0),  # shallow mean-pool — DINO (mean) analog
    "alt32": (32, 2, "cls", 7919),  # different init stream — ViT analog
    "wide64": (64, 2, "cls", 0),  # wider embedding — CLIP-L analog
    "narrow16": (16, 2, "cls", 0),  # bottlenecked — low-capacity control
}


def make_encoder_variant(input_dim: int, variant: str):
    """Return ``f(x) -> (z,)`` for a named Fig-11 encoder variant.

    * depth 1: single tanh projection straight to the embedding;
    * depth 2: the default two-layer map (``make_encoder``);
    * pooling "mean": average two half-width feature banks before the
      output projection (the mean-of-token-embeddings analog).
    """
    embed_dim, depth, pooling, seed_off = ENCODER_VARIANTS[variant]
    seed = ENCODER_SEED + seed_off
    if depth == 1:
        rng = np.random.default_rng(seed + 1000003 * input_dim + embed_dim + 13)
        w = jnp.asarray(
            rng.normal(0.0, 1.0 / np.sqrt(input_dim), (input_dim, embed_dim)).astype(
                np.float32
            )
        )
        b = jnp.asarray(
            rng.uniform(-0.1, 0.1, (embed_dim,)).astype(np.float32)
        )

        def encode1(x):
            z = jnp.tanh(x @ w + b)
            n = jnp.sqrt(jnp.sum(z * z, axis=1, keepdims=True) + 1e-12)
            return (z / n,)

        return encode1
    if pooling == "mean":
        # two half-width banks, mean-pooled, then projected
        half = ENCODER_HIDDEN // 2
        rng = np.random.default_rng(seed + 1000003 * input_dim + embed_dim + 29)
        wa = jnp.asarray(
            rng.normal(0.0, 1.0 / np.sqrt(input_dim), (input_dim, half)).astype(
                np.float32
            )
        )
        wb = jnp.asarray(
            rng.normal(0.0, 1.0 / np.sqrt(input_dim), (input_dim, half)).astype(
                np.float32
            )
        )
        wo = jnp.asarray(
            rng.normal(0.0, 1.0 / np.sqrt(half), (half, embed_dim)).astype(np.float32)
        )

        def encode_mean(x):
            h = 0.5 * (jnp.tanh(x @ wa) + jnp.tanh(x @ wb))
            z = h @ wo
            n = jnp.sqrt(jnp.sum(z * z, axis=1, keepdims=True) + 1e-12)
            return (z / n,)

        return encode_mean
    return make_encoder(input_dim, embed_dim, seed)


# ---------------------------------------------------------------------------
# Downstream MLP classifier
# ---------------------------------------------------------------------------


class MlpSpec(NamedTuple):
    input_dim: int
    hidden: int
    classes: int

    @property
    def param_shapes(self):
        d, h, c = self.input_dim, self.hidden, self.classes
        return [(d, h), (h,), (h, h), (h,), (h, c), (c,)]

    @property
    def n_params(self):
        return sum(int(np.prod(s)) for s in self.param_shapes)


PARAM_NAMES = ["w1", "b1", "w2", "b2", "w3", "b3"]


def init_params(spec: MlpSpec, seed: int):
    """He-initialised parameters (numpy). aot.py serialises these once per
    (spec, seed) so the Rust side never re-implements the initialiser."""
    rng = np.random.default_rng(seed)
    out = []
    for shape in spec.param_shapes:
        if len(shape) == 2:
            fan_in = shape[0]
            out.append(
                rng.normal(0.0, np.sqrt(2.0 / fan_in), shape).astype(np.float32)
            )
        else:
            out.append(np.zeros(shape, np.float32))
    return out


def mlp_logits(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h1 = jax.nn.relu(x @ w1 + b1)
    h2 = jax.nn.relu(h1 @ w2 + b2)
    return h2 @ w3 + b3


def mlp_penultimate(params, x):
    w1, b1, w2, b2, _, _ = params
    h1 = jax.nn.relu(x @ w1 + b1)
    return jax.nn.relu(h1 @ w2 + b2)


def masked_ce_loss(params, x, y, wt, classes):
    """Weighted-mean softmax cross entropy. ``wt`` zeroes padded rows."""
    logits = mlp_logits(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, classes, dtype=logits.dtype)
    per = -jnp.sum(onehot * logp, axis=-1)
    denom = jnp.maximum(jnp.sum(wt), 1.0)
    return jnp.sum(per * wt) / denom, logits


def make_train_step(spec: MlpSpec):
    """One SGD(+momentum/Nesterov, +weight-decay) step.

    Signature (flat, 6 params + 6 momenta + batch + 4 hyper-scalars):
        (w1,b1,w2,b2,w3,b3, m1..m6, x[B,D], y[B]i32, wt[B],
         lr, momentum, weight_decay, nesterov_flag)
      -> (w1',...,b3', m1',...,m6', loss, correct)

    ``nesterov_flag`` in {0.0, 1.0}: step = nesterov*(g + mu*v') +
    (1-nesterov)*v' with v' = mu*v + g, matching PyTorch SGD semantics
    (the paper's recipe: Nesterov SGD, momentum 0.9, wd 5e-4).
    """

    def train_step(*args):
        params = list(args[0:6])
        mom = list(args[6:12])
        x, y, wt, lr, mu, wd, nesterov = args[12:]

        def loss_fn(ps):
            loss, logits = masked_ce_loss(ps, x, y, wt, spec.classes)
            return loss, logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        correct = jnp.sum((pred == y).astype(jnp.float32) * wt)

        new_params = []
        new_mom = []
        for p, v, g in zip(params, mom, grads):
            g = g + wd * p  # L2 coupled to the gradient, as torch SGD does
            v_new = mu * v + g
            step = nesterov * (g + mu * v_new) + (1.0 - nesterov) * v_new
            new_params.append(p - lr * step)
            new_mom.append(v_new)
        return tuple(new_params) + tuple(new_mom) + (loss, correct)

    return train_step


def make_eval_batch(spec: MlpSpec):
    """(params..., x, y, wt) -> (loss_sum, correct) — sums, not means, so
    Rust can aggregate exactly across padded batches."""

    def eval_batch(*args):
        params = list(args[0:6])
        x, y, wt = args[6:]
        logits = mlp_logits(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(y, spec.classes, dtype=logits.dtype)
        per = -jnp.sum(onehot * logp, axis=-1)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        correct = jnp.sum((pred == y).astype(jnp.float32) * wt)
        return (jnp.sum(per * wt), correct)

    return eval_batch


def make_meta_batch(spec: MlpSpec):
    """(params..., x, y, wt) -> (losses[B], el2n[B], gemb[B, C]).

    * losses: per-sample CE (padded rows zeroed);
    * el2n:  ||softmax(logits) - onehot||_2 (Paul et al. 2021);
    * gemb:  last-layer gradient embedding softmax - onehot — the "PB"
      (per-batch, last-layer) gradient approximation of CRAIG/GradMatch.
    """

    def meta_batch(*args):
        params = list(args[0:6])
        x, y, wt = args[6:]
        logits = mlp_logits(params, x)
        p = jax.nn.softmax(logits, axis=-1)
        logp = jax.nn.log_softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(y, spec.classes, dtype=logits.dtype)
        losses = -jnp.sum(onehot * logp, axis=-1) * wt
        err = p - onehot
        el2n = jnp.sqrt(jnp.sum(err * err, axis=-1) + 1e-20) * wt
        gemb = err * wt[:, None]
        return (losses, el2n, gemb)

    return meta_batch


def make_proxy_features(spec: MlpSpec):
    """(w1, b1, w2, b2, x) -> (h[B, H],) penultimate features,
    L2-normalized — used when a trained proxy model replaces the zero-shot
    encoder. Takes only the four parameters it reads: the last layer
    (w3, b3) never feeds the penultimate activations, and XLA prunes
    unused entry-computation parameters when lowering, so declaring them
    would desynchronise the manifest arity from the compiled program."""

    def proxy_features(w1, b1, w2, b2, x):
        h = mlp_penultimate([w1, b1, w2, b2, None, None], x)
        n = jnp.sqrt(jnp.sum(h * h, axis=1, keepdims=True) + 1e-12)
        return (h / n,)

    return proxy_features
