"""L1 Pallas kernel: tiled pairwise cosine similarity.

This is the paper's memory/compute hot-spot: MILO builds an ``m x m``
similarity kernel K over encoder features (Sec. 3.2 of the paper), which it
then hands to the submodular maximizers. On the authors' setup this was a
GPU batched matmul inside SUBMODLIB; here it is a Pallas kernel tiled for
TPU VMEM (see DESIGN.md "Hardware adaptation"):

  * the grid is 2-D over output tiles ``(T, T)``;
  * each step streams an ``(T, E)`` block of ``a`` and ``(T, E)`` block of
    ``b`` HBM -> VMEM (BlockSpec index maps express the schedule the paper
    did with CUDA thread-blocks);
  * rows are L2-normalized in-register, the contraction feeds the MXU as a
    ``(T, E) @ (E, T)`` matmul, and the affine rescale to ``[0, 1]``
    (paper Eq. 10: ``0.5 + 0.5 * cos``) fuses into the epilogue.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO and runs bit-exact against
the ``ref.py`` oracle (checked in ``python/tests/test_kernels.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Numerical floor for row norms; matches ref.py so kernel == oracle exactly.
NORM_EPS = 1e-12

# Default output tile edge. 256 keeps the VMEM footprint of one grid step at
#   2 * T*E*4B (inputs) + T*T*4B (output) = 2*256*32*4 + 256*256*4 ~ 0.33 MB
# for E=32, far below the ~16 MB VMEM budget, leaving room for
# double-buffering the HBM->VMEM streams.
DEFAULT_TILE = 256


def _cosine_tile_kernel(a_ref, b_ref, o_ref):
    """One (T, T) output tile: normalize rows, matmul, rescale to [0,1]."""
    a = a_ref[...]
    b = b_ref[...]
    an = a * jax.lax.rsqrt(jnp.sum(a * a, axis=1, keepdims=True) + NORM_EPS)
    bn = b * jax.lax.rsqrt(jnp.sum(b * b, axis=1, keepdims=True) + NORM_EPS)
    # MXU contraction; f32 here, bf16-ready on real hardware.
    sim = jnp.dot(an, bn.T, preferred_element_type=jnp.float32)
    # Paper Eq. (10): additive rescale so all similarities are non-negative
    # (required for the submodular instantiations in Appendix D).
    o_ref[...] = 0.5 + 0.5 * sim


@functools.partial(jax.jit, static_argnames=("tile",))
def cosine_similarity(a: jax.Array, b: jax.Array, *, tile: int = DEFAULT_TILE):
    """Pairwise rescaled cosine similarity ``s[i, j] in [0, 1]``.

    Args:
      a: ``(n, e)`` float32 features; ``n`` must be a multiple of ``tile``
         (the Rust coordinator pads class partitions to the tile size and
         masks the padding out when assembling the per-class kernel).
      b: ``(m, e)`` float32 features, ``m`` a multiple of ``tile``.
      tile: output tile edge (static).

    Returns:
      ``(n, m)`` float32 similarities.
    """
    n, e = a.shape
    m, _ = b.shape
    if n % tile or m % tile:
        raise ValueError(f"tile {tile} must divide n={n}, m={m}")
    grid = (n // tile, m // tile)
    return pl.pallas_call(
        _cosine_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, e), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, e), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(a, b)


def _dot_tile_kernel(a_ref, b_ref, o_ref):
    """Raw (additively rescaled later on the Rust side) dot-product tile."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...].T, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tile",))
def dot_similarity(a: jax.Array, b: jax.Array, *, tile: int = DEFAULT_TILE):
    """Pairwise dot-product similarity (ablation I.2's "Dot Product")."""
    n, e = a.shape
    m, _ = b.shape
    if n % tile or m % tile:
        raise ValueError(f"tile {tile} must divide n={n}, m={m}")
    return pl.pallas_call(
        _dot_tile_kernel,
        grid=(n // tile, m // tile),
        in_specs=[
            pl.BlockSpec((tile, e), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, e), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(a, b)


def _rbf_tile_kernel(a_ref, b_ref, gamma_ref, o_ref):
    """RBF tile: exp(-||a_i - b_j||^2 * gamma) via the matmul identity."""
    a = a_ref[...]
    b = b_ref[...]
    a2 = jnp.sum(a * a, axis=1, keepdims=True)  # (T, 1)
    b2 = jnp.sum(b * b, axis=1, keepdims=True).T  # (1, T)
    ab = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(a2 + b2 - 2.0 * ab, 0.0)
    o_ref[...] = jnp.exp(-d2 * gamma_ref[0])


@functools.partial(jax.jit, static_argnames=("tile",))
def rbf_similarity(
    a: jax.Array, b: jax.Array, gamma: jax.Array, *, tile: int = DEFAULT_TILE
):
    """Pairwise RBF similarity, paper Eq. (11) with gamma = 1/(kw*mean_dist).

    ``gamma`` is a runtime scalar (shape ``(1,)``) so a single artifact
    serves every ``kw`` in the Table 11/12 ablation.
    """
    n, e = a.shape
    m, _ = b.shape
    if n % tile or m % tile:
        raise ValueError(f"tile {tile} must divide n={n}, m={m}")
    return pl.pallas_call(
        _rbf_tile_kernel,
        grid=(n // tile, m // tile),
        in_specs=[
            pl.BlockSpec((tile, e), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, e), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(a, b, gamma)
