"""Pure-jnp oracles for the Pallas kernels.

Each function here is the mathematically obvious implementation of the
corresponding kernel in ``similarity.py`` / ``gains.py``. The pytest suite
asserts ``allclose`` between kernel and oracle across shape/dtype sweeps
(hypothesis) — this is the CORE correctness signal for layer 1.
"""

from __future__ import annotations

import jax.numpy as jnp

NORM_EPS = 1e-12


def cosine_similarity_ref(a, b):
    """0.5 + 0.5 * cos(a_i, b_j), rescaled to [0, 1] (paper Eq. 10)."""
    an = a / jnp.sqrt(jnp.sum(a * a, axis=1, keepdims=True) + NORM_EPS)
    bn = b / jnp.sqrt(jnp.sum(b * b, axis=1, keepdims=True) + NORM_EPS)
    return 0.5 + 0.5 * an @ bn.T


def dot_similarity_ref(a, b):
    return a @ b.T


def rbf_similarity_ref(a, b, gamma):
    """exp(-gamma * ||a_i - b_j||^2) (paper Eq. 11, gamma=1/(kw*mean_dist))."""
    d2 = jnp.sum((a[:, None, :] - b[None, :, :]) ** 2, axis=-1)
    return jnp.exp(-gamma * d2)


def facility_location_gains_ref(s, mx):
    """gain(j) = sum_i max(0, s[i,j] - mx[i])."""
    return jnp.sum(jnp.maximum(s - mx[:, None], 0.0), axis=0)


def column_sums_ref(s):
    return jnp.sum(s, axis=0)


def column_maxes_ref(s):
    return jnp.max(s, axis=0)
