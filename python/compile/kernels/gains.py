"""L1 Pallas kernels: submodular marginal-gain evaluation.

The inner loop of greedy submodular maximization evaluates, for every
candidate ``j``, the marginal gain of adding ``j`` to the current subset.
For the two functions MILO's curriculum uses these are:

  * facility location (Appendix D.1.1):
        gain(j) = sum_i max(0, s[i, j] - mx[i])
    where ``mx[i]`` is the current per-ground-point coverage
    ``max_{k in S} s[i, k]``;
  * graph cut (Appendix D.1.2, lambda-weighted):
        gain(j) = colsum[j] - 2*lambda*covered[j] - lambda*s[j, j]
    where ``colsum[j] = sum_i s[i, j]`` is a one-time reduction and
    ``covered`` is maintained incrementally by the coordinator.

Both are bandwidth-bound reductions over the similarity kernel — VPU work,
not MXU work — tiled so each grid step streams one ``(TI, TJ)`` block of
``s`` through VMEM and accumulates into a ``(TJ,)`` output block. The
reduction grid dimension is innermost; ``pl.when(i == 0)`` zeroes the
accumulator on the first pass (the canonical Pallas accumulation pattern).

interpret=True for CPU-PJRT executability; numerics validated against
``ref.py`` in python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile edges for the reduction kernels. TI (rows reduced per step) is kept
# larger than TJ (candidates per step) because rows are streamed once per
# candidate tile; VMEM per step = TI*TJ*4 + TI*4 + TJ*4 bytes ~ 0.26 MB.
DEFAULT_TI = 256
DEFAULT_TJ = 256


def _fl_gain_kernel(s_ref, mx_ref, o_ref):
    j = pl.program_id(1)  # reduction dim over row tiles

    @pl.when(j == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    s = s_ref[...]
    mx = mx_ref[...]
    o_ref[...] += jnp.sum(jnp.maximum(s - mx[:, None], 0.0), axis=0)


@functools.partial(jax.jit, static_argnames=("ti", "tj"))
def facility_location_gains(
    s: jax.Array, mx: jax.Array, *, ti: int = DEFAULT_TI, tj: int = DEFAULT_TJ
):
    """Marginal FL gains for all candidates.

    Args:
      s: ``(n, m)`` similarity kernel block (rows: ground set, cols:
         candidates); ``n % ti == 0``, ``m % tj == 0``.
      mx: ``(n,)`` current coverage ``max_{k in S} s[:, k]`` (zeros when S
         is empty — valid because similarities are rescaled to [0, 1]).

    Returns:
      ``(m,)`` gains.
    """
    n, m = s.shape
    if n % ti or m % tj:
        raise ValueError(f"tiles ({ti},{tj}) must divide shape {s.shape}")
    grid = (m // tj, n // ti)  # (candidate tiles, reduction tiles)
    return pl.pallas_call(
        _fl_gain_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, tj), lambda cj, ri: (ri, cj)),
            pl.BlockSpec((ti,), lambda cj, ri: (ri,)),
        ],
        out_specs=pl.BlockSpec((tj,), lambda cj, ri: (cj,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(s, mx)


def _colsum_kernel(s_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(s_ref[...], axis=0)


@functools.partial(jax.jit, static_argnames=("ti", "tj"))
def column_sums(s: jax.Array, *, ti: int = DEFAULT_TI, tj: int = DEFAULT_TJ):
    """``colsum[j] = sum_i s[i, j]`` — the graph-cut coverage term and the
    disparity-sum bootstrap, as a tiled reduction."""
    n, m = s.shape
    if n % ti or m % tj:
        raise ValueError(f"tiles ({ti},{tj}) must divide shape {s.shape}")
    return pl.pallas_call(
        _colsum_kernel,
        grid=(m // tj, n // ti),
        in_specs=[pl.BlockSpec((ti, tj), lambda cj, ri: (ri, cj))],
        out_specs=pl.BlockSpec((tj,), lambda cj, ri: (cj,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(s)


def _colmax_kernel(s_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, -jnp.inf)

    o_ref[...] = jnp.maximum(o_ref[...], jnp.max(s_ref[...], axis=0))


@functools.partial(jax.jit, static_argnames=("ti", "tj"))
def column_maxes(s: jax.Array, *, ti: int = DEFAULT_TI, tj: int = DEFAULT_TJ):
    """``colmax[j] = max_i s[i, j]`` — the disparity-min distance update
    (``min_dist[j] = 1 - colmax[j]`` over the selected rows)."""
    n, m = s.shape
    if n % ti or m % tj:
        raise ValueError(f"tiles ({ti},{tj}) must divide shape {s.shape}")
    return pl.pallas_call(
        _colmax_kernel,
        grid=(m // tj, n // ti),
        in_specs=[pl.BlockSpec((ti, tj), lambda cj, ri: (ri, cj))],
        out_specs=pl.BlockSpec((tj,), lambda cj, ri: (cj,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(s)
