"""L1 fused kernels: tiled similarity + on-device top-``K`` candidate cut.

The host-side sparse kernel build transfers a full ``(T, n)`` similarity
strip back per tile pair and reduces it to top-``knn`` on the CPU. These
graphs move the cut on-device: one execution per ``(T, T)`` tile pair
returns only the per-row top-``K`` candidate ``(vals, cols)`` — roughly
``2K/T`` of the strip bytes — plus the two auxiliaries the host merge
needs (the tile diagonal and the per-row minimum for the dot-metric
non-negativity shift).

Contract with ``rust/src/kernel/sparse.rs::device_topk_build``:

* inputs are ``a (T, e)``, ``b (T, e)``, ``valid (1,)`` (and ``gamma
  (1,)`` for RBF). ``valid`` is the number of real columns in the ``b``
  tile; columns ``>= valid`` are padding and masked to ``-inf`` before
  the cut (their returned column indices decode to global ids ``>= n``,
  which the host filters) and to ``+inf`` for the row minimum;
* outputs, in tuple order: ``vals (T, K)``, ``cols (T, K)`` (tile-local
  column indices as exact f32 — ``T <= 2^24``), ``diag (T,)`` (the tile
  diagonal, read from the ``bi == bj`` execution), ``rowmin (T,)``;
* ``jax.lax.top_k`` breaks score ties lowest-index-first — the same
  total order (score descending, column ascending) as the host
  ``row_topk``, which is what makes the device cut change transfer
  volume but never values: the host re-selects top-``knn`` from the
  merged candidates with the exact host comparator, and any true
  top-``knn`` member has fewer than ``knn <= K`` predecessors in that
  order globally, hence also within its own tile.

Like ``similarity.py``, the Pallas similarity tiles run under
``interpret=True`` (plain HLO; bit-exact vs the oracle) and the top-k
epilogue is ordinary jax around them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import similarity as S

# Per-tile candidate width. 64 bounds the transfer to 2*T*K floats per
# tile pair while admitting every `--knn <= 64` build; larger knn falls
# back to the host-side cut transparently.
DEFAULT_K = 64


def _cut(sim, valid, k):
    """Top-``k`` cut of one ``(T, T)`` similarity tile with padding-column
    masking; returns the artifact's 4-tuple (see module docs)."""
    col = jax.lax.broadcasted_iota(jnp.int32, sim.shape, 1)
    mask = col < valid[0].astype(jnp.int32)
    vals, cols = jax.lax.top_k(jnp.where(mask, sim, -jnp.inf), k)
    rowmin = jnp.min(jnp.where(mask, sim, jnp.inf), axis=1)
    diag = jnp.diagonal(sim)
    return vals, cols.astype(jnp.float32), diag, rowmin


@functools.partial(jax.jit, static_argnames=("tile", "k"))
def cosine_topk(a, b, valid, *, tile: int = S.DEFAULT_TILE, k: int = DEFAULT_K):
    """Rescaled-cosine tile + top-``k`` cut (``topk_cosine_e*``)."""
    return _cut(S.cosine_similarity(a, b, tile=tile), valid, k)


@functools.partial(jax.jit, static_argnames=("tile", "k"))
def dot_topk(a, b, valid, *, tile: int = S.DEFAULT_TILE, k: int = DEFAULT_K):
    """Raw dot-product tile + top-``k`` cut (``topk_dot_e*``); ``rowmin``
    feeds the host's global non-negativity shift."""
    return _cut(S.dot_similarity(a, b, tile=tile), valid, k)


@functools.partial(jax.jit, static_argnames=("tile", "k"))
def rbf_topk(
    a, b, valid, gamma, *, tile: int = S.DEFAULT_TILE, k: int = DEFAULT_K
):
    """RBF tile + top-``k`` cut (``topk_rbf_e*``); ``gamma`` stays a
    runtime scalar exactly as in ``sim_rbf_e*``."""
    return _cut(S.rbf_similarity(a, b, gamma, tile=tile), valid, k)


def make_embed_cosine_topk(encode, *, tile: int = S.DEFAULT_TILE, k: int = DEFAULT_K):
    """Fuse encoder -> cosine -> top-``k`` into one graph over *raw*
    feature tiles (``embed_sim_topk_{ds}``): the whole class-block chain
    collapses to one execution per tile pair, skipping the separate
    encode pass entirely. ``encode`` is a ``f(x) -> (z,)`` closure from
    ``compile.model`` (frozen weights lower to HLO constants)."""

    def fused(a, b, valid):
        (za,) = encode(a)
        (zb,) = encode(b)
        return _cut(S.cosine_similarity(za, zb, tile=tile), valid, k)

    return fused
