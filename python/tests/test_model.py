"""L2 correctness: model graphs — shapes, gradients, optimizer semantics.

These are the exact callables aot.py lowers; testing them in Python (where
we have autodiff and an eager interpreter) certifies the HLO the Rust side
executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

SPEC = M.MlpSpec(input_dim=16, hidden=8, classes=4)
B = 8


def batch(rng, spec=SPEC, b=B):
    x = rng.standard_normal((b, spec.input_dim)).astype(np.float32)
    y = rng.integers(0, spec.classes, (b,)).astype(np.int32)
    wt = np.ones((b,), np.float32)
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(wt)


def zeros_like_params(spec):
    return [jnp.zeros(s, jnp.float32) for s in spec.param_shapes]


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def test_encoder_output_normalized():
    enc = M.make_encoder(16, 32, seed=1)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 16)), jnp.float32)
    (z,) = enc(x)
    assert z.shape == (4, 32)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(z), axis=1), 1.0, atol=1e-5)


def test_encoder_deterministic_per_seed():
    x = jnp.ones((2, 16), jnp.float32)
    (z1,) = M.make_encoder(16, 32, seed=5)(x)
    (z2,) = M.make_encoder(16, 32, seed=5)(x)
    (z3,) = M.make_encoder(16, 32, seed=6)(x)
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))
    assert not np.allclose(np.asarray(z1), np.asarray(z3))


def test_encoder_weights_dims():
    w1, b1, w2 = M.make_encoder_weights(24, 32)
    assert w1.shape == (24, M.ENCODER_HIDDEN)
    assert b1.shape == (M.ENCODER_HIDDEN,)
    assert w2.shape == (M.ENCODER_HIDDEN, 32)


# ---------------------------------------------------------------------------
# init + forward
# ---------------------------------------------------------------------------


def test_init_params_shapes_and_determinism():
    p1 = M.init_params(SPEC, 3)
    p2 = M.init_params(SPEC, 3)
    p3 = M.init_params(SPEC, 4)
    for a, b, shape in zip(p1, p2, SPEC.param_shapes):
        assert a.shape == shape
        np.testing.assert_array_equal(a, b)
    assert any(not np.allclose(a, c) for a, c in zip(p1, p3))


def test_param_count_property():
    d, h, c = SPEC
    assert SPEC.n_params == d * h + h + h * h + h + h * c + c


def test_logits_shape():
    params = [jnp.asarray(p) for p in M.init_params(SPEC, 0)]
    x, _, _ = batch(np.random.default_rng(0))
    assert M.mlp_logits(params, x).shape == (B, SPEC.classes)
    assert M.mlp_penultimate(params, x).shape == (B, SPEC.hidden)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def run_step(params, mom, x, y, wt, lr=0.1, mu=0.9, wd=0.0, nesterov=0.0):
    step = M.make_train_step(SPEC)
    hp = [jnp.float32(lr), jnp.float32(mu), jnp.float32(wd), jnp.float32(nesterov)]
    out = step(*params, *mom, x, y, wt, *hp)
    return list(out[:6]), list(out[6:12]), out[12], out[13]


def test_train_step_reduces_loss():
    rng = np.random.default_rng(0)
    params = [jnp.asarray(p) for p in M.init_params(SPEC, 1)]
    mom = zeros_like_params(SPEC)
    x, y, wt = batch(rng)
    losses = []
    for _ in range(30):
        params, mom, loss, _ = run_step(params, mom, x, y, wt, lr=0.05)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses


def test_train_step_zero_lr_is_identity():
    rng = np.random.default_rng(1)
    params = [jnp.asarray(p) for p in M.init_params(SPEC, 1)]
    mom = zeros_like_params(SPEC)
    x, y, wt = batch(rng)
    new_p, _, _, _ = run_step(params, mom, x, y, wt, lr=0.0)
    for a, b in zip(params, new_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_train_step_matches_manual_sgd():
    """nesterov=0, mu=0, wd=0 -> plain SGD: w' = w - lr * grad."""
    rng = np.random.default_rng(2)
    params = [jnp.asarray(p) for p in M.init_params(SPEC, 2)]
    mom = zeros_like_params(SPEC)
    x, y, wt = batch(rng)

    def loss_fn(ps):
        return M.masked_ce_loss(ps, x, y, wt, SPEC.classes)[0]

    grads = jax.grad(loss_fn)(params)
    new_p, _, _, _ = run_step(params, mom, x, y, wt, lr=0.2, mu=0.0)
    for p, g, np_ in zip(params, grads, new_p):
        np.testing.assert_allclose(
            np.asarray(np_), np.asarray(p) - 0.2 * np.asarray(g), atol=1e-6
        )


def test_train_step_nesterov_differs_from_classical():
    rng = np.random.default_rng(3)
    params = [jnp.asarray(p) for p in M.init_params(SPEC, 3)]
    x, y, wt = batch(rng)
    mom = [jnp.ones(s, jnp.float32) * 0.1 for s in SPEC.param_shapes]
    p_classical, _, _, _ = run_step(params, mom, x, y, wt, nesterov=0.0)
    p_nesterov, _, _, _ = run_step(params, mom, x, y, wt, nesterov=1.0)
    assert any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(p_classical, p_nesterov)
    )


def test_train_step_weight_decay_shrinks_weights():
    params = [jnp.ones(s, jnp.float32) for s in SPEC.param_shapes]
    mom = zeros_like_params(SPEC)
    x = jnp.zeros((B, SPEC.input_dim), jnp.float32)  # no gradient signal thru x=0
    y = jnp.zeros((B,), jnp.int32)
    wt = jnp.zeros((B,), jnp.float32)  # masked out: grads are exactly 0
    new_p, _, _, _ = run_step(params, mom, x, y, wt, lr=0.1, mu=0.0, wd=0.5)
    # w' = w - lr*wd*w = 0.95 * w
    np.testing.assert_allclose(np.asarray(new_p[0]), 0.95, atol=1e-6)


def test_train_step_mask_ignores_padded_rows():
    rng = np.random.default_rng(4)
    params = [jnp.asarray(p) for p in M.init_params(SPEC, 4)]
    mom = zeros_like_params(SPEC)
    x, y, wt = batch(rng)
    # Same batch with 4 extra garbage rows, masked out.
    x2 = jnp.concatenate([x, 100.0 * jnp.ones((4, SPEC.input_dim))])
    y2 = jnp.concatenate([y, jnp.zeros((4,), jnp.int32)])
    wt2 = jnp.concatenate([wt, jnp.zeros((4,))])
    p_a, _, la, ca = run_step(params, mom, x, y, wt)
    p_b, _, lb, cb = run_step(params, mom, x2, y2, wt2)
    np.testing.assert_allclose(float(la), float(lb), atol=1e-6)
    np.testing.assert_allclose(float(ca), float(cb), atol=1e-6)
    for a, b in zip(p_a, p_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), lr=st.sampled_from([0.01, 0.1, 0.5]))
def test_train_step_outputs_finite(seed, lr):
    rng = np.random.default_rng(seed)
    params = [jnp.asarray(p) for p in M.init_params(SPEC, seed % 100)]
    mom = zeros_like_params(SPEC)
    x, y, wt = batch(rng)
    new_p, new_m, loss, correct = run_step(params, mom, x, y, wt, lr=lr)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(correct) <= B
    for t in new_p + new_m:
        assert np.isfinite(np.asarray(t)).all()


# ---------------------------------------------------------------------------
# eval / meta
# ---------------------------------------------------------------------------


def test_eval_batch_counts():
    rng = np.random.default_rng(5)
    params = [jnp.asarray(p) for p in M.init_params(SPEC, 5)]
    x, y, wt = batch(rng)
    loss_sum, correct = M.make_eval_batch(SPEC)(*params, x, y, wt)
    assert float(loss_sum) > 0.0
    assert 0 <= float(correct) <= B
    # masked batch -> zero contributions
    loss0, corr0 = M.make_eval_batch(SPEC)(*params, x, y, jnp.zeros_like(wt))
    assert float(loss0) == 0.0 and float(corr0) == 0.0


def test_meta_el2n_bounds_and_losses():
    rng = np.random.default_rng(6)
    params = [jnp.asarray(p) for p in M.init_params(SPEC, 6)]
    x, y, wt = batch(rng)
    losses, el2n, gemb = M.make_meta_batch(SPEC)(*params, x, y, wt)
    assert losses.shape == (B,) and el2n.shape == (B,)
    assert gemb.shape == (B, SPEC.classes)
    # EL2N = ||p - onehot||_2 is in [0, sqrt(2)]
    assert (np.asarray(el2n) >= 0).all()
    assert (np.asarray(el2n) <= np.sqrt(2.0) + 1e-5).all()
    assert (np.asarray(losses) >= 0).all()


def test_meta_gemb_rows_sum_to_zero():
    """softmax - onehot always sums to 0 across classes."""
    rng = np.random.default_rng(7)
    params = [jnp.asarray(p) for p in M.init_params(SPEC, 7)]
    x, y, wt = batch(rng)
    _, _, gemb = M.make_meta_batch(SPEC)(*params, x, y, wt)
    np.testing.assert_allclose(np.asarray(gemb).sum(axis=1), 0.0, atol=1e-5)


def test_meta_perfect_prediction_low_el2n():
    """A sample the model nails confidently has ~zero EL2N and loss."""
    spec = M.MlpSpec(4, 8, 2)
    # Build params that map x -> very confident class-0 logits for x = e0.
    params = M.init_params(spec, 0)
    x = jnp.asarray(np.eye(4, dtype=np.float32)[:2][None].repeat(1, 0)[0])[:2]
    # Instead of engineering weights, train a few steps to confidence.
    step = M.make_train_step(spec)
    ps = [jnp.asarray(p) for p in params]
    ms = [jnp.zeros(s, jnp.float32) for s in spec.param_shapes]
    y = jnp.asarray([0, 1], jnp.int32)
    wt = jnp.ones((2,), jnp.float32)
    for _ in range(200):
        out = step(
            *ps, *ms, x, y, wt,
            jnp.float32(0.5), jnp.float32(0.9), jnp.float32(0.0), jnp.float32(1.0),
        )
        ps, ms = list(out[:6]), list(out[6:12])
    losses, el2n, _ = M.make_meta_batch(spec)(*ps, x, y, wt)
    assert float(jnp.max(el2n)) < 0.1
    assert float(jnp.max(losses)) < 0.1


def test_proxy_features_normalized():
    rng = np.random.default_rng(8)
    params = [jnp.asarray(p) for p in M.init_params(SPEC, 8)]
    x, _, _ = batch(rng)
    # proxy takes only the four parameters it reads (w1, b1, w2, b2)
    (h,) = M.make_proxy_features(SPEC)(*params[:4], x)
    assert h.shape == (B, SPEC.hidden)
    norms = np.linalg.norm(np.asarray(h), axis=1)
    # relu can zero a row; non-zero rows must be unit-norm
    nz = norms > 1e-6
    np.testing.assert_allclose(norms[nz], 1.0, atol=1e-4)
