"""AOT path tests: lowering to HLO text must succeed and be loadable.

These exercise the exact `to_hlo_text` pipeline aot.py uses (stablehlo ->
XlaComputation -> HLO text) for one representative of every artifact kind,
and sanity-check the manifest/param-blob layout contract the Rust side
parses.
"""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.kernels import similarity as S

jax.config.update("jax_platform_name", "cpu")


def lower_text(fn, in_specs):
    lowered = jax.jit(fn).lower(*in_specs)
    return aot.to_hlo_text(lowered)


def test_to_hlo_text_simple():
    txt = lower_text(lambda x: (x + 1.0,), [aot.f32((2, 2))])
    assert "HloModule" in txt
    assert "ENTRY" in txt


def test_encoder_lowers_with_baked_constants():
    txt = lower_text(M.make_encoder(16, 8, seed=0), [aot.f32((4, 16))])
    assert "HloModule" in txt
    # frozen weights become constants: the entry layout takes only x
    layout = txt.splitlines()[0]
    assert "entry_computation_layout={(f32[4,16]{1,0})->" in layout


def test_train_step_lowers():
    spec = M.MlpSpec(8, 4, 3)
    pshapes = [aot.f32(s) for s in spec.param_shapes]
    ins = (
        pshapes
        + pshapes
        + [aot.f32((4, 8)), aot.i32((4,)), aot.f32((4,))]
        + [aot.scalar()] * 4
    )
    txt = lower_text(M.make_train_step(spec), ins)
    assert "HloModule" in txt


def test_pallas_sim_lowers_to_plain_hlo():
    """interpret=True must produce HLO with no custom-calls (CPU-executable)."""
    txt = lower_text(
        lambda a, b: (S.cosine_similarity(a, b, tile=64),),
        [aot.f32((64, 8)), aot.f32((64, 8))],
    )
    assert "HloModule" in txt
    assert "custom-call" not in txt.lower() or "mosaic" not in txt.lower()


def test_param_blob_roundtrip(tmp_path):
    """The .bin layout contract: concatenated row-major f32 LE arrays in
    PARAM_NAMES order — Rust slices them back out by the spec shapes."""
    spec = M.MlpSpec(6, 5, 3)
    params = M.init_params(spec, 42)
    blob = b"".join(np.ascontiguousarray(p).tobytes() for p in params)
    assert len(blob) == 4 * spec.n_params
    # decode back
    off = 0
    for p, shape in zip(params, spec.param_shapes):
        n = int(np.prod(shape))
        vals = struct.unpack(f"<{n}f", blob[off : off + 4 * n])
        np.testing.assert_allclose(np.asarray(vals).reshape(shape), p, rtol=1e-6)
        off += 4 * n


def test_manifest_dataset_registry_consistent():
    for ds, cfg in aot.DATASETS.items():
        assert cfg["input_dim"] > 0 and cfg["classes"] >= 2
        assert 128 in cfg["hidden"], f"{ds} must compile the default tier"
    for ds in aot.PROXY_DATASETS:
        assert ds in aot.DATASETS


def test_input_digest_stable():
    assert aot.input_digest() == aot.input_digest()
    assert len(aot.input_digest()) == 16


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built yet (run `make artifacts`)",
)
def test_built_manifest_matches_registry():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    with open(path) as f:
        man = json.load(f)
    assert man["batch"] == aot.BATCH
    assert man["embed_dim"] == aot.EMBED_DIM
    names = {a["name"] for a in man["artifacts"]}
    for ds in aot.DATASETS:
        assert f"encoder_{ds}" in names
        assert f"train_step_{ds}_h128" in names
    # every artifact file referenced must exist
    base = os.path.dirname(path)
    for a in man["artifacts"]:
        assert os.path.exists(os.path.join(base, a["file"])), a["file"]
