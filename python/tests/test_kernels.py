"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (tile-multiples and embed dims) and value ranges;
every kernel must match its ref.py oracle to float32 tolerance. This is the
core correctness signal for layer 1 — the Rust side consumes exactly these
lowered graphs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gains as G
from compile.kernels import ref as R
from compile.kernels import similarity as S

jax.config.update("jax_platform_name", "cpu")

TILE = 64  # small tile for the sweeps; the AOT tile (256) is covered too


def rand(rng, shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# cosine similarity
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    nt=st.integers(1, 3),
    mt=st.integers(1, 3),
    e=st.sampled_from([4, 8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 100.0]),
)
def test_cosine_matches_ref(nt, mt, e, seed, scale):
    rng = np.random.default_rng(seed)
    a = rand(rng, (nt * TILE, e), scale)
    b = rand(rng, (mt * TILE, e), scale)
    got = S.cosine_similarity(jnp.asarray(a), jnp.asarray(b), tile=TILE)
    want = R.cosine_similarity_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_cosine_range_and_diagonal():
    rng = np.random.default_rng(0)
    a = rand(rng, (TILE, 16))
    s = np.asarray(S.cosine_similarity(jnp.asarray(a), jnp.asarray(a), tile=TILE))
    assert s.min() >= -1e-6 and s.max() <= 1.0 + 1e-6
    np.testing.assert_allclose(np.diag(s), 1.0, atol=1e-5)


def test_cosine_symmetry():
    rng = np.random.default_rng(7)
    a = rand(rng, (TILE, 32))
    s = np.asarray(S.cosine_similarity(jnp.asarray(a), jnp.asarray(a), tile=TILE))
    np.testing.assert_allclose(s, s.T, atol=1e-6)


def test_cosine_default_tile_256():
    rng = np.random.default_rng(3)
    a = rand(rng, (256, 32))
    b = rand(rng, (512, 32))
    got = S.cosine_similarity(jnp.asarray(a), jnp.asarray(b))
    want = R.cosine_similarity_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_cosine_rejects_nonmultiple():
    a = jnp.zeros((100, 8), jnp.float32)
    with pytest.raises(ValueError):
        S.cosine_similarity(a, a, tile=64)


def test_cosine_zero_rows_safe():
    """A zero feature row must not produce NaNs (eps floor in the norm)."""
    a = np.zeros((TILE, 8), np.float32)
    a[1:] = np.random.default_rng(1).standard_normal((TILE - 1, 8))
    s = np.asarray(S.cosine_similarity(jnp.asarray(a), jnp.asarray(a), tile=TILE))
    assert np.isfinite(s).all()


# ---------------------------------------------------------------------------
# dot / rbf similarity
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    nt=st.integers(1, 2),
    e=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dot_matches_ref(nt, e, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, (nt * TILE, e))
    b = rand(rng, (TILE, e))
    got = S.dot_similarity(jnp.asarray(a), jnp.asarray(b), tile=TILE)
    want = R.dot_similarity_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    gamma=st.sampled_from([0.01, 0.1, 1.0, 10.0]),
)
def test_rbf_matches_ref(seed, gamma):
    rng = np.random.default_rng(seed)
    a = rand(rng, (TILE, 16))
    b = rand(rng, (TILE, 16))
    got = S.rbf_similarity(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray([gamma], jnp.float32), tile=TILE
    )
    want = R.rbf_similarity_ref(jnp.asarray(a), jnp.asarray(b), gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_rbf_identity_diagonal():
    rng = np.random.default_rng(5)
    a = rand(rng, (TILE, 8))
    s = np.asarray(
        S.rbf_similarity(
            jnp.asarray(a), jnp.asarray(a), jnp.asarray([0.5], jnp.float32), tile=TILE
        )
    )
    np.testing.assert_allclose(np.diag(s), 1.0, atol=1e-5)
    assert (s <= 1.0 + 1e-6).all() and (s >= 0.0).all()


# ---------------------------------------------------------------------------
# gain kernels (tiled accumulating reductions)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    ri=st.integers(1, 3),
    cj=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_fl_gains_match_ref(ri, cj, seed):
    rng = np.random.default_rng(seed)
    s = rng.uniform(0, 1, (ri * TILE, cj * TILE)).astype(np.float32)
    mx = rng.uniform(0, 1, (ri * TILE,)).astype(np.float32)
    got = G.facility_location_gains(jnp.asarray(s), jnp.asarray(mx), ti=TILE, tj=TILE)
    want = R.facility_location_gains_ref(jnp.asarray(s), jnp.asarray(mx))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_fl_gains_zero_when_covered():
    """If mx already dominates every similarity, all gains are zero."""
    s = np.full((TILE, TILE), 0.3, np.float32)
    mx = np.full((TILE,), 0.9, np.float32)
    got = np.asarray(
        G.facility_location_gains(jnp.asarray(s), jnp.asarray(mx), ti=TILE, tj=TILE)
    )
    np.testing.assert_allclose(got, 0.0)


def test_fl_gains_empty_subset_is_colsum():
    """With mx = 0 (empty subset, sims in [0,1]) gains reduce to colsums."""
    rng = np.random.default_rng(11)
    s = rng.uniform(0, 1, (2 * TILE, TILE)).astype(np.float32)
    mx = np.zeros((2 * TILE,), np.float32)
    got = np.asarray(
        G.facility_location_gains(jnp.asarray(s), jnp.asarray(mx), ti=TILE, tj=TILE)
    )
    np.testing.assert_allclose(got, s.sum(axis=0), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    ri=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_colsum_matches_ref(ri, seed):
    rng = np.random.default_rng(seed)
    s = rng.uniform(-2, 2, (ri * TILE, TILE)).astype(np.float32)
    got = G.column_sums(jnp.asarray(s), ti=TILE, tj=TILE)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(R.column_sums_ref(jnp.asarray(s))), rtol=1e-4,
        atol=1e-4,
    )


@settings(max_examples=20, deadline=None)
@given(
    ri=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_colmax_matches_ref(ri, seed):
    rng = np.random.default_rng(seed)
    s = rng.uniform(-2, 2, (ri * TILE, TILE)).astype(np.float32)
    got = G.column_maxes(jnp.asarray(s), ti=TILE, tj=TILE)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(R.column_maxes_ref(jnp.asarray(s)))
    )


def test_gain_kernels_reject_nonmultiple():
    s = jnp.zeros((100, 64), jnp.float32)
    with pytest.raises(ValueError):
        G.column_sums(s, ti=64, tj=64)
    with pytest.raises(ValueError):
        G.facility_location_gains(s, jnp.zeros((100,), jnp.float32), ti=64, tj=64)
