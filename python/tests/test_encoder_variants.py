"""Fig-11 encoder variants: every variant must satisfy the encoder
contract (deterministic, unit-norm rows, frozen weights baked in) while
producing *distinct* feature geometries — that distinctness is what the
Fig-11 ablation sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

VARIANTS = list(M.ENCODER_VARIANTS)


def encode(variant, x):
    fn = M.make_encoder_variant(x.shape[1], variant)
    (z,) = jax.jit(fn)(jnp.asarray(x))
    return np.asarray(z)


@pytest.mark.parametrize("variant", VARIANTS)
def test_variant_rows_are_unit_norm(variant):
    rng = np.random.default_rng(1)
    x = rng.normal(size=(32, 64)).astype(np.float32)
    z = encode(variant, x)
    e = M.ENCODER_VARIANTS[variant][0]
    assert z.shape == (32, e)
    np.testing.assert_allclose(np.linalg.norm(z, axis=1), 1.0, atol=1e-4)


@pytest.mark.parametrize("variant", VARIANTS)
def test_variant_is_deterministic(variant):
    rng = np.random.default_rng(2)
    x = rng.normal(size=(8, 48)).astype(np.float32)
    np.testing.assert_array_equal(encode(variant, x), encode(variant, x))


def test_variants_differ_from_default():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(16, 64)).astype(np.float32)
    base = encode("cls32", x)
    for variant in VARIANTS:
        if variant == "cls32":
            continue
        z = encode(variant, x)
        if z.shape == base.shape:
            assert not np.allclose(z, base, atol=1e-5), variant


def test_cls32_matches_default_encoder():
    # the cls32 variant IS the default encoder — same weights, same output
    rng = np.random.default_rng(4)
    x = rng.normal(size=(8, 64)).astype(np.float32)
    (want,) = jax.jit(M.make_encoder(64, 32))(jnp.asarray(x))
    got = encode("cls32", x)
    np.testing.assert_allclose(got, np.asarray(want), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    d=st.sampled_from([16, 48, 64, 256]),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
    variant=st.sampled_from(VARIANTS),
)
def test_variant_shape_sweep(d, n, seed, variant):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    z = encode(variant, x)
    e = M.ENCODER_VARIANTS[variant][0]
    assert z.shape == (n, e)
    assert np.isfinite(z).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), variant=st.sampled_from(VARIANTS))
def test_variant_preserves_neighborhoods(seed, variant):
    # two nearby inputs must stay closer in embedding space than a far one
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(64,)).astype(np.float32)
    near = a + 0.01 * rng.normal(size=(64,)).astype(np.float32)
    far = rng.normal(size=(64,)).astype(np.float32)
    z = encode(variant, np.stack([a, near, far]))
    sim_near = float(z[0] @ z[1])
    sim_far = float(z[0] @ z[2])
    assert sim_near > sim_far, f"{variant}: {sim_near} <= {sim_far}"


def test_variant_lowering_to_hlo_text():
    # each variant must lower through the same AOT path as the default
    from compile.aot import to_hlo_text

    for variant in VARIANTS:
        fn = M.make_encoder_variant(64, variant)
        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((8, 64), jnp.float32))
        text = to_hlo_text(lowered)
        assert "ENTRY" in text and len(text) > 100, variant
