//! The flight recorder: an always-on, fixed-size, lock-free ring of
//! recent span and request events, with tail-sampling.
//!
//! `MILO_TRACE` answers "what happened?" only when someone turned it on
//! *before* the incident. The flight recorder is the black box for
//! everything else: it is on by default, bounded (a power-of-two ring of
//! [`RING_SLOTS`] fixed-size slots — no allocation, no unbounded growth),
//! and cheap enough to leave on in production (`bench_serve` measures and
//! asserts its marginal cost on the `NEXT_SUBSET` hot path).
//!
//! # Recording
//!
//! Every finished [`Span`](super::Span) lands one `span` event in the
//! ring; the serve dispatch path lands one `request` event per request
//! (command name, trace id, latency, error flag, stream id). Writers
//! claim a slot with one relaxed `fetch_add` and publish through a
//! per-slot sequence word (seqlock): readers that race a writer see a
//! torn sequence and skip the slot instead of blocking it. The ring is
//! best-effort by design — if it wraps mid-read the reader drops that
//! slot, never the process.
//!
//! # Tail-sampling
//!
//! A request slower than the slow threshold (`MILO_FLIGHT_SLOW_US`,
//! default 100 ms, adjustable at runtime via [`set_slow_threshold_us`])
//! or ending in error triggers a sample: every ring event sharing the
//! request's trace id is copied out into a bounded in-memory buffer
//! ([`samples`], newest [`MAX_SAMPLES`]) and — when `MILO_TRACE` is
//! configured — flushed to the trace sink as schema-v2 lines. The whole
//! span tree of a slow request is therefore available *after the fact*
//! even though nobody was tracing when it happened.
//!
//! # Surfaces
//!
//! * `GET /flight` on the serve metrics listener → [`dump_jsonl`] (the
//!   ring, oldest first, plus sampled traces, as JSON lines);
//! * the `FLIGHT` serve command → [`stats_json`] + per-sample summaries;
//! * [`set_enabled(false)`](set_enabled) — the recorder's own kill
//!   switch, independent of [`super::set_enabled`], so the bench can
//!   measure the recorder's marginal cost with spans still on.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use crate::util::json::Json;

use super::trace;

/// Ring capacity (slots); a power of two so slot = ticket & (N-1).
pub const RING_SLOTS: usize = 4096;

/// Sampled traces kept in memory (older samples are dropped first).
pub const MAX_SAMPLES: usize = 32;

/// Span/command names are truncated to this many bytes in ring slots.
pub const MAX_NAME: usize = 40;

const DEFAULT_SLOW_US: u64 = 100_000;

static FLIGHT_ENABLED: AtomicBool = AtomicBool::new(true);
// 0 = unresolved: first read resolves MILO_FLIGHT_SLOW_US (or the
// default); set_slow_threshold_us stores max(1, v) so 0 stays reserved.
static SLOW_US: AtomicU64 = AtomicU64::new(0);
static HEAD: AtomicU64 = AtomicU64::new(0);
static RECORDED: AtomicU64 = AtomicU64::new(0);
static SAMPLED: AtomicU64 = AtomicU64::new(0);

/// Enable/disable the flight recorder (default: enabled). Independent of
/// the span kill switch so each layer's overhead is measurable alone.
pub fn set_enabled(on: bool) {
    FLIGHT_ENABLED.store(on, Ordering::SeqCst);
}

/// Whether the flight recorder is recording.
pub fn enabled() -> bool {
    FLIGHT_ENABLED.load(Ordering::Relaxed)
}

/// The tail-sampling latency threshold in microseconds. First call
/// resolves `MILO_FLIGHT_SLOW_US` (default 100 000 µs = 100 ms).
pub fn slow_threshold_us() -> u64 {
    let v = SLOW_US.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let resolved = std::env::var("MILO_FLIGHT_SLOW_US")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .filter(|&us| us > 0)
        .unwrap_or(DEFAULT_SLOW_US);
    // racing first-readers may both store; they store the same value
    let _ = SLOW_US.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed);
    SLOW_US.load(Ordering::Relaxed)
}

/// Override the tail-sampling threshold at runtime (clamped to ≥ 1 µs —
/// 1 effectively samples every request; benches use that to demonstrate
/// capture without a genuinely slow request).
pub fn set_slow_threshold_us(us: u64) {
    SLOW_US.store(us.max(1), Ordering::Relaxed);
}

#[derive(Clone, Copy)]
struct SlotData {
    kind: u8, // 0 = empty, 1 = span, 2 = request
    err: bool,
    stream: u8,
    name_len: u8,
    name: [u8; MAX_NAME],
    trace: u64,
    span: u64,
    parent: u64,
    t_us: u64,
    us: u64,
}

const EMPTY_SLOT: SlotData = SlotData {
    kind: 0,
    err: false,
    stream: 0,
    name_len: 0,
    name: [0; MAX_NAME],
    trace: 0,
    span: 0,
    parent: 0,
    t_us: 0,
    us: 0,
};

struct Slot {
    // 0 = never written; writer stores 2·ticket+1 (in progress) then
    // 2·ticket+2 (published); readers require an even, matching pair
    seq: AtomicU64,
    data: UnsafeCell<SlotData>,
}

struct Ring {
    slots: Box<[Slot]>,
}

// Safety: slot payloads are only accessed under the per-slot seqlock
// protocol — writers publish through `seq` with Release, readers
// validate with Acquire and discard torn reads. A reader never
// dereferences a slot mid-write without detecting it via `seq`.
unsafe impl Sync for Ring {}

static RING: OnceLock<Ring> = OnceLock::new();

fn ring() -> &'static Ring {
    RING.get_or_init(|| {
        let slots = (0..RING_SLOTS)
            .map(|_| Slot { seq: AtomicU64::new(0), data: UnsafeCell::new(EMPTY_SLOT) })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Ring { slots }
    })
}

fn named_slot(kind: u8, name: &str) -> SlotData {
    let mut data = EMPTY_SLOT;
    data.kind = kind;
    let n = name.len().min(MAX_NAME);
    data.name[..n].copy_from_slice(&name.as_bytes()[..n]);
    data.name_len = n as u8;
    data
}

fn write_event(mut data: SlotData) {
    data.t_us = trace::now_us() as u64;
    let ring = ring();
    let ticket = HEAD.fetch_add(1, Ordering::Relaxed);
    let slot = &ring.slots[(ticket as usize) & (RING_SLOTS - 1)];
    slot.seq.store(ticket * 2 + 1, Ordering::Release);
    // Safety: see the `Sync` impl — publication is ordered by `seq`.
    unsafe { *slot.data.get() = data };
    slot.seq.store(ticket * 2 + 2, Ordering::Release);
    RECORDED.fetch_add(1, Ordering::Relaxed);
}

fn read_slot(slot: &Slot) -> Option<SlotData> {
    let before = slot.seq.load(Ordering::Acquire);
    if before == 0 || before % 2 == 1 {
        return None; // never written, or a write is in flight
    }
    // Safety: the copy is validated below — a concurrent overwrite flips
    // `seq`, and we discard the (possibly torn) copy.
    let data = unsafe { *slot.data.get() };
    let after = slot.seq.load(Ordering::Acquire);
    (before == after).then_some(data)
}

/// One event copied out of the ring (owned, safe to hold).
#[derive(Clone, Debug)]
pub struct FlightEvent {
    /// `"span"` or `"request"`.
    pub ev: &'static str,
    pub name: String,
    pub trace: u64,
    pub span: u64,
    pub parent: u64,
    /// Microseconds since the process trace epoch (when recorded).
    pub t_us: u64,
    /// Elapsed microseconds.
    pub us: u64,
    pub err: bool,
    pub stream: u8,
}

impl FlightEvent {
    fn from_slot(d: &SlotData) -> Option<FlightEvent> {
        let ev = match d.kind {
            1 => "span",
            2 => "request",
            _ => return None,
        };
        let name = std::str::from_utf8(&d.name[..d.name_len as usize])
            .unwrap_or("")
            .to_string();
        Some(FlightEvent {
            ev,
            name,
            trace: d.trace,
            span: d.span,
            parent: d.parent,
            t_us: d.t_us,
            us: d.us,
            err: d.err,
            stream: d.stream,
        })
    }

    /// The schema-v2 JSON object for this event (what `MILO_TRACE` lines
    /// and the `/flight` dump contain).
    pub fn to_json(&self) -> Json {
        let mut j = trace::event_json(
            self.ev,
            &self.name,
            self.t_us as f64,
            self.us as f64,
            self.trace,
            self.span,
            self.parent,
        );
        if self.ev == "request" {
            if let Json::Obj(m) = &mut j {
                m.insert("stream".to_string(), Json::num(self.stream as f64));
                if self.err {
                    m.insert("err".to_string(), Json::Bool(true));
                }
            }
        }
        j
    }
}

/// A tail-sampled request: the triggering request plus every ring event
/// that shared its trace id at sampling time, oldest first.
#[derive(Clone, Debug)]
pub struct SampledTrace {
    pub trace: u64,
    /// The triggering request's command name.
    pub cmd: String,
    /// The triggering request's latency in microseconds.
    pub us: u64,
    pub err: bool,
    /// Sample time (process trace-epoch microseconds).
    pub t_us: u64,
    pub events: Vec<FlightEvent>,
}

static SAMPLES: Mutex<VecDeque<SampledTrace>> = Mutex::new(VecDeque::new());

/// Record a finished span. Called from [`Span`](super::Span) teardown; a
/// no-op when the recorder is disabled.
pub fn record_span(name: &str, elapsed: Duration, trace: u64, span: u64, parent: u64) {
    if !enabled() {
        return;
    }
    let mut data = named_slot(1, name);
    data.us = elapsed.as_micros() as u64;
    data.trace = trace;
    data.span = span;
    data.parent = parent;
    write_event(data);
}

/// Record a finished request (the serve dispatch path) and apply the
/// tail-sampling decision: slower than [`slow_threshold_us`] or `err`
/// samples the whole trace. A no-op when the recorder is disabled.
pub fn record_request(cmd: &str, trace: u64, span: u64, us: u64, err: bool, stream: u8) {
    if !enabled() {
        return;
    }
    let mut data = named_slot(2, cmd);
    data.us = us;
    data.trace = trace;
    data.span = span;
    data.err = err;
    data.stream = stream;
    write_event(data);
    if trace != 0 && (err || us >= slow_threshold_us()) {
        sample_trace(trace, cmd, us, err);
    }
}

fn sample_trace(trace_id: u64, cmd: &str, us: u64, err: bool) {
    let mut events: Vec<FlightEvent> = snapshot_events()
        .into_iter()
        .filter(|e| e.trace == trace_id)
        .collect();
    events.sort_by_key(|e| e.t_us);
    let sample = SampledTrace {
        trace: trace_id,
        cmd: cmd.to_string(),
        us,
        err,
        t_us: trace::now_us() as u64,
        events,
    };
    // flush to the MILO_TRACE sink (no-op when unset): request events
    // are not emitted by Span teardown, so the sampled tree's request
    // line only exists in the sink via this path
    if trace::enabled() {
        for e in &sample.events {
            if e.ev == "request" {
                trace::emit_line(&e.to_json().to_string());
            }
        }
    }
    let mut samples = SAMPLES.lock().unwrap();
    while samples.len() >= MAX_SAMPLES {
        samples.pop_front();
    }
    samples.push_back(sample);
    SAMPLED.fetch_add(1, Ordering::Relaxed);
}

/// Copy the current ring contents, oldest first (best effort — slots
/// being overwritten while reading are skipped).
pub fn snapshot_events() -> Vec<FlightEvent> {
    let ring = ring();
    let head = HEAD.load(Ordering::Acquire);
    let span = (head as usize).min(RING_SLOTS);
    let mut out = Vec::with_capacity(span);
    // walk tickets oldest → newest so the copy is chronologically ordered
    let start = head.saturating_sub(RING_SLOTS as u64);
    for ticket in start..head {
        let slot = &ring.slots[(ticket as usize) & (RING_SLOTS - 1)];
        if let Some(d) = read_slot(slot) {
            if let Some(e) = FlightEvent::from_slot(&d) {
                out.push(e);
            }
        }
    }
    out
}

/// The tail-sampled traces currently buffered, oldest first.
pub fn samples() -> Vec<SampledTrace> {
    SAMPLES.lock().unwrap().iter().cloned().collect()
}

/// Recorder counters for `FLIGHT` / `STATS` surfaces.
#[derive(Clone, Copy, Debug)]
pub struct FlightStats {
    pub enabled: bool,
    /// Events ever recorded (monotone).
    pub recorded: u64,
    /// Events already overwritten by ring wrap-around.
    pub overwritten: u64,
    /// Tail-samples taken (monotone).
    pub sampled: u64,
    pub slow_threshold_us: u64,
    pub slots: usize,
}

pub fn stats() -> FlightStats {
    let recorded = RECORDED.load(Ordering::Relaxed);
    FlightStats {
        enabled: enabled(),
        recorded,
        overwritten: recorded.saturating_sub(RING_SLOTS as u64),
        sampled: SAMPLED.load(Ordering::Relaxed),
        slow_threshold_us: slow_threshold_us(),
        slots: RING_SLOTS,
    }
}

/// [`stats`] as JSON (the `FLIGHT` serve reply and `/flight` header).
pub fn stats_json() -> Json {
    let s = stats();
    Json::obj(vec![
        ("enabled", Json::Bool(s.enabled)),
        ("recorded", Json::num(s.recorded as f64)),
        ("overwritten", Json::num(s.overwritten as f64)),
        ("sampled", Json::num(s.sampled as f64)),
        ("slow_threshold_us", Json::num(s.slow_threshold_us as f64)),
        ("slots", Json::num(s.slots as f64)),
    ])
}

/// The `/flight` dump: one `flight` header line (the stats), then the
/// ring contents oldest-first, then each buffered tail-sample as a
/// `sample` line followed by its events — all schema-v2 JSON lines, so
/// `milo trace` can read the dump directly.
pub fn dump_jsonl() -> String {
    let mut out = String::new();
    let mut header = stats_json();
    if let Json::Obj(m) = &mut header {
        m.insert("ev".to_string(), Json::str("flight"));
    }
    out.push_str(&header.to_string());
    out.push('\n');
    for e in snapshot_events() {
        out.push_str(&e.to_json().to_string());
        out.push('\n');
    }
    for s in samples() {
        let marker = Json::obj(vec![
            ("ev", Json::str("sample")),
            ("cmd", Json::str(s.cmd.as_str())),
            ("err", Json::Bool(s.err)),
            ("t_us", Json::num(s.t_us as f64)),
            ("trace", Json::Str(super::id_hex(s.trace))),
            ("us", Json::num(s.us as f64)),
        ]);
        out.push_str(&marker.to_string());
        out.push('\n');
        for e in &s.events {
            out.push_str(&e.to_json().to_string());
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // one test: the ring, counters, and samples are process-global, and
    // the harness runs tests concurrently — a single linear scenario
    // avoids cross-test interference on the shared state
    #[test]
    fn records_samples_and_dumps() {
        assert!(enabled());
        let trace_id = crate::obs::next_id();
        let span_a = crate::obs::next_id();
        let span_b = crate::obs::next_id();
        record_span("flight_test.child", Duration::from_micros(5), trace_id, span_b, span_a);
        let before = stats().sampled;
        // a fast, error-free request: recorded but not sampled
        record_request("ping", trace_id, span_a, 1, false, 0);
        assert_eq!(stats().sampled, before);
        // an erroring request tail-samples regardless of latency
        record_request("get_meta", trace_id, span_a, 2, true, 3);
        let stats_now = stats();
        assert_eq!(stats_now.sampled, before + 1);
        assert!(stats_now.recorded >= 3);
        let all = samples();
        let s = all.iter().rfind(|s| s.trace == trace_id).expect("sample captured");
        assert_eq!(s.cmd, "get_meta");
        assert!(s.err);
        // the sample holds the whole trace: the child span and both requests
        assert!(s.events.iter().any(|e| e.ev == "span" && e.name == "flight_test.child"));
        assert!(s
            .events
            .iter()
            .any(|e| e.ev == "request" && e.name == "get_meta" && e.err && e.stream == 3));
        // events are chronological and share the trace id
        assert!(s.events.windows(2).all(|w| w[0].t_us <= w[1].t_us));
        assert!(s.events.iter().all(|e| e.trace == trace_id));

        let dump = dump_jsonl();
        let hex = crate::obs::id_hex(trace_id);
        assert!(dump.lines().next().unwrap().contains("\"ev\":\"flight\""));
        assert!(dump.contains(&hex));
        assert!(dump.contains("\"ev\":\"sample\""));
        // every line is valid JSON (the dump feeds `milo trace`)
        for line in dump.lines() {
            crate::util::json::Json::parse(line).expect("dump line parses");
        }

        // disabled: nothing lands
        set_enabled(false);
        let recorded = stats().recorded;
        record_span("flight_test.off", Duration::from_micros(1), trace_id, span_b, 0);
        record_request("ping", trace_id, span_a, u64::MAX, true, 0);
        set_enabled(true);
        assert_eq!(stats().recorded, recorded);

        // names longer than MAX_NAME truncate, never panic
        let long = "x".repeat(MAX_NAME * 2);
        record_span(&long, Duration::from_micros(1), trace_id, span_b, 0);
        let snap = snapshot_events();
        assert!(snap.iter().any(|e| e.name.len() == MAX_NAME && e.name.starts_with('x')));
    }
}
