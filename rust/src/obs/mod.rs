//! Unified telemetry: metric registries, latency histograms, and scoped
//! trace spans — zero new dependencies.
//!
//! The paper's headline axis is wall-clock, so the reproduction treats
//! timing as first-class infrastructure rather than scattered ad-hoc
//! counters. One subsystem feeds every surface: the serve `STATS` reply,
//! the `milo serve --metrics-addr` Prometheus-style exposition endpoint,
//! `BENCH_serve.json`, and the optional `MILO_TRACE` event log.
//!
//! # Pieces
//!
//! * [`MetricsRegistry`] — a named map of atomic counters, gauges, and
//!   [`Histogram`]s. Registries are cheap-`Clone` handles and can be
//!   per-component (each `MetaStore` and each `SubsetServer` owns one, so
//!   their stats stay independent) or process-global
//!   ([`MetricsRegistry::global`], which collects [`Span`] timings).
//!   Handle types ([`Counter`], [`Gauge`], `Arc<Histogram>`) are resolved
//!   once at construction; hot paths never take the registry lock.
//! * [`Histogram`] — log-bucketed latency distribution (see
//!   [`hist`] for the bucket math: 8 sub-buckets per power of two,
//!   ≤ 12.5% relative error, exact below 16 ns, saturating at ~18 min).
//!   Mergeable across threads; percentile queries return exact bucket
//!   upper bounds.
//! * [`Span`] — a scoped timer. On drop it records its elapsed time into
//!   the global registry under `span.<name>` and, when `MILO_TRACE=path`
//!   is set, appends a JSON-lines event (see [`trace`] for the schema).
//!   [`Stopwatch`](crate::util::timer::Stopwatch) sections ride on spans,
//!   so legacy `sw.time("selection", ..)` call sites feed the same
//!   telemetry.
//!
//! # Metric naming scheme
//!
//! Dotted lowercase paths, `<component>.<metric>[_<unit>][.<variant>]`:
//!
//! * `serve.requests`, `serve.accept_errors` — counters;
//! * `serve.open_connections`, `serve.wbuf_high_water` — gauges;
//! * `serve.request_latency_ns.next_subset`, `store.build_latency_ns`,
//!   `span.preprocess.sge` — histograms (values in nanoseconds; summaries
//!   render in microseconds).
//!
//! The text exposition ([`MetricsRegistry::render_text`]) maps a dotted
//! name to `milo_` + the name with non-`[A-Za-z0-9_]` characters replaced
//! by `_`, rendering histograms as Prometheus summaries (quantile series
//! plus `_sum`/`_count`).
//!
//! # Kill switch
//!
//! [`set_enabled(false)`](set_enabled) turns all span/latency recording
//! into no-ops (counters still tick — they predate this layer and cost a
//! single relaxed add). `bench_serve` uses it to *measure* the telemetry
//! overhead on the `NEXT_SUBSET` path instead of assuming it.

pub mod hist;
pub mod trace;

pub use hist::{Histogram, HistogramSnapshot};

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::util::json::Json;

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Globally enable/disable latency recording (spans and timed-path
/// histograms). Counters are unaffected. Used by benches to measure
/// instrumentation overhead; defaults to enabled.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// Whether latency recording is enabled.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// A monotone counter handle (relaxed-atomic, cheap `Clone`).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge handle: a settable value with high-water helpers.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if it is currently lower.
    pub fn record_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn dec(&self, n: u64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Clone)]
enum Metric {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Histogram(Arc<Histogram>),
}

/// A named collection of metrics. `Clone` is cheap (one `Arc`); all
/// clones share the same metrics. Lookup/creation takes a lock — resolve
/// handles once and store them, as `serve`/`store` do.
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    metrics: Arc<Mutex<BTreeMap<String, Metric>>>,
}

static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// The process-global registry: [`Span`]s and other component-less
    /// telemetry (preprocess stages, session resolution) record here.
    pub fn global() -> &'static MetricsRegistry {
        GLOBAL.get_or_init(MetricsRegistry::new)
    }

    /// Get-or-create the counter `name`. If `name` is already registered
    /// as a different kind, a detached (unexported) handle is returned.
    pub fn counter(&self, name: impl Into<Cow<'static, str>>) -> Counter {
        let name = name.into();
        let mut metrics = self.metrics.lock().unwrap();
        if let Some(Metric::Counter(c)) = metrics.get(name.as_ref()) {
            return Counter(c.clone());
        }
        if metrics.contains_key(name.as_ref()) {
            return Counter(Arc::new(AtomicU64::new(0)));
        }
        let cell = Arc::new(AtomicU64::new(0));
        metrics.insert(name.into_owned(), Metric::Counter(cell.clone()));
        Counter(cell)
    }

    /// Get-or-create the gauge `name` (same mismatch rule as `counter`).
    pub fn gauge(&self, name: impl Into<Cow<'static, str>>) -> Gauge {
        let name = name.into();
        let mut metrics = self.metrics.lock().unwrap();
        if let Some(Metric::Gauge(g)) = metrics.get(name.as_ref()) {
            return Gauge(g.clone());
        }
        if metrics.contains_key(name.as_ref()) {
            return Gauge(Arc::new(AtomicU64::new(0)));
        }
        let cell = Arc::new(AtomicU64::new(0));
        metrics.insert(name.into_owned(), Metric::Gauge(cell.clone()));
        Gauge(cell)
    }

    /// Get-or-create the histogram `name` (same mismatch rule as
    /// `counter`).
    pub fn histogram(&self, name: impl Into<Cow<'static, str>>) -> Arc<Histogram> {
        let name = name.into();
        let mut metrics = self.metrics.lock().unwrap();
        if let Some(Metric::Histogram(h)) = metrics.get(name.as_ref()) {
            return h.clone();
        }
        if metrics.contains_key(name.as_ref()) {
            return Arc::new(Histogram::new());
        }
        let h = Arc::new(Histogram::new());
        metrics.insert(name.into_owned(), Metric::Histogram(h.clone()));
        h
    }

    /// Render every metric as one JSON object: counters/gauges as
    /// numbers, histograms as summary objects (`count`, `p50_us`,
    /// `p95_us`, `p99_us`, `max_us`, `mean_us`, `saturated`). This is the
    /// single registry→JSON path shared by the serve STATS reply for both
    /// the server and store registries.
    pub fn to_json(&self) -> Json {
        let metrics = self.metrics.lock().unwrap();
        let mut obj = BTreeMap::new();
        for (name, metric) in metrics.iter() {
            let v = match metric {
                Metric::Counter(c) => Json::num(c.load(Ordering::Relaxed) as f64),
                Metric::Gauge(g) => Json::num(g.load(Ordering::Relaxed) as f64),
                Metric::Histogram(h) => h.snapshot().summary_json(),
            };
            obj.insert(name.clone(), v);
        }
        Json::Obj(obj)
    }

    /// Append a plain-text Prometheus-style exposition of every metric to
    /// `out` (see the module docs for the name mapping). Histograms render
    /// as summaries; values are in their recorded unit (nanoseconds for
    /// latency histograms).
    pub fn render_text(&self, out: &mut String) {
        let metrics = self.metrics.lock().unwrap();
        for (name, metric) in metrics.iter() {
            let mut id = String::with_capacity(name.len() + 5);
            id.push_str("milo_");
            for ch in name.chars() {
                id.push(if ch.is_ascii_alphanumeric() || ch == '_' { ch } else { '_' });
            }
            match metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "# TYPE {id} counter");
                    let _ = writeln!(out, "{id} {}", c.load(Ordering::Relaxed));
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "# TYPE {id} gauge");
                    let _ = writeln!(out, "{id} {}", g.load(Ordering::Relaxed));
                }
                Metric::Histogram(h) => {
                    let s = h.snapshot();
                    let _ = writeln!(out, "# TYPE {id} summary");
                    for (q, label) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                        let _ = writeln!(
                            out,
                            "{id}{{quantile=\"{label}\"}} {}",
                            s.percentile(q)
                        );
                    }
                    let _ = writeln!(out, "{id}_sum {}", s.sum());
                    let _ = writeln!(out, "{id}_count {}", s.count());
                }
            }
        }
    }
}

/// A scoped timer. Created with [`Span::enter`]; on drop (or explicit
/// [`finish`](Span::finish)) it records its elapsed time into the global
/// registry's `span.<name>` histogram and emits a `MILO_TRACE` event when
/// tracing is configured. When telemetry is disabled ([`set_enabled`]),
/// entering a span is a single relaxed load.
pub struct Span {
    name: Cow<'static, str>,
    start: Option<Instant>,
}

impl Span {
    pub fn enter(name: impl Into<Cow<'static, str>>) -> Span {
        Span { name: name.into(), start: enabled().then(Instant::now) }
    }

    /// End the span now, returning its measured duration (zero when
    /// telemetry was disabled at entry).
    pub fn finish(mut self) -> Duration {
        self.finish_inner()
    }

    fn finish_inner(&mut self) -> Duration {
        let Some(start) = self.start.take() else { return Duration::ZERO };
        let d = start.elapsed();
        MetricsRegistry::global()
            .histogram(format!("span.{}", self.name))
            .record_duration(d);
        trace::emit_span(&self.name, d);
        d
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        self.finish_inner();
    }
}

/// Run `f` inside a span named `name`.
pub fn time<R>(name: impl Into<Cow<'static, str>>, f: impl FnOnce() -> R) -> R {
    let _span = Span::enter(name);
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_roundtrip() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("t.count");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same name resolves to the same cell
        assert_eq!(reg.counter("t.count").get(), 5);
        let g = reg.gauge("t.gauge");
        g.set(7);
        g.record_max(3);
        assert_eq!(g.get(), 7);
        g.record_max(11);
        g.inc();
        g.dec(2);
        assert_eq!(g.get(), 10);
    }

    #[test]
    fn kind_mismatch_returns_detached_handle() {
        let reg = MetricsRegistry::new();
        reg.counter("t.dual").add(3);
        // registering the same name as a gauge must not clobber the counter
        let g = reg.gauge("t.dual");
        g.set(99);
        assert_eq!(reg.counter("t.dual").get(), 3);
    }

    #[test]
    fn to_json_renders_all_kinds() {
        let reg = MetricsRegistry::new();
        reg.counter("a.count").add(2);
        reg.gauge("b.gauge").set(9);
        let h = reg.histogram("c.hist_ns");
        h.record(5);
        h.record(7);
        let json = reg.to_json();
        assert_eq!(json.get("a.count").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(json.get("b.gauge").unwrap().as_f64().unwrap(), 9.0);
        let hist = json.get("c.hist_ns").unwrap();
        assert_eq!(hist.get("count").unwrap().as_f64().unwrap(), 2.0);
        assert!(hist.get("p99_us").unwrap().as_f64().unwrap() > 0.0);
    }

    // one test (not two) because `set_enabled` is process-global and the
    // test harness runs tests concurrently
    #[test]
    fn span_records_into_global_registry_unless_disabled() {
        let count = |name: &str| {
            MetricsRegistry::global().histogram(name.to_string()).snapshot().count()
        };
        let before = count("span.obs_test_span");
        time("obs_test_span", || std::hint::black_box(1 + 1));
        assert_eq!(count("span.obs_test_span"), before + 1);

        set_enabled(false);
        let disabled_before = count("span.obs_test_disabled");
        let d = Span::enter("obs_test_disabled").finish();
        set_enabled(true);
        assert_eq!(d, Duration::ZERO);
        assert_eq!(count("span.obs_test_disabled"), disabled_before);
    }
}
