//! Unified telemetry: metric registries, latency histograms, and scoped
//! trace spans — zero new dependencies.
//!
//! The paper's headline axis is wall-clock, so the reproduction treats
//! timing as first-class infrastructure rather than scattered ad-hoc
//! counters. One subsystem feeds every surface: the serve `STATS` reply,
//! the `milo serve --metrics-addr` Prometheus-style exposition endpoint,
//! `BENCH_serve.json`, and the optional `MILO_TRACE` event log.
//!
//! # Pieces
//!
//! * [`MetricsRegistry`] — a named map of atomic counters, gauges, and
//!   [`Histogram`]s. Registries are cheap-`Clone` handles and can be
//!   per-component (each `MetaStore` and each `SubsetServer` owns one, so
//!   their stats stay independent) or process-global
//!   ([`MetricsRegistry::global`], which collects [`Span`] timings).
//!   Handle types ([`Counter`], [`Gauge`], `Arc<Histogram>`) are resolved
//!   once at construction; hot paths never take the registry lock.
//! * [`Histogram`] — log-bucketed latency distribution (see
//!   [`hist`] for the bucket math: 8 sub-buckets per power of two,
//!   ≤ 12.5% relative error, exact below 16 ns, saturating at ~18 min).
//!   Mergeable across threads; percentile queries return exact bucket
//!   upper bounds.
//! * [`Span`] — a scoped timer with causal identity. Each span carries a
//!   `(trace, span, parent)` id triple: ids come from [`next_id`], the
//!   parent is the enclosing span on a thread-local stack, and the trace
//!   id is inherited from the ambient context (or freshly rooted). On
//!   drop it records its elapsed time into the global registry under
//!   `span.<name>`, appends a schema-v2 JSON-lines event when
//!   `MILO_TRACE=path` is set (see [`trace`]), and records into the
//!   always-on [`flight`] ring.
//!   [`Stopwatch`](crate::util::timer::Stopwatch) sections ride on spans,
//!   so legacy `sw.time("selection", ..)` call sites feed the same
//!   telemetry.
//! * [`TraceScope`] — installs a wire-received `(trace, parent)` context
//!   on the current thread, so a server dispatch span becomes a child of
//!   the client's request span. `ServeClient` stamps outgoing requests
//!   with `trace`/`span` fields (hex via [`id_hex`]); the server enters a
//!   `TraceScope` around dispatch. See the [serve module
//!   docs](crate::serve) for the wire negotiation.
//! * [`flight`] — the flight recorder: a fixed-size lock-free ring of
//!   recent span/request events that is always on, with tail-sampling —
//!   requests slower than `MILO_FLIGHT_SLOW_US` (default 100 ms) or
//!   ending in error get their whole span tree captured (and flushed to
//!   the `MILO_TRACE` sink when one is configured).
//! * [`traceview`] — the `milo trace` renderer: reads a sink (or
//!   `/flight` dump), reconstructs per-trace span trees, walks the
//!   critical path, and aggregates top spans.
//!
//! # Metric naming scheme
//!
//! Dotted lowercase paths, `<component>.<metric>[_<unit>][.<variant>]`:
//!
//! * `serve.requests`, `serve.accept_errors` — counters;
//! * `serve.open_connections`, `serve.wbuf_high_water` — gauges;
//! * `serve.request_latency_ns.next_subset`, `store.build_latency_ns`,
//!   `span.preprocess.sge` — histograms (values in nanoseconds; summaries
//!   render in microseconds).
//!
//! The text exposition ([`MetricsRegistry::render_text`]) maps a dotted
//! name to `milo_` + the name with non-`[A-Za-z0-9_]` characters replaced
//! by `_`, rendering histograms as Prometheus summaries (quantile series
//! plus `_sum`/`_count`).
//!
//! # Kill switches
//!
//! [`set_enabled(false)`](set_enabled) turns all span/latency recording
//! into no-ops (counters still tick — they predate this layer and cost a
//! single relaxed add). The flight recorder has its own, independent
//! switch ([`flight::set_enabled`]) because it is *default-on*:
//! `bench_serve` toggles each to *measure* the telemetry and flight
//! overheads on the `NEXT_SUBSET` path instead of assuming them.

pub mod flight;
pub mod hist;
pub mod trace;
pub mod traceview;

pub use hist::{Histogram, HistogramSnapshot};

use std::borrow::Cow;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::util::json::Json;

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Globally enable/disable latency recording (spans and timed-path
/// histograms). Counters are unaffected. Used by benches to measure
/// instrumentation overhead; defaults to enabled.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// Whether latency recording is enabled.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// A monotone counter handle (relaxed-atomic, cheap `Clone`).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge handle: a settable value with high-water helpers.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if it is currently lower.
    pub fn record_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`, saturating at `u64::MAX` (a gauge that pegged stays
    /// pegged rather than wrapping to a tiny value).
    pub fn add(&self, n: u64) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_add(n))
        });
    }

    /// Subtract `n`, saturating at zero. Gauges track non-negative
    /// quantities (open connections, buffered bytes); a decrement racing
    /// a restart or an accounting bug must floor at 0, not wrap to
    /// ~2^64 and poison every scrape until the next `set`.
    pub fn dec(&self, n: u64) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(n))
        });
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Clone)]
enum Metric {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Histogram(Arc<Histogram>),
}

/// A named collection of metrics. `Clone` is cheap (one `Arc`); all
/// clones share the same metrics. Lookup/creation takes a lock — resolve
/// handles once and store them, as `serve`/`store` do.
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    metrics: Arc<Mutex<BTreeMap<String, Metric>>>,
}

static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// The process-global registry: [`Span`]s and other component-less
    /// telemetry (preprocess stages, session resolution) record here.
    pub fn global() -> &'static MetricsRegistry {
        GLOBAL.get_or_init(MetricsRegistry::new)
    }

    /// Get-or-create the counter `name`. If `name` is already registered
    /// as a different kind, a detached (unexported) handle is returned.
    pub fn counter(&self, name: impl Into<Cow<'static, str>>) -> Counter {
        let name = name.into();
        let mut metrics = self.metrics.lock().unwrap();
        if let Some(Metric::Counter(c)) = metrics.get(name.as_ref()) {
            return Counter(c.clone());
        }
        if metrics.contains_key(name.as_ref()) {
            return Counter(Arc::new(AtomicU64::new(0)));
        }
        let cell = Arc::new(AtomicU64::new(0));
        metrics.insert(name.into_owned(), Metric::Counter(cell.clone()));
        Counter(cell)
    }

    /// Get-or-create the gauge `name` (same mismatch rule as `counter`).
    pub fn gauge(&self, name: impl Into<Cow<'static, str>>) -> Gauge {
        let name = name.into();
        let mut metrics = self.metrics.lock().unwrap();
        if let Some(Metric::Gauge(g)) = metrics.get(name.as_ref()) {
            return Gauge(g.clone());
        }
        if metrics.contains_key(name.as_ref()) {
            return Gauge(Arc::new(AtomicU64::new(0)));
        }
        let cell = Arc::new(AtomicU64::new(0));
        metrics.insert(name.into_owned(), Metric::Gauge(cell.clone()));
        Gauge(cell)
    }

    /// Get-or-create the histogram `name` (same mismatch rule as
    /// `counter`).
    pub fn histogram(&self, name: impl Into<Cow<'static, str>>) -> Arc<Histogram> {
        let name = name.into();
        let mut metrics = self.metrics.lock().unwrap();
        if let Some(Metric::Histogram(h)) = metrics.get(name.as_ref()) {
            return h.clone();
        }
        if metrics.contains_key(name.as_ref()) {
            return Arc::new(Histogram::new());
        }
        let h = Arc::new(Histogram::new());
        metrics.insert(name.into_owned(), Metric::Histogram(h.clone()));
        h
    }

    /// Render every metric as one JSON object: counters/gauges as
    /// numbers, histograms as summary objects (`count`, `p50_us`,
    /// `p95_us`, `p99_us`, `max_us`, `mean_us`, `saturated`). This is the
    /// single registry→JSON path shared by the serve STATS reply for both
    /// the server and store registries.
    pub fn to_json(&self) -> Json {
        let metrics = self.metrics.lock().unwrap();
        let mut obj = BTreeMap::new();
        for (name, metric) in metrics.iter() {
            let v = match metric {
                Metric::Counter(c) => Json::num(c.load(Ordering::Relaxed) as f64),
                Metric::Gauge(g) => Json::num(g.load(Ordering::Relaxed) as f64),
                Metric::Histogram(h) => h.snapshot().summary_json(),
            };
            obj.insert(name.clone(), v);
        }
        Json::Obj(obj)
    }

    /// Append a plain-text Prometheus-style exposition of every metric to
    /// `out` (see the module docs for the name mapping). Histograms render
    /// as summaries; values are in their recorded unit (nanoseconds for
    /// latency histograms).
    pub fn render_text(&self, out: &mut String) {
        let metrics = self.metrics.lock().unwrap();
        for (name, metric) in metrics.iter() {
            let mut id = String::with_capacity(name.len() + 5);
            id.push_str("milo_");
            for ch in name.chars() {
                id.push(if ch.is_ascii_alphanumeric() || ch == '_' { ch } else { '_' });
            }
            match metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "# TYPE {id} counter");
                    let _ = writeln!(out, "{id} {}", c.load(Ordering::Relaxed));
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "# TYPE {id} gauge");
                    let _ = writeln!(out, "{id} {}", g.load(Ordering::Relaxed));
                }
                Metric::Histogram(h) => {
                    let s = h.snapshot();
                    let _ = writeln!(out, "# TYPE {id} summary");
                    for (q, label) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                        let _ = writeln!(
                            out,
                            "{id}{{quantile=\"{label}\"}} {}",
                            s.percentile(q)
                        );
                    }
                    let _ = writeln!(out, "{id}_sum {}", s.sum());
                    let _ = writeln!(out, "{id}_count {}", s.count());
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Trace context: process-unique ids and the thread-local span stack
// ---------------------------------------------------------------------------

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

static ID_BASE: OnceLock<u64> = OnceLock::new();
static ID_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A fresh nonzero trace/span id. Ids are a per-process random base
/// (pid ⊕ wall-clock nanoseconds) plus an atomic counter, mixed through
/// splitmix64 — so a client and a server in different processes never
/// collide on span ids within one trace, without any coordination.
pub fn next_id() -> u64 {
    let base = *ID_BASE.get_or_init(|| {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5eed);
        nanos ^ ((std::process::id() as u64) << 32)
    });
    loop {
        let n = ID_COUNTER.fetch_add(1, Ordering::Relaxed);
        let id = splitmix64(base.wrapping_add(n));
        if id != 0 {
            return id;
        }
    }
}

/// Render an id the way it travels on the wire and in trace files:
/// 16 lowercase hex characters. (u64 ids do not survive a JSON number
/// round-trip — same reason `HELLO` carries `seed_hex`.)
pub fn id_hex(id: u64) -> String {
    format!("{id:016x}")
}

/// Parse an [`id_hex`]-formatted id; `None` on malformed input.
pub fn parse_id(s: &str) -> Option<u64> {
    if s.is_empty() || s.len() > 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

thread_local! {
    // (trace id, span id) frames; `.last()` is the current span context.
    static CURRENT: RefCell<Vec<(u64, u64)>> = const { RefCell::new(Vec::new()) };
}

fn ctx_push(frame: (u64, u64)) {
    CURRENT.with(|s| s.borrow_mut().push(frame));
}

fn ctx_pop(frame: (u64, u64)) {
    CURRENT.with(|s| {
        let mut s = s.borrow_mut();
        // exact-match removal from the tail: a span finished out of order
        // (or moved across threads) must never pop someone else's frame
        if let Some(pos) = s.iter().rposition(|f| *f == frame) {
            s.remove(pos);
        }
    });
}

/// The calling thread's current `(trace, span)` context — `(0, 0)` when
/// no span or [`TraceScope`] is active.
pub fn current_context() -> (u64, u64) {
    CURRENT.with(|s| s.borrow().last().copied().unwrap_or((0, 0)))
}

/// A guard that installs an externally-supplied trace context — e.g. one
/// that arrived over the serve wire — as the calling thread's current
/// context, so spans entered inside it become children of `parent`
/// within `trace`. Dropping the guard restores the previous context.
///
/// `TraceScope::enter(0, _)` is a no-op guard: a request with no wire
/// context leaves the ambient context untouched.
pub struct TraceScope {
    frame: Option<(u64, u64)>,
}

impl TraceScope {
    pub fn enter(trace: u64, parent: u64) -> TraceScope {
        if trace == 0 {
            return TraceScope { frame: None };
        }
        let frame = (trace, parent);
        ctx_push(frame);
        TraceScope { frame: Some(frame) }
    }
}

impl Drop for TraceScope {
    fn drop(&mut self) {
        if let Some(frame) = self.frame.take() {
            ctx_pop(frame);
        }
    }
}

/// A scoped timer. Created with [`Span::enter`]; on drop (or explicit
/// [`finish`](Span::finish)) it records its elapsed time into the global
/// registry's `span.<name>` histogram, emits a `MILO_TRACE` event when
/// tracing is configured, and records into the always-on [`flight`]
/// ring. When telemetry is disabled ([`set_enabled`]), entering a span
/// is a single relaxed load.
///
/// Spans carry causal identity: each gets a fresh [`next_id`], adopts
/// the thread's current trace (or starts a new one when none is active),
/// and parents itself under the enclosing span — so nested spans form a
/// tree that `milo trace` can reconstruct from the sink.
pub struct Span {
    name: Cow<'static, str>,
    start: Option<Instant>,
    trace: u64,
    id: u64,
    parent: u64,
}

impl Span {
    pub fn enter(name: impl Into<Cow<'static, str>>) -> Span {
        let name = name.into();
        if !enabled() {
            return Span { name, start: None, trace: 0, id: 0, parent: 0 };
        }
        let id = next_id();
        let (ambient_trace, parent) = current_context();
        // a span with no enclosing context roots its own trace, so every
        // recorded span belongs to exactly one trace
        let trace = if ambient_trace == 0 { id } else { ambient_trace };
        ctx_push((trace, id));
        Span { name, start: Some(Instant::now()), trace, id, parent }
    }

    /// The trace this span belongs to (0 when telemetry was disabled).
    pub fn trace_id(&self) -> u64 {
        self.trace
    }

    /// This span's own id (0 when telemetry was disabled).
    pub fn span_id(&self) -> u64 {
        self.id
    }

    /// End the span now, returning its measured duration (zero when
    /// telemetry was disabled at entry).
    pub fn finish(mut self) -> Duration {
        self.finish_inner()
    }

    fn finish_inner(&mut self) -> Duration {
        let Some(start) = self.start.take() else { return Duration::ZERO };
        let d = start.elapsed();
        ctx_pop((self.trace, self.id));
        MetricsRegistry::global()
            .histogram(format!("span.{}", self.name))
            .record_duration(d);
        trace::emit_span(&self.name, d, self.trace, self.id, self.parent);
        flight::record_span(&self.name, d, self.trace, self.id, self.parent);
        d
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        self.finish_inner();
    }
}

/// Run `f` inside a span named `name`.
pub fn time<R>(name: impl Into<Cow<'static, str>>, f: impl FnOnce() -> R) -> R {
    let _span = Span::enter(name);
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_roundtrip() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("t.count");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same name resolves to the same cell
        assert_eq!(reg.counter("t.count").get(), 5);
        let g = reg.gauge("t.gauge");
        g.set(7);
        g.record_max(3);
        assert_eq!(g.get(), 7);
        g.record_max(11);
        g.inc();
        g.dec(2);
        assert_eq!(g.get(), 10);
    }

    #[test]
    fn kind_mismatch_returns_detached_handle() {
        let reg = MetricsRegistry::new();
        reg.counter("t.dual").add(3);
        // registering the same name as a gauge must not clobber the counter
        let g = reg.gauge("t.dual");
        g.set(99);
        assert_eq!(reg.counter("t.dual").get(), 3);
    }

    #[test]
    fn to_json_renders_all_kinds() {
        let reg = MetricsRegistry::new();
        reg.counter("a.count").add(2);
        reg.gauge("b.gauge").set(9);
        let h = reg.histogram("c.hist_ns");
        h.record(5);
        h.record(7);
        let json = reg.to_json();
        assert_eq!(json.get("a.count").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(json.get("b.gauge").unwrap().as_f64().unwrap(), 9.0);
        let hist = json.get("c.hist_ns").unwrap();
        assert_eq!(hist.get("count").unwrap().as_f64().unwrap(), 2.0);
        assert!(hist.get("p99_us").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn gauge_add_and_dec_saturate() {
        let reg = MetricsRegistry::new();
        let g = reg.gauge("t.sat");
        // the serve.buffer_bytes shrink path decrements — below zero must
        // floor at 0, never wrap to ~2^64
        g.dec(5);
        assert_eq!(g.get(), 0);
        g.set(3);
        g.dec(10);
        assert_eq!(g.get(), 0);
        g.set(u64::MAX - 1);
        g.add(10);
        assert_eq!(g.get(), u64::MAX);
        g.dec(u64::MAX);
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn ids_are_nonzero_unique_and_hex_roundtrip() {
        let a = next_id();
        let b = next_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
        let hex = id_hex(a);
        assert_eq!(hex.len(), 16);
        assert_eq!(parse_id(&hex), Some(a));
        assert_eq!(parse_id(""), None);
        assert_eq!(parse_id("xyz"), None);
        assert_eq!(parse_id("00000000000000000"), None); // 17 chars
    }

    #[test]
    fn trace_scope_installs_and_restores_context() {
        assert_eq!(current_context(), (0, 0));
        {
            let _scope = TraceScope::enter(0xabc, 0xdef);
            assert_eq!(current_context(), (0xabc, 0xdef));
            {
                // a zero trace is a no-op guard — ambient context holds
                let _noop = TraceScope::enter(0, 7);
                assert_eq!(current_context(), (0xabc, 0xdef));
            }
            assert_eq!(current_context(), (0xabc, 0xdef));
        }
        assert_eq!(current_context(), (0, 0));
    }

    // one test (not several) because `set_enabled` is process-global and
    // the test harness runs tests concurrently
    #[test]
    fn span_records_into_global_registry_unless_disabled() {
        let count = |name: &str| {
            MetricsRegistry::global().histogram(name.to_string()).snapshot().count()
        };
        let before = count("span.obs_test_span");
        time("obs_test_span", || std::hint::black_box(1 + 1));
        assert_eq!(count("span.obs_test_span"), before + 1);

        // nested spans share one trace and parent correctly (checked here
        // so no concurrent test can flip the kill switch mid-assertion)
        let outer = Span::enter("obs_test_outer");
        assert_ne!(outer.span_id(), 0);
        assert_eq!(outer.trace_id(), outer.span_id()); // rooted its own trace
        let inner = Span::enter("obs_test_inner");
        assert_eq!(inner.trace_id(), outer.trace_id());
        assert_ne!(inner.span_id(), outer.span_id());
        assert_eq!(current_context(), (inner.trace_id(), inner.span_id()));
        drop(inner);
        assert_eq!(current_context(), (outer.trace_id(), outer.span_id()));
        drop(outer);
        assert_eq!(current_context(), (0, 0));

        set_enabled(false);
        let disabled_before = count("span.obs_test_disabled");
        let disabled = Span::enter("obs_test_disabled");
        assert_eq!(disabled.trace_id(), 0);
        assert_eq!(disabled.span_id(), 0);
        let d = disabled.finish();
        set_enabled(true);
        assert_eq!(d, Duration::ZERO);
        assert_eq!(count("span.obs_test_disabled"), disabled_before);
    }
}
