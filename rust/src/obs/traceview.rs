//! `milo trace` — render a trace sink (or `/flight` dump) as causal
//! trees.
//!
//! Input is schema-v2 JSON lines (see [`super::trace`]): `span` and
//! `request` events carrying `trace`/`span`/`parent` ids. The report
//! groups events by trace, renders each trace's span tree slowest-first
//! (children indented under their parent, chronological within a level),
//! walks the slowest trace's **critical path** — the chain of heaviest
//! children from the root — and ends with a top-spans aggregate. v1
//! lines (no ids) and `flight`/`sample` marker lines are tolerated: they
//! feed the aggregate but carry no tree structure.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet};

use crate::util::json::Json;

/// One parsed span/request event.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// `"span"` or `"request"`.
    pub ev: String,
    pub name: String,
    /// Microseconds since the emitting process's trace epoch.
    pub t_us: f64,
    /// Elapsed microseconds.
    pub us: f64,
    pub trace: u64,
    pub span: u64,
    pub parent: u64,
    pub err: bool,
}

fn id_of(v: &Json, key: &str) -> u64 {
    v.opt(key)
        .and_then(|s| s.as_str().ok())
        .and_then(super::parse_id)
        .unwrap_or(0)
}

/// Parse JSON lines, keeping `span`/`request` events and skipping
/// everything else (flight headers, sample markers, malformed lines —
/// a dump is never "invalid", it just contributes fewer events).
pub fn parse_lines(text: &str) -> Vec<TraceEvent> {
    let mut events = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(v) = Json::parse(line) else { continue };
        // v1 span lines predate the `ev` discriminator
        let ev = v.opt("ev").and_then(|e| e.as_str().ok()).unwrap_or("span");
        if ev != "span" && ev != "request" {
            continue;
        }
        let Some(name) = v.opt("name").and_then(|n| n.as_str().ok()) else {
            continue;
        };
        events.push(TraceEvent {
            ev: ev.to_string(),
            name: name.to_string(),
            t_us: v.opt("t_us").and_then(|t| t.as_f64().ok()).unwrap_or(0.0),
            us: v.opt("us").and_then(|u| u.as_f64().ok()).unwrap_or(0.0),
            trace: id_of(&v, "trace"),
            span: id_of(&v, "span"),
            parent: id_of(&v, "parent"),
            err: v.opt("err").and_then(|e| e.as_bool().ok()).unwrap_or(false),
        });
    }
    events
}

fn by_time(events: &[TraceEvent]) -> impl Fn(&usize, &usize) -> Ordering + '_ {
    move |&a, &b| {
        events[a].t_us.partial_cmp(&events[b].t_us).unwrap_or(Ordering::Equal)
    }
}

/// Link one trace's events into `(roots, children-by-parent-span)`. An
/// event whose parent isn't among the trace's span ids (including parent
/// 0) roots a subtree — a partial capture (ring wrap, v1 mix) degrades
/// to a forest instead of disappearing.
fn link(
    events: &[TraceEvent],
    idx: &[usize],
) -> (Vec<usize>, BTreeMap<u64, Vec<usize>>) {
    let spans: BTreeSet<u64> =
        idx.iter().map(|&i| events[i].span).filter(|&s| s != 0).collect();
    let mut roots = Vec::new();
    let mut children: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for &i in idx {
        let e = &events[i];
        if e.parent != 0 && e.parent != e.span && spans.contains(&e.parent) {
            children.entry(e.parent).or_default().push(i);
        } else {
            roots.push(i);
        }
    }
    roots.sort_by(by_time(events));
    for v in children.values_mut() {
        v.sort_by(by_time(events));
    }
    (roots, children)
}

fn render_tree(events: &[TraceEvent], idx: &[usize], out: &mut String) {
    let (roots, children) = link(events, idx);
    // iterative DFS with a visited guard: a malformed file (duplicated
    // ids, cycles) renders each event once instead of looping
    let mut visited: BTreeSet<usize> = BTreeSet::new();
    let mut stack: Vec<(usize, usize)> = roots.iter().rev().map(|&i| (i, 0)).collect();
    while let Some((i, depth)) = stack.pop() {
        if !visited.insert(i) {
            continue;
        }
        let e = &events[i];
        out.push_str(&format!(
            "  {:indent$}{} {:.1} µs{}\n",
            "",
            e.name,
            e.us,
            if e.err { "  [ERROR]" } else { "" },
            indent = depth * 2,
        ));
        if e.span != 0 {
            if let Some(kids) = children.get(&e.span) {
                for &k in kids.iter().rev() {
                    stack.push((k, depth + 1));
                }
            }
        }
    }
}

/// The heaviest root, then repeatedly the heaviest child — the chain a
/// latency fix has to shorten.
fn critical_path(events: &[TraceEvent], idx: &[usize]) -> Vec<usize> {
    let (roots, children) = link(events, idx);
    let heaviest = |candidates: &[usize]| {
        candidates.iter().copied().max_by(|&a, &b| {
            events[a].us.partial_cmp(&events[b].us).unwrap_or(Ordering::Equal)
        })
    };
    let Some(mut cur) = heaviest(&roots) else { return Vec::new() };
    let mut path = vec![cur];
    // bounded walk: a pathological parent graph terminates anyway
    for _ in 0..64 {
        let e = &events[cur];
        if e.span == 0 {
            break;
        }
        let Some(next) = children.get(&e.span).and_then(|k| heaviest(k)) else {
            break;
        };
        path.push(next);
        cur = next;
    }
    path
}

/// Render the full report for a trace file's contents: per-trace trees
/// (slowest `max_traces` traces), the slowest trace's critical path, and
/// the top-spans aggregate.
pub fn report(text: &str, max_traces: usize) -> String {
    let events = parse_lines(text);
    let mut out = String::new();
    if events.is_empty() {
        out.push_str("no span/request events found\n");
        return out;
    }
    let mut traces: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for (i, e) in events.iter().enumerate() {
        if e.trace != 0 {
            traces.entry(e.trace).or_default().push(i);
        }
    }
    // a trace's weight is its longest single event: the root request
    // span covers its children, so this is the end-to-end latency
    let mut order: Vec<(u64, f64)> = traces
        .iter()
        .map(|(&t, idx)| {
            (t, idx.iter().map(|&i| events[i].us).fold(0.0, f64::max))
        })
        .collect();
    order.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(Ordering::Equal));
    out.push_str(&format!(
        "{} event(s), {} trace(s)\n",
        events.len(),
        traces.len(),
    ));
    for (t, weight) in order.iter().take(max_traces) {
        out.push_str(&format!(
            "\ntrace {} — {} event(s), {weight:.1} µs\n",
            super::id_hex(*t),
            traces[t].len(),
        ));
        render_tree(&events, &traces[t], &mut out);
    }
    if let Some((t, _)) = order.first() {
        let path = critical_path(&events, &traces[t]);
        if path.len() > 1 {
            out.push_str(&format!(
                "\ncritical path (trace {}):\n",
                super::id_hex(*t),
            ));
            for &i in &path {
                out.push_str(&format!(
                    "  {} {:.1} µs\n",
                    events[i].name, events[i].us,
                ));
            }
        }
    }
    let mut agg: BTreeMap<&str, (u64, f64, f64)> = BTreeMap::new();
    for e in &events {
        let a = agg.entry(e.name.as_str()).or_insert((0, 0.0, 0.0));
        a.0 += 1;
        a.1 += e.us;
        a.2 = a.2.max(e.us);
    }
    let mut rows: Vec<(&str, (u64, f64, f64))> = agg.into_iter().collect();
    rows.sort_by(|a, b| (b.1).1.partial_cmp(&(a.1).1).unwrap_or(Ordering::Equal));
    out.push_str("\ntop spans (by total time):\n");
    out.push_str(&format!(
        "  {:<36} {:>7} {:>12} {:>12}\n",
        "name", "count", "total µs", "max µs",
    ));
    for (name, (count, total, max)) in rows.iter().take(15) {
        out.push_str(&format!(
            "  {name:<36} {count:>7} {total:>12.1} {max:>12.1}\n",
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span_line(name: &str, t_us: f64, us: f64, trace: u64, span: u64, parent: u64) -> String {
        crate::obs::trace::event_json("span", name, t_us, us, trace, span, parent)
            .to_string()
    }

    #[test]
    fn reconstructs_nested_tree_and_critical_path() {
        let text = [
            span_line("serve.client.get_meta", 1.0, 950.0, 0xaa, 0xb0, 0),
            span_line("serve.get_meta", 2.0, 900.0, 0xaa, 0xb1, 0xb0),
            span_line("store.resolve", 3.0, 700.0, 0xaa, 0xb2, 0xb1),
            span_line("kernel.execute", 4.0, 500.0, 0xaa, 0xb3, 0xb2),
            // a second, faster trace
            span_line("serve.ping", 9.0, 5.0, 0xcc, 0xd0, 0),
        ]
        .join("\n");
        let r = report(&text, 10);
        assert!(r.contains("5 event(s), 2 trace(s)"), "{r}");
        // slowest trace first, with each level indented two more spaces
        assert!(r.contains("  serve.client.get_meta 950.0 µs"), "{r}");
        assert!(r.contains("    serve.get_meta 900.0 µs"), "{r}");
        assert!(r.contains("      store.resolve 700.0 µs"), "{r}");
        assert!(r.contains("        kernel.execute 500.0 µs"), "{r}");
        let tree_pos = r.find("serve.client.get_meta").unwrap();
        let ping_pos = r.find("serve.ping").unwrap();
        assert!(tree_pos < ping_pos, "slowest trace must render first: {r}");
        // the critical path walks the heaviest chain end to end
        let cp = r.find("critical path").expect("critical path section");
        let tail = &r[cp..];
        assert!(tail.contains("kernel.execute"), "{r}");
        assert!(r.contains("top spans"), "{r}");
    }

    #[test]
    fn tolerates_v1_flight_and_garbage_lines() {
        let text = "\
{\"name\":\"preprocess.sge\",\"t_us\":1.0,\"us\":10.0}\n\
{\"ev\":\"flight\",\"recorded\":3}\n\
{\"ev\":\"sample\",\"trace\":\"00000000000000aa\"}\n\
not json at all\n\
{\"ev\":\"request\",\"name\":\"next_subset\",\"t_us\":2.0,\"us\":220.0,\
\"trace\":\"00000000000000aa\",\"span\":\"00000000000000ab\",\"err\":true}\n";
        let events = parse_lines(text);
        assert_eq!(events.len(), 2, "v1 span + request survive, rest skipped");
        let r = report(text, 10);
        // the v1 line has no trace id: aggregate-only, one rendered trace
        assert!(r.contains("2 event(s), 1 trace(s)"), "{r}");
        assert!(r.contains("[ERROR]"), "{r}");
        assert!(r.contains("preprocess.sge"), "{r}");
    }

    #[test]
    fn empty_input_reports_cleanly() {
        assert!(report("", 10).contains("no span/request events"));
    }
}
