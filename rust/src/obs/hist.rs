//! Log-bucketed latency histograms: fixed memory, lock-free recording,
//! exact-bounds percentile extraction.
//!
//! # Bucket scheme
//!
//! Values (nanoseconds by convention) are binned with [`SUB_BITS`] = 3
//! bits of sub-precision: each power-of-two range `[2^g, 2^(g+1))` splits
//! into 8 equal sub-buckets, bounding the relative bucket width to 12.5%.
//! Values below `2 * 2^SUB_BITS = 16` get one bucket each (exact).
//! Concretely, for `v >= 16` with `g = floor(log2 v)`:
//!
//! ```text
//! index(v) = (g - 3) * 8 + 8 + ((v >> (g - 3)) - 8)
//! ```
//!
//! and the bounds are recoverable from the index alone (see
//! [`bucket_bounds`]), which is what makes snapshots mergeable and
//! percentiles well-defined: a percentile query returns the *upper bound*
//! of the bucket holding the requested rank, so reported quantiles are a
//! conservative (≤ 12.5% high) estimate, never an underestimate.
//!
//! The scheme caps at [`MAX_VALUE`] = `2^40 - 1` ns (~18 minutes); larger
//! values clamp into the last bucket and tick the `saturated` counter so
//! overflow is visible rather than silent. Total footprint: [`N_BUCKETS`]
//! = 304 `AtomicU64` slots per histogram.
//!
//! Recording is a handful of relaxed atomic adds — safe from any thread,
//! cheap enough for the serve event loop's per-request path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Sub-bucket precision: `2^SUB_BITS` linear sub-buckets per power of two.
pub const SUB_BITS: u32 = 3;
const SUBS: u64 = 1 << SUB_BITS;
/// Power-of-two cap exponent: values at or above `2^MAX_GROUP` saturate.
const MAX_GROUP: u32 = 40;
/// Largest representable value; everything above clamps here.
pub const MAX_VALUE: u64 = (1u64 << MAX_GROUP) - 1;
/// Number of buckets in the scheme.
pub const N_BUCKETS: usize =
    (MAX_GROUP - SUB_BITS) as usize * SUBS as usize + SUBS as usize;

/// Bucket index for `v` (clamped to [`MAX_VALUE`]).
pub fn bucket_index(v: u64) -> usize {
    let v = v.min(MAX_VALUE);
    if v < 2 * SUBS {
        return v as usize;
    }
    let g = 63 - v.leading_zeros();
    let shift = g - SUB_BITS;
    let sub = (v >> shift) - SUBS;
    ((g - SUB_BITS) as u64 * SUBS + SUBS + sub) as usize
}

/// Inclusive `(lower, upper)` value bounds of bucket `i`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    let subs = SUBS as usize;
    if i < 2 * subs {
        return (i as u64, i as u64);
    }
    let g = SUB_BITS + ((i - subs) / subs) as u32;
    let sub = ((i - subs) % subs) as u64;
    let width = 1u64 << (g - SUB_BITS);
    let lo = (SUBS + sub) << (g - SUB_BITS);
    (lo, lo + width - 1)
}

/// A mergeable, thread-safe latency histogram over the module's bucket
/// scheme. All methods take `&self`; recording is relaxed-atomic only.
pub struct Histogram {
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    saturated: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            saturated: AtomicU64::new(0),
        }
    }

    /// Record one value (nanoseconds by convention). Values above
    /// [`MAX_VALUE`] clamp into the last bucket and count as saturated.
    pub fn record(&self, v: u64) {
        if v > MAX_VALUE {
            self.saturated.fetch_add(1, Ordering::Relaxed);
        }
        let v = v.min(MAX_VALUE);
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record an elapsed duration in nanoseconds.
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Fold `other`'s recorded values into `self`.
    pub fn merge(&self, other: &Histogram) {
        for (dst, src) in self.counts.iter().zip(other.counts.iter()) {
            let v = src.load(Ordering::Relaxed);
            if v > 0 {
                dst.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.saturated
            .fetch_add(other.saturated.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// A plain-integer copy for percentile queries and serialization.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            saturated: self.saturated.load(Ordering::Relaxed),
        }
    }
}

/// An immutable copy of a [`Histogram`]'s state.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    max: u64,
    saturated: u64,
}

impl HistogramSnapshot {
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn saturated(&self) -> u64 {
        self.saturated
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// The `q`-quantile (`q` in `[0, 1]`): the upper bound of the bucket
    /// containing rank `ceil(q * count)`. Returns 0 for an empty
    /// histogram. Because every query answers with a fixed representative
    /// per bucket, quantiles of `merge(a, b)` are always bracketed by the
    /// corresponding quantiles of `a` and `b`.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_bounds(i).1;
            }
        }
        bucket_bounds(N_BUCKETS - 1).1
    }

    /// Summary object used by the registry JSON renderer and the STATS
    /// reply: counts plus p50/p95/p99/max/mean in microseconds.
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("p50_us", Json::num(self.percentile(0.50) as f64 / 1e3)),
            ("p95_us", Json::num(self.percentile(0.95) as f64 / 1e3)),
            ("p99_us", Json::num(self.percentile(0.99) as f64 / 1e3)),
            ("max_us", Json::num(self.max as f64 / 1e3)),
            ("mean_us", Json::num(self.mean_ns() / 1e3)),
            ("saturated", Json::num(self.saturated as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_have_exact_buckets() {
        for v in 0..16u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_bounds(v as usize), (v, v));
        }
    }

    #[test]
    fn bucket_scheme_is_a_partition() {
        // indices are monotone non-decreasing in v and bounds tile the
        // whole range with no gaps or overlaps
        let mut expected_lo = 0u64;
        for i in 0..N_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(lo, expected_lo, "bucket {i} leaves a gap");
            assert!(hi >= lo);
            assert_eq!(bucket_index(lo), i);
            assert_eq!(bucket_index(hi), i);
            expected_lo = hi + 1;
        }
        assert_eq!(expected_lo, MAX_VALUE + 1, "buckets must cover up to the cap");
    }

    #[test]
    fn relative_bucket_width_is_bounded() {
        for i in 16..N_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            let width = (hi - lo + 1) as f64;
            assert!(width / lo as f64 <= 0.125 + 1e-12, "bucket {i} too wide");
        }
    }

    #[test]
    fn percentiles_of_exact_values_are_exact() {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 10] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 10);
        assert_eq!(s.sum(), 55);
        assert_eq!(s.percentile(0.5), 5);
        assert_eq!(s.percentile(1.0), 10);
        assert_eq!(s.percentile(0.0), 1);
        assert_eq!(s.max(), 10);
    }

    #[test]
    fn saturation_clamps_and_counts() {
        let h = Histogram::new();
        h.record(MAX_VALUE + 12345);
        h.record(u64::MAX);
        let s = h.snapshot();
        assert_eq!(s.saturated(), 2);
        assert_eq!(s.count(), 2);
        assert_eq!(s.max(), MAX_VALUE);
        assert_eq!(s.percentile(0.99), MAX_VALUE);
    }

    #[test]
    fn merge_sums_counts() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(100);
        b.record(1000);
        b.record(2000);
        a.merge(&b);
        let s = a.snapshot();
        assert_eq!(s.count(), 3);
        assert_eq!(s.sum(), 3100);
        assert_eq!(s.max(), 2000);
    }
}
