//! Optional structured trace log, gated by the `MILO_TRACE` environment
//! variable.
//!
//! When `MILO_TRACE=/path/to/trace.jsonl` is set, every finished
//! [`Span`](super::Span) appends one JSON object per line (JSON-lines) to
//! that file:
//!
//! ```text
//! {"ev":"span","name":"preprocess.sge","parent":"9f0c…","span":"41d2…",
//!  "t_us":812.0,"trace":"9f0c…","us":15301.2}
//! ```
//!
//! # Schema (v2)
//!
//! Fields: `ev` — event kind (`"span"`, or `"request"` for flight-sampled
//! request events); `name` — the span name; `t_us` — microseconds since
//! the process's first trace event; `us` — the span's elapsed
//! microseconds; `trace`/`span`/`parent` — causal ids as 16-hex-char
//! strings ([`id_hex`](super::id_hex)), with `parent` omitted for root
//! spans. v1 readers that ignore unknown fields keep working — the v1
//! fields are unchanged — and `milo trace` reads both (v1 lines simply
//! carry no causal structure).
//!
//! # Rotation
//!
//! `MILO_TRACE_MAX_MB=N` caps the file at `N` MiB: when a write would
//! cross the cap, the file is renamed to `<path>.1` (replacing any
//! previous `.1`, log-rotate convention) and a fresh file is started — a
//! soak can run for days without filling the disk, keeping the newest
//! full cap plus the live tail. Unset means unbounded (the v1 behavior).
//!
//! The file is opened in append mode once per process; unset (the
//! default) costs one relaxed load per span. Lines are formatted *before*
//! taking the sink lock, so concurrent spans contend only on the
//! `writeln!`, never on JSON encoding.

use std::io::Write;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

struct SinkState {
    file: std::fs::File,
    path: String,
    written: u64,
    cap_bytes: Option<u64>,
}

static SINK: OnceLock<Option<Mutex<SinkState>>> = OnceLock::new();
static EPOCH: OnceLock<Instant> = OnceLock::new();

fn sink() -> Option<&'static Mutex<SinkState>> {
    SINK.get_or_init(|| {
        let path = std::env::var("MILO_TRACE").ok()?;
        if path.is_empty() {
            return None;
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| eprintln!("[obs] cannot open MILO_TRACE={path}: {e}"))
            .ok()?;
        let written = file.metadata().map(|m| m.len()).unwrap_or(0);
        let cap_bytes = std::env::var("MILO_TRACE_MAX_MB")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .filter(|&mb| mb > 0)
            .map(|mb| mb * 1024 * 1024);
        Some(Mutex::new(SinkState { file, path, written, cap_bytes }))
    })
    .as_ref()
}

/// Whether a trace sink is configured (first call resolves `MILO_TRACE`).
pub fn enabled() -> bool {
    sink().is_some()
}

/// Microseconds since the process's first trace event (the `t_us` clock,
/// shared with the flight recorder so timestamps line up across both).
pub fn now_us() -> f64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e6
}

fn write_line(st: &mut SinkState, line: &str) {
    let len = line.len() as u64 + 1;
    if let Some(cap) = st.cap_bytes {
        if st.written > 0 && st.written + len > cap {
            // rotate once to `<path>.1` (replacing the previous `.1`) and
            // start fresh — never more than cap + one rotated file on disk
            let rotated = format!("{}.1", st.path);
            let _ = std::fs::rename(&st.path, &rotated);
            match std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&st.path)
            {
                Ok(f) => {
                    st.file = f;
                    st.written = 0;
                }
                Err(e) => {
                    eprintln!("[obs] MILO_TRACE rotation reopen failed: {e}");
                }
            }
        }
    }
    if writeln!(st.file, "{line}").is_ok() {
        st.written += len;
    }
}

/// Append one pre-formatted JSON line (no trailing newline) to the trace
/// sink; a no-op unless `MILO_TRACE` is set. The flight recorder uses
/// this to flush tail-sampled traces.
pub fn emit_line(line: &str) {
    let Some(sink) = sink() else { return };
    let mut st = sink.lock().unwrap();
    write_line(&mut st, line);
}

/// Build the schema-v2 JSON object for one span/request event. `ev` is
/// `"span"` or `"request"`; zero ids are omitted.
pub(crate) fn event_json(
    ev: &str,
    name: &str,
    t_us: f64,
    us: f64,
    trace: u64,
    span: u64,
    parent: u64,
) -> Json {
    let mut fields = vec![
        ("ev", Json::str(ev)),
        ("name", Json::str(name)),
        ("t_us", Json::num(t_us)),
        ("us", Json::num(us)),
    ];
    if trace != 0 {
        fields.push(("trace", Json::Str(super::id_hex(trace))));
    }
    if span != 0 {
        fields.push(("span", Json::Str(super::id_hex(span))));
    }
    if parent != 0 {
        fields.push(("parent", Json::Str(super::id_hex(parent))));
    }
    Json::obj(fields)
}

/// Append one span event; a no-op unless `MILO_TRACE` is set. The line
/// is formatted before the sink lock is taken.
pub fn emit_span(name: &str, elapsed: std::time::Duration, trace: u64, span: u64, parent: u64) {
    let Some(sink) = sink() else { return };
    let line = event_json(
        "span",
        name,
        now_us(),
        elapsed.as_secs_f64() * 1e6,
        trace,
        span,
        parent,
    )
    .to_string();
    let mut st = sink.lock().unwrap();
    write_line(&mut st, &line);
}
