//! Optional structured trace log, gated by the `MILO_TRACE` environment
//! variable.
//!
//! When `MILO_TRACE=/path/to/trace.jsonl` is set, every finished
//! [`Span`](super::Span) appends one JSON object per line (JSON-lines) to
//! that file:
//!
//! ```text
//! {"ev":"span","name":"preprocess.sge","t_us":812.0,"us":15301.2}
//! ```
//!
//! Fields: `ev` — event kind (currently always `"span"`); `name` — the
//! span name; `t_us` — microseconds since the process's first trace
//! event; `us` — the span's elapsed microseconds. The file is opened in
//! append mode once per process; unset (the default) costs one relaxed
//! load per span.

use std::io::Write;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

static SINK: OnceLock<Option<Mutex<std::fs::File>>> = OnceLock::new();
static EPOCH: OnceLock<Instant> = OnceLock::new();

fn sink() -> Option<&'static Mutex<std::fs::File>> {
    SINK.get_or_init(|| {
        let path = std::env::var("MILO_TRACE").ok()?;
        if path.is_empty() {
            return None;
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| eprintln!("[obs] cannot open MILO_TRACE={path}: {e}"))
            .ok()?;
        Some(Mutex::new(file))
    })
    .as_ref()
}

/// Whether a trace sink is configured (first call resolves `MILO_TRACE`).
pub fn enabled() -> bool {
    sink().is_some()
}

/// Append one span event; a no-op unless `MILO_TRACE` is set.
pub fn emit_span(name: &str, elapsed: std::time::Duration) {
    let Some(sink) = sink() else { return };
    let t_us = EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e6;
    let line = Json::obj(vec![
        ("ev", Json::str("span")),
        ("name", Json::str(name)),
        ("t_us", Json::num(t_us)),
        ("us", Json::num(elapsed.as_secs_f64() * 1e6)),
    ])
    .to_string();
    let mut f = sink.lock().unwrap();
    let _ = writeln!(f, "{line}");
}
