//! # milo — model-agnostic subset selection for efficient training & tuning
//!
//! A Rust + JAX + Pallas reproduction of *MILO: Model-Agnostic Subset
//! Selection Framework for Efficient Model Training and Tuning*
//! (Killamsetty et al., 2023).
//!
//! Three layers (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the coordinator: dataset pipeline, submodular
//!   maximization (SGE / WRE), the easy-to-hard curriculum, baselines
//!   (Random, AdaptiveRandom, CraigPB, GradMatchPB, Glister, pruning),
//!   the trainer, and the hyper-parameter tuner (Random/TPE × Hyperband).
//! * **Metadata store & selection service** — [`store`] is a versioned,
//!   content-addressed registry of pre-processed selection metadata
//!   (binary artifacts + a shared in-process LRU), and [`serve`] exposes
//!   one such artifact to N concurrent trainers/HPO trials over a small
//!   JSON-line TCP protocol (`milo serve`), so a single preprocessing pass
//!   amortizes across every consumer — the paper's "train multiple models
//!   at no additional cost", deployed.
//! * **L2 (python/compile, build-time only)** — JAX graphs: frozen feature
//!   encoders, downstream-MLP train/eval/meta steps — AOT-lowered to HLO
//!   text artifacts executed here via PJRT.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the similarity
//!   kernel and submodular gain reductions, lowered into the same HLO.
//!
//! Python never runs on the training path: `make artifacts` once, then
//! everything in `examples/`, `rust/benches/` and the `milo` CLI is
//! self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use milo::prelude::*;
//!
//! let rt = Runtime::open("artifacts")?;
//! let ds = DatasetId::Cifar10Like.generate(1);
//! let meta = Preprocessor::new(&rt).run(&ds)?;         // SGE + WRE metadata
//! let cfg = TrainConfig { epochs: 40, fraction: 0.1, ..Default::default() };
//! let mut strategy = meta.milo_strategy(1.0 / 6.0);    // easy-to-hard curriculum
//! let out = Trainer::new(&rt, &ds, cfg)?.run(&mut strategy)?;
//! println!("test acc {:.2}%", 100.0 * out.test_accuracy);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod coordinator;
pub mod data;
pub mod hpo;
pub mod kernel;
pub mod report;
pub mod runtime;
pub mod selection;
pub mod serve;
pub mod store;
pub mod submod;
pub mod tensor;
pub mod testkit;
pub mod train;
pub mod util;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::coordinator::{
        ExperimentRunner, Metadata, PreprocessOptions, Preprocessor, StrategyKind,
        TrialRecord,
    };
    pub use crate::data::{Dataset, DatasetId, Split};
    pub use crate::hpo::{HpoConfig, SearchAlgo, Tuner};
    pub use crate::kernel::{ClassKernels, SimMetric, SimilarityBackend};
    pub use crate::report::Table;
    pub use crate::runtime::Runtime;
    pub use crate::selection::{
        AdaptiveRandomStrategy, FixedStrategy, FullStrategy, MiloStrategy,
        RandomStrategy, Strategy,
    };
    pub use crate::serve::{ServeClient, ServedMiloStrategy, SubsetServer};
    pub use crate::store::{MetaKey, MetaStore};
    pub use crate::submod::{GreedyMode, SetFunctionKind};
    pub use crate::tensor::Matrix;
    pub use crate::train::{LrSchedule, TrainConfig, TrainOutcome, Trainer};
    pub use crate::util::rng::Rng;
}
