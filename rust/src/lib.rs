//! # milo — model-agnostic subset selection for efficient training & tuning
//!
//! A Rust + JAX + Pallas reproduction of *MILO: Model-Agnostic Subset
//! Selection Framework for Efficient Model Training and Tuning*
//! (Killamsetty et al., 2023).
//!
//! Layers (see `DESIGN.md`):
//!
//! * **Session API** — [`session`] is the crate's front door:
//!   [`session::MetaSource`] says *where* selection metadata comes from
//!   (inline preprocessing pass, content-addressed store, or a running
//!   `milo serve` instance) behind one `resolve` entry point, and
//!   [`session::MiloSession`] is a typed builder that hands out
//!   strategies, trainers, tuners, and experiment grids off one shared,
//!   cached resolution — the paper's "train multiple models at no
//!   additional cost" as a one-liner.
//! * **L3 (this crate)** — the coordinator: dataset pipeline, submodular
//!   maximization (SGE / WRE) over dense *or* sparse top-`knn` class
//!   kernels (one [`kernel::KernelView`] abstraction, per-class greedy
//!   fanned out across cores), the easy-to-hard curriculum, baselines
//!   (Random, AdaptiveRandom, CraigPB, GradMatchPB, Glister, pruning),
//!   the trainer, and the hyper-parameter tuner (Random/TPE × Hyperband).
//! * **Continual arrivals** — [`continual`] maintains MILO selections
//!   under a stream of labelled arrivals: per-class top-`knn` CSR kernels
//!   grow incrementally (append + re-top-k union, bit-identical to a
//!   from-scratch rebuild), dirty-class tracking re-selects only affected
//!   classes, and each `advance_epoch` yields versioned metadata that
//!   [`store::MetaStore::publish_epoch`] chains under an epoch head and
//!   [`serve::SubsetServer::publish`] pushes to subscribed trainers as
//!   `EPOCH_ADVANCE` / `SUBSET_DELTA` frames.
//! * **Overlapped kernel construction** — [`kernel::pipeline`] is the
//!   double-buffered strip pipeline under every blockwise kernel build:
//!   strip `t + 1`'s similarity execution (PJRT artifact call or native
//!   cache-blocked matmul) overlaps strip `t`'s host-side top-`knn`
//!   reduction through a bounded two-slot hand-off, with producer/consumer
//!   panics contained as `Err`. The batch, streaming, and continual paths
//!   all ride it ([`kernel::KernelSchedule`] — `--sim-tile` /
//!   `--pipeline-depth`, schedule-only and bit-identical to serial); where
//!   the manifest carries `topk_*` / `embed_sim_topk_*` artifacts, the
//!   top-`k` cut happens on-device and only candidate rows come back.
//! * **Metadata store & selection service** — [`store`] is a versioned,
//!   content-addressed registry of pre-processed selection metadata
//!   (binary artifacts + a shared in-process LRU), and [`serve`] exposes
//!   any number of `(dataset, fraction)` artifacts to thousands of
//!   concurrent trainers/HPO trials from a single-threaded event loop
//!   (`milo serve`) — readiness via epoll on Linux (raw FFI, with
//!   `poll(2)` and portable fallbacks), bounded per-connection
//!   read/write quanta for fair scheduling, and a JSON-line protocol or
//!   the binary frame wire negotiated at `HELLO` (subset index arrays
//!   as raw `u32` frames, metadata as the exact binfmt artifact bytes).
//!   Frame headers carry a stream id, so a [`serve::ConnectionPool`]
//!   multiplexes up to 31 logical sessions over one socket — each with
//!   its own entry, deterministic streams, and push subscription. The
//!   [`serve::ServeClient`] adds reconnect/retry with deterministic
//!   mid-stream resume. Both layers are consumed through
//!   [`session::MetaSource`].
//! * **Observability** — [`obs`] is a zero-dependency telemetry layer:
//!   per-component [`obs::MetricsRegistry`]s of atomic counters/gauges,
//!   mergeable log-bucketed latency [`obs::Histogram`]s with exact-bounds
//!   p50/p95/p99 extraction, and scoped [`obs::Span`] timers carrying
//!   causal `trace`/`span`/`parent` ids. A client request stamps its
//!   trace id onto the wire (negotiated at `HELLO`), the serve dispatch
//!   and everything it calls (`store.resolve`, `kernel.execute`, …) join
//!   that tree, and the optional `MILO_TRACE=path` JSON-lines sink
//!   (schema v2, `MILO_TRACE_MAX_MB` rotation) records it for the
//!   `milo trace` renderer. Independently, [`obs::flight`] is an
//!   always-on in-memory flight recorder of recent spans/requests with
//!   tail-sampling of slow or failed requests. Everything surfaces
//!   through the extended `STATS` reply, the `FLIGHT` command, the
//!   `milo serve --metrics-addr` Prometheus-style text endpoint (plus
//!   its `/flight` dump), per-`(dataset, fraction)` request attribution,
//!   and `BENCH_serve.json` (see the [`obs`] module docs for the metric
//!   naming scheme, trace schema, and histogram bucket math).
//! * **L2 (python/compile, build-time only)** — JAX graphs: frozen feature
//!   encoders, downstream-MLP train/eval/meta steps — AOT-lowered to HLO
//!   text artifacts executed here via PJRT.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the similarity
//!   kernel and submodular gain reductions, lowered into the same HLO.
//!
//! Python never runs on the training path: `make artifacts` once, then
//! everything in `examples/`, `rust/benches/` and the `milo` CLI is
//! self-contained.
//!
//! ## Quick start
//!
//! One session, one metadata resolution, as many consumers as you like:
//!
//! ```no_run
//! use milo::prelude::*;
//!
//! let rt = Runtime::open("artifacts")?;
//! let session = MiloSession::builder()
//!     .runtime(&rt)
//!     .dataset(DatasetId::Cifar10Like.generate(1))
//!     .source(MetaSource::inline(PreprocessOptions::default()))
//!     .fraction(0.1)
//!     .build()?;
//! let cfg = TrainConfig { epochs: 40, ..Default::default() };
//! // SGE + WRE metadata resolves once, then N models train off it
//! let out = session.train(StrategyKind::Milo { kappa: 1.0 / 6.0 }, cfg)?;
//! println!("test acc {:.2}%", 100.0 * out.test_accuracy);
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! Swap `MetaSource::inline(..)` for `MetaSource::store("results/store",
//! ..)?` to share one pass across processes, or
//! `MetaSource::remote("host:4077")` to consume a `milo serve` instance —
//! nothing else changes; see the [`session`] docs for the resolution
//! order. Sessions over a remote source can additionally *follow* a
//! continually-updated server via [`session::MiloSession::follow_client`].

pub mod continual;
pub mod coordinator;
pub mod data;
pub mod hpo;
pub mod kernel;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod selection;
pub mod serve;
pub mod session;
pub mod store;
pub mod submod;
pub mod tensor;
pub mod testkit;
pub mod train;
pub mod util;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::continual::{ContinualOptions, ContinualSelector, EpochStats};
    pub use crate::coordinator::{
        ExperimentRunner, Metadata, PreprocessOptions, PreprocessPipeline,
        Preprocessor, StrategyKind, TrialRecord,
    };
    pub use crate::data::{Dataset, DatasetId, Split};
    pub use crate::hpo::{HpoConfig, SearchAlgo, Tuner};
    pub use crate::kernel::{
        ClassKernels, ClassSim, KernelRef, KernelSchedule, KernelView,
        PipelineStats, SimMetric, SimilarityBackend, SparseKernel,
    };
    pub use crate::obs::{Histogram, MetricsRegistry, Span};
    pub use crate::report::Table;
    pub use crate::runtime::Runtime;
    pub use crate::selection::{
        AdaptiveRandomStrategy, FixedStrategy, FullStrategy, MiloStrategy,
        ModelProbe, RandomStrategy, SelectCtx, Strategy,
    };
    pub use crate::serve::{
        ClientOptions, ConnectionPool, EpochUpdate, RetryPolicy, ServeClient,
        ServedMiloStrategy, SubsetServer, WireMode,
    };
    pub use crate::session::{MetaSource, MiloSession, MiloSessionBuilder};
    pub use crate::store::{MetaKey, MetaStore};
    pub use crate::submod::{GreedyMode, SetFunctionKind};
    pub use crate::tensor::Matrix;
    pub use crate::train::{LrSchedule, TrainConfig, TrainOutcome, Trainer};
    pub use crate::util::rng::Rng;
}
