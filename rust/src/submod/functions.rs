//! The four set functions from the paper's Appendix D, with incremental
//! marginal-gain oracles over a symmetric similarity kernel in [0, 1].
//!
//! Every oracle is generic over [`KernelView`], so one implementation
//! serves dense [`Matrix`] blocks and sparse top-`knn`
//! [`crate::kernel::SparseKernel`] blocks alike. Sparse semantics: an
//! unstored pair has similarity exactly 0 (distance 1), which keeps all
//! four gain formulas well-defined; a *complete* sparse kernel
//! (`knn ≥ n`) iterates rows in the dense order and reproduces dense
//! gains bit-for-bit (property-tested in
//! `rust/tests/sparse_selection.rs`).
//!
//! Incremental state invariants (checked by property tests in
//! `rust/tests/submod_props.rs`):
//!   * FL:  `mx[i] = max_{k∈S} s[i,k]` (0 when S empty; valid since s ≥ 0)
//!   * GC:  `covered[j] = Σ_{k∈S} s[j,k]`, `colsum[j] = Σ_i s[i,j]`
//!   * DS:  `covered[j]` as above
//!   * DM:  `mindist[j] = min_{k∈S} (1 - s[j,k])` (∞-like 2.0 when empty;
//!     unstored sparse pairs clamp it to exactly 1.0)

use crate::kernel::{KernelRef, KernelRow, KernelView, SparseKernel};
use crate::tensor::Matrix;

/// Which set function (with parameters) — the paper's experiment axis.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SetFunctionKind {
    FacilityLocation,
    /// λ trades representation for diversity; the paper fixes λ = 0.4
    /// ("making the graph-cut function model representation more and
    /// making it monotone-submodular").
    GraphCut { lambda: f32 },
    DisparitySum,
    DisparityMin,
}

impl SetFunctionKind {
    pub const GRAPH_CUT_DEFAULT: SetFunctionKind = SetFunctionKind::GraphCut { lambda: 0.4 };

    pub fn name(&self) -> &'static str {
        match self {
            SetFunctionKind::FacilityLocation => "facility_location",
            SetFunctionKind::GraphCut { .. } => "graph_cut",
            SetFunctionKind::DisparitySum => "disparity_sum",
            SetFunctionKind::DisparityMin => "disparity_min",
        }
    }

    /// Representation functions pick easy/dense samples; diversity
    /// functions pick hard/sparse ones (paper §3, validated by Tables 1-2).
    pub fn is_representation(&self) -> bool {
        matches!(
            self,
            SetFunctionKind::FacilityLocation | SetFunctionKind::GraphCut { .. }
        )
    }

    /// Lazy greedy requires every cached gain to stay an *upper bound* as
    /// |S| grows. That fails for disparity-sum (gains grow with |S|) and
    /// for disparity-min (the empty-set seed gain is an average distance,
    /// not a bound on the later min-distance gains), so both use naive
    /// greedy — which their 1/2- and 1/4-approximations (Appendix D) are
    /// stated for anyway. Gains are O(1) against incremental state, so
    /// naive full sweeps stay O(n²) per class.
    pub fn lazy_safe(&self) -> bool {
        matches!(
            self,
            SetFunctionKind::FacilityLocation | SetFunctionKind::GraphCut { .. }
        )
    }

    /// Instantiate an oracle over a dense kernel.
    pub fn build<'a>(&self, kernel: &'a Matrix) -> Box<dyn SetFunction + 'a> {
        self.build_view(KernelRef::Dense(kernel))
    }

    /// Instantiate an oracle over a sparse top-`knn` kernel.
    pub fn build_sparse<'a>(&self, kernel: &'a SparseKernel) -> Box<dyn SetFunction + 'a> {
        self.build_view(KernelRef::Sparse(kernel))
    }

    /// Instantiate an oracle over either kernel representation — the
    /// entry point the coordinator's per-class pipeline uses
    /// (`ClassSim::view()` → oracle).
    pub fn build_view<'a>(&self, view: KernelRef<'a>) -> Box<dyn SetFunction + 'a> {
        match *self {
            SetFunctionKind::FacilityLocation => Box::new(FacilityLocation::new(view)),
            SetFunctionKind::GraphCut { lambda } => Box::new(GraphCut::new(view, lambda)),
            SetFunctionKind::DisparitySum => Box::new(DisparitySum::new(view)),
            SetFunctionKind::DisparityMin => Box::new(DisparityMin::new(view)),
        }
    }
}

/// Incremental marginal-gain oracle.
pub trait SetFunction {
    /// Ground-set size.
    fn n(&self) -> usize;
    /// Marginal gain `f(S ∪ {j}) − f(S)` against the current state.
    fn gain(&self, j: usize) -> f32;
    /// Commit `j` into S and update state. O(n).
    fn add(&mut self, j: usize);
    /// Current `f(S)`.
    fn value(&self) -> f32;
    /// Clear back to the empty set.
    fn reset(&mut self);
    /// Selected elements so far, in insertion order.
    fn selected(&self) -> &[usize];
}

// ---------------------------------------------------------------------------
// Facility location: f(S) = Σ_i max_{j∈S} s_ij
// ---------------------------------------------------------------------------

pub struct FacilityLocation<K: KernelView> {
    s: K,
    mx: Vec<f32>,
    picked: Vec<usize>,
    value: f32,
}

impl<K: KernelView> FacilityLocation<K> {
    pub fn new(s: K) -> Self {
        let n = s.n();
        FacilityLocation { s, mx: vec![0.0; n], picked: Vec::new(), value: 0.0 }
    }
}

impl<K: KernelView> SetFunction for FacilityLocation<K> {
    fn n(&self) -> usize {
        self.s.n()
    }

    #[inline]
    fn gain(&self, j: usize) -> f32 {
        // Σ_i max(0, s[i,j] − mx[i]); kernel symmetry lets us walk row j.
        // Unstored sparse entries contribute max(0, 0 − mx[i]) = 0 (mx ≥ 0),
        // so only stored entries are visited. Branchless `max` keeps the
        // dense loop auto-vectorizable (≈4× over the branchy form, see
        // EXPERIMENTS.md §Perf).
        let mut acc = 0.0f32;
        match self.s.kernel_row(j) {
            KernelRow::Dense(row) => {
                for (sij, mxi) in row.iter().zip(&self.mx) {
                    acc += (sij - mxi).max(0.0);
                }
            }
            KernelRow::Sparse { cols, vals } => {
                for (&c, &v) in cols.iter().zip(vals) {
                    acc += (v - self.mx[c as usize]).max(0.0);
                }
            }
        }
        acc
    }

    fn add(&mut self, j: usize) {
        self.value += self.gain(j);
        let mx = &mut self.mx;
        match self.s.kernel_row(j) {
            KernelRow::Dense(row) => {
                for (mxi, sij) in mx.iter_mut().zip(row) {
                    if *sij > *mxi {
                        *mxi = *sij;
                    }
                }
            }
            KernelRow::Sparse { cols, vals } => {
                for (&c, &v) in cols.iter().zip(vals) {
                    let mxi = &mut mx[c as usize];
                    if v > *mxi {
                        *mxi = v;
                    }
                }
            }
        }
        self.picked.push(j);
    }

    fn value(&self) -> f32 {
        self.value
    }

    fn reset(&mut self) {
        self.mx.iter_mut().for_each(|v| *v = 0.0);
        self.picked.clear();
        self.value = 0.0;
    }

    fn selected(&self) -> &[usize] {
        &self.picked
    }
}

// ---------------------------------------------------------------------------
// Graph cut: f(S) = Σ_{i∈D} Σ_{j∈S} s_ij − λ Σ_{i∈S} Σ_{j∈S} s_ij
// ---------------------------------------------------------------------------

pub struct GraphCut<K: KernelView> {
    s: K,
    lambda: f32,
    colsum: Vec<f32>,
    covered: Vec<f32>, // Σ_{k∈S} s[j,k]
    picked: Vec<usize>,
    value: f32,
}

impl<K: KernelView> GraphCut<K> {
    pub fn new(s: K, lambda: f32) -> Self {
        let n = s.n();
        // colsum in row-major order — the dense accumulation order, which
        // a complete sparse kernel reproduces exactly
        let mut colsum = vec![0.0f32; n];
        for i in 0..n {
            match s.kernel_row(i) {
                KernelRow::Dense(row) => {
                    for (j, v) in row.iter().enumerate() {
                        colsum[j] += v;
                    }
                }
                KernelRow::Sparse { cols, vals } => {
                    for (&c, &v) in cols.iter().zip(vals) {
                        colsum[c as usize] += v;
                    }
                }
            }
        }
        GraphCut {
            s,
            lambda,
            colsum,
            covered: vec![0.0; n],
            picked: Vec::new(),
            value: 0.0,
        }
    }
}

impl<K: KernelView> SetFunction for GraphCut<K> {
    fn n(&self) -> usize {
        self.s.n()
    }

    #[inline]
    fn gain(&self, j: usize) -> f32 {
        // Δ = colsum[j] − λ (2 Σ_{k∈S} s_jk + s_jj)
        self.colsum[j] - self.lambda * (2.0 * self.covered[j] + self.s.value_at(j, j))
    }

    fn add(&mut self, j: usize) {
        self.value += self.gain(j);
        let covered = &mut self.covered;
        match self.s.kernel_row(j) {
            KernelRow::Dense(row) => {
                for (cov, sjk) in covered.iter_mut().zip(row) {
                    *cov += *sjk;
                }
            }
            KernelRow::Sparse { cols, vals } => {
                for (&c, &v) in cols.iter().zip(vals) {
                    covered[c as usize] += v;
                }
            }
        }
        self.picked.push(j);
    }

    fn value(&self) -> f32 {
        self.value
    }

    fn reset(&mut self) {
        self.covered.iter_mut().for_each(|v| *v = 0.0);
        self.picked.clear();
        self.value = 0.0;
    }

    fn selected(&self) -> &[usize] {
        &self.picked
    }
}

// ---------------------------------------------------------------------------
// Disparity-sum: f(S) = Σ_{i∈S} Σ_{j∈S} (1 − s_ij)
// ---------------------------------------------------------------------------

pub struct DisparitySum<K: KernelView> {
    s: K,
    covered: Vec<f32>, // Σ_{k∈S} s[j,k]
    picked: Vec<usize>,
    value: f32,
}

impl<K: KernelView> DisparitySum<K> {
    pub fn new(s: K) -> Self {
        let n = s.n();
        DisparitySum { s, covered: vec![0.0; n], picked: Vec::new(), value: 0.0 }
    }
}

impl<K: KernelView> SetFunction for DisparitySum<K> {
    fn n(&self) -> usize {
        self.s.n()
    }

    #[inline]
    fn gain(&self, j: usize) -> f32 {
        // Adding j contributes (1 − s_jk) + (1 − s_kj) for each k∈S plus the
        // self term (1 − s_jj): with symmetry, 2(|S| − covered[j]) + (1 − s_jj).
        // Unstored sparse pairs sit at s = 0 — full distance — and are
        // covered by the |S| term.
        let k = self.picked.len() as f32;
        2.0 * (k - self.covered[j]) + (1.0 - self.s.value_at(j, j))
    }

    fn add(&mut self, j: usize) {
        self.value += self.gain(j);
        let covered = &mut self.covered;
        match self.s.kernel_row(j) {
            KernelRow::Dense(row) => {
                for (cov, sjk) in covered.iter_mut().zip(row) {
                    *cov += *sjk;
                }
            }
            KernelRow::Sparse { cols, vals } => {
                for (&c, &v) in cols.iter().zip(vals) {
                    covered[c as usize] += v;
                }
            }
        }
        self.picked.push(j);
    }

    fn value(&self) -> f32 {
        self.value
    }

    fn reset(&mut self) {
        self.covered.iter_mut().for_each(|v| *v = 0.0);
        self.picked.clear();
        self.value = 0.0;
    }

    fn selected(&self) -> &[usize] {
        &self.picked
    }
}

// ---------------------------------------------------------------------------
// Disparity-min: f(S) = min_{i≠j∈S} (1 − s_ij)
// ---------------------------------------------------------------------------

/// Greedy for disparity-min is the classic farthest-point (Gonzalez)
/// sweep: the "gain" of candidate j is its distance to the nearest already
/// selected point (`mindist[j]`), which the greedy maximizes — the
/// 1/4-approximation construction of Dasgupta et al. cited in Appendix D.
/// For the empty set the gain is the candidate's average distance to the
/// ground set, which makes the first pick the most outlying point.
pub struct DisparityMin<K: KernelView> {
    s: K,
    mindist: Vec<f32>,
    avgdist: Vec<f32>,
    picked: Vec<usize>,
    /// Incomplete kernels clamp `mindist` to 1.0 (the unstored-pair
    /// distance) on the first add; distances only shrink afterwards, so
    /// the O(n) clamp never needs to run twice.
    clamped: bool,
}

const EMPTY_DIST: f32 = 2.0; // > any 1 − s with s ∈ [0, 1]

impl<K: KernelView> DisparityMin<K> {
    pub fn new(s: K) -> Self {
        let n = s.n();
        let mut avgdist = vec![0.0f32; n];
        for (j, avg) in avgdist.iter_mut().enumerate() {
            *avg = match s.kernel_row(j) {
                KernelRow::Dense(row) => {
                    let total: f32 = row.iter().map(|v| 1.0 - v).sum();
                    total / n as f32
                }
                KernelRow::Sparse { cols: _, vals } => {
                    // unstored pairs sit at distance exactly 1
                    let stored: f32 = vals.iter().map(|v| 1.0 - v).sum();
                    if vals.len() == n {
                        stored / n as f32
                    } else {
                        (stored + (n - vals.len()) as f32) / n as f32
                    }
                }
            };
        }
        DisparityMin {
            s,
            mindist: vec![EMPTY_DIST; n],
            avgdist,
            picked: Vec::new(),
            clamped: false,
        }
    }
}

impl<K: KernelView> SetFunction for DisparityMin<K> {
    fn n(&self) -> usize {
        self.s.n()
    }

    #[inline]
    fn gain(&self, j: usize) -> f32 {
        if self.picked.is_empty() {
            // seed pick: most outlying point (max average distance)
            self.avgdist[j]
        } else if self.picked.contains(&j) {
            // re-adding a selected point would zero the min distance
            f32::MIN
        } else {
            self.mindist[j]
        }
    }

    fn add(&mut self, j: usize) {
        let mindist = &mut self.mindist;
        if !self.clamped && !self.s.is_complete() {
            // pairs the sparse row does not store are at distance exactly
            // 1.0; stored pairs tighten further below
            for md in mindist.iter_mut() {
                if *md > 1.0 {
                    *md = 1.0;
                }
            }
            self.clamped = true;
        }
        match self.s.kernel_row(j) {
            KernelRow::Dense(row) => {
                for (md, sjk) in mindist.iter_mut().zip(row) {
                    let d = 1.0 - *sjk;
                    if d < *md {
                        *md = d;
                    }
                }
            }
            KernelRow::Sparse { cols, vals } => {
                for (&c, &v) in cols.iter().zip(vals) {
                    let d = 1.0 - v;
                    let md = &mut mindist[c as usize];
                    if d < *md {
                        *md = d;
                    }
                }
            }
        }
        self.picked.push(j);
    }

    fn value(&self) -> f32 {
        // f(S) = min pairwise distance among selected
        if self.picked.len() < 2 {
            return 0.0;
        }
        let mut best = f32::MAX;
        for (a, &i) in self.picked.iter().enumerate() {
            for &j in &self.picked[a + 1..] {
                let d = 1.0 - self.s.value_at(i, j);
                if d < best {
                    best = d;
                }
            }
        }
        best
    }

    fn reset(&mut self) {
        self.mindist.iter_mut().for_each(|v| *v = EMPTY_DIST);
        self.picked.clear();
        self.clamped = false;
    }

    fn selected(&self) -> &[usize] {
        &self.picked
    }
}

/// Brute-force f(S) evaluation (test oracle and Gibbs rebuild path).
/// Unstored sparse pairs evaluate at similarity 0, consistent with the
/// incremental oracles.
pub fn brute_force_value<K: KernelView>(
    kind: SetFunctionKind,
    s: &K,
    subset: &[usize],
) -> f32 {
    let n = s.n();
    match kind {
        SetFunctionKind::FacilityLocation => {
            let mut total = 0.0;
            for i in 0..n {
                let mut best = 0.0f32;
                for &j in subset {
                    best = best.max(s.value_at(i, j));
                }
                total += best;
            }
            total
        }
        SetFunctionKind::GraphCut { lambda } => {
            let mut cross = 0.0;
            for i in 0..n {
                for &j in subset {
                    cross += s.value_at(i, j);
                }
            }
            let mut within = 0.0;
            for &i in subset {
                for &j in subset {
                    within += s.value_at(i, j);
                }
            }
            cross - lambda * within
        }
        SetFunctionKind::DisparitySum => {
            let mut total = 0.0;
            for &i in subset {
                for &j in subset {
                    total += 1.0 - s.value_at(i, j);
                }
            }
            total
        }
        SetFunctionKind::DisparityMin => {
            if subset.len() < 2 {
                return 0.0;
            }
            let mut best = f32::MAX;
            for (a, &i) in subset.iter().enumerate() {
                for &j in &subset[a + 1..] {
                    best = best.min(1.0 - s.value_at(i, j));
                }
            }
            best
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub(crate) fn random_kernel(n: usize, seed: u64) -> Matrix {
        // symmetric kernel in [0,1] with unit diagonal (like rescaled cosine)
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
            for j in (i + 1)..n {
                let v = rng.f32();
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        m
    }

    fn check_incremental_matches_brute(kind: SetFunctionKind, seed: u64) {
        let s = random_kernel(12, seed);
        let mut f = kind.build(&s);
        let mut subset = Vec::new();
        let mut rng = Rng::new(seed ^ 99);
        for _ in 0..6 {
            let j = loop {
                let j = rng.below(12);
                if !subset.contains(&j) {
                    break j;
                }
            };
            let before = brute_force_value(kind, &s, &subset);
            let gain = f.gain(j);
            subset.push(j);
            let after = brute_force_value(kind, &s, &subset);
            if !matches!(kind, SetFunctionKind::DisparityMin) {
                assert!(
                    (gain - (after - before)).abs() < 1e-4,
                    "{kind:?}: incremental gain {gain} vs brute {}",
                    after - before
                );
            }
            f.add(j);
            if !matches!(kind, SetFunctionKind::DisparityMin) {
                assert!(
                    (f.value() - after).abs() < 1e-3,
                    "{kind:?}: value {} vs brute {after}",
                    f.value()
                );
            } else {
                assert!((f.value() - after).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn incremental_gains_match_brute_force() {
        for seed in 0..5 {
            check_incremental_matches_brute(SetFunctionKind::FacilityLocation, seed);
            check_incremental_matches_brute(SetFunctionKind::GraphCut { lambda: 0.4 }, seed);
            check_incremental_matches_brute(SetFunctionKind::DisparitySum, seed);
            check_incremental_matches_brute(SetFunctionKind::DisparityMin, seed);
        }
    }

    #[test]
    fn fl_gains_diminish() {
        // submodularity: gain of a fixed j never increases as S grows
        let s = random_kernel(20, 3);
        let mut f = FacilityLocation::new(&s);
        let g0 = f.gain(7);
        f.add(1);
        let g1 = f.gain(7);
        f.add(2);
        let g2 = f.gain(7);
        assert!(g0 >= g1 - 1e-6 && g1 >= g2 - 1e-6, "{g0} {g1} {g2}");
    }

    #[test]
    fn gc_gains_diminish() {
        let s = random_kernel(20, 4);
        let mut f = GraphCut::new(&s, 0.4);
        let g0 = f.gain(5);
        f.add(0);
        let g1 = f.gain(5);
        f.add(9);
        let g2 = f.gain(5);
        assert!(g0 >= g1 - 1e-6 && g1 >= g2 - 1e-6);
    }

    #[test]
    fn disparity_min_prefers_far_points() {
        // 3 clusters on a line: picking greedily must hit different clusters
        let mut s = Matrix::filled(6, 6, 0.1);
        // pairs (0,1), (2,3), (4,5) are near-duplicates
        for &(a, b) in &[(0usize, 1usize), (2, 3), (4, 5)] {
            s.set(a, b, 0.95);
            s.set(b, a, 0.95);
        }
        for i in 0..6 {
            s.set(i, i, 1.0);
        }
        let mut f = DisparityMin::new(&s);
        for _ in 0..3 {
            let j = (0..6)
                .max_by(|&a, &b| f.gain(a).partial_cmp(&f.gain(b)).unwrap())
                .unwrap();
            f.add(j);
        }
        let sel = f.selected();
        let clusters: std::collections::HashSet<usize> =
            sel.iter().map(|&j| j / 2).collect();
        assert_eq!(clusters.len(), 3, "one pick per cluster, got {sel:?}");
    }

    #[test]
    fn reset_restores_empty_state() {
        let s = random_kernel(10, 5);
        for kind in [
            SetFunctionKind::FacilityLocation,
            SetFunctionKind::GRAPH_CUT_DEFAULT,
            SetFunctionKind::DisparitySum,
            SetFunctionKind::DisparityMin,
        ] {
            let mut f = kind.build(&s);
            let g_before: Vec<f32> = (0..10).map(|j| f.gain(j)).collect();
            f.add(3);
            f.add(7);
            f.reset();
            assert!(f.selected().is_empty());
            for j in 0..10 {
                assert!(
                    (f.gain(j) - g_before[j]).abs() < 1e-6,
                    "{kind:?} gain {j} after reset"
                );
            }
        }
    }

    #[test]
    fn representation_vs_diversity_classification() {
        assert!(SetFunctionKind::FacilityLocation.is_representation());
        assert!(SetFunctionKind::GRAPH_CUT_DEFAULT.is_representation());
        assert!(!SetFunctionKind::DisparityMin.is_representation());
        assert!(!SetFunctionKind::DisparitySum.is_representation());
        assert!(!SetFunctionKind::DisparitySum.lazy_safe());
        assert!(SetFunctionKind::FacilityLocation.lazy_safe());
    }
}
