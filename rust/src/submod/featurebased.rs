//! Feature-based submodular functions — no similarity kernel required.
//!
//! The paper's conclusion names its main open challenge ("the requirement
//! for a large amount of memory to construct similarity kernels, even with
//! class-wise partitioning") and proposes "feature-based submodular
//! functions" as future work. We implement that extension:
//!
//! ```text
//! f(S) = Σ_d w_d · g( Σ_{i∈S} φ_{id} )
//! ```
//!
//! with `g` concave (√· here) and `φ ≥ 0` per-sample feature activations —
//! the classic *feature-based coverage* family (Kirchhoff & Bilmes 2014,
//! the paper's ref [32] for data selection in MT). The function is
//! monotone submodular for any concave `g`, so the same greedy machinery
//! (and the 1−1/e guarantee) applies — but the memory footprint is
//! O(n·E) for the feature matrix instead of O(n²) for the kernel, and a
//! greedy sweep is O(n·E) per pick with incremental column sums.
//!
//! Non-negative features come from the frozen encoder via a fixed random
//! rotation followed by a split into positive/negative parts (`[z⁺; z⁻]`),
//! which preserves cosine geometry (⟨φ_i, φ_j⟩ recovers a shifted cosine)
//! while making every activation a coverage weight.

use crate::tensor::Matrix;

use super::functions::SetFunction;

/// Turn (possibly signed, L2-normalized) embeddings into non-negative
/// coverage features by splitting into positive and negative parts:
/// `z[n,E] → φ[n,2E]`, `φ = [max(z,0), max(−z,0)]`.
pub fn coverage_features(z: &Matrix) -> Matrix {
    let (n, e) = (z.rows, z.cols);
    let mut phi = Matrix::zeros(n, 2 * e);
    for i in 0..n {
        let src = z.row(i);
        let dst = phi.row_mut(i);
        for d in 0..e {
            let v = src[d];
            if v >= 0.0 {
                dst[d] = v;
            } else {
                dst[e + d] = -v;
            }
        }
    }
    phi
}

/// Feature-based coverage function with `g = sqrt` and uniform weights.
///
/// Implements [`SetFunction`], so [`super::greedy_maximize`] and
/// [`super::sample_importance`] work unchanged — this is what lets the
/// whole MILO pipeline (SGE subsets, WRE distributions, fixed subsets)
/// run kernel-free.
pub struct FeatureCoverage<'a> {
    phi: &'a Matrix,
    /// Incremental column sums `c_d = Σ_{i∈S} φ_{id}`.
    cols: Vec<f32>,
    /// Cached `g(c_d)` so gains are a single pass of `√(c+φ) − √c`.
    gcols: Vec<f32>,
    picked: Vec<usize>,
    value: f32,
}

impl<'a> FeatureCoverage<'a> {
    pub fn new(phi: &'a Matrix) -> Self {
        FeatureCoverage {
            phi,
            cols: vec![0.0; phi.cols],
            gcols: vec![0.0; phi.cols],
            picked: Vec::new(),
            value: 0.0,
        }
    }

    /// Bytes of working state (the memory-comparison axis of the
    /// `featspace` experiment): features + two column accumulators.
    pub fn memory_bytes(n: usize, e2: usize) -> usize {
        (n * e2 + 2 * e2) * std::mem::size_of::<f32>()
    }
}

impl<'a> SetFunction for FeatureCoverage<'a> {
    fn n(&self) -> usize {
        self.phi.rows
    }

    fn gain(&self, j: usize) -> f32 {
        let row = self.phi.row(j);
        let mut g = 0.0f32;
        for d in 0..row.len() {
            g += (self.cols[d] + row[d]).sqrt() - self.gcols[d];
        }
        g
    }

    fn add(&mut self, j: usize) {
        let row = self.phi.row(j);
        let mut delta = 0.0f32;
        for d in 0..row.len() {
            self.cols[d] += row[d];
            let g = self.cols[d].sqrt();
            delta += g - self.gcols[d];
            self.gcols[d] = g;
        }
        self.value += delta;
        self.picked.push(j);
    }

    fn value(&self) -> f32 {
        self.value
    }

    fn reset(&mut self) {
        self.cols.iter_mut().for_each(|c| *c = 0.0);
        self.gcols.iter_mut().for_each(|c| *c = 0.0);
        self.picked.clear();
        self.value = 0.0;
    }

    fn selected(&self) -> &[usize] {
        &self.picked
    }
}

/// Brute-force `f(S)` for tests.
pub fn brute_force_coverage(phi: &Matrix, subset: &[usize]) -> f32 {
    let mut total = 0.0f32;
    for d in 0..phi.cols {
        let mut c = 0.0f32;
        for &i in subset {
            c += phi.at(i, d);
        }
        total += c.sqrt();
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submod::{greedy_maximize, GreedyMode};
    use crate::util::rng::Rng;

    fn toy_features(n: usize, e: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut z = Matrix::zeros(n, e);
        for i in 0..n {
            for d in 0..e {
                z.set(i, d, rng.normal() as f32);
            }
        }
        z.l2_normalize_rows();
        z
    }

    #[test]
    fn coverage_features_are_nonnegative_and_preserve_norm() {
        let z = toy_features(40, 8, 1);
        let phi = coverage_features(&z);
        assert_eq!(phi.cols, 16);
        for i in 0..40 {
            let mut n2 = 0.0f32;
            for d in 0..16 {
                assert!(phi.at(i, d) >= 0.0);
                n2 += phi.at(i, d) * phi.at(i, d);
            }
            // ‖[z⁺; z⁻]‖² = ‖z‖² = 1
            assert!((n2 - 1.0).abs() < 1e-4, "row {i} norm² {n2}");
        }
    }

    #[test]
    fn incremental_value_matches_brute_force() {
        let z = toy_features(30, 6, 2);
        let phi = coverage_features(&z);
        let mut f = FeatureCoverage::new(&phi);
        let mut rng = Rng::new(3);
        let picks = rng.sample_indices(30, 10);
        for &j in &picks {
            f.add(j);
        }
        let expect = brute_force_coverage(&phi, &picks);
        assert!((f.value() - expect).abs() < 1e-3, "{} vs {expect}", f.value());
    }

    #[test]
    fn gains_are_diminishing() {
        // submodularity: the gain of a fixed element never increases as S
        // grows
        let z = toy_features(25, 5, 4);
        let phi = coverage_features(&z);
        let mut f = FeatureCoverage::new(&phi);
        let probe = 7usize;
        let mut last = f.gain(probe);
        for j in [0usize, 3, 11, 19, 22] {
            f.add(j);
            let g = f.gain(probe);
            assert!(g <= last + 1e-5, "gain grew: {last} -> {g}");
            last = g;
        }
    }

    #[test]
    fn gains_are_nonnegative_monotone() {
        let z = toy_features(20, 4, 5);
        let phi = coverage_features(&z);
        let mut f = FeatureCoverage::new(&phi);
        for j in 0..20 {
            assert!(f.gain(j) >= 0.0);
        }
        f.add(2);
        for j in 0..20 {
            assert!(f.gain(j) >= -1e-6);
        }
    }

    #[test]
    fn greedy_runs_kernel_free() {
        let z = toy_features(50, 8, 6);
        let phi = coverage_features(&z);
        let mut f = FeatureCoverage::new(&phi);
        let mut rng = Rng::new(7);
        let trace = greedy_maximize(&mut f, 10, GreedyMode::Naive, true, &mut rng);
        assert_eq!(trace.selected.len(), 10);
        // distinct picks
        let mut s = trace.selected.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        // gains recorded in non-increasing order (lazy-safe ⇒ greedy order)
        for w in trace.gains.windows(2) {
            assert!(w[1] <= w[0] + 1e-4, "gains not diminishing: {w:?}");
        }
    }

    #[test]
    fn reset_restores_empty_state() {
        let z = toy_features(15, 4, 8);
        let phi = coverage_features(&z);
        let mut f = FeatureCoverage::new(&phi);
        let g0: Vec<f32> = (0..15).map(|j| f.gain(j)).collect();
        f.add(1);
        f.add(5);
        f.reset();
        assert_eq!(f.value(), 0.0);
        assert!(f.selected().is_empty());
        for (j, &g) in g0.iter().enumerate() {
            assert!((f.gain(j) - g).abs() < 1e-6);
        }
    }

    #[test]
    fn memory_is_linear_not_quadratic() {
        let n = 4096;
        let e2 = 64;
        let feat = FeatureCoverage::memory_bytes(n, e2);
        let kernel = n * n * std::mem::size_of::<f32>();
        assert!(feat * 10 < kernel, "feature {feat}B vs kernel {kernel}B");
    }
}
