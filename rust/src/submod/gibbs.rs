//! Gibbs / Metropolis swap sampler for `P(S) ∝ exp(β·f(S))`, `|S| = k`.
//!
//! The paper's §3.1 names this the *ideal* formulation of informative data
//! exploration (its Eq. 2) and cites Gotovos et al. [14] for marginal
//! inference over probabilistic submodular models, but leaves the
//! fixed-cardinality extension to future work because the naive sampler
//! needs a combinatorial number of set-function evaluations and the
//! swap-chain mixes slowly near-optimal. We implement that extension here:
//!
//! * state: a subset `S` with `|S| = k` exactly;
//! * proposal: swap a uniformly random `i ∈ S` with a uniformly random
//!   `j ∉ S` (the standard fixed-cardinality exchange chain — symmetric,
//!   so the Metropolis ratio is just `exp(β·(f(S') − f(S)))`);
//! * acceptance tracked so callers can diagnose the mixing-time wall the
//!   paper predicts (acceptance → 0 as `f(S)` approaches the optimum with
//!   large β).
//!
//! `f(S')` is evaluated incrementally where the function allows it
//! (graph-cut has an O(k)-exact swap delta) and by oracle rebuild
//! otherwise (O(k·n) per proposal) — fine at class-partition scale, and
//! measuring exactly this cost is the point of the `gibbs` ablation
//! (EXPERIMENTS.md §Extensions): SGE/WRE get within noise of the exchange
//! chain at a small fraction of its evaluations, which is the empirical
//! justification for MILO's §3.1 design choice.

use crate::kernel::KernelView;
use crate::util::rng::Rng;

use super::functions::SetFunctionKind;

/// Fixed-cardinality Metropolis exchange sampler over one class kernel
/// (dense or sparse — any [`KernelView`]).
pub struct GibbsSampler<K: KernelView> {
    kernel: K,
    kind: SetFunctionKind,
    beta: f32,
    /// Current subset (sorted not required; membership mirrored in `in_s`).
    state: Vec<usize>,
    in_s: Vec<bool>,
    /// Cached `f(state)`.
    value: f32,
    /// Proposals / acceptances since construction (mixing diagnostics).
    pub proposals: u64,
    pub acceptances: u64,
    /// Set-function evaluation count (the cost axis of the ablation).
    pub evaluations: u64,
}

impl<K: KernelView> GibbsSampler<K> {
    /// Start the chain from a uniformly random size-`k` subset.
    pub fn new(
        kernel: K,
        kind: SetFunctionKind,
        beta: f32,
        k: usize,
        rng: &mut Rng,
    ) -> Self {
        let n = kernel.n();
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let state: Vec<usize> = idx[..k].to_vec();
        let mut in_s = vec![false; n];
        for &i in &state {
            in_s[i] = true;
        }
        let value = super::functions::brute_force_value(kind, &kernel, &state);
        GibbsSampler {
            kernel,
            kind,
            beta,
            state,
            in_s,
            value,
            proposals: 0,
            acceptances: 0,
            evaluations: 1,
        }
    }

    pub fn k(&self) -> usize {
        self.state.len()
    }

    pub fn value(&self) -> f32 {
        self.value
    }

    pub fn state(&self) -> &[usize] {
        &self.state
    }

    /// Observed acceptance rate (1.0 before any proposal).
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposals == 0 {
            1.0
        } else {
            self.acceptances as f64 / self.proposals as f64
        }
    }

    /// `f(state with state[pos] replaced by j)`.
    ///
    /// Graph-cut decomposes over pairs, so the swap delta is exact in
    /// O(k + n); every other function rebuilds the oracle value (O(k·n)
    /// via the brute-force evaluator — DM/DS are O(k²), FL O(k·n)).
    fn swapped_value(&mut self, pos: usize, j: usize) -> f32 {
        let out = self.state[pos];
        if let SetFunctionKind::GraphCut { lambda } = self.kind {
            // f = Σ_i Σ_{t∈S} s_it − λ Σ_{t,u∈S} s_tu
            let s = &self.kernel;
            let n = s.n();
            let mut cross_delta = 0.0f32;
            for i in 0..n {
                cross_delta += s.value_at(i, j) - s.value_at(i, out);
            }
            // within-S pair terms that change: pairs touching `out` or `j`
            let mut within_delta = 0.0f32;
            for &t in &self.state {
                if t == out {
                    continue;
                }
                within_delta += 2.0 * (s.value_at(t, j) - s.value_at(t, out));
            }
            within_delta += s.value_at(j, j) - s.value_at(out, out);
            self.evaluations += 1;
            return self.value + cross_delta - lambda * within_delta;
        }
        let mut probe = self.state.clone();
        probe[pos] = j;
        self.evaluations += 1;
        super::functions::brute_force_value(self.kind, &self.kernel, &probe)
    }

    /// One Metropolis exchange step. Returns whether the swap was accepted.
    pub fn step(&mut self, rng: &mut Rng) -> bool {
        let n = self.kernel.n();
        let k = self.state.len();
        if k == 0 || k == n {
            return false; // nothing to exchange
        }
        self.proposals += 1;
        let pos = rng.below(k);
        // rejection-sample a j ∉ S (k < n so this terminates fast)
        let j = loop {
            let cand = rng.below(n);
            if !self.in_s[cand] {
                break cand;
            }
        };
        let proposed = self.swapped_value(pos, j);
        let log_ratio = self.beta * (proposed - self.value);
        let accept = log_ratio >= 0.0 || (rng.f64() as f32) < log_ratio.exp();
        if accept {
            let out = self.state[pos];
            self.in_s[out] = false;
            self.in_s[j] = true;
            self.state[pos] = j;
            self.value = proposed;
            self.acceptances += 1;
        }
        accept
    }

    /// Run `burn_in` steps, then collect `n_samples` subsets `thin` steps
    /// apart. Each sample is a sorted copy of the state.
    pub fn sample(
        &mut self,
        burn_in: usize,
        thin: usize,
        n_samples: usize,
        rng: &mut Rng,
    ) -> Vec<Vec<usize>> {
        for _ in 0..burn_in {
            self.step(rng);
        }
        let mut out = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            for _ in 0..thin.max(1) {
                self.step(rng);
            }
            let mut s = self.state.clone();
            s.sort_unstable();
            out.push(s);
        }
        out
    }
}

/// Sample `n_subsets` class-stitched subsets from `P(S) ∝ exp(β·f(S))`
/// over per-class kernels (the same class-wise partitioning trick MILO
/// uses for SGE/WRE; `alloc[c]` is the per-class budget). Kernels are
/// any copyable [`KernelView`] — `&Matrix`, `KernelRef`, …
pub fn gibbs_class_subsets<K: KernelView + Copy>(
    kernels: &[(K, &[usize])], // (class kernel, global indices)
    alloc: &[usize],
    kind: SetFunctionKind,
    beta: f32,
    burn_in: usize,
    thin: usize,
    n_subsets: usize,
    rng: &mut Rng,
) -> (Vec<Vec<usize>>, GibbsStats) {
    let mut per_class: Vec<Vec<Vec<usize>>> = Vec::with_capacity(kernels.len());
    let mut stats = GibbsStats::default();
    for ((kernel, _), &kc) in kernels.iter().zip(alloc) {
        if kc == 0 {
            per_class.push(vec![Vec::new(); n_subsets]);
            continue;
        }
        let mut chain = GibbsSampler::new(*kernel, kind, beta, kc, rng);
        let samples = chain.sample(burn_in, thin, n_subsets, rng);
        stats.proposals += chain.proposals;
        stats.acceptances += chain.acceptances;
        stats.evaluations += chain.evaluations;
        per_class.push(samples);
    }
    let subsets = (0..n_subsets)
        .map(|si| {
            let mut subset = Vec::new();
            for (ci, (_, indices)) in kernels.iter().enumerate() {
                subset.extend(per_class[ci][si].iter().map(|&l| indices[l]));
            }
            subset.sort_unstable();
            subset
        })
        .collect();
    (subsets, stats)
}

/// Aggregate chain diagnostics across classes.
#[derive(Clone, Copy, Debug, Default)]
pub struct GibbsStats {
    pub proposals: u64,
    pub acceptances: u64,
    pub evaluations: u64,
}

impl GibbsStats {
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposals == 0 {
            1.0
        } else {
            self.acceptances as f64 / self.proposals as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submod::functions::brute_force_value;
    use crate::tensor::Matrix;

    fn toy_kernel(n: usize, seed: u64) -> Matrix {
        // random symmetric kernel in [0, 1] with unit diagonal
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = if i == j { 1.0 } else { rng.f64() as f32 };
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        m
    }

    #[test]
    fn cardinality_is_invariant() {
        let kern = toy_kernel(20, 1);
        let mut rng = Rng::new(2);
        let mut chain =
            GibbsSampler::new(&kern, SetFunctionKind::FacilityLocation, 4.0, 6, &mut rng);
        for _ in 0..200 {
            chain.step(&mut rng);
            assert_eq!(chain.k(), 6);
            // membership array consistent with state
            let marked = chain.in_s.iter().filter(|&&b| b).count();
            assert_eq!(marked, 6);
            for &i in chain.state() {
                assert!(chain.in_s[i]);
            }
        }
    }

    #[test]
    fn cached_value_tracks_brute_force() {
        for kind in [
            SetFunctionKind::FacilityLocation,
            SetFunctionKind::GRAPH_CUT_DEFAULT,
            SetFunctionKind::DisparityMin,
            SetFunctionKind::DisparitySum,
        ] {
            let kern = toy_kernel(16, 3);
            let mut rng = Rng::new(4);
            let mut chain = GibbsSampler::new(&kern, kind, 2.0, 5, &mut rng);
            for _ in 0..100 {
                chain.step(&mut rng);
            }
            let expect = brute_force_value(kind, &kern, chain.state());
            assert!(
                (chain.value() - expect).abs() < 1e-3 * (1.0 + expect.abs()),
                "{}: cached {} vs brute {}",
                kind.name(),
                chain.value(),
                expect
            );
        }
    }

    #[test]
    fn high_beta_climbs_in_value() {
        let kern = toy_kernel(30, 5);
        let mut rng = Rng::new(6);
        let mut chain =
            GibbsSampler::new(&kern, SetFunctionKind::FacilityLocation, 50.0, 5, &mut rng);
        let start = chain.value();
        for _ in 0..400 {
            chain.step(&mut rng);
        }
        assert!(
            chain.value() >= start,
            "high-beta chain went downhill: {} -> {}",
            start,
            chain.value()
        );
    }

    #[test]
    fn beta_zero_is_uniform_ergodic() {
        // with β = 0 every proposal is accepted and the chain must visit
        // many distinct subsets
        let kern = toy_kernel(12, 7);
        let mut rng = Rng::new(8);
        let mut chain =
            GibbsSampler::new(&kern, SetFunctionKind::FacilityLocation, 0.0, 3, &mut rng);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            chain.step(&mut rng);
            let mut s = chain.state().to_vec();
            s.sort_unstable();
            seen.insert(s);
        }
        assert_eq!(chain.acceptance_rate(), 1.0);
        assert!(seen.len() > 50, "only {} distinct states", seen.len());
    }

    #[test]
    fn acceptance_falls_with_beta() {
        let kern = toy_kernel(25, 9);
        let mut lo_rate = 0.0;
        let mut hi_rate = 0.0;
        for (beta, rate) in [(1.0, &mut lo_rate), (100.0, &mut hi_rate)] {
            let mut rng = Rng::new(10);
            let mut chain =
                GibbsSampler::new(&kern, SetFunctionKind::FacilityLocation, beta, 6, &mut rng);
            for _ in 0..500 {
                chain.step(&mut rng);
            }
            *rate = chain.acceptance_rate();
        }
        assert!(
            hi_rate < lo_rate,
            "acceptance should fall with beta: lo {lo_rate} hi {hi_rate}"
        );
    }

    #[test]
    fn graph_cut_swap_delta_is_exact() {
        let kern = toy_kernel(18, 11);
        let kind = SetFunctionKind::GraphCut { lambda: 0.4 };
        let mut rng = Rng::new(12);
        let mut chain = GibbsSampler::new(&kern, kind, 3.0, 6, &mut rng);
        for _ in 0..60 {
            chain.step(&mut rng);
            let expect = brute_force_value(kind, &kern, chain.state());
            assert!(
                (chain.value() - expect).abs() < 1e-2,
                "cached {} vs brute {}",
                chain.value(),
                expect
            );
        }
    }

    #[test]
    fn class_stitching_respects_alloc() {
        let k1 = toy_kernel(10, 13);
        let k2 = toy_kernel(14, 14);
        let idx1: Vec<usize> = (0..10).collect();
        let idx2: Vec<usize> = (10..24).collect();
        let mut rng = Rng::new(15);
        let (subsets, stats) = gibbs_class_subsets(
            &[(&k1, &idx1), (&k2, &idx2)],
            &[3, 4],
            SetFunctionKind::GRAPH_CUT_DEFAULT,
            4.0,
            50,
            5,
            4,
            &mut rng,
        );
        assert_eq!(subsets.len(), 4);
        for s in &subsets {
            assert_eq!(s.len(), 7);
            assert_eq!(s.iter().filter(|&&i| i < 10).count(), 3);
            assert_eq!(s.iter().filter(|&&i| i >= 10).count(), 4);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted, unique");
        }
        assert!(stats.proposals > 0 && stats.evaluations > 0);
    }

    #[test]
    fn empty_and_full_sets_are_noops() {
        let kern = toy_kernel(5, 16);
        let mut rng = Rng::new(17);
        let mut full =
            GibbsSampler::new(&kern, SetFunctionKind::FacilityLocation, 1.0, 5, &mut rng);
        assert!(!full.step(&mut rng));
        assert_eq!(full.proposals, 0);
    }
}
