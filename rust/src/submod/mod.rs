//! Submodular set functions and greedy maximizers (the SUBMODLIB
//! substrate, re-implemented from the paper's Appendix D).
//!
//! All functions operate over a per-class similarity kernel `S ∈ [0,1]ⁿˣⁿ`
//! (built by [`crate::kernel`]) and expose an *incremental oracle*: `gain(j)`
//! in O(1) against cached state, `add(j)` in O(n). That makes full greedy
//! O(n²) per class — the complexity SUBMODLIB achieves with memoization —
//! and is what keeps MILO's pre-processing "minimal" relative to training.
//!
//! The oracles are generic over [`crate::kernel::KernelView`], so the
//! same code runs against dense `n_c × n_c` blocks *and* sparse top-`knn`
//! CSR blocks ([`crate::kernel::SparseKernel`]): gains/adds over a sparse
//! row cost O(row nnz) ≈ O(knn) instead of O(n_c), and unstored pairs
//! evaluate at similarity 0 (distance 1). With `knn ≥ n_c` the sparse
//! rows are complete and iterate in the dense order, so every maximizer
//! here produces bit-identical selections over either representation —
//! `greedy_maximize`, `sample_importance`, and the [`gibbs`] chain are
//! untouched by the representation choice. The kernel-free
//! [`featurebased`] coverage functions sidestep kernels entirely and
//! keep composing through the same [`SetFunction`] trait.
//!
//! | function          | type            | paper role                        |
//! |-------------------|-----------------|-----------------------------------|
//! | facility location | representation  | Fig. 4 / SGE ablation (easy)      |
//! | graph cut (λ)     | representation  | curriculum phase 1 (easy)         |
//! | disparity-sum     | diversity       | Fig. 4 ablation (hard)            |
//! | disparity-min     | diversity       | curriculum phase 2 / WRE (hard)   |
//!
//! Maximizers: naive greedy, lazy greedy (max-heap of stale upper bounds —
//! valid whenever gains are non-increasing in |S|, i.e. all functions here
//! except disparity-sum), and stochastic greedy (Mirzasoleiman et al.,
//! the paper's SGE engine, Algorithm 2).

//! Extensions beyond the paper (its stated future work, built here):
//!
//! * [`gibbs`] — the fixed-cardinality exchange sampler for
//!   `P(S) ∝ exp(β·f(S))` (paper §3.1 Eq. 2 / Gotovos et al. [14]);
//! * [`featurebased`] — kernel-free feature-based coverage functions
//!   (the conclusion's "feature-based submodular functions" plan).

pub mod featurebased;
pub mod functions;
pub mod gibbs;
pub mod greedy;
pub mod sampling;

pub use featurebased::{coverage_features, FeatureCoverage};
pub use functions::{
    DisparityMin, DisparitySum, FacilityLocation, GraphCut, SetFunction,
    SetFunctionKind,
};
pub use gibbs::{gibbs_class_subsets, GibbsSampler, GibbsStats};
pub use greedy::{greedy_maximize, sample_importance, GreedyMode, GreedyTrace};
pub use sampling::weighted_sample_without_replacement;
