//! Greedy maximizers: naive, lazy (accelerated), and stochastic (SGE), plus
//! the full-sweep `sample_importance` pass that powers WRE.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::functions::SetFunction;
use crate::util::rng::Rng;

/// Maximizer selection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GreedyMode {
    /// Scan all candidate gains each step. O(nk). Always valid.
    Naive,
    /// Minoux's accelerated greedy: a max-heap of stale upper bounds,
    /// re-evaluating only the top. Valid when gains are non-increasing in
    /// |S| (all our functions except disparity-sum; `greedy_maximize`
    /// falls back to naive automatically via `lazy_safe`).
    Lazy,
    /// Stochastic greedy (paper Algorithm 2): per step evaluate a random
    /// subsample of size `(n/k)·ln(1/ε)`, achieving `1 − 1/e − ε` in
    /// expectation. The randomness is what lets SGE draw *n different*
    /// near-optimal subsets.
    Stochastic { epsilon: f64 },
}

/// Result of one greedy run.
#[derive(Clone, Debug)]
pub struct GreedyTrace {
    /// Selected indices, in pick order.
    pub selected: Vec<usize>,
    /// Marginal gain recorded at each pick.
    pub gains: Vec<f32>,
}

/// Maximize `f` under cardinality `k`; `rng` is used only by stochastic
/// mode. `lazy_safe=false` downgrades Lazy to Naive.
pub fn greedy_maximize(
    f: &mut dyn SetFunction,
    k: usize,
    mode: GreedyMode,
    lazy_safe: bool,
    rng: &mut Rng,
) -> GreedyTrace {
    let n = f.n();
    let k = k.min(n);
    match mode {
        GreedyMode::Naive => naive(f, k),
        GreedyMode::Lazy if lazy_safe => lazy(f, k),
        GreedyMode::Lazy => naive(f, k),
        GreedyMode::Stochastic { epsilon } => stochastic(f, k, epsilon, rng),
    }
}

fn naive(f: &mut dyn SetFunction, k: usize) -> GreedyTrace {
    let n = f.n();
    let mut in_set = vec![false; n];
    let mut selected = Vec::with_capacity(k);
    let mut gains = Vec::with_capacity(k);
    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_gain = f32::MIN;
        for j in 0..n {
            if in_set[j] {
                continue;
            }
            let g = f.gain(j);
            if g > best_gain {
                best_gain = g;
                best = j;
            }
        }
        f.add(best);
        in_set[best] = true;
        selected.push(best);
        gains.push(best_gain);
    }
    GreedyTrace { selected, gains }
}

/// Heap entry ordered by (stale) upper-bound gain.
struct Entry {
    gain: f32,
    item: usize,
    /// |S| at the time this gain was computed.
    stamp: usize,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.gain == other.gain
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.gain.partial_cmp(&other.gain).unwrap_or(Ordering::Equal)
    }
}

fn lazy(f: &mut dyn SetFunction, k: usize) -> GreedyTrace {
    let n = f.n();
    let mut heap: BinaryHeap<Entry> = (0..n)
        .map(|j| Entry { gain: f.gain(j), item: j, stamp: 0 })
        .collect();
    let mut selected = Vec::with_capacity(k);
    let mut gains = Vec::with_capacity(k);
    let mut in_set = vec![false; n];
    while selected.len() < k {
        let top = heap.pop().expect("heap exhausted before k");
        if in_set[top.item] {
            continue;
        }
        if top.stamp == selected.len() {
            // fresh bound — by diminishing returns it is the true max
            f.add(top.item);
            in_set[top.item] = true;
            selected.push(top.item);
            gains.push(top.gain);
        } else {
            // stale: re-evaluate and push back
            let g = f.gain(top.item);
            heap.push(Entry { gain: g, item: top.item, stamp: selected.len() });
        }
    }
    GreedyTrace { selected, gains }
}

fn stochastic(f: &mut dyn SetFunction, k: usize, epsilon: f64, rng: &mut Rng) -> GreedyTrace {
    let n = f.n();
    // sample size s = (n/k) ln(1/ε), clamped to [1, n]
    let s = if k == 0 {
        1
    } else {
        ((n as f64 / k as f64) * (1.0 / epsilon).ln()).ceil() as usize
    }
    .clamp(1, n);
    let mut in_set = vec![false; n];
    let mut remaining: Vec<usize> = (0..n).collect();
    let mut selected = Vec::with_capacity(k);
    let mut gains = Vec::with_capacity(k);
    for _ in 0..k {
        // draw up to s candidates from the remaining pool
        let m = s.min(remaining.len());
        let mut best = usize::MAX;
        let mut best_gain = f32::MIN;
        // partial Fisher-Yates over `remaining` to get m distinct candidates
        for t in 0..m {
            let pick = t + rng.below(remaining.len() - t);
            remaining.swap(t, pick);
            let j = remaining[t];
            let g = f.gain(j);
            if g > best_gain {
                best_gain = g;
                best = j;
            }
        }
        f.add(best);
        in_set[best] = true;
        selected.push(best);
        gains.push(best_gain);
        remaining.retain(|&j| !in_set[j]);
    }
    GreedyTrace { selected, gains }
}

/// `GreedySampleImportance` (paper Algorithm 3): run greedy to exhaustion
/// over the whole ground set, recording each element's marginal gain at its
/// point of inclusion. By diminishing returns, early (more informative)
/// elements get larger scores — these become the WRE sampling weights.
///
/// Returns `g[e]` indexed by ground-set position.
pub fn sample_importance(f: &mut dyn SetFunction, lazy_safe: bool) -> Vec<f32> {
    let n = f.n();
    let mut rng = Rng::new(0); // unused by Naive/Lazy
    let mode = if lazy_safe { GreedyMode::Lazy } else { GreedyMode::Naive };
    let trace = greedy_maximize(f, n, mode, lazy_safe, &mut rng);
    let mut g = vec![0.0f32; n];
    for (item, gain) in trace.selected.iter().zip(&trace.gains) {
        g[*item] = *gain;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submod::functions::{
        brute_force_value, FacilityLocation, GraphCut, SetFunctionKind,
    };
    use crate::tensor::Matrix;

    fn random_kernel(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
            for j in (i + 1)..n {
                let v = rng.f32();
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        m
    }

    #[test]
    fn lazy_equals_naive_for_submodular() {
        for seed in 0..5 {
            let s = random_kernel(30, seed);
            let mut rng = Rng::new(0);
            let mut f1 = FacilityLocation::new(&s);
            let t1 = greedy_maximize(&mut f1, 8, GreedyMode::Naive, true, &mut rng);
            let mut f2 = FacilityLocation::new(&s);
            let t2 = greedy_maximize(&mut f2, 8, GreedyMode::Lazy, true, &mut rng);
            assert_eq!(t1.selected, t2.selected, "seed {seed}");
            for (a, b) in t1.gains.iter().zip(&t2.gains) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn lazy_equals_naive_graph_cut() {
        for seed in 5..8 {
            let s = random_kernel(25, seed);
            let mut rng = Rng::new(0);
            let mut f1 = GraphCut::new(&s, 0.4);
            let t1 = greedy_maximize(&mut f1, 6, GreedyMode::Naive, true, &mut rng);
            let mut f2 = GraphCut::new(&s, 0.4);
            let t2 = greedy_maximize(&mut f2, 6, GreedyMode::Lazy, true, &mut rng);
            assert_eq!(t1.selected, t2.selected);
        }
    }

    #[test]
    fn greedy_beats_random_subsets() {
        let s = random_kernel(40, 9);
        let kind = SetFunctionKind::FacilityLocation;
        let mut rng = Rng::new(1);
        let mut f = FacilityLocation::new(&s);
        let t = greedy_maximize(&mut f, 6, GreedyMode::Naive, true, &mut rng);
        let greedy_val = brute_force_value(kind, &s, &t.selected);
        for seed in 0..20 {
            let mut r = Rng::new(seed + 100);
            let rand_subset = r.sample_indices(40, 6);
            let v = brute_force_value(kind, &s, &rand_subset);
            assert!(greedy_val >= v * 0.999, "greedy {greedy_val} < random {v}");
        }
    }

    #[test]
    fn stochastic_approximates_greedy() {
        let s = random_kernel(60, 10);
        let kind = SetFunctionKind::FacilityLocation;
        let mut rng = Rng::new(2);
        let mut f = FacilityLocation::new(&s);
        let full = greedy_maximize(&mut f, 10, GreedyMode::Naive, true, &mut rng);
        let full_val = brute_force_value(kind, &s, &full.selected);
        let mut worst: f32 = f32::MAX;
        for seed in 0..10 {
            let mut r = Rng::new(seed);
            let mut f2 = FacilityLocation::new(&s);
            let t = greedy_maximize(
                &mut f2,
                10,
                GreedyMode::Stochastic { epsilon: 0.01 },
                true,
                &mut r,
            );
            let v = brute_force_value(kind, &s, &t.selected);
            worst = worst.min(v / full_val);
        }
        assert!(worst > 0.9, "stochastic/greedy ratio {worst}");
    }

    #[test]
    fn stochastic_runs_vary_with_rng() {
        // the SGE property: different streams -> (usually) different subsets
        let s = random_kernel(80, 11);
        let mut sets = std::collections::HashSet::new();
        for seed in 0..6 {
            let mut r = Rng::new(seed);
            let mut f = FacilityLocation::new(&s);
            let t = greedy_maximize(
                &mut f,
                8,
                GreedyMode::Stochastic { epsilon: 0.01 },
                true,
                &mut r,
            );
            let mut sel = t.selected.clone();
            sel.sort_unstable();
            sets.insert(sel);
        }
        assert!(sets.len() >= 2, "SGE produced identical subsets every time");
    }

    #[test]
    fn selects_exactly_k_distinct() {
        let s = random_kernel(15, 12);
        for mode in [
            GreedyMode::Naive,
            GreedyMode::Lazy,
            GreedyMode::Stochastic { epsilon: 0.01 },
        ] {
            let mut rng = Rng::new(3);
            let mut f = FacilityLocation::new(&s);
            let t = greedy_maximize(&mut f, 7, mode, true, &mut rng);
            let mut sel = t.selected.clone();
            sel.sort_unstable();
            sel.dedup();
            assert_eq!(sel.len(), 7, "{mode:?}");
            assert_eq!(t.gains.len(), 7);
        }
    }

    #[test]
    fn k_larger_than_n_truncates() {
        let s = random_kernel(5, 13);
        let mut rng = Rng::new(0);
        let mut f = FacilityLocation::new(&s);
        let t = greedy_maximize(&mut f, 50, GreedyMode::Naive, true, &mut rng);
        assert_eq!(t.selected.len(), 5);
    }

    #[test]
    fn sample_importance_diminishes_over_rank() {
        let s = random_kernel(30, 14);
        let mut f = FacilityLocation::new(&s);
        let g = sample_importance(&mut f, true);
        assert_eq!(g.len(), 30);
        // reconstruct pick order: gains sorted descending must equal the
        // greedy trace order for a submodular f
        let mut f2 = FacilityLocation::new(&s);
        let mut rng = Rng::new(0);
        let t = greedy_maximize(&mut f2, 30, GreedyMode::Naive, true, &mut rng);
        for w in t.gains.windows(2) {
            assert!(w[0] >= w[1] - 1e-4, "gains must diminish: {:?}", t.gains);
        }
        // and importance of the first pick is the max
        let max_g = g.iter().cloned().fold(f32::MIN, f32::max);
        assert!((g[t.selected[0]] - max_g).abs() < 1e-6);
    }
}
