//! Weighted random sampling without replacement (Efraimidis–Spirakis),
//! the WRE sampling primitive (paper §3.1.2, citing [12]).
//!
//! Each item gets key `u_i^(1/w_i)` with `u_i ~ U(0,1)`; the k largest keys
//! are the sample. This reproduces successive weighted draws without
//! replacement in a single O(n log k) pass.

use crate::util::rng::Rng;

/// Draw `k` distinct indices from `[0, n)` with probability proportional to
/// `weights` (without replacement). Zero-weight items are only chosen once
/// every positive-weight item is exhausted.
pub fn weighted_sample_without_replacement(
    weights: &[f64],
    k: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    let n = weights.len();
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    // (key, index) min-heap of size k via sorted Vec for simplicity at the
    // sizes we use; keys: ln(u)/w is an equivalent, overflow-safe ordering.
    let mut scored: Vec<(f64, usize)> = Vec::with_capacity(n);
    for (i, &w) in weights.iter().enumerate() {
        debug_assert!(w >= 0.0, "negative weight {w}");
        let u = rng.f64().max(f64::MIN_POSITIVE);
        let key = if w > 0.0 {
            u.ln() / w // monotone transform of u^(1/w)
        } else {
            f64::NEG_INFINITY
        };
        scored.push((key, i));
    }
    // largest keys win
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    scored.into_iter().take(k).map(|(_, i)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_is_distinct_and_sized() {
        let mut rng = Rng::new(1);
        let w = vec![1.0; 50];
        let s = weighted_sample_without_replacement(&w, 10, &mut rng);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
    }

    #[test]
    fn heavier_items_sampled_more() {
        let mut rng = Rng::new(2);
        // item 0 has 10x the weight of each other item
        let mut w = vec![1.0; 20];
        w[0] = 10.0;
        let mut count0 = 0;
        let trials = 2000;
        for _ in 0..trials {
            let s = weighted_sample_without_replacement(&w, 3, &mut rng);
            if s.contains(&0) {
                count0 += 1;
            }
        }
        // uniform would include item 0 in 3/20 = 15% of draws; weighted
        // should be far higher (analytically ~70%)
        let frac = count0 as f64 / trials as f64;
        assert!(frac > 0.5, "heavy item frequency {frac}");
    }

    #[test]
    fn zero_weights_excluded_until_needed() {
        let mut rng = Rng::new(3);
        let w = vec![0.0, 1.0, 1.0, 0.0, 1.0];
        for _ in 0..100 {
            let s = weighted_sample_without_replacement(&w, 3, &mut rng);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![1, 2, 4]);
        }
        // but k beyond the positive-weight pool still fills up
        let s = weighted_sample_without_replacement(&w, 5, &mut rng);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn k_zero_and_k_above_n() {
        let mut rng = Rng::new(4);
        assert!(weighted_sample_without_replacement(&[1.0, 2.0], 0, &mut rng).is_empty());
        let s = weighted_sample_without_replacement(&[1.0, 2.0], 10, &mut rng);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn deterministic_given_rng_state() {
        let w: Vec<f64> = (1..=30).map(|i| i as f64).collect();
        let a = weighted_sample_without_replacement(&w, 5, &mut Rng::new(9));
        let b = weighted_sample_without_replacement(&w, 5, &mut Rng::new(9));
        assert_eq!(a, b);
    }
}
