//! Continual-arrival selection: MILO metadata maintained under a stream
//! of `(point, class)` arrivals (ROADMAP direction 4 — the
//! replay-buffer / continual-learning workload of the CRAIG line).
//!
//! Every other pipeline in this crate preprocesses a **fixed** dataset
//! once. [`ContinualSelector`] instead accepts embeddings one (or a
//! batch) at a time via [`ContinualSelector::arrive`] and re-derives the
//! full MILO metadata — SGE subsets, WRE distributions, the fixed
//! disparity-min subset — on demand via
//! [`ContinualSelector::advance_epoch`], doing **incremental** work
//! proportional to what actually changed:
//!
//! * **Incremental top-`knn` kernel maintenance.** For sparse cosine/dot
//!   kernels the per-row top-`knn` state is kept *pre-symmetrize*: one
//!   new arrival batch costs one `b × n_c` block matmul (new rows
//!   against all rows) instead of the full `n_c × n_c` rebuild. Old
//!   rows fold the new columns in by a top-`knn` **union update**: the
//!   true top-`knn` of a grown row is always contained in (stored
//!   entries ∪ new columns), because the stored entries are the exact
//!   top of the old columns under the same total order (score
//!   descending, column ascending — tie-free, hence unique).
//! * **Dirty-class re-selection.** Each class kernel carries a revision
//!   counter; SGE/WRE/fixed results are cached per class keyed on that
//!   revision (plus the per-job RNG seed and budget), so an epoch
//!   advance fans selection out — over the same `par_map` free-function
//!   bodies the batch pipeline uses — only for classes whose kernel or
//!   budget actually changed.
//!
//! # Bit-identity contract
//!
//! The central invariant (asserted by `rust/tests/continual_bitident.rs`)
//! is that N arrivals followed by `advance_epoch()` produce kernels,
//! SGE subsets, WRE distributions, and fixed subsets **byte-identical**
//! to a from-scratch [`crate::coordinator`] batch build over the
//! concatenated dataset. The pieces that make this hold exactly:
//!
//! * `Matrix::matmul_nt` computes each output element from its two input
//!   rows alone, so blockwise products are independent of strip
//!   grouping — a `b × n` incremental block holds the same bits as the
//!   rebuild's `128 × n` strips.
//! * L2 normalization (cosine) is per-row; normalizing arrival batches
//!   at integration time equals normalizing the concatenated matrix.
//! * `row_topk`'s total order is strict, so the kept *set* is unique and
//!   the union update reproduces it exactly; stored values are carried
//!   bitwise from their original block product (`s[i,j] == s[j,i]`
//!   bitwise, as both sides multiply/accumulate the same row pair in
//!   the same order).
//! * The dot-metric non-negativity shift is a fold of `f32::min` over
//!   all pairwise products — order-insensitive for finite floats — and
//!   is applied *after* symmetrization via the shared
//!   [`crate::kernel::sparse::kernel_from_topk`] tail, exactly as the
//!   batch builder does.
//! * RBF kernels derive `gamma` from a dense row-major f64 accumulation
//!   that is **not** resumable under appends, so RBF (and dense,
//!   `knn = None`) classes fall back to a dirty-class full rebuild —
//!   still skipped entirely for clean classes.
//! * `advance_epoch` replays the batch RNG recipe verbatim: a fresh
//!   `Rng::new(seed ^ 0x9E1E_C7).derive_str(dataset)` per epoch, SGE
//!   job seeds drawn subset-major, `k = (fraction·n).round().max(1)`,
//!   largest-remainder class allocation. Cached SGE picks are reused
//!   only when the drawn seed, the class budget, *and* the kernel
//!   revision all match — the drawn seed doubles as the staleness
//!   signal when the class count (and hence the job enumeration)
//!   changes.
//!
//! Epoch artifacts are published to the store via
//! [`crate::store::MetaStore::publish_epoch`] and pushed to subscribed
//! trainers by [`crate::serve::SubsetServer::publish`]; the `milo
//! stream` CLI wires all three into a replay-buffer workload.

use std::cmp::Ordering;
use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::Metadata;
use crate::kernel::pipeline::run_pipeline;
use crate::kernel::sparse::{
    block_rows, kernel_from_topk, row_topk_into, sparse_native, TopkScratch, STRIP_ROWS,
};
use crate::kernel::{
    native_similarity, ClassKernel, ClassKernels, ClassSim, KernelSchedule, SimMetric,
};
use crate::selection::milo::ClassProbs;
use crate::selection::proportional_allocation;
use crate::submod::{greedy_maximize, sample_importance, GreedyMode, SetFunctionKind};
use crate::tensor::Matrix;
use crate::util::math::taylor_softmax;
use crate::util::rng::Rng;
use crate::util::threads::par_map;

/// Configuration for a [`ContinualSelector`] — the continual mirror of
/// [`crate::coordinator::PreprocessOptions`] (same defaults, same store
/// fingerprint components), minus the encoder/backend knobs: arrivals
/// are already-encoded embeddings and kernel maintenance is native.
#[derive(Clone, Debug)]
pub struct ContinualOptions {
    /// Dataset name (store addressing + the batch RNG derivation tag).
    pub dataset: String,
    /// Subset fraction each epoch's selections are sized for. For a
    /// fixed-size replay buffer, update it per epoch via
    /// [`ContinualSelector::set_fraction`].
    pub fraction: f64,
    pub n_sge_subsets: usize,
    pub sge_function: SetFunctionKind,
    pub wre_function: SetFunctionKind,
    pub metric: SimMetric,
    pub epsilon: f64,
    pub seed: u64,
    /// `Some(k)`: sparse top-`k` class kernels with incremental
    /// maintenance (cosine/dot). `None`: dense kernels, rebuilt per
    /// dirty class.
    pub knn: Option<usize>,
}

impl ContinualOptions {
    pub fn new(dataset: impl Into<String>) -> ContinualOptions {
        ContinualOptions {
            dataset: dataset.into(),
            fraction: 0.1,
            n_sge_subsets: 3,
            sge_function: SetFunctionKind::GRAPH_CUT_DEFAULT,
            wre_function: SetFunctionKind::DisparityMin,
            metric: SimMetric::Cosine,
            epsilon: 0.01,
            seed: 1,
            knn: None,
        }
    }
}

/// What one [`ContinualSelector::advance_epoch`] actually did — the
/// incremental-vs-rebuild ledger `BENCH_stream.json` and the `milo
/// stream` CLI report.
#[derive(Clone, Debug)]
pub struct EpochStats {
    /// Epoch number this advance produced (1-based).
    pub epoch: u64,
    pub n_train: usize,
    /// Total selection budget `k` this epoch.
    pub k: usize,
    pub classes: usize,
    /// Classes whose kernel was updated (incrementally or rebuilt).
    pub dirty_classes: usize,
    /// Total SGE `(subset, class)` cells this epoch.
    pub sge_jobs: usize,
    /// SGE cells actually recomputed (the rest came from cache).
    pub sge_recomputed: usize,
    pub wre_recomputed: usize,
    pub fixed_recomputed: usize,
    /// Wall-clock spent folding arrivals into kernels.
    pub integrate_secs: f64,
    /// Wall-clock spent on (cached) selection fan-out.
    pub select_secs: f64,
    /// Resident bytes across all class kernels after the advance.
    pub kernel_bytes: usize,
}

/// Per-class incremental state. `rows` is the pre-symmetrize, pre-shift
/// top-`knn` row state (exact `row_topk` outputs over the full score
/// rows); `rev` bumps whenever kernel content changes and keys every
/// selection cache.
struct ClassState {
    /// Global (arrival-order) ids of this class's points.
    indices: Vec<usize>,
    /// Row-major raw embeddings, `indices.len() × dim`.
    raw: Vec<f32>,
    /// L2-normalized rows (maintained only for sparse cosine).
    norm: Vec<f32>,
    /// Incremental top-`knn` state (sparse cosine/dot only).
    rows: Vec<Vec<(u32, f32)>>,
    /// Running minimum over all raw pairwise products (dot shift).
    dot_min: f32,
    /// How many of `indices` are folded into `rows`/`dot_min`.
    integrated: usize,
    /// Kernel-content revision; selection caches key on it.
    rev: u64,
    /// Published kernel at `kernel_rev` (shared by every consumer).
    kernel: Option<ClassSim>,
    kernel_rev: u64,
}

impl Default for ClassState {
    fn default() -> Self {
        ClassState {
            indices: Vec::new(),
            raw: Vec::new(),
            norm: Vec::new(),
            rows: Vec::new(),
            dot_min: f32::MAX,
            integrated: 0,
            rev: 0,
            kernel: None,
            kernel_rev: 0,
        }
    }
}

/// Whether this (metric, knn) combination maintains kernels
/// incrementally; everything else rebuilds dirty classes from raw rows.
fn incremental(metric: SimMetric, knn: Option<usize>) -> bool {
    knn.is_some() && !matches!(metric, SimMetric::Rbf { .. })
}

impl ClassState {
    fn n(&self) -> usize {
        self.indices.len()
    }

    fn matrix(&self, dim: usize) -> Matrix {
        Matrix::from_vec(self.n(), dim, self.raw.clone())
            .expect("class rows are dim-validated at arrival")
    }

    /// Fold un-integrated arrivals into the kernel state and republish
    /// the class kernel. Returns true when the kernel changed. `Err`
    /// means a kernel-build stage panicked (the overlap pipeline
    /// contains it; see [`crate::kernel::pipeline`]).
    fn integrate(
        &mut self,
        metric: SimMetric,
        knn: Option<usize>,
        dim: usize,
    ) -> Result<bool> {
        let mut changed = false;
        if self.integrated < self.n() {
            if incremental(metric, knn) {
                self.integrate_sparse(metric, knn.unwrap(), dim)?;
            }
            self.integrated = self.n();
            self.rev += 1;
            changed = true;
        }
        if self.kernel.is_none() || self.kernel_rev != self.rev {
            self.kernel = Some(self.build_sim(metric, knn, dim));
            self.kernel_rev = self.rev;
        }
        Ok(changed)
    }

    /// One incremental union update (sparse cosine/dot): block-multiply
    /// the new rows against all rows, top-`knn` the new rows directly,
    /// and re-top-`knn` each old row over (stored ∪ new columns). The
    /// new-row block rides the same overlapped strip pipeline as the
    /// batch builders: sub-strip matmuls (produce) overlap the metric
    /// transform + new-row top-`knn` (consume). Chunking changes no
    /// bits — matmul elements are independent of strip grouping and the
    /// dot `f32::min` fold is order-insensitive — and the chunks are
    /// retained for the old-row union pass below.
    fn integrate_sparse(&mut self, metric: SimMetric, knn: usize, dim: usize) -> Result<()> {
        let n_old = self.integrated;
        let n = self.n();
        let mut block =
            Matrix::from_vec(n - n_old, dim, self.raw[n_old * dim..].to_vec())
                .expect("class rows are dim-validated at arrival");
        let all = match metric {
            SimMetric::Cosine => {
                // per-row normalization: batch-at-a-time equals
                // normalizing the concatenated matrix
                block.l2_normalize_rows();
                self.norm.extend_from_slice(block.data());
                Matrix::from_vec(n, dim, self.norm.clone())
            }
            _ => Matrix::from_vec(n, dim, self.raw.clone()),
        }
        .expect("normalized rows track raw rows");
        let b = n - n_old;
        let strip_h = STRIP_ROWS.max(1);
        let strips = b.div_ceil(strip_h);
        let keff = knn.clamp(1, n);
        struct IntState {
            rows: Vec<Vec<(u32, f32)>>,
            /// Transformed chunk strips, kept for the old-row pass.
            chunks: Vec<Matrix>,
            min: f32,
            scratch: TopkScratch,
        }
        let (block, all) = (&block, &all);
        let (st, _stats) = run_pipeline(
            strips,
            KernelSchedule::default().depth,
            IntState {
                rows: Vec::with_capacity(b),
                chunks: Vec::with_capacity(strips),
                min: self.dot_min,
                scratch: TopkScratch::new(),
            },
            |t| {
                let lo = t * strip_h;
                let hi = (lo + strip_h).min(b);
                Ok(block_rows(block, lo, hi).matmul_nt(all))
            },
            |st: &mut IntState, t, mut strip| {
                match metric {
                    SimMetric::Dot => {
                        // every pair (i, j) appears in some new block as
                        // (new, any) with s[i,j] == s[j,i] bitwise, so
                        // folding new blocks reproduces the full-matrix
                        // min exactly
                        st.min = strip.data().iter().cloned().fold(st.min, f32::min);
                    }
                    SimMetric::Cosine => {
                        for v in strip.data_mut().iter_mut() {
                            *v = 0.5 + 0.5 * *v;
                        }
                    }
                    SimMetric::Rbf { .. } => unreachable!("rbf classes rebuild"),
                }
                let lo = t * strip_h;
                for r in 0..strip.rows {
                    st.rows.push(row_topk_into(
                        strip.row(r),
                        n_old + lo + r,
                        keff,
                        &mut st.scratch,
                    ));
                }
                st.chunks.push(strip);
            },
        )?;
        self.dot_min = st.min;
        for (j, stored) in self.rows.iter_mut().enumerate() {
            let news: Vec<(u32, f32)> = st
                .chunks
                .iter()
                .enumerate()
                .flat_map(|(t, chunk)| {
                    (0..chunk.rows)
                        .map(move |r| ((n_old + t * strip_h + r) as u32, chunk.at(r, j)))
                })
                .collect();
            *stored = retopk(stored, &news, j, keff, n);
        }
        self.rows.extend(st.rows);
        Ok(())
    }

    fn build_sim(&self, metric: SimMetric, knn: Option<usize>, dim: usize) -> ClassSim {
        match knn {
            None => ClassSim::Dense(native_similarity(&self.matrix(dim), metric)),
            Some(w) if matches!(metric, SimMetric::Rbf { .. }) => {
                // rbf's gamma is a dense row-major accumulation over all
                // n² squared distances — not resumable, so rebuild
                ClassSim::Sparse(sparse_native(&self.matrix(dim), metric, w))
            }
            Some(_) => {
                let min = match metric {
                    SimMetric::Dot => self.dot_min,
                    _ => 0.0,
                };
                ClassSim::Sparse(kernel_from_topk(self.n(), self.rows.clone(), min))
            }
        }
    }
}

/// Re-derive a grown row's top-`knn` from its stored entries plus the
/// new columns — the union update. Mirrors [`row_topk`]'s semantics
/// exactly (self-loop always kept, score-descending/column-ascending
/// total order, result sorted by column); correctness rests on the
/// stored entries being the exact top of the old columns under that
/// same order, so the true top set never contains an unstored column.
fn retopk(
    stored: &[(u32, f32)],
    news: &[(u32, f32)],
    diag: usize,
    knn: usize,
    n: usize,
) -> Vec<(u32, f32)> {
    if knn >= n {
        // complete row: the old row was complete too (knn ≥ n > n_old),
        // and new columns are all larger, so concatenation stays sorted
        let mut out = stored.to_vec();
        out.extend_from_slice(news);
        return out;
    }
    let d = diag as u32;
    let diag_val = stored[stored
        .binary_search_by_key(&d, |e| e.0)
        .expect("stored rows always hold their self-loop")]
    .1;
    let mut cand: Vec<(u32, f32)> = stored
        .iter()
        .copied()
        .filter(|e| e.0 != d)
        .chain(news.iter().copied())
        .collect();
    let keep = knn - 1; // the diagonal occupies one of the knn slots
    let by = |a: &(u32, f32), b: &(u32, f32)| {
        b.1.partial_cmp(&a.1).unwrap_or(Ordering::Equal).then(a.0.cmp(&b.0))
    };
    if keep == 0 {
        cand.clear();
    } else if keep < cand.len() {
        cand.select_nth_unstable_by(keep - 1, by);
        cand.truncate(keep);
    }
    cand.push((d, diag_val));
    cand.sort_unstable_by_key(|e| e.0);
    cand
}

/// Cached per-`(subset, class)` SGE cell: valid while the drawn job
/// seed, the class budget, and the kernel revision all match.
struct SgeCell {
    seed: u64,
    kc: usize,
    rev: u64,
    picks: Vec<usize>,
}

/// MILO selections maintained under a stream of `(point, class)`
/// arrivals. See the [module docs](self) for the incremental design and
/// the bit-identity contract.
pub struct ContinualSelector {
    opts: ContinualOptions,
    dim: Option<usize>,
    classes: Vec<ClassState>,
    n_total: usize,
    epoch: u64,
    sge_cache: HashMap<(usize, usize), SgeCell>,
    wre_cache: Vec<Option<(u64, ClassProbs)>>,
    fixed_cache: Vec<Option<(u64, usize, Vec<usize>)>>,
}

impl ContinualSelector {
    pub fn new(opts: ContinualOptions) -> ContinualSelector {
        ContinualSelector {
            opts,
            dim: None,
            classes: Vec::new(),
            n_total: 0,
            epoch: 0,
            sge_cache: HashMap::new(),
            wre_cache: Vec::new(),
            fixed_cache: Vec::new(),
        }
    }

    /// Epochs produced so far (the next `advance_epoch` yields this +1).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Points arrived so far (integrated or not).
    pub fn n_train(&self) -> usize {
        self.n_total
    }

    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    pub fn options(&self) -> &ContinualOptions {
        &self.opts
    }

    /// Re-size future epochs' selections — the replay-buffer workload
    /// sets `fraction = buffer / n` before each advance so the coreset
    /// stays fixed-size while the stream grows.
    pub fn set_fraction(&mut self, fraction: f64) {
        self.opts.fraction = fraction;
    }

    /// Accept one embedded point for `class`; returns its global
    /// (arrival-order) index — row `i` of the equivalent concatenated
    /// dataset. Classes auto-grow; the embedding width is pinned by the
    /// first arrival.
    pub fn arrive(&mut self, class: usize, embedding: &[f32]) -> Result<usize> {
        let dim = *self.dim.get_or_insert(embedding.len());
        if embedding.len() != dim {
            bail!("arrival dim {} != established dim {dim}", embedding.len());
        }
        if dim == 0 {
            bail!("empty embedding");
        }
        if class >= self.classes.len() {
            self.classes.resize_with(class + 1, ClassState::default);
        }
        let id = self.n_total;
        self.n_total += 1;
        let st = &mut self.classes[class];
        st.indices.push(id);
        st.raw.extend_from_slice(embedding);
        Ok(id)
    }

    /// Integrate pending arrivals (dirty classes only, in parallel) and
    /// re-derive the full MILO metadata, reusing every selection result
    /// whose class kernel and budget did not change. The output is
    /// byte-identical to a from-scratch batch build over the
    /// concatenated dataset.
    pub fn advance_epoch(&mut self) -> Result<(Metadata, EpochStats)> {
        if self.n_total == 0 {
            bail!("advance_epoch before any arrival");
        }
        let t0 = Instant::now();
        let _span = crate::obs::Span::enter("continual.advance");
        let dim = self.dim.unwrap_or(0);
        let (metric, knn) = (self.opts.metric, self.opts.knn);

        // 1. kernel maintenance: fan dirty classes out over par_map
        let dirty: Vec<usize> = (0..self.classes.len())
            .filter(|&ci| {
                let st = &self.classes[ci];
                st.integrated < st.n() || st.kernel.is_none() || st.kernel_rev != st.rev
            })
            .collect();
        let dirty_classes = dirty.len();
        let taken: Vec<(usize, ClassState)> = dirty
            .iter()
            .map(|&ci| (ci, std::mem::take(&mut self.classes[ci])))
            .collect();
        let updated = par_map(taken, |(ci, mut st)| {
            let r = st.integrate(metric, knn, dim);
            (ci, st, r)
        });
        // restore every taken state before surfacing a failure, so an
        // errored advance leaves the selector intact
        let mut integrate_err: Option<anyhow::Error> = None;
        for (ci, st, r) in updated {
            self.classes[ci] = st;
            if let Err(e) = r {
                integrate_err.get_or_insert(e);
            }
        }
        if let Some(e) = integrate_err {
            return Err(e);
        }
        let integrate_secs = t0.elapsed().as_secs_f64();

        // 2. selection: the exact batch recipe, with revision-keyed caches
        let t1 = Instant::now();
        let n_train = self.n_total;
        let k = ((self.opts.fraction * n_train as f64).round() as usize).max(1);
        let sizes: Vec<usize> = self.classes.iter().map(|c| c.n()).collect();
        let alloc = proportional_allocation(&sizes, k.min(n_train));
        let classes = self.classes.len();
        let n_subsets = self.opts.n_sge_subsets;
        let epsilon = self.opts.epsilon;

        // SGE: draw every job seed subset-major (the batch enumeration),
        // then recompute only cache misses
        let mut rng = Rng::new(self.opts.seed ^ 0x9E1E_C7).derive_str(&self.opts.dataset);
        let jobs: Vec<(usize, usize, u64)> = (0..n_subsets)
            .flat_map(|si| (0..classes).map(move |ci| (si, ci)))
            .map(|(si, ci)| (si, ci, rng.next_u64()))
            .collect();
        let sge_jobs = jobs.len();
        let misses: Vec<(usize, usize, u64)> = jobs
            .iter()
            .copied()
            .filter(|&(si, ci, seed)| {
                !matches!(
                    self.sge_cache.get(&(si, ci)),
                    Some(c) if c.seed == seed
                        && c.kc == alloc[ci]
                        && c.rev == self.classes[ci].rev
                )
            })
            .collect();
        let sge_recomputed = misses.len();
        let kind = self.opts.sge_function;
        let states = &self.classes;
        let fresh: Vec<((usize, usize, u64), Vec<usize>)> =
            par_map(misses, |(si, ci, seed)| {
                let st = &states[ci];
                let kc = alloc[ci];
                if kc == 0 {
                    return ((si, ci, seed), Vec::new());
                }
                let sim = st.kernel.as_ref().expect("kernel published above");
                let mut f = kind.build_view(sim.view());
                let mut cell_rng = Rng::new(seed);
                let trace = greedy_maximize(
                    f.as_mut(),
                    kc,
                    GreedyMode::Stochastic { epsilon },
                    kind.lazy_safe(),
                    &mut cell_rng,
                );
                let picks = trace.selected.iter().map(|&l| st.indices[l]).collect();
                ((si, ci, seed), picks)
            });
        for ((si, ci, seed), picks) in fresh {
            self.sge_cache.insert(
                (si, ci),
                SgeCell { seed, kc: alloc[ci], rev: self.classes[ci].rev, picks },
            );
        }
        let mut sge_subsets = vec![Vec::with_capacity(k); n_subsets];
        for &(si, ci, _) in &jobs {
            sge_subsets[si].extend_from_slice(&self.sge_cache[&(si, ci)].picks);
        }
        for subset in &mut sge_subsets {
            subset.sort_unstable();
        }

        // WRE: per-class importance sweep, cached on kernel revision
        self.wre_cache.resize_with(classes, || None);
        let wre_kind = self.opts.wre_function;
        let wre_misses: Vec<usize> = (0..classes)
            .filter(|&ci| {
                !matches!(&self.wre_cache[ci], Some((rev, _)) if *rev == self.classes[ci].rev)
            })
            .collect();
        let wre_recomputed = wre_misses.len();
        let states = &self.classes;
        let fresh_wre: Vec<(usize, ClassProbs)> = par_map(wre_misses, |ci| {
            let st = &states[ci];
            let sim = st.kernel.as_ref().expect("kernel published above");
            let mut f = wre_kind.build_view(sim.view());
            let gains = sample_importance(f.as_mut(), wre_kind.lazy_safe());
            let g64: Vec<f64> = gains.iter().map(|&g| g as f64).collect();
            (ci, ClassProbs { indices: st.indices.clone(), probs: taylor_softmax(&g64) })
        });
        for (ci, probs) in fresh_wre {
            self.wre_cache[ci] = Some((self.classes[ci].rev, probs));
        }
        let wre_classes: Vec<ClassProbs> = self
            .wre_cache
            .iter()
            .map(|c| c.as_ref().expect("filled above").1.clone())
            .collect();

        // fixed subset: full lazy greedy, cached on (revision, budget)
        self.fixed_cache.resize_with(classes, || None);
        let fixed_misses: Vec<usize> = (0..classes)
            .filter(|&ci| {
                !matches!(
                    &self.fixed_cache[ci],
                    Some((rev, kc, _)) if *rev == self.classes[ci].rev && *kc == alloc[ci]
                )
            })
            .collect();
        let fixed_recomputed = fixed_misses.len();
        let states = &self.classes;
        let fresh_fixed: Vec<(usize, Vec<usize>)> = par_map(fixed_misses, |ci| {
            let st = &states[ci];
            let kc = alloc[ci];
            if kc == 0 {
                return (ci, Vec::new());
            }
            let sim = st.kernel.as_ref().expect("kernel published above");
            let mut f = wre_kind.build_view(sim.view());
            let mut cell_rng = Rng::new(0); // unused by Lazy mode
            let trace = greedy_maximize(
                f.as_mut(),
                kc,
                GreedyMode::Lazy,
                wre_kind.lazy_safe(),
                &mut cell_rng,
            );
            (ci, trace.selected.iter().map(|&l| st.indices[l]).collect())
        });
        for (ci, picks) in fresh_fixed {
            self.fixed_cache[ci] = Some((self.classes[ci].rev, alloc[ci], picks));
        }
        let mut fixed_dm: Vec<usize> = self
            .fixed_cache
            .iter()
            .flat_map(|c| c.as_ref().expect("filled above").2.iter().copied())
            .collect();
        fixed_dm.sort_unstable();

        self.epoch += 1;
        let stats = EpochStats {
            epoch: self.epoch,
            n_train,
            k,
            classes,
            dirty_classes,
            sge_jobs,
            sge_recomputed,
            wre_recomputed,
            fixed_recomputed,
            integrate_secs,
            select_secs: t1.elapsed().as_secs_f64(),
            kernel_bytes: self.kernel_bytes(),
        };
        let meta = Metadata {
            dataset: self.opts.dataset.clone(),
            fraction: self.opts.fraction,
            sge_subsets,
            wre_classes,
            fixed_dm,
            preprocess_secs: t0.elapsed().as_secs_f64(),
        };
        Ok((meta, stats))
    }

    /// Snapshot the maintained class kernels as a batch-compatible
    /// [`ClassKernels`] (clones the per-class blocks) — the bit-identity
    /// suite compares this against `build_class_kernels` on the
    /// concatenated dataset. Kernels are published by `advance_epoch`;
    /// classes with pending arrivals are integrated here first.
    pub fn class_kernels(&mut self) -> ClassKernels {
        let dim = self.dim.unwrap_or(0);
        let (metric, knn) = (self.opts.metric, self.opts.knn);
        for st in &mut self.classes {
            st.integrate(metric, knn, dim).expect("kernel integration failed");
        }
        ClassKernels {
            per_class: self
                .classes
                .iter()
                .map(|st| ClassKernel {
                    indices: st.indices.clone(),
                    sim: st.kernel.clone().expect("integrated above"),
                })
                .collect(),
            metric,
        }
    }

    /// Resident bytes across all published class kernels.
    pub fn kernel_bytes(&self) -> usize {
        self.classes
            .iter()
            .filter_map(|st| st.kernel.as_ref())
            .map(|sim| sim.memory_bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{build_class_kernels, SimilarityBackend};
    use crate::testkit::random_embeddings;

    fn striped_partition(n: usize, classes: usize) -> Vec<Vec<usize>> {
        let mut parts = vec![Vec::new(); classes];
        for i in 0..n {
            parts[i % classes].push(i);
        }
        parts
    }

    /// Feed `z` row-by-row (row i ↦ class i % classes) and return the
    /// selector — the arrival order is exactly the concatenated dataset.
    fn fed(z: &Matrix, classes: usize, opts: ContinualOptions) -> ContinualSelector {
        let mut sel = ContinualSelector::new(opts);
        for i in 0..z.rows {
            let id = sel.arrive(i % classes, z.row(i)).unwrap();
            assert_eq!(id, i);
        }
        sel
    }

    #[test]
    fn incremental_kernels_match_rebuild_bitwise() {
        let z = random_embeddings(60, 8, 17);
        for metric in [SimMetric::Cosine, SimMetric::Dot] {
            for knn in [3, 7, 64] {
                let mut opts = ContinualOptions::new("bitident");
                opts.metric = metric;
                opts.knn = Some(knn);
                // three uneven arrival waves
                let mut sel = ContinualSelector::new(opts);
                for (lo, hi) in [(0, 13), (13, 14), (14, 60)] {
                    for i in lo..hi {
                        sel.arrive(i % 4, z.row(i)).unwrap();
                    }
                    sel.advance_epoch().unwrap();
                }
                let inc = sel.class_kernels();
                let full = build_class_kernels(
                    None,
                    &z,
                    &striped_partition(60, 4),
                    metric,
                    SimilarityBackend::Native,
                    Some(knn),
                )
                .unwrap();
                for (a, b) in inc.per_class.iter().zip(&full.per_class) {
                    assert_eq!(a.indices, b.indices);
                    match (&a.sim, &b.sim) {
                        (ClassSim::Sparse(x), ClassSim::Sparse(y)) => {
                            assert_eq!(x, y, "{metric:?} knn={knn}")
                        }
                        _ => panic!("expected sparse kernels"),
                    }
                }
            }
        }
    }

    #[test]
    fn second_advance_without_arrivals_is_fully_cached() {
        let z = random_embeddings(40, 6, 3);
        let mut opts = ContinualOptions::new("cachehit");
        opts.knn = Some(5);
        let mut sel = fed(&z, 3, opts);
        let (m1, s1) = sel.advance_epoch().unwrap();
        assert_eq!(s1.sge_recomputed, s1.sge_jobs);
        let (m2, s2) = sel.advance_epoch().unwrap();
        assert_eq!(s2.dirty_classes, 0);
        assert_eq!(s2.sge_recomputed, 0);
        assert_eq!(s2.wre_recomputed, 0);
        assert_eq!(s2.fixed_recomputed, 0);
        assert_eq!(m1.sge_subsets, m2.sge_subsets);
        assert_eq!(m1.fixed_dm, m2.fixed_dm);
        assert_eq!(m1.wre_classes, m2.wre_classes);
    }

    #[test]
    fn arrivals_in_one_class_leave_other_classes_cached() {
        let z = random_embeddings(50, 6, 9);
        let mut opts = ContinualOptions::new("dirtyonly");
        opts.knn = Some(6);
        // keep per-class budgets stable across the second wave so the
        // cache comparison isolates the revision key: fraction such
        // that budgets stay proportional — just assert wre cache reuse,
        // which is budget-independent
        let mut sel = ContinualSelector::new(opts);
        for i in 0..40 {
            sel.arrive(i % 4, z.row(i)).unwrap();
        }
        sel.advance_epoch().unwrap();
        // ten more points, all class 0
        for i in 40..50 {
            sel.arrive(0, z.row(i)).unwrap();
        }
        let (_, s) = sel.advance_epoch().unwrap();
        assert_eq!(s.dirty_classes, 1);
        assert_eq!(s.wre_recomputed, 1, "clean classes must reuse WRE");
    }

    #[test]
    fn arrive_rejects_dim_mismatch() {
        let mut sel = ContinualSelector::new(ContinualOptions::new("dims"));
        sel.arrive(0, &[1.0, 2.0]).unwrap();
        assert!(sel.arrive(1, &[1.0]).is_err());
        assert!(sel.advance_epoch().is_ok());
    }

    #[test]
    fn advance_before_arrivals_errors() {
        let mut sel = ContinualSelector::new(ContinualOptions::new("empty"));
        assert!(sel.advance_epoch().is_err());
    }
}
