//! Data-pruning baselines: EL2N (Paul et al. 2021, used for Tables 1–2's
//! hardness analysis) and the self-supervised prototype-distance metric of
//! Sorscher et al. 2022 (ablation I.8 / Table 17).
//!
//! Both select a *fixed* subset before (or very early in) training —
//! exactly the "fixed data subset" regime §3 argues against; the Table 17
//! bench reproduces that argument.

use anyhow::Result;

use super::{proportional_allocation, SelectCtx, Strategy};
use crate::data::{Dataset, Split};
use crate::tensor::Matrix;
use crate::train::model::{MlpModel, StepHparams};
use crate::runtime::Runtime;

/// EL2N pruning: train a fresh network briefly (the metric is computed
/// "early in training"), score every sample by ‖softmax − onehot‖₂, then
/// keep the *hardest* `k` per class (the standard keep-hard protocol for
/// large fractions).
pub struct El2nPruneStrategy {
    warmup_epochs: usize,
    cached: Option<Vec<usize>>,
}

impl El2nPruneStrategy {
    pub fn new(warmup_epochs: usize) -> Self {
        El2nPruneStrategy { warmup_epochs, cached: None }
    }

    /// Compute EL2N scores for the whole train split with a throwaway model
    /// (seed 1) trained for `warmup_epochs`.
    pub fn scores(
        rt: &Runtime,
        ds: &Dataset,
        hidden: usize,
        warmup_epochs: usize,
        rng: &mut crate::util::rng::Rng,
    ) -> Result<Vec<f32>> {
        let mut model = MlpModel::load(rt, ds.name(), hidden, 1)?;
        let hp = StepHparams { lr: 0.05, momentum: 0.9, weight_decay: 5e-4, nesterov: true };
        let n = ds.n_train();
        let mut order: Vec<usize> = (0..n).collect();
        for _ in 0..warmup_epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(model.batch) {
                model.train_step(rt, ds, chunk, hp)?;
            }
        }
        Ok(model.meta(rt, ds, Split::Train, None)?.el2n)
    }
}

impl Strategy for El2nPruneStrategy {
    fn name(&self) -> String {
        "el2n_prune".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        if let Some(c) = &self.cached {
            return Ok(c.clone());
        }
        // EL2N needs a model to warm up and score with — request the probe
        let (rt, hidden) = {
            let probe = ctx.probe()?;
            (probe.rt, probe.model.hidden)
        };
        let scores = Self::scores(rt, ctx.ds, hidden, self.warmup_epochs, ctx.rng)?;
        let sel = keep_top_per_class(ctx.ds, &scores, ctx.k);
        self.cached = Some(sel.clone());
        Ok(sel)
    }

    fn is_adaptive(&self) -> bool {
        false
    }
}

/// Self-supervised prototype pruning (Sorscher et al.): score = distance of
/// the sample's *encoder embedding* to its class prototype (the embedding
/// centroid — the 1-means special case of their k-means protocol); keep
/// the hardest (most prototypical-distant) samples. Model-agnostic but
/// static — Table 17 shows why static loses to MILO's exploration.
pub struct SslPruneStrategy {
    /// Embedding matrix over the train split (from the preprocessor).
    embeddings: Matrix,
    cached: Option<Vec<usize>>,
}

impl SslPruneStrategy {
    pub fn new(embeddings: Matrix) -> Self {
        SslPruneStrategy { embeddings, cached: None }
    }

    /// Prototype-distance scores (higher = farther from class centroid =
    /// harder).
    pub fn scores(&self, ds: &Dataset) -> Vec<f32> {
        let e = self.embeddings.cols;
        let c = ds.classes();
        let mut centroids = Matrix::zeros(c, e);
        let mut counts = vec![0usize; c];
        for (i, &y) in ds.train_y.iter().enumerate() {
            let y = y as usize;
            for (j, v) in self.embeddings.row(i).iter().enumerate() {
                centroids.row_mut(y)[j] += v;
            }
            counts[y] += 1;
        }
        for y in 0..c {
            let cnt = counts[y].max(1) as f32;
            for v in centroids.row_mut(y).iter_mut() {
                *v /= cnt;
            }
        }
        ds.train_y
            .iter()
            .enumerate()
            .map(|(i, &y)| {
                let z = self.embeddings.row(i);
                let ct = centroids.row(y as usize);
                z.iter()
                    .zip(ct)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>()
                    .sqrt()
            })
            .collect()
    }
}

impl Strategy for SslPruneStrategy {
    fn name(&self) -> String {
        "ssl_prune".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        if let Some(c) = &self.cached {
            return Ok(c.clone());
        }
        let scores = self.scores(ctx.ds);
        let sel = keep_top_per_class(ctx.ds, &scores, ctx.k);
        self.cached = Some(sel.clone());
        Ok(sel)
    }

    fn is_adaptive(&self) -> bool {
        false
    }
}

/// Keep the top-`k` highest-scoring samples, allocated per class.
pub fn keep_top_per_class(ds: &Dataset, scores: &[f32], k: usize) -> Vec<usize> {
    let partition = ds.class_partition();
    let sizes: Vec<usize> = partition.iter().map(|p| p.len()).collect();
    let alloc = proportional_allocation(&sizes, k);
    let mut out = Vec::with_capacity(k);
    for (idx, &kc) in partition.iter().zip(&alloc) {
        let mut scored: Vec<(f32, usize)> = idx.iter().map(|&i| (scores[i], i)).collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        out.extend(scored.into_iter().take(kc).map(|(_, i)| i));
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;

    #[test]
    fn keep_top_per_class_respects_scores() {
        let ds = DatasetId::Trec6Like.generate(1);
        let n = ds.n_train();
        // score = index, so the kept set per class is its largest indices
        let scores: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let sel = keep_top_per_class(&ds, &scores, 60);
        assert_eq!(sel.len(), 60);
        let partition = ds.class_partition();
        for (c, idx) in partition.iter().enumerate() {
            let kept: Vec<usize> = sel
                .iter()
                .cloned()
                .filter(|i| ds.train_y[*i] as usize == c)
                .collect();
            let expected: Vec<usize> = {
                let mut v = idx.clone();
                v.sort_unstable();
                v.into_iter().rev().take(kept.len()).rev().collect()
            };
            assert_eq!(kept, expected, "class {c}");
        }
    }

    #[test]
    fn ssl_scores_track_generator_hardness() {
        // encoder = identity stand-in: use raw features as "embeddings";
        // prototype distance should correlate with the generator's hardness
        let ds = DatasetId::Cifar10Like.generate(2);
        let strat = SslPruneStrategy::new(ds.train_x.clone());
        let scores = strat.scores(&ds);
        // correlation via mean score of hard (h>0.6) vs easy (h<0.2) samples
        let (mut hard, mut easy) = (Vec::new(), Vec::new());
        for (i, &h) in ds.hardness.iter().enumerate() {
            if h > 0.6 {
                hard.push(scores[i]);
            } else if h < 0.2 {
                easy.push(scores[i]);
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len().max(1) as f32;
        assert!(
            mean(&hard) > mean(&easy),
            "hard {} !> easy {}",
            mean(&hard),
            mean(&easy)
        );
    }
}
