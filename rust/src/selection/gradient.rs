//! Model-dependent gradient-based baselines: CraigPB, GradMatchPB (OMP)
//! and Glister, in their CORDS per-batch/last-layer form.
//!
//! All three re-derive a subset every R epochs from the *current* model's
//! last-layer gradient embeddings `g_i = softmax(logits_i) − onehot(y_i)`
//! (the standard per-batch approximation: Killamsetty et al. 2021). The
//! expensive part — a full forward pass over the train split via the
//! `meta` artifact — is exactly the cost MILO's pre-processing avoids, and
//! is what the Fig. 1 wall-clock comparison measures.
//!
//! Simplifications vs CORDS, documented in DESIGN.md: GradMatchPB's OMP
//! weights are used for ranking but the trainer consumes unweighted
//! subsets; Glister uses the one-step Taylor approximation (no inner
//! re-evaluation loop). Both preserve the baselines' cost structure and
//! selection bias, which is what the reproduction compares.

use anyhow::Result;

use super::{proportional_allocation, SelectCtx, Strategy};
use crate::data::Split;
use crate::submod::{greedy_maximize, FacilityLocation, GreedyMode};
use crate::tensor::Matrix;
use crate::train::model::MetaOutputs;

/// Gather per-class gradient-embedding matrices from a meta pass.
fn class_gembs(
    meta: &MetaOutputs,
    partition: &[Vec<usize>],
) -> Vec<(Vec<usize>, Matrix)> {
    let c = meta.classes;
    partition
        .iter()
        .map(|idx| {
            let mut m = Matrix::zeros(idx.len(), c);
            for (r, &i) in idx.iter().enumerate() {
                m.row_mut(r).copy_from_slice(&meta.gemb[i * c..(i + 1) * c]);
            }
            (idx.clone(), m)
        })
        .collect()
}

/// CRAIGPB: per class, facility-location maximization over the gradient
/// similarity kernel — picks medoids whose gradients represent the class's
/// gradient distribution (Mirzasoleiman et al., per-batch form).
pub struct CraigPbStrategy;

impl Strategy for CraigPbStrategy {
    fn name(&self) -> String {
        "craigpb".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        let ds = ctx.ds;
        let meta = ctx.probe()?.meta(ds, Split::Train)?;
        let partition = ds.class_partition();
        let sizes: Vec<usize> = partition.iter().map(|p| p.len()).collect();
        let alloc = proportional_allocation(&sizes, ctx.k);
        let mut out = Vec::with_capacity(ctx.k);
        for ((indices, gm), &kc) in class_gembs(&meta, &partition).iter().zip(&alloc) {
            if kc == 0 {
                continue;
            }
            // gradient similarity kernel (rescaled cosine over gembs)
            let sim = crate::kernel::native_similarity(gm, crate::kernel::SimMetric::Cosine);
            let mut f = FacilityLocation::new(&sim);
            let trace = greedy_maximize(&mut f, kc, GreedyMode::Lazy, true, ctx.rng);
            out.extend(trace.selected.iter().map(|&local| indices[local]));
        }
        out.sort_unstable();
        Ok(out)
    }
}

/// GRAD-MATCHPB: orthogonal-matching-pursuit over per-sample gradient
/// embeddings, matching the mean full-data gradient per class.
pub struct GradMatchPbStrategy;

impl GradMatchPbStrategy {
    /// Non-negative OMP: greedily add the sample whose gradient has the
    /// largest positive inner product with the residual, then shrink the
    /// residual by its (clamped-positive) projection.
    fn omp(gm: &Matrix, k: usize) -> Vec<usize> {
        let n = gm.rows;
        let d = gm.cols;
        let k = k.min(n);
        // target: mean gradient
        let mut residual = vec![0.0f32; d];
        for r in 0..n {
            for (j, v) in gm.row(r).iter().enumerate() {
                residual[j] += v / n as f32;
            }
        }
        let mut picked = Vec::with_capacity(k);
        let mut in_set = vec![false; n];
        for _ in 0..k {
            let mut best = usize::MAX;
            let mut best_score = f32::MIN;
            for r in 0..n {
                if in_set[r] {
                    continue;
                }
                let dot: f32 = gm.row(r).iter().zip(&residual).map(|(a, b)| a * b).sum();
                if dot > best_score {
                    best_score = dot;
                    best = r;
                }
            }
            if best == usize::MAX {
                break;
            }
            in_set[best] = true;
            picked.push(best);
            // shrink residual by the positive projection onto the pick
            let g = gm.row(best);
            let gg: f32 = g.iter().map(|v| v * v).sum();
            if gg > 1e-12 {
                let coef = (best_score / gg).max(0.0);
                for (rv, gv) in residual.iter_mut().zip(g) {
                    *rv -= coef * gv;
                }
            }
        }
        picked
    }
}

impl Strategy for GradMatchPbStrategy {
    fn name(&self) -> String {
        "gradmatchpb".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        let ds = ctx.ds;
        let meta = ctx.probe()?.meta(ds, Split::Train)?;
        let partition = ds.class_partition();
        let sizes: Vec<usize> = partition.iter().map(|p| p.len()).collect();
        let alloc = proportional_allocation(&sizes, ctx.k);
        let mut out = Vec::with_capacity(ctx.k);
        for ((indices, gm), &kc) in class_gembs(&meta, &partition).iter().zip(&alloc) {
            for local in Self::omp(gm, kc) {
                out.push(indices[local]);
            }
        }
        out.sort_unstable();
        Ok(out)
    }
}

/// GLISTER: one-step generalization-based selection — rank train samples by
/// the alignment of their gradient with the *validation* gradient (the
/// first-order Taylor expansion of the bi-level objective), greedily
/// per class.
pub struct GlisterStrategy;

impl Strategy for GlisterStrategy {
    fn name(&self) -> String {
        "glister".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        let ds = ctx.ds;
        let (meta, val_meta) = {
            let probe = ctx.probe()?;
            (probe.meta(ds, Split::Train)?, probe.meta(ds, Split::Val)?)
        };
        let c = meta.classes;
        // mean validation gradient embedding (the descent direction whose
        // alignment we reward; sign: train gradients that point along the
        // val gradient reduce val loss when stepped against)
        let n_val = val_meta.losses.len();
        let mut vg = vec![0.0f32; c];
        for r in 0..n_val {
            for (j, v) in val_meta.gemb[r * c..(r + 1) * c].iter().enumerate() {
                vg[j] += v / n_val as f32;
            }
        }
        let partition = ctx.ds.class_partition();
        let sizes: Vec<usize> = partition.iter().map(|p| p.len()).collect();
        let alloc = proportional_allocation(&sizes, ctx.k);
        let mut out = Vec::with_capacity(ctx.k);
        for (idx, &kc) in partition.iter().zip(&alloc) {
            if kc == 0 {
                continue;
            }
            let mut scored: Vec<(f32, usize)> = idx
                .iter()
                .map(|&i| {
                    let g = &meta.gemb[i * c..(i + 1) * c];
                    let score: f32 = g.iter().zip(&vg).map(|(a, b)| a * b).sum();
                    (score, i)
                })
                .collect();
            scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            out.extend(scored.into_iter().take(kc).map(|(_, i)| i));
        }
        out.sort_unstable();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn omp_selects_gradient_representatives() {
        // two clusters of gradients; mean points between them, OMP must take
        // one from the dominant direction first
        let mut gm = Matrix::zeros(6, 2);
        for r in 0..4 {
            gm.row_mut(r).copy_from_slice(&[1.0, 0.0]);
        }
        for r in 4..6 {
            gm.row_mut(r).copy_from_slice(&[0.0, 1.0]);
        }
        let picks = GradMatchPbStrategy::omp(&gm, 2);
        assert_eq!(picks.len(), 2);
        // first pick from the dominant (4-member) direction
        assert!(picks[0] < 4, "{picks:?}");
        // second pick covers the other direction (residual now points there)
        assert!(picks[1] >= 4, "{picks:?}");
    }

    #[test]
    fn omp_handles_k_ge_n() {
        let gm = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]).unwrap();
        let picks = GradMatchPbStrategy::omp(&gm, 10);
        assert_eq!(picks.len(), 3);
    }

    #[test]
    fn omp_zero_gradients_terminate() {
        let gm = Matrix::zeros(4, 3);
        let picks = GradMatchPbStrategy::omp(&gm, 2);
        assert_eq!(picks.len(), 2); // ties resolve, no infinite loop
    }
}
