//! Subset-selection strategies: MILO and every baseline the paper
//! compares against (§4 "Subset Selection Baselines").
//!
//! A [`Strategy`] is asked for a fresh subset every `R` epochs by the
//! [`crate::train::Trainer`]; the time it spends inside [`Strategy::select`]
//! is accounted separately as *selection time* — the axis on which MILO's
//! model-agnostic pre-processing beats the model-dependent baselines
//! (paper Fig. 1).
//!
//! | strategy           | module          | model-dependent? |
//! |--------------------|-----------------|------------------|
//! | MILO / MILO(Fixed) | [`milo`]        | no (pre-processed metadata) |
//! | Random / Adaptive  | here            | no               |
//! | Full / Early-stop  | here            | no               |
//! | CraigPB            | [`gradient`]    | yes (per-R gradient pass) |
//! | GradMatchPB (OMP)  | [`gradient`]    | yes              |
//! | Glister            | [`gradient`]    | yes (+ val gradients) |
//! | EL2N / SSL pruning | [`pruning`]     | EL2N: yes; SSL: no |

pub mod gradient;
pub mod milo;
pub mod pruning;

use anyhow::Result;

pub use gradient::{CraigPbStrategy, GlisterStrategy, GradMatchPbStrategy};
pub use milo::{MiloStrategy, SgeStrategy, SgeVariantStrategy, WreStrategy};
pub use pruning::{El2nPruneStrategy, SslPruneStrategy};

use crate::data::Dataset;
use crate::runtime::Runtime;
use crate::train::model::MlpModel;
use crate::util::rng::Rng;

/// Everything a strategy may consult when (re)selecting a subset. The
/// model reference is what makes the gradient-based baselines
/// *model-dependent*; MILO never touches it.
pub struct SelectCtx<'a> {
    pub rt: &'a Runtime,
    pub ds: &'a Dataset,
    pub model: &'a mut MlpModel,
    /// Current epoch (0-based).
    pub epoch: usize,
    /// Total epochs of this run (curricula need the horizon).
    pub total_epochs: usize,
    /// Requested subset size.
    pub k: usize,
    pub rng: &'a mut Rng,
}

/// A subset-selection strategy.
pub trait Strategy {
    /// Short name for reports (matches the paper's tables).
    fn name(&self) -> String;

    /// Produce the train-set indices to use from this epoch on.
    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>>;

    /// Whether a new subset should be requested every R epochs (false for
    /// fixed-subset strategies, which are selected once).
    fn is_adaptive(&self) -> bool {
        true
    }
}

/// Allocate `k` slots across classes proportionally to class sizes
/// (largest-remainder rounding; every non-empty class keeps ≥ 0 and the
/// total is exactly `min(k, n)`).
pub fn proportional_allocation(class_sizes: &[usize], k: usize) -> Vec<usize> {
    let n: usize = class_sizes.iter().sum();
    let k = k.min(n);
    if n == 0 || k == 0 {
        return vec![0; class_sizes.len()];
    }
    let mut alloc: Vec<usize> = Vec::with_capacity(class_sizes.len());
    let mut remainders: Vec<(f64, usize)> = Vec::with_capacity(class_sizes.len());
    let mut used = 0usize;
    for (c, &sz) in class_sizes.iter().enumerate() {
        let exact = k as f64 * sz as f64 / n as f64;
        let base = (exact.floor() as usize).min(sz);
        alloc.push(base);
        used += base;
        remainders.push((exact - base as f64, c));
    }
    // distribute the remainder to the largest fractional parts with capacity
    remainders.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut left = k - used;
    let mut i = 0;
    while left > 0 {
        let (_, c) = remainders[i % remainders.len()];
        if alloc[c] < class_sizes[c] {
            alloc[c] += 1;
            left -= 1;
        }
        i += 1;
        // safety: if all classes full we would loop forever, but k ≤ n
        if i > remainders.len() * (k + 1) {
            break;
        }
    }
    alloc
}

// ---------------------------------------------------------------------------
// Model-agnostic baselines
// ---------------------------------------------------------------------------

/// RANDOM: one random subset, fixed for the whole run.
pub struct RandomStrategy {
    cached: Option<Vec<usize>>,
}

impl RandomStrategy {
    pub fn new() -> Self {
        RandomStrategy { cached: None }
    }
}

impl Default for RandomStrategy {
    fn default() -> Self {
        Self::new()
    }
}

impl Strategy for RandomStrategy {
    fn name(&self) -> String {
        "random".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        if self.cached.is_none() {
            self.cached = Some(ctx.rng.sample_indices(ctx.ds.n_train(), ctx.k));
        }
        Ok(self.cached.clone().unwrap())
    }

    fn is_adaptive(&self) -> bool {
        false
    }
}

/// ADAPTIVE-RANDOM: a fresh random subset every R epochs — the strong
/// simple baseline the paper keeps emphasizing.
pub struct AdaptiveRandomStrategy;

impl Strategy for AdaptiveRandomStrategy {
    fn name(&self) -> String {
        "adaptive_random".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        Ok(ctx.rng.sample_indices(ctx.ds.n_train(), ctx.k))
    }
}

/// FULL: the entire training set (the accuracy skyline).
pub struct FullStrategy;

impl Strategy for FullStrategy {
    fn name(&self) -> String {
        "full".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        Ok((0..ctx.ds.n_train()).collect())
    }

    fn is_adaptive(&self) -> bool {
        false
    }
}

/// A fixed, externally chosen subset (MILO(Fixed), EL2N-pruned sets, the
/// self-supervised-pruning baseline, …).
pub struct FixedStrategy {
    label: String,
    indices: Vec<usize>,
}

impl FixedStrategy {
    pub fn new(label: impl Into<String>, indices: Vec<usize>) -> Self {
        FixedStrategy { label: label.into(), indices }
    }
}

impl Strategy for FixedStrategy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn select(&mut self, _ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        Ok(self.indices.clone())
    }

    fn is_adaptive(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proportional_allocation_exact_total() {
        let sizes = [50, 30, 20];
        for k in [0, 1, 7, 10, 33, 100] {
            let a = proportional_allocation(&sizes, k);
            assert_eq!(a.iter().sum::<usize>(), k.min(100), "k={k} -> {a:?}");
            for (i, &x) in a.iter().enumerate() {
                assert!(x <= sizes[i]);
            }
        }
    }

    #[test]
    fn proportional_allocation_proportional() {
        let a = proportional_allocation(&[500, 300, 200], 100);
        assert_eq!(a, vec![50, 30, 20]);
    }

    #[test]
    fn proportional_allocation_handles_tiny_classes() {
        let a = proportional_allocation(&[1, 1, 998], 500);
        assert_eq!(a.iter().sum::<usize>(), 500);
        assert!(a[2] >= 498);
    }

    #[test]
    fn proportional_allocation_empty() {
        assert_eq!(proportional_allocation(&[], 10), Vec::<usize>::new());
        assert_eq!(proportional_allocation(&[0, 0], 10), vec![0, 0]);
    }
}
