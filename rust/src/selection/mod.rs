//! Subset-selection strategies: MILO and every baseline the paper
//! compares against (§4 "Subset Selection Baselines").
//!
//! A [`Strategy`] is asked for a fresh subset every `R` epochs by the
//! [`crate::train::Trainer`]; the time it spends inside [`Strategy::select`]
//! is accounted separately as *selection time* — the axis on which MILO's
//! model-agnostic pre-processing beats the model-dependent baselines
//! (paper Fig. 1).
//!
//! | strategy           | module          | model-dependent? |
//! |--------------------|-----------------|------------------|
//! | MILO / MILO(Fixed) | [`milo`]        | no (pre-processed metadata) |
//! | Random / Adaptive  | here            | no               |
//! | Full / Early-stop  | here            | no               |
//! | CraigPB            | [`gradient`]    | yes (per-R gradient pass) |
//! | GradMatchPB (OMP)  | [`gradient`]    | yes              |
//! | Glister            | [`gradient`]    | yes (+ val gradients) |
//! | EL2N / SSL pruning | [`pruning`]     | EL2N: yes; SSL: no |
//!
//! The model dependence is visible in the type system: [`SelectCtx`] is a
//! model-agnostic core (dataset, epoch horizon, subset size, RNG) and
//! model-dependent strategies must explicitly request the optional
//! [`ModelProbe`] via [`SelectCtx::probe`]. Model-agnostic strategies —
//! MILO, Random, Served, SSL pruning — run against a context built with
//! [`SelectCtx::model_agnostic`], with no `MlpModel` (or even runtime)
//! anywhere in sight.

pub mod gradient;
pub mod milo;
pub mod pruning;

use anyhow::{anyhow, Result};

pub use gradient::{CraigPbStrategy, GlisterStrategy, GradMatchPbStrategy};
pub use milo::{MiloStrategy, SgeStrategy, SgeVariantStrategy, WreStrategy};
pub use pruning::{El2nPruneStrategy, SslPruneStrategy};

use crate::data::{Dataset, Split};
use crate::runtime::Runtime;
use crate::train::model::{MetaOutputs, MlpModel};
use crate::util::rng::Rng;

/// The model-dependent half of a selection context: the live downstream
/// model plus the runtime needed to execute its artifacts. Gradient-based
/// baselines pay a forward/meta pass through this every R epochs — exactly
/// the cost MILO's pre-processing avoids.
pub struct ModelProbe<'a> {
    pub rt: &'a Runtime,
    pub model: &'a mut MlpModel,
}

impl<'a> ModelProbe<'a> {
    pub fn new(rt: &'a Runtime, model: &'a mut MlpModel) -> ModelProbe<'a> {
        ModelProbe { rt, model }
    }

    /// Per-sample meta pass (losses, EL2N, gradient embeddings) over a
    /// split — the expensive model-dependent computation.
    pub fn meta(&mut self, ds: &Dataset, split: Split) -> Result<MetaOutputs> {
        self.model.meta(self.rt, ds, split, None)
    }
}

/// Everything a strategy may consult when (re)selecting a subset.
///
/// The core is model-agnostic; the optional [`ModelProbe`] is what makes a
/// strategy *model-dependent*, and requesting it from a context that has
/// none (e.g. one built by [`SelectCtx::model_agnostic`]) is a loud error
/// rather than a hidden `&mut MlpModel` requirement.
pub struct SelectCtx<'a> {
    pub ds: &'a Dataset,
    /// Current epoch (0-based).
    pub epoch: usize,
    /// Total epochs of this run (curricula need the horizon).
    pub total_epochs: usize,
    /// Requested subset size.
    pub k: usize,
    pub rng: &'a mut Rng,
    probe: Option<ModelProbe<'a>>,
}

impl<'a> SelectCtx<'a> {
    /// A context with no model attached — all MILO strategies (and every
    /// other model-agnostic strategy) select through this.
    pub fn model_agnostic(
        ds: &'a Dataset,
        epoch: usize,
        total_epochs: usize,
        k: usize,
        rng: &'a mut Rng,
    ) -> SelectCtx<'a> {
        SelectCtx { ds, epoch, total_epochs, k, rng, probe: None }
    }

    /// Attach a [`ModelProbe`] (the trainer does this so model-dependent
    /// baselines can run inside the same loop).
    pub fn with_probe(mut self, probe: ModelProbe<'a>) -> SelectCtx<'a> {
        self.probe = Some(probe);
        self
    }

    /// Whether a model probe is attached.
    pub fn has_probe(&self) -> bool {
        self.probe.is_some()
    }

    /// Access the model probe; errors when the context is model-agnostic.
    pub fn probe(&mut self) -> Result<&mut ModelProbe<'a>> {
        self.probe.as_mut().ok_or_else(|| {
            anyhow!(
                "this strategy is model-dependent but the SelectCtx carries no \
                 ModelProbe (build the context with SelectCtx::with_probe, or run \
                 the strategy under a Trainer)"
            )
        })
    }
}

/// A subset-selection strategy.
pub trait Strategy {
    /// Short name for reports (matches the paper's tables).
    fn name(&self) -> String;

    /// Produce the train-set indices to use from this epoch on.
    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>>;

    /// Whether a new subset should be requested every R epochs (false for
    /// fixed-subset strategies, which are selected once).
    fn is_adaptive(&self) -> bool {
        true
    }
}

/// Allocate `k` slots across classes proportionally to class sizes
/// (largest-remainder rounding; every non-empty class keeps ≥ 0 and the
/// total is exactly `min(k, n)`).
pub fn proportional_allocation(class_sizes: &[usize], k: usize) -> Vec<usize> {
    let n: usize = class_sizes.iter().sum();
    let k = k.min(n);
    if n == 0 || k == 0 {
        return vec![0; class_sizes.len()];
    }
    let mut alloc: Vec<usize> = Vec::with_capacity(class_sizes.len());
    let mut remainders: Vec<(f64, usize)> = Vec::with_capacity(class_sizes.len());
    let mut used = 0usize;
    for (c, &sz) in class_sizes.iter().enumerate() {
        let exact = k as f64 * sz as f64 / n as f64;
        let base = (exact.floor() as usize).min(sz);
        alloc.push(base);
        used += base;
        remainders.push((exact - base as f64, c));
    }
    // Distribute the remainder to the largest fractional parts with spare
    // capacity. Invariant: Σ alloc + left == k ≤ n == Σ sizes, so whenever
    // `left > 0` some class still has capacity — after dropping saturated
    // classes every sweep hands out at least one slot, and the loop
    // terminates with Σ alloc == min(k, n) exactly (no heuristic bail-out).
    remainders.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut left = k - used;
    let mut candidates: Vec<usize> = remainders.iter().map(|&(_, c)| c).collect();
    while left > 0 {
        candidates.retain(|&c| alloc[c] < class_sizes[c]);
        debug_assert!(!candidates.is_empty(), "k <= n guarantees spare capacity");
        for &c in &candidates {
            if left == 0 {
                break;
            }
            if alloc[c] < class_sizes[c] {
                alloc[c] += 1;
                left -= 1;
            }
        }
    }
    alloc
}

// ---------------------------------------------------------------------------
// Model-agnostic baselines
// ---------------------------------------------------------------------------

/// RANDOM: one random subset, fixed for the whole run.
pub struct RandomStrategy {
    cached: Option<Vec<usize>>,
}

impl RandomStrategy {
    pub fn new() -> Self {
        RandomStrategy { cached: None }
    }
}

impl Default for RandomStrategy {
    fn default() -> Self {
        Self::new()
    }
}

impl Strategy for RandomStrategy {
    fn name(&self) -> String {
        "random".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        if self.cached.is_none() {
            self.cached = Some(ctx.rng.sample_indices(ctx.ds.n_train(), ctx.k));
        }
        Ok(self.cached.clone().unwrap())
    }

    fn is_adaptive(&self) -> bool {
        false
    }
}

/// ADAPTIVE-RANDOM: a fresh random subset every R epochs — the strong
/// simple baseline the paper keeps emphasizing.
pub struct AdaptiveRandomStrategy;

impl Strategy for AdaptiveRandomStrategy {
    fn name(&self) -> String {
        "adaptive_random".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        Ok(ctx.rng.sample_indices(ctx.ds.n_train(), ctx.k))
    }
}

/// FULL: the entire training set (the accuracy skyline).
pub struct FullStrategy;

impl Strategy for FullStrategy {
    fn name(&self) -> String {
        "full".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        Ok((0..ctx.ds.n_train()).collect())
    }

    fn is_adaptive(&self) -> bool {
        false
    }
}

/// A fixed, externally chosen subset (MILO(Fixed), EL2N-pruned sets, the
/// self-supervised-pruning baseline, …).
pub struct FixedStrategy {
    label: String,
    indices: Vec<usize>,
}

impl FixedStrategy {
    pub fn new(label: impl Into<String>, indices: Vec<usize>) -> Self {
        FixedStrategy { label: label.into(), indices }
    }
}

impl Strategy for FixedStrategy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn select(&mut self, _ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        Ok(self.indices.clone())
    }

    fn is_adaptive(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proportional_allocation_exact_total() {
        let sizes = [50, 30, 20];
        for k in [0, 1, 7, 10, 33, 100] {
            let a = proportional_allocation(&sizes, k);
            assert_eq!(a.iter().sum::<usize>(), k.min(100), "k={k} -> {a:?}");
            for (i, &x) in a.iter().enumerate() {
                assert!(x <= sizes[i]);
            }
        }
    }

    #[test]
    fn proportional_allocation_proportional() {
        let a = proportional_allocation(&[500, 300, 200], 100);
        assert_eq!(a, vec![50, 30, 20]);
    }

    #[test]
    fn proportional_allocation_handles_tiny_classes() {
        let a = proportional_allocation(&[1, 1, 998], 500);
        assert_eq!(a.iter().sum::<usize>(), 500);
        assert!(a[2] >= 498);
    }

    #[test]
    fn proportional_allocation_empty() {
        assert_eq!(proportional_allocation(&[], 10), Vec::<usize>::new());
        assert_eq!(proportional_allocation(&[0, 0], 10), vec![0, 0]);
    }

    /// The allocation invariants: Σ alloc == min(k, n) exactly and every
    /// class stays within capacity.
    fn assert_allocation_exact(sizes: &[usize], k: usize) {
        let n: usize = sizes.iter().sum();
        let a = proportional_allocation(sizes, k);
        assert_eq!(a.len(), sizes.len());
        assert_eq!(
            a.iter().sum::<usize>(),
            k.min(n),
            "sizes {sizes:?} k={k} -> {a:?}"
        );
        for (i, &x) in a.iter().enumerate() {
            assert!(x <= sizes[i], "class {i} over capacity: {a:?} vs {sizes:?}");
        }
    }

    #[test]
    fn proportional_allocation_adversarial_cases() {
        // crafted worst cases for the old heuristic bail-out: many tiny
        // classes, saturation at k ≈ n, extreme imbalance, zero classes
        assert_allocation_exact(&vec![1; 50], 49);
        assert_allocation_exact(&vec![1; 50], 50);
        assert_allocation_exact(&[1, 1, 998], 999);
        assert_allocation_exact(&[0, 0, 5, 0], 5);
        assert_allocation_exact(&[2, 3, 5, 7, 11, 13], 40);
        let mut skew: Vec<usize> = vec![1; 99];
        skew.push(10_000);
        assert_allocation_exact(&skew, 10_050);
    }

    #[test]
    fn proportional_allocation_property_sweep() {
        crate::testkit::check_cases(0xA110C, 200, |seed| {
            let mut rng = crate::util::rng::Rng::new(seed);
            let classes = 1 + rng.below(12);
            // zeros allowed: empty classes must get 0 and never wedge
            let sizes: Vec<usize> = (0..classes).map(|_| rng.below(40)).collect();
            let n: usize = sizes.iter().sum();
            for k in [
                0,
                1,
                n / 3,
                n.saturating_sub(1),
                n,
                n + 1,
                7 * n + 13,
                1 + rng.below(n.max(1) * 2),
            ] {
                assert_allocation_exact(&sizes, k);
            }
        });
    }
}
