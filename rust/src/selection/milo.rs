//! MILO's exploration strategies: SGE, WRE, the easy-to-hard curriculum,
//! and the "SGE variant with decaying greedy fraction" ablation (I.7).
//!
//! These strategies are *thin samplers over pre-processed metadata*: all
//! submodular work happened once in [`crate::coordinator::Preprocessor`]
//! (the whole point of the paper), so `select` here costs the same as
//! random sampling.

use anyhow::{ensure, Result};

use super::{proportional_allocation, SelectCtx, Strategy};
use crate::submod::weighted_sample_without_replacement;

/// Per-class WRE sampling state: class member indices (into the train set)
/// and their Taylor-softmax importance probabilities.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassProbs {
    pub indices: Vec<usize>,
    pub probs: Vec<f64>,
}

impl ClassProbs {
    /// Draw `k` members of this class without replacement, weighted.
    pub fn sample(&self, k: usize, rng: &mut crate::util::rng::Rng) -> Vec<usize> {
        weighted_sample_without_replacement(&self.probs, k, rng)
            .into_iter()
            .map(|local| self.indices[local])
            .collect()
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }
}

// ---------------------------------------------------------------------------
// SGE: cycle through n pre-selected stochastic-greedy subsets
// ---------------------------------------------------------------------------

/// Stochastic-Greedy Exploration (paper §3.1.1): the preprocessor selected
/// `n` near-optimal subsets (stochastic greedy, Algorithm 2); training
/// cycles through them, switching every R epochs.
pub struct SgeStrategy {
    label: String,
    subsets: Vec<Vec<usize>>,
    cursor: usize,
}

impl SgeStrategy {
    pub fn new(label: impl Into<String>, subsets: Vec<Vec<usize>>) -> Self {
        assert!(!subsets.is_empty(), "SGE needs at least one subset");
        SgeStrategy { label: label.into(), subsets, cursor: 0 }
    }

    /// Swap in a new epoch's subset pool and restart the cycle at subset
    /// 0, so every follower applying the same update at the same epoch
    /// boundary sees the same subsequent stream.
    pub fn replace_subsets(&mut self, subsets: Vec<Vec<usize>>) {
        assert!(!subsets.is_empty(), "SGE needs at least one subset");
        self.subsets = subsets;
        self.cursor = 0;
    }
}

impl Strategy for SgeStrategy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn select(&mut self, _ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        let s = self.subsets[self.cursor % self.subsets.len()].clone();
        self.cursor += 1;
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// WRE: weighted random exploration from the importance distribution
// ---------------------------------------------------------------------------

/// Weighted Random Exploration (paper §3.1.2): sample a fresh subset from
/// the Taylor-softmax importance distribution every R epochs, class-wise
/// without replacement.
pub struct WreStrategy {
    label: String,
    classes: Vec<ClassProbs>,
}

impl WreStrategy {
    pub fn new(label: impl Into<String>, classes: Vec<ClassProbs>) -> Self {
        WreStrategy { label: label.into(), classes }
    }

    pub fn sample_k(&self, k: usize, rng: &mut crate::util::rng::Rng) -> Vec<usize> {
        let sizes: Vec<usize> = self.classes.iter().map(|c| c.len()).collect();
        let alloc = proportional_allocation(&sizes, k);
        let mut out = Vec::with_capacity(k);
        for (cls, &kc) in self.classes.iter().zip(&alloc) {
            out.extend(cls.sample(kc, rng));
        }
        out.sort_unstable();
        out
    }
}

impl Strategy for WreStrategy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        Ok(self.sample_k(ctx.k, ctx.rng))
    }
}

// ---------------------------------------------------------------------------
// MILO: the easy-to-hard curriculum (SGE/graph-cut -> WRE/disparity-min)
// ---------------------------------------------------------------------------

/// The full MILO strategy (paper Algorithm 1): train the first `κ·T`
/// epochs on SGE subsets selected with graph-cut (easy/representative),
/// then switch to WRE sampling from the disparity-min importance
/// distribution (hard/diverse) for the rest.
pub struct MiloStrategy {
    /// Pre-selected SGE (graph-cut) subsets.
    sge: SgeStrategy,
    /// WRE (disparity-min) class distributions.
    wre: WreStrategy,
    /// Fraction of epochs on the easy phase; the paper tunes κ = 1/6.
    pub kappa: f64,
}

pub const DEFAULT_KAPPA: f64 = 1.0 / 6.0;

impl MiloStrategy {
    pub fn new(sge_subsets: Vec<Vec<usize>>, wre_classes: Vec<ClassProbs>, kappa: f64) -> Self {
        MiloStrategy {
            sge: SgeStrategy::new("milo_sge_phase", sge_subsets),
            wre: WreStrategy::new("milo_wre_phase", wre_classes),
            kappa,
        }
    }

    /// Epoch at which the curriculum flips from SGE to WRE.
    pub fn switch_epoch(&self, total_epochs: usize) -> usize {
        (self.kappa * total_epochs as f64).round() as usize
    }

    pub fn in_sge_phase(&self, epoch: usize, total_epochs: usize) -> bool {
        epoch < self.switch_epoch(total_epochs)
    }

    /// Apply a continual-arrival epoch update (the payload of a
    /// [`crate::serve::EpochUpdate`] pushed by a followed server): the
    /// SGE pool is replaced and its cycle restarts at subset 0. Push
    /// frames carry subsets only, so WRE distributions are optional —
    /// pass `Some` after a `GET_META` refresh when the WRE phase of the
    /// curriculum still lies ahead.
    pub fn apply_epoch(
        &mut self,
        sge_subsets: Vec<Vec<usize>>,
        wre_classes: Option<Vec<ClassProbs>>,
    ) {
        self.sge.replace_subsets(sge_subsets);
        if let Some(classes) = wre_classes {
            self.wre.classes = classes;
        }
    }
}

impl Strategy for MiloStrategy {
    fn name(&self) -> String {
        "milo".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        ensure!(ctx.total_epochs > 0, "total_epochs must be set");
        if self.in_sge_phase(ctx.epoch, ctx.total_epochs) {
            self.sge.select(ctx)
        } else {
            self.wre.select(ctx)
        }
    }
}

// ---------------------------------------------------------------------------
// SGE-variant ablation (paper I.7)
// ---------------------------------------------------------------------------

/// The "more exploration" SGE variant of ablation I.7: a fraction of the
/// subset comes from an SGE pick, the rest uniformly at random, with the
/// SGE share decaying from 1 to 0 over training on a cosine schedule.
pub struct SgeVariantStrategy {
    sge: SgeStrategy,
}

impl SgeVariantStrategy {
    pub fn new(sge_subsets: Vec<Vec<usize>>) -> Self {
        SgeVariantStrategy { sge: SgeStrategy::new("sge_variant_inner", sge_subsets) }
    }
}

impl Strategy for SgeVariantStrategy {
    fn name(&self) -> String {
        "sge_variant".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        let t = ctx.epoch as f64 / ctx.total_epochs.max(1) as f64;
        // cosine decay of the greedy share from 1 to 0
        let share = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
        // clamp to the population: only n_train distinct indices exist, so
        // asking for more must not spin the uniform fill forever
        let target = ctx.k.min(ctx.ds.n_train());
        let k_greedy = ((target as f64) * share).round() as usize;
        let base = self.sge.select(ctx)?;
        let mut out: Vec<usize> = base.into_iter().take(k_greedy).collect();
        fill_uniform(&mut out, ctx.ds.n_train(), target, ctx.rng);
        out.sort_unstable();
        Ok(out)
    }
}

/// Top `out` up to `min(target, n_train)` distinct indices with uniform
/// random picks from `[0, n_train)` not already present. Terminates for
/// every `target`, including `target >= n_train` (it then completes `out`
/// to the whole population).
pub(crate) fn fill_uniform(
    out: &mut Vec<usize>,
    n_train: usize,
    target: usize,
    rng: &mut crate::util::rng::Rng,
) {
    let target = target.min(n_train);
    let mut in_set = vec![false; n_train];
    for &i in out.iter() {
        in_set[i] = true;
    }
    while out.len() < target {
        let j = rng.below(n_train);
        if !in_set[j] {
            in_set[j] = true;
            out.push(j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mk_classes(n_per: usize, classes: usize) -> Vec<ClassProbs> {
        (0..classes)
            .map(|c| {
                let indices: Vec<usize> = (0..n_per).map(|i| c * n_per + i).collect();
                // heavier weight on the first element of every class
                let mut probs = vec![1.0; n_per];
                probs[0] = 50.0;
                ClassProbs { indices, probs }
            })
            .collect()
    }

    #[test]
    fn wre_sample_is_class_proportional() {
        let wre = WreStrategy::new("t", mk_classes(100, 4));
        let mut rng = Rng::new(1);
        let s = wre.sample_k(40, &mut rng);
        assert_eq!(s.len(), 40);
        // 10 per class
        for c in 0..4 {
            let count = s.iter().filter(|&&i| i / 100 == c).count();
            assert_eq!(count, 10, "class {c}");
        }
        // no duplicates
        let mut d = s.clone();
        d.dedup();
        assert_eq!(d.len(), 40);
    }

    #[test]
    fn wre_prefers_heavy_items() {
        let wre = WreStrategy::new("t", mk_classes(50, 2));
        let mut rng = Rng::new(2);
        let mut hits = 0;
        for _ in 0..200 {
            let s = wre.sample_k(10, &mut rng);
            if s.contains(&0) {
                hits += 1;
            }
        }
        // uniform would hit item 0 in ~5/50 = 10% of draws
        assert!(hits > 100, "heavy item picked {hits}/200");
    }

    #[test]
    fn sge_cycles_subsets() {
        let subsets = vec![vec![0, 1], vec![2, 3], vec![4, 5]];
        let mut s = SgeStrategy::new("t", subsets.clone());
        // SGE is model-agnostic: a bare context, no runtime, no MlpModel
        let ds = crate::data::DatasetId::Trec6Like.generate(1);
        let mut rng = Rng::new(0);
        for i in 0..6 {
            let mut ctx = SelectCtx::model_agnostic(&ds, i, 6, 2, &mut rng);
            let got = s.select(&mut ctx).unwrap();
            assert_eq!(got, subsets[i % 3]);
        }
    }

    #[test]
    fn fill_uniform_terminates_when_target_exceeds_population() {
        // regression: SgeVariantStrategy::select used to spin forever when
        // asked for k >= n_train — the uniform fill kept drawing from an
        // exhausted population. The fill must clamp to n_train and stop.
        let mut rng = Rng::new(7);
        let mut out = vec![0, 1];
        fill_uniform(&mut out, 4, 10, &mut rng);
        out.sort_unstable();
        assert_eq!(out, vec![0, 1, 2, 3], "must complete the population and stop");

        // exact-population request
        let mut out = Vec::new();
        fill_uniform(&mut out, 5, 5, &mut rng);
        out.sort_unstable();
        assert_eq!(out, vec![0, 1, 2, 3, 4]);

        // ordinary sub-population request: distinct, bounded, right size
        let mut out = vec![3];
        fill_uniform(&mut out, 100, 10, &mut rng);
        assert_eq!(out.len(), 10);
        let mut d = out.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
        assert!(out.iter().all(|&i| i < 100));
    }

    #[test]
    fn apply_epoch_swaps_the_pool_and_restarts_the_cycle() {
        let ds = crate::data::DatasetId::Trec6Like.generate(1);
        let mut rng = Rng::new(0);
        let mut m = MiloStrategy::new(
            vec![vec![0, 1], vec![2, 3]],
            mk_classes(10, 2),
            1.0, // pure SGE phase
        );
        let mut ctx = SelectCtx::model_agnostic(&ds, 0, 4, 2, &mut rng);
        assert_eq!(m.select(&mut ctx).unwrap(), vec![0, 1]);
        m.apply_epoch(vec![vec![7, 8], vec![9, 10]], None);
        // the cycle restarts at subset 0 of the new epoch's pool
        let mut rng = Rng::new(0);
        for (epoch, want) in [(1, vec![7, 8]), (2, vec![9, 10]), (3, vec![7, 8])] {
            let mut ctx = SelectCtx::model_agnostic(&ds, epoch, 9, 2, &mut rng);
            assert_eq!(m.select(&mut ctx).unwrap(), want);
        }
    }

    #[test]
    fn milo_phase_switch() {
        let m = MiloStrategy::new(vec![vec![0]], mk_classes(10, 2), 1.0 / 6.0);
        assert_eq!(m.switch_epoch(60), 10);
        assert!(m.in_sge_phase(9, 60));
        assert!(!m.in_sge_phase(10, 60));
        // kappa = 0 -> pure WRE; kappa = 1 -> pure SGE
        let pure_wre = MiloStrategy::new(vec![vec![0]], mk_classes(10, 2), 0.0);
        assert!(!pure_wre.in_sge_phase(0, 60));
        let pure_sge = MiloStrategy::new(vec![vec![0]], mk_classes(10, 2), 1.0);
        assert!(pure_sge.in_sge_phase(59, 60));
    }
}
