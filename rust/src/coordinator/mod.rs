//! The MILO coordinator: the pre-processing pipeline (paper Fig. 3, left
//! box) and the experiment runner that drives the paper's evaluation grid.
//!
//! Pre-processing is the paper's central move — all model-independent work
//! happens **once per dataset**, before any training:
//!
//! 1. encode the train split with the frozen zero-shot encoder artifact;
//! 2. build class-wise similarity kernels (Pallas artifact or native;
//!    dense `n_c²` blocks or sparse top-`knn` CSR via the `knn` option);
//! 3. SGE: `n` stochastic-greedy subsets under graph-cut (easy phase);
//! 4. WRE: full-sweep `GreedySampleImportance` under disparity-min →
//!    Taylor-softmax importance distribution per class (hard phase);
//!    — steps 2–4 and the fixed subset all fan out per class over
//!    `par_map`, with per-`(subset, class)` RNG streams so results are
//!    independent of scheduling;
//! 5. store everything as dataset metadata — the content-addressed binary
//!    registry in [`crate::store`] (or plain JSON via [`save_metadata`]) —
//!    so training any number of downstream models costs no further
//!    selection work; `milo serve` ([`crate::serve`]) exposes one such
//!    artifact to N concurrent trainers over TCP.

pub mod experiment;
pub mod repro;
pub mod stream;

use std::time::Instant;

use anyhow::{Context, Result};

pub use experiment::{ExperimentRunner, StrategyKind, TrialRecord};

use crate::data::{Dataset, Split};
use crate::kernel::{
    build_class_kernels_scheduled, sparse, ClassKernel, ClassKernels, ClassSim,
    KernelSchedule, SimMetric, SimilarityBackend,
};
use crate::runtime::{Arg, Runtime};
use crate::selection::milo::ClassProbs;
use crate::selection::proportional_allocation;
use crate::submod::{
    greedy_maximize, sample_importance, GreedyMode, SetFunctionKind,
};
use crate::tensor::Matrix;
use crate::util::json::Json;
use crate::util::math::taylor_softmax;
use crate::util::rng::Rng;
use crate::util::threads::par_map;

/// Which pre-processing pipeline produces the metadata. The kernel path is
/// the paper's recipe; the feature-based path is the conclusion's
/// kernel-free future-work variant (O(n·2E) memory instead of Σ n_c²).
/// Part of [`PreprocessOptions`] so one [`crate::session::MetaSource`]
/// addresses both — the pipeline is part of the store fingerprint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreprocessPipeline {
    /// Class-wise similarity kernels + SGE/WRE (paper Algorithm 1).
    Kernel,
    /// Kernel-free [`crate::submod::FeatureCoverage`] pipeline.
    FeatureBased,
}

impl PreprocessPipeline {
    /// Stable descriptor used in store fingerprints.
    pub fn name(self) -> &'static str {
        match self {
            PreprocessPipeline::Kernel => "kernel",
            PreprocessPipeline::FeatureBased => "feature_based",
        }
    }
}

/// Pre-processing options (defaults = the paper's recipe).
#[derive(Clone, Debug)]
pub struct PreprocessOptions {
    /// Subset fraction the SGE subsets / fixed subsets are sized for.
    pub fraction: f64,
    /// Number of SGE subsets (paper Algorithm 1 stores subsets for epochs
    /// 0, R, …, κT−R; we default to 3 and cycle).
    pub n_sge_subsets: usize,
    /// Set function for the SGE (easy) phase.
    pub sge_function: SetFunctionKind,
    /// Set function for the WRE importance sweep (hard phase).
    pub wre_function: SetFunctionKind,
    pub metric: SimMetric,
    pub backend: SimilarityBackend,
    /// Stochastic-greedy ε (paper: 0.01).
    pub epsilon: f64,
    /// Seed for the stochastic parts of pre-processing.
    pub seed: u64,
    /// Optional Fig-11 encoder variant (artifact `encoder_{ds}__{variant}`);
    /// `None` = the default zero-shot encoder.
    pub encoder_variant: Option<String>,
    /// Pipeline variant (kernel vs kernel-free feature-based).
    pub pipeline: PreprocessPipeline,
    /// Sparse kernel width: `Some(k)` builds top-`k` CSR class blocks
    /// (`≈ n_c·k` floats, gains in O(k)) instead of dense `n_c²` ones.
    /// `knn < n_c` is an approximation and selects differently from the
    /// dense path, so it is part of the store address
    /// ([`crate::store::MetaKey`]); `knn ≥ n_c` reproduces dense
    /// selections bit-for-bit. `None` = dense (the paper's recipe).
    pub knn: Option<usize>,
    /// Rows per native kernel-construction strip (`--sim-tile`); `None` =
    /// the built-in default. **Schedule-only**: changes when work happens,
    /// never any kernel value, so it is excluded from
    /// [`crate::store::MetaKey`].
    pub sim_tile: Option<usize>,
    /// Overlap depth of the kernel-build pipeline (`--pipeline-depth`):
    /// `1` = serial reference, `2` = double buffering (default). Also
    /// schedule-only and excluded from [`crate::store::MetaKey`].
    pub pipeline_depth: usize,
}

impl PreprocessOptions {
    /// The kernel-construction schedule these options imply.
    pub fn schedule(&self) -> KernelSchedule {
        KernelSchedule { strip_rows: self.sim_tile, depth: self.pipeline_depth }
    }
}

impl Default for PreprocessOptions {
    fn default() -> Self {
        PreprocessOptions {
            fraction: 0.1,
            n_sge_subsets: 3,
            sge_function: SetFunctionKind::GRAPH_CUT_DEFAULT,
            wre_function: SetFunctionKind::DisparityMin,
            metric: SimMetric::Cosine,
            backend: SimilarityBackend::Pjrt,
            epsilon: 0.01,
            seed: 1,
            encoder_variant: None,
            pipeline: PreprocessPipeline::Kernel,
            knn: None,
            sim_tile: None,
            pipeline_depth: 2,
        }
    }
}

/// The per-(dataset, fraction) metadata MILO stores (paper: "pre-selecting
/// subsets and storing them as metadata with each dataset").
#[derive(Clone, Debug, PartialEq)]
pub struct Metadata {
    pub dataset: String,
    pub fraction: f64,
    /// SGE subsets (global train indices), one per exploration round.
    pub sge_subsets: Vec<Vec<usize>>,
    /// WRE per-class importance distributions.
    pub wre_classes: Vec<ClassProbs>,
    /// Fixed disparity-min subset (the MILO(Fixed) baseline).
    pub fixed_dm: Vec<usize>,
    /// Wall-clock cost of pre-processing (App. H.3).
    pub preprocess_secs: f64,
}

/// Pre-processing pipeline bound to a runtime.
pub struct Preprocessor<'a> {
    rt: &'a Runtime,
    pub opts: PreprocessOptions,
}

impl<'a> Preprocessor<'a> {
    pub fn new(rt: &'a Runtime) -> Preprocessor<'a> {
        Preprocessor { rt, opts: PreprocessOptions::default() }
    }

    pub fn with_options(rt: &'a Runtime, opts: PreprocessOptions) -> Preprocessor<'a> {
        Preprocessor { rt, opts }
    }

    /// Encode a split with the frozen zero-shot encoder artifact (or a
    /// named Fig-11 variant when `opts.encoder_variant` is set).
    pub fn encode(&self, ds: &Dataset, split: Split) -> Result<Matrix> {
        let man = self.rt.manifest();
        let b = man.batch;
        let d = ds.id.input_dim();
        let artifact = match &self.opts.encoder_variant {
            Some(v) => format!("encoder_{}__{}", ds.name(), v),
            None => format!("encoder_{}", ds.name()),
        };
        // variants may have non-default embedding widths
        let e = man
            .artifacts
            .get(&artifact)
            .and_then(|a| a.embed_dim)
            .unwrap_or(man.embed_dim);
        let x = ds.x(split);
        let n = x.rows;
        let mut out = Matrix::zeros(n, e);
        let mut xbuf = vec![0.0f32; b * d];
        let mut at = 0usize;
        while at < n {
            let take = (n - at).min(b);
            for r in 0..take {
                xbuf[r * d..(r + 1) * d].copy_from_slice(x.row(at + r));
            }
            for r in take..b {
                xbuf[r * d..(r + 1) * d].iter_mut().for_each(|v| *v = 0.0);
            }
            let res = self.rt.execute(&artifact, &[Arg::F32(&xbuf)])?;
            for r in 0..take {
                out.row_mut(at + r).copy_from_slice(&res[0][r * e..(r + 1) * e]);
            }
            at += take;
        }
        Ok(out)
    }

    /// Build the class-wise kernels from provided embeddings (dense or
    /// sparse top-`knn`, per `opts.knn`).
    pub fn kernels(&self, ds: &Dataset, embeddings: &Matrix) -> Result<ClassKernels> {
        let _span = crate::obs::Span::enter("preprocess.kernels");
        build_class_kernels_scheduled(
            Some(self.rt),
            embeddings,
            &ds.class_partition(),
            self.opts.metric,
            self.opts.backend,
            self.opts.knn,
            &self.opts.schedule(),
        )
    }

    /// Fused fast path: when the manifest carries an
    /// `embed_sim_topk_{ds}` artifact, the whole embedding → cosine →
    /// top-`K` chain collapses into **one execution per class tile pair**
    /// straight from raw features — no separate encode pass, no full
    /// similarity strips back to the host. Only valid for the exact
    /// pipeline the artifact bakes in (Pjrt backend, cosine metric, the
    /// default zero-shot encoder, sparse `knn ≤ K`); returns `Ok(None)`
    /// whenever any of that differs so [`Preprocessor::run`] falls back
    /// to the generic encode + kernels path.
    fn fused_kernels(&self, ds: &Dataset) -> Result<Option<ClassKernels>> {
        if self.opts.backend != SimilarityBackend::Pjrt
            || self.opts.metric != SimMetric::Cosine
            || self.opts.encoder_variant.is_some()
        {
            return Ok(None);
        }
        let Some(knn) = self.opts.knn else { return Ok(None) };
        let artifact = format!("embed_sim_topk_{}", ds.name());
        let Some(entry) = self.rt.manifest().artifacts.get(&artifact) else {
            return Ok(None);
        };
        match entry.k {
            Some(k) if knn <= k => {}
            _ => return Ok(None),
        }
        let _span = crate::obs::Span::enter("preprocess.kernels");
        let x = ds.x(Split::Train);
        let sched = self.opts.schedule();
        let mut per_class = Vec::new();
        for idx in &ds.class_partition() {
            let z = x.gather_rows(idx);
            let (sk, _stats) = sparse::sparse_fused_pjrt(self.rt, &z, &artifact, knn, &sched)?;
            per_class
                .push(ClassKernel { indices: idx.clone(), sim: ClassSim::Sparse(sk) });
        }
        Ok(Some(ClassKernels { per_class, metric: self.opts.metric }))
    }

    /// SGE: `n_subsets` stochastic-greedy subsets of size `k`, assembled
    /// class-wise under `kind`.
    pub fn sge_subsets(
        &self,
        ds: &Dataset,
        kernels: &ClassKernels,
        kind: SetFunctionKind,
        k: usize,
        n_subsets: usize,
        rng: &mut Rng,
    ) -> Vec<Vec<usize>> {
        sge_subsets_from_kernels(
            ds.n_train(),
            kernels,
            kind,
            k,
            n_subsets,
            self.opts.epsilon,
            rng,
        )
    }

    /// Fixed subset by full (lazy) greedy under `kind` — Fig. 4's fixed
    /// subsets and the MILO(Fixed) baseline.
    pub fn fixed_subset(
        &self,
        ds: &Dataset,
        kernels: &ClassKernels,
        kind: SetFunctionKind,
        k: usize,
    ) -> Vec<usize> {
        fixed_subset_from_kernels(ds.n_train(), kernels, kind, k)
    }

    /// WRE: per-class GreedySampleImportance sweep under `kind`, Taylor-
    /// softmax normalized (paper Eq. 4–5).
    pub fn wre_distribution(
        &self,
        kernels: &ClassKernels,
        kind: SetFunctionKind,
    ) -> Vec<ClassProbs> {
        wre_distribution_from_kernels(kernels, kind)
    }

    /// Exchange-chain subsets from `P(S) ∝ exp(β·f(S))` (§3.1 Eq. 2, the
    /// paper's "ideal formulation" — our future-work extension). Returns
    /// the class-stitched subsets and the chain diagnostics used by the
    /// `gibbs` ablation (evaluations vs SGE's, acceptance rate).
    pub fn gibbs_subsets(
        &self,
        ds: &Dataset,
        kernels: &ClassKernels,
        kind: SetFunctionKind,
        k: usize,
        beta: f32,
        n_subsets: usize,
        rng: &mut Rng,
    ) -> (Vec<Vec<usize>>, crate::submod::GibbsStats) {
        let sizes: Vec<usize> = kernels.per_class.iter().map(|c| c.indices.len()).collect();
        let alloc = proportional_allocation(&sizes, k.min(ds.n_train()));
        let refs: Vec<(crate::kernel::KernelRef<'_>, &[usize])> = kernels
            .per_class
            .iter()
            .map(|ck| (ck.sim.view(), ck.indices.as_slice()))
            .collect();
        // burn-in/thinning scaled to the per-class budget: the chain needs
        // ~k accepted swaps to decorrelate a size-k state.
        let kc_max = alloc.iter().copied().max().unwrap_or(1).max(1);
        crate::submod::gibbs_class_subsets(
            &refs,
            &alloc,
            kind,
            beta,
            8 * kc_max,
            2 * kc_max,
            n_subsets,
            rng,
        )
    }

    /// Kernel-free feature-based pre-processing (conclusion future work):
    /// the same SGE-subsets + WRE-distribution outputs, driven by
    /// [`crate::submod::FeatureCoverage`] over non-negative coverage
    /// features — memory O(n·2E) instead of the O(Σ n_c²) class kernels.
    pub fn run_featurebased(&self, ds: &Dataset) -> Result<Metadata> {
        let t0 = Instant::now();
        let mut rng = Rng::new(self.opts.seed ^ 0xFEA7).derive_str(ds.name());
        let k = ((self.opts.fraction * ds.n_train() as f64).round() as usize).max(1);
        let embeddings =
            crate::obs::time("preprocess.encode", || self.encode(ds, Split::Train))?;
        let parts = ds.class_partition();
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        let alloc = proportional_allocation(&sizes, k.min(ds.n_train()));
        // per-class coverage features
        let phis: Vec<(Matrix, &Vec<usize>)> = parts
            .iter()
            .map(|idx| {
                let z = embeddings.gather_rows(idx);
                (crate::submod::coverage_features(&z), idx)
            })
            .collect();
        // SGE-analog: stochastic-greedy over the coverage function
        let sge_subsets: Vec<Vec<usize>> = crate::obs::time("preprocess.sge", || {
            (0..self.opts.n_sge_subsets)
                .map(|_| {
                    let mut subset = Vec::with_capacity(k);
                    for ((phi, idx), &kc) in phis.iter().zip(&alloc) {
                        if kc == 0 {
                            continue;
                        }
                        let mut f = crate::submod::FeatureCoverage::new(phi);
                        let trace = greedy_maximize(
                            &mut f,
                            kc,
                            GreedyMode::Stochastic { epsilon: self.opts.epsilon },
                            true,
                            &mut rng,
                        );
                        subset.extend(trace.selected.iter().map(|&l| idx[l]));
                    }
                    subset.sort_unstable();
                    subset
                })
                .collect()
        });
        // WRE-analog: importance sweep of the coverage gains
        let wre_classes: Vec<ClassProbs> = crate::obs::time("preprocess.wre", || {
            phis.iter()
                .map(|(phi, idx)| {
                    let mut f = crate::submod::FeatureCoverage::new(phi);
                    let gains = sample_importance(&mut f, true);
                    let g64: Vec<f64> = gains.iter().map(|&g| g as f64).collect();
                    ClassProbs { indices: (*idx).clone(), probs: taylor_softmax(&g64) }
                })
                .collect()
        });
        // fixed subset: full lazy greedy
        let fixed = crate::obs::time("preprocess.fixed", || {
            let mut fixed = Vec::with_capacity(k);
            for ((phi, idx), &kc) in phis.iter().zip(&alloc) {
                if kc == 0 {
                    continue;
                }
                let mut f = crate::submod::FeatureCoverage::new(phi);
                let trace = greedy_maximize(&mut f, kc, GreedyMode::Lazy, true, &mut rng);
                fixed.extend(trace.selected.iter().map(|&l| idx[l]));
            }
            fixed.sort_unstable();
            fixed
        });
        Ok(Metadata {
            dataset: ds.name().to_string(),
            fraction: self.opts.fraction,
            sge_subsets,
            wre_classes,
            fixed_dm: fixed,
            preprocess_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// The full MILO pre-processing pass (paper Algorithm 1, pre-processing
    /// branch): returns the metadata used by `MiloStrategy` and
    /// `MILO(Fixed)`.
    pub fn run(&self, ds: &Dataset) -> Result<Metadata> {
        let t0 = Instant::now();
        let mut rng = Rng::new(self.opts.seed ^ 0x9E1E_C7).derive_str(ds.name());
        let k = ((self.opts.fraction * ds.n_train() as f64).round() as usize).max(1);
        // embeddings only feed the kernels here, so the fused artifact
        // (when present and applicable) skips the encode pass entirely
        let kernels = match self.fused_kernels(ds)? {
            Some(kernels) => kernels,
            None => {
                let embeddings = crate::obs::time("preprocess.encode", || {
                    self.encode(ds, Split::Train)
                })?;
                self.kernels(ds, &embeddings)?
            }
        };
        let sge_subsets = self.sge_subsets(
            ds,
            &kernels,
            self.opts.sge_function,
            k,
            self.opts.n_sge_subsets,
            &mut rng,
        );
        let wre_classes = self.wre_distribution(&kernels, self.opts.wre_function);
        let fixed_dm = self.fixed_subset(ds, &kernels, self.opts.wre_function, k);
        Ok(Metadata {
            dataset: ds.name().to_string(),
            fraction: self.opts.fraction,
            sge_subsets,
            wre_classes,
            fixed_dm,
            preprocess_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Run whichever pipeline `opts.pipeline` selects — the single
    /// execution entry point [`crate::session::MetaSource`] resolution
    /// funnels through.
    pub fn execute(&self, ds: &Dataset) -> Result<Metadata> {
        match self.opts.pipeline {
            PreprocessPipeline::Kernel => self.run(ds),
            PreprocessPipeline::FeatureBased => self.run_featurebased(ds),
        }
    }

}

// ---------------------------------------------------------------------------
// Per-class selection stages (runtime-free, parallel)
// ---------------------------------------------------------------------------
//
// The greedy stages of pre-processing are pure functions of the class
// kernels, so they neither need the PJRT runtime nor a `Preprocessor` —
// the selection bench drives them directly over synthetic kernels, and
// the `Preprocessor` methods above are thin delegates. Each class is an
// independent greedy problem; all three stages fan out over
// `par_map` (kernel *construction* already did), which is what makes
// preprocessing scale with cores instead of class count.

/// SGE: `n_subsets` stochastic-greedy subsets of size `k`, assembled
/// class-wise under `kind`. One RNG stream per `(subset, class)` cell is
/// drawn from `rng` up front in a fixed order, so the result is a pure
/// function of the inputs regardless of how the parallel fan-out
/// schedules classes.
pub fn sge_subsets_from_kernels(
    n_train: usize,
    kernels: &ClassKernels,
    kind: SetFunctionKind,
    k: usize,
    n_subsets: usize,
    epsilon: f64,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let _span = crate::obs::Span::enter("preprocess.sge");
    let sizes: Vec<usize> = kernels.per_class.iter().map(|c| c.indices.len()).collect();
    let alloc = proportional_allocation(&sizes, k.min(n_train));
    let classes = kernels.per_class.len();
    let jobs: Vec<(usize, usize, u64)> = (0..n_subsets)
        .flat_map(|si| (0..classes).map(move |ci| (si, ci)))
        .map(|(si, ci)| (si, ci, rng.next_u64()))
        .collect();
    let picks: Vec<(usize, Vec<usize>)> = par_map(jobs, |(si, ci, seed)| {
        let ck = &kernels.per_class[ci];
        let kc = alloc[ci];
        if kc == 0 {
            return (si, Vec::new());
        }
        let mut f = kind.build_view(ck.sim.view());
        let mut cell_rng = Rng::new(seed);
        let trace = greedy_maximize(
            f.as_mut(),
            kc,
            GreedyMode::Stochastic { epsilon },
            kind.lazy_safe(),
            &mut cell_rng,
        );
        (si, trace.selected.iter().map(|&l| ck.indices[l]).collect())
    });
    let mut out = vec![Vec::with_capacity(k); n_subsets];
    for (si, mut local) in picks {
        out[si].append(&mut local);
    }
    for subset in &mut out {
        subset.sort_unstable();
    }
    out
}

/// Fixed subset by full (lazy) greedy under `kind`, classes in parallel
/// (lazy greedy is deterministic — no RNG is consumed).
pub fn fixed_subset_from_kernels(
    n_train: usize,
    kernels: &ClassKernels,
    kind: SetFunctionKind,
    k: usize,
) -> Vec<usize> {
    let _span = crate::obs::Span::enter("preprocess.fixed");
    let sizes: Vec<usize> = kernels.per_class.iter().map(|c| c.indices.len()).collect();
    let alloc = proportional_allocation(&sizes, k.min(n_train));
    let classes: Vec<usize> = (0..kernels.per_class.len()).collect();
    let picks: Vec<Vec<usize>> = par_map(classes, |ci| {
        let ck = &kernels.per_class[ci];
        let kc = alloc[ci];
        if kc == 0 {
            return Vec::new();
        }
        let mut f = kind.build_view(ck.sim.view());
        let mut rng = Rng::new(0); // unused by Lazy/Naive modes
        let trace =
            greedy_maximize(f.as_mut(), kc, GreedyMode::Lazy, kind.lazy_safe(), &mut rng);
        trace.selected.iter().map(|&l| ck.indices[l]).collect()
    });
    let mut subset: Vec<usize> = picks.into_iter().flatten().collect();
    subset.sort_unstable();
    subset
}

/// WRE: per-class GreedySampleImportance sweep under `kind`, Taylor-
/// softmax normalized (paper Eq. 4–5), classes in parallel (the sweep is
/// deterministic per class).
pub fn wre_distribution_from_kernels(
    kernels: &ClassKernels,
    kind: SetFunctionKind,
) -> Vec<ClassProbs> {
    let _span = crate::obs::Span::enter("preprocess.wre");
    let refs: Vec<&crate::kernel::ClassKernel> = kernels.per_class.iter().collect();
    par_map(refs, |ck| {
        let mut f = kind.build_view(ck.sim.view());
        let gains = sample_importance(f.as_mut(), kind.lazy_safe());
        let g64: Vec<f64> = gains.iter().map(|&g| g as f64).collect();
        ClassProbs { indices: ck.indices.clone(), probs: taylor_softmax(&g64) }
    })
}

// ---------------------------------------------------------------------------
// Metadata (de)serialization
// ---------------------------------------------------------------------------

/// Metadata as a JSON document — the schema shared by [`save_metadata`]
/// and the serve protocol's `GET_META` response.
pub fn metadata_to_json(meta: &Metadata) -> Json {
    let sge = Json::arr(
        meta.sge_subsets
            .iter()
            .map(|s| Json::arr(s.iter().map(|&i| Json::num(i as f64)).collect()))
            .collect(),
    );
    let wre = Json::arr(
        meta.wre_classes
            .iter()
            .map(|c| {
                Json::obj(vec![
                    (
                        "indices",
                        Json::arr(c.indices.iter().map(|&i| Json::num(i as f64)).collect()),
                    ),
                    ("probs", Json::arr(c.probs.iter().map(|&p| Json::num(p)).collect())),
                ])
            })
            .collect(),
    );
    Json::obj(vec![
        ("dataset", Json::str(meta.dataset.clone())),
        ("fraction", Json::num(meta.fraction)),
        ("sge_subsets", sge),
        ("wre_classes", wre),
        (
            "fixed_dm",
            Json::arr(meta.fixed_dm.iter().map(|&i| Json::num(i as f64)).collect()),
        ),
        ("preprocess_secs", Json::num(meta.preprocess_secs)),
    ])
}

/// Parse the [`metadata_to_json`] schema back into [`Metadata`].
pub fn metadata_from_json(v: &Json) -> Result<Metadata> {
    let usizes = |j: &Json| -> Result<Vec<usize>> {
        j.as_arr()?.iter().map(|x| x.as_usize()).collect()
    };
    let sge_subsets = v
        .get("sge_subsets")?
        .as_arr()?
        .iter()
        .map(usizes)
        .collect::<Result<Vec<_>>>()?;
    let wre_classes = v
        .get("wre_classes")?
        .as_arr()?
        .iter()
        .map(|c| -> Result<ClassProbs> {
            Ok(ClassProbs {
                indices: usizes(c.get("indices")?)?,
                probs: c
                    .get("probs")?
                    .as_arr()?
                    .iter()
                    .map(|x| x.as_f64())
                    .collect::<Result<Vec<_>>>()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Metadata {
        dataset: v.get("dataset")?.as_str()?.to_string(),
        fraction: v.get("fraction")?.as_f64()?,
        sge_subsets,
        wre_classes,
        fixed_dm: usizes(v.get("fixed_dm")?)?,
        preprocess_secs: v.get("preprocess_secs")?.as_f64()?,
    })
}

pub fn save_metadata(meta: &Metadata, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, metadata_to_json(meta).to_string())?;
    Ok(())
}

pub fn load_metadata(path: &std::path::Path) -> Result<Metadata> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    metadata_from_json(&Json::parse(&text)?)
}

impl Metadata {
    /// Instantiate the full MILO strategy from this metadata.
    pub fn milo_strategy(&self, kappa: f64) -> crate::selection::MiloStrategy {
        crate::selection::MiloStrategy::new(
            self.sge_subsets.clone(),
            self.wre_classes.clone(),
            kappa,
        )
    }

    /// The MILO(Fixed) baseline.
    pub fn milo_fixed_strategy(&self) -> crate::selection::FixedStrategy {
        crate::selection::FixedStrategy::new("milo_fixed", self.fixed_dm.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;

    fn runtime() -> Option<Runtime> {
        crate::testkit::artifacts_or_skip()
    }

    #[test]
    fn preprocess_produces_consistent_metadata() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Trec6Like.generate(1);
        let pre = Preprocessor::with_options(
            &rt,
            PreprocessOptions {
                fraction: 0.1,
                backend: SimilarityBackend::Native,
                ..Default::default()
            },
        );
        let meta = pre.run(&ds).unwrap();
        let k = (0.1 * ds.n_train() as f64).round() as usize;
        assert_eq!(meta.sge_subsets.len(), 3);
        for s in &meta.sge_subsets {
            assert_eq!(s.len(), k);
            let mut d = s.clone();
            d.dedup();
            assert_eq!(d.len(), k, "duplicates in SGE subset");
        }
        assert_eq!(meta.fixed_dm.len(), k);
        assert_eq!(meta.wre_classes.len(), ds.classes());
        let total: usize = meta.wre_classes.iter().map(|c| c.indices.len()).sum();
        assert_eq!(total, ds.n_train());
        for c in &meta.wre_classes {
            let s: f64 = c.probs.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "class probs sum {s}");
        }
        assert!(meta.preprocess_secs > 0.0);
    }

    #[test]
    fn sge_subsets_are_distinct_draws() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Cifar10Like.generate(2);
        let pre = Preprocessor::with_options(
            &rt,
            PreprocessOptions {
                fraction: 0.05,
                backend: SimilarityBackend::Native,
                n_sge_subsets: 4,
                ..Default::default()
            },
        );
        let meta = pre.run(&ds).unwrap();
        let unique: std::collections::HashSet<&Vec<usize>> = meta.sge_subsets.iter().collect();
        assert!(unique.len() >= 2, "stochastic greedy must vary draws");
    }

    #[test]
    fn metadata_roundtrips_via_json() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::RottenLike.generate(3);
        let pre = Preprocessor::with_options(
            &rt,
            PreprocessOptions {
                fraction: 0.1,
                backend: SimilarityBackend::Native,
                ..Default::default()
            },
        );
        let meta = pre.run(&ds).unwrap();
        let dir = std::env::temp_dir().join("milo_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.json");
        save_metadata(&meta, &path).unwrap();
        let back = load_metadata(&path).unwrap();
        assert_eq!(back.sge_subsets, meta.sge_subsets);
        assert_eq!(back.fixed_dm, meta.fixed_dm);
        assert_eq!(back.wre_classes.len(), meta.wre_classes.len());
        for (a, b) in back.wre_classes.iter().zip(&meta.wre_classes) {
            assert_eq!(a.indices, b.indices);
            for (x, y) in a.probs.iter().zip(&b.probs) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn representation_subsets_are_easier_than_diversity() {
        // The Fig. 4 / Tables 1-2 mechanism at metadata level: graph-cut
        // fixed subsets should have lower generator hardness than
        // disparity-min fixed subsets.
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Cifar100Like.generate(4);
        let pre = Preprocessor::with_options(
            &rt,
            PreprocessOptions {
                fraction: 0.1,
                backend: SimilarityBackend::Native,
                ..Default::default()
            },
        );
        let emb = pre.encode(&ds, Split::Train).unwrap();
        let kernels = pre.kernels(&ds, &emb).unwrap();
        let k = (0.1 * ds.n_train() as f64) as usize;
        let gc = pre.fixed_subset(&ds, &kernels, SetFunctionKind::GRAPH_CUT_DEFAULT, k);
        let dm = pre.fixed_subset(&ds, &kernels, SetFunctionKind::DisparityMin, k);
        let mean_h = |idx: &[usize]| -> f64 {
            idx.iter().map(|&i| ds.hardness[i] as f64).sum::<f64>() / idx.len() as f64
        };
        assert!(
            mean_h(&gc) < mean_h(&dm),
            "graph-cut hardness {} !< disparity-min {}",
            mean_h(&gc),
            mean_h(&dm)
        );
    }
}
