//! Experiment runner: the (dataset × strategy × fraction × seed) grid that
//! regenerates the paper's tables/figures, plus the strategy factory.

use std::sync::Arc;

use anyhow::Result;

use super::{Metadata, PreprocessOptions, Preprocessor};
use crate::data::Dataset;
use crate::kernel::SimilarityBackend;
use crate::runtime::Runtime;
use crate::selection::{
    AdaptiveRandomStrategy, CraigPbStrategy, El2nPruneStrategy, FullStrategy,
    GlisterStrategy, GradMatchPbStrategy, RandomStrategy, SgeVariantStrategy,
    SslPruneStrategy, Strategy,
};
use crate::session::MetaSource;
use crate::train::{LrSchedule, TrainConfig, TrainOutcome, Trainer};

/// All strategies the evaluation grid can instantiate. Paper §4's baseline
/// list plus the ablation variants.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StrategyKind {
    Milo { kappa: f64 },
    MiloFixed,
    Random,
    AdaptiveRandom,
    Full,
    /// FULL with the wall-clock budget of a reference run (set via
    /// `TrainConfig::time_budget_secs` by the runner).
    FullEarlyStop,
    CraigPb,
    GradMatchPb,
    Glister,
    El2nPrune,
    SslPrune,
    SgeVariant,
}

impl StrategyKind {
    /// Every strategy the grid knows, with default parameters — the single
    /// table behind [`StrategyKind::from_name`], the
    /// [`StrategyKind::parse`] error message, and `milo list`.
    pub const ALL: [StrategyKind; 12] = [
        StrategyKind::Milo { kappa: crate::selection::milo::DEFAULT_KAPPA },
        StrategyKind::MiloFixed,
        StrategyKind::Random,
        StrategyKind::AdaptiveRandom,
        StrategyKind::Full,
        StrategyKind::FullEarlyStop,
        StrategyKind::CraigPb,
        StrategyKind::GradMatchPb,
        StrategyKind::Glister,
        StrategyKind::El2nPrune,
        StrategyKind::SslPrune,
        StrategyKind::SgeVariant,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Milo { .. } => "milo",
            StrategyKind::MiloFixed => "milo_fixed",
            StrategyKind::Random => "random",
            StrategyKind::AdaptiveRandom => "adaptive_random",
            StrategyKind::Full => "full",
            StrategyKind::FullEarlyStop => "full_earlystop",
            StrategyKind::CraigPb => "craigpb",
            StrategyKind::GradMatchPb => "gradmatchpb",
            StrategyKind::Glister => "glister",
            StrategyKind::El2nPrune => "el2n_prune",
            StrategyKind::SslPrune => "ssl_prune",
            StrategyKind::SgeVariant => "sge_variant",
        }
    }

    /// Look a strategy up in [`StrategyKind::ALL`] by its
    /// [`name`](StrategyKind::name).
    pub fn from_name(name: &str) -> Option<StrategyKind> {
        Self::ALL.iter().copied().find(|k| k.name() == name)
    }

    /// [`from_name`](StrategyKind::from_name), but an unknown name is an
    /// error that lists the valid vocabulary — generated from
    /// [`StrategyKind::ALL`], so the CLI surfaces (`milo train`, `repro`,
    /// `tune`) never drift apart.
    pub fn parse(name: &str) -> Result<StrategyKind> {
        Self::from_name(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown strategy {name:?}; valid strategies: {}",
                Self::ALL
                    .iter()
                    .map(|k| k.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
    }

    /// Does this strategy need MILO pre-processing metadata?
    pub fn needs_metadata(&self) -> bool {
        matches!(
            self,
            StrategyKind::Milo { .. } | StrategyKind::MiloFixed | StrategyKind::SgeVariant
        )
    }

    /// Instantiate. `metadata` must be `Some` when [`needs_metadata`] and
    /// `embeddings` when the strategy is SslPrune.
    pub fn build(
        &self,
        metadata: Option<&Metadata>,
        embeddings: Option<&crate::tensor::Matrix>,
    ) -> Result<Box<dyn Strategy>> {
        Ok(match self {
            StrategyKind::Milo { kappa } => {
                let m = metadata.ok_or_else(|| anyhow::anyhow!("milo needs metadata"))?;
                Box::new(m.milo_strategy(*kappa))
            }
            StrategyKind::MiloFixed => {
                let m = metadata.ok_or_else(|| anyhow::anyhow!("milo_fixed needs metadata"))?;
                Box::new(m.milo_fixed_strategy())
            }
            StrategyKind::SgeVariant => {
                let m = metadata.ok_or_else(|| anyhow::anyhow!("sge_variant needs metadata"))?;
                Box::new(SgeVariantStrategy::new(m.sge_subsets.clone()))
            }
            StrategyKind::Random => Box::new(RandomStrategy::new()),
            StrategyKind::AdaptiveRandom => Box::new(AdaptiveRandomStrategy),
            StrategyKind::Full | StrategyKind::FullEarlyStop => Box::new(FullStrategy),
            StrategyKind::CraigPb => Box::new(CraigPbStrategy),
            StrategyKind::GradMatchPb => Box::new(GradMatchPbStrategy),
            StrategyKind::Glister => Box::new(GlisterStrategy),
            StrategyKind::El2nPrune => Box::new(El2nPruneStrategy::new(3)),
            StrategyKind::SslPrune => {
                let e = embeddings
                    .ok_or_else(|| anyhow::anyhow!("ssl_prune needs embeddings"))?;
                Box::new(SslPruneStrategy::new(e.clone()))
            }
        })
    }
}

/// One grid cell's outcome, flattened for report tables.
#[derive(Clone, Debug)]
pub struct TrialRecord {
    pub dataset: String,
    pub strategy: String,
    pub fraction: f64,
    pub seed: u64,
    pub outcome: TrainOutcome,
    /// FULL training time for the same (dataset, seed), for speedup.
    pub full_secs: f64,
    /// FULL test accuracy, for degradation.
    pub full_acc: f64,
    pub preprocess_secs: f64,
}

impl TrialRecord {
    pub fn speedup(&self) -> f64 {
        self.outcome.speedup_vs(self.full_secs)
    }

    pub fn degradation_pct(&self) -> f64 {
        (self.full_acc - self.outcome.test_accuracy) * 100.0
    }
}

/// Drives the evaluation grid for one dataset. The R-interval convention
/// follows the paper: MILO and Adaptive-Random use R=1; the gradient-based
/// baselines use the efficiency R (10 vision / 3 text).
pub struct ExperimentRunner<'a> {
    pub rt: &'a Runtime,
    pub ds: &'a Dataset,
    pub epochs: usize,
    /// R for the gradient-based baselines.
    pub r_expensive: usize,
    /// SGE/WRE pre-processing backend.
    pub backend: SimilarityBackend,
    /// Metadata cache dir (None disables caching). Superseded by `source`;
    /// kept as the short spelling of a store-backed source.
    pub cache_dir: Option<std::path::PathBuf>,
    /// Where per-cell metadata comes from (re-targeted per fraction/seed
    /// cell). When unset, falls back to `cache_dir` (store) or an inline
    /// pass. `MiloSession::runner` presets this with the session's source.
    pub source: Option<MetaSource>,
    /// Verbose progress lines to stderr.
    pub verbose: bool,
    /// One-slot memo of the last resolved cell, keyed by the full
    /// configuration descriptor (so post-construction `backend`/`source`
    /// mutations are never silently ignored) — grids run several
    /// strategies at the same cell, and an Inline source is always-fresh,
    /// so without this every metadata-consuming cell would repay the full
    /// preprocessing pass.
    memo: std::sync::Mutex<Option<(String, Arc<Metadata>)>>,
}

impl<'a> ExperimentRunner<'a> {
    pub fn new(rt: &'a Runtime, ds: &'a Dataset, epochs: usize) -> Self {
        let text = matches!(
            ds.id,
            crate::data::DatasetId::Trec6Like
                | crate::data::DatasetId::ImdbLike
                | crate::data::DatasetId::RottenLike
        );
        ExperimentRunner {
            rt,
            ds,
            epochs,
            r_expensive: if text { 3 } else { 10 },
            backend: SimilarityBackend::Native,
            cache_dir: None,
            source: None,
            verbose: false,
            memo: std::sync::Mutex::new(None),
        }
    }

    fn log(&self, msg: &str) {
        if self.verbose {
            eprintln!("[runner] {msg}");
        }
    }

    /// Pre-process metadata for one grid cell, routed through the runner's
    /// [`MetaSource`] (re-targeted at the cell's fraction/seed). The last
    /// resolution is memoized, so consecutive cells at one configuration
    /// share a single pass even with an always-fresh Inline source.
    pub fn preprocess(&self, fraction: f64, seed: u64) -> Result<Arc<Metadata>> {
        let source = match &self.source {
            Some(src) => src
                .clone()
                .with_fraction(fraction)
                .with_seed(seed)
                .with_backend(self.backend),
            None => {
                let opts = PreprocessOptions {
                    fraction,
                    backend: self.backend,
                    seed,
                    ..Default::default()
                };
                match &self.cache_dir {
                    Some(dir) => MetaSource::store(dir.clone(), opts)?,
                    None => MetaSource::inline(opts),
                }
            }
        };
        // everything that changes the selection output is in the tag:
        // local sources use the store fingerprint, remote ones the
        // address plus the re-targeted expectations
        let tag = match source.options() {
            Some(opts) => {
                crate::store::MetaKey::from_options(self.ds.name(), opts).fingerprint()
            }
            None => format!("remote:{:?}:f{fraction}:s{seed}", source),
        };
        if let Some((t, meta)) = &*self.memo.lock().unwrap() {
            if *t == tag {
                return Ok(meta.clone());
            }
        }
        let meta = source.resolve(Some(self.rt), self.ds)?;
        *self.memo.lock().unwrap() = Some((tag, meta.clone()));
        Ok(meta)
    }

    fn config(&self, kind: StrategyKind, fraction: f64, seed: u64) -> TrainConfig {
        let base = TrainConfig::recipe_for(self.ds, self.epochs);
        let r = match kind {
            StrategyKind::CraigPb | StrategyKind::GradMatchPb | StrategyKind::Glister => {
                self.r_expensive
            }
            _ => 1,
        };
        TrainConfig {
            fraction: if matches!(kind, StrategyKind::Full | StrategyKind::FullEarlyStop) {
                1.0
            } else {
                fraction
            },
            r,
            seed,
            schedule: LrSchedule::Cosine { total: self.epochs },
            ..base
        }
    }

    /// Train FULL once for reference numbers.
    pub fn run_full(&self, seed: u64) -> Result<TrainOutcome> {
        let cfg = self.config(StrategyKind::Full, 1.0, seed);
        Trainer::new(self.rt, self.ds, cfg)?.run(&mut FullStrategy)
    }

    /// Run one (strategy, fraction, seed) cell, given the FULL reference.
    pub fn run_cell(
        &self,
        kind: StrategyKind,
        fraction: f64,
        seed: u64,
        full: &TrainOutcome,
    ) -> Result<TrialRecord> {
        self.log(&format!(
            "{} {} f={fraction} seed={seed}",
            self.ds.name(),
            kind.name()
        ));
        let mut preprocess_secs = 0.0;
        let metadata = if kind.needs_metadata() {
            let m = self.preprocess(fraction, seed)?;
            preprocess_secs = m.preprocess_secs;
            Some(m)
        } else {
            None
        };
        let embeddings = if matches!(kind, StrategyKind::SslPrune) {
            let pre = Preprocessor::with_options(
                self.rt,
                PreprocessOptions { backend: self.backend, ..Default::default() },
            );
            Some(pre.encode(self.ds, crate::data::Split::Train)?)
        } else {
            None
        };
        let mut strategy = kind.build(metadata.as_deref(), embeddings.as_ref())?;
        let mut cfg = self.config(kind, fraction, seed);
        if matches!(kind, StrategyKind::FullEarlyStop) {
            // budget-match against a fraction-sized run: the paper stops FULL
            // when it has consumed the subset run's time; approximate with
            // fraction × full time.
            cfg.time_budget_secs = Some(full.train_secs * fraction);
        }
        let outcome = Trainer::new(self.rt, self.ds, cfg)?.run(strategy.as_mut())?;
        Ok(TrialRecord {
            dataset: self.ds.name().to_string(),
            strategy: kind.name().to_string(),
            fraction,
            seed,
            outcome,
            full_secs: full.train_secs,
            full_acc: full.test_accuracy,
            preprocess_secs,
        })
    }

    /// The full grid for Fig. 6-style comparisons.
    pub fn run_grid(
        &self,
        kinds: &[StrategyKind],
        fractions: &[f64],
        seeds: &[u64],
    ) -> Result<Vec<TrialRecord>> {
        let mut out = Vec::new();
        for &seed in seeds {
            let full = self.run_full(seed)?;
            self.log(&format!(
                "{} full: acc {:.4} time {:.2}s",
                self.ds.name(),
                full.test_accuracy,
                full.train_secs
            ));
            for &fraction in fractions {
                for &kind in kinds {
                    out.push(self.run_cell(kind, fraction, seed, &full)?);
                }
            }
            // record FULL itself as a row (fraction 1.0)
            out.push(TrialRecord {
                dataset: self.ds.name().to_string(),
                strategy: "full".into(),
                fraction: 1.0,
                seed,
                full_secs: full.train_secs,
                full_acc: full.test_accuracy,
                outcome: full,
                preprocess_secs: 0.0,
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;

    fn runtime() -> Option<Runtime> {
        crate::testkit::artifacts_or_skip()
    }

    #[test]
    fn strategy_kind_roundtrip() {
        // the full table round-trips through its own names
        for kind in StrategyKind::ALL {
            assert_eq!(StrategyKind::from_name(kind.name()), Some(kind));
            assert_eq!(StrategyKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(matches!(
            StrategyKind::from_name("milo"),
            Some(StrategyKind::Milo { .. })
        ));
        assert!(StrategyKind::from_name("bogus").is_none());
    }

    #[test]
    fn parse_error_lists_every_valid_name() {
        let err = format!("{:#}", StrategyKind::parse("bogus").unwrap_err());
        for kind in StrategyKind::ALL {
            assert!(err.contains(kind.name()), "{err} missing {}", kind.name());
        }
    }

    #[test]
    fn build_fails_without_required_inputs() {
        assert!(StrategyKind::Milo { kappa: 0.2 }.build(None, None).is_err());
        assert!(StrategyKind::SslPrune.build(None, None).is_err());
        assert!(StrategyKind::Random.build(None, None).is_ok());
    }

    #[test]
    fn small_grid_cell_runs_end_to_end() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::RottenLike.generate(1);
        let runner = ExperimentRunner::new(&rt, &ds, 4);
        let full = runner.run_full(1).unwrap();
        let rec = runner
            .run_cell(
                StrategyKind::Milo { kappa: 1.0 / 6.0 },
                0.1,
                1,
                &full,
            )
            .unwrap();
        assert!(rec.speedup() > 1.0, "speedup {}", rec.speedup());
        assert!(rec.outcome.test_accuracy > 0.4); // 2-class task
        assert!(rec.preprocess_secs > 0.0);
    }
}
