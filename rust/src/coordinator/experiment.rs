//! Experiment runner: the (dataset × strategy × fraction × seed) grid that
//! regenerates the paper's tables/figures, plus the strategy factory.

use anyhow::Result;

use super::{Metadata, PreprocessOptions, Preprocessor};
use crate::data::Dataset;
use crate::kernel::SimilarityBackend;
use crate::runtime::Runtime;
use crate::selection::{
    AdaptiveRandomStrategy, CraigPbStrategy, El2nPruneStrategy, FullStrategy,
    GlisterStrategy, GradMatchPbStrategy, RandomStrategy, SgeVariantStrategy,
    SslPruneStrategy, Strategy,
};
use crate::train::{LrSchedule, TrainConfig, TrainOutcome, Trainer};

/// All strategies the evaluation grid can instantiate. Paper §4's baseline
/// list plus the ablation variants.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StrategyKind {
    Milo { kappa: f64 },
    MiloFixed,
    Random,
    AdaptiveRandom,
    Full,
    /// FULL with the wall-clock budget of a reference run (set via
    /// `TrainConfig::time_budget_secs` by the runner).
    FullEarlyStop,
    CraigPb,
    GradMatchPb,
    Glister,
    El2nPrune,
    SslPrune,
    SgeVariant,
}

impl StrategyKind {
    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Milo { .. } => "milo",
            StrategyKind::MiloFixed => "milo_fixed",
            StrategyKind::Random => "random",
            StrategyKind::AdaptiveRandom => "adaptive_random",
            StrategyKind::Full => "full",
            StrategyKind::FullEarlyStop => "full_earlystop",
            StrategyKind::CraigPb => "craigpb",
            StrategyKind::GradMatchPb => "gradmatchpb",
            StrategyKind::Glister => "glister",
            StrategyKind::El2nPrune => "el2n_prune",
            StrategyKind::SslPrune => "ssl_prune",
            StrategyKind::SgeVariant => "sge_variant",
        }
    }

    pub fn from_name(name: &str) -> Option<StrategyKind> {
        Some(match name {
            "milo" => StrategyKind::Milo { kappa: crate::selection::milo::DEFAULT_KAPPA },
            "milo_fixed" => StrategyKind::MiloFixed,
            "random" => StrategyKind::Random,
            "adaptive_random" => StrategyKind::AdaptiveRandom,
            "full" => StrategyKind::Full,
            "full_earlystop" => StrategyKind::FullEarlyStop,
            "craigpb" => StrategyKind::CraigPb,
            "gradmatchpb" => StrategyKind::GradMatchPb,
            "glister" => StrategyKind::Glister,
            "el2n_prune" => StrategyKind::El2nPrune,
            "ssl_prune" => StrategyKind::SslPrune,
            "sge_variant" => StrategyKind::SgeVariant,
            _ => return None,
        })
    }

    /// Does this strategy need MILO pre-processing metadata?
    pub fn needs_metadata(&self) -> bool {
        matches!(
            self,
            StrategyKind::Milo { .. } | StrategyKind::MiloFixed | StrategyKind::SgeVariant
        )
    }

    /// Instantiate. `metadata` must be `Some` when [`needs_metadata`] and
    /// `embeddings` when the strategy is SslPrune.
    pub fn build(
        &self,
        metadata: Option<&Metadata>,
        embeddings: Option<&crate::tensor::Matrix>,
    ) -> Result<Box<dyn Strategy>> {
        Ok(match self {
            StrategyKind::Milo { kappa } => {
                let m = metadata.ok_or_else(|| anyhow::anyhow!("milo needs metadata"))?;
                Box::new(m.milo_strategy(*kappa))
            }
            StrategyKind::MiloFixed => {
                let m = metadata.ok_or_else(|| anyhow::anyhow!("milo_fixed needs metadata"))?;
                Box::new(m.milo_fixed_strategy())
            }
            StrategyKind::SgeVariant => {
                let m = metadata.ok_or_else(|| anyhow::anyhow!("sge_variant needs metadata"))?;
                Box::new(SgeVariantStrategy::new(m.sge_subsets.clone()))
            }
            StrategyKind::Random => Box::new(RandomStrategy::new()),
            StrategyKind::AdaptiveRandom => Box::new(AdaptiveRandomStrategy),
            StrategyKind::Full | StrategyKind::FullEarlyStop => Box::new(FullStrategy),
            StrategyKind::CraigPb => Box::new(CraigPbStrategy),
            StrategyKind::GradMatchPb => Box::new(GradMatchPbStrategy),
            StrategyKind::Glister => Box::new(GlisterStrategy),
            StrategyKind::El2nPrune => Box::new(El2nPruneStrategy::new(3)),
            StrategyKind::SslPrune => {
                let e = embeddings
                    .ok_or_else(|| anyhow::anyhow!("ssl_prune needs embeddings"))?;
                Box::new(SslPruneStrategy::new(e.clone()))
            }
        })
    }
}

/// One grid cell's outcome, flattened for report tables.
#[derive(Clone, Debug)]
pub struct TrialRecord {
    pub dataset: String,
    pub strategy: String,
    pub fraction: f64,
    pub seed: u64,
    pub outcome: TrainOutcome,
    /// FULL training time for the same (dataset, seed), for speedup.
    pub full_secs: f64,
    /// FULL test accuracy, for degradation.
    pub full_acc: f64,
    pub preprocess_secs: f64,
}

impl TrialRecord {
    pub fn speedup(&self) -> f64 {
        self.outcome.speedup_vs(self.full_secs)
    }

    pub fn degradation_pct(&self) -> f64 {
        (self.full_acc - self.outcome.test_accuracy) * 100.0
    }
}

/// Drives the evaluation grid for one dataset. The R-interval convention
/// follows the paper: MILO and Adaptive-Random use R=1; the gradient-based
/// baselines use the efficiency R (10 vision / 3 text).
pub struct ExperimentRunner<'a> {
    pub rt: &'a Runtime,
    pub ds: &'a Dataset,
    pub epochs: usize,
    /// R for the gradient-based baselines.
    pub r_expensive: usize,
    /// SGE/WRE pre-processing backend.
    pub backend: SimilarityBackend,
    /// Metadata cache dir (None disables caching).
    pub cache_dir: Option<std::path::PathBuf>,
    /// Verbose progress lines to stderr.
    pub verbose: bool,
}

impl<'a> ExperimentRunner<'a> {
    pub fn new(rt: &'a Runtime, ds: &'a Dataset, epochs: usize) -> Self {
        let text = matches!(
            ds.id,
            crate::data::DatasetId::Trec6Like
                | crate::data::DatasetId::ImdbLike
                | crate::data::DatasetId::RottenLike
        );
        ExperimentRunner {
            rt,
            ds,
            epochs,
            r_expensive: if text { 3 } else { 10 },
            backend: SimilarityBackend::Native,
            cache_dir: None,
            verbose: false,
        }
    }

    fn log(&self, msg: &str) {
        if self.verbose {
            eprintln!("[runner] {msg}");
        }
    }

    /// Pre-process metadata for a fraction (cached when a dir is set).
    pub fn preprocess(&self, fraction: f64, seed: u64) -> Result<Metadata> {
        let pre = Preprocessor::with_options(
            self.rt,
            PreprocessOptions {
                fraction,
                backend: self.backend,
                seed,
                ..Default::default()
            },
        );
        match &self.cache_dir {
            Some(dir) => pre.run_cached(self.ds, dir.clone()),
            None => pre.run(self.ds),
        }
    }

    fn config(&self, kind: StrategyKind, fraction: f64, seed: u64) -> TrainConfig {
        let base = TrainConfig::recipe_for(self.ds, self.epochs);
        let r = match kind {
            StrategyKind::CraigPb | StrategyKind::GradMatchPb | StrategyKind::Glister => {
                self.r_expensive
            }
            _ => 1,
        };
        TrainConfig {
            fraction: if matches!(kind, StrategyKind::Full | StrategyKind::FullEarlyStop) {
                1.0
            } else {
                fraction
            },
            r,
            seed,
            schedule: LrSchedule::Cosine { total: self.epochs },
            ..base
        }
    }

    /// Train FULL once for reference numbers.
    pub fn run_full(&self, seed: u64) -> Result<TrainOutcome> {
        let cfg = self.config(StrategyKind::Full, 1.0, seed);
        Trainer::new(self.rt, self.ds, cfg)?.run(&mut FullStrategy)
    }

    /// Run one (strategy, fraction, seed) cell, given the FULL reference.
    pub fn run_cell(
        &self,
        kind: StrategyKind,
        fraction: f64,
        seed: u64,
        full: &TrainOutcome,
    ) -> Result<TrialRecord> {
        self.log(&format!(
            "{} {} f={fraction} seed={seed}",
            self.ds.name(),
            kind.name()
        ));
        let mut preprocess_secs = 0.0;
        let metadata = if kind.needs_metadata() {
            let m = self.preprocess(fraction, seed)?;
            preprocess_secs = m.preprocess_secs;
            Some(m)
        } else {
            None
        };
        let embeddings = if matches!(kind, StrategyKind::SslPrune) {
            let pre = Preprocessor::with_options(
                self.rt,
                PreprocessOptions { backend: self.backend, ..Default::default() },
            );
            Some(pre.encode(self.ds, crate::data::Split::Train)?)
        } else {
            None
        };
        let mut strategy = kind.build(metadata.as_ref(), embeddings.as_ref())?;
        let mut cfg = self.config(kind, fraction, seed);
        if matches!(kind, StrategyKind::FullEarlyStop) {
            // budget-match against a fraction-sized run: the paper stops FULL
            // when it has consumed the subset run's time; approximate with
            // fraction × full time.
            cfg.time_budget_secs = Some(full.train_secs * fraction);
        }
        let outcome = Trainer::new(self.rt, self.ds, cfg)?.run(strategy.as_mut())?;
        Ok(TrialRecord {
            dataset: self.ds.name().to_string(),
            strategy: kind.name().to_string(),
            fraction,
            seed,
            outcome,
            full_secs: full.train_secs,
            full_acc: full.test_accuracy,
            preprocess_secs,
        })
    }

    /// The full grid for Fig. 6-style comparisons.
    pub fn run_grid(
        &self,
        kinds: &[StrategyKind],
        fractions: &[f64],
        seeds: &[u64],
    ) -> Result<Vec<TrialRecord>> {
        let mut out = Vec::new();
        for &seed in seeds {
            let full = self.run_full(seed)?;
            self.log(&format!(
                "{} full: acc {:.4} time {:.2}s",
                self.ds.name(),
                full.test_accuracy,
                full.train_secs
            ));
            for &fraction in fractions {
                for &kind in kinds {
                    out.push(self.run_cell(kind, fraction, seed, &full)?);
                }
            }
            // record FULL itself as a row (fraction 1.0)
            out.push(TrialRecord {
                dataset: self.ds.name().to_string(),
                strategy: "full".into(),
                fraction: 1.0,
                seed,
                full_secs: full.train_secs,
                full_acc: full.test_accuracy,
                outcome: full,
                preprocess_secs: 0.0,
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;

    fn runtime() -> Option<Runtime> {
        crate::testkit::artifacts_or_skip()
    }

    #[test]
    fn strategy_kind_roundtrip() {
        for kind in [
            StrategyKind::MiloFixed,
            StrategyKind::Random,
            StrategyKind::AdaptiveRandom,
            StrategyKind::Full,
            StrategyKind::CraigPb,
            StrategyKind::GradMatchPb,
            StrategyKind::Glister,
            StrategyKind::El2nPrune,
            StrategyKind::SslPrune,
            StrategyKind::SgeVariant,
        ] {
            assert_eq!(StrategyKind::from_name(kind.name()), Some(kind));
        }
        assert!(matches!(
            StrategyKind::from_name("milo"),
            Some(StrategyKind::Milo { .. })
        ));
        assert!(StrategyKind::from_name("bogus").is_none());
    }

    #[test]
    fn build_fails_without_required_inputs() {
        assert!(StrategyKind::Milo { kappa: 0.2 }.build(None, None).is_err());
        assert!(StrategyKind::SslPrune.build(None, None).is_err());
        assert!(StrategyKind::Random.build(None, None).is_ok());
    }

    #[test]
    fn small_grid_cell_runs_end_to_end() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::RottenLike.generate(1);
        let runner = ExperimentRunner::new(&rt, &ds, 4);
        let full = runner.run_full(1).unwrap();
        let rec = runner
            .run_cell(
                StrategyKind::Milo { kappa: 1.0 / 6.0 },
                0.1,
                1,
                &full,
            )
            .unwrap();
        assert!(rec.speedup() > 1.0, "speedup {}", rec.speedup());
        assert!(rec.outcome.test_accuracy > 0.4); // 2-class task
        assert!(rec.preprocess_secs > 0.0);
    }
}
