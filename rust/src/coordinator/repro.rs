//! Experiment regenerators: one function per paper table/figure.
//!
//! Each produces [`Table`]s whose rows mirror what the paper reports
//! (strategy, subset size, accuracy, time, speedup, degradation, …) and
//! saves CSV + markdown under the results directory. The `milo repro`
//! CLI and the benches are thin wrappers over these.
//!
//! Scaling: `ReproOptions::epochs`/`seeds`/`fractions` control cost; the
//! defaults regenerate every figure on a laptop-class CPU in minutes. The
//! shapes (orderings, crossovers), not absolute GPU numbers, are the
//! reproduction target — see EXPERIMENTS.md.

use anyhow::Result;

use super::experiment::{ExperimentRunner, StrategyKind};
use super::{PreprocessOptions, Preprocessor};
use crate::data::{Dataset, DatasetId, Split};
use crate::hpo::{HpoConfig, SearchAlgo, Tuner};
use crate::kernel::{SimMetric, SimilarityBackend};
use crate::report::{f, pct, Table};
use crate::runtime::Runtime;
use crate::selection::milo::DEFAULT_KAPPA;
use crate::selection::{SgeStrategy, Strategy, WreStrategy};
use crate::submod::SetFunctionKind;
use crate::train::{TrainConfig, Trainer};
use crate::util::math::{kendall_tau, mean, median, stddev};
use crate::util::rng::Rng;

/// Shared knobs for all regenerators.
#[derive(Clone, Debug)]
pub struct ReproOptions {
    pub epochs: usize,
    pub seeds: Vec<u64>,
    pub fractions: Vec<f64>,
    pub out_dir: std::path::PathBuf,
    pub backend: SimilarityBackend,
    /// Restrict grid experiments (fig6, fig9) to these strategies; `None`
    /// keeps each figure's paper defaults. Accepts the full
    /// [`StrategyKind::from_name`] vocabulary (`milo repro fig6
    /// --strategies milo,random`).
    pub strategies: Option<Vec<StrategyKind>>,
    pub verbose: bool,
}

impl Default for ReproOptions {
    fn default() -> Self {
        ReproOptions {
            epochs: 40,
            seeds: vec![1],
            fractions: vec![0.01, 0.05, 0.1, 0.3],
            out_dir: "results".into(),
            backend: SimilarityBackend::Native,
            strategies: None,
            verbose: true,
        }
    }
}

impl ReproOptions {
    fn runner<'a>(&self, rt: &'a Runtime, ds: &'a Dataset) -> ExperimentRunner<'a> {
        let mut r = ExperimentRunner::new(rt, ds, self.epochs);
        r.backend = self.backend;
        r.verbose = self.verbose;
        r
    }
}

fn outcome_row(
    t: &mut Table,
    ds: &str,
    strategy: &str,
    fraction: f64,
    acc: f64,
    acc_sd: f64,
    secs: f64,
    full_acc: f64,
    full_secs: f64,
) {
    t.push(vec![
        ds.to_string(),
        strategy.to_string(),
        f(fraction, 2),
        pct(acc),
        f(acc_sd * 100.0, 2),
        f(secs, 2),
        f(full_secs / secs.max(1e-9), 2),
        f((full_acc - acc) * 100.0, 2),
    ]);
}

const GRID_HEADERS: [&str; 8] = [
    "dataset", "strategy", "fraction", "test_acc_%", "std_%", "train_secs", "speedup",
    "degradation_%",
];

/// Aggregate per-(strategy, fraction) means over seeds.
fn aggregate(
    records: &[super::experiment::TrialRecord],
) -> Vec<(String, f64, f64, f64, f64, f64, f64)> {
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<(String, String), Vec<&super::experiment::TrialRecord>> =
        BTreeMap::new();
    for r in records {
        groups
            .entry((r.strategy.clone(), format!("{:.4}", r.fraction)))
            .or_default()
            .push(r);
    }
    groups
        .into_iter()
        .map(|((strategy, _), rs)| {
            let accs: Vec<f32> = rs.iter().map(|r| r.outcome.test_accuracy as f32).collect();
            let secs: Vec<f32> = rs.iter().map(|r| r.outcome.train_secs as f32).collect();
            let full_acc = rs.iter().map(|r| r.full_acc).sum::<f64>() / rs.len() as f64;
            let full_secs = rs.iter().map(|r| r.full_secs).sum::<f64>() / rs.len() as f64;
            (
                strategy,
                rs[0].fraction,
                mean(&accs),
                stddev(&accs),
                mean(&secs),
                full_acc,
                full_secs,
            )
        })
        .collect()
}

// ===========================================================================
// Fig. 1 — convergence (epochs & wallclock) of AdaptiveRandom vs CraigPB vs
// GradMatchPB at 10%, R=1 (selection every epoch)
// ===========================================================================

pub fn fig1_convergence(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let ds = DatasetId::Cifar100Like.generate(opts.seeds[0]);
    let mut epoch_t = Table::new(
        "Fig 1a: val accuracy vs epoch (10% CIFAR100-like, R=1)",
        &["strategy", "epoch", "val_acc_%"],
    );
    let mut time_t = Table::new(
        "Fig 1b: val accuracy vs train wallclock (10% CIFAR100-like, R=1)",
        &["strategy", "train_secs", "val_acc_%"],
    );
    for kind in [
        StrategyKind::AdaptiveRandom,
        StrategyKind::CraigPb,
        StrategyKind::GradMatchPb,
    ] {
        let mut strategy = kind.build(None, None)?;
        let cfg = TrainConfig {
            epochs: opts.epochs,
            fraction: 0.1,
            r: 1, // paper Fig 1: NEW SUBSET EVERY EPOCH for everyone
            eval_every: 2,
            seed: opts.seeds[0],
            ..TrainConfig::recipe_for(&ds, opts.epochs)
        };
        let out = Trainer::new(rt, &ds, cfg)?.run(strategy.as_mut())?;
        for p in &out.trace {
            epoch_t.push(vec![
                kind.name().into(),
                p.epoch.to_string(),
                pct(p.val_accuracy),
            ]);
            time_t.push(vec![
                kind.name().into(),
                f(p.train_secs, 3),
                pct(p.val_accuracy),
            ]);
        }
    }
    epoch_t.save(&opts.out_dir, "fig1a_convergence_epochs")?;
    time_t.save(&opts.out_dir, "fig1b_convergence_time")?;
    Ok(vec![epoch_t, time_t])
}

// ===========================================================================
// Fig. 4 — fixed subsets selected by different set functions
// ===========================================================================

pub fn fig4_setfunctions(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let ds = DatasetId::Cifar100Like.generate(opts.seeds[0]);
    let mut t = Table::new(
        "Fig 4: fixed-subset accuracy by set function (CIFAR100-like)",
        &["set_function", "fraction", "test_acc_%"],
    );
    let pre = Preprocessor::with_options(
        rt,
        PreprocessOptions { backend: opts.backend, ..Default::default() },
    );
    let emb = pre.encode(&ds, Split::Train)?;
    let kernels = pre.kernels(&ds, &emb)?;
    for &fraction in &opts.fractions {
        let k = (fraction * ds.n_train() as f64).round() as usize;
        for kind in [
            SetFunctionKind::FacilityLocation,
            SetFunctionKind::GRAPH_CUT_DEFAULT,
            SetFunctionKind::DisparitySum,
            SetFunctionKind::DisparityMin,
        ] {
            let subset = pre.fixed_subset(&ds, &kernels, kind, k);
            let mut strat =
                crate::selection::FixedStrategy::new(kind.name(), subset);
            let cfg = TrainConfig {
                epochs: opts.epochs,
                fraction,
                eval_every: 0,
                seed: opts.seeds[0],
                ..TrainConfig::recipe_for(&ds, opts.epochs)
            };
            let out = Trainer::new(rt, &ds, cfg)?.run(&mut strat)?;
            t.push(vec![
                kind.name().into(),
                f(fraction, 2),
                pct(out.test_accuracy),
            ]);
            if opts.verbose {
                eprintln!(
                    "[fig4] {} f={fraction}: {:.2}%",
                    kind.name(),
                    100.0 * out.test_accuracy
                );
            }
        }
    }
    t.save(&opts.out_dir, "fig4_setfunctions")?;
    Ok(vec![t])
}

// ===========================================================================
// Fig. 5a — SGE vs WRE vs Fixed across sizes and functions
// Fig. 5b / 12 / 13 / 14 — early-convergence comparisons
// ===========================================================================

/// Build an SGE or WRE strategy for an arbitrary set function (ablations).
pub fn exploration_strategy(
    rt: &Runtime,
    ds: &Dataset,
    kind: SetFunctionKind,
    explore: &str, // "sge" | "wre" | "fixed"
    fraction: f64,
    backend: SimilarityBackend,
    seed: u64,
) -> Result<Box<dyn Strategy>> {
    let pre = Preprocessor::with_options(
        rt,
        PreprocessOptions { fraction, backend, seed, ..Default::default() },
    );
    let emb = pre.encode(ds, Split::Train)?;
    let kernels = pre.kernels(ds, &emb)?;
    let k = (fraction * ds.n_train() as f64).round() as usize;
    Ok(match explore {
        "sge" => {
            let mut rng = Rng::new(seed ^ 0x56E);
            let subsets = pre.sge_subsets(ds, &kernels, kind, k, 3, &mut rng);
            Box::new(SgeStrategy::new(format!("sge_{}", kind.name()), subsets))
        }
        "wre" => {
            let classes = pre.wre_distribution(&kernels, kind);
            Box::new(WreStrategy::new(format!("wre_{}", kind.name()), classes))
        }
        "fixed" => {
            let subset = pre.fixed_subset(ds, &kernels, kind, k);
            Box::new(crate::selection::FixedStrategy::new(
                format!("fixed_{}", kind.name()),
                subset,
            ))
        }
        other => anyhow::bail!("unknown exploration {other}"),
    })
}

pub fn fig5a_sge_wre(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let ds = DatasetId::Cifar100Like.generate(opts.seeds[0]);
    let mut t = Table::new(
        "Fig 5a: SGE vs WRE vs Fixed across subset sizes (CIFAR100-like)",
        &["exploration", "set_function", "fraction", "test_acc_%"],
    );
    for &fraction in &opts.fractions {
        for kind in [SetFunctionKind::GRAPH_CUT_DEFAULT, SetFunctionKind::DisparityMin] {
            for explore in ["fixed", "sge", "wre"] {
                let mut strat = exploration_strategy(
                    rt, &ds, kind, explore, fraction, opts.backend, opts.seeds[0],
                )?;
                let cfg = TrainConfig {
                    epochs: opts.epochs,
                    fraction,
                    eval_every: 0,
                    seed: opts.seeds[0],
                    ..TrainConfig::recipe_for(&ds, opts.epochs)
                };
                let out = Trainer::new(rt, &ds, cfg)?.run(strat.as_mut())?;
                t.push(vec![
                    explore.into(),
                    kind.name().into(),
                    f(fraction, 2),
                    pct(out.test_accuracy),
                ]);
                if opts.verbose {
                    eprintln!(
                        "[fig5a] {explore} {} f={fraction}: {:.2}%",
                        kind.name(),
                        100.0 * out.test_accuracy
                    );
                }
            }
        }
    }
    t.save(&opts.out_dir, "fig5a_sge_wre")?;
    Ok(vec![t])
}

/// Generic early-convergence comparison over (exploration, function) arms.
/// Covers Fig 5b (ds=cifar100, arms below), Fig 12 (SGE/GC vs SGE/FL) and
/// Fig 13 (SGE/GC vs WRE/GC).
pub fn convergence_compare(
    rt: &Runtime,
    opts: &ReproOptions,
    ds_id: DatasetId,
    fraction: f64,
    arms: &[(&str, SetFunctionKind)],
    stem: &str,
    title: &str,
) -> Result<Vec<Table>> {
    let ds = ds_id.generate(opts.seeds[0]);
    let mut t = Table::new(title, &["arm", "epoch", "val_acc_%"]);
    for &(explore, kind) in arms {
        let mut strat = exploration_strategy(
            rt, &ds, kind, explore, fraction, opts.backend, opts.seeds[0],
        )?;
        let cfg = TrainConfig {
            epochs: opts.epochs,
            fraction,
            eval_every: 1,
            seed: opts.seeds[0],
            ..TrainConfig::recipe_for(&ds, opts.epochs)
        };
        let out = Trainer::new(rt, &ds, cfg)?.run(strat.as_mut())?;
        let arm = format!("{}_{}", explore, kind.name());
        for p in &out.trace {
            t.push(vec![arm.clone(), p.epoch.to_string(), pct(p.val_accuracy)]);
        }
    }
    t.save(&opts.out_dir, stem)?;
    Ok(vec![t])
}

pub fn fig5b_early_convergence(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    convergence_compare(
        rt,
        opts,
        DatasetId::Cifar100Like,
        0.05,
        &[
            ("sge", SetFunctionKind::GRAPH_CUT_DEFAULT),
            ("wre", SetFunctionKind::DisparityMin),
            ("sge", SetFunctionKind::FacilityLocation),
            ("wre", SetFunctionKind::GRAPH_CUT_DEFAULT),
        ],
        "fig5b_early_convergence",
        "Fig 5b: early convergence, 5% CIFAR100-like",
    )
}

pub fn fig12_sge_gc_vs_fl(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let mut out = Vec::new();
    for (ds, frac) in [
        (DatasetId::Cifar10Like, 0.05),
        (DatasetId::Cifar100Like, 0.1),
        (DatasetId::Trec6Like, 0.1),
    ] {
        out.extend(convergence_compare(
            rt,
            opts,
            ds,
            frac,
            &[
                ("sge", SetFunctionKind::GRAPH_CUT_DEFAULT),
                ("sge", SetFunctionKind::FacilityLocation),
            ],
            &format!("fig12_{}_{frac}", ds.name()),
            &format!("Fig 12: SGE(GC) vs SGE(FL), {} {}%", ds.name(), frac * 100.0),
        )?);
    }
    Ok(out)
}

pub fn fig13_sge_vs_wre_gc(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let mut out = Vec::new();
    for (ds, frac) in [
        (DatasetId::Cifar10Like, 0.05),
        (DatasetId::Cifar100Like, 0.1),
        (DatasetId::Trec6Like, 0.1),
    ] {
        out.extend(convergence_compare(
            rt,
            opts,
            ds,
            frac,
            &[
                ("sge", SetFunctionKind::GRAPH_CUT_DEFAULT),
                ("wre", SetFunctionKind::GRAPH_CUT_DEFAULT),
            ],
            &format!("fig13_{}_{frac}", ds.name()),
            &format!("Fig 13: SGE(GC) vs WRE(GC), {} {}%", ds.name(), frac * 100.0),
        )?);
    }
    Ok(out)
}

/// Fig 14: curriculum (MILO) vs pure SGE(GC) vs pure WRE(DM) convergence.
pub fn fig14_curriculum_convergence(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds_id in [DatasetId::Cifar10Like, DatasetId::TinyImagenetLike] {
        let ds = ds_id.generate(opts.seeds[0]);
        let fraction = 0.05;
        let mut t = Table::new(
            format!("Fig 14: curriculum vs pure exploration, 5% {}", ds.name()),
            &["arm", "epoch", "val_acc_%"],
        );
        let runner = opts.runner(rt, &ds);
        let meta = runner.preprocess(fraction, opts.seeds[0])?;
        // pure-phase arms are MILO at κ = 1 / 0 — all through the factory
        let arms: Vec<(&str, Box<dyn Strategy>)> = vec![
            (
                "milo_curriculum",
                StrategyKind::Milo { kappa: DEFAULT_KAPPA }.build(Some(&*meta), None)?,
            ),
            (
                "sge_graph_cut",
                StrategyKind::Milo { kappa: 1.0 }.build(Some(&*meta), None)?,
            ),
            (
                "wre_disparity_min",
                StrategyKind::Milo { kappa: 0.0 }.build(Some(&*meta), None)?,
            ),
        ];
        for (name, mut strat) in arms {
            let cfg = TrainConfig {
                epochs: opts.epochs,
                fraction,
                eval_every: 1,
                seed: opts.seeds[0],
                ..TrainConfig::recipe_for(&ds, opts.epochs)
            };
            let out = Trainer::new(rt, &ds, cfg)?.run(strat.as_mut())?;
            for p in &out.trace {
                t.push(vec![name.into(), p.epoch.to_string(), pct(p.val_accuracy)]);
            }
        }
        t.save(&opts.out_dir, &format!("fig14_{}", ds.name()))?;
        tables.push(t);
    }
    Ok(tables)
}

// ===========================================================================
// Fig. 6 (+Tables 5-8) — the main training tradeoff grid
// ===========================================================================

pub fn fig6_tradeoff(
    rt: &Runtime,
    opts: &ReproOptions,
    datasets: &[DatasetId],
) -> Result<Vec<Table>> {
    let kinds = opts.strategies.clone().unwrap_or_else(|| {
        vec![
            StrategyKind::Random,
            StrategyKind::AdaptiveRandom,
            StrategyKind::Glister,
            StrategyKind::CraigPb,
            StrategyKind::GradMatchPb,
            StrategyKind::MiloFixed,
            StrategyKind::Milo { kappa: DEFAULT_KAPPA },
        ]
    });
    let mut tables = Vec::new();
    for &ds_id in datasets {
        let ds = ds_id.generate(opts.seeds[0]);
        let runner = opts.runner(rt, &ds);
        let records = runner.run_grid(&kinds, &opts.fractions, &opts.seeds)?;
        let mut t = Table::new(
            format!(
                "Fig 6 / Tables 5-8: speedup vs accuracy tradeoff, {}",
                ds.name()
            ),
            &GRID_HEADERS,
        );
        for (strategy, fraction, acc, sd, secs, full_acc, full_secs) in aggregate(&records) {
            outcome_row(
                &mut t, ds.name(), &strategy, fraction, acc, sd, secs, full_acc, full_secs,
            );
        }
        t.save(&opts.out_dir, &format!("fig6_{}", ds.name()))?;
        tables.push(t);
    }
    Ok(tables)
}

/// Fig 6 g/h: convergence-with-time at 30%.
pub fn fig6gh_convergence(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds_id in [DatasetId::Cifar100Like, DatasetId::Trec6Like] {
        let ds = ds_id.generate(opts.seeds[0]);
        let runner = opts.runner(rt, &ds);
        let mut t = Table::new(
            format!("Fig 6g/h: convergence with time, 30% {}", ds.name()),
            &["strategy", "train_secs", "val_acc_%"],
        );
        for kind in [
            StrategyKind::Milo { kappa: DEFAULT_KAPPA },
            StrategyKind::AdaptiveRandom,
            StrategyKind::GradMatchPb,
            StrategyKind::CraigPb,
            StrategyKind::Full,
        ] {
            let metadata = if kind.needs_metadata() {
                Some(runner.preprocess(0.3, opts.seeds[0])?)
            } else {
                None
            };
            let mut strategy = kind.build(metadata.as_deref(), None)?;
            let mut cfg = TrainConfig {
                epochs: opts.epochs,
                fraction: if matches!(kind, StrategyKind::Full) { 1.0 } else { 0.3 },
                eval_every: 2,
                seed: opts.seeds[0],
                ..TrainConfig::recipe_for(&ds, opts.epochs)
            };
            if matches!(kind, StrategyKind::CraigPb | StrategyKind::GradMatchPb) {
                cfg.r = runner.r_expensive;
            }
            let out = Trainer::new(rt, &ds, cfg)?.run(strategy.as_mut())?;
            for p in &out.trace {
                t.push(vec![
                    kind.name().into(),
                    f(p.train_secs, 3),
                    pct(p.val_accuracy),
                ]);
            }
        }
        t.save(&opts.out_dir, &format!("fig6gh_{}", ds.name()))?;
        tables.push(t);
    }
    Ok(tables)
}

// ===========================================================================
// Fig. 7 (+Table 10) — hyper-parameter tuning tradeoff
// ===========================================================================

pub fn fig7_hpo(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds_id in [DatasetId::Trec6Like, DatasetId::Cifar10Like] {
        let ds = ds_id.generate(opts.seeds[0]);
        let mut t = Table::new(
            format!("Fig 7 / Table 10: HPO tradeoff, {}", ds.name()),
            &[
                "search", "strategy", "fraction", "best_test_acc_%", "tuning_secs",
                "speedup",
            ],
        );
        for algo in [SearchAlgo::Random, SearchAlgo::Tpe] {
            // FULL reference tuning
            let full_cfg = HpoConfig {
                algo,
                strategy: StrategyKind::Full,
                fraction: 1.0,
                max_epochs: opts.epochs.min(27).max(4),
                eta: 3,
                seed: opts.seeds[0],
            };
            let full = Tuner::new(rt, &ds, full_cfg.clone()).run()?;
            t.push(vec![
                algo.name().into(),
                "full".into(),
                "1.00".into(),
                pct(full.best_test_accuracy),
                f(full.tuning_secs, 2),
                "1.00".into(),
            ]);
            for &fraction in &opts.fractions {
                for kind in [
                    StrategyKind::Random,
                    StrategyKind::AdaptiveRandom,
                    StrategyKind::CraigPb,
                    StrategyKind::MiloFixed,
                    StrategyKind::Milo { kappa: DEFAULT_KAPPA },
                ] {
                    let cfg = HpoConfig {
                        algo,
                        strategy: kind,
                        fraction,
                        ..full_cfg.clone()
                    };
                    let out = Tuner::new(rt, &ds, cfg).run()?;
                    t.push(vec![
                        algo.name().into(),
                        kind.name().into(),
                        f(fraction, 2),
                        pct(out.best_test_accuracy),
                        f(out.tuning_secs, 2),
                        f(full.tuning_secs / out.tuning_secs.max(1e-9), 2),
                    ]);
                    if opts.verbose {
                        eprintln!(
                            "[fig7] {} {} {} f={fraction}: acc {:.2}% {:.1}s",
                            ds.name(),
                            algo.name(),
                            kind.name(),
                            100.0 * out.best_test_accuracy,
                            out.tuning_secs
                        );
                    }
                }
            }
        }
        t.save(&opts.out_dir, &format!("fig7_{}", ds.name()))?;
        tables.push(t);
    }
    Ok(tables)
}

// ===========================================================================
// Tables 1-2 — EL2N scores of subsets per set function
// ===========================================================================

pub fn table_el2n(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds_id in [DatasetId::Cifar100Like, DatasetId::Cifar10Like] {
        let ds = ds_id.generate(opts.seeds[0]);
        let mut t = Table::new(
            format!("Tables 1-2: EL2N of selected subsets, {}", ds.name()),
            &[
                "fraction", "set_function", "el2n_mean", "el2n_median",
                "gen_hardness_mean",
            ],
        );
        // EL2N scores from a briefly trained model (Paul et al. protocol)
        let mut rng = Rng::new(opts.seeds[0]);
        let scores = crate::selection::pruning::El2nPruneStrategy::scores(
            rt, &ds, 128, 3, &mut rng,
        )?;
        let pre = Preprocessor::with_options(
            rt,
            PreprocessOptions { backend: opts.backend, ..Default::default() },
        );
        let emb = pre.encode(&ds, Split::Train)?;
        let kernels = pre.kernels(&ds, &emb)?;
        for &fraction in &opts.fractions {
            let k = (fraction * ds.n_train() as f64).round() as usize;
            for kind in [
                SetFunctionKind::GRAPH_CUT_DEFAULT,
                SetFunctionKind::FacilityLocation,
                SetFunctionKind::DisparityMin,
                SetFunctionKind::DisparitySum,
            ] {
                let subset = pre.fixed_subset(&ds, &kernels, kind, k);
                let sel_scores: Vec<f32> = subset.iter().map(|&i| scores[i]).collect();
                let sel_hard: Vec<f32> = subset.iter().map(|&i| ds.hardness[i]).collect();
                t.push(vec![
                    f(fraction, 2),
                    kind.name().into(),
                    f(mean(&sel_scores), 4),
                    f(median(&sel_scores), 4),
                    f(mean(&sel_hard), 4),
                ]);
            }
        }
        t.save(&opts.out_dir, &format!("table_el2n_{}", ds.name()))?;
        tables.push(t);
    }
    Ok(tables)
}

// ===========================================================================
// Table 9 — hyper-parameter ordering retention (Kendall tau)
// ===========================================================================

pub fn table_kendall(rt: &Runtime, opts: &ReproOptions, n_configs: usize) -> Result<Vec<Table>> {
    let ds = DatasetId::Trec6Like.generate(opts.seeds[0]);
    let space = crate::hpo::HpoSpace::default_for(&ds);
    let grid = space.grid(n_configs);
    let epochs = opts.epochs.min(12).max(3);

    // evaluate the grid under one strategy; returns val accuracies
    let eval_grid = |kind: StrategyKind, fraction: f64| -> Result<Vec<f64>> {
        let cfg = HpoConfig {
            algo: SearchAlgo::Random,
            strategy: kind,
            fraction,
            max_epochs: epochs,
            eta: 3,
            seed: opts.seeds[0],
        };
        let mut tuner = Tuner::new(rt, &ds, cfg);
        if kind.needs_metadata() {
            let pre = Preprocessor::with_options(
                rt,
                PreprocessOptions {
                    fraction,
                    backend: opts.backend,
                    seed: opts.seeds[0],
                    ..Default::default()
                },
            );
            tuner.metadata = Some(std::sync::Arc::new(pre.run(&ds)?));
        }
        let mut sw = crate::util::timer::Stopwatch::new();
        grid.iter()
            .map(|c| Ok(tuner.evaluate(c, epochs, &mut sw)?.val_accuracy))
            .collect()
    };

    let full_order = eval_grid(StrategyKind::Full, 1.0)?;
    let mut t = Table::new(
        format!(
            "Table 9: Kendall-tau ordering retention vs FULL ({} configs, TREC6-like)",
            grid.len()
        ),
        &["fraction", "strategy", "kendall_tau"],
    );
    for &fraction in &[0.01, 0.05, 0.1] {
        for kind in [
            StrategyKind::Milo { kappa: DEFAULT_KAPPA },
            StrategyKind::Random,
            StrategyKind::AdaptiveRandom,
            StrategyKind::CraigPb,
        ] {
            let order = eval_grid(kind, fraction)?;
            let tau = kendall_tau(&order, &full_order);
            t.push(vec![f(fraction, 2), kind.name().into(), f(tau, 4)]);
            if opts.verbose {
                eprintln!("[kendall] {} f={fraction}: tau {:.4}", kind.name(), tau);
            }
        }
    }
    t.save(&opts.out_dir, "table9_kendall")?;
    Ok(vec![t])
}

// ===========================================================================
// Tables 11-12 — similarity metric ablation
// ===========================================================================

pub fn table_simmetric(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds_id in [DatasetId::Cifar100Like, DatasetId::Trec6Like] {
        let ds = ds_id.generate(opts.seeds[0]);
        let mut t = Table::new(
            format!(
                "Tables 11-12: similarity-metric ablation (5% FL fixed subsets, {})",
                ds.name()
            ),
            &["metric", "test_acc_%"],
        );
        let metrics = [
            SimMetric::Cosine,
            SimMetric::Dot,
            SimMetric::Rbf { kw: 0.01 },
            SimMetric::Rbf { kw: 0.05 },
            SimMetric::Rbf { kw: 0.1 },
            SimMetric::Rbf { kw: 0.5 },
            SimMetric::Rbf { kw: 1.0 },
        ];
        for metric in metrics {
            let pre = Preprocessor::with_options(
                rt,
                PreprocessOptions { metric, backend: opts.backend, ..Default::default() },
            );
            let emb = pre.encode(&ds, Split::Train)?;
            let kernels = pre.kernels(&ds, &emb)?;
            let k = (0.05 * ds.n_train() as f64).round() as usize;
            let subset =
                pre.fixed_subset(&ds, &kernels, SetFunctionKind::FacilityLocation, k);
            let mut strat = crate::selection::FixedStrategy::new(metric.name(), subset);
            let cfg = TrainConfig {
                epochs: opts.epochs,
                fraction: 0.05,
                eval_every: 0,
                seed: opts.seeds[0],
                ..TrainConfig::recipe_for(&ds, opts.epochs)
            };
            let out = Trainer::new(rt, &ds, cfg)?.run(&mut strat)?;
            t.push(vec![metric.name(), pct(out.test_accuracy)]);
        }
        t.save(&opts.out_dir, &format!("table_simmetric_{}", ds.name()))?;
        tables.push(t);
    }
    Ok(tables)
}

// ===========================================================================
// Table 13 + Fig 14 — kappa curriculum sweep
// ===========================================================================

pub fn table_kappa(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let kappas = [0.0, 1.0 / 12.0, 1.0 / 10.0, 1.0 / 8.0, 1.0 / 6.0, 0.25, 0.5, 1.0];
    let mut tables = Vec::new();
    for ds_id in [DatasetId::Cifar100Like, DatasetId::Cifar10Like] {
        let ds = ds_id.generate(opts.seeds[0]);
        let runner = opts.runner(rt, &ds);
        let mut t = Table::new(
            format!("Table 13: kappa sweep, {}", ds.name()),
            &["fraction", "kappa", "test_acc_%"],
        );
        for &fraction in &opts.fractions {
            let meta = runner.preprocess(fraction, opts.seeds[0])?;
            for &kappa in &kappas {
                let mut strat = meta.milo_strategy(kappa);
                let cfg = TrainConfig {
                    epochs: opts.epochs,
                    fraction,
                    eval_every: 0,
                    seed: opts.seeds[0],
                    ..TrainConfig::recipe_for(&ds, opts.epochs)
                };
                let out = Trainer::new(rt, &ds, cfg)?.run(&mut strat)?;
                t.push(vec![f(fraction, 2), f(kappa, 4), pct(out.test_accuracy)]);
                if opts.verbose {
                    eprintln!(
                        "[kappa] {} f={fraction} k={kappa:.3}: {:.2}%",
                        ds.name(),
                        100.0 * out.test_accuracy
                    );
                }
            }
        }
        t.save(&opts.out_dir, &format!("table13_kappa_{}", ds.name()))?;
        tables.push(t);
    }
    Ok(tables)
}

// ===========================================================================
// Table 14 — R sweep
// ===========================================================================

pub fn table_r(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let ds = DatasetId::Cifar100Like.generate(opts.seeds[0]);
    let runner = opts.runner(rt, &ds);
    let mut t = Table::new(
        "Table 14: selection-interval R sweep (MILO, CIFAR100-like)",
        &["fraction", "R", "test_acc_%"],
    );
    for &fraction in &[0.1, 0.3] {
        let meta = runner.preprocess(fraction, opts.seeds[0])?;
        for r in [1usize, 2, 5, 10] {
            let mut strat = meta.milo_strategy(DEFAULT_KAPPA);
            let cfg = TrainConfig {
                epochs: opts.epochs,
                fraction,
                r,
                eval_every: 0,
                seed: opts.seeds[0],
                ..TrainConfig::recipe_for(&ds, opts.epochs)
            };
            let out = Trainer::new(rt, &ds, cfg)?.run(&mut strat)?;
            t.push(vec![f(fraction, 2), r.to_string(), pct(out.test_accuracy)]);
        }
    }
    t.save(&opts.out_dir, "table14_r_sweep")?;
    Ok(vec![t])
}

// ===========================================================================
// Tables 15-16 — WRE vs the exploration-heavy SGE variant
// ===========================================================================

pub fn table_wre_variant(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds_id in [DatasetId::Cifar100Like, DatasetId::Cifar10Like] {
        let ds = ds_id.generate(opts.seeds[0]);
        let runner = opts.runner(rt, &ds);
        let mut t = Table::new(
            format!("Tables 15-16: MILO vs SGE-variant (more exploration), {}", ds.name()),
            &["fraction", "strategy", "test_acc_%"],
        );
        for &fraction in &[0.05, 0.1] {
            let meta = runner.preprocess(fraction, opts.seeds[0])?;
            // both arms through the one strategy factory
            for (name, mut strat) in [
                (
                    "milo",
                    StrategyKind::Milo { kappa: DEFAULT_KAPPA }
                        .build(Some(&*meta), None)?,
                ),
                (
                    "sge_variant",
                    StrategyKind::SgeVariant.build(Some(&*meta), None)?,
                ),
            ] {
                let cfg = TrainConfig {
                    epochs: opts.epochs,
                    fraction,
                    eval_every: 0,
                    seed: opts.seeds[0],
                    ..TrainConfig::recipe_for(&ds, opts.epochs)
                };
                let out = Trainer::new(rt, &ds, cfg)?.run(strat.as_mut())?;
                t.push(vec![f(fraction, 2), name.into(), pct(out.test_accuracy)]);
            }
        }
        t.save(&opts.out_dir, &format!("table15_16_wre_{}", ds.name()))?;
        tables.push(t);
    }
    Ok(tables)
}

// ===========================================================================
// Table 17 — MILO vs self-supervised pruning
// ===========================================================================

pub fn table_ssl_prune(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let ds = DatasetId::Cifar100Like.generate(opts.seeds[0]);
    let runner = opts.runner(rt, &ds);
    let full = runner.run_full(opts.seeds[0])?;
    let mut t = Table::new(
        "Table 17: MILO vs self-supervised pruning metric (CIFAR100-like)",
        &["fraction", "strategy", "test_acc_%", "speedup"],
    );
    // MILO at 30%
    let rec = runner.run_cell(
        StrategyKind::Milo { kappa: DEFAULT_KAPPA },
        0.3,
        opts.seeds[0],
        &full,
    )?;
    t.push(vec![
        "0.30".into(),
        "milo".into(),
        pct(rec.outcome.test_accuracy),
        f(rec.speedup(), 2),
    ]);
    // SSL pruning at 30% and 70%
    for fraction in [0.3, 0.7] {
        let rec = runner.run_cell(StrategyKind::SslPrune, fraction, opts.seeds[0], &full)?;
        t.push(vec![
            f(fraction, 2),
            "ssl_prune".into(),
            pct(rec.outcome.test_accuracy),
            f(rec.speedup(), 2),
        ]);
    }
    t.save(&opts.out_dir, "table17_ssl_prune")?;
    Ok(vec![t])
}

// ===========================================================================
// App H.2 — proxy-model encoder; App H.3 — pre-processing time share
// ===========================================================================

pub fn proxy_encoder(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let ds = DatasetId::Cifar100Like.generate(opts.seeds[0]);
    let mut t = Table::new(
        "App H.2: zero-shot encoder vs trained proxy encoder (CIFAR100-like, 10%)",
        &["encoder", "test_acc_%", "preprocess_secs"],
    );
    let fraction = 0.1;
    // (a) zero-shot encoder path
    let runner = opts.runner(rt, &ds);
    let full = runner.run_full(opts.seeds[0])?;
    let rec = runner.run_cell(
        StrategyKind::Milo { kappa: DEFAULT_KAPPA },
        fraction,
        opts.seeds[0],
        &full,
    )?;
    t.push(vec![
        "zero_shot".into(),
        pct(rec.outcome.test_accuracy),
        f(rec.preprocess_secs, 2),
    ]);
    // (b) proxy path: train a proxy model briefly, then use its penultimate
    // features as the embedding space for the same pipeline.
    let t0 = std::time::Instant::now();
    let proxy_cfg = TrainConfig {
        epochs: (opts.epochs / 4).max(2),
        fraction: 1.0,
        eval_every: 0,
        seed: opts.seeds[0],
        ..TrainConfig::recipe_for(&ds, (opts.epochs / 4).max(2))
    };
    let mut trainer = Trainer::new(rt, &ds, proxy_cfg)?;
    trainer.run(&mut crate::selection::FullStrategy)?;
    let mut proxy = trainer.into_model();
    let all: Vec<usize> = (0..ds.n_train()).collect();
    let emb = proxy.proxy_features(rt, &ds, &all)?;
    // same preprocessing, but over proxy embeddings (native backend: the
    // 128-dim sim artifact also exists, but native keeps the ablation fast)
    let pre = Preprocessor::with_options(
        rt,
        PreprocessOptions {
            fraction,
            backend: SimilarityBackend::Native,
            seed: opts.seeds[0],
            ..Default::default()
        },
    );
    let kernels = pre.kernels(&ds, &emb)?;
    let k = (fraction * ds.n_train() as f64).round() as usize;
    let mut rng = Rng::new(opts.seeds[0] ^ 0x9807_1e);
    let sge = pre.sge_subsets(&ds, &kernels, SetFunctionKind::GRAPH_CUT_DEFAULT, k, 3, &mut rng);
    let wre = pre.wre_distribution(&kernels, SetFunctionKind::DisparityMin);
    let prep_secs = t0.elapsed().as_secs_f64();
    let mut strat = crate::selection::MiloStrategy::new(sge, wre, DEFAULT_KAPPA);
    let cfg = TrainConfig {
        epochs: opts.epochs,
        fraction,
        eval_every: 0,
        seed: opts.seeds[0],
        ..TrainConfig::recipe_for(&ds, opts.epochs)
    };
    let out = Trainer::new(rt, &ds, cfg)?.run(&mut strat)?;
    t.push(vec![
        "proxy_mlp".into(),
        pct(out.test_accuracy),
        f(prep_secs, 2),
    ]);
    t.save(&opts.out_dir, "h2_proxy_encoder")?;
    Ok(vec![t])
}

pub fn preprocess_time(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "App H.3: pre-processing time vs full training time",
        &["dataset", "preprocess_secs", "full_train_secs", "share_%", "backend"],
    );
    for ds_id in [DatasetId::Cifar10Like, DatasetId::Cifar100Like, DatasetId::Glyphs] {
        let ds = ds_id.generate(opts.seeds[0]);
        let runner = opts.runner(rt, &ds);
        let meta = runner.preprocess(0.1, opts.seeds[0])?;
        let full = runner.run_full(opts.seeds[0])?;
        t.push(vec![
            ds.name().into(),
            f(meta.preprocess_secs, 3),
            f(full.train_secs, 3),
            f(100.0 * meta.preprocess_secs / full.train_secs.max(1e-9), 1),
            format!("{:?}", opts.backend),
        ]);
    }
    t.save(&opts.out_dir, "h3_preprocess_time")?;
    Ok(vec![t])
}

// ===========================================================================
// Fig 9 / App H.1 — specialized-domain datasets with the general encoder
// ===========================================================================

/// App H.1: MILO vs baselines on the specialized-domain stand-ins
/// (OrganCMNIST-like, DermaMNIST-like) at 5% and 10%, using the *general*
/// zero-shot encoder — the paper's claim is that a generic pre-trained
/// encoder generalizes to unseen domains for subset selection.
pub fn fig9_specialized(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let kinds = opts.strategies.clone().unwrap_or_else(|| {
        vec![
            StrategyKind::Random,
            StrategyKind::AdaptiveRandom,
            StrategyKind::CraigPb,
            StrategyKind::GradMatchPb,
            StrategyKind::MiloFixed,
            StrategyKind::Milo { kappa: DEFAULT_KAPPA },
        ]
    });
    let fractions = [0.05, 0.1];
    let mut tables = Vec::new();
    for ds_id in [DatasetId::OrganaLike, DatasetId::DermaLike] {
        let ds = ds_id.generate(opts.seeds[0]);
        let runner = opts.runner(rt, &ds);
        let records = runner.run_grid(&kinds, &fractions, &opts.seeds)?;
        let mut t = Table::new(
            format!("Fig 9 / App H.1: specialized domain, {}", ds.name()),
            &GRID_HEADERS,
        );
        for (strategy, fraction, acc, sd, secs, full_acc, full_secs) in aggregate(&records) {
            outcome_row(
                &mut t, ds.name(), &strategy, fraction, acc, sd, secs, full_acc, full_secs,
            );
        }
        t.save(&opts.out_dir, &format!("fig9_{}", ds.name()))?;
        tables.push(t);
    }
    Ok(tables)
}

// ===========================================================================
// Fig 11 — encoder-variant ablation
// ===========================================================================

/// Fig 11: performance of a fixed 5% facility-location subset under each
/// frozen encoder variant (paper: DINO CLS/mean, ViT, CLIP for vision;
/// distilroberta vs mpnet for text). Variants are separate AOT artifacts
/// `encoder_{ds}__{variant}` differing in pooling/depth/width/init.
pub fn fig11_encoders(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let variants: [Option<&str>; 5] =
        [None, Some("mean32"), Some("alt32"), Some("wide64"), Some("narrow16")];
    let mut tables = Vec::new();
    for ds_id in [DatasetId::Cifar100Like, DatasetId::Trec6Like] {
        let ds = ds_id.generate(opts.seeds[0]);
        let mut t = Table::new(
            format!("Fig 11: encoder-variant ablation (5% FL fixed subset, {})", ds.name()),
            &["encoder", "embed_dim", "test_acc_%"],
        );
        for variant in variants {
            let pre = Preprocessor::with_options(
                rt,
                PreprocessOptions {
                    backend: opts.backend,
                    encoder_variant: variant.map(str::to_string),
                    ..Default::default()
                },
            );
            let emb = pre.encode(&ds, Split::Train)?;
            let e = emb.cols;
            let kernels = pre.kernels(&ds, &emb)?;
            let k = (0.05 * ds.n_train() as f64).round() as usize;
            let subset =
                pre.fixed_subset(&ds, &kernels, SetFunctionKind::FacilityLocation, k);
            let name = variant.unwrap_or("cls32");
            let mut strat = crate::selection::FixedStrategy::new(name, subset);
            let cfg = TrainConfig {
                epochs: opts.epochs,
                fraction: 0.05,
                eval_every: 0,
                seed: opts.seeds[0],
                ..TrainConfig::recipe_for(&ds, opts.epochs)
            };
            let out = Trainer::new(rt, &ds, cfg)?.run(&mut strat)?;
            t.push(vec![name.into(), e.to_string(), pct(out.test_accuracy)]);
            if opts.verbose {
                eprintln!(
                    "[fig11] {} {name} (e={e}): {:.2}%",
                    ds.name(),
                    100.0 * out.test_accuracy
                );
            }
        }
        t.save(&opts.out_dir, &format!("fig11_{}", ds.name()))?;
        tables.push(t);
    }
    Ok(tables)
}

// ===========================================================================
// Extensions (paper future work): Gibbs exploration & kernel-free MILO
// ===========================================================================

/// Extension A (paper §3.1 Eq. 2): exchange-chain sampling from
/// `P(S) ∝ exp(β·f(S))` vs SGE/WRE — quality (test acc) against
/// set-function-evaluation cost. Demonstrates the mixing-time wall the
/// paper cites as its reason to prefer SGE/WRE.
pub fn ext_gibbs(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let ds = DatasetId::Cifar100Like.generate(opts.seeds[0]);
    let fraction = 0.05;
    let k = (fraction * ds.n_train() as f64).round() as usize;
    let pre = Preprocessor::with_options(
        rt,
        PreprocessOptions { fraction, backend: opts.backend, ..Default::default() },
    );
    let emb = pre.encode(&ds, Split::Train)?;
    let kernels = pre.kernels(&ds, &emb)?;
    let mut t = Table::new(
        "Ext A: Gibbs exchange chain vs SGE/WRE (5% CIFAR100-like, graph-cut)",
        &["arm", "beta", "test_acc_%", "evaluations", "acceptance_%"],
    );
    // Gibbs arms across temperatures
    for beta in [0.5f32, 2.0, 8.0] {
        let mut rng = Rng::new(opts.seeds[0] ^ 0x61BB5);
        let (subsets, stats) = pre.gibbs_subsets(
            &ds,
            &kernels,
            SetFunctionKind::GRAPH_CUT_DEFAULT,
            k,
            beta,
            3,
            &mut rng,
        );
        let mut strat = SgeStrategy::new(format!("gibbs_b{beta}"), subsets);
        let cfg = TrainConfig {
            epochs: opts.epochs,
            fraction,
            eval_every: 0,
            seed: opts.seeds[0],
            ..TrainConfig::recipe_for(&ds, opts.epochs)
        };
        let out = Trainer::new(rt, &ds, cfg)?.run(&mut strat)?;
        t.push(vec![
            "gibbs".into(),
            f(beta as f64, 1),
            pct(out.test_accuracy),
            stats.evaluations.to_string(),
            f(100.0 * stats.acceptance_rate(), 1),
        ]);
        if opts.verbose {
            eprintln!(
                "[gibbs] beta={beta}: {:.2}% acc, {} evals, {:.1}% accepted",
                100.0 * out.test_accuracy,
                stats.evaluations,
                100.0 * stats.acceptance_rate()
            );
        }
    }
    // SGE / WRE reference arms (evaluation cost of stochastic greedy is
    // n/k·ln(1/ε) gains per pick ⇒ ≈ n·ln(1/ε) per subset)
    for explore in ["sge", "wre"] {
        let mut strat = exploration_strategy(
            rt,
            &ds,
            SetFunctionKind::GRAPH_CUT_DEFAULT,
            explore,
            fraction,
            opts.backend,
            opts.seeds[0],
        )?;
        let cfg = TrainConfig {
            epochs: opts.epochs,
            fraction,
            eval_every: 0,
            seed: opts.seeds[0],
            ..TrainConfig::recipe_for(&ds, opts.epochs)
        };
        let out = Trainer::new(rt, &ds, cfg)?.run(strat.as_mut())?;
        let evals = (ds.n_train() as f64 * (1.0f64 / 0.01).ln()).round() as u64;
        t.push(vec![
            explore.into(),
            "-".into(),
            pct(out.test_accuracy),
            (if explore == "sge" { 3 * evals } else { evals * 2 }).to_string(),
            "-".into(),
        ]);
    }
    t.save(&opts.out_dir, "ext_gibbs")?;
    Ok(vec![t])
}

/// Extension B (conclusion future work): kernel-free feature-based MILO vs
/// kernel MILO — accuracy and pre-processing memory/time.
pub fn ext_featurebased(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds_id in [DatasetId::Cifar100Like, DatasetId::Trec6Like] {
        let ds = ds_id.generate(opts.seeds[0]);
        let mut t = Table::new(
            format!("Ext B: kernel MILO vs kernel-free feature-based MILO, {}", ds.name()),
            &["arm", "fraction", "test_acc_%", "prep_secs", "prep_mem_bytes"],
        );
        for &fraction in &[0.05, 0.1] {
            let pre = Preprocessor::with_options(
                rt,
                PreprocessOptions {
                    fraction,
                    backend: opts.backend,
                    seed: opts.seeds[0],
                    ..Default::default()
                },
            );
            // kernel path (memory = Σ_c n_c² floats)
            let emb = pre.encode(&ds, Split::Train)?;
            let kernels = pre.kernels(&ds, &emb)?;
            let kern_mem = kernels.total_elements() * std::mem::size_of::<f32>();
            let meta_k = pre.run(&ds)?;
            let feat_mem = crate::submod::FeatureCoverage::memory_bytes(
                ds.n_train(),
                2 * emb.cols,
            );
            let meta_f = pre.run_featurebased(&ds)?;
            for (arm, meta, mem) in [
                ("kernel", &meta_k, kern_mem),
                ("feature_based", &meta_f, feat_mem),
            ] {
                let mut strat = meta.milo_strategy(DEFAULT_KAPPA);
                let cfg = TrainConfig {
                    epochs: opts.epochs,
                    fraction,
                    eval_every: 0,
                    seed: opts.seeds[0],
                    ..TrainConfig::recipe_for(&ds, opts.epochs)
                };
                let out = Trainer::new(rt, &ds, cfg)?.run(&mut strat)?;
                t.push(vec![
                    arm.into(),
                    f(fraction, 2),
                    pct(out.test_accuracy),
                    f(meta.preprocess_secs, 3),
                    mem.to_string(),
                ]);
                if opts.verbose {
                    eprintln!(
                        "[featspace] {} {arm} f={fraction}: {:.2}%, {:.3}s, {} B",
                        ds.name(),
                        100.0 * out.test_accuracy,
                        meta.preprocess_secs,
                        mem
                    );
                }
            }
        }
        t.save(&opts.out_dir, &format!("ext_featurebased_{}", ds.name()))?;
        tables.push(t);
    }
    Ok(tables)
}

// ===========================================================================
// Fig 2 — headline summary (aggregates fig6+fig7 outputs)
// ===========================================================================

pub fn fig2_summary(rt: &Runtime, opts: &ReproOptions) -> Result<Vec<Table>> {
    // Training side: MILO vs FULL at 10% and 30% on three datasets.
    let mut t = Table::new(
        "Fig 2: MILO headline speedup vs accuracy drop",
        &["task", "dataset", "fraction", "speedup", "acc_drop_%"],
    );
    for ds_id in [DatasetId::Cifar10Like, DatasetId::Trec6Like, DatasetId::Glyphs] {
        let ds = ds_id.generate(opts.seeds[0]);
        let runner = opts.runner(rt, &ds);
        let full = runner.run_full(opts.seeds[0])?;
        for fraction in [0.1, 0.3] {
            let rec = runner.run_cell(
                StrategyKind::Milo { kappa: DEFAULT_KAPPA },
                fraction,
                opts.seeds[0],
                &full,
            )?;
            t.push(vec![
                "training".into(),
                ds.name().into(),
                f(fraction, 2),
                f(rec.speedup(), 2),
                f(rec.degradation_pct(), 2),
            ]);
        }
    }
    t.save(&opts.out_dir, "fig2_summary")?;
    Ok(vec![t])
}
