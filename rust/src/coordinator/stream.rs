//! Streaming pre-processor: bounded-memory MILO pre-processing with
//! backpressure.
//!
//! The batch [`super::Preprocessor::run`] materializes the full n×E
//! embedding matrix and *every* class kernel simultaneously — the memory
//! profile the paper's conclusion flags as MILO's main limitation. This
//! pipeline instead streams **one class at a time** through three stages:
//!
//! ```text
//!  producer (main thread, owns PJRT)      workers (pure Rust)
//!  ┌───────────────────────────────┐      ┌──────────────────────────┐
//!  │ encode class c rows (PJRT)    │ ──▶  │ kernel → SGE picks → WRE │
//!  │ blocks when `max_inflight`    │ sync │ sweep → fixed picks      │
//!  │ class payloads are queued     │ chan │ (per-class, independent) │
//!  └───────────────────────────────┘      └──────────────────────────┘
//! ```
//!
//! Backpressure: the handoff is a `sync_channel(max_inflight)` — when the
//! workers fall behind, the producer blocks *before* encoding the next
//! class, so peak memory is O(largest-class embeddings+kernel ×
//! (max_inflight + workers)) instead of O(n·E + Σ n_c²). Every per-class
//! output of MILO pre-processing (SGE picks, WRE distribution, fixed
//! picks) is class-decomposable, so the streamed metadata is structurally
//! identical to the batch path's.
//!
//! Determinism: per-class RNG streams are derived as `seed ⊕ class`, so
//! results are independent of worker scheduling.
//!
//! This pipeline streams the *processing* of a dataset that is already
//! complete; when the **data itself** arrives over time, use
//! [`crate::continual`], which maintains the kernels and selections
//! incrementally across arrival batches instead of bounding one pass.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;

use anyhow::Result;

use crate::data::Dataset;
use crate::kernel::{native_similarity, KernelSchedule};
use crate::runtime::Arg;
use crate::selection::milo::ClassProbs;
use crate::selection::proportional_allocation;
use crate::submod::{greedy_maximize, sample_importance, GreedyMode};
use crate::tensor::Matrix;
use crate::util::math::taylor_softmax;
use crate::util::rng::Rng;

use super::{Metadata, Preprocessor};

/// Streaming knobs.
#[derive(Clone, Copy, Debug)]
pub struct StreamOptions {
    /// Class payloads allowed in the producer→worker queue at once.
    pub max_inflight: usize,
    /// Worker threads building kernels / running greedy.
    pub workers: usize,
}

impl Default for StreamOptions {
    fn default() -> Self {
        StreamOptions {
            max_inflight: 2,
            workers: crate::util::threads::max_threads().clamp(1, 4),
        }
    }
}

/// Peak-memory accounting for the ablation (`ext` experiments) and tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    /// Max class payloads simultaneously alive (queued + in-processing).
    pub peak_inflight: usize,
    /// Peak bytes of embeddings + kernels alive at once.
    pub peak_bytes: usize,
    /// Bytes the dense-kernel batch path would have held at its peak
    /// (full embedding matrix + all dense class kernels) — the reference
    /// axis for the paper's memory-limitation comparison.
    pub batch_bytes: usize,
}

/// One class flowing through the pipeline.
struct ClassPayload {
    class: usize,
    indices: Vec<usize>,
    emb: Matrix,
    kc: usize,
    n_sge: usize,
    seed: u64,
    sge_fn: crate::submod::SetFunctionKind,
    wre_fn: crate::submod::SetFunctionKind,
    epsilon: f64,
    /// Sparse top-`knn` class blocks (`None` = dense) — the streaming
    /// path honors the same option as the batch path, and the two
    /// memory levers compound.
    knn: Option<usize>,
    /// Strip schedule for sparse blocks: each worker runs its class
    /// through the same overlapped build pipeline as the batch path
    /// ([`crate::kernel::pipeline`]), so `--sim-tile`/`--pipeline-depth`
    /// steer streaming too.
    sched: KernelSchedule,
}

/// Per-class results folded back into [`Metadata`].
struct ClassResult {
    class: usize,
    indices: Vec<usize>,
    sge_picks: Vec<Vec<usize>>, // local indices, one per SGE subset
    probs: Vec<f64>,
    fixed_picks: Vec<usize>,
}

fn process_class(
    p: ClassPayload,
    live: &AtomicUsize,
    peak: &AtomicUsize,
) -> Result<ClassResult> {
    // dense or sparse top-knn per the preprocessing option — the
    // bounded-memory pipeline and kernel sparsification compound
    let sim = match p.knn {
        None => crate::kernel::ClassSim::Dense(native_similarity(
            &p.emb,
            crate::kernel::SimMetric::Cosine,
        )),
        Some(k) => crate::kernel::ClassSim::Sparse(
            crate::kernel::sparse::sparse_native_scheduled(
                &p.emb,
                crate::kernel::SimMetric::Cosine,
                k,
                &p.sched,
            )?
            .0,
        ),
    };
    // account this class's working set against the peak for its whole
    // processing lifetime — embeddings + kernel stay alive through the
    // greedy sweeps below (CSR blocks pay columns + row index on top of
    // the floats, so count real bytes)
    let bytes =
        p.emb.rows * p.emb.cols * std::mem::size_of::<f32>() + sim.memory_bytes();
    let now = live.fetch_add(bytes, Ordering::SeqCst) + bytes;
    peak.fetch_max(now, Ordering::SeqCst);
    let mut rng = Rng::new(p.seed);
    let sge_picks: Vec<Vec<usize>> = (0..p.n_sge)
        .map(|_| {
            if p.kc == 0 {
                return Vec::new();
            }
            let mut f = p.sge_fn.build_view(sim.view());
            greedy_maximize(
                f.as_mut(),
                p.kc,
                GreedyMode::Stochastic { epsilon: p.epsilon },
                p.sge_fn.lazy_safe(),
                &mut rng,
            )
            .selected
        })
        .collect();
    let probs = {
        let mut f = p.wre_fn.build_view(sim.view());
        let gains = sample_importance(f.as_mut(), p.wre_fn.lazy_safe());
        let g64: Vec<f64> = gains.iter().map(|&g| g as f64).collect();
        taylor_softmax(&g64)
    };
    let fixed_picks = if p.kc == 0 {
        Vec::new()
    } else {
        let mut f = p.wre_fn.build_view(sim.view());
        greedy_maximize(f.as_mut(), p.kc, GreedyMode::Lazy, p.wre_fn.lazy_safe(), &mut rng)
            .selected
    };
    live.fetch_sub(bytes, Ordering::SeqCst);
    Ok(ClassResult {
        class: p.class,
        indices: p.indices,
        sge_picks,
        probs,
        fixed_picks,
    })
}

impl<'a> Preprocessor<'a> {
    /// Bounded-memory streaming pre-processing. Returns the same
    /// [`Metadata`] shape as [`Preprocessor::run`] plus pipeline stats.
    ///
    /// Peak memory is bounded by `(max_inflight + workers)` class working
    /// sets instead of the whole dataset — the streaming answer to the
    /// paper's kernel-memory limitation (its §3.2 class-wise trick bounds
    /// *each* kernel; this bounds how many are alive at once).
    pub fn run_streaming(
        &self,
        ds: &Dataset,
        stream: StreamOptions,
    ) -> Result<(Metadata, StreamStats)> {
        let t0 = std::time::Instant::now();
        let k = ((self.opts.fraction * ds.n_train() as f64).round() as usize).max(1);
        let parts = ds.class_partition();
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        let alloc = proportional_allocation(&sizes, k.min(ds.n_train()));
        let n_sge = self.opts.n_sge_subsets;
        let c = parts.len();

        let man = self.rt.manifest();
        let b = man.batch;
        let d = ds.id.input_dim();
        let artifact = format!("encoder_{}", ds.name());
        let e = man
            .artifacts
            .get(&artifact)
            .and_then(|a| a.embed_dim)
            .unwrap_or(man.embed_dim);

        let inflight = AtomicUsize::new(0);
        let peak_inflight = AtomicUsize::new(0);
        let live_bytes = AtomicUsize::new(0);
        let peak_bytes = AtomicUsize::new(0);

        let (tx, rx) = sync_channel::<ClassPayload>(stream.max_inflight.max(1));
        let rx = std::sync::Mutex::new(rx);
        let results = std::sync::Mutex::new(Vec::<ClassResult>::with_capacity(c));
        let worker_err = std::sync::Mutex::new(None::<anyhow::Error>);

        let mut encode_err: Option<anyhow::Error> = None;
        std::thread::scope(|scope| {
            // workers: pure-Rust per-class kernel + greedy
            for _ in 0..stream.workers.max(1) {
                scope.spawn(|| loop {
                    let payload = { rx.lock().unwrap().recv() };
                    match payload {
                        Ok(p) => {
                            // after a failure, keep draining (dropping
                            // payloads) so the producer never deadlocks
                            // on a full channel
                            let failed = worker_err.lock().unwrap().is_some();
                            let r = (!failed)
                                .then(|| process_class(p, &live_bytes, &peak_bytes));
                            inflight.fetch_sub(1, Ordering::SeqCst);
                            match r {
                                Some(Ok(res)) => results.lock().unwrap().push(res),
                                Some(Err(e)) => {
                                    worker_err.lock().unwrap().get_or_insert(e);
                                }
                                None => {}
                            }
                        }
                        Err(_) => break, // channel closed: done
                    }
                });
            }
            // producer (this thread): PJRT-encode one class at a time
            let mut xbuf = vec![0.0f32; b * d];
            'outer: for (class, idx) in parts.iter().enumerate() {
                if worker_err.lock().unwrap().is_some() {
                    break; // a kernel build failed: stop encoding
                }
                let x = ds.x(crate::data::Split::Train);
                let mut emb = Matrix::zeros(idx.len(), e);
                let mut at = 0usize;
                while at < idx.len() {
                    let take = (idx.len() - at).min(b);
                    for r in 0..take {
                        xbuf[r * d..(r + 1) * d].copy_from_slice(x.row(idx[at + r]));
                    }
                    for r in take..b {
                        xbuf[r * d..(r + 1) * d].iter_mut().for_each(|v| *v = 0.0);
                    }
                    let res = match self.rt.execute(&artifact, &[Arg::F32(&xbuf)]) {
                        Ok(r) => r,
                        Err(err) => {
                            encode_err = Some(err);
                            break 'outer;
                        }
                    };
                    for r in 0..take {
                        emb.row_mut(at + r)
                            .copy_from_slice(&res[0][r * e..(r + 1) * e]);
                    }
                    at += take;
                }
                let now = inflight.fetch_add(1, Ordering::SeqCst) + 1;
                peak_inflight.fetch_max(now, Ordering::SeqCst);
                // send blocks when max_inflight payloads are queued —
                // the backpressure edge
                let payload = ClassPayload {
                    class,
                    indices: idx.clone(),
                    emb,
                    kc: alloc[class],
                    n_sge,
                    seed: self.opts.seed ^ 0x57AE ^ (class as u64).wrapping_mul(0x9E37),
                    sge_fn: self.opts.sge_function,
                    wre_fn: self.opts.wre_function,
                    epsilon: self.opts.epsilon,
                    knn: self.opts.knn,
                    sched: self.opts.schedule(),
                };
                if tx.send(payload).is_err() {
                    break;
                }
            }
            drop(tx); // close the channel so workers drain and exit
        });
        if let Some(err) = encode_err {
            return Err(err);
        }
        if let Some(err) = worker_err.into_inner().unwrap() {
            return Err(err);
        }

        // fold per-class results (sorted by class for determinism)
        let mut results = results.into_inner().unwrap();
        results.sort_by_key(|r| r.class);
        let mut sge_subsets = vec![Vec::new(); n_sge];
        let mut wre_classes = Vec::with_capacity(c);
        let mut fixed = Vec::new();
        for r in results {
            for (si, picks) in r.sge_picks.iter().enumerate() {
                sge_subsets[si].extend(picks.iter().map(|&l| r.indices[l]));
            }
            fixed.extend(r.fixed_picks.iter().map(|&l| r.indices[l]));
            wre_classes.push(ClassProbs { indices: r.indices, probs: r.probs });
        }
        for s in &mut sge_subsets {
            s.sort_unstable();
        }
        fixed.sort_unstable();

        let batch_bytes = (ds.n_train() * e
            + sizes.iter().map(|&n| n * n).sum::<usize>())
            * std::mem::size_of::<f32>();
        let stats = StreamStats {
            peak_inflight: peak_inflight.load(Ordering::SeqCst),
            peak_bytes: peak_bytes.load(Ordering::SeqCst),
            batch_bytes,
        };
        Ok((
            Metadata {
                dataset: ds.name().to_string(),
                fraction: self.opts.fraction,
                sge_subsets,
                wre_classes,
                fixed_dm: fixed,
                preprocess_secs: t0.elapsed().as_secs_f64(),
            },
            stats,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PreprocessOptions;
    use crate::data::DatasetId;
    use crate::runtime::Runtime;

    fn runtime() -> Option<Runtime> {
        crate::testkit::artifacts_or_skip()
    }

    fn pre<'a>(rt: &'a Runtime, fraction: f64, seed: u64) -> Preprocessor<'a> {
        Preprocessor::with_options(
            rt,
            PreprocessOptions {
                fraction,
                seed,
                backend: crate::kernel::SimilarityBackend::Native,
                ..Default::default()
            },
        )
    }

    #[test]
    fn streaming_output_is_structurally_identical_to_batch() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Trec6Like.generate(1);
        let p = pre(&rt, 0.1, 1);
        let batch = p.run(&ds).unwrap();
        let (streamed, _) = p.run_streaming(&ds, StreamOptions::default()).unwrap();
        assert_eq!(streamed.sge_subsets.len(), batch.sge_subsets.len());
        for (a, b) in streamed.sge_subsets.iter().zip(&batch.sge_subsets) {
            assert_eq!(a.len(), b.len());
        }
        assert_eq!(streamed.fixed_dm.len(), batch.fixed_dm.len());
        assert_eq!(streamed.wre_classes.len(), batch.wre_classes.len());
        for (a, b) in streamed.wre_classes.iter().zip(&batch.wre_classes) {
            assert_eq!(a.indices, b.indices);
            let sum: f64 = a.probs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
        }
        // the WRE distributions are deterministic (no rng) → must agree
        // exactly with the batch path
        for (a, b) in streamed.wre_classes.iter().zip(&batch.wre_classes) {
            for (x, y) in a.probs.iter().zip(&b.probs) {
                assert!((x - y).abs() < 1e-9, "WRE probs diverged");
            }
        }
    }

    #[test]
    fn streaming_honors_sparse_kernels() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Trec6Like.generate(6);
        let p = Preprocessor::with_options(
            &rt,
            PreprocessOptions {
                fraction: 0.1,
                seed: 6,
                backend: crate::kernel::SimilarityBackend::Native,
                knn: Some(8),
                ..Default::default()
            },
        );
        let (meta, stats) = p.run_streaming(&ds, StreamOptions::default()).unwrap();
        let k = (0.1 * ds.n_train() as f64).round() as usize;
        for s in &meta.sge_subsets {
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        }
        assert_eq!(meta.fixed_dm.len(), k);
        for c in &meta.wre_classes {
            let sum: f64 = c.probs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
        }
        // sparse blocks shrink the streamed working set further below
        // the dense batch reference
        assert!(stats.peak_bytes < stats.batch_bytes);
    }

    #[test]
    fn streaming_is_deterministic() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Trec6Like.generate(2);
        let p = pre(&rt, 0.05, 2);
        // different worker counts must not change the output
        let (a, _) = p
            .run_streaming(&ds, StreamOptions { max_inflight: 1, workers: 1 })
            .unwrap();
        let (b, _) = p
            .run_streaming(&ds, StreamOptions { max_inflight: 3, workers: 4 })
            .unwrap();
        assert_eq!(a.sge_subsets, b.sge_subsets);
        assert_eq!(a.fixed_dm, b.fixed_dm);
        for (x, y) in a.wre_classes.iter().zip(&b.wre_classes) {
            assert_eq!(x.indices, y.indices);
            assert_eq!(x.probs, y.probs);
        }
    }

    #[test]
    fn backpressure_bounds_inflight_payloads() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Cifar10Like.generate(3);
        let p = pre(&rt, 0.1, 3);
        let opts = StreamOptions { max_inflight: 2, workers: 2 };
        let (_, stats) = p.run_streaming(&ds, opts).unwrap();
        // alive payloads = queued (≤ max_inflight) + claimed by workers
        // (≤ workers) + the one the producer holds while blocked on send
        let bound = opts.max_inflight + opts.workers + 1;
        assert!(
            stats.peak_inflight <= bound,
            "peak inflight {} exceeds bound {bound}",
            stats.peak_inflight,
        );
        assert!(stats.peak_bytes > 0);
        assert!(
            stats.peak_bytes < stats.batch_bytes,
            "streaming peak {} should undercut batch {}",
            stats.peak_bytes,
            stats.batch_bytes
        );
    }

    #[test]
    fn streamed_metadata_trains_a_model() {
        let Some(rt) = runtime() else { return };
        use crate::train::{TrainConfig, Trainer};
        let ds = DatasetId::Trec6Like.generate(4);
        let p = pre(&rt, 0.1, 4);
        let (meta, _) = p.run_streaming(&ds, StreamOptions::default()).unwrap();
        let mut strat = meta.milo_strategy(1.0 / 6.0);
        let cfg = TrainConfig {
            epochs: 6,
            fraction: 0.1,
            eval_every: 0,
            seed: 4,
            ..TrainConfig::recipe_for(&ds, 6)
        };
        let out = Trainer::new(&rt, &ds, cfg).unwrap().run(&mut strat).unwrap();
        assert!(out.test_accuracy > 1.5 / ds.classes() as f64, "should beat chance");
    }
}
