//! `milo` — the coordinator CLI.
//!
//! Subcommands:
//!   * `preprocess` — run MILO pre-processing for a dataset/fraction and
//!     store the metadata (subsets + WRE distribution) on disk;
//!   * `precompute` — pre-processing into the content-addressed metadata
//!     store (versioned binary artifacts, fingerprinted by configuration);
//!   * `serve`      — serve store artifacts (any number of dataset ×
//!     fraction entries from one event-loop process) to N concurrent
//!     trainers over TCP, JSON-line or binary-frame wire (see
//!     `milo::serve` for the protocol);
//!   * `stream`     — synthetic continual-arrival workload: batches of
//!     embeddings arrive, a fixed-size replay-buffer coreset is
//!     re-selected incrementally each epoch (`milo::continual`), and
//!     each epoch is optionally published to the store's version chain
//!     and pushed live to `--serve` subscribers;
//!   * `train`      — train a downstream model with any strategy;
//!   * `tune`       — hyper-parameter tuning (Random/TPE × Hyperband),
//!     optionally against a running `milo serve` (`--server addr:port`);
//!   * `repro`      — regenerate a paper table/figure (see DESIGN.md §5);
//!   * `list`       — datasets / strategies / experiments.
//!
//! All randomness flows from `--seed`; artifacts must exist
//! (`make artifacts`).

use anyhow::{bail, Result};

use milo::coordinator::repro::{self, ReproOptions};
use milo::coordinator::{PreprocessOptions, Preprocessor, StrategyKind};
use milo::data::DatasetId;
use milo::hpo::{HpoConfig, SearchAlgo, Tuner};
use milo::kernel::SimilarityBackend;
use milo::runtime::Runtime;
use milo::session::MetaSource;
use milo::util::args::Args;

const USAGE: &str = "\
milo — model-agnostic subset selection (MILO reproduction)

USAGE:
  milo preprocess --dataset <name> [--fraction 0.1] [--backend pjrt|native]
                  [--knn 32|full]  (sparse top-knn kernels vs dense blocks)
                  [--streaming]    (bounded-memory pipeline w/ backpressure)
                  [--sim-tile N] [--pipeline-depth 2]  (kernel-build schedule;
                  overlap depth 1 = serial — changes wall time, never values)
  milo precompute --dataset <name> [--fraction 0.1] [--seed 1] [--knn 32|full]
                  [--store results/store]   (content-addressed binary store)
                  [--sim-tile N] [--pipeline-depth 2]
  milo serve --dataset <name> | --datasets a,b [--fractions 0.1,0.3]
             [--addr 127.0.0.1:4077] [--fraction 0.1] [--seed 1] [--knn 32|full]
             [--store results/store] [--featurebased]
             [--sim-tile N] [--pipeline-depth 2]
             [--metrics-addr 127.0.0.1:9464]  (plain-text metrics exposition)
             (one event-loop process serves every dataset×fraction entry)
  milo stream [--dataset stream] [--classes 4] [--dim 16] [--batch 64]
              [--batches 8] [--buffer 128] [--knn 16|full] [--seed 1]
              [--store results/store]      (publish each epoch's artifact + head)
              [--serve 127.0.0.1:4077]     (push EPOCH_ADVANCE/SUBSET_DELTA live)
              [--metrics-addr 127.0.0.1:9464]  (exposition + /flight dump)
  milo trace <trace.jsonl> [--traces 10]
             (render per-trace span trees, the critical path, and a top-spans
              summary from a MILO_TRACE sink or a /flight dump)
  milo train --dataset <name> --strategy <name> [--fraction 0.1]
             [--epochs 40] [--seed 1] [--r 1] [--kappa 0.1667]
  milo tune --dataset <name> --strategy <name> [--algo random|tpe]
            [--fraction 0.1] [--max-epochs 27] [--server host:port]
  milo repro <experiment>... [--epochs 40] [--seeds 1,2]
             [--fractions 0.01,0.05,0.1,0.3] [--strategies milo,random,...]
             [--out results]
  milo list

Strategy names (train/tune/repro share one vocabulary; see `milo list`):
  any name from StrategyKind — an unknown name lists the valid set.

EXPERIMENTS (milo repro):
  fig1 fig2 fig4 fig5a fig5b fig6 fig6gh fig7 fig9 fig11 fig12 fig13 fig14
  el2n kendall simmetric kappa rsweep wrevariant sslprune proxy preptime
  gibbs featspace   (extensions: paper future work)
  quick (= fig4+fig5b+el2n with small budgets)   all
";

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let args = Args::from_env(&["verbose", "quiet", "help", "streaming", "featurebased"])?;
    if args.flag("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    match args.positional[0].as_str() {
        "list" => {
            println!("datasets:");
            for id in DatasetId::ALL {
                let (tr, va, te) = id.sizes();
                println!(
                    "  {:14} D={:3} C={:3} splits {}/{}/{}",
                    id.name(),
                    id.input_dim(),
                    id.classes(),
                    tr,
                    va,
                    te
                );
            }
            // generated from the one StrategyKind table, never hand-listed
            println!(
                "\nstrategies: {}",
                StrategyKind::ALL
                    .iter()
                    .map(|k| k.name())
                    .collect::<Vec<_>>()
                    .join(" ")
            );
            Ok(())
        }
        "preprocess" => cmd_preprocess(&args, &artifacts),
        "precompute" => cmd_precompute(&args, &artifacts),
        "serve" => cmd_serve(&args, &artifacts),
        "stream" => cmd_stream(&args),
        "trace" => cmd_trace(&args),
        "train" => cmd_train(&args, &artifacts),
        "tune" => cmd_tune(&args, &artifacts),
        "repro" => cmd_repro(&args, &artifacts),
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn backend_of(args: &Args) -> Result<SimilarityBackend> {
    Ok(match args.get_or("backend", "native") {
        "pjrt" => SimilarityBackend::Pjrt,
        "native" => SimilarityBackend::Native,
        other => bail!("unknown backend {other:?}"),
    })
}

/// `--knn N` selects sparse top-`N` kernel blocks (`≈ n_c·N` floats,
/// O(N) gains); `--knn full` (or omitting the flag) keeps the paper's
/// dense `n_c²` blocks. Sparse configs address separate store artifacts.
fn knn_of(args: &Args) -> Result<Option<usize>> {
    match args.get("knn") {
        None | Some("full") | Some("dense") => Ok(None),
        Some(text) => {
            let k: usize = text.parse().map_err(|_| {
                anyhow::anyhow!(
                    "--knn expects a positive integer or 'full', got {text:?}"
                )
            })?;
            if k == 0 {
                bail!("--knn must be positive (use 'full' for dense kernels)");
            }
            Ok(Some(k))
        }
    }
}

/// `--sim-tile N` / `--pipeline-depth N`: the kernel-build schedule.
/// Schedule-only — both change wall time, never kernel values, so they
/// are deliberately *not* part of the store fingerprint
/// (see `milo::kernel::pipeline`).
fn schedule_of(args: &Args) -> Result<(Option<usize>, usize)> {
    let sim_tile = match args.get("sim-tile") {
        None => None,
        Some(_) => Some(args.get_usize("sim-tile", 0)?.max(1)),
    };
    let depth = args.get_usize("pipeline-depth", 2)?.max(1);
    Ok((sim_tile, depth))
}

fn dataset_of(args: &Args) -> Result<(DatasetId, u64)> {
    let name = args
        .get("dataset")
        .ok_or_else(|| anyhow::anyhow!("--dataset is required"))?;
    let seed = args.get_u64("seed", 1)?;
    Ok((DatasetId::from_name(name)?, seed))
}

/// `--strategy` for `train`/`tune`: the full [`StrategyKind::parse`]
/// vocabulary, with `--kappa` overriding MILO's curriculum fraction.
fn strategy_of(args: &Args) -> Result<StrategyKind> {
    let kind = StrategyKind::parse(args.get_or("strategy", "milo"))?;
    Ok(match kind {
        StrategyKind::Milo { kappa } => {
            StrategyKind::Milo { kappa: args.get_f64("kappa", kappa)? }
        }
        other => other,
    })
}

/// `--strategies a,b,c` for `repro` (same vocabulary, same errors).
fn strategies_of(args: &Args) -> Result<Option<Vec<StrategyKind>>> {
    match args.get("strategies") {
        None => Ok(None),
        Some(list) => list
            .split(',')
            .map(|name| StrategyKind::parse(name.trim()))
            .collect::<Result<Vec<_>>>()
            .map(Some),
    }
}

fn cmd_preprocess(args: &Args, artifacts: &str) -> Result<()> {
    let rt = Runtime::open(artifacts)?;
    let (id, seed) = dataset_of(args)?;
    let ds = id.generate(seed);
    let fraction = args.get_f64("fraction", 0.1)?;
    let (sim_tile, pipeline_depth) = schedule_of(args)?;
    let pre = Preprocessor::with_options(
        &rt,
        PreprocessOptions {
            fraction,
            backend: backend_of(args)?,
            seed,
            knn: knn_of(args)?,
            sim_tile,
            pipeline_depth,
            ..Default::default()
        },
    );
    let out_dir = std::path::PathBuf::from(args.get_or("out", "results/metadata"));
    if args.flag("streaming") {
        // bounded-memory pipeline (see coordinator::stream)
        let (meta, stats) = pre.run_streaming(
            &ds,
            milo::coordinator::stream::StreamOptions::default(),
        )?;
        println!(
            "streamed {} f={fraction}: {} SGE subsets of {}, peak {} B \
             (batch path would hold {} B), {:.2}s",
            ds.name(),
            meta.sge_subsets.len(),
            meta.sge_subsets.first().map(|s| s.len()).unwrap_or(0),
            stats.peak_bytes,
            stats.batch_bytes,
            meta.preprocess_secs,
        );
        std::fs::create_dir_all(&out_dir)?;
        milo::coordinator::save_metadata(
            &meta,
            &out_dir.join(format!("{}_f{}_s{}_stream.json", ds.name(), fraction, seed)),
        )?;
        return Ok(());
    }
    let meta = MetaSource::store(out_dir.clone(), pre.opts.clone())?
        .resolve(Some(&rt), &ds)?;
    println!(
        "preprocessed {} f={fraction}: {} SGE subsets of {}, WRE over {} classes, \
         fixed-DM {}, {:.2}s -> {}",
        ds.name(),
        meta.sge_subsets.len(),
        meta.sge_subsets.first().map(|s| s.len()).unwrap_or(0),
        meta.wre_classes.len(),
        meta.fixed_dm.len(),
        meta.preprocess_secs,
        out_dir.display()
    );
    Ok(())
}

/// Store-backed preprocessing shared by `precompute` and `serve`: resolve
/// the configuration fingerprint, then hit the store (cache → disk →
/// build).
fn store_metadata(
    args: &Args,
    artifacts: &str,
) -> Result<(milo::store::MetaStore, milo::store::MetaKey, std::sync::Arc<milo::coordinator::Metadata>, String, u64)>
{
    let rt = Runtime::open(artifacts)?;
    let (id, seed) = dataset_of(args)?;
    let ds = id.generate(seed);
    let (sim_tile, pipeline_depth) = schedule_of(args)?;
    let opts = PreprocessOptions {
        fraction: args.get_f64("fraction", 0.1)?,
        backend: backend_of(args)?,
        seed,
        knn: knn_of(args)?,
        sim_tile,
        pipeline_depth,
        ..Default::default()
    };
    let store = milo::store::MetaStore::shared(args.get_or("store", "results/store"))?;
    // the key is only re-derived here for the fingerprint/path printout
    let key = milo::store::MetaKey::from_options(ds.name(), &opts);
    let meta =
        MetaSource::store_handle(store.clone(), opts).resolve(Some(&rt), &ds)?;
    Ok((store, key, meta, ds.name().to_string(), seed))
}

fn cmd_precompute(args: &Args, artifacts: &str) -> Result<()> {
    let (store, key, meta, dataset, _) = store_metadata(args, artifacts)?;
    let st = store.stats();
    println!(
        "{} {} -> {} ({} SGE subsets of {}, WRE over {} classes, {})",
        dataset,
        key.fingerprint(),
        store.path_for(&key).display(),
        meta.sge_subsets.len(),
        meta.sge_subsets.first().map(|s| s.len()).unwrap_or(0),
        meta.wre_classes.len(),
        if st.builds > 0 {
            format!("built in {:.2}s", meta.preprocess_secs)
        } else {
            "already in store".to_string()
        },
    );
    Ok(())
}

/// `milo serve`: one event-loop process serving every `dataset × fraction`
/// entry named on the command line, resolved through the content-addressed
/// store. The runtime is optional — entries already precomputed into the
/// store are served without the AOT artifacts; a store miss without a
/// runtime is a clean error naming the missing fingerprint.
fn cmd_serve(args: &Args, artifacts: &str) -> Result<()> {
    let rt = Runtime::open(artifacts).ok();
    let seed = args.get_u64("seed", 1)?;
    let datasets: Vec<String> = match args.get("datasets") {
        Some(_) => args.get_list_str("datasets", &[]),
        None => vec![args
            .get("dataset")
            .ok_or_else(|| anyhow::anyhow!("--dataset or --datasets is required"))?
            .to_string()],
    };
    let fractions: Vec<f64> = match args.get("fractions") {
        Some(_) => args.get_list_f64("fractions", &[])?,
        None => vec![args.get_f64("fraction", 0.1)?],
    };
    let pipeline = if args.flag("featurebased") {
        milo::coordinator::PreprocessPipeline::FeatureBased
    } else {
        milo::coordinator::PreprocessPipeline::Kernel
    };
    let store = milo::store::MetaStore::shared(args.get_or("store", "results/store"))?;
    let mut entries = Vec::new();
    let mut described = Vec::new();
    for name in &datasets {
        let id = DatasetId::from_name(name)?;
        let ds = id.generate(seed);
        for &fraction in &fractions {
            let (sim_tile, pipeline_depth) = schedule_of(args)?;
            let opts = PreprocessOptions {
                fraction,
                backend: backend_of(args)?,
                seed,
                pipeline,
                knn: knn_of(args)?,
                sim_tile,
                pipeline_depth,
                ..Default::default()
            };
            let key = milo::store::MetaKey::from_options(ds.name(), &opts);
            let meta = milo::session::MetaSource::store_handle(store.clone(), opts)
                .resolve(rt.as_ref(), &ds)?;
            described.push(format!("{}@{} ({})", ds.name(), fraction, key.fingerprint()));
            entries.push(meta);
        }
    }
    let addr = args.get_or("addr", "127.0.0.1:4077");
    let opts = milo::serve::ServeOptions {
        metrics_addr: args.get("metrics-addr").map(|s| s.to_string()),
    };
    let server =
        milo::serve::SubsetServer::bind_with(addr, entries, Some(store), seed, opts)?;
    println!(
        "serving {} entr{} (seed {}) on {} — protocol: see `milo::serve` docs",
        described.len(),
        if described.len() == 1 { "y" } else { "ies" },
        seed,
        server.addr(),
    );
    if let Some(m) = server.metrics_addr() {
        println!(
            "  metrics exposition on http://{m}/metrics, flight recorder \
             dump on http://{m}/flight"
        );
    }
    for d in &described {
        println!("  {d}");
    }
    server.run_forever();
    Ok(())
}

/// `milo stream`: the continual-arrival workload end to end. Synthetic
/// embeddings arrive in batches; before each epoch advance the selection
/// fraction is re-pointed at `buffer / n`, so the coreset stays
/// fixed-size while the stream grows (the replay-buffer regime). Each
/// epoch's metadata is re-derived incrementally (dirty classes only —
/// the per-epoch ledger is printed), optionally chained into the store
/// (`--store`: versioned artifact + head record) and pushed to
/// subscribed trainers (`--serve`: EPOCH_ADVANCE + SUBSET_DELTA frames).
fn cmd_stream(args: &Args) -> Result<()> {
    use milo::continual::{ContinualOptions, ContinualSelector};
    let dataset = args.get_or("dataset", "stream").to_string();
    let classes = args.get_usize("classes", 4)?.max(1);
    let dim = args.get_usize("dim", 16)?.max(1);
    let batch = args.get_usize("batch", 64)?.max(1);
    let batches = args.get_usize("batches", 8)?.max(1);
    let buffer = args.get_usize("buffer", 128)?.max(1);
    let seed = args.get_u64("seed", 1)?;
    let mut copts = ContinualOptions::new(&dataset);
    copts.seed = seed;
    copts.knn = knn_of(args)?;
    let store = match args.get("store") {
        Some(root) => Some(milo::store::MetaStore::shared(root)?),
        None => None,
    };

    let mut sel = ContinualSelector::new(copts.clone());
    let mut sched = milo::util::rng::Rng::new(seed).derive_str("arrivals");
    let serve_opts = milo::serve::ServeOptions {
        metrics_addr: args.get("metrics-addr").map(|s| s.to_string()),
    };
    let mut server: Option<milo::serve::SubsetServer> = None;
    let mut chain_key: Option<milo::store::MetaKey> = None;
    for b in 0..batches as u64 {
        let z = milo::testkit::random_embeddings(batch, dim, seed ^ ((b + 1) << 32));
        for i in 0..batch {
            sel.arrive(sched.below(classes), z.row(i))?;
        }
        sel.set_fraction((buffer as f64 / sel.n_train() as f64).min(1.0));
        let (meta, stats) = sel.advance_epoch()?;
        let meta = std::sync::Arc::new(meta);
        println!(
            "epoch {:>3}: n={:<6} k={:<5} dirty {}/{} classes, sge {}/{} wre {} \
             fixed {}, integrate {:.1}ms select {:.1}ms, kernels {} KiB",
            stats.epoch,
            stats.n_train,
            stats.k,
            stats.dirty_classes,
            stats.classes,
            stats.sge_recomputed,
            stats.sge_jobs,
            stats.wre_recomputed,
            stats.fixed_recomputed,
            1e3 * stats.integrate_secs,
            1e3 * stats.select_secs,
            stats.kernel_bytes / 1024,
        );
        if let Some(store) = &store {
            // the chain key is the epoch-1 configuration: the key's
            // fraction is a fingerprint component, so it must stay fixed
            // across the chain even though each epoch's metadata carries
            // the fraction it was actually sized for
            let key = chain_key.get_or_insert_with(|| milo::store::MetaKey {
                dataset: dataset.clone(),
                encoder: "stream".into(),
                sge_function: milo::store::set_function_descriptor(copts.sge_function),
                wre_function: milo::store::set_function_descriptor(copts.wre_function),
                fraction: copts.fraction,
                n_subsets: copts.n_sge_subsets,
                epsilon: copts.epsilon,
                seed,
                metric: format!("{:?}", copts.metric).to_lowercase(),
                backend: "native".into(),
                pipeline: "continual".into(),
                knn: copts.knn,
                epoch: None,
            });
            store.publish_epoch(key, stats.epoch, (*meta).clone())?;
        }
        match (&server, args.get("serve")) {
            (None, Some(addr)) => {
                let s = milo::serve::SubsetServer::bind_with(
                    addr,
                    vec![meta.clone()],
                    store.clone(),
                    seed,
                    serve_opts.clone(),
                )?;
                println!(
                    "serving {dataset} on {} — SUBSCRIBE (frame wire) for live \
                     epoch pushes",
                    s.addr()
                );
                if let Some(m) = s.metrics_addr() {
                    println!(
                        "  metrics exposition on http://{m}/metrics, flight \
                         recorder dump on http://{m}/flight"
                    );
                }
                server = Some(s);
            }
            (Some(s), _) => s.publish(&dataset, stats.epoch, meta.clone())?,
            (None, None) => {}
        }
    }
    if let Some(key) = &chain_key {
        if let Some(store) = &store {
            println!(
                "store chain {} head={:?} epochs={:?}",
                key.fingerprint(),
                store.head_epoch(key)?,
                store.epoch_chain(key)?,
            );
        }
    }
    if let Some(s) = server {
        println!("stream complete — serving the head epoch until killed");
        s.run_forever();
    }
    Ok(())
}

/// `milo trace`: offline rendering of a `MILO_TRACE` sink (or a `GET
/// /flight` dump — same JSON-lines schema). All the reconstruction logic
/// lives in `milo::obs::traceview`, where it is unit-tested.
fn cmd_trace(args: &Args) -> Result<()> {
    let path = match args.positional.get(1) {
        Some(p) => p.as_str(),
        None => bail!(
            "milo trace needs a file: `milo trace trace.jsonl` (a MILO_TRACE \
             sink or a /flight dump)\n{USAGE}"
        ),
    };
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read trace file {path}: {e}"))?;
    let max_traces = args.get_usize("traces", 10)?.max(1);
    print!("{}", milo::obs::traceview::report(&text, max_traces));
    Ok(())
}

fn cmd_train(args: &Args, artifacts: &str) -> Result<()> {
    let rt = Runtime::open(artifacts)?;
    let (id, seed) = dataset_of(args)?;
    let ds = id.generate(seed);
    let kind = strategy_of(args)?;
    let fraction = args.get_f64("fraction", 0.1)?;
    let epochs = args.get_usize("epochs", 40)?;
    let mut runner = milo::coordinator::ExperimentRunner::new(&rt, &ds, epochs);
    runner.backend = backend_of(args)?;
    runner.verbose = args.flag("verbose");
    runner.r_expensive = args.get_usize("r", runner.r_expensive)?;
    let full = runner.run_full(seed)?;
    let rec = runner.run_cell(kind, fraction, seed, &full)?;
    println!(
        "{} {} f={fraction} seed={seed}: test acc {:.2}% (full {:.2}%), \
         time {:.2}s (full {:.2}s) -> speedup {:.2}x, degradation {:.2}%",
        ds.name(),
        kind.name(),
        100.0 * rec.outcome.test_accuracy,
        100.0 * rec.full_acc,
        rec.outcome.train_secs,
        rec.full_secs,
        rec.speedup(),
        rec.degradation_pct(),
    );
    Ok(())
}

fn cmd_tune(args: &Args, artifacts: &str) -> Result<()> {
    let rt = Runtime::open(artifacts)?;
    let (id, seed) = dataset_of(args)?;
    let ds = id.generate(seed);
    let algo = match args.get_or("algo", "random") {
        "random" => SearchAlgo::Random,
        "tpe" => SearchAlgo::Tpe,
        other => bail!("unknown search algo {other:?}"),
    };
    let kind = strategy_of(args)?;
    let cfg = HpoConfig {
        algo,
        strategy: kind,
        fraction: args.get_f64("fraction", 0.1)?,
        max_epochs: args.get_usize("max-epochs", 27)?,
        eta: args.get_usize("eta", 3)?,
        seed,
    };
    let fraction = cfg.fraction;
    let mut tuner = Tuner::new(&rt, &ds, cfg);
    tuner.source = args
        .get("server")
        .map(|addr| MetaSource::remote_expecting(addr, seed, fraction));
    tuner.verbose = args.flag("verbose");
    let out = tuner.run()?;
    println!(
        "tuned {} with {}/{}: best val {:.2}%, test {:.2}%, {} trials, {:.2}s",
        ds.name(),
        algo.name(),
        kind.name(),
        100.0 * out.best.val_accuracy,
        100.0 * out.best_test_accuracy,
        out.trials.len(),
        out.tuning_secs,
    );
    println!("best config: {:?}", out.best.config);
    Ok(())
}

fn cmd_repro(args: &Args, artifacts: &str) -> Result<()> {
    let rt = Runtime::open(artifacts)?;
    let mut opts = ReproOptions {
        epochs: args.get_usize("epochs", 40)?,
        seeds: args
            .get_list_f64("seeds", &[1.0])?
            .into_iter()
            .map(|s| s as u64)
            .collect(),
        fractions: args.get_list_f64("fractions", &[0.01, 0.05, 0.1, 0.3])?,
        out_dir: args.get_or("out", "results").into(),
        backend: backend_of(args)?,
        strategies: strategies_of(args)?,
        verbose: !args.flag("quiet"),
    };
    let mut experiments: Vec<String> = args.positional[1..].to_vec();
    if experiments.is_empty() {
        bail!("repro needs at least one experiment\n{USAGE}");
    }
    if experiments.iter().any(|e| e == "all") {
        experiments = [
            "fig1", "fig2", "fig4", "fig5a", "fig5b", "fig6", "fig6gh", "fig7",
            "fig9", "fig11", "fig12", "fig13", "fig14", "el2n", "kendall",
            "simmetric", "kappa", "rsweep", "wrevariant", "sslprune", "proxy",
            "preptime",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
    }
    for exp in &experiments {
        eprintln!(
            "=== repro {exp} (epochs={}, seeds={:?}) ===",
            opts.epochs, opts.seeds
        );
        let t0 = std::time::Instant::now();
        let tables = match exp.as_str() {
            "fig1" => repro::fig1_convergence(&rt, &opts)?,
            "fig2" => repro::fig2_summary(&rt, &opts)?,
            "fig4" => repro::fig4_setfunctions(&rt, &opts)?,
            "fig5a" => repro::fig5a_sge_wre(&rt, &opts)?,
            "fig5b" => repro::fig5b_early_convergence(&rt, &opts)?,
            "fig6" => {
                let datasets = args
                    .get_list_str(
                        "datasets",
                        &["cifar10", "cifar100", "trec6", "rotten", "glyphs"],
                    )
                    .iter()
                    .map(|n| DatasetId::from_name(n))
                    .collect::<Result<Vec<_>>>()?;
                repro::fig6_tradeoff(&rt, &opts, &datasets)?
            }
            "fig6gh" => repro::fig6gh_convergence(&rt, &opts)?,
            "fig7" => repro::fig7_hpo(&rt, &opts)?,
            "fig9" => repro::fig9_specialized(&rt, &opts)?,
            "fig11" => repro::fig11_encoders(&rt, &opts)?,
            "fig12" => repro::fig12_sge_gc_vs_fl(&rt, &opts)?,
            "fig13" => repro::fig13_sge_vs_wre_gc(&rt, &opts)?,
            "fig14" => repro::fig14_curriculum_convergence(&rt, &opts)?,
            "el2n" => repro::table_el2n(&rt, &opts)?,
            "kendall" => {
                repro::table_kendall(&rt, &opts, args.get_usize("configs", 108)?)?
            }
            "simmetric" => repro::table_simmetric(&rt, &opts)?,
            "kappa" => repro::table_kappa(&rt, &opts)?,
            "rsweep" => repro::table_r(&rt, &opts)?,
            "wrevariant" => repro::table_wre_variant(&rt, &opts)?,
            "sslprune" => repro::table_ssl_prune(&rt, &opts)?,
            "proxy" => repro::proxy_encoder(&rt, &opts)?,
            "preptime" => repro::preprocess_time(&rt, &opts)?,
            "gibbs" => repro::ext_gibbs(&rt, &opts)?,
            "featspace" => repro::ext_featurebased(&rt, &opts)?,
            "quick" => {
                opts.epochs = opts.epochs.min(10);
                opts.fractions = vec![0.05, 0.3];
                let mut all = repro::fig4_setfunctions(&rt, &opts)?;
                all.extend(repro::fig5b_early_convergence(&rt, &opts)?);
                all.extend(repro::table_el2n(&rt, &opts)?);
                all
            }
            other => bail!("unknown experiment {other:?}\n{USAGE}"),
        };
        for t in &tables {
            println!("{}", t.to_markdown());
        }
        eprintln!("=== {exp} done in {:.1}s ===", t0.elapsed().as_secs_f64());
    }
    Ok(())
}
