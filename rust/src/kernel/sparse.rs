//! Sparse top-`knn` similarity kernels: CSR class blocks built blockwise
//! from embeddings, without ever materializing the dense `n_c × n_c`
//! matrix.
//!
//! # Layout
//!
//! [`SparseKernel`] is standard CSR over a square `n × n` kernel:
//! `row_ptr[j]..row_ptr[j+1]` indexes parallel `cols`/`vals` slices
//! holding row `j`'s stored entries, columns sorted ascending. Memory is
//! `n·r̄` floats (plus `u32` columns) for an average stored row of `r̄`
//! entries, versus `n²` for a dense block — at `knn ≪ n_c` that is the
//! `n_c·knn` vs `n_c²` saving the selection bench (`BENCH_select.json`)
//! tracks, and it shrinks every artifact the store/serve layers ship.
//!
//! # Construction
//!
//! [`build_sparse_kernel`] streams `STRIP_ROWS × n` (native) or
//! `sim_tile × n` (PJRT) row strips of the similarity matrix, keeps each
//! row's `knn` largest similarities (the self-loop is always kept, and
//! ties break toward the smaller column so construction is fully
//! deterministic), and then **symmetrizes by union**: whenever `(i, j)`
//! is kept, `(j, i)` is stored too with the same value. Stored rows
//! therefore hold between `knn` and `n` entries; the kernel stays
//! symmetric, which every gain oracle in [`crate::submod`] relies on.
//!
//! Peak construction memory is one strip plus the kept entries — the
//! dense block never exists, for either backend.
//!
//! Both backends run their strips through the overlapped
//! [`super::pipeline`]: strip `t + 1`'s similarity execution (PJRT
//! artifact call or native block matmul) overlaps strip `t`'s host-side
//! top-`knn` reduction, controlled by a [`KernelSchedule`]. The single
//! in-order consumer preserves every accumulation order the serial build
//! uses (the dot-metric min fold, the RBF f64 mean), so pipelined output
//! is **bit-identical** to `depth = 1` — `rust/tests/kernel_pipeline.rs`
//! sweeps the property. When the manifest carries a fused
//! `topk_{metric}_e{E}` artifact (similarity + per-tile top-`K` in one
//! execution), the PJRT path additionally moves the cut on-device and
//! transfers only `(cols, vals)` candidates — `≈ 2K/tile` of the strip
//! bytes — falling back to host top-k when the artifact is absent or
//! `knn > K`. Candidate unions are re-reduced on the host with the exact
//! `row_topk` comparator, so the device cut changes transfer volume,
//! never values.
//!
//! # Semantics: when sparse changes selections
//!
//! An unstored pair has similarity exactly `0.0` (distance `1.0`), so
//! for `knn < n_c` the sparse kernel is an **approximation**: facility
//! location / graph-cut gains ignore weak similarities below the top-k
//! cut, and the disparity functions saturate far pairs at distance 1.
//! Selections can (and usually do) differ from the dense kernel's — this
//! is the standard sparsification trade of the CRAIG line of work, and
//! the property suite in `rust/tests/sparse_selection.rs` bounds it from
//! the other side: with `knn ≥ n_c` every row is complete, the per-entry
//! f32 operations happen in exactly the dense order, and selections are
//! **bit-for-bit identical** to the dense path for every set function ×
//! greedy mode.

use std::cmp::Ordering;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{Arg, Runtime};
use crate::tensor::Matrix;
use crate::util::math::round_up;

use super::pipeline::{run_pipeline, KernelSchedule, PipelineStats};
use super::{SimMetric, SimilarityBackend};

/// Rows per native construction strip: large enough to amortize the
/// block matmul, small enough that a strip (`STRIP_ROWS × n_c` floats)
/// stays cache-resident for class-partition sizes.
pub(crate) const STRIP_ROWS: usize = 128;

/// CSR top-`knn` similarity kernel. See the [module docs](self) for the
/// layout and construction contract.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseKernel {
    n: usize,
    row_ptr: Vec<usize>,
    cols: Vec<u32>,
    vals: Vec<f32>,
}

impl SparseKernel {
    /// Ground-set size (the kernel is `n × n`).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Stored entries.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Whether every pair is stored (`knn ≥ n` construction): complete
    /// kernels reproduce dense gains bit-for-bit.
    pub fn is_complete(&self) -> bool {
        self.nnz() == self.n * self.n
    }

    /// Actual resident bytes: values + `u32` columns + the row index.
    pub fn memory_bytes(&self) -> usize {
        self.vals.len() * std::mem::size_of::<f32>()
            + self.cols.len() * std::mem::size_of::<u32>()
            + self.row_ptr.len() * std::mem::size_of::<usize>()
    }

    /// Row `j` as parallel `(cols, vals)` slices, columns ascending.
    pub fn row(&self, j: usize) -> (&[u32], &[f32]) {
        let (lo, hi) = (self.row_ptr[j], self.row_ptr[j + 1]);
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// `s[i, j]`, `0.0` when the pair is not stored.
    pub fn at(&self, i: usize, j: usize) -> f32 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(j as u32)) {
            Ok(p) => vals[p],
            Err(_) => 0.0,
        }
    }

    /// Sparsify an existing dense kernel: per-row top-`knn` (self-loop
    /// kept, smaller-column tie-break), symmetrized by union. Values are
    /// copied as-is — used by tests and by consumers that already hold a
    /// dense block.
    pub fn from_dense(m: &Matrix, knn: usize) -> SparseKernel {
        assert_eq!(m.rows, m.cols, "kernel must be square");
        let n = m.rows;
        let knn = knn.max(1);
        let rows: Vec<Vec<(u32, f32)>> =
            (0..n).map(|i| row_topk(m.row(i), i, knn)).collect();
        symmetrize(n, rows)
    }
}

/// Reusable workspace for [`row_topk_into`]: the candidate-column index
/// buffer, grown once and reused across every row of a build instead of
/// allocating a fresh `Vec` per call.
#[derive(Default)]
pub(crate) struct TopkScratch {
    idx: Vec<u32>,
}

impl TopkScratch {
    pub(crate) fn new() -> TopkScratch {
        TopkScratch::default()
    }
}

/// Keep row `i`'s `knn` largest scores. The self-loop (`diag == i`) is
/// always kept; among the rest, ties break toward the smaller column so
/// the result is a deterministic function of the scores. Returned
/// entries are sorted by column. Selection is a `select_nth_unstable_by`
/// partial partition over `scratch`'s reused index buffer — the only
/// allocation is the returned row itself.
pub(crate) fn row_topk_into(
    scores: &[f32],
    diag: usize,
    knn: usize,
    scratch: &mut TopkScratch,
) -> Vec<(u32, f32)> {
    let n = scores.len();
    debug_assert!(diag < n && knn >= 1);
    if knn >= n {
        return scores.iter().enumerate().map(|(c, &v)| (c as u32, v)).collect();
    }
    let idx = &mut scratch.idx;
    idx.clear();
    idx.extend((0..n as u32).filter(|&c| c as usize != diag));
    let keep = knn - 1; // the diagonal occupies one of the knn slots
    let by_score_then_col = |a: &u32, b: &u32| {
        let (sa, sb) = (scores[*a as usize], scores[*b as usize]);
        sb.partial_cmp(&sa).unwrap_or(Ordering::Equal).then(a.cmp(b))
    };
    if keep == 0 {
        idx.clear();
    } else {
        // knn < n ⇒ keep ≤ n − 2 < idx.len(), so the partition is valid
        idx.select_nth_unstable_by(keep - 1, by_score_then_col);
        idx.truncate(keep);
    }
    idx.push(diag as u32);
    idx.sort_unstable();
    idx.iter().map(|&c| (c, scores[c as usize])).collect()
}

/// [`row_topk_into`] with a one-shot scratch, for callers outside the
/// strip loops (dense sparsification, incremental re-top-k).
pub(crate) fn row_topk(scores: &[f32], diag: usize, knn: usize) -> Vec<(u32, f32)> {
    row_topk_into(scores, diag, knn, &mut TopkScratch::new())
}

/// Union-symmetrize per-row kept lists (each sorted by column) and pack
/// them into CSR: whenever `(i, j)` was kept, `(j, i)` is stored with
/// the same value (similarities are symmetric, so copying the value is
/// exact — and it *enforces* symmetry for backends whose float results
/// are only symmetric to tolerance).
pub(crate) fn symmetrize(n: usize, mut rows: Vec<Vec<(u32, f32)>>) -> SparseKernel {
    let mut mirrors: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n];
    for i in 0..n {
        for &(j, v) in &rows[i] {
            let j = j as usize;
            if j == i {
                continue;
            }
            if rows[j].binary_search_by_key(&(i as u32), |e| e.0).is_err() {
                mirrors[j].push((i as u32, v));
            }
        }
    }
    for (row, mut extra) in rows.iter_mut().zip(mirrors) {
        if extra.is_empty() {
            continue;
        }
        row.append(&mut extra);
        row.sort_unstable_by_key(|e| e.0);
    }
    let nnz: usize = rows.iter().map(|r| r.len()).sum();
    let mut row_ptr = Vec::with_capacity(n + 1);
    let mut cols = Vec::with_capacity(nnz);
    let mut vals = Vec::with_capacity(nnz);
    row_ptr.push(0);
    for row in rows {
        for (c, v) in row {
            cols.push(c);
            vals.push(v);
        }
        row_ptr.push(cols.len());
    }
    SparseKernel { n, row_ptr, cols, vals }
}

/// Pack per-row top-`knn` kept lists (exact [`row_topk`] outputs over
/// the full score rows) into a finished kernel: union-symmetrize, then
/// apply the dot-metric non-negativity shift when `min < 0.0`. This is
/// precisely the tail of [`sparse_native`]'s Cosine/Dot paths (pass
/// `min = 0.0` for cosine), factored out so the continual-arrival layer
/// ([`crate::continual`]) can publish incrementally maintained rows with
/// bit-identical results to a from-scratch build.
pub(crate) fn kernel_from_topk(n: usize, rows: Vec<Vec<(u32, f32)>>, min: f32) -> SparseKernel {
    let mut kernel = symmetrize(n, rows);
    if min < 0.0 {
        for v in kernel.vals.iter_mut() {
            *v -= min;
        }
    }
    kernel
}

/// Build a sparse top-`knn` kernel over `z` (`n × e` embeddings) under
/// `metric`, via the requested similarity backend. `knn` is clamped to
/// `[1, n]`; `knn ≥ n` yields a complete kernel whose gains are
/// bit-identical to the dense path's.
pub fn build_sparse_kernel(
    runtime: Option<&Runtime>,
    z: &Matrix,
    metric: SimMetric,
    backend: SimilarityBackend,
    knn: usize,
) -> Result<SparseKernel> {
    match backend {
        SimilarityBackend::Native => Ok(sparse_native(z, metric, knn)),
        SimilarityBackend::Pjrt => {
            let rt = runtime.ok_or_else(|| {
                anyhow::anyhow!("Pjrt backend requires a Runtime")
            })?;
            sparse_pjrt(rt, z, metric, knn)
        }
    }
}

/// `r1 − r0` contiguous rows of `src` as their own matrix (the strip
/// operand for the blockwise matmul).
pub(crate) fn block_rows(src: &Matrix, r0: usize, r1: usize) -> Matrix {
    Matrix::from_vec(r1 - r0, src.cols, src.data()[r0 * src.cols..r1 * src.cols].to_vec())
        .expect("block rows dims are consistent by construction")
}

/// Native blockwise construction under the default (double-buffered)
/// schedule. Per-entry f32 values are computed by the exact operations
/// [`super::native_similarity`] performs (same normalized operands, same
/// strip matmul, same per-entry transform), so a complete (`knn ≥ n`)
/// sparse kernel holds the exact dense values.
pub fn sparse_native(z: &Matrix, metric: SimMetric, knn: usize) -> SparseKernel {
    sparse_native_scheduled(z, metric, knn, &KernelSchedule::default())
        .expect("native kernel build failed")
        .0
}

/// [`sparse_native`] under an explicit [`KernelSchedule`]: the strip
/// matmul (produce) overlaps the previous strip's top-`knn` reduction
/// (consume) through [`run_pipeline`]. Every per-entry value and every
/// accumulation order (the dot min fold over whole strips in strip
/// order, the RBF f64 mean in dense row-major order) matches the serial
/// build exactly — output is bit-identical for any `strip_rows`/`depth`.
pub fn sparse_native_scheduled(
    z: &Matrix,
    metric: SimMetric,
    knn: usize,
    sched: &KernelSchedule,
) -> Result<(SparseKernel, PipelineStats)> {
    let n = z.rows;
    if n == 0 {
        let empty = SparseKernel { n: 0, row_ptr: vec![0], cols: Vec::new(), vals: Vec::new() };
        return Ok((empty, PipelineStats::default()));
    }
    let knn = knn.clamp(1, n);
    let strip_h = sched.strip_rows.unwrap_or(STRIP_ROWS).max(1);
    let strips = n.div_ceil(strip_h);
    let bounds = |t: usize| (t * strip_h, (t * strip_h + strip_h).min(n));
    match metric {
        SimMetric::Cosine => {
            let mut zn = z.clone();
            zn.l2_normalize_rows();
            let zn = &zn;
            let ((rows, _), stats) = run_pipeline(
                strips,
                sched.depth,
                (Vec::with_capacity(n), TopkScratch::new()),
                |t| {
                    let (at, hi) = bounds(t);
                    let mut strip = block_rows(zn, at, hi).matmul_nt(zn);
                    for v in strip.data_mut().iter_mut() {
                        *v = 0.5 + 0.5 * *v;
                    }
                    Ok(strip)
                },
                |(rows, scratch): &mut (Vec<Vec<(u32, f32)>>, TopkScratch), t, strip| {
                    let (at, hi) = bounds(t);
                    for r in 0..(hi - at) {
                        rows.push(row_topk_into(strip.row(r), at + r, knn, scratch));
                    }
                },
            )?;
            Ok((kernel_from_topk(n, rows, 0.0), stats))
        }
        SimMetric::Dot => {
            struct DotState {
                rows: Vec<Vec<(u32, f32)>>,
                min: f32,
                scratch: TopkScratch,
            }
            let (st, stats) = run_pipeline(
                strips,
                sched.depth,
                DotState {
                    rows: Vec::with_capacity(n),
                    min: f32::MAX,
                    scratch: TopkScratch::new(),
                },
                |t| {
                    let (at, hi) = bounds(t);
                    Ok(block_rows(z, at, hi).matmul_nt(z))
                },
                |st: &mut DotState, t, strip| {
                    let (at, hi) = bounds(t);
                    st.min = strip.data().iter().cloned().fold(st.min, f32::min);
                    for r in 0..(hi - at) {
                        st.rows.push(row_topk_into(strip.row(r), at + r, knn, &mut st.scratch));
                    }
                },
            )?;
            // additive shift to non-negativity (paper I.2). The shift is
            // monotone, so applying it after top-k selection keeps the
            // kept set identical to selecting on shifted values.
            Ok((kernel_from_topk(n, st.rows, st.min), stats))
        }
        SimMetric::Rbf { kw } => {
            // One pass over squared-distance strips: keep each row's knn
            // *smallest* d² (similarity is monotone-decreasing in d²)
            // while accumulating the matrix mean. The single in-order
            // consumer folds rows in dense row-major order, so the f64
            // mean — and hence gamma — matches the dense
            // parameterization exactly.
            let mut sq = vec![0.0f32; n];
            for (i, s) in sq.iter_mut().enumerate() {
                *s = z.row(i).iter().map(|v| v * v).sum();
            }
            let sq = &sq;
            struct RbfState {
                rows: Vec<Vec<(u32, f32)>>,
                sum: f64,
                // one reused buffer of negated d² scores (smallest d² =
                // largest similarity) — no per-row allocation
                neg: Vec<f32>,
                scratch: TopkScratch,
            }
            let (st, stats) = run_pipeline(
                strips,
                sched.depth,
                RbfState {
                    rows: Vec::with_capacity(n),
                    sum: 0.0,
                    neg: vec![0.0f32; n],
                    scratch: TopkScratch::new(),
                },
                |t| {
                    let (at, hi) = bounds(t);
                    Ok(block_rows(z, at, hi).matmul_nt(z))
                },
                |st: &mut RbfState, t, strip| {
                    let (at, hi) = bounds(t);
                    for r in 0..(hi - at) {
                        let i = at + r;
                        let dots = strip.row(r);
                        for j in 0..n {
                            let v = (sq[i] + sq[j] - 2.0 * dots[j]).max(0.0);
                            st.neg[j] = -v;
                            st.sum += v as f64;
                        }
                        let mut kept = row_topk_into(&st.neg, i, knn, &mut st.scratch);
                        for e in kept.iter_mut() {
                            e.1 = -e.1;
                        }
                        st.rows.push(kept);
                    }
                },
            )?;
            let mean = (st.sum / (n * n) as f64).max(1e-12);
            let gamma = (1.0 / (kw * mean)) as f32;
            let mut kernel = symmetrize(n, st.rows);
            for v in kernel.vals.iter_mut() {
                *v = (-gamma * *v).exp();
            }
            Ok((kernel, stats))
        }
    }
}

/// Dense row-major mean of the pairwise squared distances, accumulated
/// blockwise — the exact value (same per-entry f32 arithmetic, same f64
/// summation order) `pairwise_sq_dists(z).mean()` produces, without the
/// `n × n` matrix.
fn mean_sq_dist_blockwise(z: &Matrix) -> f64 {
    let n = z.rows;
    if n == 0 {
        return 0.0;
    }
    let mut sq = vec![0.0f32; n];
    for (i, s) in sq.iter_mut().enumerate() {
        *s = z.row(i).iter().map(|v| v * v).sum();
    }
    let mut sum = 0.0f64;
    let mut at = 0;
    while at < n {
        let hi = (at + STRIP_ROWS).min(n);
        let block = block_rows(z, at, hi);
        let strip = block.matmul_nt(z);
        for r in 0..(hi - at) {
            let i = at + r;
            let dots = strip.row(r);
            for j in 0..n {
                sum += (sq[i] + sq[j] - 2.0 * dots[j]).max(0.0) as f64;
            }
        }
        at = hi;
    }
    sum / (n * n) as f64
}

/// PJRT blockwise construction under the default (double-buffered)
/// schedule: one `sim_tile × n` strip at a time through the Pallas
/// similarity artifact (the same tile calls [`super::pjrt_similarity`]
/// makes, minus the `n × n` assembly). RBF gamma is derived blockwise
/// natively so it matches the dense PJRT path's parameterization
/// exactly.
pub fn sparse_pjrt(
    rt: &Runtime,
    z: &Matrix,
    metric: SimMetric,
    knn: usize,
) -> Result<SparseKernel> {
    Ok(sparse_pjrt_scheduled(rt, z, metric, knn, &KernelSchedule::default())?.0)
}

/// [`sparse_pjrt`] under an explicit [`KernelSchedule`]: artifact
/// execution for strip `t + 1` overlaps strip `t`'s host-side reduction.
/// When the manifest carries a `topk_{metric}_e{E}` artifact wide enough
/// for `knn`, the top-`K` cut runs on-device and only candidate
/// `(cols, vals)` rows come back; otherwise full similarity strips are
/// reduced on the host. Both paths produce the same kernel.
pub fn sparse_pjrt_scheduled(
    rt: &Runtime,
    z: &Matrix,
    metric: SimMetric,
    knn: usize,
    sched: &KernelSchedule,
) -> Result<(SparseKernel, PipelineStats)> {
    let n = z.rows;
    if n == 0 {
        let empty = SparseKernel { n: 0, row_ptr: vec![0], cols: Vec::new(), vals: Vec::new() };
        return Ok((empty, PipelineStats::default()));
    }
    let knn = knn.clamp(1, n);
    let e = z.cols;
    let base = match metric {
        SimMetric::Cosine => "cosine",
        SimMetric::Dot => "dot",
        SimMetric::Rbf { .. } => "rbf",
    };
    let gamma = match metric {
        SimMetric::Rbf { kw } => {
            Some((1.0 / (kw * mean_sq_dist_blockwise(z).max(1e-12))) as f32)
        }
        _ => None,
    };
    let dot = matches!(metric, SimMetric::Dot);

    // On-device top-k when the fused artifact exists and is wide enough:
    // `knn ≤ K` guarantees each tile's top-K contains every member of
    // the row's global top-knn that lives in that tile (fewer than knn
    // entries precede it in the strict score-then-column order, so fewer
    // than knn ≤ K precede it within its own tile). Absent or too
    // narrow, fall back to host top-k transparently.
    let topk_name = format!("topk_{base}_e{e}");
    if let Some(k) = rt.manifest().artifacts.get(&topk_name).and_then(|a| a.k) {
        if knn <= k {
            let tile = rt
                .manifest()
                .artifacts
                .get(&topk_name)
                .and_then(|a| a.tile)
                .unwrap_or(rt.manifest().sim_tile);
            let spec = DeviceTopkSpec { artifact: &topk_name, k, tile, gamma, dot_shift: dot };
            return device_topk_build(rt, z, knn, &spec, sched.depth);
        }
    }

    // host top-k over full similarity strips
    let tile = rt.manifest().sim_tile;
    let np = round_up(n, tile);
    let mut zp = Matrix::zeros(np, e);
    zp.write_rows(0, z);
    let zp = &zp;
    let tiles = np / tile;
    let artifact = format!("sim_{base}_e{e}");
    let artifact = &artifact;

    struct HostState {
        rows: Vec<Vec<(u32, f32)>>,
        min: f32,
        scratch: TopkScratch,
    }
    let (st, stats) = run_pipeline(
        tiles,
        sched.depth,
        HostState { rows: Vec::with_capacity(n), min: f32::MAX, scratch: TopkScratch::new() },
        |bi| {
            let a = Matrix::from_vec(
                tile,
                e,
                zp.data()[bi * tile * e..(bi + 1) * tile * e].to_vec(),
            )?;
            let mut strip = vec![0.0f32; tile * np];
            for bj in 0..tiles {
                let b = Matrix::from_vec(
                    tile,
                    e,
                    zp.data()[bj * tile * e..(bj + 1) * tile * e].to_vec(),
                )?;
                let res = match gamma {
                    Some(g) => rt.execute(
                        artifact,
                        &[Arg::F32(a.data()), Arg::F32(b.data()), Arg::F32(&[g])],
                    )?,
                    None => {
                        rt.execute(artifact, &[Arg::F32(a.data()), Arg::F32(b.data())])?
                    }
                };
                let block = &res[0];
                for r in 0..tile {
                    strip[r * np + bj * tile..r * np + (bj + 1) * tile]
                        .copy_from_slice(&block[r * tile..(r + 1) * tile]);
                }
            }
            Ok(strip)
        },
        |st: &mut HostState, bi, strip: Vec<f32>| {
            for r in 0..tile {
                let i = bi * tile + r;
                if i >= n {
                    break;
                }
                // crop padded columns before selection — padded
                // rows/cols must never become edges
                let srow = &strip[r * np..r * np + n];
                if dot {
                    st.min = srow.iter().cloned().fold(st.min, f32::min);
                }
                st.rows.push(row_topk_into(srow, i, knn, &mut st.scratch));
            }
        },
    )?;
    let mut kernel = symmetrize(n, st.rows);
    // dot metric: shift after selection (monotone) over the cropped
    // min, matching the dense PJRT path
    if dot && st.min < 0.0 {
        for v in kernel.vals.iter_mut() {
            *v -= st.min;
        }
    }
    Ok((kernel, stats))
}

/// Parameters of one fused similarity → per-tile top-`K` artifact
/// execution (`topk_{metric}_e{E}` over embeddings, or
/// `embed_sim_topk_{ds}` over raw feature rows).
struct DeviceTopkSpec<'a> {
    artifact: &'a str,
    /// Per-tile candidate width `K` baked into the artifact.
    k: usize,
    /// Tile rows baked into the artifact.
    tile: usize,
    /// RBF gamma (passed as the artifact's fourth input when set).
    gamma: Option<f32>,
    /// Apply the dot-metric non-negativity shift from the device row
    /// minima.
    dot_shift: bool,
}

/// One produced strip of the on-device top-k path: per-`bj` candidate
/// `(vals, cols)` pairs instead of the full `tile × n` similarity strip
/// (`≈ 2K/tile` of the bytes).
struct TopkStrip {
    /// Per `bj` tile: parallel `(vals, cols)` buffers, each `tile · K`
    /// long, row-major; `cols` holds tile-local indices as exact f32s.
    tiles: Vec<(Vec<f32>, Vec<f32>)>,
    /// Tile diagonal from the `bi == bj` execution — the self-loop
    /// values, which a dot-metric top-K may otherwise drop.
    diag: Vec<f32>,
    /// Per `bj` row minima over valid columns (`[bj · tile + r]`), used
    /// only for the dot shift.
    rowmin: Vec<f32>,
}

/// Run the pipelined on-device top-k build: produce executes the fused
/// artifact per `(bi, bj)` tile pair; consume merges each row's per-tile
/// candidates (plus the diagonal) with the exact [`row_topk`] comparator
/// — so device selection changes transfer volume, never values.
fn device_topk_build(
    rt: &Runtime,
    src: &Matrix,
    knn: usize,
    spec: &DeviceTopkSpec<'_>,
    depth: usize,
) -> Result<(SparseKernel, PipelineStats)> {
    let n = src.rows;
    let d = src.cols;
    let (tile, k) = (spec.tile, spec.k);
    let np = round_up(n, tile);
    let mut zp = Matrix::zeros(np, d);
    zp.write_rows(0, src);
    let zp = &zp;
    let tiles = np / tile;

    struct MergeState {
        rows: Vec<Vec<(u32, f32)>>,
        min: f32,
        cand: Vec<(u32, f32)>,
    }
    let (st, stats) = run_pipeline(
        tiles,
        depth,
        MergeState { rows: Vec::with_capacity(n), min: f32::MAX, cand: Vec::new() },
        |bi| {
            let a = Matrix::from_vec(
                tile,
                d,
                zp.data()[bi * tile * d..(bi + 1) * tile * d].to_vec(),
            )?;
            let mut out = TopkStrip {
                tiles: Vec::with_capacity(tiles),
                diag: Vec::new(),
                rowmin: Vec::with_capacity(tiles * tile),
            };
            for bj in 0..tiles {
                let b = Matrix::from_vec(
                    tile,
                    d,
                    zp.data()[bj * tile * d..(bj + 1) * tile * d].to_vec(),
                )?;
                // columns ≥ valid are padding: masked to −inf before the
                // device cut so they can never be candidates
                let valid = [(n - bj * tile).min(tile) as f32];
                let gamma_buf = [spec.gamma.unwrap_or(0.0)];
                let mut args = vec![Arg::F32(a.data()), Arg::F32(b.data()), Arg::F32(&valid)];
                if spec.gamma.is_some() {
                    args.push(Arg::F32(&gamma_buf));
                }
                let mut res = rt.execute(spec.artifact, &args)?;
                if res.len() != 4 {
                    bail!(
                        "artifact {} returned {} outputs, expected (vals, cols, diag, rowmin)",
                        spec.artifact,
                        res.len()
                    );
                }
                let rowmin = res.pop().unwrap();
                let dg = res.pop().unwrap();
                let cols = res.pop().unwrap();
                let vals = res.pop().unwrap();
                if vals.len() != tile * k
                    || cols.len() != tile * k
                    || dg.len() != tile
                    || rowmin.len() != tile
                {
                    bail!("artifact {} output shapes unexpected", spec.artifact);
                }
                if bj == bi {
                    out.diag = dg;
                }
                out.rowmin.extend_from_slice(&rowmin);
                out.tiles.push((vals, cols));
            }
            Ok(out)
        },
        |st: &mut MergeState, bi, strip: TopkStrip| {
            for r in 0..tile {
                let i = bi * tile + r;
                if i >= n {
                    break;
                }
                if spec.dot_shift {
                    // per-row device minima over valid columns reproduce
                    // the serial full-strip fold (f32 min is
                    // order-insensitive)
                    for bj in 0..tiles {
                        st.min = st.min.min(strip.rowmin[bj * tile + r]);
                    }
                }
                st.cand.clear();
                for (bj, (vals, cols)) in strip.tiles.iter().enumerate() {
                    let at = r * k;
                    for s in 0..k {
                        // masked candidates decode to columns ≥ n and
                        // drop here, as does the diagonal (re-added from
                        // the device diag output below)
                        let c = bj * tile + cols[at + s] as usize;
                        if c >= n || c == i {
                            continue;
                        }
                        st.cand.push((c as u32, vals[at + s]));
                    }
                }
                let keep = knn - 1;
                if keep == 0 {
                    st.cand.clear();
                } else if st.cand.len() > keep {
                    st.cand.select_nth_unstable_by(keep - 1, |a, b| {
                        b.1.partial_cmp(&a.1).unwrap_or(Ordering::Equal).then(a.0.cmp(&b.0))
                    });
                    st.cand.truncate(keep);
                }
                st.cand.push((i as u32, strip.diag[r]));
                st.cand.sort_unstable_by_key(|e| e.0);
                st.rows.push(st.cand.clone());
            }
        },
    )?;
    let mut kernel = symmetrize(n, st.rows);
    if spec.dot_shift && st.min < 0.0 {
        for v in kernel.vals.iter_mut() {
            *v -= st.min;
        }
    }
    Ok((kernel, stats))
}

/// Build a class block's sparse kernel straight from **raw feature
/// rows** through a fused `embed_sim_topk_{ds}` artifact — embedding →
/// cosine similarity → per-tile top-`K` collapsed into one execution per
/// tile pair. Requires `knn ≤ K`; callers gate on the artifact's `k`
/// meta and fall back to the encode-then-kernel path otherwise.
pub fn sparse_fused_pjrt(
    rt: &Runtime,
    features: &Matrix,
    artifact: &str,
    knn: usize,
    sched: &KernelSchedule,
) -> Result<(SparseKernel, PipelineStats)> {
    let n = features.rows;
    if n == 0 {
        let empty = SparseKernel { n: 0, row_ptr: vec![0], cols: Vec::new(), vals: Vec::new() };
        return Ok((empty, PipelineStats::default()));
    }
    let entry = rt.manifest().artifact(artifact)?;
    let k = entry
        .k
        .ok_or_else(|| anyhow!("artifact {artifact} lacks a top-k width (`k`) meta"))?;
    let knn = knn.clamp(1, n);
    if knn > k {
        bail!("fused artifact {artifact} is top-{k}: too narrow for knn={knn}");
    }
    let tile = entry.tile.unwrap_or(rt.manifest().sim_tile);
    let spec = DeviceTopkSpec { artifact, k, tile, gamma: None, dot_shift: false };
    device_topk_build(rt, features, knn, &spec, sched.depth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::native_similarity;
    use crate::testkit::{random_embeddings, random_kernel};

    fn assert_valid(k: &SparseKernel, knn: usize) {
        let n = k.n();
        assert_eq!(k.row_ptr.len(), n + 1);
        for j in 0..n {
            let (cols, vals) = k.row(j);
            assert_eq!(cols.len(), vals.len());
            assert!(cols.len() >= knn.min(n), "row {j} lost entries");
            assert!(cols.len() <= n);
            assert!(cols.windows(2).all(|w| w[0] < w[1]), "row {j} not sorted/unique");
            assert!(cols.binary_search(&(j as u32)).is_ok(), "row {j} lost its self-loop");
        }
        // symmetric union with equal values
        for i in 0..n {
            let (cols, vals) = k.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                assert_eq!(k.at(c as usize, i), v, "asymmetric at ({i},{c})");
            }
        }
    }

    #[test]
    fn from_dense_keeps_topk_and_symmetrizes() {
        let m = random_kernel(20, 3);
        for knn in [1, 2, 5, 10, 20, 64] {
            let s = SparseKernel::from_dense(&m, knn);
            assert_valid(&s, knn.min(20));
            for i in 0..20 {
                for j in 0..20 {
                    let v = s.at(i, j);
                    assert!(v == 0.0 || v == m.at(i, j), "({i},{j}) holds a foreign value");
                }
            }
        }
        // complete sparsification stores everything
        let full = SparseKernel::from_dense(&m, 20);
        assert!(full.is_complete());
        assert_eq!(full.nnz(), 400);
    }

    /// Regression pin for the scratch-buffer partial selection: ties
    /// must break toward the smaller column (`select_nth_unstable_by`
    /// is *unstable*, so only the explicit `.then(a.cmp(b))` arm keeps
    /// the result deterministic), the self-loop always survives, and a
    /// reused scratch is indistinguishable from a fresh one.
    #[test]
    fn row_topk_breaks_ties_toward_smaller_columns() {
        // all-equal scores: top-knn must be exactly the first columns
        // (plus the self-loop), for every diagonal position
        let scores = [0.5f32; 9];
        for diag in [0, 4, 8] {
            let row = row_topk(&scores, diag, 4);
            let mut expect: Vec<u32> = (0..9u32).filter(|&c| c as usize != diag).take(3).collect();
            expect.push(diag as u32);
            expect.sort_unstable();
            let got: Vec<u32> = row.iter().map(|e| e.0).collect();
            assert_eq!(got, expect, "diag {diag}");
        }
        // duplicated score groups: the kept member of each tied group is
        // the smallest column, byte-for-byte stable across a reused
        // scratch and many repetitions
        let scores = [0.9, 0.1, 0.9, 0.7, 0.1, 0.7, 0.9, 0.3];
        let reference = row_topk(&scores, 7, 3);
        assert_eq!(reference, vec![(0, 0.9), (2, 0.9), (7, 0.3)]);
        let mut scratch = TopkScratch::new();
        for _ in 0..5 {
            assert_eq!(row_topk_into(&scores, 7, 3, &mut scratch), reference);
        }
        // and through the full build: a kernel over rank-1 embeddings
        // (every off-diagonal similarity identical per row) is a pure
        // tie-break exercise — byte-identical across repeated builds
        let mut z = Matrix::zeros(12, 3);
        for i in 0..12 {
            z.set(i, 0, 1.0);
        }
        let a = sparse_native(&z, SimMetric::Cosine, 4);
        let b = sparse_native(&z, SimMetric::Cosine, 4);
        assert_eq!(a, b);
        for i in 0..12 {
            assert!(a.row(i).0.contains(&(i as u32)));
        }
    }

    /// Quick in-module cousin of `tests/kernel_pipeline.rs`: the
    /// pipelined build is the serial build, byte for byte.
    #[test]
    fn scheduled_build_matches_serial_exactly() {
        let z = random_embeddings(50, 5, 11);
        for metric in [SimMetric::Cosine, SimMetric::Dot, SimMetric::Rbf { kw: 0.4 }] {
            let (serial, _) =
                sparse_native_scheduled(&z, metric, 6, &KernelSchedule::serial()).unwrap();
            for sched in [
                KernelSchedule::default(),
                KernelSchedule { strip_rows: Some(9), depth: 4 },
            ] {
                let (piped, stats) = sparse_native_scheduled(&z, metric, 6, &sched).unwrap();
                assert_eq!(piped, serial, "{metric:?} {sched:?}");
                assert!(stats.strips > 0);
            }
        }
    }

    #[test]
    fn native_complete_matches_dense_values_exactly() {
        let z = random_embeddings(30, 8, 5);
        for metric in [SimMetric::Cosine, SimMetric::Dot, SimMetric::Rbf { kw: 0.3 }] {
            let dense = native_similarity(&z, metric);
            let sparse = sparse_native(&z, metric, 30);
            assert!(sparse.is_complete(), "{metric:?}");
            for i in 0..30 {
                for j in 0..30 {
                    assert_eq!(
                        dense.at(i, j).to_bits(),
                        sparse.at(i, j).to_bits(),
                        "{metric:?} ({i},{j}): {} vs {}",
                        dense.at(i, j),
                        sparse.at(i, j),
                    );
                }
            }
        }
    }

    #[test]
    fn native_sparse_rows_hold_the_largest_similarities() {
        let z = random_embeddings(40, 6, 7);
        let dense = native_similarity(&z, SimMetric::Cosine);
        let knn = 5;
        let sparse = sparse_native(&z, SimMetric::Cosine, knn);
        assert_valid(&sparse, knn);
        // every stored value matches the dense entry, and each row's own
        // top-k (pre-union) can't have dropped a strictly larger
        // similarity than one it kept: the knn-th largest dense value of
        // row i must be stored
        for i in 0..40 {
            let mut row: Vec<f32> = (0..40).map(|j| dense.at(i, j)).collect();
            row.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let threshold = row[knn - 1];
            let (cols, vals) = sparse.row(i);
            let stored_max_missing = (0..40)
                .filter(|j| cols.binary_search(&(*j as u32)).is_err())
                .map(|j| dense.at(i, j))
                .fold(f32::MIN, f32::max);
            assert!(
                stored_max_missing <= threshold + 1e-6,
                "row {i} dropped a top-{knn} similarity"
            );
            for (&c, &v) in cols.iter().zip(vals) {
                assert_eq!(v.to_bits(), dense.at(i, c as usize).to_bits());
            }
        }
    }

    #[test]
    fn degenerate_sizes_are_handled() {
        // n = 1: one self-loop, complete
        let z1 = random_embeddings(1, 4, 1);
        let s = sparse_native(&z1, SimMetric::Cosine, 8);
        assert_eq!(s.n(), 1);
        assert!(s.is_complete());
        assert_eq!(s.row(0).0, &[0u32]);
        // n = 0: empty
        let z0 = Matrix::zeros(0, 4);
        let s0 = sparse_native(&z0, SimMetric::Cosine, 8);
        assert_eq!(s0.n(), 0);
        assert_eq!(s0.nnz(), 0);
        // knn ≥ n clamps to complete for every small n
        for n in 2..6 {
            let z = random_embeddings(n, 4, n as u64);
            let s = sparse_native(&z, SimMetric::Cosine, 64);
            assert!(s.is_complete(), "n={n}");
        }
    }

    #[test]
    fn rbf_gamma_matches_dense_parameterization() {
        let z = random_embeddings(25, 6, 9);
        // the blockwise mean must equal the dense pairwise mean exactly
        let dense = {
            let n = z.rows;
            let mut sq = vec![0.0f32; n];
            for (i, s) in sq.iter_mut().enumerate() {
                *s = z.row(i).iter().map(|v| v * v).sum();
            }
            let d = z.matmul_nt(&z);
            let mut m = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    m.set(i, j, (sq[i] + sq[j] - 2.0 * d.at(i, j)).max(0.0));
                }
            }
            m.mean()
        };
        assert_eq!(dense.to_bits(), mean_sq_dist_blockwise(&z).to_bits());
    }

    #[test]
    fn pjrt_sparse_complete_matches_dense_pjrt() {
        let Some(rt) = crate::testkit::artifacts_or_skip() else { return };
        let e = rt.manifest().embed_dim;
        let z = random_embeddings(70, e, 11); // non-multiple of tile
        for metric in [SimMetric::Cosine, SimMetric::Rbf { kw: 0.1 }] {
            let dense = crate::kernel::pjrt_similarity(&rt, &z, metric).unwrap();
            let sparse = sparse_pjrt(&rt, &z, metric, 70).unwrap();
            assert!(sparse.is_complete());
            for i in 0..70 {
                for j in 0..70 {
                    // the union copies s[i,j] over s[j,i] where the PJRT
                    // output is asymmetric at float level, so compare
                    // against either orientation
                    let got = sparse.at(i, j);
                    assert!(
                        got == dense.at(i, j) || got == dense.at(j, i),
                        "{metric:?} ({i},{j}): {got} vs {} / {}",
                        dense.at(i, j),
                        dense.at(j, i),
                    );
                }
            }
        }
    }
}
