//! Similarity-kernel construction.
//!
//! MILO's main memory/compute cost is the `m × m` similarity kernel over
//! encoder features. We reproduce the paper's **class-wise partitioning
//! trick** (§3.2): the kernel is built per class (`c` independent
//! `(m/c)²` blocks, a `c²` memory saving) and each block feeds the
//! submodular machinery independently.
//!
//! # Representations
//!
//! Each class block is stored as one of two [`ClassSim`] representations,
//! selected by the `knn` preprocessing option:
//!
//! * **Dense** (`knn = None`) — the full `n_c × n_c` [`Matrix`] block,
//!   `n_c²` floats. The paper's recipe.
//! * **Sparse** (`knn = Some(k)`) — a top-`k` CSR block
//!   ([`sparse::SparseKernel`]): each point keeps its `k` largest
//!   similarities (self-loop always kept, symmetrized by union), built
//!   blockwise from the embeddings without ever materializing the dense
//!   block. Memory is `≈ n_c·knn` floats instead of `n_c²` — the
//!   standard sparsification trick for scaling facility-location-style
//!   selection (CRAIG; Mirzasoleiman et al. 2020). For `knn < n_c` the
//!   kernel (and hence the selections) is an approximation; `knn ≥ n_c`
//!   reproduces the dense selections bit-for-bit (see [`sparse`]).
//!
//! The submodular stack consumes either through the [`view::KernelView`]
//! abstraction, so set functions and greedy maximizers are agnostic to
//! the representation.
//!
//! Two backends compute each block:
//!
//! * [`SimilarityBackend::Pjrt`] — streams `sim_tile × sim_tile` blocks
//!   through the AOT-compiled **Pallas** similarity artifact (L1). This is
//!   the architecture path: the same kernel that would run on a TPU's MXU.
//! * [`SimilarityBackend::Native`] — a cache-blocked Rust implementation,
//!   used as a cross-check (tests assert both agree) and as the fast path
//!   for ablation sweeps where PJRT call overhead on tiny classes
//!   dominates.
//!
//! Metrics: rescaled cosine (default), dot-product, and RBF with the
//! paper's `kw` parameterization (ablation I.2, Tables 11–12).
//!
//! # The overlap pipeline
//!
//! Sparse (top-`knn`) builds stream row strips through a bounded
//! two-slot producer/consumer ([`pipeline::run_pipeline`]): the
//! similarity execution of strip `t + 1` overlaps the host-side
//! top-`knn` reduction of strip `t`.
//!
//! ```text
//!   producer (calling thread)          consumer (one scoped thread)
//!   ┌───────────────┐   sync_channel   ┌───────────────┐
//!   │ execute strip │ ──(depth − 1)──▶ │ row_topk strip│
//!   │     t + 1     │    slots         │       t       │
//!   └───────────────┘                  └───────────────┘
//! ```
//!
//! Two knobs steer it, both surfaced on the CLI and on
//! [`crate::coordinator::PreprocessOptions`]:
//!
//! * **`--sim-tile N`** ([`KernelSchedule::strip_rows`]) — rows per
//!   native construction strip. PJRT strips are always the artifact's
//!   baked `sim_tile`.
//! * **`--pipeline-depth N`** ([`KernelSchedule::depth`]) — `1` is the
//!   serial reference loop; `2` (default) is classic double buffering.
//!
//! Both are **schedule-only**: the single in-order consumer preserves
//! every accumulation order of the serial build, so output is
//! bit-identical for any knob setting — which is why neither enters
//! [`crate::store::MetaKey`]. A panic on either side of the hand-off is
//! contained and surfaced as an `Err`, never a poisoned build.
//!
//! When the manifest provides a fused `topk_{metric}_e{E}` artifact, the
//! PJRT path performs the top-`K` cut **on-device** and transfers only
//! `(cols, vals)` candidates per tile (`≈ 2K/tile` of the full strip
//! bytes); where it provides `embed_sim_topk_{ds}`, the preprocessor
//! collapses embedding → similarity → top-k into one execution per class
//! block. Candidate unions are re-reduced on the host with the exact
//! serial comparator, so on-device selection changes transfer volume,
//! **never values** — and both fusions fall back transparently when the
//! artifacts are absent or `knn > K`.
//!
//! Dense (`knn = None`) blocks have no host-side reduction stage to
//! overlap, so they always run the serial loop regardless of `depth`.

pub mod pipeline;
pub mod sparse;
pub mod view;

pub use pipeline::{KernelSchedule, PipelineStats};
pub use sparse::{build_sparse_kernel, SparseKernel};
pub use view::{KernelRef, KernelRow, KernelView};

use anyhow::Result;

use crate::runtime::{Arg, Runtime};
use crate::tensor::Matrix;
use crate::util::math::round_up;
use crate::util::threads::par_map;

/// Similarity metric (paper ablation I.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SimMetric {
    /// `0.5 + 0.5·cos` (paper Eq. 10) — the default everywhere.
    Cosine,
    /// Raw dot product, additively shifted to be non-negative.
    Dot,
    /// `exp(-‖a−b‖² / (kw · mean_dist))` (paper Eq. 11).
    Rbf { kw: f64 },
}

impl SimMetric {
    pub fn name(&self) -> String {
        match self {
            SimMetric::Cosine => "cosine".into(),
            SimMetric::Dot => "dot".into(),
            SimMetric::Rbf { kw } => format!("rbf_kw{kw}"),
        }
    }
}

/// Which engine computes the similarity blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimilarityBackend {
    /// Pallas artifact via PJRT (the L1 path).
    Pjrt,
    /// Cache-blocked Rust (cross-check / tiny-class fast path).
    Native,
}

/// One class's similarity block: dense (the paper's recipe) or sparse
/// top-`knn` CSR (the memory-scaling variant). Either way the submodular
/// stack reads it through [`ClassSim::view`].
#[derive(Clone, Debug)]
pub enum ClassSim {
    /// `n_c × n_c` block, values in [0, 1] for cosine/RBF.
    Dense(Matrix),
    /// Top-`knn` CSR block (`≈ n_c·knn` stored floats).
    Sparse(SparseKernel),
}

impl ClassSim {
    /// Ground-set size of this block.
    pub fn n(&self) -> usize {
        match self {
            ClassSim::Dense(m) => m.rows,
            ClassSim::Sparse(s) => s.n(),
        }
    }

    /// Stored floats — `n_c²` dense, `nnz` sparse (the memory axis of
    /// the §3.2 report and the selection bench).
    pub fn stored(&self) -> usize {
        match self {
            ClassSim::Dense(m) => m.rows * m.cols,
            ClassSim::Sparse(s) => s.nnz(),
        }
    }

    /// Actual resident bytes of this block — CSR blocks pay a `u32`
    /// column per value plus the row index, not just the floats.
    pub fn memory_bytes(&self) -> usize {
        match self {
            ClassSim::Dense(m) => m.rows * m.cols * std::mem::size_of::<f32>(),
            ClassSim::Sparse(s) => s.memory_bytes(),
        }
    }

    /// Borrowed [`KernelView`] over this block.
    pub fn view(&self) -> KernelRef<'_> {
        match self {
            ClassSim::Dense(m) => KernelRef::Dense(m),
            ClassSim::Sparse(s) => KernelRef::Sparse(s),
        }
    }
}

/// One class's kernel block.
#[derive(Clone, Debug)]
pub struct ClassKernel {
    /// Train-set indices of this class's samples (row/col order of `sim`).
    pub indices: Vec<usize>,
    /// This class's similarity block (dense or sparse top-`knn`).
    pub sim: ClassSim,
}

/// The class-partitioned similarity structure MILO stores as metadata.
#[derive(Clone, Debug)]
pub struct ClassKernels {
    pub per_class: Vec<ClassKernel>,
    pub metric: SimMetric,
}

impl ClassKernels {
    /// Total stored kernel floats (for the §3.2 memory-saving report and
    /// the `BENCH_select` memory axis): `Σ n_c²` dense, `Σ nnz_c` sparse.
    pub fn total_elements(&self) -> usize {
        self.per_class.iter().map(|k| k.sim.stored()).sum()
    }
}

/// Build per-class kernels from embeddings.
///
/// `embeddings` is the full train-split embedding matrix (row = sample);
/// `partition[c]` lists the train indices of class `c` (from
/// [`crate::data::Dataset::class_partition`]); `knn = Some(k)` builds
/// sparse top-`k` blocks instead of dense ones.
///
/// Class embedding rows are gathered once up front (shared by both
/// backends) and each class's `indices` vector is cloned exactly once,
/// into the returned [`ClassKernel`].
pub fn build_class_kernels(
    runtime: Option<&Runtime>,
    embeddings: &Matrix,
    partition: &[Vec<usize>],
    metric: SimMetric,
    backend: SimilarityBackend,
    knn: Option<usize>,
) -> Result<ClassKernels> {
    build_class_kernels_scheduled(
        runtime,
        embeddings,
        partition,
        metric,
        backend,
        knn,
        &KernelSchedule::default(),
    )
}

/// [`build_class_kernels`] under an explicit [`KernelSchedule`]. The
/// schedule steers sparse strip builds only (dense blocks have no
/// host-side reduction stage to overlap); output is bit-identical for
/// any schedule.
pub fn build_class_kernels_scheduled(
    runtime: Option<&Runtime>,
    embeddings: &Matrix,
    partition: &[Vec<usize>],
    metric: SimMetric,
    backend: SimilarityBackend,
    knn: Option<usize>,
    sched: &KernelSchedule,
) -> Result<ClassKernels> {
    let per_class = match backend {
        SimilarityBackend::Native => {
            // pure Rust: gather + similarity fan out over classes
            let classes: Vec<usize> = (0..partition.len()).collect();
            par_map(classes, |ci| {
                let idx = &partition[ci];
                let z = embeddings.gather_rows(idx);
                let sim = match knn {
                    None => ClassSim::Dense(native_similarity(&z, metric)),
                    Some(k) => ClassSim::Sparse(
                        sparse::sparse_native_scheduled(&z, metric, k, sched)?.0,
                    ),
                };
                Ok(ClassKernel { indices: idx.clone(), sim })
            })
            .into_iter()
            .collect::<Result<Vec<_>>>()?
        }
        SimilarityBackend::Pjrt => {
            let rt = runtime.ok_or_else(|| {
                anyhow::anyhow!("Pjrt backend requires a Runtime")
            })?;
            // the gather is pure CPU work — hoist it out of the serial
            // artifact loop and fan it out, but only a bounded window of
            // classes at a time: gathering every class up front would
            // transiently duplicate the whole embedding matrix
            let window = crate::util::threads::max_threads().max(1);
            let mut out = Vec::with_capacity(partition.len());
            for chunk in partition.chunks(window) {
                let gathered: Vec<Matrix> = par_map(
                    chunk.iter().collect::<Vec<_>>(),
                    |idx| embeddings.gather_rows(idx),
                );
                for (idx, z) in chunk.iter().zip(gathered) {
                    let sim = match knn {
                        None => ClassSim::Dense(pjrt_similarity(rt, &z, metric)?),
                        Some(k) => ClassSim::Sparse(
                            sparse::sparse_pjrt_scheduled(rt, &z, metric, k, sched)?.0,
                        ),
                    };
                    out.push(ClassKernel { indices: idx.clone(), sim });
                }
            }
            out
        }
    };
    Ok(ClassKernels { per_class, metric })
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Compute the full pairwise similarity of `z` (n×e) under `metric`.
pub fn native_similarity(z: &Matrix, metric: SimMetric) -> Matrix {
    match metric {
        SimMetric::Cosine => {
            let mut zn = z.clone();
            zn.l2_normalize_rows();
            let mut s = zn.matmul_nt(&zn);
            for v in s.data_mut().iter_mut() {
                *v = 0.5 + 0.5 * *v;
            }
            s
        }
        SimMetric::Dot => {
            let mut s = z.matmul_nt(z);
            // additive shift to non-negativity (paper I.2)
            let min = s.data().iter().cloned().fold(f32::MAX, f32::min);
            if min < 0.0 {
                for v in s.data_mut().iter_mut() {
                    *v -= min;
                }
            }
            s
        }
        SimMetric::Rbf { kw } => {
            let d2 = pairwise_sq_dists(z);
            let mean = d2.mean().max(1e-12);
            let gamma = (1.0 / (kw * mean)) as f32;
            let mut s = d2;
            for v in s.data_mut().iter_mut() {
                *v = (-gamma * *v).exp();
            }
            s
        }
    }
}

fn pairwise_sq_dists(z: &Matrix) -> Matrix {
    let n = z.rows;
    let mut sq = vec![0.0f32; n];
    for i in 0..n {
        sq[i] = z.row(i).iter().map(|v| v * v).sum();
    }
    let mut d2 = z.matmul_nt(z);
    for i in 0..n {
        for j in 0..n {
            let v = (sq[i] + sq[j] - 2.0 * d2.at(i, j)).max(0.0);
            d2.set(i, j, v);
        }
    }
    d2
}

// ---------------------------------------------------------------------------
// PJRT (Pallas) backend
// ---------------------------------------------------------------------------

/// Compute the full pairwise similarity by streaming `tile × tile` blocks
/// through the Pallas artifact; `z` is padded with zero rows to a tile
/// multiple and the result cropped back. Zero-row padding is safe: cosine
/// handles zero rows via its norm eps, and padded rows/cols are cropped
/// before any consumer sees them.
pub fn pjrt_similarity(rt: &Runtime, z: &Matrix, metric: SimMetric) -> Result<Matrix> {
    let tile = rt.manifest().sim_tile;
    let e = z.cols;
    let n = z.rows;
    let np = round_up(n.max(1), tile);
    let mut zp = Matrix::zeros(np, e);
    zp.write_rows(0, z);

    // RBF gamma must match the native parameterization: mean pairwise
    // squared distance over the (unpadded) block.
    let artifact;
    let mut gamma = 0.0f32;
    match metric {
        SimMetric::Cosine => artifact = format!("sim_cosine_e{e}"),
        SimMetric::Dot => artifact = format!("sim_dot_e{e}"),
        SimMetric::Rbf { kw } => {
            artifact = format!("sim_rbf_e{e}");
            let d2 = pairwise_sq_dists(z);
            gamma = (1.0 / (kw * d2.mean().max(1e-12))) as f32;
        }
    }

    let mut out = Matrix::zeros(np, np);
    let tiles = np / tile;
    for bi in 0..tiles {
        let a = Matrix::from_vec(
            tile,
            e,
            zp.data()[bi * tile * e..(bi + 1) * tile * e].to_vec(),
        )?;
        for bj in 0..tiles {
            let b = Matrix::from_vec(
                tile,
                e,
                zp.data()[bj * tile * e..(bj + 1) * tile * e].to_vec(),
            )?;
            let res = match metric {
                SimMetric::Rbf { .. } => rt.execute(
                    &artifact,
                    &[Arg::F32(a.data()), Arg::F32(b.data()), Arg::F32(&[gamma])],
                )?,
                _ => rt.execute(&artifact, &[Arg::F32(a.data()), Arg::F32(b.data())])?,
            };
            let block = &res[0];
            for r in 0..tile {
                let dst_row = bi * tile + r;
                let dst0 = dst_row * np + bj * tile;
                out.data_mut()[dst0..dst0 + tile]
                    .copy_from_slice(&block[r * tile..(r + 1) * tile]);
            }
        }
    }
    // crop to n×n
    let mut cropped = Matrix::zeros(n, n);
    for r in 0..n {
        cropped.row_mut(r).copy_from_slice(&out.row(r)[..n]);
    }
    // dot metric: shift AFTER cropping so padding zeros don't skew the min
    if matches!(metric, SimMetric::Dot) {
        let min = cropped.data().iter().cloned().fold(f32::MAX, f32::min);
        if min < 0.0 {
            for v in cropped.data_mut().iter_mut() {
                *v -= min;
            }
        }
    }
    Ok(cropped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_embed(n: usize, e: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(n, e);
        for v in m.data_mut().iter_mut() {
            *v = rng.normal_f32(0.0, 1.0);
        }
        m
    }

    #[test]
    fn native_cosine_properties() {
        let z = rand_embed(20, 8, 1);
        let s = native_similarity(&z, SimMetric::Cosine);
        for i in 0..20 {
            assert!((s.at(i, i) - 1.0).abs() < 1e-5);
            for j in 0..20 {
                assert!((s.at(i, j) - s.at(j, i)).abs() < 1e-5);
                assert!((-1e-5..=1.0 + 1e-5).contains(&s.at(i, j)));
            }
        }
    }

    #[test]
    fn native_dot_nonnegative() {
        let z = rand_embed(15, 6, 2);
        let s = native_similarity(&z, SimMetric::Dot);
        assert!(s.data().iter().all(|&v| v >= -1e-6));
    }

    #[test]
    fn native_rbf_kw_controls_decay() {
        let z = rand_embed(15, 6, 3);
        let sharp = native_similarity(&z, SimMetric::Rbf { kw: 0.01 });
        let smooth = native_similarity(&z, SimMetric::Rbf { kw: 1.0 });
        // off-diagonal similarities decay faster with small kw
        let off = |s: &Matrix| {
            let mut t = 0.0;
            for i in 0..15 {
                for j in 0..15 {
                    if i != j {
                        t += s.at(i, j) as f64;
                    }
                }
            }
            t
        };
        assert!(off(&sharp) < off(&smooth));
        for i in 0..15 {
            assert!((sharp.at(i, i) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn class_kernels_native_structure() {
        let z = rand_embed(30, 8, 4);
        let partition = vec![
            (0..10).collect::<Vec<_>>(),
            (10..25).collect(),
            (25..30).collect(),
        ];
        let ck = build_class_kernels(
            None,
            &z,
            &partition,
            SimMetric::Cosine,
            SimilarityBackend::Native,
            None,
        )
        .unwrap();
        assert_eq!(ck.per_class.len(), 3);
        assert_eq!(ck.per_class[0].sim.n(), 10);
        assert_eq!(ck.per_class[1].sim.n(), 15);
        assert_eq!(ck.per_class[2].sim.n(), 5);
        // memory saving vs full kernel: 10²+15²+5² ≪ 30²
        assert!(ck.total_elements() < 30 * 30);
    }

    #[test]
    fn class_kernels_sparse_structure() {
        let z = rand_embed(60, 8, 7);
        let partition = vec![
            (0..30).collect::<Vec<_>>(),
            (30..55).collect(),
            (55..60).collect(),
        ];
        let dense = build_class_kernels(
            None,
            &z,
            &partition,
            SimMetric::Cosine,
            SimilarityBackend::Native,
            None,
        )
        .unwrap();
        let sparse = build_class_kernels(
            None,
            &z,
            &partition,
            SimMetric::Cosine,
            SimilarityBackend::Native,
            Some(4),
        )
        .unwrap();
        assert_eq!(sparse.per_class.len(), 3);
        for (d, s) in dense.per_class.iter().zip(&sparse.per_class) {
            assert_eq!(d.indices, s.indices);
            assert_eq!(d.sim.n(), s.sim.n());
        }
        // top-4 blocks store far fewer floats than the dense 30²+25²+5²
        assert!(
            sparse.total_elements() * 2 < dense.total_elements(),
            "sparse {} vs dense {}",
            sparse.total_elements(),
            dense.total_elements()
        );
        // every row of the tiny class (n_c = 5, knn = 4) keeps its knn
        // entries, self-loop included
        match &sparse.per_class[2].sim {
            ClassSim::Sparse(k) => assert!(k.nnz() >= 5 * 4),
            ClassSim::Dense(_) => panic!("expected a sparse block"),
        }
    }

    #[test]
    fn pjrt_matches_native_cosine() {
        let Some(rt) = crate::testkit::artifacts_or_skip() else { return };
        let e = rt.manifest().embed_dim;
        let z = rand_embed(70, e, 5); // non-multiple of tile: exercises padding
        let native = native_similarity(&z, SimMetric::Cosine);
        let pjrt = pjrt_similarity(&rt, &z, SimMetric::Cosine).unwrap();
        assert_eq!(pjrt.rows, 70);
        for i in 0..70 {
            for j in 0..70 {
                assert!(
                    (native.at(i, j) - pjrt.at(i, j)).abs() < 1e-4,
                    "({i},{j}): native {} pjrt {}",
                    native.at(i, j),
                    pjrt.at(i, j)
                );
            }
        }
    }

    #[test]
    fn pjrt_matches_native_rbf() {
        let Some(rt) = crate::testkit::artifacts_or_skip() else { return };
        let e = rt.manifest().embed_dim;
        let z = rand_embed(40, e, 6);
        let native = native_similarity(&z, SimMetric::Rbf { kw: 0.1 });
        let pjrt = pjrt_similarity(&rt, &z, SimMetric::Rbf { kw: 0.1 }).unwrap();
        for i in 0..40 {
            for j in 0..40 {
                assert!(
                    (native.at(i, j) - pjrt.at(i, j)).abs() < 2e-3,
                    "({i},{j}): {} vs {}",
                    native.at(i, j),
                    pjrt.at(i, j)
                );
            }
        }
    }
}
