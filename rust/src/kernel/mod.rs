//! Similarity-kernel construction.
//!
//! MILO's main memory/compute cost is the `m × m` similarity kernel over
//! encoder features. We reproduce the paper's **class-wise partitioning
//! trick** (§3.2): the kernel is built per class (`c` independent
//! `(m/c)²` blocks, a `c²` memory saving) and each block feeds the
//! submodular machinery independently.
//!
//! Two backends compute each block:
//!
//! * [`SimilarityBackend::Pjrt`] — streams `sim_tile × sim_tile` blocks
//!   through the AOT-compiled **Pallas** similarity artifact (L1). This is
//!   the architecture path: the same kernel that would run on a TPU's MXU.
//! * [`SimilarityBackend::Native`] — a cache-blocked Rust implementation,
//!   used as a cross-check (tests assert both agree) and as the fast path
//!   for ablation sweeps where PJRT call overhead on tiny classes
//!   dominates.
//!
//! Metrics: rescaled cosine (default), dot-product, and RBF with the
//! paper's `kw` parameterization (ablation I.2, Tables 11–12).

use anyhow::Result;

use crate::runtime::{Arg, Runtime};
use crate::tensor::Matrix;
use crate::util::math::round_up;
use crate::util::threads::par_map;

/// Similarity metric (paper ablation I.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SimMetric {
    /// `0.5 + 0.5·cos` (paper Eq. 10) — the default everywhere.
    Cosine,
    /// Raw dot product, additively shifted to be non-negative.
    Dot,
    /// `exp(-‖a−b‖² / (kw · mean_dist))` (paper Eq. 11).
    Rbf { kw: f64 },
}

impl SimMetric {
    pub fn name(&self) -> String {
        match self {
            SimMetric::Cosine => "cosine".into(),
            SimMetric::Dot => "dot".into(),
            SimMetric::Rbf { kw } => format!("rbf_kw{kw}"),
        }
    }
}

/// Which engine computes the similarity blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimilarityBackend {
    /// Pallas artifact via PJRT (the L1 path).
    Pjrt,
    /// Cache-blocked Rust (cross-check / tiny-class fast path).
    Native,
}

/// One class's kernel block.
#[derive(Clone, Debug)]
pub struct ClassKernel {
    /// Train-set indices of this class's samples (row/col order of `sim`).
    pub indices: Vec<usize>,
    /// `n_c × n_c` similarity block, values in [0, 1] for cosine/RBF.
    pub sim: Matrix,
}

/// The class-partitioned similarity structure MILO stores as metadata.
#[derive(Clone, Debug)]
pub struct ClassKernels {
    pub per_class: Vec<ClassKernel>,
    pub metric: SimMetric,
}

impl ClassKernels {
    /// Total kernel memory in floats (for the §3.2 memory-saving report).
    pub fn total_elements(&self) -> usize {
        self.per_class.iter().map(|k| k.sim.rows * k.sim.rows).sum()
    }
}

/// Build per-class kernels from embeddings.
///
/// `embeddings` is the full train-split embedding matrix (row = sample);
/// `partition[c]` lists the train indices of class `c` (from
/// [`crate::data::Dataset::class_partition`]).
pub fn build_class_kernels(
    runtime: Option<&Runtime>,
    embeddings: &Matrix,
    partition: &[Vec<usize>],
    metric: SimMetric,
    backend: SimilarityBackend,
) -> Result<ClassKernels> {
    let per_class = match backend {
        SimilarityBackend::Native => {
            // pure Rust: parallel over classes
            let jobs: Vec<(Vec<usize>, Matrix)> = partition
                .iter()
                .map(|idx| (idx.clone(), embeddings.gather_rows(idx)))
                .collect();
            par_map(jobs, |(indices, z)| ClassKernel {
                sim: native_similarity(&z, metric),
                indices,
            })
        }
        SimilarityBackend::Pjrt => {
            let rt = runtime.ok_or_else(|| {
                anyhow::anyhow!("Pjrt backend requires a Runtime")
            })?;
            let mut out = Vec::with_capacity(partition.len());
            for idx in partition {
                let z = embeddings.gather_rows(idx);
                out.push(ClassKernel {
                    sim: pjrt_similarity(rt, &z, metric)?,
                    indices: idx.clone(),
                });
            }
            out
        }
    };
    Ok(ClassKernels { per_class, metric })
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Compute the full pairwise similarity of `z` (n×e) under `metric`.
pub fn native_similarity(z: &Matrix, metric: SimMetric) -> Matrix {
    match metric {
        SimMetric::Cosine => {
            let mut zn = z.clone();
            zn.l2_normalize_rows();
            let mut s = zn.matmul_nt(&zn);
            for v in s.data_mut().iter_mut() {
                *v = 0.5 + 0.5 * *v;
            }
            s
        }
        SimMetric::Dot => {
            let mut s = z.matmul_nt(z);
            // additive shift to non-negativity (paper I.2)
            let min = s.data().iter().cloned().fold(f32::MAX, f32::min);
            if min < 0.0 {
                for v in s.data_mut().iter_mut() {
                    *v -= min;
                }
            }
            s
        }
        SimMetric::Rbf { kw } => {
            let d2 = pairwise_sq_dists(z);
            let mean = d2.mean().max(1e-12);
            let gamma = (1.0 / (kw * mean)) as f32;
            let mut s = d2;
            for v in s.data_mut().iter_mut() {
                *v = (-gamma * *v).exp();
            }
            s
        }
    }
}

fn pairwise_sq_dists(z: &Matrix) -> Matrix {
    let n = z.rows;
    let mut sq = vec![0.0f32; n];
    for i in 0..n {
        sq[i] = z.row(i).iter().map(|v| v * v).sum();
    }
    let mut d2 = z.matmul_nt(z);
    for i in 0..n {
        for j in 0..n {
            let v = (sq[i] + sq[j] - 2.0 * d2.at(i, j)).max(0.0);
            d2.set(i, j, v);
        }
    }
    d2
}

// ---------------------------------------------------------------------------
// PJRT (Pallas) backend
// ---------------------------------------------------------------------------

/// Compute the full pairwise similarity by streaming `tile × tile` blocks
/// through the Pallas artifact; `z` is padded with zero rows to a tile
/// multiple and the result cropped back. Zero-row padding is safe: cosine
/// handles zero rows via its norm eps, and padded rows/cols are cropped
/// before any consumer sees them.
pub fn pjrt_similarity(rt: &Runtime, z: &Matrix, metric: SimMetric) -> Result<Matrix> {
    let tile = rt.manifest().sim_tile;
    let e = z.cols;
    let n = z.rows;
    let np = round_up(n.max(1), tile);
    let mut zp = Matrix::zeros(np, e);
    zp.write_rows(0, z);

    // RBF gamma must match the native parameterization: mean pairwise
    // squared distance over the (unpadded) block.
    let artifact;
    let mut gamma = 0.0f32;
    match metric {
        SimMetric::Cosine => artifact = format!("sim_cosine_e{e}"),
        SimMetric::Dot => artifact = format!("sim_dot_e{e}"),
        SimMetric::Rbf { kw } => {
            artifact = format!("sim_rbf_e{e}");
            let d2 = pairwise_sq_dists(z);
            gamma = (1.0 / (kw * d2.mean().max(1e-12))) as f32;
        }
    }

    let mut out = Matrix::zeros(np, np);
    let tiles = np / tile;
    for bi in 0..tiles {
        let a = Matrix::from_vec(
            tile,
            e,
            zp.data()[bi * tile * e..(bi + 1) * tile * e].to_vec(),
        )?;
        for bj in 0..tiles {
            let b = Matrix::from_vec(
                tile,
                e,
                zp.data()[bj * tile * e..(bj + 1) * tile * e].to_vec(),
            )?;
            let res = match metric {
                SimMetric::Rbf { .. } => rt.execute(
                    &artifact,
                    &[Arg::F32(a.data()), Arg::F32(b.data()), Arg::F32(&[gamma])],
                )?,
                _ => rt.execute(&artifact, &[Arg::F32(a.data()), Arg::F32(b.data())])?,
            };
            let block = &res[0];
            for r in 0..tile {
                let dst_row = bi * tile + r;
                let dst0 = dst_row * np + bj * tile;
                out.data_mut()[dst0..dst0 + tile]
                    .copy_from_slice(&block[r * tile..(r + 1) * tile]);
            }
        }
    }
    // crop to n×n
    let mut cropped = Matrix::zeros(n, n);
    for r in 0..n {
        cropped.row_mut(r).copy_from_slice(&out.row(r)[..n]);
    }
    // dot metric: shift AFTER cropping so padding zeros don't skew the min
    if matches!(metric, SimMetric::Dot) {
        let min = cropped.data().iter().cloned().fold(f32::MAX, f32::min);
        if min < 0.0 {
            for v in cropped.data_mut().iter_mut() {
                *v -= min;
            }
        }
    }
    Ok(cropped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_embed(n: usize, e: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(n, e);
        for v in m.data_mut().iter_mut() {
            *v = rng.normal_f32(0.0, 1.0);
        }
        m
    }

    #[test]
    fn native_cosine_properties() {
        let z = rand_embed(20, 8, 1);
        let s = native_similarity(&z, SimMetric::Cosine);
        for i in 0..20 {
            assert!((s.at(i, i) - 1.0).abs() < 1e-5);
            for j in 0..20 {
                assert!((s.at(i, j) - s.at(j, i)).abs() < 1e-5);
                assert!((-1e-5..=1.0 + 1e-5).contains(&s.at(i, j)));
            }
        }
    }

    #[test]
    fn native_dot_nonnegative() {
        let z = rand_embed(15, 6, 2);
        let s = native_similarity(&z, SimMetric::Dot);
        assert!(s.data().iter().all(|&v| v >= -1e-6));
    }

    #[test]
    fn native_rbf_kw_controls_decay() {
        let z = rand_embed(15, 6, 3);
        let sharp = native_similarity(&z, SimMetric::Rbf { kw: 0.01 });
        let smooth = native_similarity(&z, SimMetric::Rbf { kw: 1.0 });
        // off-diagonal similarities decay faster with small kw
        let off = |s: &Matrix| {
            let mut t = 0.0;
            for i in 0..15 {
                for j in 0..15 {
                    if i != j {
                        t += s.at(i, j) as f64;
                    }
                }
            }
            t
        };
        assert!(off(&sharp) < off(&smooth));
        for i in 0..15 {
            assert!((sharp.at(i, i) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn class_kernels_native_structure() {
        let z = rand_embed(30, 8, 4);
        let partition = vec![
            (0..10).collect::<Vec<_>>(),
            (10..25).collect(),
            (25..30).collect(),
        ];
        let ck = build_class_kernels(
            None,
            &z,
            &partition,
            SimMetric::Cosine,
            SimilarityBackend::Native,
        )
        .unwrap();
        assert_eq!(ck.per_class.len(), 3);
        assert_eq!(ck.per_class[0].sim.rows, 10);
        assert_eq!(ck.per_class[1].sim.rows, 15);
        assert_eq!(ck.per_class[2].sim.rows, 5);
        // memory saving vs full kernel: 10²+15²+5² ≪ 30²
        assert!(ck.total_elements() < 30 * 30);
    }

    #[test]
    fn pjrt_matches_native_cosine() {
        let Some(rt) = crate::testkit::artifacts_or_skip() else { return };
        let e = rt.manifest().embed_dim;
        let z = rand_embed(70, e, 5); // non-multiple of tile: exercises padding
        let native = native_similarity(&z, SimMetric::Cosine);
        let pjrt = pjrt_similarity(&rt, &z, SimMetric::Cosine).unwrap();
        assert_eq!(pjrt.rows, 70);
        for i in 0..70 {
            for j in 0..70 {
                assert!(
                    (native.at(i, j) - pjrt.at(i, j)).abs() < 1e-4,
                    "({i},{j}): native {} pjrt {}",
                    native.at(i, j),
                    pjrt.at(i, j)
                );
            }
        }
    }

    #[test]
    fn pjrt_matches_native_rbf() {
        let Some(rt) = crate::testkit::artifacts_or_skip() else { return };
        let e = rt.manifest().embed_dim;
        let z = rand_embed(40, e, 6);
        let native = native_similarity(&z, SimMetric::Rbf { kw: 0.1 });
        let pjrt = pjrt_similarity(&rt, &z, SimMetric::Rbf { kw: 0.1 }).unwrap();
        for i in 0..40 {
            for j in 0..40 {
                assert!(
                    (native.at(i, j) - pjrt.at(i, j)).abs() < 2e-3,
                    "({i},{j}): {} vs {}",
                    native.at(i, j),
                    pjrt.at(i, j)
                );
            }
        }
    }
}
