//! `KernelView` — the similarity-kernel access abstraction the whole
//! submodular stack is routed over.
//!
//! The set functions in [`crate::submod`] never touch a concrete kernel
//! type: they are generic over this trait, so one implementation of each
//! gain oracle serves both the dense `n_c × n_c` class blocks
//! ([`crate::tensor::Matrix`]) and the sparse top-`knn` CSR blocks
//! ([`crate::kernel::SparseKernel`]). The contract:
//!
//! * the kernel is square over `n` points, symmetric, with values in
//!   `[0, 1]` for the cosine/RBF metrics;
//! * a pair that is **not stored** has similarity exactly `0.0`
//!   (equivalently: distance `1 − 0 = 1` for the disparity functions) —
//!   sparse representations are "dense matrices with implicit zeros", so
//!   every gain formula stays well-defined;
//! * [`KernelView::kernel_row`] hands back the storage-native row form:
//!   a contiguous `&[f32]` for dense kernels (the auto-vectorized hot
//!   loops are preserved verbatim), or parallel `(cols, vals)` slices
//!   for CSR rows. Rows are iterated in ascending column order in both
//!   forms, which is what makes a *complete* sparse kernel (`knn ≥ n`)
//!   reproduce dense gains bit-for-bit: identical f32 operations in
//!   identical order.

use crate::tensor::Matrix;

use super::sparse::SparseKernel;

/// One kernel row, in its storage-native form. Both forms iterate
/// entries in ascending column order.
pub enum KernelRow<'a> {
    /// A contiguous dense row (`len == n`).
    Dense(&'a [f32]),
    /// A CSR row: `cols[t]` holds the column of `vals[t]`, sorted
    /// ascending, no duplicates.
    Sparse { cols: &'a [u32], vals: &'a [f32] },
}

/// Read access to a square similarity kernel. See the [module
/// docs](self) for the contract.
pub trait KernelView {
    /// Ground-set size (the kernel is `n × n`).
    fn n(&self) -> usize;

    /// Stored entries — `n²` for dense, `nnz` for sparse (the memory
    /// axis of the §3.2 report and the selection bench).
    fn stored(&self) -> usize;

    /// Whether every pair is stored. Complete kernels skip the
    /// implicit-zero handling (e.g. disparity-min's distance-1 clamp),
    /// which is what keeps the dense hot paths byte-for-byte unchanged.
    fn is_complete(&self) -> bool;

    /// `s[i, j]`; `0.0` for unstored sparse pairs.
    fn value_at(&self, i: usize, j: usize) -> f32;

    /// Row `j` in storage-native form.
    fn kernel_row(&self, j: usize) -> KernelRow<'_>;
}

impl KernelView for Matrix {
    #[inline]
    fn n(&self) -> usize {
        // a rectangular "kernel" would silently truncate the oracle
        // state zips — fail loudly, as the old per-oracle asserts did
        assert_eq!(self.rows, self.cols, "kernel must be square");
        self.rows
    }

    #[inline]
    fn stored(&self) -> usize {
        self.rows * self.cols
    }

    #[inline]
    fn is_complete(&self) -> bool {
        true
    }

    #[inline]
    fn value_at(&self, i: usize, j: usize) -> f32 {
        self.at(i, j)
    }

    #[inline]
    fn kernel_row(&self, j: usize) -> KernelRow<'_> {
        KernelRow::Dense(self.row(j))
    }
}

impl KernelView for SparseKernel {
    #[inline]
    fn n(&self) -> usize {
        self.n()
    }

    #[inline]
    fn stored(&self) -> usize {
        self.nnz()
    }

    #[inline]
    fn is_complete(&self) -> bool {
        self.is_complete()
    }

    #[inline]
    fn value_at(&self, i: usize, j: usize) -> f32 {
        self.at(i, j)
    }

    #[inline]
    fn kernel_row(&self, j: usize) -> KernelRow<'_> {
        let (cols, vals) = self.row(j);
        KernelRow::Sparse { cols, vals }
    }
}

/// References are views too, so `SetFunctionKind::build(&matrix)` and
/// the boxed oracles keep working over borrowed kernels.
impl<K: KernelView + ?Sized> KernelView for &K {
    #[inline]
    fn n(&self) -> usize {
        (**self).n()
    }

    #[inline]
    fn stored(&self) -> usize {
        (**self).stored()
    }

    #[inline]
    fn is_complete(&self) -> bool {
        (**self).is_complete()
    }

    #[inline]
    fn value_at(&self, i: usize, j: usize) -> f32 {
        (**self).value_at(i, j)
    }

    #[inline]
    fn kernel_row(&self, j: usize) -> KernelRow<'_> {
        (**self).kernel_row(j)
    }
}

/// A borrowed kernel of either representation — the runtime-dispatch
/// companion to the [`KernelView`] generic (one `match` per row access,
/// with the per-entry loops monomorphized inside each arm).
#[derive(Clone, Copy, Debug)]
pub enum KernelRef<'a> {
    Dense(&'a Matrix),
    Sparse(&'a SparseKernel),
}

impl KernelView for KernelRef<'_> {
    #[inline]
    fn n(&self) -> usize {
        match self {
            KernelRef::Dense(m) => KernelView::n(*m),
            KernelRef::Sparse(s) => s.n(),
        }
    }

    #[inline]
    fn stored(&self) -> usize {
        match self {
            KernelRef::Dense(m) => KernelView::stored(*m),
            KernelRef::Sparse(s) => s.nnz(),
        }
    }

    #[inline]
    fn is_complete(&self) -> bool {
        match self {
            KernelRef::Dense(_) => true,
            KernelRef::Sparse(s) => s.is_complete(),
        }
    }

    #[inline]
    fn value_at(&self, i: usize, j: usize) -> f32 {
        match self {
            KernelRef::Dense(m) => m.at(i, j),
            KernelRef::Sparse(s) => s.at(i, j),
        }
    }

    #[inline]
    fn kernel_row(&self, j: usize) -> KernelRow<'_> {
        match self {
            KernelRef::Dense(m) => KernelRow::Dense(m.row(j)),
            KernelRef::Sparse(s) => {
                let (cols, vals) = s.row(j);
                KernelRow::Sparse { cols, vals }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_view_reports_matrix_shape() {
        let mut m = Matrix::zeros(3, 3);
        m.set(1, 2, 0.5);
        assert_eq!(KernelView::n(&m), 3);
        assert_eq!(KernelView::stored(&m), 9);
        assert!(KernelView::is_complete(&m));
        assert_eq!(m.value_at(1, 2), 0.5);
        match m.kernel_row(1) {
            KernelRow::Dense(row) => assert_eq!(row, &[0.0, 0.0, 0.5]),
            KernelRow::Sparse { .. } => panic!("dense kernel must yield dense rows"),
        }
    }

    #[test]
    fn kernel_ref_delegates_to_both_representations() {
        let m = crate::testkit::random_kernel(6, 1);
        let s = SparseKernel::from_dense(&m, 6);
        let dv = KernelRef::Dense(&m);
        let sv = KernelRef::Sparse(&s);
        assert_eq!(dv.n(), sv.n());
        assert!(dv.is_complete() && sv.is_complete());
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(dv.value_at(i, j), sv.value_at(i, j), "({i},{j})");
            }
        }
    }
}
