//! Overlapped strip pipeline for kernel construction.
//!
//! Blockwise kernel builds have two alternating stages per row strip:
//! **produce** (the similarity execution — a PJRT artifact call or the
//! native cache-blocked matmul) and **consume** (the host-side top-`knn`
//! reduction). Run serially, the device/matmul side sits idle while the
//! host selects, and vice versa. [`run_pipeline`] overlaps them with a
//! bounded two-slot hand-off:
//!
//! ```text
//!   producer (calling thread)          consumer (one scoped thread)
//!   ┌───────────────┐   sync_channel   ┌───────────────┐
//!   │ execute strip │ ──(depth − 1)──▶ │ row_topk strip│
//!   │     t + 1     │    slots         │       t       │
//!   └───────────────┘                  └───────────────┘
//! ```
//!
//! The producer stays on the calling thread (a [`crate::runtime::Runtime`]
//! is `!Send`); the consumer is a single in-order scoped thread, so
//! reductions happen in exactly the serial strip order — which is what
//! keeps pipelined output *bit-identical* to the serial build (see the
//! [`super::sparse`] docs for the per-metric argument; the RBF f64 mean
//! accumulation in particular requires in-order consumption).
//!
//! Failure containment: a panic in either stage is caught and surfaced as
//! an `Err` from [`run_pipeline`] instead of poisoning the build or
//! deadlocking the peer stage. `depth <= 1` (or a single strip) degrades
//! to a fully inline serial loop with the same containment.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::obs::Span;

/// Scheduling knobs for blockwise kernel construction. Both knobs are
/// **schedule-only**: they change when work happens, never any per-entry
/// value, so they are deliberately excluded from
/// [`crate::store::MetaKey`] fingerprints (the bit-identity property
/// tests in `rust/tests/kernel_pipeline.rs` prove the exclusion sound).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelSchedule {
    /// Rows per native construction strip (`None` = the built-in
    /// default). PJRT strips are always `sim_tile` rows — the artifact's
    /// tile shape is baked at lowering time — so this knob only affects
    /// the native backend.
    pub strip_rows: Option<usize>,
    /// Pipeline depth: `1` runs strips fully serially on the calling
    /// thread; `d >= 2` lets the producer run up to `d − 1` strips ahead
    /// of the consumer (`2` is classic double buffering, the default).
    pub depth: usize,
}

impl Default for KernelSchedule {
    fn default() -> KernelSchedule {
        KernelSchedule { strip_rows: None, depth: 2 }
    }
}

impl KernelSchedule {
    /// The degenerate serial schedule (`depth = 1`): reference behavior
    /// for the bit-identity sweep and the bench baseline.
    pub fn serial() -> KernelSchedule {
        KernelSchedule { strip_rows: None, depth: 1 }
    }
}

/// Timing breakdown of one (possibly pipelined) blockwise build.
///
/// `produce_secs`/`consume_secs` are per-stage busy times summed over
/// strips; under overlap their sum exceeds `wall_secs`. `stall_secs` is
/// the time the producer spent blocked on a full hand-off channel — the
/// device-idle component the overlap bench (`BENCH_select.json`
/// `"overlap"` section) reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineStats {
    /// Number of row strips processed.
    pub strips: usize,
    /// Total time in the produce stage (similarity execution).
    pub produce_secs: f64,
    /// Total time in the consume stage (host top-`knn` reduction).
    pub consume_secs: f64,
    /// Producer time blocked waiting for a free hand-off slot.
    pub stall_secs: f64,
    /// End-to-end wall time of the build.
    pub wall_secs: f64,
}

impl PipelineStats {
    /// Fold another build's timings into this one (used to aggregate
    /// across class blocks).
    pub fn merge(&mut self, other: &PipelineStats) {
        self.strips += other.strips;
        self.produce_secs += other.produce_secs;
        self.consume_secs += other.consume_secs;
        self.stall_secs += other.stall_secs;
        self.wall_secs += other.wall_secs;
    }

    /// Fraction of wall time the producer (the device side) spent
    /// stalled on the hand-off — `0.0` means the device never waited for
    /// the host reduction.
    pub fn device_idle_fraction(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            (self.stall_secs / self.wall_secs).clamp(0.0, 1.0)
        }
    }
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

// A panicked produce closure may leave its captures half-mutated, but on
// `Err` the whole build is discarded — nothing observes the torn state —
// so `AssertUnwindSafe` is sound here.
fn contained<S>(produce: &mut impl FnMut(usize) -> Result<S>, t: usize) -> Result<S> {
    match catch_unwind(AssertUnwindSafe(|| produce(t))) {
        Ok(r) => r,
        Err(p) => Err(anyhow!(
            "kernel pipeline producer panicked on strip {t}: {}",
            panic_text(p.as_ref())
        )),
    }
}

/// Run `strips` produce→consume pairs with up to `depth − 1` strips in
/// flight between the stages.
///
/// `produce(t)` runs on the calling thread (it may borrow `!Send` state
/// such as a [`crate::runtime::Runtime`]); `consume(&mut state, t, strip)`
/// runs on one scoped consumer thread, strictly in strip order. The
/// final `state` is returned with the stage timings. Panics in either
/// stage surface as `Err`; `depth <= 1` or `strips <= 1` runs inline
/// with no thread.
pub fn run_pipeline<S, T, P, C>(
    strips: usize,
    depth: usize,
    mut state: T,
    mut produce: P,
    mut consume: C,
) -> Result<(T, PipelineStats)>
where
    S: Send,
    T: Send,
    P: FnMut(usize) -> Result<S>,
    C: FnMut(&mut T, usize, S) + Send,
{
    let mut stats = PipelineStats { strips, ..Default::default() };
    let wall0 = Instant::now();

    if depth <= 1 || strips <= 1 {
        for t in 0..strips {
            let t0 = Instant::now();
            let strip = {
                let _sp = Span::enter("kernel.execute");
                contained(&mut produce, t)?
            };
            stats.produce_secs += t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            {
                let _sp = Span::enter("kernel.topk");
                consume(&mut state, t, strip);
            }
            stats.consume_secs += t1.elapsed().as_secs_f64();
        }
        stats.wall_secs = wall0.elapsed().as_secs_f64();
        return Ok((state, stats));
    }

    let (tx, rx) = mpsc::sync_channel::<(usize, S)>(depth - 1);
    let (joined, consume_secs, produced) = std::thread::scope(|scope| {
        let handle = scope.spawn(move || {
            let mut secs = 0.0f64;
            while let Ok((t, strip)) = rx.recv() {
                let t0 = Instant::now();
                {
                    let _sp = Span::enter("kernel.topk");
                    consume(&mut state, t, strip);
                }
                secs += t0.elapsed().as_secs_f64();
            }
            (state, secs)
        });

        let mut produced: Result<()> = Ok(());
        for t in 0..strips {
            let t0 = Instant::now();
            let r = {
                let _sp = Span::enter("kernel.execute");
                contained(&mut produce, t)
            };
            stats.produce_secs += t0.elapsed().as_secs_f64();
            let strip = match r {
                Ok(s) => s,
                Err(e) => {
                    produced = Err(e);
                    break;
                }
            };
            // Hand off. A full channel means the producer is `depth − 1`
            // strips ahead — that wait is the stall the stats report.
            match tx.try_send((t, strip)) {
                Ok(()) => {}
                Err(mpsc::TrySendError::Full(v)) => {
                    let t1 = Instant::now();
                    let sent = {
                        let _sp = Span::enter("kernel.pipeline_stall");
                        tx.send(v)
                    };
                    stats.stall_secs += t1.elapsed().as_secs_f64();
                    if sent.is_err() {
                        // receiver gone: the consumer panicked; the join
                        // below reports it
                        break;
                    }
                }
                Err(mpsc::TrySendError::Disconnected(_)) => break,
            }
        }
        drop(tx); // closes the channel so the consumer drains and exits

        match handle.join() {
            Ok((state, secs)) => (Ok(state), secs, produced),
            Err(p) => (
                Err(anyhow!(
                    "kernel pipeline consumer panicked: {}",
                    panic_text(p.as_ref())
                )),
                0.0,
                produced,
            ),
        }
    });

    let state = joined?;
    produced?;
    stats.consume_secs = consume_secs;
    stats.wall_secs = wall0.elapsed().as_secs_f64();
    Ok((state, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Sum strips through the pipeline and check ordering + totals.
    fn sum_build(strips: usize, depth: usize) -> (Vec<usize>, PipelineStats) {
        let (state, stats) = run_pipeline(
            strips,
            depth,
            Vec::new(),
            |t| Ok(t * 10),
            |order: &mut Vec<usize>, t, v| {
                assert_eq!(v, t * 10);
                order.push(t);
            },
        )
        .unwrap();
        (state, stats)
    }

    #[test]
    fn consumes_in_order_at_every_depth() {
        for depth in [1, 2, 3, 8] {
            for strips in [0, 1, 2, 7] {
                let (order, stats) = sum_build(strips, depth);
                assert_eq!(order, (0..strips).collect::<Vec<_>>(), "depth {depth}");
                assert_eq!(stats.strips, strips);
                assert!(stats.wall_secs >= 0.0);
            }
        }
    }

    #[test]
    fn producer_error_surfaces() {
        let r = run_pipeline(
            4,
            2,
            (),
            |t| if t == 2 { Err(anyhow!("boom")) } else { Ok(t) },
            |_: &mut (), _, _| {},
        );
        assert!(r.is_err());
    }

    #[test]
    fn producer_panic_is_contained() {
        for depth in [1, 2] {
            let r = run_pipeline(
                4,
                depth,
                (),
                |t| {
                    if t == 1 {
                        panic!("producer exploded");
                    }
                    Ok(t)
                },
                |_: &mut (), _, _| {},
            );
            let err = format!("{:#}", r.unwrap_err());
            assert!(err.contains("producer"), "depth {depth}: {err}");
            assert!(err.contains("producer exploded"), "depth {depth}: {err}");
        }
    }

    #[test]
    fn consumer_panic_is_contained() {
        let r = run_pipeline(
            64,
            2,
            (),
            |t| Ok(t),
            |_: &mut (), t, _| {
                if t == 1 {
                    panic!("consumer exploded");
                }
            },
        );
        let err = format!("{:#}", r.unwrap_err());
        assert!(err.contains("consumer"), "{err}");
    }

    #[test]
    fn stall_is_bounded_by_wall() {
        let (_, stats) = run_pipeline(
            8,
            2,
            (),
            |t| Ok(t),
            |_: &mut (), _, _| std::thread::sleep(std::time::Duration::from_millis(1)),
        )
        .unwrap();
        assert!(stats.stall_secs <= stats.wall_secs + 1e-3);
        let f = stats.device_idle_fraction();
        assert!((0.0..=1.0).contains(&f));
    }
}
