//! Property-testing helpers (proptest is unavailable offline): seeded
//! case generators with shrinking-free "many seeds" sweeps, used by the
//! integration tests in `rust/tests/` to exercise invariants across random
//! instances.

use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Open the AOT artifact runtime for a test, or skip uniformly.
///
/// Artifact-gated tests (anything touching the PJRT runtime, encoders, or
/// trained models) call this instead of hand-rolling a `manifest.json`
/// existence check: `let Some(rt) = artifacts_or_skip() else { return };`.
/// Missing artifacts print one consistent skip line and the test passes
/// vacuously; *present but broken* artifacts panic, because that's a real
/// failure the suite must surface, not a skip.
pub fn artifacts_or_skip() -> Option<crate::runtime::Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "testkit: artifacts missing under {} (run `make artifacts`); test skipped",
            dir.display()
        );
        return None;
    }
    Some(
        crate::runtime::Runtime::open(&dir)
            .expect("artifacts present but the runtime failed to open them"),
    )
}

/// Run `f` for `n_cases` derived seeds; panics carry the failing seed so a
/// failure is reproducible with `case(seed)`.
pub fn check_cases(base_seed: u64, n_cases: usize, f: impl Fn(u64)) {
    for i in 0..n_cases {
        let seed = Rng::new(base_seed).derive(i as u64).next_u64();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if let Err(e) = result {
            eprintln!("testkit: failing case seed = {seed} (case #{i})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random symmetric similarity kernel with unit diagonal in [0, 1] — the
/// shape every submodular component consumes.
pub fn random_kernel(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        m.set(i, i, 1.0);
        for j in (i + 1)..n {
            let v = rng.f32();
            m.set(i, j, v);
            m.set(j, i, v);
        }
    }
    m
}

/// Clustered kernel: `clusters` groups with high in-group similarity —
/// lets tests assert representation-vs-diversity behaviour with known
/// ground truth. Returns (kernel, cluster assignment).
pub fn clustered_kernel(
    n: usize,
    clusters: usize,
    in_sim: f32,
    out_sim: f32,
    seed: u64,
) -> (Matrix, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let assign: Vec<usize> = (0..n).map(|i| i % clusters).collect();
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let base = if i == j {
                1.0
            } else if assign[i] == assign[j] {
                in_sim
            } else {
                out_sim
            };
            let v = (base + rng.normal_f32(0.0, 0.02)).clamp(0.0, 1.0);
            m.set(i, j, v);
            m.set(j, i, v);
        }
    }
    (m, assign)
}

/// Structurally valid synthetic selection metadata for a dataset: three
/// strided SGE subsets of ~`fraction`·n, per-class striped WRE
/// probabilities (normalized), and a strided fixed subset. Store, serve,
/// and session tests (and the artifact-free benches/examples) share this
/// instead of hand-rolling per-file variants — dataset generation needs
/// no AOT artifacts, so it works in every environment.
pub fn synthetic_metadata(
    ds: &crate::data::Dataset,
    fraction: f64,
) -> crate::coordinator::Metadata {
    let n = ds.n_train();
    let k = ds.subset_size(fraction);
    crate::coordinator::Metadata {
        dataset: ds.name().to_string(),
        fraction,
        sge_subsets: (0..3)
            .map(|r| {
                let mut s: Vec<usize> = (0..k).map(|i| (i * 11 + r * 5) % n).collect();
                s.sort_unstable();
                s.dedup();
                s
            })
            .collect(),
        wre_classes: ds
            .class_partition()
            .into_iter()
            .map(|indices| {
                let probs: Vec<f64> =
                    (0..indices.len()).map(|i| 1.0 + (i % 5) as f64).collect();
                let total: f64 = probs.iter().sum::<f64>().max(1e-12);
                crate::selection::milo::ClassProbs {
                    indices,
                    probs: probs.into_iter().map(|p| p / total).collect(),
                }
            })
            .collect(),
        fixed_dm: (0..k).map(|i| (i * 7) % n).collect(),
        preprocess_secs: 0.125,
    }
}

/// Random unit-norm embedding matrix.
pub fn random_embeddings(n: usize, e: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut m = Matrix::zeros(n, e);
    for v in m.data_mut().iter_mut() {
        *v = rng.normal_f32(0.0, 1.0);
    }
    m.l2_normalize_rows();
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_kernel_is_valid() {
        let k = random_kernel(10, 1);
        for i in 0..10 {
            assert_eq!(k.at(i, i), 1.0);
            for j in 0..10 {
                assert_eq!(k.at(i, j), k.at(j, i));
                assert!((0.0..=1.0).contains(&k.at(i, j)));
            }
        }
    }

    #[test]
    fn clustered_kernel_separates() {
        let (k, assign) = clustered_kernel(12, 3, 0.9, 0.2, 2);
        let mut in_s = 0.0f64;
        let mut out_s = 0.0f64;
        let (mut ni, mut no) = (0, 0);
        for i in 0..12 {
            for j in 0..12 {
                if i == j {
                    continue;
                }
                if assign[i] == assign[j] {
                    in_s += k.at(i, j) as f64;
                    ni += 1;
                } else {
                    out_s += k.at(i, j) as f64;
                    no += 1;
                }
            }
        }
        assert!(in_s / ni as f64 > out_s / no as f64 + 0.3);
    }

    #[test]
    fn check_cases_reports_seed() {
        // all passing
        check_cases(1, 5, |seed| assert!(seed != 0 || seed == 0));
    }
}

/// Minimal bench harness (criterion is unavailable offline): time a
/// closure over warmup + measured iterations and print a stable one-line
/// summary (used by `rust/benches/*`).
pub fn bench<R>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> R) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = std::time::Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples[0];
    let p50 = samples[samples.len() / 2];
    println!(
        "bench {name:40} mean {:>10.3}ms  p50 {:>10.3}ms  min {:>10.3}ms  (n={})",
        mean * 1e3,
        p50 * 1e3,
        min * 1e3,
        samples.len()
    );
}
