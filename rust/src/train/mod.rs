//! The trainer: runs the downstream model on strategy-selected subsets
//! through the AOT `train_step` artifact, with LR scheduling, periodic
//! evaluation and split wall-clock accounting (selection vs step vs eval —
//! the decomposition behind the paper's Fig. 1/Fig. 6 time axes).

pub mod model;
pub mod schedule;

use anyhow::Result;

pub use model::{EvalOutcome, MetaOutputs, MlpModel, StepHparams, StepOutcome};
pub use schedule::LrSchedule;

use crate::data::{Dataset, Split};
use crate::runtime::Runtime;
use crate::selection::{ModelProbe, SelectCtx, Strategy};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// One training run's configuration (the paper's per-dataset recipes are
/// encoded in [`TrainConfig::recipe_for`]).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Training epochs (the paper trains 200 on vision; we default lower —
    /// convergence at our scale is much faster — and benches override).
    pub epochs: usize,
    /// Subset fraction of the train split (1.0 = FULL).
    pub fraction: f64,
    /// Selection interval R: a fresh subset every R epochs (for adaptive
    /// strategies).
    pub r: usize,
    /// Downstream-model capacity tier (must be compiled in the manifest).
    pub hidden: usize,
    /// Parameter-init seed (1..=5 compiled); also seeds the run RNG.
    pub seed: u64,
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    pub nesterov: bool,
    pub schedule: LrSchedule,
    /// Evaluate on the validation split every this many epochs (0 = never;
    /// test split is always evaluated at the end).
    pub eval_every: usize,
    /// Stop early when this much wall-clock (seconds) is consumed
    /// (FULL-EARLYSTOP's budget matching); None = run all epochs.
    pub time_budget_secs: Option<f64>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 60,
            fraction: 0.1,
            r: 1,
            hidden: 128,
            seed: 1,
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 5e-4,
            nesterov: true,
            schedule: LrSchedule::Cosine { total: 60 },
            eval_every: 5,
            time_budget_secs: None,
        }
    }
}

impl TrainConfig {
    /// The paper's optimizer recipe for a dataset family (text datasets use
    /// Adam/lr 1e-3 in the paper; our artifact optimizer is SGD — we keep
    /// the SGD recipe with a text-appropriate LR, which converges
    /// comparably at this scale).
    pub fn recipe_for(ds: &Dataset, epochs: usize) -> TrainConfig {
        let text = matches!(
            ds.id,
            crate::data::DatasetId::Trec6Like
                | crate::data::DatasetId::ImdbLike
                | crate::data::DatasetId::RottenLike
        );
        TrainConfig {
            epochs,
            schedule: LrSchedule::Cosine { total: epochs },
            lr: if text { 0.1 } else { 0.05 },
            ..Default::default()
        }
    }

    /// Subset size for this dataset.
    pub fn k(&self, ds: &Dataset) -> usize {
        ds.subset_size(self.fraction)
    }
}

/// A point on the convergence trace.
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub epoch: usize,
    /// Wall-clock seconds since training started (selection + steps; eval
    /// excluded, matching how the paper plots time).
    pub train_secs: f64,
    pub val_accuracy: f64,
    pub val_loss: f64,
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub strategy: String,
    pub test_accuracy: f64,
    pub test_loss: f64,
    /// Selection + step time (the "training time" axis of the paper).
    pub train_secs: f64,
    /// Of which: time inside Strategy::select.
    pub selection_secs: f64,
    pub step_secs: f64,
    pub eval_secs: f64,
    pub epochs_run: usize,
    pub steps_run: usize,
    pub trace: Vec<TracePoint>,
}

impl TrainOutcome {
    /// Speedup vs a reference (FULL) training time.
    pub fn speedup_vs(&self, full_secs: f64) -> f64 {
        full_secs / self.train_secs.max(1e-9)
    }
}

/// Orchestrates one training run.
pub struct Trainer<'a> {
    rt: &'a Runtime,
    ds: &'a Dataset,
    cfg: TrainConfig,
    model: MlpModel,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, ds: &'a Dataset, cfg: TrainConfig) -> Result<Trainer<'a>> {
        let model = MlpModel::load(rt, ds.name(), cfg.hidden, cfg.seed)?;
        Ok(Trainer { rt, ds, cfg, model })
    }

    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Run the full training loop with `strategy` choosing subsets.
    pub fn run(&mut self, strategy: &mut dyn Strategy) -> Result<TrainOutcome> {
        let mut sw = Stopwatch::new();
        let mut rng = Rng::new(self.cfg.seed ^ 0x7124_1135).derive_str(&strategy.name());
        let k = self.cfg.k(self.ds);
        let mut subset: Vec<usize> = Vec::new();
        let mut trace = Vec::new();
        let mut steps = 0usize;
        let mut epochs_run = 0usize;
        let hp_base = StepHparams {
            lr: self.cfg.lr as f32,
            momentum: self.cfg.momentum as f32,
            weight_decay: self.cfg.weight_decay as f32,
            nesterov: self.cfg.nesterov,
        };

        // Warm the executables outside the timed region (compile-once cost
        // is shared by all strategies and excluded like the paper excludes
        // CUDA warmup).
        self.rt
            .prepare(&format!("train_step_{}_h{}", self.ds.name(), self.cfg.hidden))?;
        self.rt
            .prepare(&format!("eval_{}_h{}", self.ds.name(), self.cfg.hidden))?;

        for epoch in 0..self.cfg.epochs {
            epochs_run = epoch + 1;
            // (re)select
            let need_select = subset.is_empty()
                || (strategy.is_adaptive() && epoch % self.cfg.r == 0);
            if need_select {
                let mut ctx = SelectCtx::model_agnostic(
                    self.ds,
                    epoch,
                    self.cfg.epochs,
                    k,
                    &mut rng,
                )
                .with_probe(ModelProbe::new(self.rt, &mut self.model));
                subset = sw.time("selection", || strategy.select(&mut ctx))?;
                anyhow::ensure!(!subset.is_empty(), "strategy returned empty subset");
            }
            // one epoch of mini-batch SGD over the subset
            let lr = (self.cfg.lr * self.cfg.schedule.factor(epoch)) as f32;
            let hp = StepHparams { lr, ..hp_base };
            let mut order = subset.clone();
            rng.shuffle(&mut order);
            let batch = self.model.batch;
            sw.time("steps", || -> Result<()> {
                for chunk in order.chunks(batch) {
                    self.model.train_step(self.rt, self.ds, chunk, hp)?;
                    steps += 1;
                }
                Ok(())
            })?;
            // periodic validation
            if self.cfg.eval_every > 0
                && (epoch % self.cfg.eval_every == 0 || epoch + 1 == self.cfg.epochs)
            {
                let ev = sw.time("eval", || {
                    self.model.evaluate(self.rt, self.ds, Split::Val)
                })?;
                trace.push(TracePoint {
                    epoch,
                    train_secs: sw.secs("selection") + sw.secs("steps"),
                    val_accuracy: ev.accuracy,
                    val_loss: ev.loss,
                });
            }
            // time budget (FULL-EARLYSTOP)
            if let Some(budget) = self.cfg.time_budget_secs {
                if sw.secs("selection") + sw.secs("steps") >= budget {
                    break;
                }
            }
        }

        let test = sw.time("eval", || self.model.evaluate(self.rt, self.ds, Split::Test))?;
        Ok(TrainOutcome {
            strategy: strategy.name(),
            test_accuracy: test.accuracy,
            test_loss: test.loss,
            train_secs: sw.secs("selection") + sw.secs("steps"),
            selection_secs: sw.secs("selection"),
            step_secs: sw.secs("steps"),
            eval_secs: sw.secs("eval"),
            epochs_run,
            steps_run: steps,
            trace,
        })
    }

    /// Consume the trainer and return the trained model (proxy-encoder
    /// path needs the parameters afterwards).
    pub fn into_model(self) -> MlpModel {
        self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::selection::{AdaptiveRandomStrategy, FullStrategy, RandomStrategy};

    fn runtime() -> Option<Runtime> {
        crate::testkit::artifacts_or_skip()
    }

    #[test]
    fn trains_and_beats_chance() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Trec6Like.generate(1);
        let cfg = TrainConfig {
            epochs: 12,
            fraction: 0.3,
            eval_every: 4,
            schedule: LrSchedule::Cosine { total: 12 },
            ..TrainConfig::recipe_for(&ds, 12)
        };
        let mut t = Trainer::new(&rt, &ds, cfg).unwrap();
        let out = t.run(&mut AdaptiveRandomStrategy).unwrap();
        assert!(out.test_accuracy > 1.0 / 6.0 + 0.1, "acc {}", out.test_accuracy);
        assert!(!out.trace.is_empty());
        assert!(out.steps_run > 0);
        assert!(out.train_secs > 0.0);
    }

    #[test]
    fn subset_training_faster_than_full() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Trec6Like.generate(2);
        let mk = |fraction: f64| TrainConfig {
            epochs: 6,
            fraction,
            eval_every: 0,
            ..TrainConfig::recipe_for(&ds, 6)
        };
        let full = Trainer::new(&rt, &ds, mk(1.0))
            .unwrap()
            .run(&mut FullStrategy)
            .unwrap();
        let sub = Trainer::new(&rt, &ds, mk(0.1))
            .unwrap()
            .run(&mut AdaptiveRandomStrategy)
            .unwrap();
        assert!(
            sub.train_secs < full.train_secs,
            "subset {} !< full {}",
            sub.train_secs,
            full.train_secs
        );
        assert!(sub.speedup_vs(full.train_secs) > 1.5);
    }

    #[test]
    fn fixed_random_selects_once() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Trec6Like.generate(3);
        let cfg = TrainConfig {
            epochs: 4,
            fraction: 0.05,
            eval_every: 0,
            ..TrainConfig::recipe_for(&ds, 4)
        };
        let mut strat = RandomStrategy::new();
        let out = Trainer::new(&rt, &ds, cfg).unwrap().run(&mut strat).unwrap();
        // selection happens exactly once for non-adaptive strategies:
        // 4 epochs * ceil(120/128) = 4 steps
        assert_eq!(out.steps_run, 4);
    }

    #[test]
    fn early_stop_budget_respected() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Trec6Like.generate(4);
        let cfg = TrainConfig {
            epochs: 1000,
            fraction: 1.0,
            eval_every: 0,
            time_budget_secs: Some(0.05),
            ..TrainConfig::recipe_for(&ds, 1000)
        };
        let out = Trainer::new(&rt, &ds, cfg).unwrap().run(&mut FullStrategy).unwrap();
        assert!(out.epochs_run < 1000, "budget ignored: {} epochs", out.epochs_run);
    }

    #[test]
    fn deterministic_given_seed() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Trec6Like.generate(5);
        let cfg = TrainConfig {
            epochs: 3,
            fraction: 0.1,
            eval_every: 0,
            ..TrainConfig::recipe_for(&ds, 3)
        };
        let a = Trainer::new(&rt, &ds, cfg.clone())
            .unwrap()
            .run(&mut AdaptiveRandomStrategy)
            .unwrap();
        let b = Trainer::new(&rt, &ds, cfg)
            .unwrap()
            .run(&mut AdaptiveRandomStrategy)
            .unwrap();
        assert_eq!(a.test_accuracy, b.test_accuracy);
        assert_eq!(a.test_loss, b.test_loss);
    }
}
