//! Learning-rate schedules (the paper's recipes: cosine annealing for most
//! runs, cyclic for ImageNet, linear step-decay in the HPO search space).

/// LR schedule evaluated per epoch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    Constant,
    /// Cosine annealing from base LR to ~0 over `total` epochs (SGDR-style,
    /// single phase, as the paper uses).
    Cosine { total: usize },
    /// Multiply by `gamma` every `every` epochs (the HPO space's
    /// "linear decay by γ after every 20 epochs").
    StepDecay { gamma: f64, every: usize },
    /// Triangular cyclic LR between `base·min_ratio` and `base` with the
    /// given period (the ImageNet recipe's cyclic scheduler).
    Cyclic { period: usize, min_ratio: f64 },
}

impl LrSchedule {
    /// LR multiplier at `epoch` (multiplies the base LR).
    pub fn factor(&self, epoch: usize) -> f64 {
        match *self {
            LrSchedule::Constant => 1.0,
            LrSchedule::Cosine { total } => {
                let t = (epoch as f64 / total.max(1) as f64).min(1.0);
                0.5 * (1.0 + (std::f64::consts::PI * t).cos())
            }
            LrSchedule::StepDecay { gamma, every } => {
                gamma.powi((epoch / every.max(1)) as i32)
            }
            LrSchedule::Cyclic { period, min_ratio } => {
                let p = period.max(2);
                let phase = epoch % p;
                let half = p as f64 / 2.0;
                let tri = if (phase as f64) < half {
                    phase as f64 / half
                } else {
                    2.0 - phase as f64 / half
                };
                min_ratio + (1.0 - min_ratio) * tri
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LrSchedule::Constant => "constant",
            LrSchedule::Cosine { .. } => "cosine",
            LrSchedule::StepDecay { .. } => "step_decay",
            LrSchedule::Cyclic { .. } => "cyclic",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_endpoints() {
        let s = LrSchedule::Cosine { total: 100 };
        assert!((s.factor(0) - 1.0).abs() < 1e-12);
        assert!(s.factor(100) < 1e-9);
        assert!((s.factor(50) - 0.5).abs() < 1e-9);
        // monotone decreasing
        for e in 1..100 {
            assert!(s.factor(e) <= s.factor(e - 1) + 1e-12);
        }
    }

    #[test]
    fn step_decay() {
        let s = LrSchedule::StepDecay { gamma: 0.1, every: 20 };
        assert_eq!(s.factor(0), 1.0);
        assert_eq!(s.factor(19), 1.0);
        assert!((s.factor(20) - 0.1).abs() < 1e-12);
        assert!((s.factor(45) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn cyclic_bounds_and_period() {
        let s = LrSchedule::Cyclic { period: 10, min_ratio: 0.1 };
        for e in 0..40 {
            let f = s.factor(e);
            assert!((0.1 - 1e-9..=1.0 + 1e-9).contains(&f), "epoch {e}: {f}");
        }
        assert!((s.factor(0) - 0.1).abs() < 1e-9);
        assert!((s.factor(5) - 1.0).abs() < 1e-9);
        assert!((s.factor(10) - s.factor(0)).abs() < 1e-9);
    }
}
