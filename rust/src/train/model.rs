//! Downstream-model handle: host-side parameter state + the AOT train /
//! eval / meta artifacts that operate on it.
//!
//! The model is a black box to MILO (that is the paper's thesis); this
//! struct is the only place the coordinator touches its parameters, and
//! everything it does goes through the three compiled graphs:
//! `train_step_{ds}_h{h}`, `eval_{ds}_h{h}`, `meta_{ds}_h{h}`.

use anyhow::{Context, Result};

use crate::data::{Dataset, Split};
use crate::runtime::{Arg, Runtime};
use crate::tensor::read_f32_blob;

/// Hyper-parameters fed to the train-step artifact at every call (runtime
/// scalars — LR schedules stay in Rust).
#[derive(Clone, Copy, Debug)]
pub struct StepHparams {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub nesterov: bool,
}

/// Result of one train step.
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    pub loss: f32,
    pub correct: f32,
    pub examples: f32,
}

/// Aggregate eval result.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalOutcome {
    pub loss: f64,
    pub accuracy: f64,
    pub examples: usize,
}

/// Per-sample metadata from the meta artifact (model-dependent metrics the
/// gradient-based baselines consume).
#[derive(Clone, Debug)]
pub struct MetaOutputs {
    /// per-sample cross-entropy
    pub losses: Vec<f32>,
    /// per-sample EL2N = ‖softmax − onehot‖₂
    pub el2n: Vec<f32>,
    /// last-layer gradient embeddings, row-major `n × classes`
    pub gemb: Vec<f32>,
    pub classes: usize,
}

/// Host-side MLP state bound to a (dataset, hidden) artifact family.
pub struct MlpModel {
    pub dataset: String,
    pub hidden: usize,
    pub classes: usize,
    pub input_dim: usize,
    pub batch: usize,
    params: Vec<Vec<f32>>,
    momentum: Vec<Vec<f32>>,
    train_artifact: String,
    eval_artifact: String,
    meta_artifact: String,
    // scratch buffers reused across steps (perf: no per-step allocation)
    xbuf: Vec<f32>,
    ybuf: Vec<i32>,
    wbuf: Vec<f32>,
}

impl MlpModel {
    /// Load the He-init parameters for `seed` from the artifact store.
    pub fn load(rt: &Runtime, dataset: &str, hidden: usize, seed: u64) -> Result<MlpModel> {
        let man = rt.manifest();
        let cfg = man.dataset(dataset)?;
        let shapes = man.param_shapes(dataset, hidden)?;
        let blob = read_f32_blob(&man.params_path(dataset, hidden, seed))
            .with_context(|| format!("params for {dataset} h{hidden} seed {seed}"))?;
        let mut params = Vec::with_capacity(shapes.len());
        let mut off = 0usize;
        for shape in &shapes {
            let n: usize = shape.iter().product();
            params.push(blob[off..off + n].to_vec());
            off += n;
        }
        anyhow::ensure!(off == blob.len(), "param blob size mismatch");
        let momentum = params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        let batch = man.batch;
        Ok(MlpModel {
            dataset: dataset.to_string(),
            hidden,
            classes: cfg.classes,
            input_dim: cfg.input_dim,
            batch,
            params,
            momentum,
            train_artifact: format!("train_step_{dataset}_h{hidden}"),
            eval_artifact: format!("eval_{dataset}_h{hidden}"),
            meta_artifact: format!("meta_{dataset}_h{hidden}"),
            xbuf: vec![0.0; batch * cfg.input_dim],
            ybuf: vec![0; batch],
            wbuf: vec![0.0; batch],
        })
    }

    /// Total parameter count (for reporting).
    pub fn n_params(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }

    /// Raw parameter access (proxy-encoder path and tests).
    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// Reset momentum (used when a tuner reuses a model across trials).
    pub fn reset_momentum(&mut self) {
        for m in self.momentum.iter_mut() {
            m.iter_mut().for_each(|v| *v = 0.0);
        }
    }

    fn fill_batch(&mut self, ds: &Dataset, split: Split, idx: &[usize]) {
        debug_assert!(idx.len() <= self.batch);
        let x = ds.x(split);
        let y = ds.y(split);
        let d = self.input_dim;
        for (bi, &i) in idx.iter().enumerate() {
            self.xbuf[bi * d..(bi + 1) * d].copy_from_slice(x.row(i));
            self.ybuf[bi] = y[i] as i32;
            self.wbuf[bi] = 1.0;
        }
        // zero-pad the tail
        for bi in idx.len()..self.batch {
            self.xbuf[bi * d..(bi + 1) * d].iter_mut().for_each(|v| *v = 0.0);
            self.ybuf[bi] = 0;
            self.wbuf[bi] = 0.0;
        }
    }

    /// Run one train step on `idx` (≤ batch) train samples.
    pub fn train_step(
        &mut self,
        rt: &Runtime,
        ds: &Dataset,
        idx: &[usize],
        hp: StepHparams,
    ) -> Result<StepOutcome> {
        self.fill_batch(ds, Split::Train, idx);
        let mut args: Vec<Arg<'_>> = Vec::with_capacity(19);
        for p in &self.params {
            args.push(Arg::F32(p));
        }
        for m in &self.momentum {
            args.push(Arg::F32(m));
        }
        args.push(Arg::F32(&self.xbuf));
        args.push(Arg::I32(&self.ybuf));
        args.push(Arg::F32(&self.wbuf));
        args.push(Arg::Scalar(hp.lr));
        args.push(Arg::Scalar(hp.momentum));
        args.push(Arg::Scalar(hp.weight_decay));
        args.push(Arg::Scalar(if hp.nesterov { 1.0 } else { 0.0 }));
        let mut out = rt.execute(&self.train_artifact, &args)?;
        anyhow::ensure!(out.len() == 14, "train_step returned {}", out.len());
        let correct = out.pop().unwrap()[0];
        let loss = out.pop().unwrap()[0];
        // outputs 0..6 new params, 6..12 new momentum
        for (m, v) in self.momentum.iter_mut().rev().zip(out.drain(6..).rev()) {
            *m = v;
        }
        for (p, v) in self.params.iter_mut().zip(out) {
            *p = v;
        }
        Ok(StepOutcome { loss, correct, examples: idx.len() as f32 })
    }

    /// Evaluate loss/accuracy over a whole split.
    pub fn evaluate(&mut self, rt: &Runtime, ds: &Dataset, split: Split) -> Result<EvalOutcome> {
        let n = ds.y(split).len();
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let all: Vec<usize> = (0..n).collect();
        for chunk in all.chunks(self.batch) {
            self.fill_batch(ds, split, chunk);
            let mut args: Vec<Arg<'_>> = Vec::with_capacity(9);
            for p in &self.params {
                args.push(Arg::F32(p));
            }
            args.push(Arg::F32(&self.xbuf));
            args.push(Arg::I32(&self.ybuf));
            args.push(Arg::F32(&self.wbuf));
            let out = rt.execute(&self.eval_artifact, &args)?;
            loss_sum += out[0][0] as f64;
            correct += out[1][0] as f64;
        }
        Ok(EvalOutcome {
            loss: loss_sum / n as f64,
            accuracy: correct / n as f64,
            examples: n,
        })
    }

    /// Compute per-sample metadata for the given indices of `split` (or the
    /// whole split when `idx` is `None`). This is the expensive
    /// model-dependent pass the gradient-based baselines pay every R epochs
    /// (Glister additionally runs it on the validation split).
    pub fn meta(
        &mut self,
        rt: &Runtime,
        ds: &Dataset,
        split: Split,
        idx: Option<&[usize]>,
    ) -> Result<MetaOutputs> {
        let all: Vec<usize>;
        let indices: &[usize] = match idx {
            Some(v) => v,
            None => {
                all = (0..ds.y(split).len()).collect();
                &all
            }
        };
        let c = self.classes;
        let mut losses = Vec::with_capacity(indices.len());
        let mut el2n = Vec::with_capacity(indices.len());
        let mut gemb = Vec::with_capacity(indices.len() * c);
        for chunk in indices.chunks(self.batch) {
            self.fill_batch(ds, split, chunk);
            let mut args: Vec<Arg<'_>> = Vec::with_capacity(9);
            for p in &self.params {
                args.push(Arg::F32(p));
            }
            args.push(Arg::F32(&self.xbuf));
            args.push(Arg::I32(&self.ybuf));
            args.push(Arg::F32(&self.wbuf));
            let out = rt.execute(&self.meta_artifact, &args)?;
            losses.extend_from_slice(&out[0][..chunk.len()]);
            el2n.extend_from_slice(&out[1][..chunk.len()]);
            gemb.extend_from_slice(&out[2][..chunk.len() * c]);
        }
        Ok(MetaOutputs { losses, el2n, gemb, classes: c })
    }

    /// Proxy features (App. H.2): penultimate activations for arbitrary
    /// train rows, via the `proxy_{ds}_h{h}` artifact (only compiled for
    /// the proxy datasets).
    pub fn proxy_features(
        &mut self,
        rt: &Runtime,
        ds: &Dataset,
        indices: &[usize],
    ) -> Result<crate::tensor::Matrix> {
        let name = format!("proxy_{}_h{}", self.dataset, self.hidden);
        let h = self.hidden;
        let mut out = crate::tensor::Matrix::zeros(indices.len(), h);
        let mut at = 0usize;
        for chunk in indices.chunks(self.batch) {
            self.fill_batch(ds, Split::Train, chunk);
            // the proxy artifact takes only the four parameters it reads
            // (w1, b1, w2, b2) — see model.py::make_proxy_features
            let mut args: Vec<Arg<'_>> = Vec::with_capacity(5);
            for p in &self.params[..4] {
                args.push(Arg::F32(p));
            }
            args.push(Arg::F32(&self.xbuf));
            let res = rt.execute(&name, &args)?;
            for r in 0..chunk.len() {
                out.row_mut(at + r).copy_from_slice(&res[0][r * h..(r + 1) * h]);
            }
            at += chunk.len();
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;

    fn runtime() -> Option<Runtime> {
        crate::testkit::artifacts_or_skip()
    }

    #[test]
    fn load_and_count_params() {
        let Some(rt) = runtime() else { return };
        let m = MlpModel::load(&rt, "cifar10", 128, 1).unwrap();
        // 64*128 + 128 + 128*128 + 128 + 128*10 + 10
        assert_eq!(m.n_params(), 64 * 128 + 128 + 128 * 128 + 128 + 128 * 10 + 10);
        assert!(MlpModel::load(&rt, "cifar10", 999, 1).is_err());
    }

    #[test]
    fn train_step_reduces_loss_on_fixed_batch() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Trec6Like.generate(1);
        let mut m = MlpModel::load(&rt, "trec6", 128, 1).unwrap();
        let idx: Vec<usize> = (0..64).collect();
        let hp = StepHparams { lr: 0.1, momentum: 0.9, weight_decay: 0.0, nesterov: true };
        let first = m.train_step(&rt, &ds, &idx, hp).unwrap();
        let mut last = first;
        for _ in 0..25 {
            last = m.train_step(&rt, &ds, &idx, hp).unwrap();
        }
        assert!(
            last.loss < first.loss * 0.7,
            "loss did not drop: {} -> {}",
            first.loss,
            last.loss
        );
        assert!(last.correct >= first.correct);
    }

    #[test]
    fn evaluate_counts_whole_split() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Trec6Like.generate(2);
        let mut m = MlpModel::load(&rt, "trec6", 128, 2).unwrap();
        let out = m.evaluate(&rt, &ds, Split::Test).unwrap();
        assert_eq!(out.examples, ds.test_y.len());
        assert!(out.loss > 0.0);
        assert!((0.0..=1.0).contains(&out.accuracy));
    }

    #[test]
    fn meta_shapes_and_bounds() {
        let Some(rt) = runtime() else { return };
        let ds = DatasetId::Trec6Like.generate(3);
        let mut m = MlpModel::load(&rt, "trec6", 128, 3).unwrap();
        let idx: Vec<usize> = (0..200).collect();
        let meta = m.meta(&rt, &ds, Split::Train, Some(&idx)).unwrap();
        assert_eq!(meta.losses.len(), 200);
        assert_eq!(meta.el2n.len(), 200);
        assert_eq!(meta.gemb.len(), 200 * 6);
        for &e in &meta.el2n {
            assert!((0.0..=1.5).contains(&e), "el2n {e}");
        }
        // gradient-embedding rows sum to ~0 (softmax - onehot)
        for r in 0..200 {
            let s: f32 = meta.gemb[r * 6..(r + 1) * 6].iter().sum();
            assert!(s.abs() < 1e-4, "row {r} sums to {s}");
        }
    }

    #[test]
    fn different_seeds_different_params() {
        let Some(rt) = runtime() else { return };
        let a = MlpModel::load(&rt, "cifar10", 128, 1).unwrap();
        let b = MlpModel::load(&rt, "cifar10", 128, 2).unwrap();
        assert_ne!(a.params()[0], b.params()[0]);
    }
}
