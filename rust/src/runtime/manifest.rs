//! `artifacts/manifest.json` parsing — the contract between `aot.py` (L2
//! build time) and the Rust coordinator (L3 run time).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element type of an artifact input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// Declared input of an artifact (shape + dtype).
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl InputSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT-compiled artifact.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub inputs: Vec<InputSpec>,
    /// kind-specific metadata (dataset, hidden, tile, metric, …)
    pub dataset: Option<String>,
    pub hidden: Option<usize>,
    pub classes: Option<usize>,
    pub input_dim: Option<usize>,
    pub metric: Option<String>,
    pub embed_dim: Option<usize>,
    pub tile: Option<usize>,
    /// Per-tile candidate width of a fused top-k artifact
    /// (`topk_*` / `embed_sim_topk_*`); `None` for everything else.
    pub k: Option<usize>,
}

/// Per-dataset shape configuration (must match rust/src/data generators).
#[derive(Clone, Debug)]
pub struct DatasetCfg {
    pub input_dim: usize,
    pub classes: usize,
    pub hidden: Vec<usize>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub base_dir: PathBuf,
    pub batch: usize,
    pub embed_dim: usize,
    pub sim_tile: usize,
    pub param_seeds: Vec<u64>,
    pub datasets: BTreeMap<String, DatasetCfg>,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    pub digest: String,
}

impl Manifest {
    /// Load and validate `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;

        let mut datasets = BTreeMap::new();
        for (name, cfg) in v.get("datasets")?.as_obj()? {
            datasets.insert(
                name.clone(),
                DatasetCfg {
                    input_dim: cfg.get("input_dim")?.as_usize()?,
                    classes: cfg.get("classes")?.as_usize()?,
                    hidden: cfg
                        .get("hidden")?
                        .as_arr()?
                        .iter()
                        .map(|h| h.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in v.get("artifacts")?.as_arr()? {
            let name = a.get("name")?.as_str()?.to_string();
            let file = dir.join(a.get("file")?.as_str()?);
            if !file.exists() {
                bail!("artifact file missing: {}", file.display());
            }
            let inputs = a
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|i| -> Result<InputSpec> {
                    let shape = i
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?;
                    let dtype = match i.get("dtype")?.as_str()? {
                        "float32" => DType::F32,
                        "int32" => DType::I32,
                        other => bail!("unsupported dtype {other}"),
                    };
                    Ok(InputSpec { shape, dtype })
                })
                .collect::<Result<Vec<_>>>()?;
            let get_usize = |k: &str| a.opt(k).and_then(|x| x.as_usize().ok());
            let get_str = |k: &str| a.opt(k).and_then(|x| x.as_str().ok().map(String::from));
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    name,
                    file,
                    kind: a.get("kind")?.as_str()?.to_string(),
                    inputs,
                    dataset: get_str("dataset"),
                    hidden: get_usize("hidden"),
                    classes: get_usize("classes"),
                    input_dim: get_usize("input_dim"),
                    metric: get_str("metric"),
                    embed_dim: get_usize("embed_dim"),
                    tile: get_usize("tile"),
                    k: get_usize("k"),
                },
            );
        }

        Ok(Manifest {
            base_dir: dir,
            batch: v.get("batch")?.as_usize()?,
            embed_dim: v.get("embed_dim")?.as_usize()?,
            sim_tile: v.get("sim_tile")?.as_usize()?,
            param_seeds: v
                .get("param_seeds")?
                .as_arr()?
                .iter()
                .map(|s| s.as_usize().map(|x| x as u64))
                .collect::<Result<Vec<_>>>()?,
            datasets,
            artifacts,
            digest: v.get("digest")?.as_str()?.to_string(),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetCfg> {
        self.datasets
            .get(name)
            .ok_or_else(|| anyhow!("dataset {name:?} not in manifest"))
    }

    /// Path of a serialized He-init parameter blob.
    pub fn params_path(&self, dataset: &str, hidden: usize, seed: u64) -> PathBuf {
        self.base_dir
            .join("params")
            .join(format!("{dataset}_h{hidden}_s{seed}.bin"))
    }

    /// MLP parameter shapes for (dataset, hidden): mirrors MlpSpec.param_shapes.
    pub fn param_shapes(&self, dataset: &str, hidden: usize) -> Result<Vec<Vec<usize>>> {
        let cfg = self.dataset(dataset)?;
        if !cfg.hidden.contains(&hidden) {
            bail!("hidden={hidden} not compiled for {dataset} (have {:?})", cfg.hidden);
        }
        let (d, h, c) = (cfg.input_dim, hidden, cfg.classes);
        Ok(vec![
            vec![d, h],
            vec![h],
            vec![h, h],
            vec![h],
            vec![h, c],
            vec![c],
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests run against the real built artifacts when present.
    fn manifest() -> Option<Manifest> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_built_manifest() {
        let Some(m) = manifest() else { return };
        assert_eq!(m.batch, 128);
        assert!(m.datasets.contains_key("cifar10"));
        assert!(m.artifacts.contains_key("encoder_cifar10"));
        assert!(m.artifacts.contains_key("train_step_cifar10_h128"));
        assert_eq!(m.param_seeds, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn param_shapes_consistent() {
        let Some(m) = manifest() else { return };
        let shapes = m.param_shapes("cifar10", 128).unwrap();
        assert_eq!(shapes[0], vec![64, 128]);
        assert_eq!(shapes[5], vec![10]);
        assert!(m.param_shapes("cifar10", 999).is_err());
        // blob size matches the declared shapes
        let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        let blob = std::fs::read(m.params_path("cifar10", 128, 1)).unwrap();
        assert_eq!(blob.len(), total * 4);
    }

    #[test]
    fn train_step_input_arity() {
        let Some(m) = manifest() else { return };
        let a = m.artifact("train_step_cifar10_h128").unwrap();
        // 6 params + 6 momenta + x + y + wt + 4 scalars = 19
        assert_eq!(a.inputs.len(), 19);
        assert_eq!(a.inputs[12].shape, vec![128, 64]);
        assert_eq!(a.inputs[13].dtype, DType::I32);
    }
}
