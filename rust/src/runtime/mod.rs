//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
//!
//! One [`Runtime`] per process: a CPU `PjRtClient`, the parsed
//! [`Manifest`], and a lazy cache of compiled executables keyed by
//! artifact name. Compilation happens at most once per artifact; execution
//! is a thin wrapper that packs `f32`/`i32` host slices into literals,
//! runs, and unpacks the single result tuple (all artifacts are lowered
//! with `return_tuple=True`).
//!
//! The xla crate's handles are raw C pointers (`!Send`), so a `Runtime`
//! must stay on the thread that created it; the coordinator keeps all PJRT
//! work on the main thread and fans out only pure-Rust work.

pub mod manifest;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::obs::{Histogram, MetricsRegistry};

pub use manifest::{ArtifactEntry, DType, DatasetCfg, InputSpec, Manifest};

/// Host-side argument for an artifact execution.
pub enum Arg<'a> {
    /// f32 tensor data (row-major, must match the declared input shape).
    F32(&'a [f32]),
    /// i32 tensor data.
    I32(&'a [i32]),
    /// f32 scalar.
    Scalar(f32),
}

/// Execution statistics (for EXPERIMENTS.md §Perf and the perf benches).
#[derive(Default, Debug, Clone, Copy)]
pub struct RuntimeStats {
    pub compilations: usize,
    pub executions: usize,
    pub compile_secs: f64,
    pub execute_secs: f64,
}

/// Compile-path statistics (cold path, guarded by the executable cache's
/// `RefCell` discipline).
#[derive(Default)]
struct CompileStats {
    compilations: usize,
    compile_secs: f64,
}

/// PJRT client + compiled-executable cache over an artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    compile_stats: RefCell<CompileStats>,
    // The execute path is hot (every similarity strip) and may be timed
    // from pipeline threads observing `stats()` concurrently, so it
    // avoids `RefCell` borrows: two relaxed atomics plus a histogram
    // handle resolved once at `open`.
    executions: AtomicU64,
    execute_ns: AtomicU64,
    execute_hist: Arc<Histogram>,
}

impl Runtime {
    /// Open the artifacts directory (usually `"artifacts"`) and create the
    /// CPU PJRT client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(BTreeMap::new()),
            compile_stats: RefCell::new(CompileStats::default()),
            executions: AtomicU64::new(0),
            execute_ns: AtomicU64::new(0),
            execute_hist: MetricsRegistry::global().histogram("runtime.execute_latency_ns"),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        let c = self.compile_stats.borrow();
        RuntimeStats {
            compilations: c.compilations,
            executions: self.executions.load(Ordering::Relaxed) as usize,
            compile_secs: c.compile_secs,
            execute_secs: self.execute_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }

    /// Ensure an artifact is compiled (warm the cache).
    pub fn prepare(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.artifact(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            entry.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        {
            let mut st = self.compile_stats.borrow_mut();
            st.compilations += 1;
            st.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` with host arguments; returns the unpacked
    /// output tuple as f32 vectors (all artifact outputs are f32).
    pub fn execute(&self, name: &str, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        self.prepare(name)?;
        let entry = self.manifest.artifact(name)?;
        if args.len() != entry.inputs.len() {
            anyhow::bail!(
                "{name}: expected {} args, got {}",
                entry.inputs.len(),
                args.len()
            );
        }
        // Pack literals according to the declared specs.
        let mut literals = Vec::with_capacity(args.len());
        for (i, (arg, spec)) in args.iter().zip(&entry.inputs).enumerate() {
            literals.push(pack_literal(arg, spec).with_context(|| {
                format!("{name}: packing arg {i} (shape {:?})", spec.shape)
            })?);
        }
        let cache = self.cache.borrow();
        let exe = cache.get(name).expect("prepared above");
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        let elapsed = t0.elapsed();
        self.executions.fetch_add(1, Ordering::Relaxed);
        self.execute_ns
            .fetch_add(elapsed.as_nanos().min(u64::MAX as u128) as u64, Ordering::Relaxed);
        // per-execution latency distribution, honoring the obs kill switch
        if crate::obs::enabled() {
            self.execute_hist.record_duration(elapsed);
        }
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} result: {e:?}"))?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }

    /// Number of artifacts compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

fn pack_literal(arg: &Arg<'_>, spec: &InputSpec) -> Result<xla::Literal> {
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    match (arg, spec.dtype) {
        (Arg::Scalar(v), DType::F32) => {
            if !spec.shape.is_empty() && spec.elements() != 1 {
                anyhow::bail!("scalar arg for non-scalar input {:?}", spec.shape);
            }
            if spec.shape.is_empty() {
                Ok(xla::Literal::scalar(*v))
            } else {
                Ok(xla::Literal::vec1(&[*v])
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape: {e:?}"))?)
            }
        }
        (Arg::F32(data), DType::F32) => {
            if data.len() != spec.elements() {
                anyhow::bail!(
                    "f32 arg has {} elems, input wants {:?}",
                    data.len(),
                    spec.shape
                );
            }
            Ok(xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape: {e:?}"))?)
        }
        (Arg::I32(data), DType::I32) => {
            if data.len() != spec.elements() {
                anyhow::bail!(
                    "i32 arg has {} elems, input wants {:?}",
                    data.len(),
                    spec.shape
                );
            }
            Ok(xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape: {e:?}"))?)
        }
        (_, want) => anyhow::bail!("dtype mismatch: input wants {want:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        crate::testkit::artifacts_or_skip()
    }

    #[test]
    fn encoder_executes_and_normalizes() {
        let Some(rt) = runtime() else { return };
        let b = rt.manifest().batch;
        let d = rt.manifest().dataset("cifar10").unwrap().input_dim;
        let x: Vec<f32> = (0..b * d).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect();
        let out = rt.execute("encoder_cifar10", &[Arg::F32(&x)]).unwrap();
        assert_eq!(out.len(), 1);
        let e = rt.manifest().embed_dim;
        assert_eq!(out[0].len(), b * e);
        // rows are unit-norm
        for r in 0..b {
            let n: f32 = out[0][r * e..(r + 1) * e].iter().map(|v| v * v).sum();
            assert!((n - 1.0).abs() < 1e-4, "row {r} norm^2 {n}");
        }
    }

    #[test]
    fn execute_validates_arity_and_shape() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("encoder_cifar10", &[]).is_err());
        let bad = vec![0.0f32; 7];
        assert!(rt.execute("encoder_cifar10", &[Arg::F32(&bad)]).is_err());
        assert!(rt.execute("no_such_artifact", &[]).is_err());
    }

    #[test]
    fn executable_cache_reused() {
        let Some(rt) = runtime() else { return };
        let b = rt.manifest().batch;
        let d = rt.manifest().dataset("trec6").unwrap().input_dim;
        let x = vec![0.5f32; b * d];
        rt.execute("encoder_trec6", &[Arg::F32(&x)]).unwrap();
        let c1 = rt.stats().compilations;
        rt.execute("encoder_trec6", &[Arg::F32(&x)]).unwrap();
        assert_eq!(rt.stats().compilations, c1, "second call must hit cache");
        assert!(rt.stats().executions >= 2);
    }

    #[test]
    fn sim_cosine_artifact_matches_native() {
        let Some(rt) = runtime() else { return };
        let t = rt.manifest().sim_tile;
        let e = rt.manifest().embed_dim;
        let mut rng = crate::util::rng::Rng::new(9);
        let a: Vec<f32> = (0..t * e).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let out = rt
            .execute(&format!("sim_cosine_e{e}"), &[Arg::F32(&a), Arg::F32(&a)])
            .unwrap();
        let s = &out[0];
        assert_eq!(s.len(), t * t);
        // diagonal ~1, range [0,1]
        for i in 0..t {
            assert!((s[i * t + i] - 1.0).abs() < 1e-4);
        }
        assert!(s.iter().all(|&v| (-1e-4..=1.0 + 1e-4).contains(&v)));
    }
}
