//! Dataset substrate.
//!
//! The paper evaluates on CIFAR10/100, TinyImageNet, TREC6, IMDB, Rotten
//! Tomatoes (+ MedMNIST variants in the appendix). Those corpora are not
//! available in this offline environment, so per the substitution rule in
//! DESIGN.md §2 we build generators that reproduce the *geometry* MILO's
//! mechanisms depend on:
//!
//! * [`gaussmix`] — multi-modal Gaussian class manifolds with dense "easy"
//!   cores and sparse "hard" tails (vision-like stand-ins). The density
//!   gradient is exactly what representation vs diversity set functions
//!   trade off over (paper Fig. 4, Tables 1-2).
//! * [`text`] — topic-mixture bag-of-features documents with controlled
//!   class overlap (text-like stand-ins).
//! * [`glyphs`] — procedurally *rendered* 16×16 digit images (strokes +
//!   affine jitter + noise): a real pixel-space workload for the
//!   end-to-end example, learnable but non-Gaussian.
//!
//! Every dataset carries train/val/test splits (the paper's 90/10 split
//! protocol) and a per-sample ground-truth hardness score from the
//! generator, used to validate the EL2N analysis of Tables 1-2.

pub mod gaussmix;
pub mod glyphs;
pub mod text;

use anyhow::{bail, Result};

use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Which split of a dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

/// The synthetic dataset registry. Names must match `aot.py::DATASETS`
/// (the artifact shapes are keyed by them).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DatasetId {
    Cifar10Like,
    Cifar100Like,
    TinyImagenetLike,
    OrganaLike,
    DermaLike,
    Trec6Like,
    ImdbLike,
    RottenLike,
    Glyphs,
}

impl DatasetId {
    pub const ALL: [DatasetId; 9] = [
        DatasetId::Cifar10Like,
        DatasetId::Cifar100Like,
        DatasetId::TinyImagenetLike,
        DatasetId::OrganaLike,
        DatasetId::DermaLike,
        DatasetId::Trec6Like,
        DatasetId::ImdbLike,
        DatasetId::RottenLike,
        DatasetId::Glyphs,
    ];

    /// Manifest key (artifact name component).
    pub fn name(self) -> &'static str {
        match self {
            DatasetId::Cifar10Like => "cifar10",
            DatasetId::Cifar100Like => "cifar100",
            DatasetId::TinyImagenetLike => "tinyimagenet",
            DatasetId::OrganaLike => "organa",
            DatasetId::DermaLike => "derma",
            DatasetId::Trec6Like => "trec6",
            DatasetId::ImdbLike => "imdb",
            DatasetId::RottenLike => "rotten",
            DatasetId::Glyphs => "glyphs",
        }
    }

    pub fn from_name(name: &str) -> Result<DatasetId> {
        for id in DatasetId::ALL {
            if id.name() == name {
                return Ok(id);
            }
        }
        bail!("unknown dataset {name:?}")
    }

    pub fn input_dim(self) -> usize {
        match self {
            DatasetId::Trec6Like | DatasetId::ImdbLike | DatasetId::RottenLike => 48,
            DatasetId::Glyphs => 256,
            _ => 64,
        }
    }

    pub fn classes(self) -> usize {
        match self {
            DatasetId::Cifar10Like | DatasetId::Glyphs => 10,
            DatasetId::Cifar100Like => 100,
            DatasetId::TinyImagenetLike => 200,
            DatasetId::OrganaLike => 11,
            DatasetId::DermaLike => 7,
            DatasetId::Trec6Like => 6,
            DatasetId::ImdbLike | DatasetId::RottenLike => 2,
        }
    }

    /// (train, val, test) sizes — scaled-down analogues of the paper's
    /// datasets, sized so the full experiment grid is tractable on CPU
    /// while keeping the train set ≫ subset sizes of interest.
    pub fn sizes(self) -> (usize, usize, usize) {
        match self {
            DatasetId::Cifar10Like => (5000, 500, 1000),
            DatasetId::Cifar100Like => (6000, 600, 1000),
            DatasetId::TinyImagenetLike => (8000, 800, 1000),
            DatasetId::OrganaLike => (3300, 330, 660),
            DatasetId::DermaLike => (2100, 210, 420),
            DatasetId::Trec6Like => (2400, 300, 600),
            DatasetId::ImdbLike => (4000, 400, 1000),
            DatasetId::RottenLike => (2000, 250, 500),
            DatasetId::Glyphs => (4000, 400, 1000),
        }
    }

    /// Generate the dataset deterministically from `seed`.
    pub fn generate(self, seed: u64) -> Dataset {
        let rng = Rng::new(seed ^ 0xDA7A_0000).derive_str(self.name());
        match self {
            DatasetId::Glyphs => glyphs::generate(self, rng),
            DatasetId::Trec6Like | DatasetId::ImdbLike | DatasetId::RottenLike => {
                let overlap = match self {
                    DatasetId::Trec6Like => 0.35,
                    DatasetId::ImdbLike => 0.55,
                    DatasetId::RottenLike => 0.65,
                    _ => unreachable!(),
                };
                text::generate(self, rng, overlap)
            }
            _ => {
                // Vision-like: harder datasets = more classes, tighter
                // packing (class separation shrinks as class count grows,
                // mirroring CIFAR100/TinyImageNet being harder than
                // CIFAR10).
                let sep = match self {
                    DatasetId::Cifar10Like => 3.4,
                    DatasetId::OrganaLike => 3.0,
                    // DermaMNIST-like: few classes but heavy class
                    // imbalance-like overlap (skin-lesion classes are
                    // visually close) — tighter packing than Organ.
                    DatasetId::DermaLike => 2.6,
                    DatasetId::Cifar100Like => 2.4,
                    DatasetId::TinyImagenetLike => 2.1,
                    _ => 3.0,
                };
                gaussmix::generate(self, rng, sep)
            }
        }
    }
}

/// An in-memory dataset with splits and generator ground truth.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub id: DatasetId,
    pub train_x: Matrix,
    pub train_y: Vec<u32>,
    pub val_x: Matrix,
    pub val_y: Vec<u32>,
    pub test_x: Matrix,
    pub test_y: Vec<u32>,
    /// Generator ground-truth hardness in [0, 1] per train sample (distance
    /// from the class core / overlap measure); used to validate the EL2N
    /// analysis, not visible to any selection strategy.
    pub hardness: Vec<f32>,
}

impl Dataset {
    pub fn name(&self) -> &'static str {
        self.id.name()
    }

    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }

    /// Subset size for a fraction of the train split (rounded, clamped to
    /// `[1, n_train]`) — the one rounding rule every consumer shares
    /// (`TrainConfig::k`, `MiloSession::k`, testkit, benches).
    pub fn subset_size(&self, fraction: f64) -> usize {
        ((fraction * self.n_train() as f64).round() as usize).clamp(1, self.n_train())
    }

    pub fn classes(&self) -> usize {
        self.id.classes()
    }

    pub fn x(&self, split: Split) -> &Matrix {
        match split {
            Split::Train => &self.train_x,
            Split::Val => &self.val_x,
            Split::Test => &self.test_x,
        }
    }

    pub fn y(&self, split: Split) -> &[u32] {
        match split {
            Split::Train => &self.train_y,
            Split::Val => &self.val_y,
            Split::Test => &self.test_y,
        }
    }

    /// Class-wise partition of the train split: `partition[c]` lists the
    /// train indices with label `c` (paper §3.2's class-wise trick — the
    /// kernel memory drops by `c²` and selection parallelizes per class).
    pub fn class_partition(&self) -> Vec<Vec<usize>> {
        let mut parts = vec![Vec::new(); self.classes()];
        for (i, &y) in self.train_y.iter().enumerate() {
            parts[y as usize].push(i);
        }
        parts
    }

    /// Basic integrity validation (used by generator tests).
    pub fn validate(&self) -> Result<()> {
        let d = self.id.input_dim();
        let (tr, va, te) = self.id.sizes();
        if self.train_x.rows != tr || self.train_x.cols != d {
            bail!("train_x shape {}x{}", self.train_x.rows, self.train_x.cols);
        }
        if self.train_y.len() != tr || self.val_y.len() != va || self.test_y.len() != te {
            bail!("split sizes mismatch");
        }
        if self.hardness.len() != tr {
            bail!("hardness length mismatch");
        }
        let c = self.classes() as u32;
        for &y in self.train_y.iter().chain(&self.val_y).chain(&self.test_y) {
            if y >= c {
                bail!("label {y} out of range");
            }
        }
        for &h in &self.hardness {
            if !(0.0..=1.0).contains(&h) {
                bail!("hardness {h} out of [0,1]");
            }
        }
        if self.train_x.data().iter().any(|v| !v.is_finite()) {
            bail!("non-finite features");
        }
        Ok(())
    }
}

/// Helper shared by generators: split a generated pool into train/val/test
/// by shuffling indices.
pub(crate) fn split_pool(
    id: DatasetId,
    x: Matrix,
    y: Vec<u32>,
    hardness: Vec<f32>,
    rng: &mut Rng,
) -> Dataset {
    let (tr, va, te) = id.sizes();
    assert_eq!(x.rows, tr + va + te, "pool size mismatch");
    let mut idx: Vec<usize> = (0..x.rows).collect();
    rng.shuffle(&mut idx);
    let take = |range: std::ops::Range<usize>| -> (Matrix, Vec<u32>, Vec<f32>) {
        let ids = &idx[range];
        let xs = x.gather_rows(ids);
        let ys = ids.iter().map(|&i| y[i]).collect();
        let hs = ids.iter().map(|&i| hardness[i]).collect();
        (xs, ys, hs)
    };
    let (train_x, train_y, h) = take(0..tr);
    let (val_x, val_y, _) = take(tr..tr + va);
    let (test_x, test_y, _) = take(tr + va..tr + va + te);
    Dataset {
        id,
        train_x,
        train_y,
        val_x,
        val_y,
        test_x,
        test_y,
        hardness: h,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_generators_validate() {
        for id in DatasetId::ALL {
            let ds = id.generate(1);
            ds.validate().unwrap_or_else(|e| panic!("{}: {e}", id.name()));
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = DatasetId::Cifar10Like.generate(5);
        let b = DatasetId::Cifar10Like.generate(5);
        let c = DatasetId::Cifar10Like.generate(6);
        assert_eq!(a.train_x.data(), b.train_x.data());
        assert_eq!(a.train_y, b.train_y);
        assert_ne!(a.train_x.data(), c.train_x.data());
    }

    #[test]
    fn class_partition_covers_everything() {
        let ds = DatasetId::Trec6Like.generate(2);
        let parts = ds.class_partition();
        assert_eq!(parts.len(), 6);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, ds.n_train());
        for (c, part) in parts.iter().enumerate() {
            assert!(!part.is_empty(), "class {c} empty");
            for &i in part {
                assert_eq!(ds.train_y[i] as usize, c);
            }
        }
    }

    #[test]
    fn roundtrip_names() {
        for id in DatasetId::ALL {
            assert_eq!(DatasetId::from_name(id.name()).unwrap(), id);
        }
        assert!(DatasetId::from_name("nope").is_err());
    }

    #[test]
    fn classes_roughly_balanced() {
        let ds = DatasetId::Cifar10Like.generate(3);
        let parts = ds.class_partition();
        let expect = ds.n_train() / ds.classes();
        for p in parts {
            assert!(
                p.len() > expect / 2 && p.len() < expect * 2,
                "class size {} vs expected {}",
                p.len(),
                expect
            );
        }
    }
}
