//! Topic-mixture "document" generator (text-like stand-in).
//!
//! Each class is a topic: a sparse distribution over `D` vocabulary
//! dimensions. A document mixes its class topic with a shared background
//! topic and (with probability given by `overlap`) a rival class's topic —
//! the knob that makes RottenTomatoes-like sets (high lexical overlap
//! between sentiments) harder than TREC-like sets (distinct question
//! types). Features are sqrt-tf normalized counts, the standard
//! bag-of-words geometry.

use super::{split_pool, Dataset, DatasetId};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Tokens drawn per document.
const DOC_LEN: usize = 60;
/// Weight of the shared background topic in every document.
const BACKGROUND: f64 = 0.35;

pub fn generate(id: DatasetId, rng: Rng, overlap: f64) -> Dataset {
    let d = id.input_dim();
    let c = id.classes();
    let (tr, va, te) = id.sizes();
    let total = tr + va + te;

    // Topic distributions: class topics concentrate on a random subset of
    // dims; background is broad.
    let mut trng = rng.derive(1);
    let topic_support = d / 3;
    let mut topics: Vec<Vec<f64>> = Vec::with_capacity(c + 1);
    for _ in 0..=c {
        let mut w = vec![0.0f64; d];
        // background (last entry) covers everything lightly
        for v in w.iter_mut() {
            *v = 0.05 + trng.f64() * 0.1;
        }
        let dims = trng.sample_indices(d, topic_support);
        for &j in &dims {
            w[j] += 0.5 + trng.f64();
        }
        let s: f64 = w.iter().sum();
        for v in w.iter_mut() {
            *v /= s;
        }
        topics.push(w);
    }
    let background = topics.pop().unwrap();

    let mut x = Matrix::zeros(total, d);
    let mut y = Vec::with_capacity(total);
    let mut hardness = Vec::with_capacity(total);
    let mut srng = rng.derive(2);
    for i in 0..total {
        let class = i % c;
        // contamination: blend in a rival topic for `overlap`-share of docs
        let contaminated = srng.chance(overlap);
        let rival = if contaminated {
            let o = srng.below(c.max(2) - 1);
            Some(if o >= class { o + 1 } else { o })
        } else {
            None
        };
        let mix = srng.range_f64(0.25, 0.55); // rival share when contaminated
        // token multinomial draw
        let row = x.row_mut(i);
        for _ in 0..DOC_LEN {
            let u = srng.f64();
            let topic: &[f64] = if u < BACKGROUND {
                &background
            } else if let Some(r) = rival {
                if u < BACKGROUND + (1.0 - BACKGROUND) * mix {
                    &topics[r]
                } else {
                    &topics[class]
                }
            } else {
                &topics[class]
            };
            let j = srng.weighted_index(topic);
            row[j] += 1.0;
        }
        // sqrt-tf then L2 normalize
        let mut norm = 0.0f32;
        for v in row.iter_mut() {
            *v = v.sqrt();
            norm += *v * *v;
        }
        let norm = norm.sqrt().max(1e-6);
        for v in row.iter_mut() {
            *v /= norm;
        }
        y.push(class as u32);
        hardness.push(if contaminated {
            (0.5 + mix as f32).min(0.999)
        } else {
            0.2 * srng.f32()
        });
    }

    let mut prng = rng.derive(3);
    split_pool(id, x, y, hardness, &mut prng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn docs_are_unit_norm() {
        let ds = DatasetId::Trec6Like.generate(11);
        for r in 0..20 {
            let n: f32 = ds.train_x.row(r).iter().map(|v| v * v).sum();
            assert!((n - 1.0).abs() < 1e-4, "row {r}: {n}");
        }
    }

    #[test]
    fn class_topics_distinguishable() {
        // mean within-class cosine > mean across-class cosine
        let ds = DatasetId::Trec6Like.generate(12);
        let cos = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x * y) as f64).sum()
        };
        let (mut win, mut acr) = (0.0, 0.0);
        let (mut nw, mut na) = (0usize, 0usize);
        for i in 0..80 {
            for j in (i + 1)..80 {
                let c = cos(ds.train_x.row(i), ds.train_x.row(j));
                if ds.train_y[i] == ds.train_y[j] {
                    win += c;
                    nw += 1;
                } else {
                    acr += c;
                    na += 1;
                }
            }
        }
        assert!(win / nw as f64 > acr / na as f64 + 0.01);
    }

    #[test]
    fn higher_overlap_means_harder() {
        // rotten (overlap .65) should have more contaminated docs than trec6
        let trec = DatasetId::Trec6Like.generate(13);
        let rotten = DatasetId::RottenLike.generate(13);
        let frac_hard = |ds: &Dataset| {
            ds.hardness.iter().filter(|&&h| h > 0.5).count() as f64
                / ds.hardness.len() as f64
        };
        assert!(frac_hard(&rotten) > frac_hard(&trec));
    }
}
