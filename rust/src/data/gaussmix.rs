//! Multi-modal Gaussian class-manifold generator (vision-like stand-in).
//!
//! Each class is a mixture of one **dense core mode** (most of the mass,
//! small covariance — the "easy" samples representation functions pick) and
//! a few **sparse tail modes** (little mass, wide covariance, placed toward
//! other classes — the "hard" samples diversity functions pick). A small
//! label-noise fraction adds genuinely mislabelled points, the hardest of
//! all. Ground-truth hardness is the sample's Mahalanobis-ish distance from
//! its class core rescaled to [0, 1], with mislabelled points pinned at 1.

use super::{split_pool, Dataset, DatasetId};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Fraction of each class drawn from the dense core mode.
const CORE_MASS: f64 = 0.65;
/// Number of sparse tail modes per class.
const TAIL_MODES: usize = 3;
/// Fraction of labels flipped to a random other class.
const LABEL_NOISE: f64 = 0.02;
/// Core / tail standard deviations.
const CORE_STD: f32 = 0.55;
const TAIL_STD: f32 = 1.25;

pub fn generate(id: DatasetId, rng: Rng, class_sep: f32) -> Dataset {
    let d = id.input_dim();
    let c = id.classes();
    let (tr, va, te) = id.sizes();
    let total = tr + va + te;

    // Class core centres: random directions scaled to `class_sep`.
    let mut centres = Matrix::zeros(c, d);
    {
        let mut crng = rng.derive(1);
        for k in 0..c {
            let row = centres.row_mut(k);
            let mut norm = 0.0f32;
            for v in row.iter_mut() {
                *v = crng.normal_f32(0.0, 1.0);
                norm += *v * *v;
            }
            let norm = norm.sqrt().max(1e-6);
            for v in row.iter_mut() {
                *v *= class_sep / norm;
            }
        }
    }

    // Tail-mode centres: interpolations from the class core toward another
    // class's core (so tails live in the contested regions between
    // manifolds — the geometrically hard samples).
    let mut tails = vec![Vec::with_capacity(TAIL_MODES); c];
    {
        let mut trng = rng.derive(2);
        for k in 0..c {
            for _ in 0..TAIL_MODES {
                let other = {
                    let o = trng.below(c.max(2) - 1);
                    if o >= k {
                        o + 1
                    } else {
                        o
                    }
                };
                let alpha = 0.35 + 0.3 * trng.f32(); // 35–65% toward the rival
                let mut centre = vec![0.0f32; d];
                for (j, v) in centre.iter_mut().enumerate() {
                    *v = centres.at(k, j) * (1.0 - alpha) + centres.at(other, j) * alpha;
                }
                tails[k].push(centre);
            }
        }
    }

    let mut x = Matrix::zeros(total, d);
    let mut y = Vec::with_capacity(total);
    let mut hardness = Vec::with_capacity(total);
    let mut srng = rng.derive(3);
    let mut nrng = rng.derive(4);
    for i in 0..total {
        let class = i % c; // balanced
        let core = srng.chance(CORE_MASS);
        let (centre, std): (&[f32], f32) = if core {
            (centres.row(class), CORE_STD)
        } else {
            let m = srng.below(TAIL_MODES);
            (&tails[class][m], TAIL_STD)
        };
        let row = x.row_mut(i);
        let mut dist2 = 0.0f32;
        for (j, v) in row.iter_mut().enumerate() {
            let noise = srng.normal_f32(0.0, std);
            *v = centre[j] + noise;
            let dc = *v - centres.at(class, j);
            dist2 += dc * dc;
        }
        // label noise: flip to a uniformly random different class
        let (label, mislabelled) = if nrng.chance(LABEL_NOISE) {
            let o = nrng.below(c.max(2) - 1);
            (if o >= class { o + 1 } else { o }, true)
        } else {
            (class, false)
        };
        y.push(label as u32);
        // Hardness: distance from own-core, squashed to [0,1]; mislabelled
        // points are maximally hard.
        let h = if mislabelled {
            1.0
        } else {
            let scale = CORE_STD * (d as f32).sqrt();
            (dist2.sqrt() / (3.0 * scale)).min(0.999)
        };
        hardness.push(h);
    }

    let mut prng = rng.derive(5);
    split_pool(id, x, y, hardness, &mut prng)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn centroid_distance(ds: &Dataset, a: u32, b: u32) -> f32 {
        let d = ds.id.input_dim();
        let mut ca = vec![0.0f32; d];
        let mut cb = vec![0.0f32; d];
        let (mut na, mut nb) = (0usize, 0usize);
        for (i, &yy) in ds.train_y.iter().enumerate() {
            if yy == a {
                for (j, v) in ds.train_x.row(i).iter().enumerate() {
                    ca[j] += v;
                }
                na += 1;
            } else if yy == b {
                for (j, v) in ds.train_x.row(i).iter().enumerate() {
                    cb[j] += v;
                }
                nb += 1;
            }
        }
        let mut dist = 0.0f32;
        for j in 0..d {
            let diff = ca[j] / na as f32 - cb[j] / nb as f32;
            dist += diff * diff;
        }
        dist.sqrt()
    }

    #[test]
    fn classes_are_separated() {
        let ds = DatasetId::Cifar10Like.generate(7);
        // any two class centroids should be farther apart than a within-core std
        let d01 = centroid_distance(&ds, 0, 1);
        assert!(d01 > 1.0, "centroid distance {d01}");
    }

    #[test]
    fn hardness_correlates_with_distance_from_core() {
        let ds = DatasetId::Cifar10Like.generate(8);
        // mean hardness of the farthest quartile must exceed the nearest
        let mut hs: Vec<f32> = ds.hardness.clone();
        hs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q1 = hs[hs.len() / 4];
        let q3 = hs[3 * hs.len() / 4];
        assert!(q3 > q1 + 0.05, "hardness has no spread: q1={q1} q3={q3}");
    }

    #[test]
    fn harder_dataset_has_closer_classes() {
        let easy = DatasetId::Cifar10Like.generate(9);
        let hard = DatasetId::TinyImagenetLike.generate(9);
        let de = centroid_distance(&easy, 0, 1);
        let dh = centroid_distance(&hard, 0, 1);
        assert!(
            dh < de * 1.2,
            "tinyimagenet ({dh}) should not be much more separated than cifar10 ({de})"
        );
    }
}
