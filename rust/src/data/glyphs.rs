//! Procedural glyph dataset: the real small end-to-end workload.
//!
//! Renders 16×16 grayscale images of the digits 0–9 as anti-aliased line
//! strokes on a seven-segment-plus-diagonals skeleton, with per-sample
//! affine jitter (translation, scale, shear), stroke-intensity variation
//! and additive pixel noise. Unlike the Gaussian-mixture stand-ins this is
//! a genuine pixel-space recognition task: classes are *not* Gaussian
//! blobs, the encoder has to earn its similarity structure, and a
//! downstream MLP reaches high accuracy only by actually learning shapes.
//! `examples/end_to_end.rs` runs the full MILO pipeline on it.

use super::{split_pool, Dataset, DatasetId};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

pub const SIDE: usize = 16;

/// Line segments (x0, y0, x1, y1) in a [0,1]² glyph box per digit.
/// Seven-segment layout with diagonals for 2/4/7-style strokes.
fn strokes(digit: usize) -> &'static [(f32, f32, f32, f32)] {
    // segment endpoints
    const T: (f32, f32, f32, f32) = (0.2, 0.15, 0.8, 0.15); // top
    const M: (f32, f32, f32, f32) = (0.2, 0.5, 0.8, 0.5); // middle
    const B: (f32, f32, f32, f32) = (0.2, 0.85, 0.8, 0.85); // bottom
    const TL: (f32, f32, f32, f32) = (0.2, 0.15, 0.2, 0.5); // top-left
    const TR: (f32, f32, f32, f32) = (0.8, 0.15, 0.8, 0.5); // top-right
    const BL: (f32, f32, f32, f32) = (0.2, 0.5, 0.2, 0.85); // bottom-left
    const BR: (f32, f32, f32, f32) = (0.8, 0.5, 0.8, 0.85); // bottom-right
    const DIAG: (f32, f32, f32, f32) = (0.8, 0.15, 0.25, 0.85); // 7's leg
    match digit {
        0 => &[T, B, TL, TR, BL, BR],
        1 => &[TR, BR],
        2 => &[T, TR, M, BL, B],
        3 => &[T, TR, M, BR, B],
        4 => &[TL, TR, M, BR],
        5 => &[T, TL, M, BR, B],
        6 => &[T, TL, M, BL, BR, B],
        7 => &[T, DIAG],
        8 => &[T, M, B, TL, TR, BL, BR],
        9 => &[T, M, B, TL, TR, BR],
        _ => unreachable!("digit out of range"),
    }
}

/// Render one digit into a SIDE×SIDE buffer with the given jitter.
#[allow(clippy::too_many_arguments)]
fn render(
    digit: usize,
    dx: f32,
    dy: f32,
    scale: f32,
    shear: f32,
    intensity: f32,
    noise_std: f32,
    rng: &mut Rng,
) -> Vec<f32> {
    let mut img = vec![0.0f32; SIDE * SIDE];
    let w = 0.085f32; // stroke half-width in glyph units
    // For every pixel, compute min distance to any stroke segment and shade.
    for py in 0..SIDE {
        for px in 0..SIDE {
            // map pixel centre back into glyph coordinates (inverse affine)
            let ux = (px as f32 + 0.5) / SIDE as f32;
            let uy = (py as f32 + 0.5) / SIDE as f32;
            let gx0 = (ux - 0.5 - dx) / scale + 0.5;
            let gy0 = (uy - 0.5 - dy) / scale + 0.5;
            let gx = gx0 - shear * (gy0 - 0.5);
            let gy = gy0;
            let mut dmin = f32::MAX;
            for &(x0, y0, x1, y1) in strokes(digit) {
                let d = dist_point_segment(gx, gy, x0, y0, x1, y1);
                if d < dmin {
                    dmin = d;
                }
            }
            // soft stroke profile: 1 inside, smooth falloff over one w
            let v = if dmin <= w {
                1.0
            } else {
                (1.0 - (dmin - w) / w).max(0.0)
            };
            img[py * SIDE + px] = intensity * v;
        }
    }
    // additive pixel noise, clipped to [0, 1.2]
    for v in img.iter_mut() {
        *v = (*v + rng.normal_f32(0.0, noise_std)).clamp(0.0, 1.2);
    }
    img
}

fn dist_point_segment(px: f32, py: f32, x0: f32, y0: f32, x1: f32, y1: f32) -> f32 {
    let (vx, vy) = (x1 - x0, y1 - y0);
    let (wx, wy) = (px - x0, py - y0);
    let c1 = vx * wx + vy * wy;
    if c1 <= 0.0 {
        return (wx * wx + wy * wy).sqrt();
    }
    let c2 = vx * vx + vy * vy;
    if c2 <= c1 {
        let (dx, dy) = (px - x1, py - y1);
        return (dx * dx + dy * dy).sqrt();
    }
    let t = c1 / c2;
    let (dx, dy) = (px - (x0 + t * vx), py - (y0 + t * vy));
    (dx * dx + dy * dy).sqrt()
}

pub fn generate(id: DatasetId, rng: Rng) -> Dataset {
    assert_eq!(id, DatasetId::Glyphs);
    let (tr, va, te) = id.sizes();
    let total = tr + va + te;
    let d = id.input_dim();
    assert_eq!(d, SIDE * SIDE);
    let c = id.classes();

    let mut x = Matrix::zeros(total, d);
    let mut y = Vec::with_capacity(total);
    let mut hardness = Vec::with_capacity(total);
    let mut grng = rng.derive(1);
    for i in 0..total {
        let digit = i % c;
        // jitter magnitudes: most samples mild (easy), a tail extreme (hard)
        let extreme = grng.chance(0.3);
        let (jit, noise) = if extreme {
            (0.14, 0.22)
        } else {
            (0.05, 0.08)
        };
        let dx = grng.normal_f32(0.0, jit).clamp(-0.2, 0.2);
        let dy = grng.normal_f32(0.0, jit).clamp(-0.2, 0.2);
        let scale = (1.0 + grng.normal_f32(0.0, jit)).clamp(0.6, 1.35);
        let shear = grng.normal_f32(0.0, jit * 1.5).clamp(-0.35, 0.35);
        let intensity = (1.0 + grng.normal_f32(0.0, 0.15)).clamp(0.5, 1.3);
        let img = render(digit, dx, dy, scale, shear, intensity, noise, &mut grng);
        x.row_mut(i).copy_from_slice(&img);
        y.push(digit as u32);
        // hardness proxy: jitter magnitude + noise level, normalized
        let h = ((dx.abs() + dy.abs() + (scale - 1.0).abs() + shear.abs()) / 0.9
            + noise / 0.5)
            .min(0.999);
        hardness.push(h);
    }

    let mut prng = rng.derive(2);
    split_pool(id, x, y, hardness, &mut prng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_are_nontrivial_and_distinct() {
        let mut rng = Rng::new(0);
        let a = render(0, 0.0, 0.0, 1.0, 0.0, 1.0, 0.0, &mut rng);
        let b = render(1, 0.0, 0.0, 1.0, 0.0, 1.0, 0.0, &mut rng);
        let mass_a: f32 = a.iter().sum();
        let mass_b: f32 = b.iter().sum();
        assert!(mass_a > 5.0, "digit 0 should have substantial ink: {mass_a}");
        assert!(mass_a > mass_b, "0 has more segments than 1");
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 5.0, "digits must differ: {diff}");
    }

    #[test]
    fn all_digits_have_strokes() {
        for d in 0..10 {
            assert!(!strokes(d).is_empty());
        }
    }

    #[test]
    fn point_segment_distance() {
        assert!((dist_point_segment(0.0, 1.0, -1.0, 0.0, 1.0, 0.0) - 1.0).abs() < 1e-6);
        assert!((dist_point_segment(2.0, 0.0, -1.0, 0.0, 1.0, 0.0) - 1.0).abs() < 1e-6);
        assert!(dist_point_segment(0.5, 0.0, -1.0, 0.0, 1.0, 0.0) < 1e-6);
    }

    #[test]
    fn same_digit_closer_than_cross_digit_on_average() {
        // sanity: raw-pixel nearest-neighbour structure exists (so encoder
        // similarity has signal to work with)
        let ds = DatasetId::Glyphs.generate(4);
        let mut within = 0.0f64;
        let mut across = 0.0f64;
        let (mut nw, mut na) = (0usize, 0usize);
        for i in 0..60 {
            for j in (i + 1)..60 {
                let d: f32 = ds
                    .train_x
                    .row(i)
                    .iter()
                    .zip(ds.train_x.row(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if ds.train_y[i] == ds.train_y[j] {
                    within += d as f64;
                    nw += 1;
                } else {
                    across += d as f64;
                    na += 1;
                }
            }
        }
        assert!(within / (nw as f64) < across / (na as f64));
    }
}
