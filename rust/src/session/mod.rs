//! The crate's front door: one API for inline, store-backed, and served
//! selection metadata.
//!
//! The paper's core move — decoupling subset selection from training so
//! one preprocessing pass amortizes across any number of models — used to
//! be spelled three different ways in this crate (`Preprocessor::run`,
//! the store-backed `run_cached`, and the `milo serve` wire path), each
//! hand-wired into the Trainer, Tuner, ExperimentRunner, and CLI
//! separately. This module says it once, in the type system:
//!
//! * [`MetaSource`] — *where selection metadata comes from*. Three
//!   variants with a single [`MetaSource::resolve`] entry point:
//!
//!   | variant | resolution order |
//!   |---|---|
//!   | [`MetaSource::Inline`]  | run the configured preprocessing pipeline (kernel or feature-based) in-process — always a fresh pass |
//!   | [`MetaSource::Store`]   | in-process LRU → on-disk binary artifact → build via the pipeline (once per fingerprint, across threads) |
//!   | [`MetaSource::Remote`]  | `GET_META` from a running `milo serve` instance (binary frame wire by default — the exact binfmt artifact bytes — with reconnect/retry); never builds locally. With [`MetaSource::remote_pooled`] every client the source creates is a multiplexed stream on a shared [`ConnectionPool`] connection instead of its own socket |
//!
//! * [`MiloSession`] — *who consumes it*. A typed builder binding a
//!   runtime (optional — store/remote sources work without one), a
//!   dataset, a source, and a fraction; the session hands out strategies,
//!   trainers, tuners, and experiment runners that all share one cached
//!   resolution. "Train N models off one pass" is a loop over
//!   [`MiloSession::train`].
//!
//! ```no_run
//! use milo::prelude::*;
//!
//! let rt = Runtime::open("artifacts")?;
//! let session = MiloSession::builder()
//!     .runtime(&rt)
//!     .dataset(DatasetId::Cifar10Like.generate(1))
//!     .source(MetaSource::inline(PreprocessOptions::default()))
//!     .fraction(0.1)
//!     .build()?;
//! // one resolution, any number of consumers
//! for kind in [StrategyKind::Milo { kappa: 1.0 / 6.0 }, StrategyKind::MiloFixed] {
//!     let cfg = TrainConfig { epochs: 40, ..Default::default() };
//!     let out = session.train(kind, cfg)?;
//!     println!("{}: {:.2}%", out.strategy, 100.0 * out.test_accuracy);
//! }
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The pre-session shims (`Preprocessor::run_cached`, `Tuner::with_server`)
//! are gone: construct a [`MetaSource`] (or let the [`MiloSession`]
//! builder do it).
//!
//! # Following a continual-arrival server
//!
//! A session over a [`MetaSource::Remote`] source can additionally
//! **follow** a server fed by [`crate::continual`]:
//! [`MiloSession::follow_client`] hands out a subscribed
//! [`ServeClient`] whose [`ServeClient::follow`] iterator yields one
//! [`crate::serve::EpochUpdate`] per published epoch — the trainer
//! switches subset universes at each yield, and across reconnects each
//! epoch is still observed at most once (see the [`crate::serve`] *Epoch
//! versioning* docs for the push protocol and
//! [`crate::store::MetaStore::load_following`] for the pin → head → base
//! resolution order used by store-side followers).

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, ensure, Result};

use crate::coordinator::{
    ExperimentRunner, Metadata, PreprocessOptions, Preprocessor, StrategyKind,
};
use crate::data::{Dataset, Split};
use crate::hpo::{HpoConfig, Tuner};
use crate::kernel::SimilarityBackend;
use crate::runtime::Runtime;
use crate::selection::Strategy;
use crate::serve::{
    ClientOptions, ConnectionPool, RetryPolicy, ServeClient, ServedMiloStrategy,
    WireMode,
};
use crate::store::{MetaKey, MetaStore};
use crate::train::{TrainConfig, TrainOutcome, Trainer};

/// Where selection metadata comes from. See the [module docs](self) for
/// the resolution order of each variant.
#[derive(Clone)]
pub enum MetaSource {
    /// Run the preprocessing pipeline in-process, every time.
    Inline(PreprocessOptions),
    /// Resolve through a content-addressed [`MetaStore`]: LRU → disk →
    /// build (at most one pass per fingerprint across all threads).
    Store {
        store: MetaStore,
        opts: PreprocessOptions,
    },
    /// Fetch from a running `milo serve` instance; validates the served
    /// dataset (always) and seed/fraction (when expectations are set).
    Remote {
        addr: String,
        /// Client id keying the server-side deterministic streams.
        client_id: String,
        /// When set, the server's announced stream seed must match.
        expect_seed: Option<u64>,
        /// When set, the served metadata's fraction must match.
        expect_fraction: Option<f64>,
        /// Wire format to negotiate (default: binary frames — `GET_META`
        /// then transfers the exact binfmt artifact bytes).
        wire: WireMode,
        /// Reconnect/retry policy for transport failures mid-resolution
        /// and mid-stream.
        retry: RetryPolicy,
        /// When set (and the wire is [`WireMode::Frame`]), every client
        /// this source creates is a multiplexed stream leased from this
        /// shared [`ConnectionPool`] instead of its own socket — a
        /// session fleet on one host then shares connections.
        pool: Option<ConnectionPool>,
    },
}

impl std::fmt::Debug for MetaSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MetaSource::Inline(opts) => f.debug_tuple("Inline").field(opts).finish(),
            MetaSource::Store { store, opts } => f
                .debug_struct("Store")
                .field("root", &store.root())
                .field("opts", opts)
                .finish(),
            MetaSource::Remote {
                addr,
                client_id,
                expect_seed,
                expect_fraction,
                wire,
                retry,
                pool,
            } => f
                .debug_struct("Remote")
                .field("addr", addr)
                .field("client_id", client_id)
                .field("expect_seed", expect_seed)
                .field("expect_fraction", expect_fraction)
                .field("wire", wire)
                .field("retry", retry)
                .field("pooled", &pool.is_some())
                .finish(),
        }
    }
}

impl MetaSource {
    /// An inline source: preprocess in-process under `opts`.
    pub fn inline(opts: PreprocessOptions) -> MetaSource {
        MetaSource::Inline(opts)
    }

    /// A store-backed source rooted at `dir`. Uses [`MetaStore::shared`]
    /// so every source on the same root shares one LRU and one set of
    /// per-fingerprint build locks.
    pub fn store(dir: impl Into<PathBuf>, opts: PreprocessOptions) -> Result<MetaSource> {
        Ok(MetaSource::Store { store: MetaStore::shared(dir)?, opts })
    }

    /// A store-backed source over an existing handle.
    pub fn store_handle(store: MetaStore, opts: PreprocessOptions) -> MetaSource {
        MetaSource::Store { store, opts }
    }

    /// A served source with no seed/fraction expectations (the dataset is
    /// always validated on resolve). Negotiates the binary frame wire and
    /// the default [`RetryPolicy`].
    pub fn remote(addr: impl Into<String>) -> MetaSource {
        MetaSource::Remote {
            addr: addr.into(),
            client_id: "milo_session".to_string(),
            expect_seed: None,
            expect_fraction: None,
            wire: WireMode::Frame,
            retry: RetryPolicy::default(),
            pool: None,
        }
    }

    /// A served source that refuses metadata from a server running a
    /// different seed or holding a different fraction — a mismatched
    /// server would hand out selections for a different dataset
    /// instantiation.
    pub fn remote_expecting(
        addr: impl Into<String>,
        seed: u64,
        fraction: f64,
    ) -> MetaSource {
        MetaSource::Remote {
            addr: addr.into(),
            client_id: "milo_session".to_string(),
            expect_seed: Some(seed),
            expect_fraction: Some(fraction),
            wire: WireMode::Frame,
            retry: RetryPolicy::default(),
            pool: None,
        }
    }

    /// A served source whose clients are multiplexed streams leased from
    /// `pool`'s shared framed connections — N sessions (strategies,
    /// followers, resolves) share sockets instead of dialing one each.
    /// Same validation and retry semantics as [`MetaSource::remote`].
    pub fn remote_pooled(pool: &ConnectionPool) -> MetaSource {
        MetaSource::Remote {
            addr: pool.addr().to_string(),
            client_id: "milo_session".to_string(),
            expect_seed: None,
            expect_fraction: None,
            wire: WireMode::Frame,
            retry: RetryPolicy::default(),
            pool: Some(pool.clone()),
        }
    }

    /// Return this source with its clients routed through a shared
    /// connection pool (no-op on local sources; pooling requires the
    /// frame wire, so pair with the default [`WireMode::Frame`]).
    pub fn with_pool(mut self, shared: &ConnectionPool) -> MetaSource {
        if let MetaSource::Remote { pool, .. } = &mut self {
            *pool = Some(shared.clone());
        }
        self
    }

    /// Return this source with the wire format swapped (no-op on local
    /// sources).
    pub fn with_wire(mut self, mode: WireMode) -> MetaSource {
        if let MetaSource::Remote { wire, .. } = &mut self {
            *wire = mode;
        }
        self
    }

    /// Return this source with the reconnect policy swapped (no-op on
    /// local sources).
    pub fn with_retry(mut self, policy: RetryPolicy) -> MetaSource {
        if let MetaSource::Remote { retry, .. } = &mut self {
            *retry = policy;
        }
        self
    }

    /// The fraction this source is configured for, when it knows one.
    pub fn fraction(&self) -> Option<f64> {
        match self {
            MetaSource::Inline(o) | MetaSource::Store { opts: o, .. } => Some(o.fraction),
            MetaSource::Remote { expect_fraction, .. } => *expect_fraction,
        }
    }

    /// The seed this source is configured for, when it knows one.
    pub fn seed(&self) -> Option<u64> {
        match self {
            MetaSource::Inline(o) | MetaSource::Store { opts: o, .. } => Some(o.seed),
            MetaSource::Remote { expect_seed, .. } => *expect_seed,
        }
    }

    /// Return this source re-targeted at `fraction` (expectation update on
    /// a remote source).
    pub fn with_fraction(mut self, fraction: f64) -> MetaSource {
        match &mut self {
            MetaSource::Inline(o) | MetaSource::Store { opts: o, .. } => {
                o.fraction = fraction;
            }
            MetaSource::Remote { expect_fraction, .. } => {
                *expect_fraction = Some(fraction);
            }
        }
        self
    }

    /// Return this source re-seeded (expectation update on a remote
    /// source).
    pub fn with_seed(mut self, seed: u64) -> MetaSource {
        match &mut self {
            MetaSource::Inline(o) | MetaSource::Store { opts: o, .. } => o.seed = seed,
            MetaSource::Remote { expect_seed, .. } => *expect_seed = Some(seed),
        }
        self
    }

    /// Return this source with the similarity backend swapped (no-op on a
    /// remote source — the server already paid for preprocessing).
    pub fn with_backend(mut self, backend: SimilarityBackend) -> MetaSource {
        match &mut self {
            MetaSource::Inline(o) | MetaSource::Store { opts: o, .. } => {
                o.backend = backend;
            }
            MetaSource::Remote { .. } => {}
        }
        self
    }

    /// Return this source with the sparse-kernel width swapped
    /// (`Some(k)` = top-`k` CSR class blocks, `None` = dense; no-op on a
    /// remote source). Sparse and dense configurations address separate
    /// store artifacts — `knn` is part of the [`MetaKey`] fingerprint.
    pub fn with_knn(mut self, knn: Option<usize>) -> MetaSource {
        match &mut self {
            MetaSource::Inline(o) | MetaSource::Store { opts: o, .. } => {
                o.knn = knn;
            }
            MetaSource::Remote { .. } => {}
        }
        self
    }

    /// Preprocessing options backing this source, when local.
    pub fn options(&self) -> Option<&PreprocessOptions> {
        match self {
            MetaSource::Inline(o) | MetaSource::Store { opts: o, .. } => Some(o),
            MetaSource::Remote { .. } => None,
        }
    }

    /// The single resolution entry point. `rt` is required by
    /// [`MetaSource::Inline`] (and by a [`MetaSource::Store`] miss that
    /// must build); store hits and remote fetches work without one, which
    /// is what lets model-agnostic consumers run with no runtime at all.
    pub fn resolve(&self, rt: Option<&Runtime>, ds: &Dataset) -> Result<Arc<Metadata>> {
        // per-source-kind resolution latency in the global registry
        // (`span.session.resolve.*`) — how long consumers wait on metadata
        let _span = crate::obs::Span::enter(match self {
            MetaSource::Inline(_) => "session.resolve.inline",
            MetaSource::Store { .. } => "session.resolve.store",
            MetaSource::Remote { .. } => "session.resolve.remote",
        });
        match self {
            MetaSource::Inline(opts) => {
                let rt = rt.ok_or_else(|| {
                    anyhow!("MetaSource::Inline needs a runtime to preprocess")
                })?;
                let pre = Preprocessor::with_options(rt, opts.clone());
                Ok(Arc::new(pre.execute(ds)?))
            }
            MetaSource::Store { store, opts } => {
                let key = MetaKey::from_options(ds.name(), opts);
                store.get_or_build(&key, || match rt {
                    Some(rt) => Preprocessor::with_options(rt, opts.clone()).execute(ds),
                    None => bail!(
                        "metadata {} is not in the store and no runtime is \
                         available to build it",
                        key.canonical()
                    ),
                })
            }
            MetaSource::Remote {
                addr,
                client_id,
                expect_seed,
                expect_fraction,
                wire,
                retry,
                pool,
            } => {
                // route to the right entry on a multi-dataset server: the
                // HELLO names the dataset (and fraction, when expected), so
                // a server not holding it refuses loudly up front
                let opts = ClientOptions {
                    wire: *wire,
                    dataset: Some(ds.name().to_string()),
                    fraction: *expect_fraction,
                    retry: *retry,
                };
                let mut client = connect_remote(addr, pool, client_id, opts)?;
                if let Some(seed) = expect_seed {
                    ensure!(
                        client.server_seed() == *seed,
                        "serve at {addr} runs seed {}, this source expects {seed}",
                        client.server_seed(),
                    );
                }
                let meta = client.get_meta()?;
                // a mismatched server would hand us subsets indexing a
                // different train set — fail loudly, never train on them
                ensure!(
                    meta.dataset == ds.name(),
                    "serve at {addr} holds metadata for dataset {:?}, \
                     this source expects {:?}",
                    meta.dataset,
                    ds.name(),
                );
                if let Some(fraction) = expect_fraction {
                    ensure!(
                        (meta.fraction - fraction).abs() < 1e-9,
                        "serve at {addr} holds metadata for fraction {}, \
                         this source expects {fraction}",
                        meta.fraction,
                    );
                }
                Ok(Arc::new(meta))
            }
        }
    }
}

/// Dial a served source's client: a multiplexed stream leased from the
/// shared pool when one is configured (frame wire only — the stream id
/// lives in the frame header), else a dedicated socket.
fn connect_remote(
    addr: &str,
    pool: &Option<ConnectionPool>,
    client_id: &str,
    opts: ClientOptions,
) -> Result<ServeClient> {
    match pool {
        Some(pool) if opts.wire == WireMode::Frame => {
            ServeClient::connect_pooled(pool, client_id, opts)
        }
        _ => ServeClient::connect_with(addr, client_id, opts),
    }
}

/// Builder for [`MiloSession`]; see [`MiloSession::builder`].
#[derive(Default)]
pub struct MiloSessionBuilder<'a> {
    rt: Option<&'a Runtime>,
    ds: Option<Dataset>,
    source: Option<MetaSource>,
    fraction: Option<f64>,
    seed: Option<u64>,
}

impl<'a> MiloSessionBuilder<'a> {
    /// Attach the AOT artifact runtime. Optional: sessions over store or
    /// remote sources can run model-agnostic strategies without one;
    /// anything that preprocesses or trains will error until a runtime is
    /// attached.
    pub fn runtime(mut self, rt: &'a Runtime) -> Self {
        self.rt = Some(rt);
        self
    }

    /// The dataset this session selects over (required).
    pub fn dataset(mut self, ds: Dataset) -> Self {
        self.ds = Some(ds);
        self
    }

    /// Where metadata comes from. Defaults to
    /// `MetaSource::inline(PreprocessOptions::default())`.
    pub fn source(mut self, source: MetaSource) -> Self {
        self.source = Some(source);
        self
    }

    /// Subset fraction; overrides the source's configured fraction so the
    /// session has exactly one answer. Defaults to the source's fraction
    /// (0.1 for an expectation-free remote).
    pub fn fraction(mut self, fraction: f64) -> Self {
        self.fraction = Some(fraction);
        self
    }

    /// Preprocessing seed; overrides the source's configured seed the same
    /// way.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn build(self) -> Result<MiloSession<'a>> {
        let ds = self.ds.ok_or_else(|| anyhow!("MiloSession needs a dataset"))?;
        let mut source = self
            .source
            .unwrap_or_else(|| MetaSource::inline(PreprocessOptions::default()));
        if let Some(fraction) = self.fraction {
            source = source.with_fraction(fraction);
        }
        if let Some(seed) = self.seed {
            source = source.with_seed(seed);
        }
        let fraction = self.fraction.or_else(|| source.fraction()).unwrap_or(0.1);
        let seed = self.seed.or_else(|| source.seed()).unwrap_or(1);
        ensure!(
            fraction > 0.0 && fraction <= 1.0,
            "fraction must be in (0, 1], got {fraction}"
        );
        Ok(MiloSession {
            rt: self.rt,
            ds,
            source,
            fraction,
            seed,
            resolved: Mutex::new(None),
            embeddings: Mutex::new(None),
        })
    }
}

/// One dataset + one metadata source + one cached resolution, shared by
/// every consumer the session hands out. See the [module docs](self).
pub struct MiloSession<'a> {
    rt: Option<&'a Runtime>,
    ds: Dataset,
    source: MetaSource,
    fraction: f64,
    seed: u64,
    resolved: Mutex<Option<Arc<Metadata>>>,
    /// Cached train-split encoder embeddings (SSL pruning input).
    embeddings: Mutex<Option<Arc<crate::tensor::Matrix>>>,
}

impl<'a> MiloSession<'a> {
    pub fn builder() -> MiloSessionBuilder<'a> {
        MiloSessionBuilder::default()
    }

    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }

    pub fn source(&self) -> &MetaSource {
        &self.source
    }

    pub fn fraction(&self) -> f64 {
        self.fraction
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Subset size implied by the session fraction.
    pub fn k(&self) -> usize {
        self.ds.subset_size(self.fraction)
    }

    /// The attached runtime, or a descriptive error for consumers that
    /// need one.
    pub fn runtime(&self) -> Result<&'a Runtime> {
        self.rt.ok_or_else(|| {
            anyhow!(
                "this MiloSession has no runtime attached (builder().runtime(..)); \
                 preprocessing and training need the AOT artifacts"
            )
        })
    }

    /// Resolve the session's metadata through its source — exactly once;
    /// every later call (and every consumer built from this session) gets
    /// the cached `Arc`.
    pub fn metadata(&self) -> Result<Arc<Metadata>> {
        let mut slot = self.resolved.lock().unwrap();
        if let Some(meta) = &*slot {
            return Ok(meta.clone());
        }
        let meta = self.source.resolve(self.rt, &self.ds)?;
        // Local sources inherit the session fraction by construction, but
        // an expectation-free remote (or a hand-crafted store artifact)
        // could hold a different subset size — training a 10% config on
        // 30% subsets must be loud, never silent.
        ensure!(
            (meta.fraction - self.fraction).abs() < 1e-9,
            "resolved metadata holds fraction {}, this session is configured \
             for {} (set .fraction(..) on the builder to match the source)",
            meta.fraction,
            self.fraction,
        );
        *slot = Some(meta.clone());
        Ok(meta)
    }

    /// Encoder embeddings over the train split (SSL pruning input) —
    /// computed once per session, like [`MiloSession::metadata`].
    fn ssl_embeddings(&self) -> Result<Arc<crate::tensor::Matrix>> {
        let mut slot = self.embeddings.lock().unwrap();
        if let Some(emb) = &*slot {
            return Ok(emb.clone());
        }
        let pre =
            Preprocessor::with_options(self.runtime()?, self.preprocess_options());
        let emb = Arc::new(pre.encode(&self.ds, Split::Train)?);
        *slot = Some(emb.clone());
        Ok(emb)
    }

    /// Preprocessing options consistent with this session (used for
    /// embedding-only passes like SSL pruning).
    fn preprocess_options(&self) -> PreprocessOptions {
        match self.source.options() {
            Some(opts) => opts.clone(),
            None => PreprocessOptions {
                fraction: self.fraction,
                seed: self.seed,
                ..Default::default()
            },
        }
    }

    /// Build any [`StrategyKind`] against this session's shared
    /// resolution. All strategy construction funnels through
    /// [`StrategyKind::build`]; the session supplies the inputs each kind
    /// needs (metadata, embeddings) from its cache.
    pub fn strategy(&self, kind: StrategyKind) -> Result<Box<dyn Strategy>> {
        let metadata = if kind.needs_metadata() {
            Some(self.metadata()?)
        } else {
            None
        };
        let embeddings = if matches!(kind, StrategyKind::SslPrune) {
            Some(self.ssl_embeddings()?)
        } else {
            None
        };
        kind.build(metadata.as_deref(), embeddings.as_deref())
    }

    /// A live served strategy (SGE cycle + WRE draws over the wire) —
    /// requires a [`MetaSource::Remote`] source. Inherits the source's
    /// wire format and retry policy and routes to this session's
    /// `(dataset, fraction)` entry on a multi-dataset server.
    pub fn served_strategy(
        &self,
        client_id: &str,
        kappa: f64,
    ) -> Result<ServedMiloStrategy> {
        match &self.source {
            MetaSource::Remote { addr, wire, retry, pool, .. } => {
                let opts = ClientOptions {
                    wire: *wire,
                    dataset: Some(self.ds.name().to_string()),
                    fraction: Some(self.fraction),
                    retry: *retry,
                };
                match pool {
                    Some(pool) if *wire == WireMode::Frame => {
                        ServedMiloStrategy::connect_pooled(pool, client_id, kappa, opts)
                    }
                    _ => ServedMiloStrategy::connect_with(addr, client_id, kappa, opts),
                }
            }
            other => bail!(
                "served_strategy needs a MetaSource::Remote source, this session \
                 uses {other:?}"
            ),
        }
    }

    /// A subscribed follow-mode client for a continual-arrival server —
    /// requires a [`MetaSource::Remote`] source. Negotiates the frame
    /// wire (push frames are binary) and routes by dataset only: a
    /// followed entry's fraction drifts as the stream grows (a fixed-size
    /// buffer over more arrivals), so the bind-time fraction key is not
    /// required to match this session's. Iterate epoch updates with
    /// [`ServeClient::follow`] / [`ServeClient::poll_push`].
    pub fn follow_client(&self, client_id: &str) -> Result<ServeClient> {
        match &self.source {
            MetaSource::Remote { addr, retry, pool, .. } => {
                let opts = ClientOptions {
                    wire: WireMode::Frame,
                    dataset: Some(self.ds.name().to_string()),
                    fraction: None,
                    retry: *retry,
                };
                let mut client = connect_remote(addr, pool, client_id, opts)?;
                client.subscribe()?;
                Ok(client)
            }
            other => bail!(
                "follow_client needs a MetaSource::Remote source, this session \
                 uses {other:?}"
            ),
        }
    }

    /// A trainer over this session's runtime and dataset.
    pub fn trainer(&self, cfg: TrainConfig) -> Result<Trainer<'_>> {
        Trainer::new(self.runtime()?, &self.ds, cfg)
    }

    /// Train one model with `kind` choosing subsets — strategy
    /// construction, fraction wiring, and the shared resolution in one
    /// call. The session's fraction is authoritative (`cfg.fraction` is
    /// overwritten; FULL variants train on everything as always).
    pub fn train(&self, kind: StrategyKind, mut cfg: TrainConfig) -> Result<TrainOutcome> {
        // FullEarlyStop's semantics live entirely in the time budget
        // (ExperimentRunner::run_cell budget-matches it against a subset
        // run); without one it would silently degrade to plain FULL.
        if matches!(kind, StrategyKind::FullEarlyStop) {
            ensure!(
                cfg.time_budget_secs.is_some(),
                "StrategyKind::FullEarlyStop needs cfg.time_budget_secs (or use \
                 session.runner(..) which budget-matches it against a subset run)"
            );
        }
        cfg.fraction = if matches!(kind, StrategyKind::Full | StrategyKind::FullEarlyStop)
        {
            1.0
        } else {
            self.fraction
        };
        let mut strategy = self.strategy(kind)?;
        self.trainer(cfg)?.run(strategy.as_mut())
    }

    /// A tuner whose trials share this session's resolution (the
    /// amortization that makes MILO tuning fast). The tuner's fraction
    /// must match the session's when its strategy consumes metadata.
    pub fn tuner(&self, cfg: HpoConfig) -> Result<Tuner<'_>> {
        let rt = self.runtime()?;
        if cfg.strategy.needs_metadata() {
            ensure!(
                (cfg.fraction - self.fraction).abs() < 1e-9,
                "HpoConfig fraction {} differs from the session fraction {} — \
                 the shared metadata would not match",
                cfg.fraction,
                self.fraction,
            );
        }
        let needs_meta = cfg.strategy.needs_metadata();
        let mut tuner = Tuner::new(rt, &self.ds, cfg);
        tuner.source = Some(self.source.clone());
        if needs_meta {
            tuner.metadata = Some(self.metadata()?);
        }
        Ok(tuner)
    }

    /// An experiment runner whose per-cell preprocessing routes through
    /// this session's source (re-targeted per fraction/seed cell).
    pub fn runner(&self, epochs: usize) -> Result<ExperimentRunner<'_>> {
        let mut runner = ExperimentRunner::new(self.runtime()?, &self.ds, epochs);
        if let Some(opts) = self.source.options() {
            runner.backend = opts.backend;
        }
        runner.source = Some(self.source.clone());
        Ok(runner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::testkit::synthetic_metadata;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("milo_session_{tag}_{}", std::process::id()))
    }

    #[test]
    fn builder_requires_dataset() {
        assert!(MiloSession::builder().build().is_err());
    }

    #[test]
    fn builder_fraction_overrides_source() {
        let ds = DatasetId::Trec6Like.generate(1);
        let session = MiloSession::builder()
            .dataset(ds)
            .source(MetaSource::inline(PreprocessOptions {
                fraction: 0.5,
                ..Default::default()
            }))
            .fraction(0.2)
            .build()
            .unwrap();
        assert_eq!(session.fraction(), 0.2);
        assert_eq!(session.source().fraction(), Some(0.2));
    }

    #[test]
    fn inline_without_runtime_errors_cleanly() {
        let ds = DatasetId::Trec6Like.generate(1);
        let session = MiloSession::builder().dataset(ds).build().unwrap();
        let err = session.metadata().unwrap_err();
        assert!(format!("{err:#}").contains("runtime"), "{err:#}");
    }

    #[test]
    fn store_session_resolves_and_caches_without_runtime() {
        let dir = tmp_dir("store_noruntime");
        std::fs::remove_dir_all(&dir).ok();
        let ds = DatasetId::Trec6Like.generate(3);
        let opts = PreprocessOptions { fraction: 0.1, seed: 3, ..Default::default() };
        let store = MetaStore::open(&dir).unwrap();
        let key = MetaKey::from_options(ds.name(), &opts);
        store.put(&key, synthetic_metadata(&ds, 0.1)).unwrap();

        let session = MiloSession::builder()
            .dataset(DatasetId::Trec6Like.generate(3))
            .source(MetaSource::store_handle(store.clone(), opts))
            .build()
            .unwrap();
        let a = session.metadata().unwrap();
        let b = session.metadata().unwrap();
        assert!(Arc::ptr_eq(&a, &b), "resolution must be cached");
        assert_eq!(a.dataset, "trec6");

        // model-agnostic strategies come straight off the session, no
        // runtime and no MlpModel anywhere
        let mut strat = session.strategy(StrategyKind::Milo { kappa: 0.5 }).unwrap();
        let mut rng = crate::util::rng::Rng::new(7);
        let mut ctx = crate::selection::SelectCtx::model_agnostic(
            session.dataset(),
            0,
            10,
            session.k(),
            &mut rng,
        );
        let sel = strat.select(&mut ctx).unwrap();
        assert_eq!(sel, a.sge_subsets[0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_miss_without_runtime_is_a_clean_error() {
        let dir = tmp_dir("store_miss");
        std::fs::remove_dir_all(&dir).ok();
        let ds = DatasetId::Trec6Like.generate(4);
        let source = MetaSource::store(
            &dir,
            PreprocessOptions { seed: 4, ..Default::default() },
        )
        .unwrap();
        let err = source.resolve(None, &ds).unwrap_err();
        assert!(format!("{err:#}").contains("no runtime"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn remote_source_validates_dataset_and_seed() {
        let ds = DatasetId::Trec6Like.generate(5);
        let meta = Arc::new(synthetic_metadata(&ds, 0.1));
        let server =
            crate::serve::SubsetServer::bind("127.0.0.1:0", meta, None, 5).unwrap();
        let addr = server.addr().to_string();

        // matching expectations resolve
        let ok = MetaSource::remote_expecting(&addr, 5, 0.1).resolve(None, &ds);
        assert_eq!(ok.unwrap().dataset, "trec6");

        // wrong seed expectation is refused
        let err = MetaSource::remote_expecting(&addr, 6, 0.1)
            .resolve(None, &ds)
            .unwrap_err();
        assert!(format!("{err:#}").contains("seed"), "{err:#}");

        // wrong dataset is refused
        let other = DatasetId::RottenLike.generate(5);
        let err = MetaSource::remote(&addr).resolve(None, &other).unwrap_err();
        assert!(format!("{err:#}").contains("dataset"), "{err:#}");
        server.shutdown();
    }
}
