//! Compact binary encoding for [`Metadata`] artifacts.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic    8  b"MILOSTOR"
//! version  4  u32 — FORMAT_VERSION; readers reject anything else
//! dataset  4+n  u32 length + UTF-8 bytes
//! fraction 8  f64
//! secs     8  f64 (preprocess_secs)
//! sge      4  u32 subset count
//!          per subset: 4 u32 length + length×4 u32 indices
//! wre      4  u32 class count
//!          per class: 4 u32 length + length×4 u32 indices
//!                     + length×8 f64 probabilities
//! fixed    4  u32 length + length×4 u32 indices
//! check    8  u64 FNV-1a over every preceding byte
//! ```
//!
//! The encoding is deterministic, so save → load → save is byte-identical
//! (property-tested in `rust/tests/store_props.rs`). Decoding validates the
//! magic, the schema version, every length prefix against the remaining
//! buffer (no length-driven over-allocation), and the trailing checksum —
//! a truncated or bit-flipped artifact is a clean `Err`, never a panic or
//! a silently wrong selection.

use anyhow::{bail, ensure, Result};

use super::fnv1a64;
use crate::coordinator::Metadata;
use crate::selection::milo::ClassProbs;

pub const MAGIC: &[u8; 8] = b"MILOSTOR";
pub const FORMAT_VERSION: u32 = 1;

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_indices(out: &mut Vec<u8>, idx: &[usize]) -> Result<()> {
    ensure!(idx.len() <= u32::MAX as usize, "subset too large for format");
    push_u32(out, idx.len() as u32);
    for &i in idx {
        ensure!(i <= u32::MAX as usize, "index {i} overflows u32");
        push_u32(out, i as u32);
    }
    Ok(())
}

/// Fallible serialization: validates the format contract (every index and
/// length fits `u32`, per-class probs aligned with indices) and returns a
/// clean `Err` for a document that cannot be represented. The serve layer
/// uses this so a pathological in-memory document degrades to a protocol
/// error instead of panicking the event loop.
pub fn try_encode(meta: &Metadata) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(64 + 4 * meta.fixed_dm.len());
    out.extend_from_slice(MAGIC);
    push_u32(&mut out, FORMAT_VERSION);
    ensure!(meta.dataset.len() <= u32::MAX as usize, "dataset name too long");
    push_u32(&mut out, meta.dataset.len() as u32);
    out.extend_from_slice(meta.dataset.as_bytes());
    push_f64(&mut out, meta.fraction);
    push_f64(&mut out, meta.preprocess_secs);
    ensure!(meta.sge_subsets.len() <= u32::MAX as usize, "too many SGE subsets");
    push_u32(&mut out, meta.sge_subsets.len() as u32);
    for s in &meta.sge_subsets {
        push_indices(&mut out, s)?;
    }
    ensure!(meta.wre_classes.len() <= u32::MAX as usize, "too many WRE classes");
    push_u32(&mut out, meta.wre_classes.len() as u32);
    for c in &meta.wre_classes {
        ensure!(
            c.indices.len() == c.probs.len(),
            "ClassProbs invariant violated: {} indices vs {} probs",
            c.indices.len(),
            c.probs.len(),
        );
        push_indices(&mut out, &c.indices)?;
        for &p in &c.probs {
            push_f64(&mut out, p);
        }
    }
    push_indices(&mut out, &meta.fixed_dm)?;
    let check = fnv1a64(&out);
    out.extend_from_slice(&check.to_le_bytes());
    Ok(out)
}

/// Serialize metadata to the versioned binary layout. Panics on a document
/// that violates the format contract — every `Metadata` produced by the
/// pipeline satisfies it; use [`try_encode`] when the document comes from
/// an untrusted source.
pub fn encode(meta: &Metadata) -> Vec<u8> {
    try_encode(meta).expect("metadata violates the binfmt format contract")
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.bytes.len() - self.pos < n {
            bail!(
                "truncated artifact: wanted {n} bytes at offset {}, {} left",
                self.pos,
                self.bytes.len() - self.pos
            );
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// Length-prefixed count, validated against the bytes actually left
    /// (`elem_bytes` per element) so a corrupted length can't drive an
    /// over-allocation.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_bytes) > self.bytes.len() - self.pos {
            bail!("corrupt length {n} at offset {}", self.pos - 4);
        }
        Ok(n)
    }

    fn indices(&mut self) -> Result<Vec<usize>> {
        let n = self.count(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32()? as usize);
        }
        Ok(out)
    }
}

/// Decode a binary artifact, validating magic, version, lengths, and
/// checksum.
pub fn decode(bytes: &[u8]) -> Result<Metadata> {
    if bytes.len() < MAGIC.len() + 4 + 8 {
        bail!("artifact too short ({} bytes)", bytes.len());
    }
    if &bytes[..MAGIC.len()] != MAGIC {
        bail!("bad magic: not a milo metadata artifact");
    }
    let (payload, check_bytes) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes([
        check_bytes[0],
        check_bytes[1],
        check_bytes[2],
        check_bytes[3],
        check_bytes[4],
        check_bytes[5],
        check_bytes[6],
        check_bytes[7],
    ]);
    if fnv1a64(payload) != stored {
        bail!("checksum mismatch: artifact is truncated or corrupted");
    }
    let mut c = Cursor { bytes: payload, pos: MAGIC.len() };
    let version = c.u32()?;
    if version != FORMAT_VERSION {
        bail!(
            "schema version mismatch: artifact is v{version}, this build reads v{FORMAT_VERSION}"
        );
    }
    let name_len = c.count(1)?;
    let dataset = std::str::from_utf8(c.take(name_len)?)?.to_string();
    let fraction = c.f64()?;
    let preprocess_secs = c.f64()?;
    let n_sge = c.count(4)?;
    let mut sge_subsets = Vec::with_capacity(n_sge);
    for _ in 0..n_sge {
        sge_subsets.push(c.indices()?);
    }
    let n_wre = c.count(4)?;
    let mut wre_classes = Vec::with_capacity(n_wre);
    for _ in 0..n_wre {
        let indices = c.indices()?;
        let mut probs = Vec::with_capacity(indices.len());
        for _ in 0..indices.len() {
            probs.push(c.f64()?);
        }
        wre_classes.push(ClassProbs { indices, probs });
    }
    let fixed_dm = c.indices()?;
    if c.pos != payload.len() {
        bail!("trailing bytes after metadata payload (offset {})", c.pos);
    }
    Ok(Metadata {
        dataset,
        fraction,
        sge_subsets,
        wre_classes,
        fixed_dm,
        preprocess_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> Metadata {
        Metadata {
            dataset: "cifar10".into(),
            fraction: 0.1,
            sge_subsets: vec![vec![0, 3, 7], vec![1, 4, 8]],
            wre_classes: vec![
                ClassProbs { indices: vec![0, 1], probs: vec![0.75, 0.25] },
                ClassProbs { indices: vec![2, 3, 4], probs: vec![0.2, 0.3, 0.5] },
            ],
            fixed_dm: vec![0, 4],
            preprocess_secs: 2.5,
        }
    }

    #[test]
    fn roundtrip_is_exact_and_byte_identical() {
        let m = meta();
        let bytes = encode(&m);
        let back = decode(&bytes).unwrap();
        assert_eq!(back, m);
        assert_eq!(encode(&back), bytes, "re-encode must be byte-identical");
    }

    #[test]
    fn every_truncation_is_a_clean_error() {
        let bytes = encode(&meta());
        for cut in 0..bytes.len() {
            assert!(
                decode(&bytes[..cut]).is_err(),
                "truncation to {cut} bytes must fail"
            );
        }
    }

    #[test]
    fn bit_flips_fail_the_checksum() {
        let bytes = encode(&meta());
        for pos in [0, 9, 13, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            assert!(decode(&bad).is_err(), "flip at {pos} must fail");
        }
    }

    #[test]
    fn future_schema_version_is_rejected_with_guidance() {
        let mut bytes = encode(&meta());
        // bump the version field and re-stamp the checksum
        bytes[8..12].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        let n = bytes.len();
        let check = fnv1a64(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&check.to_le_bytes());
        let err = decode(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn empty_metadata_roundtrips() {
        let m = Metadata {
            dataset: String::new(),
            fraction: 0.0,
            sge_subsets: vec![],
            wre_classes: vec![],
            fixed_dm: vec![],
            preprocess_secs: 0.0,
        };
        assert_eq!(decode(&encode(&m)).unwrap(), m);
    }
}
