//! Selection-metadata store: a versioned, content-addressed registry for
//! pre-processed MILO metadata (SGE subsets, WRE distributions, fixed
//! subsets).
//!
//! The paper's central economics — "pre-processing only needs to be done
//! once per dataset (and subset size)" — only pays off if *every* consumer
//! (trainer, HPO trial, bench, served client) can find and share the one
//! artifact that matches its configuration. The store makes that artifact
//! first-class:
//!
//! * **Content addressing** — a [`MetaKey`] canonically fingerprints
//!   `(dataset, encoder, set functions, fraction, n_subsets, ε, seed,
//!   kernel metric)`; two preprocessing runs with the same key share one
//!   file and one cache slot, while any change to the recipe gets a new
//!   address instead of silently reusing stale selections.
//! * **Compact binary encoding** — [`binfmt`] replaces the seed's JSON
//!   round-trip (the hot path for HPO, where every trial used to re-parse
//!   float arrays) with a length-prefixed little-endian layout plus an
//!   FNV-1a checksum, so corrupted or truncated artifacts are detected and
//!   rebuilt rather than mis-parsed.
//! * **Schema versioning** — artifacts carry a format version; a store
//!   reading a future/past layout rebuilds instead of guessing.
//! * **Shared in-process LRU** — a [`MetaStore`] is a cheap-`Clone` handle
//!   over one `Arc`'d cache, so N threads (HPO trials, served connections)
//!   hit the same decoded [`Metadata`] without re-reading disk.
//!
//! [`MetaStore::get_or_build`] is the single entry point:
//! cache hit → disk load → build, with per-fingerprint build locks —
//! concurrent callers of one configuration trigger exactly one
//! preprocessing pass while distinct configurations build in parallel.
//! [`MetaStore::shared`] hands out one process-wide handle per root so
//! independent call sites get the same guarantee.

pub mod binfmt;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{Metadata, PreprocessOptions};
use crate::obs::{Counter, Histogram, MetricsRegistry};
use crate::submod::SetFunctionKind;
use crate::util::json::Json;

/// Selection-algorithm revision, folded into every [`MetaKey`]
/// fingerprint. Bumped whenever the preprocessing pipeline changes the
/// selections it produces for *identical options* (rev 2: per-
/// `(subset, class)` RNG streams for the parallel SGE fan-out), so
/// artifacts built by an older revision re-address and rebuild instead
/// of silently serving selections the current code cannot reproduce.
pub const SELECTION_ALGO_REVISION: u32 = 2;

/// FNV-1a 64-bit hash — the store's fingerprint and checksum primitive
/// (dependency-free, stable across platforms).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Full descriptor of a set function, including parameters that
/// `SetFunctionKind::name` elides (graph-cut λ changes the selection, so it
/// must change the address).
pub fn set_function_descriptor(kind: SetFunctionKind) -> String {
    match kind {
        SetFunctionKind::GraphCut { lambda } => format!("graph_cut_l{lambda}"),
        other => other.name().to_string(),
    }
}

/// Address component for the similarity backend. PJRT and native kernels
/// agree only to float tolerance, so greedy tie-breaks (and thus the
/// selections) can differ — the two must not alias to one artifact.
pub fn backend_descriptor(backend: crate::kernel::SimilarityBackend) -> &'static str {
    match backend {
        crate::kernel::SimilarityBackend::Pjrt => "pjrt",
        crate::kernel::SimilarityBackend::Native => "native",
    }
}

/// Canonical fingerprint key of one preprocessing configuration. Everything
/// that changes the selection output is part of the address; nothing else
/// is. In particular the kernel-build *schedule*
/// ([`PreprocessOptions::sim_tile`] / `pipeline_depth`, see
/// [`crate::kernel::pipeline`]) is deliberately absent: it changes wall
/// time, never kernel values — the bit-identity property tests in
/// `rust/tests/kernel_pipeline.rs` prove the exclusion sound.
#[derive(Clone, Debug, PartialEq)]
pub struct MetaKey {
    pub dataset: String,
    /// Encoder artifact variant; `"default"` for the zero-shot encoder.
    pub encoder: String,
    pub sge_function: String,
    pub wre_function: String,
    pub fraction: f64,
    pub n_subsets: usize,
    pub epsilon: f64,
    pub seed: u64,
    pub metric: String,
    /// Similarity backend (`"pjrt"` / `"native"`) — part of the address
    /// because the backends agree only to float tolerance.
    pub backend: String,
    /// Preprocessing pipeline (`"kernel"` / `"feature_based"`) — the two
    /// pipelines select different subsets from identical inputs, so they
    /// must not alias to one artifact.
    pub pipeline: String,
    /// Sparse kernel width (`None` = dense blocks). `knn < n_c` changes
    /// the selections (the sparse kernel is an approximation), so sparse
    /// and dense artifacts must address separately.
    pub knn: Option<usize>,
    /// Continual-arrival epoch (`None` = the ordinary batch artifact).
    /// Each [`crate::continual::ContinualSelector::advance_epoch`] output
    /// is immutable and addresses separately; `None` keys fingerprint
    /// exactly as before the epoch component existed, so every batch
    /// artifact keeps its address.
    pub epoch: Option<u64>,
}

impl MetaKey {
    /// Key for a [`Preprocessor`](crate::coordinator::Preprocessor) run of
    /// `dataset` under `opts`.
    pub fn from_options(dataset: &str, opts: &PreprocessOptions) -> MetaKey {
        MetaKey {
            dataset: dataset.to_string(),
            encoder: opts
                .encoder_variant
                .clone()
                .unwrap_or_else(|| "default".to_string()),
            sge_function: set_function_descriptor(opts.sge_function),
            wre_function: set_function_descriptor(opts.wre_function),
            fraction: opts.fraction,
            n_subsets: opts.n_sge_subsets,
            epsilon: opts.epsilon,
            seed: opts.seed,
            metric: opts.metric.name(),
            backend: backend_descriptor(opts.backend).to_string(),
            pipeline: opts.pipeline.name().to_string(),
            knn: opts.knn,
            epoch: None,
        }
    }

    /// This key pinned to one continual-arrival epoch (the version-chain
    /// member, not the batch artifact).
    pub fn at_epoch(&self, epoch: u64) -> MetaKey {
        MetaKey { epoch: Some(epoch), ..self.clone() }
    }

    /// Canonical string form — the pre-image of the fingerprint. Field
    /// order is fixed; floats use Rust's shortest-roundtrip formatting, so
    /// equal f64 values always produce equal text. The epoch component is
    /// appended only when pinned, so pre-epoch keys (and their on-disk
    /// artifacts) keep their exact historical addresses.
    pub fn canonical(&self) -> String {
        let mut s = format!(
            "alg={}|ds={}|enc={}|sge={}|wre={}|f={}|n={}|eps={}|seed={}|metric={}|backend={}|pipe={}|knn={}",
            SELECTION_ALGO_REVISION,
            self.dataset,
            self.encoder,
            self.sge_function,
            self.wre_function,
            self.fraction,
            self.n_subsets,
            self.epsilon,
            self.seed,
            self.metric,
            self.backend,
            self.pipeline,
            self.knn
                .map(|k| k.to_string())
                .unwrap_or_else(|| "dense".to_string()),
        );
        if let Some(e) = self.epoch {
            s.push_str(&format!("|epoch={e}"));
        }
        s
    }

    /// 16-hex-char content address.
    pub fn fingerprint(&self) -> String {
        format!("{:016x}", fnv1a64(self.canonical().as_bytes()))
    }

    /// Store-relative file name: human-greppable dataset prefix + address.
    pub fn file_name(&self) -> String {
        format!("{}_{}.meta", self.dataset, self.fingerprint())
    }
}

/// Monotonic counters over a store's lifetime (exposed via `milo serve`
/// STATS and asserted by the amortization tests: `builds == 1` is the
/// paper's "train multiple models at no additional cost").
///
/// This is a snapshot of the store's [`MetricsRegistry`] counters — the
/// registry (see [`MetaStore::registry`]) additionally carries
/// hit/disk-load/build latency histograms that the struct form elides.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// `get_or_build` satisfied from the in-process LRU.
    pub hits: u64,
    /// `get_or_build` calls that missed the LRU.
    pub misses: u64,
    /// Misses satisfied by decoding a persisted artifact.
    pub disk_loads: u64,
    /// Misses that ran the builder (a full preprocessing pass).
    pub builds: u64,
    /// LRU entries evicted to respect capacity.
    pub evictions: u64,
}

/// The store's per-instance metrics: one registry, with counter and
/// histogram handles pre-resolved so `get_or_build` never takes the
/// registry lock.
struct StoreMetrics {
    registry: MetricsRegistry,
    hits: Counter,
    misses: Counter,
    disk_loads: Counter,
    builds: Counter,
    evictions: Counter,
    hit_latency: Arc<Histogram>,
    disk_load_latency: Arc<Histogram>,
    build_latency: Arc<Histogram>,
}

impl StoreMetrics {
    fn new() -> StoreMetrics {
        let registry = MetricsRegistry::new();
        StoreMetrics {
            hits: registry.counter("store.hits"),
            misses: registry.counter("store.misses"),
            disk_loads: registry.counter("store.disk_loads"),
            builds: registry.counter("store.builds"),
            evictions: registry.counter("store.evictions"),
            hit_latency: registry.histogram("store.hit_latency_ns"),
            disk_load_latency: registry.histogram("store.disk_load_latency_ns"),
            build_latency: registry.histogram("store.build_latency_ns"),
            registry,
        }
    }

    fn snapshot(&self) -> StoreStats {
        StoreStats {
            hits: self.hits.get(),
            misses: self.misses.get(),
            disk_loads: self.disk_loads.get(),
            builds: self.builds.get(),
            evictions: self.evictions.get(),
        }
    }
}

/// In-process LRU over decoded metadata, keyed by fingerprint. Entries are
/// `Arc`s, so eviction never invalidates a handle a trainer still holds.
struct LruCache {
    cap: usize,
    tick: u64,
    map: HashMap<String, (Arc<Metadata>, u64)>,
}

impl LruCache {
    fn get(&mut self, fp: &str) -> Option<Arc<Metadata>> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(fp).map(|slot| {
            slot.1 = tick;
            slot.0.clone()
        })
    }

    /// Insert, returning how many entries were evicted.
    fn insert(&mut self, fp: String, meta: Arc<Metadata>) -> u64 {
        self.tick += 1;
        self.map.insert(fp, (meta, self.tick));
        let mut evicted = 0;
        while self.map.len() > self.cap {
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| k.clone())
                .expect("non-empty map over capacity");
            self.map.remove(&oldest);
            evicted += 1;
        }
        evicted
    }
}

struct StoreInner {
    root: PathBuf,
    cache: Mutex<LruCache>,
    /// One lock per fingerprint: concurrent `get_or_build` callers of the
    /// *same* key run exactly one disk load / builder invocation, while
    /// distinct keys (other datasets/fractions) build in parallel instead
    /// of queueing behind an unrelated minutes-long preprocessing pass.
    key_locks: Mutex<HashMap<String, Arc<Mutex<()>>>>,
    metrics: StoreMetrics,
}

/// Handle to a metadata store rooted at a directory. `Clone` is cheap and
/// all clones share one cache and one stats block — pass clones freely to
/// worker threads and server connections.
#[derive(Clone)]
pub struct MetaStore {
    inner: Arc<StoreInner>,
}

/// Default LRU capacity: HPO sweeps touch a handful of (dataset, fraction)
/// cells at a time; decoded metadata is O(n_train) floats per entry.
pub const DEFAULT_CACHE_ENTRIES: usize = 16;

/// Process-wide registry backing [`MetaStore::shared`].
static SHARED_STORES: OnceLock<Mutex<HashMap<PathBuf, MetaStore>>> = OnceLock::new();

impl MetaStore {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> Result<MetaStore> {
        Self::with_capacity(root, DEFAULT_CACHE_ENTRIES)
    }

    /// Process-wide shared handle for `root`: every caller passing the
    /// same root (byte-identical path — no canonicalization) gets the same
    /// LRU and per-key build locks, so independent call sites (e.g.
    /// `session::MetaSource::store` resolutions across experiment threads)
    /// still trigger at most one preprocessing pass per configuration.
    pub fn shared(root: impl Into<PathBuf>) -> Result<MetaStore> {
        let root = root.into();
        let registry = SHARED_STORES.get_or_init(|| Mutex::new(HashMap::new()));
        let mut registry = registry.lock().unwrap();
        if let Some(store) = registry.get(&root) {
            return Ok(store.clone());
        }
        let store = MetaStore::open(root.clone())?;
        registry.insert(root, store.clone());
        Ok(store)
    }

    pub fn with_capacity(root: impl Into<PathBuf>, cap: usize) -> Result<MetaStore> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating store root {}", root.display()))?;
        Ok(MetaStore {
            inner: Arc::new(StoreInner {
                root,
                cache: Mutex::new(LruCache {
                    cap: cap.max(1),
                    tick: 0,
                    map: HashMap::new(),
                }),
                key_locks: Mutex::new(HashMap::new()),
                metrics: StoreMetrics::new(),
            }),
        })
    }

    pub fn root(&self) -> &Path {
        &self.inner.root
    }

    /// Absolute path of the artifact for `key` (whether or not it exists).
    pub fn path_for(&self, key: &MetaKey) -> PathBuf {
        self.inner.root.join(key.file_name())
    }

    pub fn stats(&self) -> StoreStats {
        self.inner.metrics.snapshot()
    }

    /// This store's metrics registry: the [`StoreStats`] counters plus
    /// `store.{hit,disk_load,build}_latency_ns` histograms. The serve
    /// layer renders it into STATS replies and the `--metrics-addr`
    /// exposition.
    pub fn registry(&self) -> &MetricsRegistry {
        &self.inner.metrics.registry
    }

    /// Decode the persisted artifact for `key`, bypassing the LRU.
    /// `Ok(None)` when absent; `Err` on a corrupted / truncated / stale
    /// artifact (callers that want self-healing use [`get_or_build`]).
    ///
    /// [`get_or_build`]: MetaStore::get_or_build
    pub fn load_uncached(&self, key: &MetaKey) -> Result<Option<Metadata>> {
        let path = self.path_for(key);
        if !path.exists() {
            return Ok(None);
        }
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let meta = binfmt::decode(&bytes)
            .with_context(|| format!("decoding {}", path.display()))?;
        Ok(Some(meta))
    }

    /// Encode and persist `meta` under `key` (atomic write: temp file +
    /// rename), and publish it to the shared cache.
    pub fn put(&self, key: &MetaKey, meta: Metadata) -> Result<Arc<Metadata>> {
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let meta = Arc::new(meta);
        let bytes = binfmt::encode(&meta);
        let path = self.path_for(key);
        // pid + process-wide sequence number: concurrent writers of the
        // same key (even via independent handles) never share a temp file
        let tmp = self.inner.root.join(format!(
            ".{}.tmp{}.{}",
            key.file_name(),
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        std::fs::write(&tmp, &bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing {}", path.display()))?;
        self.cache_insert(key, meta.clone());
        Ok(meta)
    }

    /// The store's main entry point: LRU hit → disk load → `build` (exactly
    /// once per key across all threads sharing this store). A persisted
    /// artifact that fails to decode — corruption, truncation, or a schema
    /// version this build doesn't speak — is rebuilt, not trusted.
    pub fn get_or_build(
        &self,
        key: &MetaKey,
        build: impl FnOnce() -> Result<Metadata>,
    ) -> Result<Arc<Metadata>> {
        // the causal-tracing hop between a serve dispatch span and the
        // kernel-build spans the builder emits: a slow resolve shows up
        // in the request's span tree as `store.resolve` with the build
        // underneath it
        let _span = crate::obs::Span::enter("store.resolve");
        let m = &self.inner.metrics;
        let fp = key.fingerprint();
        let t0 = crate::obs::enabled().then(Instant::now);
        if let Some(meta) = self.inner.cache.lock().unwrap().get(&fp) {
            m.hits.inc();
            if let Some(t0) = t0 {
                m.hit_latency.record_duration(t0.elapsed());
            }
            return Ok(meta);
        }
        m.misses.inc();
        let key_lock = {
            let mut locks = self.inner.key_locks.lock().unwrap();
            locks.entry(fp.clone()).or_default().clone()
        };
        let _guard = key_lock.lock().unwrap();
        // Another thread may have finished the same miss while we waited.
        if let Some(meta) = self.inner.cache.lock().unwrap().get(&fp) {
            return Ok(meta);
        }
        match self.load_uncached(key) {
            Ok(Some(meta)) => {
                m.disk_loads.inc();
                if let Some(t0) = t0 {
                    m.disk_load_latency.record_duration(t0.elapsed());
                }
                let meta = Arc::new(meta);
                self.cache_insert(key, meta.clone());
                return Ok(meta);
            }
            Ok(None) => {}
            Err(e) => {
                eprintln!(
                    "[store] stale or corrupted artifact {} ({e:#}); rebuilding",
                    self.path_for(key).display()
                );
            }
        }
        m.builds.inc();
        let meta = build().with_context(|| {
            format!("building metadata for {}", key.canonical())
        })?;
        if let Some(t0) = t0 {
            m.build_latency.record_duration(t0.elapsed());
        }
        self.put(key, meta)
    }

    /// Cache-aware single-key load: LRU hit → disk → `Ok(None)`. Unlike
    /// [`get_or_build`](MetaStore::get_or_build) there is no builder —
    /// continual-arrival followers must *observe* the published chain,
    /// never regenerate it.
    pub fn load(&self, key: &MetaKey) -> Result<Option<Arc<Metadata>>> {
        let m = &self.inner.metrics;
        let fp = key.fingerprint();
        if let Some(meta) = self.inner.cache.lock().unwrap().get(&fp) {
            m.hits.inc();
            return Ok(Some(meta));
        }
        match self.load_uncached(key)? {
            Some(meta) => {
                m.disk_loads.inc();
                let meta = Arc::new(meta);
                self.cache_insert(key, meta.clone());
                Ok(Some(meta))
            }
            None => Ok(None),
        }
    }

    // -----------------------------------------------------------------
    // Continual-arrival version chains
    //
    // Epoch-pinned artifacts are ordinary immutable store entries (the
    // epoch is part of the fingerprint). The only mutable state is one
    // small head record per base configuration —
    // `{dataset}_{base_fp}.head`, JSON `{"head": N, "epochs": [...]}` —
    // updated by atomic rename under the base key's build lock, so
    // trainers either see the old head or the new one, never a torn
    // record.
    // -----------------------------------------------------------------

    /// Path of the version-chain head record for `key`'s base
    /// configuration (the epoch component is ignored).
    pub fn head_path(&self, key: &MetaKey) -> PathBuf {
        let base = MetaKey { epoch: None, ..key.clone() };
        self.inner
            .root
            .join(format!("{}_{}.head", base.dataset, base.fingerprint()))
    }

    /// Persist `meta` as the epoch-`epoch` member of `key`'s version
    /// chain and advance the head record. The pinned artifact lands
    /// before the head moves, so a follower that reads the new head
    /// always finds its artifact.
    pub fn publish_epoch(
        &self,
        key: &MetaKey,
        epoch: u64,
        meta: Metadata,
    ) -> Result<Arc<Metadata>> {
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let meta = self.put(&key.at_epoch(epoch), meta)?;
        let head_lock = {
            let base = MetaKey { epoch: None, ..key.clone() };
            let mut locks = self.inner.key_locks.lock().unwrap();
            locks
                .entry(format!("{}.head", base.fingerprint()))
                .or_default()
                .clone()
        };
        let _guard = head_lock.lock().unwrap();
        let mut epochs = self.epoch_chain(key)?;
        if !epochs.contains(&epoch) {
            epochs.push(epoch);
            epochs.sort_unstable();
        }
        let head = *epochs.last().expect("chain contains the epoch just added");
        let doc = Json::obj(vec![
            ("head", Json::num(head as f64)),
            (
                "epochs",
                Json::arr(epochs.iter().map(|&e| Json::num(e as f64)).collect()),
            ),
        ]);
        let path = self.head_path(key);
        let tmp = self.inner.root.join(format!(
            ".head.tmp{}.{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        std::fs::write(&tmp, doc.to_string())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing {}", path.display()))?;
        Ok(meta)
    }

    /// Current head epoch of `key`'s version chain; `Ok(None)` when no
    /// epoch was ever published for this configuration.
    pub fn head_epoch(&self, key: &MetaKey) -> Result<Option<u64>> {
        Ok(self.read_head(key)?.map(|(head, _)| head))
    }

    /// All published epochs of `key`'s version chain, ascending (empty
    /// when none exist).
    pub fn epoch_chain(&self, key: &MetaKey) -> Result<Vec<u64>> {
        Ok(self.read_head(key)?.map(|(_, chain)| chain).unwrap_or_default())
    }

    fn read_head(&self, key: &MetaKey) -> Result<Option<(u64, Vec<u64>)>> {
        let path = self.head_path(key);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = Json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        let head = doc.get("head")?.as_usize()? as u64;
        let epochs = doc
            .get("epochs")?
            .as_arr()?
            .iter()
            .map(|e| Ok(e.as_usize()? as u64))
            .collect::<Result<Vec<u64>>>()?;
        Ok(Some((head, epochs)))
    }

    /// Resolve `key` under the pin/follow order the serve layer and
    /// trainers rely on: a pinned epoch loads exactly that artifact
    /// (deterministic forever); an unpinned key follows the chain head
    /// when one exists, falling back to the plain batch artifact.
    pub fn load_following(&self, key: &MetaKey) -> Result<Option<Arc<Metadata>>> {
        if key.epoch.is_some() {
            return self.load(key);
        }
        if let Some(head) = self.head_epoch(key)? {
            return self.load(&key.at_epoch(head));
        }
        self.load(key)
    }

    fn cache_insert(&self, key: &MetaKey, meta: Arc<Metadata>) {
        let evicted = self
            .inner
            .cache
            .lock()
            .unwrap()
            .insert(key.fingerprint(), meta);
        if evicted > 0 {
            self.inner.metrics.evictions.add(evicted);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::milo::ClassProbs;

    fn sample_meta(tag: usize) -> Metadata {
        Metadata {
            dataset: "trec6".into(),
            fraction: 0.1,
            sge_subsets: vec![vec![tag, tag + 2], vec![tag + 1, tag + 3]],
            wre_classes: vec![ClassProbs {
                indices: vec![0, 1, 2],
                probs: vec![0.5, 0.25, 0.25],
            }],
            fixed_dm: vec![0, 2],
            preprocess_secs: 0.5,
        }
    }

    fn tmp_store(name: &str) -> MetaStore {
        let dir = std::env::temp_dir()
            .join(format!("milo_store_test_{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        MetaStore::open(dir).unwrap()
    }

    fn key(seed: u64) -> MetaKey {
        MetaKey {
            dataset: "trec6".into(),
            encoder: "default".into(),
            sge_function: "graph_cut_l0.4".into(),
            wre_function: "disparity_min".into(),
            fraction: 0.1,
            n_subsets: 3,
            epsilon: 0.01,
            seed,
            metric: "cosine".into(),
            backend: "native".into(),
            pipeline: "kernel".into(),
            knn: None,
            epoch: None,
        }
    }

    #[test]
    fn fingerprint_is_stable_and_distinguishes_keys() {
        let a = key(1);
        assert_eq!(a.fingerprint(), key(1).fingerprint());
        assert_ne!(a.fingerprint(), key(2).fingerprint());
        let mut frac = key(1);
        frac.fraction = 0.3;
        assert_ne!(a.fingerprint(), frac.fingerprint());
        // sparse and dense kernels must not alias to one artifact
        let mut sparse = key(1);
        sparse.knn = Some(32);
        assert_ne!(a.fingerprint(), sparse.fingerprint());
        let mut wider = key(1);
        wider.knn = Some(64);
        assert_ne!(sparse.fingerprint(), wider.fingerprint());
        assert_eq!(a.fingerprint().len(), 16);
    }

    #[test]
    fn schedule_knobs_do_not_change_the_address() {
        // sim_tile / pipeline_depth are schedule-only: every variant
        // must alias to the same store artifact
        let base = crate::coordinator::PreprocessOptions::default();
        let a = MetaKey::from_options("synthetic", &base);
        for (tile, depth) in [(None, 1), (Some(32), 2), (Some(7), 4), (None, 8)] {
            let opts = crate::coordinator::PreprocessOptions {
                sim_tile: tile,
                pipeline_depth: depth,
                ..base.clone()
            };
            let b = MetaKey::from_options("synthetic", &opts);
            assert_eq!(a.fingerprint(), b.fingerprint(), "tile {tile:?} depth {depth}");
            assert_eq!(a.canonical(), b.canonical());
        }
    }

    #[test]
    fn get_or_build_builds_once_then_hits() {
        let store = tmp_store("once");
        let k = key(1);
        let mut builds = 0;
        let a = store
            .get_or_build(&k, || {
                builds += 1;
                Ok(sample_meta(10))
            })
            .unwrap();
        let b = store
            .get_or_build(&k, || {
                builds += 1;
                Ok(sample_meta(99))
            })
            .unwrap();
        assert_eq!(builds, 1);
        assert_eq!(a.sge_subsets, b.sge_subsets);
        let st = store.stats();
        assert_eq!(st.builds, 1);
        assert_eq!(st.hits, 1);
        assert_eq!(st.misses, 1);
        std::fs::remove_dir_all(store.root()).ok();
    }

    #[test]
    fn fresh_handle_loads_from_disk_without_building() {
        let store = tmp_store("disk");
        let k = key(2);
        store.put(&k, sample_meta(7)).unwrap();
        // a fresh store over the same root has a cold LRU
        let store2 = MetaStore::open(store.root()).unwrap();
        let meta = store2
            .get_or_build(&k, || panic!("must load from disk"))
            .unwrap();
        assert_eq!(meta.sge_subsets[0], vec![7, 9]);
        assert_eq!(store2.stats().disk_loads, 1);
        assert_eq!(store2.stats().builds, 0);
        std::fs::remove_dir_all(store.root()).ok();
    }

    #[test]
    fn corrupted_artifact_is_rebuilt() {
        let store = tmp_store("corrupt");
        let k = key(3);
        store.put(&k, sample_meta(1)).unwrap();
        std::fs::write(store.path_for(&k), b"definitely not a metadata blob").unwrap();
        let store2 = MetaStore::open(store.root()).unwrap();
        assert!(store2.load_uncached(&k).is_err(), "corrupt must be an error");
        let meta = store2.get_or_build(&k, || Ok(sample_meta(5))).unwrap();
        assert_eq!(meta.sge_subsets[0], vec![5, 7]);
        assert_eq!(store2.stats().builds, 1);
        // and the rebuilt artifact is readable again
        assert!(store2.load_uncached(&k).unwrap().is_some());
        std::fs::remove_dir_all(store.root()).ok();
    }

    #[test]
    fn concurrent_get_or_build_runs_builder_exactly_once() {
        let store = tmp_store("concurrent");
        let k = key(4);
        let builds = std::sync::atomic::AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let store = store.clone();
                let k = &k;
                let builds = &builds;
                scope.spawn(move || {
                    store
                        .get_or_build(k, || {
                            builds.fetch_add(1, Ordering::Relaxed);
                            // widen the race window
                            std::thread::sleep(std::time::Duration::from_millis(10));
                            Ok(sample_meta(3))
                        })
                        .unwrap();
                });
            }
        });
        assert_eq!(builds.load(Ordering::Relaxed), 1);
        assert_eq!(store.stats().builds, 1);
        std::fs::remove_dir_all(store.root()).ok();
    }

    #[test]
    fn shared_handles_share_cache_and_counters() {
        let dir = std::env::temp_dir()
            .join(format!("milo_store_test_shared_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let a = MetaStore::shared(&dir).unwrap();
        let b = MetaStore::shared(&dir).unwrap();
        a.get_or_build(&key(9), || Ok(sample_meta(2))).unwrap();
        // b is the same handle under the hood: a's build is b's cache hit
        let got = b
            .get_or_build(&key(9), || panic!("must hit the shared cache"))
            .unwrap();
        assert_eq!(got.sge_subsets[0], vec![2, 4]);
        assert_eq!(b.stats().builds, 1);
        assert_eq!(b.stats().hits, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn epoch_component_extends_but_never_rewrites_addresses() {
        let base = key(1);
        // unpinned keys fingerprint exactly as before the epoch existed
        assert!(!base.canonical().contains("epoch"));
        let e3 = base.at_epoch(3);
        assert!(e3.canonical().ends_with("|epoch=3"));
        assert_ne!(base.fingerprint(), e3.fingerprint());
        assert_ne!(e3.fingerprint(), base.at_epoch(4).fingerprint());
    }

    #[test]
    fn publish_epoch_chains_and_follow_resolves_pin_then_head_then_base() {
        let store = tmp_store("epochs");
        let k = key(6);
        // no chain, no base artifact: nothing to follow
        assert!(store.load_following(&k).unwrap().is_none());
        assert_eq!(store.head_epoch(&k).unwrap(), None);
        // base batch artifact only → follow falls back to it
        store.put(&k, sample_meta(1)).unwrap();
        assert_eq!(store.load_following(&k).unwrap().unwrap().sge_subsets[0], vec![1, 3]);
        // published epochs advance the head
        store.publish_epoch(&k, 1, sample_meta(10)).unwrap();
        store.publish_epoch(&k, 2, sample_meta(20)).unwrap();
        assert_eq!(store.head_epoch(&k).unwrap(), Some(2));
        assert_eq!(store.epoch_chain(&k).unwrap(), vec![1, 2]);
        let followed = store.load_following(&k).unwrap().unwrap();
        assert_eq!(followed.sge_subsets[0], vec![20, 22]);
        // a pinned key stays pinned regardless of the head
        let pinned = store.load_following(&k.at_epoch(1)).unwrap().unwrap();
        assert_eq!(pinned.sge_subsets[0], vec![10, 12]);
        // a fresh handle over the same root sees the same chain
        let store2 = MetaStore::open(store.root()).unwrap();
        assert_eq!(store2.head_epoch(&k).unwrap(), Some(2));
        assert_eq!(
            store2.load_following(&k).unwrap().unwrap().sge_subsets[0],
            vec![20, 22]
        );
        std::fs::remove_dir_all(store.root()).ok();
    }

    #[test]
    fn lru_evicts_oldest_but_disk_persists() {
        let store = MetaStore::with_capacity(
            std::env::temp_dir().join(format!("milo_store_test_lru_{}", std::process::id())),
            2,
        )
        .unwrap();
        for s in 0..3u64 {
            store.get_or_build(&key(s), || Ok(sample_meta(s as usize))).unwrap();
        }
        assert_eq!(store.stats().evictions, 1);
        // evicted entry comes back from disk, not the builder
        let meta = store
            .get_or_build(&key(0), || panic!("evicted entry must reload from disk"))
            .unwrap();
        assert_eq!(meta.sge_subsets[0], vec![0, 2]);
        std::fs::remove_dir_all(store.root()).ok();
    }
}
