//! Result tables: CSV + markdown writers used by `milo repro`, the benches
//! and EXPERIMENTS.md generation.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// A simple column-oriented results table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// Push with automatic Display formatting.
    pub fn push_display(&mut self, row: &[&dyn std::fmt::Display]) {
        self.push(row.iter().map(|v| v.to_string()).collect());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&csv_row(&self.headers));
        for row in &self.rows {
            out.push_str(&csv_row(row));
        }
        out
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}\n", self.title);
        }
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }

    /// Write both `{stem}.csv` and `{stem}.md` under `dir`.
    pub fn save(&self, dir: impl AsRef<Path>, stem: &str) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        std::fs::write(dir.join(format!("{stem}.md")), self.to_markdown())?;
        Ok(())
    }
}

fn csv_row(cells: &[String]) -> String {
    let escaped: Vec<String> = cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect();
    format!("{}\n", escaped.join(","))
}

/// Format a float with fixed precision (helper for table rows).
pub fn f(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

/// Format an accuracy as percent.
pub fn pct(v: f64) -> String {
    format!("{:.2}", 100.0 * v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_and_csv_shapes() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.push(vec!["1".into(), "x,y".into()]);
        t.push(vec!["2".into(), "q\"z".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a | b |"));
        assert!(md.lines().count() >= 5);
        let csv = t.to_csv();
        assert!(csv.starts_with("a,b\n"));
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push(vec!["only-one".into()]);
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join("milo_report_test");
        let mut t = Table::new("t", &["x"]);
        t.push(vec!["1".into()]);
        t.save(&dir, "demo").unwrap();
        assert!(dir.join("demo.csv").exists());
        assert!(dir.join("demo.md").exists());
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.9312), "93.12");
    }
}
