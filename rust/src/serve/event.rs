//! Readiness polling and listener setup for the event-loop server.
//!
//! The offline build vendors no async runtime and no `mio`/`libc` crates,
//! so this module speaks to the OS directly. Readiness comes in tiers:
//!
//! 1. **epoll** (Linux, default): a stateful [`Poller`] registers each
//!    socket once (`epoll_create1`/`epoll_ctl`, level-triggered) and
//!    `epoll_wait` returns only the ready sockets — per-tick cost scales
//!    with *activity*, not with the total connection count, which is what
//!    lets one loop hold thousands of idle trainers.
//! 2. **poll(2)** (Linux, fallback if `epoll_create1` fails): the
//!    [`Poller`] keeps the registration table itself and rebuilds the
//!    pollfd array per tick — O(total connections) per tick.
//! 3. **portable fallback** (non-Linux): a short sleep that reports every
//!    registered socket as ready, which the nonblocking reads/writes then
//!    resolve to `WouldBlock` — correct, just not cheap.
//!
//! The module also declares a raw `socket`/`setsockopt`/`bind`/`listen`
//! path so the listener carries `SO_REUSEADDR` — a restarted `milo serve`
//! must rebind its port while old connections sit in TIME_WAIT.

use std::net::{SocketAddr, TcpListener, TcpStream};

use anyhow::{Context, Result};

/// What the event loop wants to hear about a connection.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Interest {
    pub read: bool,
    pub write: bool,
}

/// What the poll reported for a connection.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct Ready {
    pub readable: bool,
    pub writable: bool,
    /// POLLERR/POLLHUP/POLLNVAL — the connection should be torn down.
    pub error: bool,
}

/// Opaque per-socket identity handed to [`wait`]. A real file descriptor
/// on unix; unused by the fallback path elsewhere.
#[cfg(unix)]
pub(crate) type SockId = i32;
#[cfg(not(unix))]
pub(crate) type SockId = usize;

#[cfg(unix)]
pub(crate) fn stream_id(s: &TcpStream) -> SockId {
    use std::os::unix::io::AsRawFd;
    s.as_raw_fd()
}

#[cfg(not(unix))]
pub(crate) fn stream_id(_s: &TcpStream) -> SockId {
    0
}

#[cfg(unix)]
pub(crate) fn listener_id(l: &TcpListener) -> SockId {
    use std::os::unix::io::AsRawFd;
    l.as_raw_fd()
}

#[cfg(not(unix))]
pub(crate) fn listener_id(_l: &TcpListener) -> SockId {
    0
}

// ---------------------------------------------------------------------------
// poll(2) — Linux
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::{c_int, c_ulong};

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    extern "C" {
        // int poll(struct pollfd *fds, nfds_t nfds, int timeout);
        pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    }
}

/// Block up to `timeout_ms` until a listener or a connection is ready.
/// Takes any number of listeners (the serve loop passes the protocol
/// listener plus an optional `--metrics-addr` one); returns per-listener
/// readiness in the same order as `listeners` and per-connection readiness
/// in the same order as `conns`. Never panics; on an unexpected poll
/// failure it degrades to "everything ready" after a short sleep, which
/// the nonblocking socket ops resolve safely.
#[cfg(target_os = "linux")]
pub(crate) fn wait(
    listeners: &[SockId],
    conns: &[(SockId, Interest)],
    timeout_ms: i32,
) -> (Vec<bool>, Vec<Ready>) {
    let mut fds: Vec<sys::PollFd> =
        Vec::with_capacity(listeners.len() + conns.len());
    for id in listeners {
        fds.push(sys::PollFd { fd: *id, events: sys::POLLIN, revents: 0 });
    }
    for (id, interest) in conns {
        let mut events = 0i16;
        if interest.read {
            events |= sys::POLLIN;
        }
        if interest.write {
            events |= sys::POLLOUT;
        }
        fds.push(sys::PollFd { fd: *id, events, revents: 0 });
    }
    loop {
        let rc = unsafe {
            sys::poll(fds.as_mut_ptr(), fds.len() as std::os::raw::c_ulong, timeout_ms)
        };
        if rc >= 0 {
            break;
        }
        let err = std::io::Error::last_os_error();
        if err.kind() == std::io::ErrorKind::Interrupted {
            continue; // EINTR: retry the poll
        }
        // Unexpected failure: degrade to the fallback semantics.
        std::thread::sleep(std::time::Duration::from_millis(2));
        return (vec![true; listeners.len()], fallback_ready(conns));
    }
    let listeners_ready = fds[..listeners.len()]
        .iter()
        .map(|f| f.revents & (sys::POLLIN | sys::POLLERR) != 0)
        .collect();
    let ready = fds[listeners.len()..]
        .iter()
        .map(|f| Ready {
            readable: f.revents & sys::POLLIN != 0,
            writable: f.revents & sys::POLLOUT != 0,
            error: f.revents & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0,
        })
        .collect();
    (listeners_ready, ready)
}

/// Portable fallback: sleep briefly, then report everything as ready. The
/// nonblocking socket ops turn spurious readiness into `WouldBlock`.
#[cfg(not(target_os = "linux"))]
pub(crate) fn wait(
    listeners: &[SockId],
    conns: &[(SockId, Interest)],
    timeout_ms: i32,
) -> (Vec<bool>, Vec<Ready>) {
    std::thread::sleep(std::time::Duration::from_millis(timeout_ms.clamp(1, 5) as u64));
    (vec![true; listeners.len()], fallback_ready(conns))
}

fn fallback_ready(conns: &[(SockId, Interest)]) -> Vec<Ready> {
    conns
        .iter()
        .map(|(_, interest)| Ready {
            readable: interest.read,
            writable: interest.write,
            error: false,
        })
        .collect()
}

// ---------------------------------------------------------------------------
// epoll — Linux
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod ep {
    use std::os::raw::c_int;

    pub const EPOLL_CLOEXEC: c_int = 0x80000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;

    /// `struct epoll_event`. The kernel ABI packs it on x86-64 (12 bytes);
    /// every other architecture uses natural alignment (16 bytes).
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(
            epfd: c_int,
            op: c_int,
            fd: c_int,
            event: *mut EpollEvent,
        ) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn close(fd: c_int) -> c_int;
    }

    pub fn interest_mask(interest: super::Interest) -> u32 {
        let mut m = 0u32;
        if interest.read {
            m |= EPOLLIN;
        }
        if interest.write {
            m |= EPOLLOUT;
        }
        m
    }
}

/// Stateful readiness source for the event loop. Sockets are registered
/// once ([`Poller::add`]), retargeted only when their interest actually
/// changes ([`Poller::modify`]), and deregistered before close
/// ([`Poller::remove`] — mandatory on the epoll tier, where the kernel
/// table would otherwise keep reporting a recycled fd).
///
/// [`Poller::wait`] fills `events` with `(socket, readiness)` pairs for
/// ready sockets only. On the epoll tier that is `O(ready)`; the poll and
/// portable tiers report in registration order and cost `O(registered)`.
pub(crate) struct Poller {
    #[cfg(target_os = "linux")]
    epfd: SockId,
    /// Registration table: authoritative on the poll/portable tiers,
    /// mirror (for sizing the event buffer) on the epoll tier.
    slots: Vec<(SockId, Interest)>,
    #[cfg(target_os = "linux")]
    evbuf: Vec<ep::EpollEvent>,
}

impl Poller {
    /// Open a poller on the best available tier.
    pub fn new() -> Poller {
        #[cfg(target_os = "linux")]
        {
            let epfd = unsafe { ep::epoll_create1(ep::EPOLL_CLOEXEC) };
            return Poller { epfd, slots: Vec::new(), evbuf: Vec::new() };
        }
        #[cfg(not(target_os = "linux"))]
        Poller { slots: Vec::new() }
    }

    /// Which readiness tier this poller runs on: `"epoll"`, `"poll"`, or
    /// `"fallback"`. Surfaced through STATS so tests (and operators) can
    /// confirm the epoll path is actually exercised.
    pub fn backend(&self) -> &'static str {
        #[cfg(target_os = "linux")]
        {
            if self.epfd >= 0 {
                return "epoll";
            }
            return "poll";
        }
        #[cfg(not(target_os = "linux"))]
        "fallback"
    }

    fn slot(&mut self, id: SockId) -> Option<&mut (SockId, Interest)> {
        self.slots.iter_mut().find(|(sid, _)| *sid == id)
    }

    /// Register a socket. No-op if already registered (use
    /// [`Poller::modify`] to change interest).
    pub fn add(&mut self, id: SockId, interest: Interest) {
        if self.slot(id).is_some() {
            return;
        }
        self.slots.push((id, interest));
        #[cfg(target_os = "linux")]
        if self.epfd >= 0 {
            let mut ev =
                ep::EpollEvent { events: ep::interest_mask(interest), data: id as u64 };
            let rc =
                unsafe { ep::epoll_ctl(self.epfd, ep::EPOLL_CTL_ADD, id, &mut ev) };
            debug_assert!(rc == 0, "epoll_ctl ADD: {}", std::io::Error::last_os_error());
        }
    }

    /// Change a registered socket's interest. Cheap to call only on
    /// change — the event loop caches the last interest per connection.
    pub fn modify(&mut self, id: SockId, interest: Interest) {
        match self.slot(id) {
            Some(slot) => slot.1 = interest,
            None => return,
        }
        #[cfg(target_os = "linux")]
        if self.epfd >= 0 {
            let mut ev =
                ep::EpollEvent { events: ep::interest_mask(interest), data: id as u64 };
            let rc =
                unsafe { ep::epoll_ctl(self.epfd, ep::EPOLL_CTL_MOD, id, &mut ev) };
            debug_assert!(rc == 0, "epoll_ctl MOD: {}", std::io::Error::last_os_error());
        }
    }

    /// Deregister a socket. Must happen before the fd is closed on the
    /// epoll tier (a closed-then-recycled fd would inherit stale events).
    pub fn remove(&mut self, id: SockId) {
        let before = self.slots.len();
        self.slots.retain(|(sid, _)| *sid != id);
        if self.slots.len() == before {
            return;
        }
        #[cfg(target_os = "linux")]
        if self.epfd >= 0 {
            let mut ev = ep::EpollEvent { events: 0, data: 0 };
            let rc =
                unsafe { ep::epoll_ctl(self.epfd, ep::EPOLL_CTL_DEL, id, &mut ev) };
            debug_assert!(rc == 0, "epoll_ctl DEL: {}", std::io::Error::last_os_error());
        }
    }

    /// Number of registered sockets.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Wait up to `timeout_ms`, appending `(socket, readiness)` for each
    /// ready socket to `events` (cleared first). Sockets with empty
    /// interest are reported only on error/hangup.
    pub fn wait(&mut self, timeout_ms: i32, events: &mut Vec<(SockId, Ready)>) {
        events.clear();
        #[cfg(target_os = "linux")]
        if self.epfd >= 0 {
            // one slot per registered socket: level-triggered epoll can
            // report at most that many, and the buffer tracks fleet size
            let want = self.slots.len().max(64);
            if self.evbuf.len() < want {
                self.evbuf.resize(want, ep::EpollEvent { events: 0, data: 0 });
            }
            let rc = loop {
                let rc = unsafe {
                    ep::epoll_wait(
                        self.epfd,
                        self.evbuf.as_mut_ptr(),
                        self.evbuf.len() as std::os::raw::c_int,
                        timeout_ms,
                    )
                };
                if rc >= 0 {
                    break rc;
                }
                let err = std::io::Error::last_os_error();
                if err.kind() != std::io::ErrorKind::Interrupted {
                    // unexpected failure: degrade to everything-ready
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    for (id, interest) in &self.slots {
                        events.push((
                            *id,
                            Ready {
                                readable: interest.read,
                                writable: interest.write,
                                error: false,
                            },
                        ));
                    }
                    return;
                }
            };
            for ev in &self.evbuf[..rc as usize] {
                let mask = ev.events;
                events.push((
                    ev.data as SockId,
                    Ready {
                        readable: mask & ep::EPOLLIN != 0,
                        writable: mask & ep::EPOLLOUT != 0,
                        error: mask & (ep::EPOLLERR | ep::EPOLLHUP) != 0,
                    },
                ));
            }
            return;
        }
        // poll(2) / portable tier: the free-function path over the table
        let (_, ready) = wait(&[], &self.slots, timeout_ms);
        for ((id, _), r) in self.slots.iter().zip(ready) {
            if r.readable || r.writable || r.error {
                events.push((*id, r));
            }
        }
    }
}

#[cfg(target_os = "linux")]
impl Drop for Poller {
    fn drop(&mut self) {
        if self.epfd >= 0 {
            unsafe { ep::close(self.epfd) };
        }
    }
}

// ---------------------------------------------------------------------------
// SO_REUSEADDR listener — Linux (raw socket FFI), std elsewhere
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sock {
    use std::os::raw::{c_int, c_void};

    pub const AF_INET: c_int = 2;
    pub const SOCK_STREAM: c_int = 1;
    pub const SOL_SOCKET: c_int = 1;
    pub const SO_REUSEADDR: c_int = 2;

    /// `struct sockaddr_in` (Linux): family, BE port, BE address, padding.
    #[repr(C)]
    pub struct SockaddrIn {
        pub family: u16,
        pub port: u16,
        pub addr: u32,
        pub zero: [u8; 8],
    }

    extern "C" {
        pub fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
        pub fn setsockopt(
            fd: c_int,
            level: c_int,
            name: c_int,
            val: *const c_void,
            len: u32,
        ) -> c_int;
        pub fn bind(fd: c_int, addr: *const SockaddrIn, len: u32) -> c_int;
        pub fn listen(fd: c_int, backlog: c_int) -> c_int;
        pub fn close(fd: c_int) -> c_int;
    }
}

/// Bind a TCP listener with `SO_REUSEADDR` so a restarted server can
/// rebind its address while prior connections drain through TIME_WAIT
/// (the reconnect tests kill and restart a server on one port). Falls
/// back to a plain [`TcpListener::bind`] for non-IPv4 addresses and on
/// non-Linux targets.
pub(crate) fn bind_reusable(addr: &str) -> Result<TcpListener> {
    let parsed: SocketAddr = addr
        .parse()
        .with_context(|| format!("invalid listen address {addr:?}"))?;
    #[cfg(target_os = "linux")]
    {
        if let SocketAddr::V4(v4) = parsed {
            if let Some(listener) = bind_reusable_v4(v4) {
                return Ok(listener);
            }
        }
    }
    TcpListener::bind(parsed).with_context(|| format!("binding {addr}"))
}

#[cfg(target_os = "linux")]
fn bind_reusable_v4(addr: std::net::SocketAddrV4) -> Option<TcpListener> {
    use std::os::unix::io::FromRawFd;
    unsafe {
        let fd = sock::socket(sock::AF_INET, sock::SOCK_STREAM, 0);
        if fd < 0 {
            return None;
        }
        let one: std::os::raw::c_int = 1;
        if sock::setsockopt(
            fd,
            sock::SOL_SOCKET,
            sock::SO_REUSEADDR,
            &one as *const _ as *const std::ffi::c_void,
            std::mem::size_of_val(&one) as u32,
        ) < 0
        {
            sock::close(fd);
            return None;
        }
        let sa = sock::SockaddrIn {
            family: sock::AF_INET as u16,
            port: addr.port().to_be(),
            addr: u32::from(*addr.ip()).to_be(),
            zero: [0; 8],
        };
        if sock::bind(fd, &sa, std::mem::size_of::<sock::SockaddrIn>() as u32) < 0 {
            sock::close(fd);
            return None;
        }
        if sock::listen(fd, 128) < 0 {
            sock::close(fd);
            return None;
        }
        Some(TcpListener::from_raw_fd(fd))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reusable_listener_binds_and_accepts() {
        let listener = bind_reusable("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (_conn, _) = listener.accept().unwrap();
        drop(client);
    }

    #[test]
    fn rebinding_after_close_succeeds() {
        // the property SO_REUSEADDR buys: close a listener that had live
        // connections, then immediately bind the same port again
        let listener = bind_reusable("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (conn, _) = listener.accept().unwrap();
        drop(conn); // server-side close first -> TIME_WAIT on the port
        drop(listener);
        drop(client);
        let again = bind_reusable(&addr.to_string()).unwrap();
        assert_eq!(again.local_addr().unwrap(), addr);
    }

    #[test]
    fn poller_reports_readiness_and_respects_remove() {
        use std::io::Write;

        let listener = bind_reusable("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();

        let mut poller = Poller::new();
        #[cfg(target_os = "linux")]
        assert_eq!(poller.backend(), "epoll", "Linux must land on the epoll tier");
        poller.add(listener_id(&listener), Interest { read: true, write: false });
        assert_eq!(poller.len(), 1);

        // a pending connection must wake the listener
        let mut client = TcpStream::connect(addr).unwrap();
        let mut events = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        let conn = loop {
            poller.wait(100, &mut events);
            if events.iter().any(|(id, r)| *id == listener_id(&listener) && r.readable)
            {
                if let Ok((conn, _)) = listener.accept() {
                    break conn;
                }
            }
            assert!(std::time::Instant::now() < deadline, "listener never woke");
        };
        conn.set_nonblocking(true).unwrap();

        // bytes in flight must raise readable on the accepted socket
        poller.add(stream_id(&conn), Interest { read: true, write: true });
        client.write_all(b"ping").unwrap();
        client.flush().unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        loop {
            poller.wait(100, &mut events);
            if events.iter().any(|(id, r)| *id == stream_id(&conn) && r.readable) {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "conn never readable");
        }

        // after remove, the socket must not be reported again
        poller.remove(stream_id(&conn));
        assert_eq!(poller.len(), 1);
        client.write_all(b"more").unwrap();
        poller.wait(50, &mut events);
        assert!(
            events.iter().all(|(id, _)| *id != stream_id(&conn)),
            "removed socket still reported"
        );
        drop(client);
    }

    #[test]
    fn wait_reports_listener_readiness() {
        let listener = bind_reusable("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        // nothing pending: poll times out quickly and reports not-ready
        // (fallback builds report ready; both are valid inputs to the loop)
        let (ready, conns) = wait(&[listener_id(&listener)], &[], 10);
        assert_eq!(ready.len(), 1);
        assert!(conns.is_empty());
        // a pending connection must wake the listener within the timeout
        let _client = TcpStream::connect(addr).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        loop {
            let (ready, _) = wait(&[listener_id(&listener)], &[], 100);
            if ready[0] && listener.accept().is_ok() {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "listener never woke");
        }
    }
}
