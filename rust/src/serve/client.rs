//! Blocking Rust client for the `milo serve` protocol, plus a
//! [`Strategy`] adapter so a trainer can draw its subsets live from a
//! served metadata instance instead of local files.
//!
//! The client speaks both wire formats (see [`crate::serve`]): JSON lines
//! (the default) and the length-prefixed binary frame mode negotiated at
//! `HELLO` ([`ClientOptions::wire`]). It also carries the fleet-scale
//! resilience the ROADMAP asked for:
//!
//! * **Reconnect/retry** ([`RetryPolicy`]): when the transport fails
//!   mid-stream, the client redials and re-`HELLO`s with the same client
//!   id plus a `resume` hint (`{sge, wre_ks}`), which the server uses to
//!   **fast-forward** its deterministic streams past every subset this
//!   client already consumed — one request, no subset payloads
//!   re-transferred (the streams are pure functions of `(seed, entry,
//!   client id)` — see the serve module docs). The failed request is then
//!   re-issued, so the consumer observes the exact stream an
//!   uninterrupted connection would have produced, or a clear "giving
//!   up" error once the retry budget is exhausted. The replay journal
//!   costs one `u64` plus one `usize` per WRE draw.
//! * **Graceful close**: dropping a [`ServeClient`] sends `GOODBYE` so
//!   the server reclaims the connection slot immediately instead of
//!   waiting to notice the FIN.
//! * **Follow mode** ([`ServeClient::subscribe`] /
//!   [`ServeClient::poll_push`] / [`ServeClient::follow`]): on the frame
//!   wire, a subscribed client receives the server's `EPOCH_ADVANCE` +
//!   `SUBSET_DELTA` push bursts (see the [`crate::serve`] *Epoch
//!   versioning* docs), reassembled into [`EpochUpdate`]s and delivered
//!   at most once per epoch — push frames that arrive interleaved with
//!   request/response traffic are stashed, never confused for a response.
//!   Across a reconnect the client re-subscribes and, if the server's
//!   epoch moved while it was away, synthesizes the missed advance from
//!   `GET_META` (collapsing intermediate epochs to the head — a follower
//!   observes each delivered epoch exactly once, in increasing order).
//! * **Connection pooling** ([`ConnectionPool`] /
//!   [`ServeClient::connect_pooled`]): a fleet of logical sessions shares
//!   framed TCP connections instead of one socket per trainer. Each
//!   pooled session gets its own stream id (the frame header's stream
//!   bits — see [`crate::serve`] *Stream multiplexing*) on a shared
//!   connection, with its own `HELLO`-negotiated entry binding, its own
//!   deterministic streams, and its own per-stream subscription; up to
//!   [`frame::MAX_STREAMS`]` - 1` sessions ride one socket before the
//!   pool dials another. Request/response exchanges serialize on the
//!   shared connection (one roundtrip holds it at a time), pushes for
//!   sibling streams are stashed for their owners, and a transport error
//!   poisons the shared socket so every session on it reconnects onto a
//!   fresh one — replaying its deterministic streams exactly as a
//!   dedicated connection would.
//! * **Causal tracing**: when the server acks the trace capability at
//!   `HELLO` (`"trace":true` — see the [`crate::serve`] *Causal tracing*
//!   docs), every request is stamped with a fresh trace id and the
//!   client's request-span id, so the server's dispatch — and its
//!   downstream store/kernel spans — join the client's trace tree; the
//!   server echoes the id on control replies
//!   ([`ServeClient::last_trace`]). Older servers never see the fields.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::frame::{self, Frame};
use super::WireMode;
use crate::coordinator::{metadata_from_json, Metadata};
use crate::selection::{SelectCtx, Strategy};
use crate::util::json::Json;

/// Reconnect budget for a [`ServeClient`]: after a transport failure the
/// client redials up to `max_reconnects` times with linear backoff
/// (`backoff_ms`, `2·backoff_ms`, …) before giving up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    pub max_reconnects: u32,
    pub backoff_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_reconnects: 3, backoff_ms: 100 }
    }
}

impl RetryPolicy {
    /// Fail fast on the first transport error (the pre-retry behaviour).
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_reconnects: 0, backoff_ms: 0 }
    }
}

/// Connection options for [`ServeClient::connect_with`].
#[derive(Clone, Debug, Default)]
pub struct ClientOptions {
    /// Wire format to negotiate at `HELLO` (default: JSON lines).
    pub wire: WireMode,
    /// Served entry to bind to on a multi-dataset server (default: the
    /// server's first entry).
    pub dataset: Option<String>,
    /// Served fraction to bind to (with or without `dataset`).
    pub fraction: Option<f64>,
    pub retry: RetryPolicy,
}

/// What the server announced at `HELLO` for the bound entry.
struct HelloInfo {
    dataset: String,
    fraction: f64,
    seed: u64,
    /// The entry's continual-arrival epoch (0 = batch / pre-epoch server).
    epoch: u64,
    /// Whether the server acked the trace capability (`"trace":true` in
    /// its `HELLO` reply) — only then does the client stamp requests with
    /// `trace`/`span` fields. Absent on older servers.
    trace: bool,
}

/// One complete epoch advance, reassembled from a push burst (or
/// synthesized from `GET_META` after a reconnect that skipped epochs):
/// the new epoch's full subset universe.
#[derive(Clone, Debug, PartialEq)]
pub struct EpochUpdate {
    pub epoch: u64,
    /// The epoch's SGE subsets, in cycle order.
    pub sge_subsets: Vec<Vec<usize>>,
    /// The epoch's fixed disparity-min subset.
    pub fixed_dm: Vec<usize>,
}

/// One live transport: buffered reader + writer halves of a TCP stream,
/// byte counters, and the active wire format.
struct Wire {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    framed: bool,
    /// Push frames that arrived interleaved with request/response traffic
    /// — stashed with their stream id by [`Wire::roundtrip_on`], picked
    /// up by the owning session's reassembler.
    pushed: Vec<(u8, Frame)>,
    tx: u64,
    rx: u64,
}

impl Wire {
    fn send_line(&mut self, text: &str) -> Result<()> {
        let mut line = text.to_string();
        line.push('\n');
        self.writer.write_all(line.as_bytes()).context("sending request")?;
        self.tx += line.len() as u64;
        Ok(())
    }

    fn recv_line(&mut self) -> Result<String> {
        let mut response = String::new();
        let n = self.reader.read_line(&mut response).context("reading response")?;
        if n == 0 {
            bail!("server closed the connection");
        }
        self.rx += n as u64;
        Ok(response)
    }

    fn send_frame(&mut self, f: &Frame) -> Result<()> {
        self.send_frame_on(0, f)
    }

    fn send_frame_on(&mut self, stream: u8, f: &Frame) -> Result<()> {
        let bytes = f.encode_on(stream);
        self.writer.write_all(&bytes).context("sending frame")?;
        self.tx += bytes.len() as u64;
        Ok(())
    }

    fn recv_frame(&mut self) -> Result<(u8, Frame)> {
        let mut header = [0u8; frame::HEADER_LEN];
        self.reader.read_exact(&mut header).context("reading frame header")?;
        // shared header validation (length cap, kind range) — the one
        // definition in `frame` — before allocating for the payload
        let (len, kind, stream) = frame::parse_header(&header)?;
        let mut payload = vec![0u8; len];
        self.reader.read_exact(&mut payload).context("reading frame payload")?;
        self.rx += (frame::HEADER_LEN + len) as u64;
        Ok((stream, frame::parse_payload(kind, &payload)?))
    }

    /// One request/response exchange on `stream` in the active wire
    /// format. Errors here are transport-level (lost connection, corrupt
    /// framing, a response on the wrong stream) — a server-side
    /// `"ok":false` / `ERROR` frame comes back as `Ok` and is surfaced by
    /// the response interpreters, so it is never retried.
    /// Server-initiated push frames that land between a request and its
    /// response are stashed with their stream id, never returned as the
    /// response. Exchanges on a shared connection serialize (the caller
    /// holds the connection for the whole roundtrip), so the response to
    /// this request is the next non-push frame — and it must carry this
    /// stream's id.
    fn roundtrip_on(&mut self, stream: u8, request: &Json) -> Result<Frame> {
        if self.framed {
            self.send_frame_on(stream, &Frame::Json(request.to_string()))?;
            loop {
                let (s, f) = self.recv_frame()?;
                if is_push(&f) {
                    self.pushed.push((s, f));
                    continue;
                }
                ensure!(
                    s == stream,
                    "response arrived on stream {s} while waiting on stream \
                     {stream} — the multiplexed connection is desynchronized",
                );
                return Ok(f);
            }
        } else {
            debug_assert_eq!(stream, 0, "the JSON wire is single-stream");
            self.send_line(&request.to_string())?;
            let line = self.recv_line()?;
            Ok(Frame::Json(line.trim_end().to_string()))
        }
    }

    /// Wait up to `timeout` for the next frame without consuming any
    /// bytes on timeout: the readiness probe is `fill_buf` (which only
    /// peeks), so a timeout mid-wait can never desynchronize the frame
    /// stream; once bytes are available the full frame is read blocking
    /// (the server writes frames contiguously).
    fn poll_frame(&mut self, timeout: Duration) -> Result<Option<(u8, Frame)>> {
        self.writer
            .set_read_timeout(Some(timeout.max(Duration::from_millis(1))))
            .context("arming the poll timeout")?;
        let ready = match self.reader.fill_buf() {
            Ok(buf) if buf.is_empty() => {
                let _ = self.writer.set_read_timeout(None);
                bail!("server closed the connection");
            }
            Ok(_) => true,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                false
            }
            Err(e) => {
                let _ = self.writer.set_read_timeout(None);
                return Err(e).context("polling for push frames");
            }
        };
        self.writer.set_read_timeout(None).context("disarming the poll timeout")?;
        if !ready {
            return Ok(None);
        }
        self.recv_frame().map(Some)
    }

    /// [`Wire::poll_frame`] filtered to `stream`: a push for a sibling
    /// stream is stashed for its owner (and reported as `None` — the
    /// caller's deadline loop keeps polling); a non-push frame for any
    /// other stream means the connection is desynchronized.
    fn poll_frame_on(&mut self, stream: u8, timeout: Duration) -> Result<Option<Frame>> {
        match self.poll_frame(timeout)? {
            None => Ok(None),
            Some((s, f)) if s == stream => Ok(Some(f)),
            Some((s, f)) if is_push(&f) => {
                self.pushed.push((s, f));
                Ok(None)
            }
            Some((s, f)) => bail!(
                "unsolicited {} frame on stream {s} while polling stream {stream} \
                 — the multiplexed connection is desynchronized",
                f.kind_name(),
            ),
        }
    }
}

fn is_push(f: &Frame) -> bool {
    matches!(f, Frame::EpochAdvance { .. } | Frame::SubsetDelta { .. })
}

/// How long a pooled session's `poll_push` holds the shared connection
/// per wait slice before releasing it to sibling roundtrips.
const POOL_POLL_SLICE_MS: u64 = 20;

/// Assemble a `HELLO` request. `resume` is the reconnect fast-forward
/// hint: `(SGE draws consumed, WRE ks consumed)` — the server skips the
/// deterministic streams ahead in this one request, with no subset
/// payload re-transfer. `negotiate_wire` includes the `wire` field — only
/// the handshake on a fresh connection (stream 0) renegotiates the wire;
/// a pooled stream's `HELLO` inherits the connection's framing.
fn hello_request(
    client_id: &str,
    opts: &ClientOptions,
    resume: Option<(u64, &[usize])>,
    negotiate_wire: bool,
) -> Json {
    let mut fields = vec![
        ("cmd", Json::str("HELLO")),
        ("client", Json::str(client_id)),
    ];
    if negotiate_wire {
        fields.push(("wire", Json::str(opts.wire.name())));
    }
    if let Some(ds) = &opts.dataset {
        fields.push(("dataset", Json::str(ds.clone())));
    }
    if let Some(f) = opts.fraction {
        fields.push(("fraction", Json::num(f)));
    }
    if let Some((sge, ks)) = resume {
        fields.push((
            "resume",
            Json::obj(vec![
                ("sge", Json::num(sge as f64)),
                (
                    "wre_ks",
                    Json::arr(ks.iter().map(|&k| Json::num(k as f64)).collect()),
                ),
            ]),
        ));
    }
    Json::obj(fields)
}

/// Extract what the server announced from an `"ok":true` HELLO response.
fn parse_hello(v: &Json) -> Result<HelloInfo> {
    // prefer the exact hex seed; the numeric field rounds above 2^53
    let seed = match v.opt("seed_hex").and_then(|s| s.as_str().ok()) {
        Some(hex) => u64::from_str_radix(hex, 16)
            .with_context(|| format!("bad seed_hex {hex:?} in HELLO response"))?,
        None => v.get("seed")?.as_f64()? as u64,
    };
    Ok(HelloInfo {
        dataset: v.get("dataset")?.as_str()?.to_string(),
        fraction: v.get("fraction")?.as_f64()?,
        seed,
        // absent on pre-epoch servers: those serve the batch state (0)
        epoch: v.opt("epoch").and_then(|e| e.as_f64().ok()).unwrap_or(0.0) as u64,
        trace: v.opt("trace").and_then(|t| t.as_bool().ok()).unwrap_or(false),
    })
}

/// Dial + `HELLO` handshake (always JSON-line; the connection switches to
/// frames after a confirmed `"wire":"frame"` response).
fn dial(
    addr: &str,
    client_id: &str,
    opts: &ClientOptions,
    resume: Option<(u64, &[usize])>,
) -> Result<(Wire, HelloInfo)> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to milo serve at {addr}"))?;
    let _ = stream.set_nodelay(true);
    let mut wire = Wire {
        reader: BufReader::new(stream.try_clone()?),
        writer: stream,
        framed: false,
        pushed: Vec::new(),
        tx: 0,
        rx: 0,
    };
    wire.send_line(&hello_request(client_id, opts, resume, true).to_string())?;
    let line = wire.recv_line()?;
    let v = ok_json(&Frame::Json(line.clone()))
        .with_context(|| format!("HELLO to milo serve at {addr}"))?;
    let info = parse_hello(&v)?;
    if opts.wire == WireMode::Frame {
        let confirmed = v.opt("wire").and_then(|w| w.as_str().ok()) == Some("frame");
        ensure!(confirmed, "server at {addr} did not confirm frame mode");
        wire.framed = true;
    }
    Ok((wire, info))
}

// ---------------------------------------------------------------------------
// Connection pooling
// ---------------------------------------------------------------------------

/// A framed connection shared by several pooled sessions. `wire` goes
/// `None` when a transport error poisons the socket — every session
/// multiplexed on it then reconnects through the pool (a desynchronized
/// shared connection cannot be trusted for anyone).
struct PooledWire {
    wire: Option<Wire>,
}

type SharedConn = Arc<Mutex<PooledWire>>;

/// One pooled connection and the stream ids currently allocated on it
/// (bit `s` set = stream `s` leased; bit 0 is the connection's control
/// stream, never leased).
struct PoolSlot {
    conn: SharedConn,
    streams: u32,
}

/// A shared pool of multiplexed framed connections to one `milo serve`
/// address. [`ServeClient::connect_pooled`] leases a stream id on an
/// existing connection with capacity, dialing a new socket only when
/// every pooled connection already carries [`frame::MAX_STREAMS`]` - 1`
/// sessions. Clone the pool handle freely — clones share the same
/// connections.
#[derive(Clone)]
pub struct ConnectionPool {
    addr: String,
    inner: Arc<Mutex<Vec<PoolSlot>>>,
}

impl ConnectionPool {
    /// A pool for `addr`. No connection is dialed until the first lease.
    pub fn new(addr: &str) -> ConnectionPool {
        ConnectionPool { addr: addr.to_string(), inner: Arc::new(Mutex::new(Vec::new())) }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Live pooled connections (diagnostics: N sessions over
    /// `connections()` sockets is the multiplexing win).
    pub fn connections(&self) -> usize {
        self.inner
            .lock()
            .expect("pool lock")
            .iter()
            .filter(|s| s.conn.lock().expect("pooled conn lock").wire.is_some())
            .count()
    }

    /// Lease `(connection, stream id)` — reusing a live connection with a
    /// free stream id, else dialing a fresh one (its stream-0 handshake
    /// negotiates the frame wire; stream 0 stays the pool's control
    /// session and is never leased).
    fn checkout(&self) -> Result<(SharedConn, u8)> {
        let mut slots = self.inner.lock().expect("pool lock");
        // drop fully-idle poisoned slots; poisoned slots with outstanding
        // leases stay until their sessions check back in (checkin on a
        // pruned slot is a no-op)
        slots.retain(|s| {
            s.streams != 0 || s.conn.lock().expect("pooled conn lock").wire.is_some()
        });
        for slot in slots.iter_mut() {
            if slot.conn.lock().expect("pooled conn lock").wire.is_none() {
                continue;
            }
            if let Some(s) =
                (1..frame::MAX_STREAMS as u32).find(|s| slot.streams & (1 << s) == 0)
            {
                slot.streams |= 1 << s;
                return Ok((slot.conn.clone(), s as u8));
            }
        }
        let opts = ClientOptions { wire: WireMode::Frame, ..ClientOptions::default() };
        let (wire, _info) = dial(&self.addr, "pool", &opts, None)?;
        let conn: SharedConn = Arc::new(Mutex::new(PooledWire { wire: Some(wire) }));
        slots.push(PoolSlot { conn: conn.clone(), streams: 1 << 1 });
        Ok((conn, 1))
    }

    /// Return a leased stream id. The connection stays pooled for reuse.
    fn checkin(&self, conn: &SharedConn, stream: u8) {
        let mut slots = self.inner.lock().expect("pool lock");
        if let Some(slot) = slots.iter_mut().find(|s| Arc::ptr_eq(&s.conn, conn)) {
            slot.streams &= !(1u32 << stream);
        }
    }
}

/// `HELLO` on a pooled stream: open (or re-bind) the stream's session on
/// the shared framed connection. A transport error poisons the shared
/// socket.
fn open_session(
    conn: &SharedConn,
    stream: u8,
    addr: &str,
    client_id: &str,
    opts: &ClientOptions,
    resume: Option<(u64, &[usize])>,
) -> Result<HelloInfo> {
    let mut pw = conn.lock().expect("pooled conn lock");
    let wire = pw
        .wire
        .as_mut()
        .ok_or_else(|| anyhow!("pooled connection to milo serve at {addr} lost"))?;
    let req = hello_request(client_id, opts, resume, false);
    match wire.roundtrip_on(stream, &req) {
        Ok(f) => {
            let v = ok_json(&f)
                .with_context(|| format!("HELLO on stream {stream} to {addr}"))?;
            parse_hello(&v)
        }
        Err(e) => {
            pw.wire = None;
            Err(e)
        }
    }
}

/// How a [`ServeClient`] reaches the server: a dedicated socket (all
/// traffic on stream 0) or a leased stream on a pool-shared socket.
enum Transport {
    Direct(Option<Wire>),
    Pooled { pool: ConnectionPool, conn: SharedConn, stream: u8 },
}

/// A blocking session against a [`SubsetServer`](super::SubsetServer) —
/// over its own socket ([`ServeClient::connect`]) or a stream leased from
/// a shared [`ConnectionPool`] ([`ServeClient::connect_pooled`]). One
/// request/response round-trip per call; reconnecting (same `client_id`)
/// replays the same deterministic stream, and the built-in
/// [`RetryPolicy`] does exactly that transparently on transport failure.
pub struct ServeClient {
    addr: String,
    client_id: String,
    opts: ClientOptions,
    transport: Transport,
    server_dataset: String,
    server_fraction: f64,
    server_seed: u64,
    /// The server epoch this session's streams belong to (from `HELLO` /
    /// the last delivered [`EpochUpdate`]).
    server_epoch: u64,
    /// Whether this client asked for push frames (survives reconnects:
    /// the retry path re-`SUBSCRIBE`s).
    subscribed: bool,
    /// Highest epoch delivered to the consumer — the at-most-once gate.
    last_epoch: u64,
    /// Reassembled, not-yet-delivered epoch updates, oldest first.
    pending_pushes: VecDeque<EpochUpdate>,
    /// The burst currently being reassembled (`EPOCH_ADVANCE` seen, some
    /// deltas still in flight).
    partial: Option<PartialUpdate>,
    /// Replay journal: successful `NEXT_SUBSET` count …
    sge_drawn: u64,
    /// … and the `k` of every successful `SAMPLE_WRE`, in order.
    wre_ks: Vec<usize>,
    /// Byte counters folded in from torn-down connections.
    bytes_tx: u64,
    bytes_rx: u64,
    goodbye_sent: bool,
    /// Server acked the trace capability at `HELLO` — requests are
    /// stamped with `trace`/`span` fields (see [`crate::serve`] docs).
    server_trace: bool,
    /// `(trace id, server echoed it)` for the most recent stamped
    /// request — see [`ServeClient::last_trace`].
    last_trace: Option<(u64, bool)>,
}

/// An [`EpochUpdate`] mid-reassembly: the announced delta count and the
/// deltas received so far.
struct PartialUpdate {
    epoch: u64,
    n_subsets: usize,
    sge_subsets: Vec<Vec<usize>>,
    fixed_dm: Option<Vec<usize>>,
}

impl ServeClient {
    /// Connect with default options (JSON lines, default entry, default
    /// retry policy), binding the session to `client_id` (which keys the
    /// server-side deterministic streams — see the module docs of
    /// [`crate::serve`]).
    pub fn connect(addr: &str, client_id: &str) -> Result<ServeClient> {
        ServeClient::connect_with(addr, client_id, ClientOptions::default())
    }

    /// Connect with explicit wire format, entry routing, and retry policy.
    pub fn connect_with(
        addr: &str,
        client_id: &str,
        opts: ClientOptions,
    ) -> Result<ServeClient> {
        let (wire, info) = dial(addr, client_id, &opts, None)?;
        Ok(ServeClient::assemble(
            addr,
            client_id,
            opts,
            Transport::Direct(Some(wire)),
            info,
        ))
    }

    /// Open a logical session as a multiplexed stream on a pool-shared
    /// connection: same protocol surface as a dedicated connection (entry
    /// routing, deterministic streams, per-stream subscription + push
    /// delivery), but a fleet of sessions shares sockets. Always the
    /// frame wire (the stream id lives in the frame header).
    pub fn connect_pooled(
        pool: &ConnectionPool,
        client_id: &str,
        opts: ClientOptions,
    ) -> Result<ServeClient> {
        ensure!(
            opts.wire == WireMode::Frame,
            "pooled sessions are multiplexed over the frame wire — connect \
             with ClientOptions {{ wire: WireMode::Frame, .. }}",
        );
        let (conn, stream) = pool.checkout()?;
        match open_session(&conn, stream, pool.addr(), client_id, &opts, None) {
            Ok(info) => Ok(ServeClient::assemble(
                pool.addr(),
                client_id,
                opts,
                Transport::Pooled { pool: pool.clone(), conn, stream },
                info,
            )),
            Err(e) => {
                pool.checkin(&conn, stream);
                Err(e)
            }
        }
    }

    fn assemble(
        addr: &str,
        client_id: &str,
        opts: ClientOptions,
        transport: Transport,
        info: HelloInfo,
    ) -> ServeClient {
        ServeClient {
            addr: addr.to_string(),
            client_id: client_id.to_string(),
            opts,
            transport,
            server_dataset: info.dataset,
            server_fraction: info.fraction,
            server_seed: info.seed,
            server_epoch: info.epoch,
            subscribed: false,
            last_epoch: info.epoch,
            pending_pushes: VecDeque::new(),
            partial: None,
            sge_drawn: 0,
            wre_ks: Vec::new(),
            bytes_tx: 0,
            bytes_rx: 0,
            goodbye_sent: false,
            server_trace: info.trace,
            last_trace: None,
        }
    }

    pub fn client_id(&self) -> &str {
        &self.client_id
    }

    /// Dataset of the entry the server bound this session to at HELLO.
    pub fn server_dataset(&self) -> &str {
        &self.server_dataset
    }

    /// Fraction of the bound entry.
    pub fn server_fraction(&self) -> f64 {
        self.server_fraction
    }

    /// Stream seed the server announced in HELLO — compare against your
    /// own configuration before trusting the served selections.
    pub fn server_seed(&self) -> u64 {
        self.server_seed
    }

    /// Negotiated wire format.
    pub fn wire_mode(&self) -> WireMode {
        self.opts.wire
    }

    /// Whether the server acked the trace capability at `HELLO`.
    pub fn trace_capable(&self) -> bool {
        self.server_trace
    }

    /// The most recent stamped request's `(trace id, server echoed it)` —
    /// `None` until the first request after a trace-capable `HELLO`. The
    /// id keys this request's span tree in the server's `MILO_TRACE` sink
    /// / flight recorder ([`crate::obs::id_hex`] is its wire form).
    pub fn last_trace(&self) -> Option<(u64, bool)> {
        self.last_trace
    }

    /// Bytes written to the server so far (all connections). On a pooled
    /// session the live term counts the whole shared connection — every
    /// stream's traffic, not just this session's.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_tx
            + match &self.transport {
                Transport::Direct(w) => w.as_ref().map_or(0, |w| w.tx),
                Transport::Pooled { conn, .. } => conn
                    .lock()
                    .expect("pooled conn lock")
                    .wire
                    .as_ref()
                    .map_or(0, |w| w.tx),
            }
    }

    /// Bytes read from the server so far (all connections; see
    /// [`ServeClient::bytes_sent`] for pooled-session scope).
    pub fn bytes_received(&self) -> u64 {
        self.bytes_rx
            + match &self.transport {
                Transport::Direct(w) => w.as_ref().map_or(0, |w| w.rx),
                Transport::Pooled { conn, .. } => conn
                    .lock()
                    .expect("pooled conn lock")
                    .wire
                    .as_ref()
                    .map_or(0, |w| w.rx),
            }
    }

    /// Whether the transport currently has a live socket.
    fn transport_live(&self) -> bool {
        match &self.transport {
            Transport::Direct(w) => w.is_some(),
            Transport::Pooled { conn, .. } => {
                conn.lock().expect("pooled conn lock").wire.is_some()
            }
        }
    }

    /// Tear down the live socket. For a pooled session this poisons the
    /// *shared* connection — a transport error on a multiplexed socket
    /// desynchronizes every stream on it, so all sibling sessions
    /// reconnect too (exactly what a dropped dedicated socket would mean
    /// for each of them).
    fn drop_conn(&mut self) {
        let taken = match &mut self.transport {
            Transport::Direct(w) => w.take(),
            Transport::Pooled { conn, .. } => {
                conn.lock().expect("pooled conn lock").wire.take()
            }
        };
        if let Some(wire) = taken {
            self.bytes_tx += wire.tx;
            self.bytes_rx += wire.rx;
        }
    }

    /// One roundtrip on the live transport — no retry, no reconnect (the
    /// building block `call` and the reconnect path share). A transport
    /// error on a shared connection poisons it for every stream.
    fn roundtrip_live(&mut self, request: &Json) -> Result<Frame> {
        match &mut self.transport {
            Transport::Direct(Some(wire)) => wire.roundtrip_on(0, request),
            Transport::Direct(None) => {
                bail!("connection to milo serve at {} lost", self.addr)
            }
            Transport::Pooled { conn, stream, .. } => {
                let mut pw = conn.lock().expect("pooled conn lock");
                let wire = pw.wire.as_mut().ok_or_else(|| {
                    anyhow!("pooled connection to milo serve at {} lost", self.addr)
                })?;
                let r = wire.roundtrip_on(*stream, request);
                if r.is_err() {
                    pw.wire = None;
                }
                r
            }
        }
    }

    /// Re-establish the transport and re-`HELLO` with `resume`. Direct:
    /// redial the socket. Pooled: lease a fresh `(connection, stream)`
    /// from the pool (the old lease died with its poisoned socket) and
    /// open the session there.
    fn redial(&mut self, resume: Option<(u64, &[usize])>) -> Result<HelloInfo> {
        match &mut self.transport {
            Transport::Direct(slot) => {
                let (wire, info) = dial(&self.addr, &self.client_id, &self.opts, resume)?;
                *slot = Some(wire);
                Ok(info)
            }
            Transport::Pooled { pool, conn, stream } => {
                if conn.lock().expect("pooled conn lock").wire.is_some() {
                    // the shared socket is fine (e.g. the epoch-change
                    // re-HELLO): re-bind this stream's session in place —
                    // never check the id in while live, or a sibling
                    // could lease it before we re-acquire one
                    return open_session(
                        conn,
                        *stream,
                        &self.addr,
                        &self.client_id,
                        &self.opts,
                        resume,
                    );
                }
                let pool = pool.clone();
                // the old lease died with its poisoned socket; ids on a
                // poisoned connection are never re-leased, so this
                // checkin cannot collide
                pool.checkin(conn, *stream);
                let (new_conn, new_stream) = pool.checkout()?;
                match open_session(
                    &new_conn,
                    new_stream,
                    &self.addr,
                    &self.client_id,
                    &self.opts,
                    resume,
                ) {
                    Ok(info) => {
                        *conn = new_conn;
                        *stream = new_stream;
                        Ok(info)
                    }
                    Err(e) => {
                        pool.checkin(&new_conn, new_stream);
                        Err(e)
                    }
                }
            }
        }
    }

    /// Redial, re-HELLO with the resume hint (the server fast-forwards its
    /// deterministic streams past everything this client already consumed
    /// in that one request — no subset payloads are re-transferred), and
    /// validate the server still serves the same stream universe. After
    /// this, the next draw is exactly what the uninterrupted stream would
    /// have produced.
    fn reconnect_and_replay(&mut self) -> Result<()> {
        let journal = (self.sge_drawn, self.wre_ks.clone());
        let mut info = self.redial(Some((journal.0, &journal.1)))?;
        ensure!(
            info.seed == self.server_seed,
            "server at {} came back with seed {} (session started on {}) — \
             refusing to resume a different stream universe",
            self.addr,
            info.seed,
            self.server_seed,
        );
        // a following session tolerates fraction drift (a fixed-size
        // replay buffer over a growing stream shrinks the fraction every
        // epoch); an ordinary session does not
        let fraction_ok = (info.fraction - self.server_fraction).abs() < 1e-9
            || self.subscribed
            || info.epoch != self.server_epoch;
        ensure!(
            info.dataset == self.server_dataset && fraction_ok,
            "server at {} came back serving {}@{} (session started on {}@{})",
            self.addr,
            info.dataset,
            info.fraction,
            self.server_dataset,
            self.server_fraction,
        );
        if info.epoch != self.server_epoch {
            // the entry advanced while we were away: the replay journal
            // describes the *old* epoch's streams, so the fast-forward
            // just performed was against the wrong universe — restart the
            // streams cleanly at the head epoch instead (a re-HELLO on a
            // pooled stream re-binds that stream's session in place)
            self.sge_drawn = 0;
            self.wre_ks.clear();
            info = self.redial(None)?;
        }
        let missed_epoch = info.epoch > self.last_epoch;
        self.server_fraction = info.fraction;
        self.server_epoch = info.epoch;
        // a restarted server may have gained or lost the capability
        self.server_trace = info.trace;
        if self.subscribed {
            // the subscription died with the old connection — re-arm it,
            // and surface the advance(s) we slept through as one
            // synthesized update from the head epoch's metadata, so a
            // follower still observes every delivered epoch in order
            let f = self
                .roundtrip_live(&Json::obj(vec![("cmd", Json::str("SUBSCRIBE"))]))?;
            ok_json(&f)?;
            if missed_epoch {
                let f = self
                    .roundtrip_live(&Json::obj(vec![("cmd", Json::str("GET_META"))]))?;
                let meta = match &f {
                    Frame::Meta(_) => f.decode_meta()?,
                    _ => metadata_from_json(ok_json(&f)?.get("meta")?)?,
                };
                self.partial = None; // any half-burst died with the old conn
                self.pending_pushes.push_back(EpochUpdate {
                    epoch: info.epoch,
                    sge_subsets: meta.sge_subsets,
                    fixed_dm: meta.fixed_dm,
                });
            }
        }
        Ok(())
    }

    /// One protocol round-trip with the retry policy applied. When the
    /// server acked the trace capability at `HELLO`, the request is
    /// stamped with a fresh trace id and this client's request-span id —
    /// the server joins its dispatch (and everything downstream of it) to
    /// that trace and echoes the id on control replies — and the
    /// round-trip runs under a `serve.client.<cmd>` span, so client-side
    /// wait time and server-side handling land in one causal tree.
    fn call(&mut self, request: &Json) -> Result<Frame> {
        if !self.server_trace {
            return self.call_raw(request);
        }
        let trace = crate::obs::next_id();
        let hex = crate::obs::id_hex(trace);
        let _scope = crate::obs::TraceScope::enter(trace, 0);
        let cmd = request
            .opt("cmd")
            .and_then(|c| c.as_str().ok())
            .unwrap_or("other")
            .to_ascii_lowercase();
        let span = crate::obs::Span::enter(format!("serve.client.{cmd}"));
        // with telemetry disabled the span carries no id — the trace id
        // itself then parents the server's dispatch span
        let span_id = if span.span_id() != 0 { span.span_id() } else { trace };
        let mut stamped = request.clone();
        if let Json::Obj(m) = &mut stamped {
            m.insert("trace".to_string(), Json::Str(hex.clone()));
            m.insert("span".to_string(), Json::Str(crate::obs::id_hex(span_id)));
        }
        let result = self.call_raw(&stamped);
        // a control reply echoes the id verbatim; binary subset/meta
        // frames can't (and a pre-trace server after reconnect won't)
        let echoed = match &result {
            Ok(Frame::Json(text)) => text.contains(&hex),
            _ => false,
        };
        self.last_trace = Some((trace, echoed));
        result
    }

    /// `call` without trace stamping: transport failures trigger
    /// reconnect + deterministic replay; server-side errors come back as
    /// frames and are never retried.
    fn call_raw(&mut self, request: &Json) -> Result<Frame> {
        let mut first_err: Option<anyhow::Error> = None;
        if self.transport_live() {
            match self.roundtrip_live(request) {
                Ok(f) => return Ok(f),
                // keep the root cause: with an empty retry budget this is
                // the error the caller sees
                Err(e) => first_err = Some(e),
            }
        }
        if first_err.is_some() {
            self.drop_conn();
        }
        let max = self.opts.retry.max_reconnects;
        let mut last = first_err
            .unwrap_or_else(|| anyhow!("connection to milo serve at {} lost", self.addr));
        for attempt in 1..=max {
            std::thread::sleep(std::time::Duration::from_millis(
                self.opts.retry.backoff_ms.saturating_mul(attempt as u64),
            ));
            match self.reconnect_and_replay() {
                Ok(()) => match self.roundtrip_live(request) {
                    Ok(f) => return Ok(f),
                    Err(e) => {
                        last = e;
                        self.drop_conn();
                    }
                },
                // a deterministic refusal (seed/entry mismatch, policy
                // rejection) comes from a live server that will refuse
                // every redial identically — fail fast, don't burn the
                // backoff budget calling it "unreachable"
                Err(e) if is_refusal(&e) => {
                    return Err(e.context(format!(
                        "reconnect to milo serve at {} was refused — giving up",
                        self.addr,
                    )))
                }
                Err(e) => last = e,
            }
        }
        Err(last.context(format!(
            "milo serve at {} unreachable after {} reconnect attempt(s) — giving up",
            self.addr, max,
        )))
    }

    /// Fetch the full metadata document (the `GET_META` command) — in
    /// frame mode the payload is the exact binfmt artifact bytes
    /// (validated magic/version/checksum); in JSON mode the JSON schema
    /// of `save_metadata`.
    pub fn get_meta(&mut self) -> Result<Metadata> {
        let f = self.call(&Json::obj(vec![("cmd", Json::str("GET_META"))]))?;
        match &f {
            Frame::Meta(_) => f.decode_meta(),
            _ => {
                let v = ok_json(&f)?;
                metadata_from_json(v.get("meta")?)
            }
        }
    }

    /// Draw the next SGE subset in this client's cycle; returns
    /// `(subset index, train indices)`.
    pub fn next_subset(&mut self) -> Result<(usize, Vec<usize>)> {
        let f = self.call(&Json::obj(vec![("cmd", Json::str("NEXT_SUBSET"))]))?;
        let (index, subset) = subset_of(&f)?;
        let index = index.ok_or_else(|| anyhow!("NEXT_SUBSET response missing index"))?;
        self.sge_drawn += 1;
        Ok((index, subset))
    }

    /// Draw a fresh size-`k` WRE subset from this client's seeded stream.
    pub fn sample_wre(&mut self, k: usize) -> Result<Vec<usize>> {
        let f = self.call(&Json::obj(vec![
            ("cmd", Json::str("SAMPLE_WRE")),
            ("k", Json::num(k as f64)),
        ]))?;
        let (_, subset) = subset_of(&f)?;
        self.wre_ks.push(k);
        Ok(subset)
    }

    /// Server + store statistics as raw JSON (the `STATS` command).
    pub fn stats(&mut self) -> Result<Json> {
        let f = self.call(&Json::obj(vec![("cmd", Json::str("STATS"))]))?;
        let v = ok_json(&f)?;
        Ok(v.get("stats")?.clone())
    }

    pub fn ping(&mut self) -> Result<()> {
        let f = self.call(&Json::obj(vec![("cmd", Json::str("PING"))]))?;
        ok_json(&f)?;
        Ok(())
    }

    /// The server epoch this session's streams belong to (0 = batch).
    pub fn server_epoch(&self) -> u64 {
        self.server_epoch
    }

    /// Ask the server to push `EPOCH_ADVANCE` + `SUBSET_DELTA` frames on
    /// every epoch publish (frame wire only). Returns `(current epoch,
    /// SGE subset count)`. The subscription survives reconnects — the
    /// retry path re-subscribes and synthesizes any advance that happened
    /// while the connection was down.
    pub fn subscribe(&mut self) -> Result<(u64, usize)> {
        ensure!(
            self.opts.wire == WireMode::Frame,
            "SUBSCRIBE requires the frame wire — connect with ClientOptions \
             {{ wire: WireMode::Frame, .. }}",
        );
        let f = self.call(&Json::obj(vec![("cmd", Json::str("SUBSCRIBE"))]))?;
        let v = ok_json(&f)?;
        let epoch = v.get("epoch")?.as_f64()? as u64;
        let n_subsets = v.get("n_subsets")?.as_usize()?;
        self.subscribed = true;
        self.server_epoch = self.server_epoch.max(epoch);
        self.last_epoch = self.last_epoch.max(epoch);
        Ok((epoch, n_subsets))
    }

    /// Deliver the next epoch update, waiting up to `timeout_ms` for one
    /// to arrive. `Ok(None)` = no update within the window (the
    /// connection is fine). Each delivered epoch is observed **exactly
    /// once**, in increasing order — duplicates (e.g. a replayed burst
    /// plus a reconnect-synthesized head) are dropped here. Delivering an
    /// update moves this session's streams to the new epoch: the next
    /// `NEXT_SUBSET` / `SAMPLE_WRE` draws come from the new epoch's
    /// universe, restarting the deterministic streams.
    pub fn poll_push(&mut self, timeout_ms: u64) -> Result<Option<EpochUpdate>> {
        ensure!(self.subscribed, "poll_push requires subscribe() first");
        let deadline = Instant::now() + Duration::from_millis(timeout_ms);
        loop {
            self.ingest_stashed();
            if let Some(u) = self.take_ready() {
                return Ok(Some(u));
            }
            if !self.transport_live() {
                // the transport died earlier; reuse the retry machinery by
                // issuing a cheap request, which reconnects + re-subscribes
                // (and synthesizes a missed advance) or gives up cleanly
                self.ping()?;
                continue;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Ok(None);
            }
            // on a pool-shared connection, wait in short slices: the
            // socket is released between slices so sibling sessions can
            // run their roundtrips inside this session's follow window
            let slice = match &self.transport {
                Transport::Pooled { .. } => {
                    left.min(Duration::from_millis(POOL_POLL_SLICE_MS))
                }
                Transport::Direct(_) => left,
            };
            match self.poll_transport(slice) {
                Ok(Some(f)) if is_push(&f) => self.assemble(f),
                Ok(Some(f)) => {
                    bail!("unsolicited {} frame outside a request", f.kind_name())
                }
                Ok(None) => {
                    if matches!(self.transport, Transport::Direct(_)) {
                        return Ok(None);
                    }
                    // pooled: a sibling's push may have been stashed, or
                    // the slice elapsed — loop (the deadline check above
                    // ends the wait)
                }
                Err(e) => {
                    // transport failure mid-follow: reconnect via the retry
                    // path (ping re-subscribes and synthesizes the head
                    // advance if one was missed), then keep polling
                    self.drop_conn();
                    self.ping().context(e)?;
                }
            }
        }
    }

    /// Wait up to `timeout` for one frame on this session's stream;
    /// sibling-stream pushes are stashed for their owners. A transport
    /// error on a shared connection poisons it.
    fn poll_transport(&mut self, timeout: Duration) -> Result<Option<Frame>> {
        match &mut self.transport {
            Transport::Direct(Some(wire)) => wire.poll_frame_on(0, timeout),
            Transport::Direct(None) => {
                bail!("connection to milo serve at {} lost", self.addr)
            }
            Transport::Pooled { conn, stream, .. } => {
                let mut pw = conn.lock().expect("pooled conn lock");
                let wire = pw.wire.as_mut().ok_or_else(|| {
                    anyhow!("pooled connection to milo serve at {} lost", self.addr)
                })?;
                let r = wire.poll_frame_on(*stream, timeout);
                if r.is_err() {
                    pw.wire = None;
                }
                r
            }
        }
    }

    /// Iterate epoch updates: each `next()` waits up to `timeout_ms` and
    /// ends the iteration (returns `None`) when no update arrives in the
    /// window. Errors surface as `Some(Err(_))`.
    pub fn follow(&mut self, timeout_ms: u64) -> FollowStream<'_> {
        FollowStream { client: self, timeout_ms }
    }

    /// Move this session's stashed push frames (received interleaved with
    /// responses) into the reassembler. On a shared connection only the
    /// frames tagged with this session's stream id are taken — siblings'
    /// pushes stay stashed for their owners, in arrival order.
    fn ingest_stashed(&mut self) {
        let mine: Vec<Frame> = match &mut self.transport {
            Transport::Direct(Some(w)) if !w.pushed.is_empty() => {
                std::mem::take(&mut w.pushed).into_iter().map(|(_, f)| f).collect()
            }
            Transport::Direct(_) => return,
            Transport::Pooled { conn, stream, .. } => {
                let mut pw = conn.lock().expect("pooled conn lock");
                let Some(w) = pw.wire.as_mut() else { return };
                if w.pushed.is_empty() {
                    return;
                }
                let s = *stream;
                let (mine, rest): (Vec<_>, Vec<_>) =
                    std::mem::take(&mut w.pushed).into_iter().partition(|(t, _)| *t == s);
                w.pushed = rest;
                mine.into_iter().map(|(_, f)| f).collect()
            }
        };
        for f in mine {
            self.assemble(f);
        }
    }

    /// Feed one push frame to the burst reassembler; a completed burst
    /// becomes a pending [`EpochUpdate`].
    fn assemble(&mut self, f: Frame) {
        match f {
            Frame::EpochAdvance { epoch, n_subsets } => {
                self.partial = Some(PartialUpdate {
                    epoch,
                    n_subsets: n_subsets as usize,
                    sge_subsets: Vec::with_capacity(n_subsets as usize),
                    fixed_dm: None,
                });
            }
            Frame::SubsetDelta { epoch, index, indices } => {
                let Some(p) = self.partial.as_mut() else { return };
                if p.epoch != epoch {
                    return; // a delta without its announce — drop it
                }
                let indices: Vec<usize> = indices.iter().map(|&i| i as usize).collect();
                if index == frame::NO_INDEX {
                    p.fixed_dm = Some(indices);
                } else if (index as usize) == p.sge_subsets.len() {
                    // deltas arrive in cycle order within one burst
                    p.sge_subsets.push(indices);
                }
                if p.sge_subsets.len() == p.n_subsets && p.fixed_dm.is_some() {
                    let p = self.partial.take().expect("checked");
                    self.pending_pushes.push_back(EpochUpdate {
                        epoch: p.epoch,
                        sge_subsets: p.sge_subsets,
                        fixed_dm: p.fixed_dm.expect("checked"),
                    });
                }
            }
            _ => {}
        }
    }

    /// Pop the oldest pending update newer than anything delivered,
    /// advancing the session's stream epoch and resetting the replay
    /// journal (the old epoch's draw counts describe streams that no
    /// longer exist).
    fn take_ready(&mut self) -> Option<EpochUpdate> {
        while let Some(u) = self.pending_pushes.pop_front() {
            if u.epoch > self.last_epoch {
                self.last_epoch = u.epoch;
                self.server_epoch = u.epoch;
                self.sge_drawn = 0;
                self.wre_ks.clear();
                return Some(u);
            }
        }
        None
    }

    /// Graceful close. On a dedicated connection the server reclaims the
    /// whole slot; on a pooled session only this stream's server-side
    /// session is torn down — the shared socket lives on for its
    /// siblings. The stream id itself returns to the pool when the
    /// client is dropped (checking it in here would let a sibling lease
    /// it while this object still exists — and `Drop`'s unconditional
    /// checkin would then free the sibling's lease). Dropping the client
    /// sends the same close message best-effort; calling this explicitly
    /// also confirms the acknowledgement.
    pub fn goodbye(&mut self) -> Result<()> {
        self.goodbye_sent = true;
        let req = Json::obj(vec![("cmd", Json::str("GOODBYE"))]);
        match &mut self.transport {
            Transport::Direct(_) => {
                if self.transport_live() {
                    let f = self.roundtrip_live(&req)?;
                    ok_json(&f)?;
                }
                self.drop_conn();
                Ok(())
            }
            Transport::Pooled { conn, stream, .. } => {
                let mut pw = conn.lock().expect("pooled conn lock");
                match pw.wire.as_mut() {
                    None => Ok(()),
                    Some(wire) => match wire.roundtrip_on(*stream, &req) {
                        Ok(f) => ok_json(&f).map(|_| ()),
                        Err(e) => {
                            pw.wire = None;
                            Err(e)
                        }
                    },
                }
            }
        }
    }

    /// Drop the connection abruptly — a bare FIN, no GOODBYE (and none on
    /// [`Drop`] either). Exercises the server's EOF sweep the way a
    /// crashed trainer would; the stress/push tests use it to prove slot
    /// and subscriber reclamation without a polite disconnect. On a
    /// pooled session this kills the *shared* socket — exactly what a
    /// crash of a process multiplexing several trainers does.
    pub fn abandon(&mut self) {
        self.goodbye_sent = true;
        self.drop_conn();
    }
}

/// Iterator form of [`ServeClient::poll_push`]: yields epoch updates as
/// they arrive, ending the iteration when `timeout_ms` passes without
/// one. A trainer's follow loop is then plain `for update in
/// client.follow(ms) { ... }`, switching datasets at each yield.
pub struct FollowStream<'a> {
    client: &'a mut ServeClient,
    timeout_ms: u64,
}

impl Iterator for FollowStream<'_> {
    type Item = Result<EpochUpdate>;

    fn next(&mut self) -> Option<Self::Item> {
        match self.client.poll_push(self.timeout_ms) {
            Ok(Some(u)) => Some(Ok(u)),
            Ok(None) => None,
            Err(e) => Some(Err(e)),
        }
    }
}

impl Drop for ServeClient {
    fn drop(&mut self) {
        // best-effort goodbye so the server reclaims the slot (or the
        // stream's session) promptly — never block (or panic) on the way
        // out
        if !self.goodbye_sent {
            let req = Json::obj(vec![("cmd", Json::str("GOODBYE"))]);
            match &mut self.transport {
                Transport::Direct(Some(wire)) => {
                    let _ = if wire.framed {
                        wire.send_frame(&Frame::Json(req.to_string()))
                    } else {
                        wire.send_line(&req.to_string())
                    };
                }
                Transport::Direct(None) => {}
                Transport::Pooled { conn, stream, .. } => {
                    // a fire-and-forget GOODBYE would leave its response
                    // frame unread on the shared socket and desynchronize
                    // the siblings — do the full roundtrip (the server
                    // answers control frames promptly); on any error
                    // poison the socket rather than leave it torn
                    if let Ok(mut pw) = conn.try_lock() {
                        if let Some(wire) = pw.wire.as_mut() {
                            if wire.roundtrip_on(*stream, &req).is_err() {
                                pw.wire = None;
                            }
                        }
                    }
                }
            }
        }
        // return a pooled stream id regardless of how the session ended
        if let Transport::Pooled { pool, conn, stream } = &self.transport {
            pool.checkin(conn, *stream);
        }
    }
}

/// Whether a reconnect failure is a deterministic server-side refusal
/// (markers this crate stamps itself: the server's `"ok":false` HELLO
/// becomes `server error:`, and the stream-universe guards in
/// `reconnect_and_replay` say `refusing to resume` / `came back
/// serving`). Redialing a live server that refused is pointless.
fn is_refusal(e: &anyhow::Error) -> bool {
    let msg = format!("{e:#}");
    msg.contains("server error:")
        || msg.contains("refusing to resume")
        || msg.contains("came back serving")
}

/// Interpret a control response: parsed JSON on `"ok":true`, an error on
/// `"ok":false` / `ERROR` frames / unexpected kinds.
fn ok_json(f: &Frame) -> Result<Json> {
    match f {
        Frame::Json(text) => {
            let v = Json::parse(text.trim_end())
                .with_context(|| format!("bad response {text:?}"))?;
            if !v.get("ok")?.as_bool()? {
                let msg = v
                    .opt("error")
                    .and_then(|e| e.as_str().ok().map(|s| s.to_string()))
                    .unwrap_or_else(|| "unknown server error".to_string());
                bail!("server error: {msg}");
            }
            Ok(v)
        }
        Frame::Error(msg) => bail!("server error: {msg}"),
        other => bail!("unexpected {} response", other.kind_name()),
    }
}

/// Interpret a subset response in either wire format: `(cycle index if
/// any, train indices)`.
fn subset_of(f: &Frame) -> Result<(Option<usize>, Vec<usize>)> {
    match f {
        Frame::Subset { index, indices } => Ok((
            if *index == frame::NO_INDEX { None } else { Some(*index as usize) },
            indices.iter().map(|&i| i as usize).collect(),
        )),
        Frame::Json(_) | Frame::Error(_) => {
            let v = ok_json(f)?;
            let index = v.opt("index").and_then(|x| x.as_usize().ok());
            let subset = v
                .get("subset")?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<Vec<_>>>()?;
            Ok((index, subset))
        }
        other => bail!("unexpected {} response to a subset request", other.kind_name()),
    }
}

/// The MILO easy-to-hard curriculum, served: SGE subsets come from
/// `NEXT_SUBSET` during the first `κ·T` epochs, WRE draws from
/// `SAMPLE_WRE` afterwards. N trainers pointing at one server share a
/// single preprocessing pass — the paper's "no additional cost" claim as a
/// deployment topology.
pub struct ServedMiloStrategy {
    client: ServeClient,
    pub kappa: f64,
}

impl ServedMiloStrategy {
    pub fn connect(addr: &str, client_id: &str, kappa: f64) -> Result<ServedMiloStrategy> {
        ServedMiloStrategy::connect_with(addr, client_id, kappa, ClientOptions::default())
    }

    /// Connect with explicit wire format / entry routing / retry policy.
    pub fn connect_with(
        addr: &str,
        client_id: &str,
        kappa: f64,
        opts: ClientOptions,
    ) -> Result<ServedMiloStrategy> {
        Ok(ServedMiloStrategy {
            client: ServeClient::connect_with(addr, client_id, opts)?,
            kappa,
        })
    }

    /// Draw from a stream multiplexed on a pool-shared connection — a
    /// trainer fleet on one host shares sockets instead of holding one
    /// each (`opts.wire` must be [`WireMode::Frame`]).
    pub fn connect_pooled(
        pool: &ConnectionPool,
        client_id: &str,
        kappa: f64,
        opts: ClientOptions,
    ) -> Result<ServedMiloStrategy> {
        Ok(ServedMiloStrategy {
            client: ServeClient::connect_pooled(pool, client_id, opts)?,
            kappa,
        })
    }

    fn switch_epoch(&self, total_epochs: usize) -> usize {
        (self.kappa * total_epochs as f64).round() as usize
    }
}

impl Strategy for ServedMiloStrategy {
    fn name(&self) -> String {
        "milo_served".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        anyhow::ensure!(ctx.total_epochs > 0, "total_epochs must be set");
        if ctx.epoch < self.switch_epoch(ctx.total_epochs) {
            Ok(self.client.next_subset()?.1)
        } else {
            self.client.sample_wre(ctx.k)
        }
    }
}
