//! Blocking Rust client for the `milo serve` protocol, plus a
//! [`Strategy`] adapter so a trainer can draw its subsets live from a
//! served metadata instance instead of local files.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use crate::coordinator::{metadata_from_json, Metadata};
use crate::selection::{SelectCtx, Strategy};
use crate::util::json::Json;

/// A blocking connection to a [`SubsetServer`](super::SubsetServer). One
/// request/response round-trip per call; reconnect (same `client_id`) to
/// replay the same deterministic stream.
pub struct ServeClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    client_id: String,
    server_dataset: String,
    server_seed: u64,
}

impl ServeClient {
    /// Connect and bind the session to `client_id` (which keys the
    /// server-side deterministic streams — see the module docs of
    /// [`crate::serve`]).
    pub fn connect(addr: &str, client_id: &str) -> Result<ServeClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to milo serve at {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        let mut client = ServeClient {
            reader,
            writer: stream,
            client_id: client_id.to_string(),
            server_dataset: String::new(),
            server_seed: 0,
        };
        let hello = client.call(Json::obj(vec![
            ("cmd", Json::str("HELLO")),
            ("client", Json::str(client_id)),
        ]))?;
        client.server_dataset = hello.get("dataset")?.as_str()?.to_string();
        client.server_seed = hello.get("seed")?.as_f64()? as u64;
        Ok(client)
    }

    pub fn client_id(&self) -> &str {
        &self.client_id
    }

    /// Dataset the server announced in HELLO.
    pub fn server_dataset(&self) -> &str {
        &self.server_dataset
    }

    /// Stream seed the server announced in HELLO — compare against your
    /// own configuration before trusting the served selections.
    pub fn server_seed(&self) -> u64 {
        self.server_seed
    }

    /// One protocol round-trip; errors on transport failure or an
    /// `"ok":false` response.
    fn call(&mut self, request: Json) -> Result<Json> {
        let mut line = request.to_string();
        line.push('\n');
        self.writer.write_all(line.as_bytes()).context("sending request")?;
        let mut response = String::new();
        let n = self.reader.read_line(&mut response).context("reading response")?;
        if n == 0 {
            bail!("server closed the connection");
        }
        let v = Json::parse(response.trim_end())
            .with_context(|| format!("bad response line {response:?}"))?;
        if !v.get("ok")?.as_bool()? {
            let msg = v
                .opt("error")
                .and_then(|e| e.as_str().ok().map(|s| s.to_string()))
                .unwrap_or_else(|| "unknown server error".to_string());
            bail!("server error: {msg}");
        }
        Ok(v)
    }

    /// Fetch the full metadata document (the `GET_META` command) — lets a
    /// tuner or trainer run entirely off a served preprocessing pass.
    pub fn get_meta(&mut self) -> Result<Metadata> {
        let v = self.call(Json::obj(vec![("cmd", Json::str("GET_META"))]))?;
        metadata_from_json(v.get("meta")?)
    }

    /// Draw the next SGE subset in this client's cycle; returns
    /// `(subset index, train indices)`.
    pub fn next_subset(&mut self) -> Result<(usize, Vec<usize>)> {
        let v = self.call(Json::obj(vec![("cmd", Json::str("NEXT_SUBSET"))]))?;
        let index = v.get("index")?.as_usize()?;
        let subset = v
            .get("subset")?
            .as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Result<Vec<_>>>()?;
        Ok((index, subset))
    }

    /// Draw a fresh size-`k` WRE subset from this client's seeded stream.
    pub fn sample_wre(&mut self, k: usize) -> Result<Vec<usize>> {
        let v = self.call(Json::obj(vec![
            ("cmd", Json::str("SAMPLE_WRE")),
            ("k", Json::num(k as f64)),
        ]))?;
        v.get("subset")?
            .as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect()
    }

    /// Server + store statistics as raw JSON (the `STATS` command).
    pub fn stats(&mut self) -> Result<Json> {
        let v = self.call(Json::obj(vec![("cmd", Json::str("STATS"))]))?;
        Ok(v.get("stats")?.clone())
    }

    pub fn ping(&mut self) -> Result<()> {
        self.call(Json::obj(vec![("cmd", Json::str("PING"))]))?;
        Ok(())
    }
}

/// The MILO easy-to-hard curriculum, served: SGE subsets come from
/// `NEXT_SUBSET` during the first `κ·T` epochs, WRE draws from
/// `SAMPLE_WRE` afterwards. N trainers pointing at one server share a
/// single preprocessing pass — the paper's "no additional cost" claim as a
/// deployment topology.
pub struct ServedMiloStrategy {
    client: ServeClient,
    pub kappa: f64,
}

impl ServedMiloStrategy {
    pub fn connect(addr: &str, client_id: &str, kappa: f64) -> Result<ServedMiloStrategy> {
        Ok(ServedMiloStrategy { client: ServeClient::connect(addr, client_id)?, kappa })
    }

    fn switch_epoch(&self, total_epochs: usize) -> usize {
        (self.kappa * total_epochs as f64).round() as usize
    }
}

impl Strategy for ServedMiloStrategy {
    fn name(&self) -> String {
        "milo_served".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Vec<usize>> {
        anyhow::ensure!(ctx.total_epochs > 0, "total_epochs must be set");
        if ctx.epoch < self.switch_epoch(ctx.total_epochs) {
            Ok(self.client.next_subset()?.1)
        } else {
            self.client.sample_wre(ctx.k)
        }
    }
}
