//! Length-prefixed binary frames for the `milo serve` wire protocol.
//!
//! The JSON-line protocol re-serializes every subset index array per
//! request — for a 10% CIFAR-sized subset that is ~5 text bytes per index
//! plus the envelope, parsed back to integers on the client. The frame
//! mode (negotiated at `HELLO`, see [`crate::serve`]) sends the same
//! payloads as raw little-endian `u32` words and ships full metadata as
//! the [`crate::store::binfmt`] artifact encoding — the exact bytes the
//! store persists, checksum included, so a served document is
//! *byte-identical* to the on-disk artifact.
//!
//! # Layout
//!
//! Every frame is a 5-byte header followed by the payload:
//!
//! ```text
//! word  4  u32 LE — low 27 bits: payload length in bytes (excluding
//!                   this header); high 5 bits: stream id (0–31)
//! kind  1  u8     — payload interpretation (below)
//! payload  len bytes
//! ```
//!
//! The **stream id** multiplexes up to [`MAX_STREAMS`] logical sessions
//! over one TCP connection (see the [`crate::serve`] protocol docs).
//! Stream 0 is the connection's default/control stream; because
//! [`MAX_PAYLOAD`] needs only 27 bits, a stream-0 frame is *byte-identical*
//! to the pre-multiplexing wire — old clients and servers interoperate
//! unchanged as long as they never open a nonzero stream.
//!
//! | kind | name | payload |
//! |---|---|---|
//! | 0 | `JSON`   | a UTF-8 JSON document (requests; control responses) |
//! | 1 | `SUBSET` | `u32` subset index (`NO_INDEX` for WRE draws) + `u32` count + count×`u32` train indices |
//! | 2 | `META`   | a complete [`crate::store::binfmt`] metadata artifact |
//! | 3 | `ERROR`  | a UTF-8 error message |
//! | 4 | `EPOCH_ADVANCE` | `u64` epoch + `u32` SGE subset count — server-initiated, announces a continual-arrival epoch |
//! | 5 | `SUBSET_DELTA`  | `u64` epoch + `u32` subset index (`NO_INDEX` = fixed subset) + `u32` count + count×`u32` train indices — server-initiated, the subset's full new contents |
//!
//! Kinds 4–5 are **push** frames: only the server emits them, only to
//! connections that sent `SUBSCRIBE`, and always as one `EPOCH_ADVANCE`
//! followed contiguously by that epoch's `SUBSET_DELTA`s (see the
//! [`crate::serve`] protocol docs).
//!
//! Decoding is incremental ([`FrameDecoder`] accepts arbitrary byte
//! chunks, as delivered by a nonblocking socket) and total: a truncated
//! buffer is `Ok(None)` (wait for more bytes), while a corrupted one — an
//! unknown kind, an oversized or inconsistent length prefix, invalid
//! UTF-8 — is a clean `Err`, never a panic and never an over-allocation.
//! `encode(decode(bytes)) == bytes` for every valid frame
//! (property-tested in `rust/tests/serve_frame_props.rs`).

use anyhow::{bail, Result};

use crate::coordinator::Metadata;
use crate::store::binfmt;

/// Frame header size: u32 payload length + u8 kind.
pub const HEADER_LEN: usize = 5;

/// Hard ceiling on a single frame's payload — a corrupted length prefix
/// must never drive allocation (largest real payload is a full metadata
/// artifact, a few MB). Must stay under `1 << LEN_BITS`: the length
/// shares the header's u32 word with the stream id.
pub const MAX_PAYLOAD: usize = 64 << 20;

/// Bits of the header word carrying the payload length; the remaining
/// `32 - LEN_BITS` high bits carry the stream id.
const LEN_BITS: u32 = 27;

/// Mask extracting the payload length from the header word.
const LEN_MASK: u32 = (1 << LEN_BITS) - 1;

/// Logical streams per connection (5 header bits). Stream 0 is the
/// control/default stream; 1..=31 are allocatable session streams.
pub const MAX_STREAMS: usize = 32;

// the length field must be able to express MAX_PAYLOAD
const _: () = assert!(MAX_PAYLOAD as u32 <= LEN_MASK);

/// `SUBSET` frame index sentinel for draws that have no cycle position
/// (WRE samples).
pub const NO_INDEX: u32 = u32::MAX;

pub const KIND_JSON: u8 = 0;
pub const KIND_SUBSET: u8 = 1;
pub const KIND_META: u8 = 2;
pub const KIND_ERROR: u8 = 3;
pub const KIND_EPOCH: u8 = 4;
pub const KIND_DELTA: u8 = 5;

/// Highest valid frame kind — [`parse_header`]'s range check.
const KIND_MAX: u8 = KIND_DELTA;

/// One decoded wire frame. `Json`/`Error` hold the raw text, `Meta` holds
/// the raw binfmt artifact bytes (decode with [`Frame::decode_meta`]) —
/// round-tripping a frame through encode→decode→encode is byte-identical.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// A JSON document (request or control response).
    Json(String),
    /// A subset payload: cycle index ([`NO_INDEX`] for WRE) + train indices.
    Subset { index: u32, indices: Vec<u32> },
    /// A binfmt-encoded metadata artifact (the store's on-disk bytes).
    Meta(Vec<u8>),
    /// A protocol error message.
    Error(String),
    /// Server push: a continual-arrival epoch advanced; `n_subsets`
    /// `SUBSET_DELTA` frames (plus one for the fixed subset) follow.
    EpochAdvance { epoch: u64, n_subsets: u32 },
    /// Server push: one subset's full contents at `epoch` ([`NO_INDEX`]
    /// = the fixed disparity-min subset).
    SubsetDelta { epoch: u64, index: u32, indices: Vec<u32> },
}

impl Frame {
    /// Build a `META` frame from a metadata document (binfmt encoding —
    /// versioned, length-validated, FNV-checksummed).
    pub fn meta(meta: &Metadata) -> Frame {
        Frame::Meta(binfmt::encode(meta))
    }

    /// Build a `SUBSET` frame from usize train indices.
    pub fn subset(index: u32, indices: &[usize]) -> Frame {
        Frame::Subset {
            index,
            indices: indices
                .iter()
                .map(|&i| {
                    assert!(i <= u32::MAX as usize, "index {i} overflows u32");
                    i as u32
                })
                .collect(),
        }
    }

    pub fn kind(&self) -> u8 {
        match self {
            Frame::Json(_) => KIND_JSON,
            Frame::Subset { .. } => KIND_SUBSET,
            Frame::Meta(_) => KIND_META,
            Frame::Error(_) => KIND_ERROR,
            Frame::EpochAdvance { .. } => KIND_EPOCH,
            Frame::SubsetDelta { .. } => KIND_DELTA,
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            Frame::Json(_) => "JSON",
            Frame::Subset { .. } => "SUBSET",
            Frame::Meta(_) => "META",
            Frame::Error(_) => "ERROR",
            Frame::EpochAdvance { .. } => "EPOCH_ADVANCE",
            Frame::SubsetDelta { .. } => "SUBSET_DELTA",
        }
    }

    /// Serialize to header + payload bytes on stream 0 (the legacy wire).
    pub fn encode(&self) -> Vec<u8> {
        self.encode_on(0)
    }

    /// Serialize to header + payload bytes with `stream` in the header's
    /// stream-id bits.
    pub fn encode_on(&self, stream: u8) -> Vec<u8> {
        let payload: Vec<u8> = match self {
            Frame::Json(s) => s.as_bytes().to_vec(),
            Frame::Error(s) => s.as_bytes().to_vec(),
            Frame::Meta(bytes) => bytes.clone(),
            Frame::Subset { index, indices } => {
                let mut p = Vec::with_capacity(8 + 4 * indices.len());
                p.extend_from_slice(&index.to_le_bytes());
                p.extend_from_slice(&(indices.len() as u32).to_le_bytes());
                for &i in indices {
                    p.extend_from_slice(&i.to_le_bytes());
                }
                p
            }
            Frame::EpochAdvance { epoch, n_subsets } => {
                let mut p = Vec::with_capacity(12);
                p.extend_from_slice(&epoch.to_le_bytes());
                p.extend_from_slice(&n_subsets.to_le_bytes());
                p
            }
            Frame::SubsetDelta { epoch, index, indices } => {
                let mut p = Vec::with_capacity(16 + 4 * indices.len());
                p.extend_from_slice(&epoch.to_le_bytes());
                p.extend_from_slice(&index.to_le_bytes());
                p.extend_from_slice(&(indices.len() as u32).to_le_bytes());
                for &i in indices {
                    p.extend_from_slice(&i.to_le_bytes());
                }
                p
            }
        };
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        write_frame_on(&mut out, stream, self.kind(), &payload);
        out
    }

    /// Decode the `META` payload back to a metadata document, validating
    /// the artifact's magic, schema version, lengths, and checksum.
    pub fn decode_meta(&self) -> Result<Metadata> {
        match self {
            Frame::Meta(bytes) => binfmt::decode(bytes),
            other => bail!("expected a META frame, got {}", other.kind_name()),
        }
    }

    /// `SUBSET` payload as usize train indices; errors on any other kind.
    pub fn decode_subset(&self) -> Result<(u32, Vec<usize>)> {
        match self {
            Frame::Subset { index, indices } => {
                Ok((*index, indices.iter().map(|&i| i as usize).collect()))
            }
            other => bail!("expected a SUBSET frame, got {}", other.kind_name()),
        }
    }
}

/// Pack payload length + stream id into the header's u32 word.
#[inline]
fn header_word(len: usize, stream: u8) -> u32 {
    debug_assert!(len <= MAX_PAYLOAD);
    debug_assert!((stream as usize) < MAX_STREAMS);
    (len as u32) | ((stream as u32) << LEN_BITS)
}

/// Append one framed message (header + payload) on stream 0 to `out`.
/// Used by [`Frame::encode`] and by the server's cached-payload fast path
/// (which frames pre-encoded bytes without re-building a [`Frame`]).
pub fn write_frame_into(out: &mut Vec<u8>, kind: u8, payload: &[u8]) {
    write_frame_on(out, 0, kind, payload);
}

/// Append one framed message on an explicit stream — the single place
/// that knows the header layout.
pub fn write_frame_on(out: &mut Vec<u8>, stream: u8, kind: u8, payload: &[u8]) {
    assert!(payload.len() <= MAX_PAYLOAD, "frame payload too large");
    assert!((stream as usize) < MAX_STREAMS, "stream id {stream} out of range");
    out.extend_from_slice(&header_word(payload.len(), stream).to_le_bytes());
    out.push(kind);
    out.extend_from_slice(payload);
}

/// Append a `SUBSET` frame encoded straight from a `usize` index slice —
/// byte-identical to `Frame::subset(index, indices).encode()` (plus the
/// stream bits) without the intermediate `Vec<u32>`/`Vec<u8>`. This is
/// the server's `NEXT_SUBSET` hot path: the subset travels from the
/// shared metadata slice into the connection's write buffer with no
/// per-request re-encode. The caller validates lengths/ranges up front (a
/// served payload must degrade to an ERROR frame, never panic the event
/// loop).
pub fn write_subset_frame_on(out: &mut Vec<u8>, stream: u8, index: u32, indices: &[usize]) {
    let len = 8 + 4 * indices.len();
    assert!(len <= MAX_PAYLOAD, "subset frame payload too large");
    assert!((stream as usize) < MAX_STREAMS, "stream id {stream} out of range");
    out.reserve(HEADER_LEN + len);
    out.extend_from_slice(&header_word(len, stream).to_le_bytes());
    out.push(KIND_SUBSET);
    out.extend_from_slice(&index.to_le_bytes());
    out.extend_from_slice(&(indices.len() as u32).to_le_bytes());
    for &i in indices {
        debug_assert!(i <= u32::MAX as usize, "index {i} overflows u32");
        out.extend_from_slice(&(i as u32).to_le_bytes());
    }
}

/// Stream-0 [`write_subset_frame_on`].
pub fn write_subset_frame_into(out: &mut Vec<u8>, index: u32, indices: &[usize]) {
    write_subset_frame_on(out, 0, index, indices);
}

/// Append a `SUBSET_DELTA` frame encoded straight from a `usize` index
/// slice — byte-identical to
/// `Frame::SubsetDelta { .. }.encode()` without intermediate vectors.
/// This is the push-broadcast hot path: on an epoch advance the server
/// encodes each new subset once and replays the burst per subscribed
/// stream (see [`restream_frames`]).
pub fn write_delta_frame_into(out: &mut Vec<u8>, epoch: u64, index: u32, indices: &[usize]) {
    let len = 16 + 4 * indices.len();
    assert!(len <= MAX_PAYLOAD, "delta frame payload too large");
    out.reserve(HEADER_LEN + len);
    out.extend_from_slice(&header_word(len, 0).to_le_bytes());
    out.push(KIND_DELTA);
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&index.to_le_bytes());
    out.extend_from_slice(&(indices.len() as u32).to_le_bytes());
    for &i in indices {
        debug_assert!(i <= u32::MAX as usize, "index {i} overflows u32");
        out.extend_from_slice(&(i as u32).to_le_bytes());
    }
}

/// Copy a pre-encoded stream-0 frame sequence into `out`, rewriting every
/// header's stream bits to `stream`. The push path pre-encodes one epoch
/// burst per publish; broadcasting to a subscriber on stream N is this
/// header patch plus a memcpy — payloads are never re-encoded, so the
/// bytes delivered per stream stay identical to a dedicated connection's.
pub fn restream_frames(src: &[u8], out: &mut Vec<u8>, stream: u8) -> Result<()> {
    assert!((stream as usize) < MAX_STREAMS, "stream id {stream} out of range");
    out.reserve(src.len());
    let mut pos = 0usize;
    while pos < src.len() {
        if src.len() - pos < HEADER_LEN {
            bail!("truncated frame header in pre-encoded burst");
        }
        let header: [u8; HEADER_LEN] =
            src[pos..pos + HEADER_LEN].try_into().expect("sliced exactly HEADER_LEN");
        let (len, kind, _) = parse_header(&header)?;
        if src.len() - pos < HEADER_LEN + len {
            bail!("truncated frame payload in pre-encoded burst");
        }
        out.extend_from_slice(&header_word(len, stream).to_le_bytes());
        out.push(kind);
        out.extend_from_slice(&src[pos + HEADER_LEN..pos + HEADER_LEN + len]);
        pos += HEADER_LEN + len;
    }
    Ok(())
}

/// Validate a frame header, returning `(payload length, kind, stream)`.
/// The single place that checks the length cap and kind range — used by
/// the incremental [`FrameDecoder`] and the client's blocking reader, so
/// the two cannot drift.
pub fn parse_header(header: &[u8; HEADER_LEN]) -> Result<(usize, u8, u8)> {
    let word = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
    let len = (word & LEN_MASK) as usize;
    let stream = (word >> LEN_BITS) as u8;
    let kind = header[4];
    // validate before anyone waits on (or allocates for) the payload: a
    // corrupted length or kind must fail fast
    if len > MAX_PAYLOAD {
        bail!("frame payload length {len} exceeds the {MAX_PAYLOAD} byte cap");
    }
    if kind > KIND_MAX {
        bail!("unknown frame kind {kind}");
    }
    Ok((len, kind, stream))
}

/// Parse one payload of `kind` into a [`Frame`]. Total: every malformed
/// payload is an `Err`.
pub fn parse_payload(kind: u8, payload: &[u8]) -> Result<Frame> {
    match kind {
        KIND_JSON => Ok(Frame::Json(
            std::str::from_utf8(payload)
                .map_err(|e| anyhow::anyhow!("JSON frame is not UTF-8: {e}"))?
                .to_string(),
        )),
        KIND_ERROR => Ok(Frame::Error(
            std::str::from_utf8(payload)
                .map_err(|e| anyhow::anyhow!("ERROR frame is not UTF-8: {e}"))?
                .to_string(),
        )),
        KIND_META => Ok(Frame::Meta(payload.to_vec())),
        KIND_SUBSET => {
            if payload.len() < 8 {
                bail!("SUBSET frame too short ({} bytes)", payload.len());
            }
            let index = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]);
            let count =
                u32::from_le_bytes([payload[4], payload[5], payload[6], payload[7]]) as usize;
            if payload.len() != 8 + 4 * count {
                bail!(
                    "SUBSET frame length mismatch: {} indices declared, {} payload bytes",
                    count,
                    payload.len()
                );
            }
            let mut indices = Vec::with_capacity(count);
            for c in payload[8..].chunks_exact(4) {
                indices.push(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            Ok(Frame::Subset { index, indices })
        }
        KIND_EPOCH => {
            if payload.len() != 12 {
                bail!("EPOCH_ADVANCE frame must be 12 bytes, got {}", payload.len());
            }
            let epoch = u64::from_le_bytes(payload[..8].try_into().expect("checked"));
            let n_subsets =
                u32::from_le_bytes(payload[8..12].try_into().expect("checked"));
            Ok(Frame::EpochAdvance { epoch, n_subsets })
        }
        KIND_DELTA => {
            if payload.len() < 16 {
                bail!("SUBSET_DELTA frame too short ({} bytes)", payload.len());
            }
            let epoch = u64::from_le_bytes(payload[..8].try_into().expect("checked"));
            let index = u32::from_le_bytes(payload[8..12].try_into().expect("checked"));
            let count =
                u32::from_le_bytes(payload[12..16].try_into().expect("checked")) as usize;
            if payload.len() != 16 + 4 * count {
                bail!(
                    "SUBSET_DELTA frame length mismatch: {} indices declared, {} payload bytes",
                    count,
                    payload.len()
                );
            }
            let mut indices = Vec::with_capacity(count);
            for c in payload[16..].chunks_exact(4) {
                indices.push(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            Ok(Frame::SubsetDelta { epoch, index, indices })
        }
        other => bail!("unknown frame kind {other}"),
    }
}

/// Incremental frame decoder: push arbitrary byte chunks (as a nonblocking
/// socket delivers them), pull complete frames. Partial input is never an
/// error — [`FrameDecoder::next`] returns `Ok(None)` until a full frame is
/// buffered — while structurally invalid input (bad kind, absurd length)
/// fails fast without waiting for the bogus payload to "complete".
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder { buf: Vec::new() }
    }

    /// Append raw bytes from the transport.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a complete frame — nonzero
    /// at connection close means the peer died mid-frame.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len()
    }

    /// Take the undecoded remainder (used when a connection negotiates
    /// back to JSON-line mode mid-stream).
    pub fn take_buffer(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.buf)
    }

    /// Release buffer capacity left over from a burst: once drained below
    /// `keep` bytes of content, capacity above `keep` is returned to the
    /// allocator. One oversized request must not pin its high-water
    /// allocation for the connection's lifetime.
    pub fn shrink(&mut self, keep: usize) {
        if self.buf.capacity() > keep && self.buf.len() <= keep {
            self.buf.shrink_to(keep);
        }
    }

    /// Buffer capacity currently held (content + slack) — the
    /// per-connection memory the decoder pins between requests.
    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Pop the next complete frame. `Ok(None)` = incomplete, wait for more
    /// bytes; `Err` = the stream is corrupt and cannot be resynchronized.
    pub fn next(&mut self) -> Result<Option<Frame>> {
        Ok(self.next_with_stream()?.map(|(_, frame)| frame))
    }

    /// Pop the next complete frame with its stream id. `Ok(None)` =
    /// incomplete, wait for more bytes; `Err` = the stream is corrupt and
    /// cannot be resynchronized.
    pub fn next_with_stream(&mut self) -> Result<Option<(u8, Frame)>> {
        if self.buf.len() < HEADER_LEN {
            return Ok(None);
        }
        let header: [u8; HEADER_LEN] =
            self.buf[..HEADER_LEN].try_into().expect("sliced exactly HEADER_LEN");
        let (len, kind, stream) = parse_header(&header)?;
        if self.buf.len() < HEADER_LEN + len {
            return Ok(None);
        }
        let frame = parse_payload(kind, &self.buf[HEADER_LEN..HEADER_LEN + len])?;
        self.buf.drain(..HEADER_LEN + len);
        Ok(Some((stream, frame)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_roundtrip_is_byte_identical() {
        let f = Frame::subset(2, &[0, 7, 1000, 4_000_000]);
        let bytes = f.encode();
        let mut d = FrameDecoder::new();
        d.push(&bytes);
        let back = d.next().unwrap().unwrap();
        assert_eq!(back, f);
        assert_eq!(back.encode(), bytes);
        assert_eq!(d.pending_bytes(), 0);
    }

    #[test]
    fn direct_subset_writer_matches_frame_encode() {
        for indices in [vec![], vec![0usize], vec![5, 0, 7, 1000, 4_000_000]] {
            for index in [0u32, 3, NO_INDEX] {
                let canonical = Frame::subset(index, &indices).encode();
                let mut direct = Vec::new();
                write_subset_frame_into(&mut direct, index, &indices);
                assert_eq!(direct, canonical, "index {index} indices {indices:?}");
            }
        }
    }

    #[test]
    fn push_frame_roundtrips_are_byte_identical() {
        let frames = [
            Frame::EpochAdvance { epoch: 0, n_subsets: 0 },
            Frame::EpochAdvance { epoch: u64::MAX, n_subsets: 3 },
            Frame::SubsetDelta { epoch: 7, index: 0, indices: vec![] },
            Frame::SubsetDelta {
                epoch: 1 << 40,
                index: NO_INDEX,
                indices: vec![5, 0, 7, 1000, 4_000_000],
            },
        ];
        for f in frames {
            let bytes = f.encode();
            let mut d = FrameDecoder::new();
            d.push(&bytes);
            let back = d.next().unwrap().unwrap();
            assert_eq!(back, f);
            assert_eq!(back.encode(), bytes);
            assert_eq!(d.pending_bytes(), 0);
        }
    }

    #[test]
    fn direct_delta_writer_matches_frame_encode() {
        for indices in [vec![], vec![0usize], vec![5, 0, 7, 1000, 4_000_000]] {
            for (epoch, index) in [(0u64, 0u32), (9, 2), (u64::MAX, NO_INDEX)] {
                let canonical = Frame::SubsetDelta {
                    epoch,
                    index,
                    indices: indices.iter().map(|&i| i as u32).collect(),
                }
                .encode();
                let mut direct = Vec::new();
                write_delta_frame_into(&mut direct, epoch, index, &indices);
                assert_eq!(direct, canonical, "epoch {epoch} index {index}");
            }
        }
    }

    #[test]
    fn truncated_push_frames_are_errors() {
        // an EPOCH_ADVANCE must be exactly 12 bytes
        let mut d = FrameDecoder::new();
        d.push(&[8, 0, 0, 0, KIND_EPOCH, 1, 2, 3, 4, 5, 6, 7, 8]);
        assert!(d.next().is_err());

        // a SUBSET_DELTA whose declared count exceeds the payload
        let mut bytes = Frame::SubsetDelta { epoch: 1, index: 0, indices: vec![1, 2, 3] }
            .encode();
        bytes.truncate(bytes.len() - 4);
        let declared = (bytes.len() - HEADER_LEN) as u32;
        bytes[..4].copy_from_slice(&declared.to_le_bytes());
        let mut d = FrameDecoder::new();
        d.push(&bytes);
        assert!(d.next().is_err());
    }

    #[test]
    fn split_delivery_reassembles() {
        let f = Frame::Json("{\"cmd\":\"PING\"}".into());
        let bytes = f.encode();
        let mut d = FrameDecoder::new();
        for b in &bytes[..bytes.len() - 1] {
            d.push(&[*b]);
            assert_eq!(d.next().unwrap(), None, "must wait for the full frame");
        }
        d.push(&bytes[bytes.len() - 1..]);
        assert_eq!(d.next().unwrap().unwrap(), f);
    }

    #[test]
    fn bad_kind_and_oversized_length_are_errors() {
        let mut d = FrameDecoder::new();
        d.push(&[1, 0, 0, 0, 99, 0]); // kind 99
        assert!(d.next().is_err());

        let mut d = FrameDecoder::new();
        d.push(&[0xFF, 0xFF, 0xFF, 0xFF, KIND_JSON]); // 4 GB payload claim
        assert!(d.next().is_err());
    }

    #[test]
    fn subset_length_mismatch_is_an_error() {
        let mut bytes = Frame::subset(0, &[1, 2, 3]).encode();
        // shrink the payload but keep the declared index count
        bytes.truncate(bytes.len() - 4);
        let declared = (bytes.len() - HEADER_LEN) as u32;
        bytes[..4].copy_from_slice(&declared.to_le_bytes());
        let mut d = FrameDecoder::new();
        d.push(&bytes);
        assert!(d.next().is_err());
    }

    #[test]
    fn stream_bits_roundtrip_and_stream_zero_is_the_legacy_wire() {
        let f = Frame::subset(2, &[0, 7, 1000]);
        for stream in [0u8, 1, 5, (MAX_STREAMS - 1) as u8] {
            let mut bytes = Vec::new();
            write_frame_on(&mut bytes, stream, f.kind(), &f.encode()[HEADER_LEN..]);
            let mut d = FrameDecoder::new();
            d.push(&bytes);
            let (got_stream, got) = d.next_with_stream().unwrap().unwrap();
            assert_eq!(got_stream, stream);
            assert_eq!(got, f);
        }
        // stream 0 must be byte-identical to the pre-multiplexing header:
        // the u32 word is exactly the payload length
        let bytes = f.encode();
        let word = u32::from_le_bytes(bytes[..4].try_into().unwrap());
        assert_eq!(word as usize, bytes.len() - HEADER_LEN);
    }

    #[test]
    fn restream_patches_headers_and_preserves_payload_bytes() {
        let mut burst = Vec::new();
        write_delta_frame_into(&mut burst, 3, 0, &[1, 2, 9]);
        write_delta_frame_into(&mut burst, 3, NO_INDEX, &[4]);
        let mut out = Vec::new();
        restream_frames(&burst, &mut out, 7).unwrap();
        assert_eq!(out.len(), burst.len());
        let mut d = FrameDecoder::new();
        d.push(&out);
        let mut streams = Vec::new();
        let mut frames = Vec::new();
        while let Some((s, f)) = d.next_with_stream().unwrap() {
            streams.push(s);
            frames.push(f);
        }
        assert_eq!(streams, vec![7, 7]);
        // payloads are untouched: re-encoding on stream 0 reproduces the burst
        let mut back = Vec::new();
        for f in &frames {
            back.extend_from_slice(&f.encode());
        }
        assert_eq!(back, burst);
        // restreaming to 0 is the identity
        let mut zero = Vec::new();
        restream_frames(&burst, &mut zero, 0).unwrap();
        assert_eq!(zero, burst);
        // truncated bursts are errors, never panics
        assert!(restream_frames(&burst[..burst.len() - 1], &mut Vec::new(), 1).is_err());
        assert!(restream_frames(&burst[..3], &mut Vec::new(), 1).is_err());
    }

    #[test]
    fn subset_writer_on_stream_matches_patched_encode() {
        let indices = vec![5usize, 0, 7, 1000];
        let mut direct = Vec::new();
        write_subset_frame_on(&mut direct, 9, 3, &indices);
        let mut patched = Vec::new();
        restream_frames(&Frame::subset(3, &indices).encode(), &mut patched, 9).unwrap();
        assert_eq!(direct, patched);
    }

    #[test]
    fn decoder_shrink_releases_burst_capacity() {
        let mut d = FrameDecoder::new();
        let big = Frame::Json("x".repeat(1 << 20)).encode();
        d.push(&big);
        assert!(d.capacity() >= 1 << 20);
        d.next().unwrap().unwrap();
        d.shrink(4096);
        assert!(d.capacity() <= 4096, "capacity {} still pinned", d.capacity());
    }

    #[test]
    fn non_utf8_json_frame_is_an_error() {
        let mut out = vec![2, 0, 0, 0, KIND_JSON, 0xFF, 0xFE];
        let mut d = FrameDecoder::new();
        d.push(&out);
        assert!(d.next().is_err());
        out[4] = KIND_META; // raw bytes are fine for META
        let mut d = FrameDecoder::new();
        d.push(&out);
        assert!(matches!(d.next().unwrap(), Some(Frame::Meta(_))));
    }
}
