//! `milo serve` — a concurrent subset-serving service over pre-processed
//! selection metadata.
//!
//! The paper's amortization claim ("the same pre-processed subsets can be
//! used to train multiple models at no additional cost") becomes literal
//! infrastructure here: one process pays for preprocessing once (via the
//! [`crate::store`] registry), then any number of concurrent trainers /
//! HPO trials connect and draw deterministic subset streams from it.
//!
//! The server is a **single event loop** over nonblocking TCP (no async
//! runtime is vendored offline; readiness comes from a stateful
//! [`event::Poller`] — **epoll** on Linux, with `poll(2)` and a portable
//! sleep as fallback tiers, so per-tick cost scales with socket
//! *activity*, not with the total connection count): one thread owns a
//! registry of connections keyed by token, each with its own read/write
//! buffers, so thousands of mostly-idle trainer connections cost a few KB
//! apiece instead of an OS thread. One server process can serve
//! **multiple `(dataset, fraction)` metadata entries**
//! ([`SubsetServer::bind_multi`], `milo serve --datasets a,b --fractions
//! 0.1,0.3`); each logical session binds to one entry at `HELLO` and
//! draws from it until its next `HELLO`.
//!
//! # Wire formats
//!
//! Every connection starts in **JSON-line mode**: one JSON object per
//! `\n`-terminated UTF-8 line in each direction. A client that sends
//! `"wire":"frame"` in `HELLO` switches the connection to **binary frame
//! mode** after the (JSON-line) `HELLO` response: both directions then
//! carry length-prefixed frames (see [`frame`]) — requests are `JSON`
//! frames, control responses are `JSON` frames, `NEXT_SUBSET` /
//! `SAMPLE_WRE` responses are raw-`u32` `SUBSET` frames, `GET_META`
//! responses are `META` frames holding the exact [`crate::store::binfmt`]
//! artifact bytes (checksum included — a served document is byte-identical
//! to the on-disk artifact), and protocol errors are `ERROR` frames.
//!
//! # Stream multiplexing
//!
//! On the frame wire, the header's spare bits carry a **stream id**
//! (0–31, see [`frame`]): one TCP connection multiplexes up to
//! [`frame::MAX_STREAMS`] logical sessions. Stream 0 is the connection's
//! default session (byte-identical to the pre-multiplexing wire, so
//! proto-2 clients interoperate unchanged); a client opens stream `N > 0`
//! by sending `HELLO` on it — each stream then holds an independent
//! session (its own client id, `(dataset, fraction)` entry binding,
//! deterministic cursors, and subscription), and every response/push
//! frame travels on the stream that asked for it. Per-stream rules:
//!
//! * the wire format is a **connection** property: only a stream-0
//!   `HELLO` may switch it (a nonzero-stream `HELLO` naming a different
//!   wire is an error);
//! * `SUBSCRIBE` subscribes **the stream**, not the socket — the
//!   `serve.subscribers` gauge counts subscribed streams, and an epoch
//!   push burst is delivered once per subscribed stream bound to the
//!   published entry (same payload bytes, per-stream headers);
//! * `GOODBYE` on stream `N > 0` tears down that session only (its
//!   subscription included) and the connection lives on; `GOODBYE` on
//!   stream 0 closes the whole connection, every session with it.
//!
//! [`ServeClient`] exposes this through a shared
//! [`client::ConnectionPool`]: a fleet of [`crate::session::MiloSession`]
//! trainers hands each client one pooled stream instead of one socket —
//! byte-identical payloads at a fraction of the fd budget.
//!
//! # Fairness
//!
//! The loop bounds per-connection work per tick: outbound bytes flush in
//! bounded **write quanta** and inbound bytes are read in bounded **read
//! quanta**, with ready connections serviced in round-robin rotation. A
//! multi-MB `GET_META` (or an epoch push burst, or a chatty pipeliner)
//! therefore spreads across ticks instead of monopolizing the loop, and
//! other clients' small-request latency stays bounded (asserted by
//! `rust/tests/serve_fairness.rs`). Buffers that ballooned for one burst
//! are shrunk back under a threshold once flushed, so a burst sets no
//! permanent per-connection memory high-water (the `serve.buffer_bytes`
//! gauge tracks currently-held capacity; `serve.wbuf_high_water` keeps
//! the historical peak).
//!
//! Hot-path responses never re-encode on the event-loop thread:
//! `NEXT_SUBSET` frames are written straight from the entry's stored
//! subset slice into the connection's write buffer (no per-request clone
//! or intermediate `Vec<u8>`), and `GET_META` serves per-entry bytes
//! serialized once at bind on *both* wires (binfmt artifact bytes in
//! frame mode, the full JSON response line in JSON mode).
//!
//! # Protocol reference
//!
//! Requests (JSON object with a `"cmd"` field, in either wire format):
//!
//! | request | response |
//! |---|---|
//! | `{"cmd":"HELLO","client":"<id>","wire":"json"\|"frame","dataset":…,"fraction":…,"resume":{"sge":N,"wre_ks":[…]}}` | `{"ok":true,"server":"milo-serve","proto":3,"dataset":…,"fraction":…,"seed":…,"seed_hex":…,"n_sge_subsets":…,"n_entries":…,"wire":…}` — binds this connection to client id `<id>` and a served entry (`dataset`/`fraction` optional; default = the first entry, entries searched in registration order), (re)starts its deterministic streams, optionally fast-forwards them past draws a reconnecting client already consumed (`resume`), and switches the wire format. `seed_hex` is the exact stream seed (the numeric `seed` rounds above 2^53) |
//! | `{"cmd":"GET_META"}` | the bound entry's full metadata document (JSON schema of `save_metadata`, or a binfmt `META` frame) |
//! | `{"cmd":"NEXT_SUBSET"}` | the next SGE subset in this client's cycle with its cycle `index` |
//! | `{"cmd":"SAMPLE_WRE","k":K}` | a fresh size-K WRE draw from this client's seeded stream |
//! | `{"cmd":"SUBSCRIBE"}` | `{"ok":true,"subscribed":true,"epoch":…,"n_subsets":…}` — frame wire only; the requesting **stream** now receives push frames on every epoch publish (see *Epoch versioning* below) |
//! | `{"cmd":"STATS"}` | serving + store telemetry (see *STATS reply* below) |
//! | `{"cmd":"FLIGHT"}` | flight-recorder counters plus a summary of buffered tail-samples (see *Causal tracing* below; full event dumps live on the HTTP `/flight` surface) |
//! | `{"cmd":"GOODBYE"}` | `{"ok":true,"goodbye":true}`; on stream 0 the server then closes the connection and reclaims its slot, on stream `N > 0` only that stream's session is torn down |
//! | `{"cmd":"PING"}` | `{"ok":true}` |
//!
//! # Causal tracing
//!
//! Any request may carry `"trace"` and `"span"` fields — 16-hex-char ids
//! ([`crate::obs::id_hex`]) naming the client-side trace and the client's
//! request span. The server runs the whole dispatch under that context:
//! the per-command span (`serve.hello`, `serve.next_subset`, …) parents
//! under the client's span, and every span opened downstream — a deferred
//! entry's `store.resolve`, a kernel build — joins the same tree, so one
//! `MILO_TRACE` sink (or a flight tail-sample) reconstructs client
//! request → dispatch → store → kernel as one causal tree (`milo trace`
//! renders it). The trace id is echoed back as `"trace"` on control
//! replies. The fields are additive JSON — proto-3 peers that never send
//! them are untouched — and the server advertises the capability with
//! `"trace":true` in its `HELLO` reply; [`ServeClient`] stamps requests
//! only after seeing that ack.
//!
//! The **flight recorder** ([`crate::obs::flight`]) is always on: every
//! finished dispatch lands in a fixed-size lock-free ring, and a request
//! that errors or exceeds the tail-sampling threshold
//! (`MILO_FLIGHT_SLOW_US`, default 100 ms) gets its whole trace buffered
//! — and flushed to the `MILO_TRACE` sink when one is configured — even
//! though nothing was being traced when the request started. `FLIGHT`
//! (above) returns the counters; `GET /flight` on the metrics listener
//! dumps ring + samples as JSON lines.
//!
//! # Epoch versioning and push frames
//!
//! A continual-arrival pipeline (see [`crate::continual`]) re-selects as
//! data streams in and hands each new selection to the running server via
//! [`SubsetServer::publish`]`(dataset, epoch, meta)`. Publishes are
//! queued and applied **on the event-loop thread between ticks**, so a
//! request never observes a half-swapped entry:
//!
//! * the entry's metadata, pre-encoded `GET_META` bytes, and epoch number
//!   are swapped atomically (epochs must be strictly increasing; epoch 0
//!   is the bind-time state and stale publishes are dropped);
//! * every **subscribed stream** bound to that entry receives one
//!   `EPOCH_ADVANCE` frame (new epoch + SGE subset count) followed
//!   contiguously by one `SUBSET_DELTA` frame per SGE subset (index =
//!   cycle position) plus one for the fixed disparity-min subset (index =
//!   [`frame::NO_INDEX`]) — each delta carries the subset's **full new
//!   contents**, so a follower never needs a read-back request; the burst
//!   is encoded once per publish and replayed per stream with only the
//!   header's stream bits rewritten;
//! * sessions bound to the entry switch streams at the epoch boundary:
//!   the next request after a publish re-derives the connection's SGE
//!   cursor and WRE stream for the new epoch (see *Determinism* below),
//!   so a trainer that keeps drawing simply crosses over.
//!
//! `SUBSCRIBE` requires the binary frame wire (push payloads are binary);
//! a `HELLO` (re-bind) on a stream cancels that stream's subscription,
//! and a subscribed stream that says `GOODBYE` — or whose connection is
//! torn down for overshooting the outbound-buffer cap, or disconnects
//! abruptly — is removed from the subscriber set before the next
//! broadcast, so a push can never write into a reclaimed slot. Trainers that only ever poll (`NEXT_SUBSET`)
//! need none of this: polling sessions follow the head epoch implicitly.
//!
//! Followers that pin instead of following resolve artifacts through the
//! store, not the server: [`crate::store::MetaStore::load_following`]
//! resolves **pinned epoch → published head → base artifact**, in that
//! order (the server always serves its newest published epoch).
//!
//! ## STATS reply
//!
//! `STATS` returns a `"stats"` object with (both wires, JSON either way):
//!
//! * the legacy flat counters — `connections`, `open_connections`,
//!   `requests`, `subsets_served`, `wre_samples`, `goodbyes`, `bytes_rx`,
//!   `bytes_tx` — plus `accept_errors` (listener `accept` failures, e.g.
//!   fd exhaustion), `wbuf_teardowns` (connections killed for
//!   overshooting the outbound-buffer cap), `push_frames` (push frames
//!   broadcast to subscribers across all epoch publishes), and
//!   `subscribers` (streams currently subscribed — a gauge, like
//!   `open_connections`), so slow-reader kills, accept backoff, and push
//!   fan-out are diagnosable instead of silent;
//! * `"metrics"` — the server's full [`crate::obs::MetricsRegistry`]
//!   rendered to JSON: every counter above under its `serve.*` name, the
//!   `serve.wbuf_high_water` and `serve.buffer_bytes` gauges (historical
//!   peak vs currently-held buffer capacity — see *Fairness* above), and
//!   histogram summaries
//!   (`count`/`p50_us`/`p95_us`/`p99_us`/`max_us`/`mean_us`/`saturated`)
//!   for per-frame-type request latency
//!   (`serve.request_latency_ns.<hello|get_meta|next_subset|sample_wre|stats|flight|ping|goodbye|other>`),
//!   **per-entry attribution** (`serve.requests.entry.<dataset>@<fraction>`
//!   counters and `serve.request_latency_ns.entry.<dataset>@<fraction>`
//!   histograms — which served entry is hot, and how it's behaving),
//!   per-stream request counters (`serve.requests.stream.<id>`), and
//!   per-tick poll/dispatch time (`serve.tick_{poll,dispatch}_ns`);
//! * `"flight"` — the flight-recorder counters
//!   ([`crate::obs::flight::stats_json`]);
//! * `"store"` — the same registry rendering of the backing
//!   [`MetaStore`]'s metrics (counters + hit/disk-load/build latency
//!   histograms), or `null` when serving without a store;
//! * `"entries"`, `"dataset"`, `"client"` — the served entry list and
//!   this session's binding;
//! * `"readiness"` — the event loop's readiness tier (`"epoll"`,
//!   `"poll"`, or `"fallback"`), so deployments can confirm the epoll
//!   path is actually in use.
//!
//! # Metrics exposition (`--metrics-addr`)
//!
//! `milo serve --metrics-addr host:port` (or
//! [`ServeOptions::metrics_addr`] via [`SubsetServer::bind_with`]) binds
//! a second listener on the *same* event loop that answers any HTTP
//! request with a plain-text Prometheus-style exposition of the server
//! registry, the store registry, and the process-global registry (span
//! timings) — `curl http://host:port/metrics` and point a scraper at it.
//! `GET /flight` on the same listener instead returns the flight
//! recorder's JSON-lines dump (ring contents plus tail-samples — feed it
//! to `milo trace`); any other path serves the exposition. Responses are
//! one-shot (`Connection: close`); the endpoint shares the serve thread,
//! so a scrape costs one registry render, no extra thread.
//!
//! A malformed request (bad JSON, bad frame, unknown command) gets an
//! `"ok":false` line / `ERROR` frame; only an unrecoverable framing error
//! closes the connection. Clients should send `GOODBYE` before closing
//! (the [`ServeClient`] does so on drop) — the event loop also reclaims
//! slots on abrupt disconnect, so a crashed trainer never leaks a token.
//!
//! # Determinism contract
//!
//! Streams are keyed by `(server seed, entry, client id)`, **not** by
//! arrival order or wire format, so N concurrent clients never race each
//! other's randomness and JSON/frame consumers of one id see one stream:
//!
//! * `NEXT_SUBSET` cycles the entry's pre-selected SGE subsets starting at
//!   [`client_start_cursor`] (`fnv1a64(client) % n_subsets`) — distinct
//!   clients start at staggered phases and each client's sequence is a
//!   pure function of its id and the metadata.
//! * `SAMPLE_WRE` draws from [`client_stream_rng`] — an independent,
//!   non-overlapping RNG stream per `(entry, client id)`.
//!
//! Under epoch versioning the key grows one component: streams are a pure
//! function of `(server seed, entry, client id, epoch)` —
//! [`client_stream_rng_at`] derives the epoch into the WRE stream (epoch
//! 0, the bind-time state, keeps the exact historical batch streams), and
//! the SGE cursor restarts at [`client_start_cursor`] over the epoch's
//! subsets. Two followers of the same epoch therefore see identical
//! streams regardless of when they attached or how many publishes they
//! watched happen.
//!
//! Consequently a client that reconnects — or connects to a restarted
//! server holding the same store artifact and seed — with the same id
//! replays exactly the same stream from the start, and [`ServeClient`]'s
//! retry policy turns that replay into transparent mid-stream resume:
//! its re-`HELLO` carries a `resume` hint and the server fast-forwards
//! the streams server-side, so no already-consumed payload crosses the
//! wire twice. Asserted end-to-end by `rust/tests/serve_concurrent.rs`,
//! `rust/tests/serve_stress.rs`, and `rust/tests/serve_reconnect.rs`.

pub mod client;
pub(crate) mod event;
pub mod frame;

pub use client::{
    ClientOptions, ConnectionPool, EpochUpdate, FollowStream, RetryPolicy,
    ServeClient, ServedMiloStrategy,
};
pub use frame::{Frame, FrameDecoder};

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::coordinator::{metadata_to_json, Metadata};
use crate::obs::{flight, Counter, Gauge, Histogram, MetricsRegistry};
use crate::selection::WreStrategy;
use crate::store::{binfmt, fnv1a64, MetaStore};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Wire-protocol version, bumped on incompatible changes. v2 = binary
/// frame negotiation + multi-entry routing + `GOODBYE`; v3 = stream-id
/// multiplexing (per-stream sessions/subscriptions — stream 0 stays
/// byte-compatible with v2). Trace context (`trace`/`span` request
/// fields, the `trace` reply echo, `FLIGHT`) is an additive v3 extension
/// negotiated via the `HELLO` capability ack — no bump.
pub const PROTO_VERSION: u32 = 3;

/// Ceiling on a single buffered request (line or partial frame) — a
/// misbehaving client must not grow server memory without bound.
const MAX_REQUEST_BYTES: usize = 16 << 20;

/// Ceiling on a connection's queued outbound bytes. A client that
/// pipelines requests without reading responses stops being read once
/// its responses back up (TCP backpressure), and is torn down if a
/// single processing burst still overshoots this cap — server memory
/// stays bounded per connection.
const MAX_WBUF_BYTES: usize = 64 << 20;

/// Poll timeout: bounds shutdown latency, not request latency (readiness
/// wakes the loop immediately).
const POLL_TIMEOUT_MS: i32 = 50;

/// Per-connection, per-tick bound on outbound flush bytes. Large
/// responses (a multi-MB `GET_META`, an epoch push burst) drain in
/// quanta, round-robin with every other ready connection, so one bulk
/// transfer cannot monopolize the loop and inflate small-request latency.
const WRITE_QUANTUM: usize = 256 << 10;

/// Per-connection, per-tick bound on inbound read bytes — a pipeliner
/// blasting requests is serviced fairly, not exhaustively. Level-
/// triggered readiness re-reports the socket next tick, so nothing is
/// lost by stopping early.
const READ_QUANTUM: usize = 256 << 10;

/// Buffer capacity a connection may keep between bursts. After a flush
/// (or a drained request), rbuf/wbuf/decoder capacity above this is
/// returned to the allocator — one multi-MB burst must not pin its
/// high-water allocation per connection forever (fatal at fleet scale).
const BUF_KEEP_BYTES: usize = 64 << 10;

/// How long accepts stay paused after a persistent `accept` failure
/// (e.g. EMFILE): the listener's readiness interest is dropped for this
/// window — established connections keep being served at full speed —
/// then accepting resumes.
const ACCEPT_PAUSE_MS: u64 = 50;

/// Hard ceiling on the `resume.wre_ks` fast-forward list a single `HELLO`
/// may carry. The effective per-entry cap is work-based — each replayed
/// draw costs O(population), so the allowed draw count is
/// `MAX_RESUME_WORK / population`, clamped by this constant — bounding
/// the synchronous replay one reconnect can put on the shared event-loop
/// thread to roughly a second. A trainer draws one WRE subset per epoch,
/// so real sessions sit orders of magnitude below either bound.
const MAX_RESUME_DRAWS: usize = 100_000;

/// Work budget (in per-point units) for one resume fast-forward.
const MAX_RESUME_WORK: u64 = 1 << 30;

/// Wire format of a connection (negotiated at `HELLO`; see the
/// [module docs](self)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireMode {
    /// One JSON object per `\n`-terminated line (the default).
    Json,
    /// Length-prefixed binary frames (see [`frame`]).
    Frame,
}

impl Default for WireMode {
    fn default() -> Self {
        WireMode::Json
    }
}

impl WireMode {
    pub fn name(self) -> &'static str {
        match self {
            WireMode::Json => "json",
            WireMode::Frame => "frame",
        }
    }

    pub fn parse(name: &str) -> Result<WireMode> {
        match name {
            "json" => Ok(WireMode::Json),
            "frame" => Ok(WireMode::Frame),
            other => anyhow::bail!("unknown wire mode {other:?} (expected json|frame)"),
        }
    }
}

/// The deterministic WRE stream for `(seed, entry, client id)` — the
/// server draws `SAMPLE_WRE` responses from exactly this generator, in
/// request order. Public so tests (and suspicious clients) can reproduce
/// a served stream inline from the shared metadata.
pub fn client_stream_rng(seed: u64, meta: &Metadata, client: &str) -> Rng {
    Rng::new(seed)
        .derive_str("serve_wre")
        .derive_str(&meta.dataset)
        .derive(meta.fraction.to_bits())
        .derive_str(client)
}

/// [`client_stream_rng`] at a continual-arrival epoch: epoch 0 (the
/// bind-time state) is exactly the batch stream — byte-compatible with
/// every pre-epoch client — and each later epoch derives an independent
/// stream, so a follower's draws after an `EPOCH_ADVANCE` are a pure
/// function of `(seed, entry, client id, epoch)`.
pub fn client_stream_rng_at(seed: u64, meta: &Metadata, client: &str, epoch: u64) -> Rng {
    let base = client_stream_rng(seed, meta, client);
    if epoch == 0 {
        base
    } else {
        base.derive(epoch)
    }
}

/// Where `client`'s SGE cycle starts in `meta.sge_subsets` — clients are
/// staggered across the cycle by a hash of their id.
pub fn client_start_cursor(meta: &Metadata, client: &str) -> usize {
    let n = meta.sge_subsets.len().max(1);
    (fnv1a64(client.as_bytes()) % n as u64) as usize
}

/// Serving counters (reported by `STATS`). A snapshot of the server's
/// [`MetricsRegistry`] counters — the registry additionally carries the
/// latency histograms and gauges the struct form elides.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    /// Total connections accepted over the server's lifetime (including
    /// metrics-exposition connections).
    pub connections: u64,
    /// Connections currently open (a gauge — the "no leaked slots"
    /// number the goodbye tests assert on).
    pub open_connections: u64,
    pub requests: u64,
    pub subsets_served: u64,
    pub wre_samples: u64,
    /// `GOODBYE`s received (graceful closes).
    pub goodbyes: u64,
    pub bytes_rx: u64,
    pub bytes_tx: u64,
    /// Listener `accept` failures (e.g. EMFILE under fd exhaustion) that
    /// triggered the accept backoff.
    pub accept_errors: u64,
    /// Connections torn down for overshooting the outbound-buffer cap
    /// (a client pipelining far past its read rate).
    pub wbuf_teardowns: u64,
    /// Push frames (`EPOCH_ADVANCE` + `SUBSET_DELTA`) broadcast to
    /// subscribers across all epoch publishes.
    pub push_frames: u64,
    /// Streams currently subscribed to push frames (a gauge; one
    /// multiplexed connection can hold several).
    pub subscribers: u64,
    /// Total rbuf+wbuf+decoder capacity currently held across live
    /// connections (a gauge — goes back down when post-flush shrinking
    /// releases a burst's allocation).
    pub buffer_bytes: u64,
}

/// Request commands instrumented with a per-frame-type latency histogram
/// (`serve.request_latency_ns.<name>`); the last slot collects unknown /
/// malformed requests.
const CMD_NAMES: [&str; 10] = [
    "hello", "get_meta", "next_subset", "sample_wre", "subscribe", "stats", "flight",
    "ping", "goodbye", "other",
];
const CMD_OTHER: usize = CMD_NAMES.len() - 1;

/// Dispatch span name per command slot — static so the per-request span
/// costs no allocation for its name.
const CMD_SPANS: [&str; CMD_NAMES.len()] = [
    "serve.hello",
    "serve.get_meta",
    "serve.next_subset",
    "serve.sample_wre",
    "serve.subscribe",
    "serve.stats",
    "serve.flight",
    "serve.ping",
    "serve.goodbye",
    "serve.other",
];

fn cmd_slot(cmd: &str) -> usize {
    match cmd {
        "HELLO" => 0,
        "GET_META" => 1,
        "NEXT_SUBSET" => 2,
        "SAMPLE_WRE" => 3,
        "SUBSCRIBE" => 4,
        "STATS" => 5,
        "FLIGHT" => 6,
        "PING" => 7,
        "GOODBYE" => 8,
        _ => CMD_OTHER,
    }
}

/// The server's per-instance metrics: one registry, with every handle the
/// event loop touches pre-resolved at bind so the hot path never takes
/// the registry lock.
struct ServeMetrics {
    registry: MetricsRegistry,
    connections: Counter,
    open_connections: Gauge,
    requests: Counter,
    subsets_served: Counter,
    wre_samples: Counter,
    goodbyes: Counter,
    bytes_rx: Counter,
    bytes_tx: Counter,
    accept_errors: Counter,
    wbuf_teardowns: Counter,
    push_frames: Counter,
    subscribers: Gauge,
    metrics_scrapes: Counter,
    /// Largest unflushed outbound buffer observed on any connection.
    wbuf_high_water: Gauge,
    /// Total rbuf+wbuf+decoder capacity currently held across live
    /// connections — unlike the high-water mark this goes back *down*
    /// when post-flush shrinking releases a burst's allocation.
    buffer_bytes: Gauge,
    /// Time spent blocked in `poll(2)` per event-loop tick.
    tick_poll: Arc<Histogram>,
    /// Time spent accepting/reading/dispatching/writing per tick.
    tick_dispatch: Arc<Histogram>,
    /// Request handling + response encode latency, per frame type.
    req_latency: [Arc<Histogram>; CMD_NAMES.len()],
    /// Per-entry attribution: request count and latency labeled by the
    /// served `(dataset, fraction)` entry
    /// (`serve.requests.entry.<dataset>@<fraction>` /
    /// `serve.request_latency_ns.entry.<…>`) — one hot entry in a
    /// multi-entry fleet is visible per scrape, not just in aggregate.
    entry_requests: Vec<Counter>,
    entry_latency: Vec<Arc<Histogram>>,
    /// Requests per multiplexed stream id (`serve.requests.stream.<id>`).
    stream_requests: Vec<Counter>,
}

impl ServeMetrics {
    fn new(entries: &[(String, f64)]) -> ServeMetrics {
        let registry = MetricsRegistry::new();
        ServeMetrics {
            entry_requests: entries
                .iter()
                .map(|(d, f)| registry.counter(format!("serve.requests.entry.{d}@{f}")))
                .collect(),
            entry_latency: entries
                .iter()
                .map(|(d, f)| {
                    registry.histogram(format!("serve.request_latency_ns.entry.{d}@{f}"))
                })
                .collect(),
            stream_requests: (0..frame::MAX_STREAMS)
                .map(|i| registry.counter(format!("serve.requests.stream.{i}")))
                .collect(),
            connections: registry.counter("serve.connections"),
            open_connections: registry.gauge("serve.open_connections"),
            requests: registry.counter("serve.requests"),
            subsets_served: registry.counter("serve.subsets_served"),
            wre_samples: registry.counter("serve.wre_samples"),
            goodbyes: registry.counter("serve.goodbyes"),
            bytes_rx: registry.counter("serve.bytes_rx"),
            bytes_tx: registry.counter("serve.bytes_tx"),
            accept_errors: registry.counter("serve.accept_errors"),
            wbuf_teardowns: registry.counter("serve.wbuf_teardowns"),
            push_frames: registry.counter("serve.push_frames"),
            subscribers: registry.gauge("serve.subscribers"),
            metrics_scrapes: registry.counter("serve.metrics_scrapes"),
            wbuf_high_water: registry.gauge("serve.wbuf_high_water"),
            buffer_bytes: registry.gauge("serve.buffer_bytes"),
            tick_poll: registry.histogram("serve.tick_poll_ns"),
            tick_dispatch: registry.histogram("serve.tick_dispatch_ns"),
            req_latency: std::array::from_fn(|i| {
                registry.histogram(format!("serve.request_latency_ns.{}", CMD_NAMES[i]))
            }),
            registry,
        }
    }
}

/// One served entry's epoch-versioned payloads — everything a request
/// handler may serve for the entry, swapped as a unit by a publish so a
/// session never sees metadata from one epoch and encoded bytes from
/// another.
struct EntryState {
    meta: Arc<Metadata>,
    /// binfmt artifact bytes, encoded once per epoch (at bind / publish,
    /// never on the event-loop thread): `GET_META` in frame mode serves
    /// these directly. `None` = the entry cannot travel as a `META` frame
    /// (not binfmt-encodable or above the frame cap); frame-mode clients
    /// get an error directing them to the JSON wire.
    encoded: Option<Arc<Vec<u8>>>,
    /// JSON `GET_META` response line (`ok` envelope + document + trailing
    /// newline) — the JSON wire's analogue of `encoded`.
    meta_json: Arc<Vec<u8>>,
    /// Continual-arrival epoch; 0 = the bind-time (batch) state.
    epoch: u64,
}

fn entry_state(meta: Arc<Metadata>, epoch: u64) -> EntryState {
    let encoded = binfmt::try_encode(&meta)
        .ok()
        .filter(|bytes| bytes.len() <= frame::MAX_PAYLOAD)
        .map(Arc::new);
    let mut line = ok_response(vec![("meta", metadata_to_json(&meta))])
        .to_string()
        .into_bytes();
    line.push(b'\n');
    EntryState { meta, encoded, meta_json: Arc::new(line), epoch }
}

/// A lazily-resolved entry's builder (see
/// [`SubsetServer::bind_deferred`]): called at most once, on the first
/// request that touches the entry, on the event-loop thread — under the
/// request's dispatch span, so the serve → `store.resolve` →
/// kernel-build chain of a cold entry is one causal trace.
pub type EntryResolver = Box<dyn FnMut() -> Result<Metadata> + Send>;

/// One lazily-resolved entry for [`SubsetServer::bind_deferred`]: the
/// `(dataset, fraction)` routing key plus the builder that produces its
/// metadata on first touch — typically a closure around
/// [`MetaStore::get_or_build`](crate::store::MetaStore::get_or_build),
/// so a cold entry resolves through the shared artifact store.
pub struct DeferredEntry {
    pub dataset: String,
    pub fraction: f64,
    pub resolve: EntryResolver,
}

/// A served `(dataset, fraction)` slot. The routing key is fixed at bind
/// (a re-published entry keeps its `HELLO` address even when the replayed
/// fraction drifts, e.g. a fixed-size buffer over a growing stream); the
/// state behind it is epoch-versioned.
struct EntryCell {
    dataset: String,
    fraction: f64,
    state: Mutex<EntryState>,
    /// `Some` until a deferred entry resolves (kept on failure so the
    /// next request retries); eagerly-bound entries are born `None`.
    resolver: Mutex<Option<EntryResolver>>,
    /// Fast path for [`ensure_resolved`] — true once real state landed
    /// (resolution or a publish).
    resolved: AtomicBool,
}

impl EntryCell {
    fn eager(meta: Arc<Metadata>) -> EntryCell {
        EntryCell {
            dataset: meta.dataset.clone(),
            fraction: meta.fraction,
            state: Mutex::new(entry_state(meta, 0)),
            resolver: Mutex::new(None),
            resolved: AtomicBool::new(true),
        }
    }

    /// The entry's current `(epoch, metadata)` — one short lock, no
    /// allocation beyond the `Arc` bump.
    fn snapshot(&self) -> (u64, Arc<Metadata>) {
        let st = self.state.lock().expect("entry lock poisoned");
        (st.epoch, st.meta.clone())
    }
}

/// Resolve a deferred entry if it hasn't been yet: run its builder and
/// swap the real state in (unless a concurrent publish already supplied
/// newer state). A failed build keeps the resolver for the next request
/// to retry and surfaces the error to this one.
fn ensure_resolved(shared: &Shared, entry: usize) -> Result<(), String> {
    let cell = &shared.entries[entry];
    if cell.resolved.load(Ordering::Acquire) {
        return Ok(());
    }
    let mut resolver = cell.resolver.lock().expect("resolver lock poisoned");
    if cell.resolved.load(Ordering::Acquire) {
        return Ok(()); // raced another resolution (or a publish)
    }
    let Some(build) = resolver.as_mut() else {
        cell.resolved.store(true, Ordering::Release);
        return Ok(());
    };
    match build() {
        Ok(meta) => {
            {
                let mut st = cell.state.lock().expect("entry lock poisoned");
                // a publish that raced in carries epoch ≥ 1 and is newer
                // than the bind-time build — never clobber it
                if st.epoch == 0 {
                    *st = entry_state(Arc::new(meta), 0);
                }
            }
            *resolver = None;
            cell.resolved.store(true, Ordering::Release);
            Ok(())
        }
        Err(e) => Err(format!(
            "deferred entry {}@{} failed to resolve: {e:#}",
            cell.dataset, cell.fraction
        )),
    }
}

/// One queued [`SubsetServer::publish`], fully pre-encoded on the
/// publisher's thread: the event loop only swaps the state and copies the
/// broadcast burst into subscriber write buffers.
struct PendingPublish {
    entry: usize,
    state: EntryState,
    /// The push burst — one `EPOCH_ADVANCE` + all `SUBSET_DELTA` frames,
    /// encoded once per publish (not per subscriber).
    burst: Vec<u8>,
    /// Frames in `burst`, for the `serve.push_frames` counter.
    n_frames: u64,
}

struct Shared {
    entries: Vec<EntryCell>,
    /// Publishes queued for the event loop to apply between ticks.
    pending: Mutex<Vec<PendingPublish>>,
    seed: u64,
    store: Option<MetaStore>,
    shutdown: AtomicBool,
    metrics: ServeMetrics,
    /// Readiness tier the event loop landed on (`"epoll"` / `"poll"` /
    /// `"fallback"`), set once by the loop thread; reported by `STATS`.
    backend: std::sync::OnceLock<&'static str>,
}

impl Shared {
    fn stats(&self) -> ServeStats {
        let m = &self.metrics;
        ServeStats {
            connections: m.connections.get(),
            open_connections: m.open_connections.get(),
            requests: m.requests.get(),
            subsets_served: m.subsets_served.get(),
            wre_samples: m.wre_samples.get(),
            goodbyes: m.goodbyes.get(),
            bytes_rx: m.bytes_rx.get(),
            bytes_tx: m.bytes_tx.get(),
            accept_errors: m.accept_errors.get(),
            wbuf_teardowns: m.wbuf_teardowns.get(),
            push_frames: m.push_frames.get(),
            subscribers: m.subscribers.get(),
            buffer_bytes: m.buffer_bytes.get(),
        }
    }
}

/// Options for [`SubsetServer::bind_with`] beyond the required entry
/// list.
#[derive(Clone, Debug, Default)]
pub struct ServeOptions {
    /// Bind a plain-text metrics exposition listener on this address
    /// (e.g. `"127.0.0.1:9464"`), served from the same event loop — see
    /// the [module docs](self) *Metrics exposition* section.
    pub metrics_addr: Option<String>,
}

/// A running subset server. Bind with [`SubsetServer::bind`] (one entry),
/// [`SubsetServer::bind_multi`] (one process, many `(dataset, fraction)`
/// entries), or [`SubsetServer::bind_with`] (multi + [`ServeOptions`]),
/// read the actual address with
/// [`addr`](SubsetServer::addr) (pass port 0 for an ephemeral port), stop
/// with [`shutdown`](SubsetServer::shutdown) or block forever with
/// [`run_forever`](SubsetServer::run_forever).
pub struct SubsetServer {
    addr: SocketAddr,
    metrics_addr: Option<SocketAddr>,
    shared: Arc<Shared>,
    event_loop: Option<JoinHandle<()>>,
}

impl SubsetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) serving a single metadata entry.
    /// `store` is optional and only used to report store statistics over
    /// `STATS`.
    pub fn bind(
        addr: &str,
        meta: Arc<Metadata>,
        store: Option<MetaStore>,
        seed: u64,
    ) -> Result<SubsetServer> {
        SubsetServer::bind_multi(addr, vec![meta], store, seed)
    }

    /// Bind `addr` serving several `(dataset, fraction)` entries from one
    /// event loop. Clients route with the `dataset`/`fraction` fields of
    /// `HELLO`; entry 0 is the default for clients that name neither.
    pub fn bind_multi(
        addr: &str,
        entries: Vec<Arc<Metadata>>,
        store: Option<MetaStore>,
        seed: u64,
    ) -> Result<SubsetServer> {
        SubsetServer::bind_with(addr, entries, store, seed, ServeOptions::default())
    }

    /// [`bind_multi`](SubsetServer::bind_multi) plus [`ServeOptions`]
    /// (e.g. a metrics exposition listener).
    pub fn bind_with(
        addr: &str,
        entries: Vec<Arc<Metadata>>,
        store: Option<MetaStore>,
        seed: u64,
        opts: ServeOptions,
    ) -> Result<SubsetServer> {
        // pay each entry's artifact encoding once, up front (and once per
        // publish thereafter) — never per GET_META on the event-loop thread
        let cells = entries.into_iter().map(EntryCell::eager).collect();
        SubsetServer::bind_cells(addr, cells, store, seed, opts)
    }

    /// Bind without resolving: each [`DeferredEntry`] is routable
    /// immediately but pays its metadata build on the **first request
    /// that touches it** (a `HELLO` naming it, or any request on the
    /// default stream-0 session for entry 0) — on the event-loop thread,
    /// under that request's dispatch span, so the
    /// `serve.hello` → `store.resolve` → kernel-build chain of a cold
    /// entry shows up as one causal trace (and a slow resolve
    /// tail-samples into the flight recorder). A failed build is
    /// reported to the requesting client and retried on the next touch;
    /// a [`publish`](SubsetServer::publish) also resolves the entry (its
    /// state is newer than the bind-time build).
    pub fn bind_deferred(
        addr: &str,
        entries: Vec<DeferredEntry>,
        store: Option<MetaStore>,
        seed: u64,
        opts: ServeOptions,
    ) -> Result<SubsetServer> {
        let cells = entries
            .into_iter()
            .map(|d| {
                // a structurally-empty placeholder keeps HELLO routing and
                // sessions well-defined before resolution; every draw path
                // checks for empty subsets already
                let placeholder = Arc::new(Metadata {
                    dataset: d.dataset.clone(),
                    fraction: d.fraction,
                    sge_subsets: Vec::new(),
                    wre_classes: Vec::new(),
                    fixed_dm: Vec::new(),
                    preprocess_secs: 0.0,
                });
                EntryCell {
                    dataset: d.dataset,
                    fraction: d.fraction,
                    state: Mutex::new(entry_state(placeholder, 0)),
                    resolver: Mutex::new(Some(d.resolve)),
                    resolved: AtomicBool::new(false),
                }
            })
            .collect();
        SubsetServer::bind_cells(addr, cells, store, seed, opts)
    }

    fn bind_cells(
        addr: &str,
        cells: Vec<EntryCell>,
        store: Option<MetaStore>,
        seed: u64,
        opts: ServeOptions,
    ) -> Result<SubsetServer> {
        ensure!(!cells.is_empty(), "a subset server needs at least one entry");
        for (i, a) in cells.iter().enumerate() {
            for b in cells.iter().skip(i + 1) {
                ensure!(
                    a.dataset != b.dataset || (a.fraction - b.fraction).abs() > 1e-9,
                    "duplicate served entry {}@{} — routing would be ambiguous",
                    a.dataset,
                    a.fraction,
                );
            }
        }
        let listener = event::bind_reusable(addr)?;
        let local = listener.local_addr()?;
        let metrics_listener = match &opts.metrics_addr {
            Some(maddr) => Some(event::bind_reusable(maddr)?),
            None => None,
        };
        let metrics_local = match &metrics_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };
        let labels: Vec<(String, f64)> =
            cells.iter().map(|c| (c.dataset.clone(), c.fraction)).collect();
        let shared = Arc::new(Shared {
            entries: cells,
            pending: Mutex::new(Vec::new()),
            seed,
            store,
            shutdown: AtomicBool::new(false),
            metrics: ServeMetrics::new(&labels),
            backend: std::sync::OnceLock::new(),
        });
        let loop_shared = shared.clone();
        let event_loop = std::thread::spawn(move || {
            event_loop(listener, metrics_listener, loop_shared)
        });
        Ok(SubsetServer {
            addr: local,
            metrics_addr: metrics_local,
            shared,
            event_loop: Some(event_loop),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The bound metrics exposition address, when
    /// [`ServeOptions::metrics_addr`] was set.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_addr
    }

    pub fn stats(&self) -> ServeStats {
        self.shared.stats()
    }

    /// The `(dataset, fraction)` entries this server routes between
    /// (bind-time routing keys — a published entry keeps its address).
    pub fn entries(&self) -> Vec<(String, f64)> {
        self.shared
            .entries
            .iter()
            .map(|e| (e.dataset.clone(), e.fraction))
            .collect()
    }

    /// The entry's current continual-arrival epoch (0 = bind-time state).
    pub fn epoch_of(&self, dataset: &str) -> Option<u64> {
        self.shared
            .entries
            .iter()
            .find(|e| e.dataset == dataset)
            .map(|e| e.snapshot().0)
    }

    /// Publish a new epoch of selection metadata for the entry serving
    /// `dataset` (see the [module docs](self), *Epoch versioning*).
    ///
    /// All encoding — the binfmt artifact, the JSON `GET_META` line, the
    /// push burst (`EPOCH_ADVANCE` + `SUBSET_DELTA` frames) — happens on
    /// the caller's thread; the event loop atomically swaps the entry
    /// state between ticks and copies the burst into every subscribed
    /// connection's write buffer. Epochs must be strictly increasing per
    /// entry (epoch 0 is the bind-time state).
    pub fn publish(&self, dataset: &str, epoch: u64, meta: Arc<Metadata>) -> Result<()> {
        let entry = self
            .shared
            .entries
            .iter()
            .position(|e| e.dataset == dataset)
            .ok_or_else(|| {
                anyhow::anyhow!("no served entry for dataset {dataset:?}")
            })?;
        ensure!(epoch > 0, "epoch 0 is the bind-time state; publish epochs from 1");
        {
            let st = self.shared.entries[entry].state.lock().expect("entry lock");
            ensure!(
                epoch > st.epoch,
                "publish epoch {epoch} must exceed the current epoch {}",
                st.epoch,
            );
        }
        // pre-validate the push payloads so the broadcast can never panic
        // (or overflow a frame) on the shared event-loop thread
        for s in meta.sge_subsets.iter().chain(std::iter::once(&meta.fixed_dm)) {
            ensure!(
                s.len() <= (frame::MAX_PAYLOAD - 16) / 4
                    && s.iter().all(|&i| i <= u32::MAX as usize),
                "subset does not fit a SUBSET_DELTA frame",
            );
        }
        let mut burst = Frame::EpochAdvance {
            epoch,
            n_subsets: meta.sge_subsets.len() as u32,
        }
        .encode();
        for (si, s) in meta.sge_subsets.iter().enumerate() {
            frame::write_delta_frame_into(&mut burst, epoch, si as u32, s);
        }
        frame::write_delta_frame_into(&mut burst, epoch, frame::NO_INDEX, &meta.fixed_dm);
        let n_frames = 2 + meta.sge_subsets.len() as u64;
        let state = entry_state(meta, epoch);
        self.shared
            .pending
            .lock()
            .expect("pending lock")
            .push(PendingPublish { entry, state, burst, n_frames });
        // wake the poll so the push lands now, not at the next timeout tick
        let _ = TcpStream::connect(self.addr);
        Ok(())
    }

    /// Block the calling thread until the event loop exits (the `milo
    /// serve` subcommand's steady state).
    pub fn run_forever(mut self) {
        if let Some(h) = self.event_loop.take() {
            let _ = h.join();
        }
    }

    /// Stop the event loop and join it. Open connections are closed and
    /// every gauge contribution they held (slots, stream subscriptions,
    /// buffer capacity) is drained; the returned post-shutdown counters
    /// let callers assert nothing leaked.
    pub fn shutdown(mut self) -> ServeStats {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Unblock the poll with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.event_loop.take() {
            let _ = h.join();
        }
        self.shared.stats()
    }
}

// ---------------------------------------------------------------------------
// The event loop
// ---------------------------------------------------------------------------

fn event_loop(
    listener: TcpListener,
    metrics_listener: Option<TcpListener>,
    shared: Arc<Shared>,
) {
    if listener.set_nonblocking(true).is_err() {
        eprintln!("[serve] listener set_nonblocking failed; server exiting");
        return;
    }
    let proto_lid = event::listener_id(&listener);
    let mut poller = event::Poller::new();
    let _ = shared.backend.set(poller.backend());
    poller.add(proto_lid, event::Interest { read: true, write: false });
    let metrics_lid = match &metrics_listener {
        Some(ml) => {
            if ml.set_nonblocking(true).is_err() {
                eprintln!(
                    "[serve] metrics listener set_nonblocking failed; server exiting"
                );
                return;
            }
            let lid = event::listener_id(ml);
            poller.add(lid, event::Interest { read: true, write: false });
            Some(lid)
        }
        None => None,
    };
    let mut conns: HashMap<usize, Conn> = HashMap::new();
    // socket → token: the poller reports readiness by socket id
    let mut by_fd: HashMap<event::SockId, usize> = HashMap::new();
    let mut next_token: usize = 0;
    let mut events: Vec<(event::SockId, event::Ready)> = Vec::new();
    // while Some, listeners have their read interest dropped and no
    // accepts happen — the non-blocking EMFILE backoff (established
    // connections keep being served; nothing sleeps on this thread)
    let mut accept_paused_until: Option<Instant> = None;
    // round-robin offset so ready connections take turns going first
    let mut rr: usize = 0;
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        // apply queued epoch publishes before refreshing interest, so
        // broadcast bytes get their write interest registered this tick
        apply_pending(&shared, &mut conns);
        // re-target only the connections whose interest actually changed
        // (the poller registration is stateful — this is what keeps a
        // tick O(activity) instead of O(connections) on the epoll tier)
        for c in conns.values_mut() {
            let interest = event::Interest {
                // stop reading a client whose responses are backed up
                // (outbound cap) — TCP backpressure does the rest
                read: !c.closing && c.wbuf.len() - c.wpos < MAX_WBUF_BYTES,
                write: c.wpos < c.wbuf.len(),
            };
            if (interest.read, interest.write) != c.last_interest {
                poller.modify(c.id, interest);
                c.last_interest = (interest.read, interest.write);
            }
        }
        // resume accepting once the pause window has elapsed
        let mut timeout_ms = POLL_TIMEOUT_MS;
        if let Some(deadline) = accept_paused_until {
            let now = Instant::now();
            if now >= deadline {
                accept_paused_until = None;
                poller.modify(proto_lid, event::Interest { read: true, write: false });
                if let Some(lid) = metrics_lid {
                    poller.modify(lid, event::Interest { read: true, write: false });
                }
            } else {
                // wake no later than the pause deadline
                let left = deadline.duration_since(now).as_millis() as i32;
                timeout_ms = timeout_ms.min(left.max(1));
            }
        }
        let t_poll = crate::obs::enabled().then(Instant::now);
        poller.wait(timeout_ms, &mut events);
        if let Some(t) = t_poll {
            shared.metrics.tick_poll.record_duration(t.elapsed());
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            break; // don't accept the shutdown wake-up connection
        }
        let t_dispatch = crate::obs::enabled().then(Instant::now);
        // fairness: rotate which ready socket is serviced first, so a
        // connection with a large quantum-bounded flush cannot sit at a
        // fixed position ahead of everyone else tick after tick
        if events.len() > 1 {
            let n = events.len();
            events.rotate_left(rr % n);
            rr = rr.wrapping_add(1);
        }
        for i in 0..events.len() {
            let (fd, r) = events[i];
            if fd == proto_lid || Some(fd) == metrics_lid {
                if accept_paused_until.is_none() {
                    let (l, kind) = if fd == proto_lid {
                        (&listener, ConnKind::Proto)
                    } else {
                        (
                            metrics_listener
                                .as_ref()
                                .expect("metrics lid implies listener"),
                            ConnKind::Metrics,
                        )
                    };
                    accept_paused_until = accept_new(
                        l,
                        &mut conns,
                        &mut by_fd,
                        &mut next_token,
                        &shared,
                        &mut poller,
                        kind,
                    );
                    if accept_paused_until.is_some() {
                        // a fresh pause: drop listener interest so the
                        // ready backlog stops waking the loop for the
                        // pause window (resumed above after the deadline)
                        poller.modify(
                            proto_lid,
                            event::Interest { read: false, write: false },
                        );
                        if let Some(lid) = metrics_lid {
                            poller.modify(
                                lid,
                                event::Interest { read: false, write: false },
                            );
                        }
                    }
                }
                continue;
            }
            let Some(&t) = by_fd.get(&fd) else { continue };
            let Some(conn) = conns.get_mut(&t) else { continue };
            // read before honouring an error condition: a peer that sent
            // GOODBYE and hung up still gets its goodbye processed (the
            // read itself surfaces the reset if the data is gone)
            if (r.readable || r.error) && !conn.dead && !conn.closing {
                conn.read_ready(&shared);
            }
            if r.error {
                conn.dead = true;
            }
            if !conn.dead && conn.wpos < conn.wbuf.len() {
                conn.write_ready(&shared);
            }
            if conn.closing && conn.wpos >= conn.wbuf.len() {
                conn.dead = true;
            }
            if !conn.dead {
                conn.account_buffers(&shared);
            }
        }
        // sweep dead connections: deregister from the poller *before*
        // the fd closes (a recycled fd must not inherit stale events),
        // and return every gauge contribution — slot, per-stream
        // subscriptions, buffer capacity
        let dead: Vec<usize> =
            conns.iter().filter(|(_, c)| c.dead).map(|(t, _)| *t).collect();
        for t in dead {
            let mut conn = conns.remove(&t).expect("dead token present");
            poller.remove(conn.id);
            by_fd.remove(&conn.id);
            conn.release_gauges(&shared);
        }
        if let Some(t) = t_dispatch {
            shared.metrics.tick_dispatch.record_duration(t.elapsed());
        }
    }
    // shutdown: drain *all* gauges for the connections still open — the
    // slot gauge and every remaining stream subscription (leaking
    // `serve.subscribers` here would poison restarts that reuse the
    // registry snapshot for monitoring)
    for (_, mut conn) in conns.drain() {
        poller.remove(conn.id);
        conn.release_gauges(&shared);
    }
}

/// Swap in queued epoch publishes and broadcast each one's push burst to
/// every subscribed stream bound to the entry. Runs on the event-loop
/// thread between ticks, so requests never observe a half-applied
/// publish; skips `closing`/`dead` connections (a `GOODBYE` already
/// cleared their subscriptions — pushes never target a reclaimed slot).
/// The burst is encoded once per publish; per-stream delivery rewrites
/// only the frame headers' stream bits.
fn apply_pending(shared: &Arc<Shared>, conns: &mut HashMap<usize, Conn>) {
    let pending: Vec<PendingPublish> =
        std::mem::take(&mut *shared.pending.lock().expect("pending lock"));
    for p in pending {
        {
            let mut st = shared.entries[p.entry].state.lock().expect("entry lock");
            if p.state.epoch <= st.epoch {
                continue; // stale publish (raced a newer one) — drop it
            }
            *st = p.state;
        }
        // a publish supplies real state: a deferred entry it lands on is
        // resolved (its bind-time builder would only be stale now)
        let cell = &shared.entries[p.entry];
        if !cell.resolved.swap(true, Ordering::AcqRel) {
            *cell.resolver.lock().expect("resolver lock poisoned") = None;
        }
        for conn in conns.values_mut() {
            if conn.kind != ConnKind::Proto || conn.dead || conn.closing {
                continue;
            }
            for si in 0..conn.sessions.len() {
                let (stream, ref session) = conn.sessions[si];
                if !session.subscribed || session.entry != p.entry {
                    continue;
                }
                if stream == 0 {
                    conn.wbuf.extend_from_slice(&p.burst);
                } else if frame::restream_frames(&p.burst, &mut conn.wbuf, stream)
                    .is_err()
                {
                    // the burst was validated at publish; an error here
                    // means corruption — kill the conn, never the loop
                    conn.dead = true;
                    break;
                }
                shared.metrics.push_frames.add(p.n_frames);
            }
            if conn.wbuf.len() - conn.wpos > MAX_WBUF_BYTES {
                // a subscriber that stopped reading: tear it down (the
                // sweep reclaims its subscriptions) rather than let
                // epoch bursts grow server memory without bound
                shared.metrics.wbuf_teardowns.inc();
                conn.dead = true;
            }
        }
    }
}

/// Accept every pending connection. Returns `Some(deadline)` when a
/// persistent error (e.g. EMFILE under fd exhaustion) should pause
/// accepting until then — the caller drops listener interest for the
/// window instead of sleeping, so established connections keep being
/// served while the storm lasts.
fn accept_new(
    listener: &TcpListener,
    conns: &mut HashMap<usize, Conn>,
    by_fd: &mut HashMap<event::SockId, usize>,
    next_token: &mut usize,
    shared: &Arc<Shared>,
    poller: &mut event::Poller,
    kind: ConnKind,
) -> Option<Instant> {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                shared.metrics.connections.inc();
                shared.metrics.open_connections.inc();
                let token = *next_token;
                *next_token += 1;
                let conn = Conn::new(stream, kind);
                poller.add(
                    conn.id,
                    event::Interest {
                        read: conn.last_interest.0,
                        write: conn.last_interest.1,
                    },
                );
                by_fd.insert(conn.id, token);
                conns.insert(token, conn);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return None,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => {
                // a persistent error leaves the backlog poll-ready
                // forever — pause accepts (non-blocking: the event loop
                // drops listener interest until the deadline) and count
                // it so the backoff is visible in STATS instead of silent
                shared.metrics.accept_errors.inc();
                eprintln!("[serve] accept error: {e}; pausing accepts {ACCEPT_PAUSE_MS}ms");
                return Some(
                    Instant::now() + std::time::Duration::from_millis(ACCEPT_PAUSE_MS),
                );
            }
        }
    }
}

/// What protocol a connection speaks: the subset protocol (JSON lines /
/// frames) or the one-shot HTTP metrics exposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ConnKind {
    Proto,
    Metrics,
}

/// One registered connection: nonblocking stream + read/write buffers +
/// negotiated wire format + per-stream deterministic session state.
struct Conn {
    stream: TcpStream,
    id: event::SockId,
    kind: ConnKind,
    /// Inbound bytes awaiting a complete JSON line (JSON-line mode).
    rbuf: Vec<u8>,
    /// Inbound frame reassembly (frame mode).
    decoder: FrameDecoder,
    /// Outbound bytes not yet written; `wpos` marks the flushed prefix.
    wbuf: Vec<u8>,
    wpos: usize,
    wire: WireMode,
    /// Logical sessions keyed by stream id. Stream 0 — the connection's
    /// default session — opens lazily on its first request (so accepting
    /// a connection never snapshots, or forces resolution of, entry 0);
    /// streams `N > 0` open on their first `HELLO`. Linear search: real
    /// fleets run a handful of streams per socket, far below
    /// [`frame::MAX_STREAMS`].
    sessions: Vec<(u8, Session)>,
    /// Trace id (hex) to echo on the next control reply — set per
    /// request by `dispatch` when the request carried a `trace` field.
    trace_echo: Option<String>,
    /// Flush the write buffer, then close (set by a stream-0 `GOODBYE` /
    /// protocol errors).
    closing: bool,
    /// Tear down on the next sweep.
    dead: bool,
    /// `(read, write)` interest last registered with the poller — the
    /// loop calls `modify` only when this changes.
    last_interest: (bool, bool),
    /// Buffer capacity last reported into the `serve.buffer_bytes` gauge.
    reported_cap: usize,
}

impl Conn {
    fn new(stream: TcpStream, kind: ConnKind) -> Conn {
        let id = event::stream_id(&stream);
        Conn {
            stream,
            id,
            kind,
            rbuf: Vec::new(),
            decoder: FrameDecoder::new(),
            wbuf: Vec::new(),
            wpos: 0,
            wire: WireMode::Json,
            sessions: Vec::new(),
            trace_echo: None,
            closing: false,
            dead: false,
            last_interest: (true, false),
            reported_cap: 0,
        }
    }

    fn session_mut(&mut self, stream: u8) -> Option<&mut Session> {
        self.sessions.iter_mut().find(|(s, _)| *s == stream).map(|(_, s)| s)
    }

    /// Resolve the session for `stream`, opening it if this is its
    /// `HELLO`. Stream 0 — the connection's default session — also opens
    /// lazily on its first non-`HELLO` request (anonymous, bound to entry
    /// 0, which must resolve first if it was deferred). A request on an
    /// unopened nonzero stream is an error — multiplexed sessions are
    /// HELLO-negotiated.
    fn session_index(
        &mut self,
        stream: u8,
        is_hello: bool,
        shared: &Shared,
    ) -> Result<usize, String> {
        if let Some(i) = self.sessions.iter().position(|(s, _)| *s == stream) {
            return Ok(i);
        }
        if is_hello || stream == 0 {
            if !is_hello {
                ensure_resolved(shared, 0)?;
            }
            self.sessions.push((stream, Session::new("anon", 0, shared)));
            return Ok(self.sessions.len() - 1);
        }
        Err(format!("stream {stream} has no session — open it with HELLO first"))
    }

    fn read_ready(&mut self, shared: &Shared) {
        let mut chunk = [0u8; 8192];
        let mut taken = 0usize;
        loop {
            if taken >= READ_QUANTUM {
                // fairness: a pipeliner blasting requests yields the loop;
                // level-triggered readiness re-reports the socket next tick
                break;
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    taken += n;
                    shared.metrics.bytes_rx.add(n as u64);
                    match self.wire {
                        WireMode::Json => self.rbuf.extend_from_slice(&chunk[..n]),
                        WireMode::Frame => self.decoder.push(&chunk[..n]),
                    }
                    self.process_pending(shared);
                    if self.closing || self.dead {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
    }

    fn write_ready(&mut self, shared: &Shared) {
        // fairness: flush at most one quantum per tick, so a multi-MB
        // response (META, push burst) drains round-robin with everyone
        // else's traffic instead of monopolizing the loop
        let mut budget = WRITE_QUANTUM;
        while self.wpos < self.wbuf.len() && budget > 0 {
            let end = self.wbuf.len().min(self.wpos + budget);
            match self.stream.write(&self.wbuf[self.wpos..end]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    shared.metrics.bytes_tx.add(n as u64);
                    self.wpos += n;
                    budget -= n;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.wpos >= self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
            // release the burst's capacity: clear() keeps the high-water
            // allocation pinned per connection forever otherwise
            if self.wbuf.capacity() > BUF_KEEP_BYTES {
                self.wbuf.shrink_to(BUF_KEEP_BYTES);
            }
        } else if self.wpos >= WRITE_QUANTUM {
            // quantum-bounded flushing leaves a growing flushed prefix;
            // compact it so partial flushes don't grow the buffer without
            // bound across ticks
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
    }

    /// Shrink drained buffers back under [`BUF_KEEP_BYTES`] and reconcile
    /// this connection's contribution to the `serve.buffer_bytes` gauge.
    /// Called after each serviced tick and balanced by
    /// [`Conn::release_gauges`] at teardown.
    fn account_buffers(&mut self, shared: &Shared) {
        if self.rbuf.capacity() > BUF_KEEP_BYTES && self.rbuf.len() <= BUF_KEEP_BYTES {
            self.rbuf.shrink_to(BUF_KEEP_BYTES);
        }
        self.decoder.shrink(BUF_KEEP_BYTES);
        let cap = self.rbuf.capacity() + self.wbuf.capacity() + self.decoder.capacity();
        match cap.cmp(&self.reported_cap) {
            std::cmp::Ordering::Greater => {
                shared.metrics.buffer_bytes.add((cap - self.reported_cap) as u64)
            }
            std::cmp::Ordering::Less => {
                shared.metrics.buffer_bytes.dec((self.reported_cap - cap) as u64)
            }
            std::cmp::Ordering::Equal => {}
        }
        self.reported_cap = cap;
    }

    /// Return every gauge contribution this connection holds: its open
    /// slot, each subscribed stream, and its reported buffer capacity.
    /// The one place teardown accounting lives — called from the dead
    /// sweep and the shutdown drain, so neither path can leak a gauge.
    fn release_gauges(&mut self, shared: &Shared) {
        shared.metrics.open_connections.dec(1);
        let subs =
            self.sessions.iter().filter(|(_, s)| s.subscribed).count() as u64;
        if subs > 0 {
            shared.metrics.subscribers.dec(subs);
        }
        if self.reported_cap > 0 {
            shared.metrics.buffer_bytes.dec(self.reported_cap as u64);
            self.reported_cap = 0;
        }
    }

    /// Drain every complete message buffered so far, appending responses
    /// to the write buffer.
    fn process_pending(&mut self, shared: &Shared) {
        if self.kind == ConnKind::Metrics {
            self.process_metrics(shared);
            return;
        }
        loop {
            if self.closing || self.dead {
                return;
            }
            if self.wbuf.len() - self.wpos > MAX_WBUF_BYTES {
                // the client pipelined far past its read rate: one burst
                // overshot the outbound cap even with reads gated off
                shared.metrics.wbuf_teardowns.inc();
                self.dead = true;
                return;
            }
            match self.wire {
                WireMode::Json => {
                    let Some(nl) = self.rbuf.iter().position(|&b| b == b'\n') else {
                        if self.rbuf.len() > MAX_REQUEST_BYTES {
                            self.push_reply(
                                Err("request line exceeds the size cap".to_string()),
                                0,
                                shared,
                            );
                            self.closing = true;
                        }
                        return;
                    };
                    let line: Vec<u8> = self.rbuf.drain(..=nl).collect();
                    let text = String::from_utf8_lossy(&line[..nl]).into_owned();
                    if text.trim().is_empty() {
                        continue;
                    }
                    // the JSON wire has no stream field: always stream 0
                    self.dispatch(&text, 0, shared);
                }
                WireMode::Frame => match self.decoder.next_with_stream() {
                    Ok(None) => {
                        if self.decoder.pending_bytes() > MAX_REQUEST_BYTES {
                            self.push_reply(
                                Err("frame exceeds the size cap".to_string()),
                                0,
                                shared,
                            );
                            self.closing = true;
                        }
                        return;
                    }
                    Ok(Some((stream, Frame::Json(text)))) => {
                        self.dispatch(&text, stream, shared);
                    }
                    Ok(Some((stream, other))) => {
                        // requests must be JSON frames; anything else is a
                        // protocol violation we cannot resynchronize from
                        self.push_reply(
                            Err(format!(
                                "requests must be JSON frames, got {}",
                                other.kind_name()
                            )),
                            stream,
                            shared,
                        );
                        self.closing = true;
                    }
                    Err(e) => {
                        self.push_reply(Err(format!("bad frame: {e:#}")), 0, shared);
                        self.closing = true;
                    }
                },
            }
        }
    }

    /// Handle one complete request on `stream` (either wire): parse,
    /// dispatch against the stream's session, encode the reply —
    /// recording the end-to-end latency into the per-frame-type,
    /// per-entry, and per-stream surfaces, the flight ring, and the
    /// outbound high-water mark.
    ///
    /// A request carrying `trace`/`span` fields (hex ids, negotiated at
    /// `HELLO`) runs under that context: the per-command dispatch span —
    /// and every span opened downstream of it (`store.resolve`,
    /// `kernel.execute`, …) — joins the client's trace tree, and the
    /// trace id is echoed back on the control reply.
    fn dispatch(&mut self, text: &str, stream: u8, shared: &Shared) {
        shared.metrics.requests.inc();
        if let Some(c) = shared.metrics.stream_requests.get(stream as usize) {
            c.inc();
        }
        let t0 = (crate::obs::enabled() || flight::enabled()).then(Instant::now);
        let mut wire_trace = 0u64;
        let mut wire_span = 0u64;
        let (slot, trace, entry, reply) = match Json::parse(text) {
            Ok(req) => {
                let cmd = req.opt("cmd").and_then(|c| c.as_str().ok());
                let slot = cmd.map(cmd_slot).unwrap_or(CMD_OTHER);
                let is_hello = cmd == Some("HELLO");
                if let Some(id) =
                    req.opt("trace").and_then(|t| t.as_str().ok()).and_then(crate::obs::parse_id)
                {
                    wire_trace = id;
                }
                if let Some(id) =
                    req.opt("span").and_then(|s| s.as_str().ok()).and_then(crate::obs::parse_id)
                {
                    wire_span = id;
                }
                match self.session_index(stream, is_hello, shared) {
                    Ok(si) => {
                        let _scope = crate::obs::TraceScope::enter(wire_trace, wire_span);
                        let span = crate::obs::Span::enter(CMD_SPANS[slot]);
                        let reply = handle_request(
                            &req,
                            &mut self.sessions[si].1,
                            stream,
                            self.wire,
                            shared,
                        );
                        // the span roots its own trace when the wire gave
                        // none, so the flight recorder can always
                        // tail-sample by trace id
                        let trace = if wire_trace != 0 { wire_trace } else { span.trace_id() };
                        (slot, trace, self.sessions[si].1.entry, reply)
                    }
                    Err(msg) => (slot, wire_trace, usize::MAX, Err(msg)),
                }
            }
            Err(e) => (CMD_OTHER, 0, usize::MAX, Err(format!("bad request json: {e:#}"))),
        };
        if let Some(c) = shared.metrics.entry_requests.get(entry) {
            c.inc();
        }
        // never echo on HELLO: its reply carries the `"trace":true`
        // capability ack, which an echo field would shadow
        self.trace_echo =
            (wire_trace != 0 && slot != 0).then(|| crate::obs::id_hex(wire_trace));
        let is_err = reply.is_err();
        self.push_reply(reply, stream, shared);
        self.trace_echo = None;
        if let Some(t0) = t0 {
            let elapsed = t0.elapsed();
            if crate::obs::enabled() {
                shared.metrics.req_latency[slot].record_duration(elapsed);
                if let Some(h) = shared.metrics.entry_latency.get(entry) {
                    h.record_duration(elapsed);
                }
            }
            flight::record_request(
                CMD_NAMES[slot],
                trace,
                wire_span,
                elapsed.as_micros() as u64,
                is_err,
                stream,
            );
        }
        shared
            .metrics
            .wbuf_high_water
            .record_max((self.wbuf.len() - self.wpos) as u64);
    }

    /// The metrics-exposition protocol: wait for a complete HTTP request
    /// head (blank line), answer with one document — the plain-text
    /// exposition, or the flight-recorder dump when the request line asks
    /// for `/flight` — flush, close. Everything else about HTTP is
    /// deliberately ignored.
    fn process_metrics(&mut self, shared: &Shared) {
        if self.closing || self.dead {
            return;
        }
        if self.rbuf.len() > MAX_REQUEST_BYTES {
            self.dead = true;
            return;
        }
        let head_done = self.rbuf.windows(4).any(|w| w == b"\r\n\r\n")
            || self.rbuf.windows(2).any(|w| w == b"\n\n");
        if !head_done {
            return;
        }
        // "GET /flight HTTP/1.1" → the flight dump; anything else → the
        // exposition (the v1 behavior, whatever the path)
        let line_end = self.rbuf.iter().position(|&b| b == b'\n').unwrap_or(0);
        let request_line = String::from_utf8_lossy(&self.rbuf[..line_end]).into_owned();
        self.rbuf.clear();
        shared.metrics.metrics_scrapes.inc();
        let path = request_line.split_whitespace().nth(1).unwrap_or("");
        let flight = path == "/flight" || path.starts_with("/flight?");
        let (body, content_type) = if flight {
            (flight::dump_jsonl(), "application/json")
        } else {
            (render_exposition(shared), "text/plain; version=0.0.4")
        };
        let head = format!(
            "HTTP/1.0 200 OK\r\nContent-Type: {content_type}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len(),
        );
        self.wbuf.extend_from_slice(head.as_bytes());
        self.wbuf.extend_from_slice(body.as_bytes());
        self.closing = true;
    }

    fn push_reply(&mut self, reply: Result<Reply, String>, stream: u8, shared: &Shared) {
        match reply {
            Ok(Reply::Fields(fields)) => self.push_ok(stream, fields),
            Ok(Reply::Hello { fields, switch }) => {
                // the HELLO response travels in the *old* wire format;
                // everything after it speaks the negotiated one. (The
                // re-bind already cancelled this stream's subscription in
                // handle_request, where the old session was replaced; a
                // nonzero-stream HELLO asking for a wire switch was
                // rejected there before touching the session.)
                self.push_ok(stream, fields);
                if stream == 0 {
                    self.switch_wire(switch);
                }
            }
            Ok(Reply::Subscribed { epoch, n_subsets }) => {
                if let Some(sess) = self.session_mut(stream) {
                    if !sess.subscribed {
                        sess.subscribed = true;
                        shared.metrics.subscribers.inc();
                    }
                }
                self.push_ok(
                    stream,
                    vec![
                        ("subscribed", Json::Bool(true)),
                        ("epoch", Json::num(epoch as f64)),
                        ("n_subsets", Json::num(n_subsets as f64)),
                    ],
                );
            }
            Ok(Reply::Subset { index, subset }) => {
                let subset = subset.as_slice();
                match self.wire {
                    WireMode::Json => {
                        let mut fields: Vec<(&str, Json)> = Vec::new();
                        if index != frame::NO_INDEX {
                            fields.push(("index", Json::num(index as f64)));
                        }
                        fields.push(("subset", indices_json(subset)));
                        self.push_ok(stream, fields);
                    }
                    WireMode::Frame => {
                        // pre-validate so a pathological artifact degrades to a
                        // per-connection error frame, never a panic that would
                        // take the whole event loop down
                        let fits = subset.len() <= (frame::MAX_PAYLOAD - 8) / 4
                            && subset.iter().all(|&i| i <= u32::MAX as usize);
                        if fits {
                            // encode straight from the (shared or freshly
                            // drawn) subset slice into the write buffer —
                            // no intermediate Frame/Vec<u8> per request
                            frame::write_subset_frame_on(
                                &mut self.wbuf,
                                stream,
                                index,
                                subset,
                            );
                        } else {
                            self.push_frame(
                                stream,
                                &Frame::Error(
                                    "subset does not fit a binary frame — use the \
                                     JSON wire"
                                        .to_string(),
                                ),
                            );
                        }
                    }
                }
            }
            Ok(Reply::Meta { json, bin }) => match self.wire {
                // the JSON response line was serialized once at
                // bind/publish — copy it straight into the write buffer
                WireMode::Json => {
                    self.wbuf.extend_from_slice(&json);
                }
                // the artifact bytes were encoded (and size/contract
                // checked) once at bind/publish — frame them straight into
                // the write buffer, no per-request re-encode and no panic
                // path
                WireMode::Frame => match &bin {
                    Some(bytes) => {
                        frame::write_frame_on(
                            &mut self.wbuf,
                            stream,
                            frame::KIND_META,
                            bytes,
                        );
                    }
                    None => {
                        self.push_frame(
                            stream,
                            &Frame::Error(
                                "metadata cannot travel as a META frame (not \
                                 binfmt-encodable or above the frame cap) — use \
                                 the JSON wire"
                                    .to_string(),
                            ),
                        );
                    }
                },
            },
            Ok(Reply::Goodbye) => {
                shared.metrics.goodbyes.inc();
                if stream == 0 {
                    // whole-connection goodbye: leave the subscriber set
                    // *now* — broadcasts between this goodbye and the
                    // flush-then-close sweep must not append push frames
                    // to a connection that said goodbye
                    self.unsubscribe_all(shared);
                    self.push_ok(stream, vec![("goodbye", Json::Bool(true))]);
                    self.closing = true;
                } else {
                    // per-stream goodbye: tear down this session only
                    // (subscription included); the connection and its
                    // other streams live on
                    if let Some(i) =
                        self.sessions.iter().position(|(s, _)| *s == stream)
                    {
                        if self.sessions[i].1.subscribed {
                            shared.metrics.subscribers.dec(1);
                        }
                        self.sessions.swap_remove(i);
                    }
                    self.push_ok(stream, vec![("goodbye", Json::Bool(true))]);
                }
            }
            Err(msg) => match self.wire {
                WireMode::Json => self.push_line(&err_response(&msg).to_string()),
                WireMode::Frame => self.push_frame(stream, &Frame::Error(msg)),
            },
        }
    }

    fn unsubscribe_all(&mut self, shared: &Shared) {
        for (_, sess) in &mut self.sessions {
            if sess.subscribed {
                sess.subscribed = false;
                shared.metrics.subscribers.dec(1);
            }
        }
    }

    fn push_ok(&mut self, stream: u8, mut fields: Vec<(&str, Json)>) {
        // echo the request's trace id so the client can pair reply and
        // trace without inspecting the server's sink (HELLO replies never
        // carry an echo — clients don't stamp trace fields on HELLO, the
        // capability is negotiated there)
        if let Some(hex) = self.trace_echo.take() {
            fields.push(("trace", Json::Str(hex)));
        }
        let doc = ok_response(fields).to_string();
        match self.wire {
            WireMode::Json => self.push_line(&doc),
            WireMode::Frame => self.push_frame(stream, &Frame::Json(doc)),
        }
    }

    fn push_line(&mut self, text: &str) {
        self.wbuf.extend_from_slice(text.as_bytes());
        self.wbuf.push(b'\n');
    }

    fn push_frame(&mut self, stream: u8, f: &Frame) {
        self.wbuf.extend_from_slice(&f.encode_on(stream));
    }

    fn switch_wire(&mut self, to: WireMode) {
        if self.wire == to {
            return;
        }
        // migrate any pipelined inbound bytes to the new format's buffer
        match to {
            WireMode::Frame => {
                let leftover: Vec<u8> = self.rbuf.drain(..).collect();
                self.decoder.push(&leftover);
            }
            WireMode::Json => {
                self.rbuf.extend_from_slice(&self.decoder.take_buffer());
            }
        }
        self.wire = to;
    }
}

// ---------------------------------------------------------------------------
// Request dispatch
// ---------------------------------------------------------------------------

/// Per-connection deterministic stream state, (re)initialized by `HELLO`
/// and re-derived at each epoch boundary (see [`Session::sync`]).
struct Session {
    client: String,
    /// Index into `Shared::entries` this connection is bound to.
    entry: usize,
    /// The epoch this session's streams were derived for.
    epoch: u64,
    /// The entry's metadata at `epoch` — the snapshot every draw in this
    /// epoch serves from (and the `Arc` the zero-copy subset replies
    /// share), so a mid-session publish never tears a response.
    meta: Arc<Metadata>,
    /// Absolute position in the entry's SGE subset cycle.
    cursor: usize,
    /// WRE sampler, built on first `SAMPLE_WRE` — connections that only
    /// `GET_META` or draw SGE subsets never pay the O(n_train)
    /// distribution copy.
    wre: Option<WreStrategy>,
    rng: Rng,
    /// Whether this session's stream receives epoch push frames.
    /// Per-stream, not per-socket: one multiplexed connection can carry
    /// both subscribed and unsubscribed sessions.
    subscribed: bool,
}

impl Session {
    fn new(client: &str, entry: usize, shared: &Shared) -> Session {
        let (epoch, meta) = shared.entries[entry].snapshot();
        Session::at_epoch(client, entry, epoch, meta, shared.seed)
    }

    fn at_epoch(
        client: &str,
        entry: usize,
        epoch: u64,
        meta: Arc<Metadata>,
        seed: u64,
    ) -> Session {
        Session {
            client: client.to_string(),
            entry,
            epoch,
            cursor: client_start_cursor(&meta, client),
            wre: None,
            rng: client_stream_rng_at(seed, &meta, client, epoch),
            meta,
            subscribed: false,
        }
    }

    /// Re-derive the streams if the bound entry advanced past this
    /// session's epoch — called before dispatching every request, so a
    /// session crosses an epoch boundary at its next draw and two
    /// followers of one epoch see identical streams regardless of when
    /// they attached.
    fn sync(&mut self, shared: &Shared) {
        let (epoch, meta) = shared.entries[self.entry].snapshot();
        if epoch != self.epoch {
            let client = std::mem::take(&mut self.client);
            // crossing an epoch re-derives the streams, not the
            // subscription — a subscribed stream stays subscribed
            let subscribed = self.subscribed;
            *self = Session::at_epoch(&client, self.entry, epoch, meta, shared.seed);
            self.subscribed = subscribed;
        }
    }
}

/// What a request produced; the connection encodes it per wire format.
/// Shares the server's per-epoch payloads by `Arc` so served bytes travel
/// into the connection's write buffer without a per-request re-encode.
enum Reply {
    /// Control response fields (`ok:true` is prepended at encode time).
    Fields(Vec<(&'static str, Json)>),
    /// HELLO response + the wire format to switch to afterwards.
    Hello {
        fields: Vec<(&'static str, Json)>,
        switch: WireMode,
    },
    /// A subset payload (`index == frame::NO_INDEX` for WRE draws).
    Subset { index: u32, subset: SubsetPayload },
    /// The session's metadata document — the per-epoch bytes encoded at
    /// bind/publish time, on both wires.
    Meta {
        json: Arc<Vec<u8>>,
        bin: Option<Arc<Vec<u8>>>,
    },
    /// SUBSCRIBE acknowledgment; the connection flips its subscriber flag.
    Subscribed { epoch: u64, n_subsets: u32 },
    /// Acknowledge and close.
    Goodbye,
}

/// Subset payload: `NEXT_SUBSET` shares the session's epoch-snapshot
/// metadata (no per-request clone of the subset); `SAMPLE_WRE` draws are
/// owned.
enum SubsetPayload {
    Shared { meta: Arc<Metadata>, si: usize },
    Owned(Vec<usize>),
}

impl SubsetPayload {
    fn as_slice(&self) -> &[usize] {
        match self {
            SubsetPayload::Shared { meta, si } => &meta.sge_subsets[*si],
            SubsetPayload::Owned(v) => v,
        }
    }
}

fn find_entry(
    shared: &Shared,
    dataset: Option<&str>,
    fraction: Option<f64>,
) -> Result<usize, String> {
    if dataset.is_none() && fraction.is_none() {
        return Ok(0);
    }
    for (i, e) in shared.entries.iter().enumerate() {
        if let Some(ds) = dataset {
            if e.dataset != ds {
                continue;
            }
        }
        if let Some(f) = fraction {
            if (e.fraction - f).abs() > 1e-9 {
                continue;
            }
        }
        return Ok(i);
    }
    let served: Vec<String> = shared
        .entries
        .iter()
        .map(|e| format!("{}@{}", e.dataset, e.fraction))
        .collect();
    Err(format!(
        "no served entry for dataset {} fraction {}; serving: {}",
        dataset.map(|d| format!("{d:?}")).unwrap_or_else(|| "<any>".to_string()),
        fraction.map(|f| f.to_string()).unwrap_or_else(|| "<any>".to_string()),
        served.join(", "),
    ))
}

fn handle_request(
    request: &Json,
    session: &mut Session,
    stream: u8,
    wire: WireMode,
    shared: &Shared,
) -> Result<Reply, String> {
    let cmd = match request.get("cmd").and_then(|c| Ok(c.as_str()?.to_string())) {
        Ok(c) => c,
        Err(_) => return Err("request needs a string \"cmd\" field".to_string()),
    };
    // cross any epoch boundary before serving: publishes are applied
    // between ticks, so within this dispatch the entry state is stable
    session.sync(shared);
    match cmd.as_str() {
        "HELLO" => {
            let client = request
                .opt("client")
                .and_then(|c| c.as_str().ok())
                .unwrap_or("anon");
            let switch = match request.opt("wire").and_then(|w| w.as_str().ok()) {
                None => wire,
                Some(name) => WireMode::parse(name).map_err(|e| format!("{e:#}"))?,
            };
            if stream != 0 && switch != wire {
                // reject before touching the session: the wire format is a
                // connection property negotiated on the default stream —
                // multiplexed streams share the connection's framing layer
                return Err("the wire format is negotiated on stream 0 only — \
                            multiplexed streams speak the connection's wire"
                    .to_string());
            }
            let dataset = request.opt("dataset").and_then(|d| d.as_str().ok());
            let fraction = request.opt("fraction").and_then(|f| f.as_f64().ok());
            let entry = find_entry(shared, dataset, fraction)?;
            // a deferred entry materializes on its first HELLO — inside
            // this dispatch's span, so the resolution cost (store load or
            // preprocess) shows up on the requesting trace
            ensure_resolved(shared, entry)?;
            // a re-bind cancels any subscription: the new entry (or
            // identity) must opt in again explicitly
            if session.subscribed {
                shared.metrics.subscribers.dec(1);
            }
            *session = Session::new(client, entry, shared);
            let meta = session.meta.clone();
            let meta = &*meta;
            // `resume`: fast-forward the deterministic streams past draws a
            // reconnecting client already consumed — one request, no subset
            // payload re-transfer (the streams are pure functions of the
            // session key, so skipping ahead here is exact)
            if let Some(resume) = request.opt("resume") {
                let sge = match resume.opt("sge") {
                    None => 0,
                    Some(x) => x
                        .as_usize()
                        .map_err(|_| "resume.sge must be a non-negative integer")?,
                };
                // only cursor % n is observable, so advance modulo the
                // cycle — immune to an absurd (overflowing) hint
                let n = meta.sge_subsets.len().max(1);
                session.cursor = (session.cursor % n) + (sge % n);
                if let Some(ks) = resume.opt("wre_ks") {
                    let ks = ks
                        .as_arr()
                        .map_err(|_| "resume.wre_ks must be an array".to_string())?;
                    let population = wre_population(meta);
                    // each replayed draw costs O(population) regardless of
                    // k, so cap the *work* (draws × population), not just
                    // the count — one HELLO must never stall the shared
                    // event-loop thread for more than ~a second
                    let max_draws = (MAX_RESUME_WORK / population.max(1) as u64)
                        .min(MAX_RESUME_DRAWS as u64) as usize;
                    if ks.len() > max_draws {
                        return Err(format!(
                            "resume.wre_ks has {} entries, above this entry's \
                             {} cap — the stream is too old to resume; \
                             restart it",
                            ks.len(),
                            max_draws,
                        ));
                    }
                    let wre = session.wre.get_or_insert_with(|| {
                        WreStrategy::new("serve_wre", meta.wre_classes.clone())
                    });
                    for k in ks {
                        match k.as_usize() {
                            Ok(k) if k > 0 && k <= population => {
                                let _ = wre.sample_k(k, &mut session.rng);
                            }
                            _ => {
                                return Err(format!(
                                    "resume.wre_ks must be positive integers \
                                     within the served population ({population})"
                                ))
                            }
                        }
                    }
                }
            }
            Ok(Reply::Hello {
                fields: vec![
                    ("server", Json::str("milo-serve")),
                    ("proto", Json::num(PROTO_VERSION as f64)),
                    ("dataset", Json::str(meta.dataset.clone())),
                    ("fraction", Json::num(meta.fraction)),
                    // the stream seed — clients verify it against their own
                    // configuration (a mismatched server would silently hand
                    // out selections for a different dataset instantiation).
                    // `seed_hex` is the exact value; the numeric field is
                    // kept for human readers but rounds above 2^53.
                    ("seed", Json::num(shared.seed as f64)),
                    ("seed_hex", Json::str(format!("{:016x}", shared.seed))),
                    ("n_sge_subsets", Json::num(meta.sge_subsets.len() as f64)),
                    ("n_entries", Json::num(shared.entries.len() as f64)),
                    // the entry's continual-arrival epoch (0 = batch);
                    // follow-mode clients use it to detect missed advances
                    ("epoch", Json::num(session.epoch as f64)),
                    ("wire", Json::str(switch.name())),
                    // capability ack: this server understands request
                    // `trace`/`span` fields and echoes the trace id on
                    // control replies (proto-3 compatible — older servers
                    // simply omit this field and clients fall back)
                    ("trace", Json::Bool(true)),
                ],
                switch,
            })
        }
        "GET_META" => {
            // the per-epoch bytes, encoded once at bind/publish — the
            // session synced above, so this is its epoch's document
            let st = shared.entries[session.entry].state.lock().expect("entry lock");
            Ok(Reply::Meta { json: st.meta_json.clone(), bin: st.encoded.clone() })
        }
        "NEXT_SUBSET" => {
            let n = session.meta.sge_subsets.len();
            if n == 0 {
                return Err("metadata has no SGE subsets".to_string());
            }
            let index = session.cursor % n;
            session.cursor += 1;
            shared.metrics.subsets_served.inc();
            // zero-copy: the reply shares the session's epoch-snapshot
            // metadata; the connection encodes the subset straight from it
            Ok(Reply::Subset {
                index: index as u32,
                subset: SubsetPayload::Shared { meta: session.meta.clone(), si: index },
            })
        }
        "SUBSCRIBE" => {
            if wire != WireMode::Frame {
                return Err(
                    "SUBSCRIBE requires the binary frame wire (push frames are \
                     binary) — HELLO with \"wire\":\"frame\" first"
                        .to_string(),
                );
            }
            Ok(Reply::Subscribed {
                epoch: session.epoch,
                n_subsets: session.meta.sge_subsets.len() as u32,
            })
        }
        "SAMPLE_WRE" => {
            let k = match request.get("k").and_then(|k| k.as_usize()) {
                Ok(k) if k > 0 => k,
                _ => {
                    return Err(
                        "SAMPLE_WRE needs a positive integer \"k\"".to_string()
                    )
                }
            };
            let meta = session.meta.clone();
            // reject k beyond the served population before sampling: an
            // absurd k must cost this client an error response, never an
            // allocation (or panic) on the shared event-loop thread
            let population = wre_population(&meta);
            if k > population {
                return Err(format!(
                    "SAMPLE_WRE k={k} exceeds the served population {population}"
                ));
            }
            let wre = session.wre.get_or_insert_with(|| {
                WreStrategy::new("serve_wre", meta.wre_classes.clone())
            });
            let subset = wre.sample_k(k, &mut session.rng);
            shared.metrics.wre_samples.inc();
            Ok(Reply::Subset {
                index: frame::NO_INDEX,
                subset: SubsetPayload::Owned(subset),
            })
        }
        "STATS" => {
            let s = shared.stats();
            // one registry→JSON renderer serves both the server's and the
            // store's telemetry (counters + histogram summaries) — no
            // hand-assembled stats JSON to drift out of sync
            let store = match &shared.store {
                Some(st) => st.registry().to_json(),
                None => Json::Null,
            };
            let entries = Json::arr(
                shared
                    .entries
                    .iter()
                    .map(|m| {
                        Json::obj(vec![
                            ("dataset", Json::str(m.dataset.clone())),
                            ("fraction", Json::num(m.fraction)),
                        ])
                    })
                    .collect(),
            );
            Ok(Reply::Fields(vec![(
                "stats",
                Json::obj(vec![
                    ("connections", Json::num(s.connections as f64)),
                    ("open_connections", Json::num(s.open_connections as f64)),
                    ("requests", Json::num(s.requests as f64)),
                    ("subsets_served", Json::num(s.subsets_served as f64)),
                    ("wre_samples", Json::num(s.wre_samples as f64)),
                    ("goodbyes", Json::num(s.goodbyes as f64)),
                    ("bytes_rx", Json::num(s.bytes_rx as f64)),
                    ("bytes_tx", Json::num(s.bytes_tx as f64)),
                    ("accept_errors", Json::num(s.accept_errors as f64)),
                    ("wbuf_teardowns", Json::num(s.wbuf_teardowns as f64)),
                    ("push_frames", Json::num(s.push_frames as f64)),
                    ("subscribers", Json::num(s.subscribers as f64)),
                    (
                        "readiness",
                        Json::str(shared.backend.get().copied().unwrap_or("unknown")),
                    ),
                    (
                        "dataset",
                        Json::str(shared.entries[session.entry].dataset.clone()),
                    ),
                    ("epoch", Json::num(session.epoch as f64)),
                    ("entries", entries),
                    ("client", Json::str(session.client.clone())),
                    ("store", store),
                    ("metrics", shared.metrics.registry.to_json()),
                    ("flight", flight::stats_json()),
                ]),
            )]))
        }
        "FLIGHT" => {
            // recorder counters plus the buffered tail-samples (summary
            // form: full event dumps stay on the `/flight` HTTP surface,
            // which isn't bounded by a control-reply budget)
            let samples = Json::arr(
                flight::samples()
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("trace", Json::Str(crate::obs::id_hex(s.trace))),
                            ("cmd", Json::str(s.cmd.clone())),
                            ("us", Json::num(s.us as f64)),
                            ("err", Json::Bool(s.err)),
                            ("t_us", Json::num(s.t_us as f64)),
                            ("events", Json::num(s.events.len() as f64)),
                        ])
                    })
                    .collect(),
            );
            Ok(Reply::Fields(vec![
                ("flight", flight::stats_json()),
                ("samples", samples),
            ]))
        }
        "GOODBYE" => Ok(Reply::Goodbye),
        "PING" => Ok(Reply::Fields(vec![])),
        other => Err(format!("unknown cmd {other:?}")),
    }
}

fn ok_response(mut fields: Vec<(&str, Json)>) -> Json {
    fields.insert(0, ("ok", Json::Bool(true)));
    Json::obj(fields)
}

fn err_response(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))])
}

/// The `--metrics-addr` document: the server registry, the store
/// registry (when serving from a store), and the process-global registry
/// (span timings), concatenated as one text exposition.
fn render_exposition(shared: &Shared) -> String {
    let mut out = String::new();
    shared.metrics.registry.render_text(&mut out);
    if let Some(store) = &shared.store {
        store.registry().render_text(&mut out);
    }
    MetricsRegistry::global().render_text(&mut out);
    out
}

fn indices_json(idx: &[usize]) -> Json {
    Json::arr(idx.iter().map(|&i| Json::num(i as f64)).collect())
}

/// Total points the entry's WRE distribution covers — the largest `k` a
/// draw (or a resume fast-forward) may legitimately request.
fn wre_population(meta: &Metadata) -> usize {
    meta.wre_classes.iter().map(|c| c.indices.len()).sum()
}

