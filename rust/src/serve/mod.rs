//! `milo serve` — a concurrent subset-serving service over pre-processed
//! selection metadata.
//!
//! The paper's amortization claim ("the same pre-processed subsets can be
//! used to train multiple models at no additional cost") becomes literal
//! infrastructure here: one process pays for preprocessing once (via the
//! [`crate::store`] registry), then any number of concurrent trainers /
//! HPO trials connect and draw deterministic subset streams from it. The
//! server is thread-per-connection over blocking TCP — no async runtime is
//! available offline, and selection serving is tiny-message/low-QPS
//! relative to training steps, so OS threads are the right tool.
//!
//! # Protocol reference
//!
//! One JSON object per line (`\n`-terminated, UTF-8) in each direction.
//! Every response carries `"ok": true` or `"ok": false` with an `"error"`
//! string. Requests:
//!
//! | request | response |
//! |---|---|
//! | `{"cmd":"HELLO","client":"<id>"}` | `{"ok":true,"server":"milo-serve","proto":1,"dataset":…,"n_sge_subsets":…}` — binds this connection to client id `<id>` and (re)starts its deterministic streams |
//! | `{"cmd":"GET_META"}` | `{"ok":true,"meta":{…}}` — the full metadata document (same JSON schema as `save_metadata`) |
//! | `{"cmd":"NEXT_SUBSET"}` | `{"ok":true,"index":i,"subset":[…]}` — the next SGE subset in this client's cycle (`index` = which pre-selected subset was served) |
//! | `{"cmd":"SAMPLE_WRE","k":K}` | `{"ok":true,"subset":[…]}` — a fresh size-K WRE draw from this client's seeded stream |
//! | `{"cmd":"STATS"}` | `{"ok":true,"stats":{connections,requests,subsets_served,wre_samples,store:{hits,misses,disk_loads,builds,evictions}\|null}}` |
//! | `{"cmd":"PING"}` | `{"ok":true}` |
//!
//! # Determinism contract
//!
//! Streams are keyed by `(server seed, client id)`, **not** by arrival
//! order, so N concurrent clients never race each other's randomness:
//!
//! * `NEXT_SUBSET` cycles the pre-selected SGE subsets starting at
//!   `fnv1a64(client) % n_subsets` — distinct clients start at staggered
//!   phases of the cycle and each client's sequence is a pure function of
//!   its id and the metadata.
//! * `SAMPLE_WRE` draws from `Rng::new(seed).derive_str("serve_wre")
//!   .derive_str(client)` — an independent, non-overlapping RNG stream per
//!   client id.
//!
//! Consequently a client that reconnects (or connects to a restarted
//! server holding the same store artifact and seed) with the same id
//! replays exactly the same stream — asserted end-to-end by
//! `rust/tests/serve_concurrent.rs`.

pub mod client;

pub use client::{ServeClient, ServedMiloStrategy};

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::coordinator::{metadata_to_json, Metadata};
use crate::selection::WreStrategy;
use crate::store::{fnv1a64, MetaStore, StoreStats};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Wire-protocol version, bumped on incompatible changes.
pub const PROTO_VERSION: u32 = 1;

/// Serving counters (reported by `STATS`).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    pub connections: u64,
    pub requests: u64,
    pub subsets_served: u64,
    pub wre_samples: u64,
}

struct Shared {
    meta: Arc<Metadata>,
    seed: u64,
    store: Option<MetaStore>,
    shutdown: AtomicBool,
    connections: AtomicU64,
    requests: AtomicU64,
    subsets_served: AtomicU64,
    wre_samples: AtomicU64,
}

impl Shared {
    fn stats(&self) -> ServeStats {
        ServeStats {
            connections: self.connections.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            subsets_served: self.subsets_served.load(Ordering::Relaxed),
            wre_samples: self.wre_samples.load(Ordering::Relaxed),
        }
    }
}

/// A running subset server. Bind with [`SubsetServer::bind`], read the
/// actual address with [`addr`](SubsetServer::addr) (pass port 0 for an
/// ephemeral port), stop with [`shutdown`](SubsetServer::shutdown) or block
/// forever with [`run_forever`](SubsetServer::run_forever).
pub struct SubsetServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl SubsetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and start accepting connections.
    /// `store` is optional and only used to report store statistics over
    /// `STATS`.
    pub fn bind(
        addr: &str,
        meta: Arc<Metadata>,
        store: Option<MetaStore>,
        seed: u64,
    ) -> Result<SubsetServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let shared = Arc::new(Shared {
            meta,
            seed,
            store,
            shutdown: AtomicBool::new(false),
            connections: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            subsets_served: AtomicU64::new(0),
            wre_samples: AtomicU64::new(0),
        });
        let accept_shared = shared.clone();
        let accept = std::thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(SubsetServer { addr: local, shared, accept: Some(accept) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> ServeStats {
        self.shared.stats()
    }

    /// Block the calling thread until the accept loop exits (the `milo
    /// serve` subcommand's steady state).
    pub fn run_forever(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting connections and join the accept thread. Connections
    /// already open are served until their client disconnects.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for conn in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                shared.connections.fetch_add(1, Ordering::Relaxed);
                let conn_shared = shared.clone();
                std::thread::spawn(move || {
                    let _ = handle_connection(stream, conn_shared);
                });
            }
            Err(e) => {
                eprintln!("[serve] accept error: {e}");
            }
        }
    }
}

/// Per-connection deterministic stream state, (re)initialized by `HELLO`.
struct Session {
    client: String,
    /// Absolute position in the SGE subset cycle.
    cursor: usize,
    /// WRE sampler, built on first `SAMPLE_WRE` — connections that only
    /// `GET_META` or draw SGE subsets never pay the O(n_train)
    /// distribution copy.
    wre: Option<WreStrategy>,
    rng: Rng,
}

impl Session {
    fn new(client: &str, shared: &Shared) -> Session {
        let n = shared.meta.sge_subsets.len().max(1);
        Session {
            client: client.to_string(),
            cursor: (fnv1a64(client.as_bytes()) % n as u64) as usize,
            wre: None,
            rng: Rng::new(shared.seed)
                .derive_str("serve_wre")
                .derive_str(client),
        }
    }
}

fn ok_response(mut fields: Vec<(&str, Json)>) -> Json {
    fields.insert(0, ("ok", Json::Bool(true)));
    Json::obj(fields)
}

fn err_response(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))])
}

fn store_stats_json(stats: StoreStats) -> Json {
    Json::obj(vec![
        ("hits", Json::num(stats.hits as f64)),
        ("misses", Json::num(stats.misses as f64)),
        ("disk_loads", Json::num(stats.disk_loads as f64)),
        ("builds", Json::num(stats.builds as f64)),
        ("evictions", Json::num(stats.evictions as f64)),
    ])
}

fn indices_json(idx: &[usize]) -> Json {
    Json::arr(idx.iter().map(|&i| Json::num(i as f64)).collect())
}

fn dispatch(request: &Json, session: &mut Session, shared: &Shared) -> Json {
    let cmd = match request.get("cmd").and_then(|c| Ok(c.as_str()?.to_string())) {
        Ok(c) => c,
        Err(_) => return err_response("request needs a string \"cmd\" field"),
    };
    match cmd.as_str() {
        "HELLO" => {
            let client = request
                .opt("client")
                .and_then(|c| c.as_str().ok())
                .unwrap_or("anon");
            *session = Session::new(client, shared);
            ok_response(vec![
                ("server", Json::str("milo-serve")),
                ("proto", Json::num(PROTO_VERSION as f64)),
                ("dataset", Json::str(shared.meta.dataset.clone())),
                // the stream seed — clients verify it against their own
                // configuration (a mismatched server would silently hand
                // out selections for a different dataset instantiation)
                ("seed", Json::num(shared.seed as f64)),
                (
                    "n_sge_subsets",
                    Json::num(shared.meta.sge_subsets.len() as f64),
                ),
            ])
        }
        "GET_META" => ok_response(vec![("meta", metadata_to_json(&shared.meta))]),
        "NEXT_SUBSET" => {
            let n = shared.meta.sge_subsets.len();
            if n == 0 {
                return err_response("metadata has no SGE subsets");
            }
            let index = session.cursor % n;
            session.cursor += 1;
            shared.subsets_served.fetch_add(1, Ordering::Relaxed);
            ok_response(vec![
                ("index", Json::num(index as f64)),
                ("subset", indices_json(&shared.meta.sge_subsets[index])),
            ])
        }
        "SAMPLE_WRE" => {
            let k = match request.get("k").and_then(|k| k.as_usize()) {
                Ok(k) if k > 0 => k,
                _ => return err_response("SAMPLE_WRE needs a positive integer \"k\""),
            };
            let wre = session.wre.get_or_insert_with(|| {
                WreStrategy::new("serve_wre", shared.meta.wre_classes.clone())
            });
            let subset = wre.sample_k(k, &mut session.rng);
            shared.wre_samples.fetch_add(1, Ordering::Relaxed);
            ok_response(vec![("subset", indices_json(&subset))])
        }
        "STATS" => {
            let s = shared.stats();
            let store = match &shared.store {
                Some(st) => store_stats_json(st.stats()),
                None => Json::Null,
            };
            ok_response(vec![(
                "stats",
                Json::obj(vec![
                    ("connections", Json::num(s.connections as f64)),
                    ("requests", Json::num(s.requests as f64)),
                    ("subsets_served", Json::num(s.subsets_served as f64)),
                    ("wre_samples", Json::num(s.wre_samples as f64)),
                    ("dataset", Json::str(shared.meta.dataset.clone())),
                    ("client", Json::str(session.client.clone())),
                    ("store", store),
                ]),
            )])
        }
        "PING" => ok_response(vec![]),
        other => err_response(&format!("unknown cmd {other:?}")),
    }
}

fn handle_connection(stream: TcpStream, shared: Arc<Shared>) -> Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut session = Session::new("anon", &shared);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // client went away
        };
        if line.trim().is_empty() {
            continue;
        }
        shared.requests.fetch_add(1, Ordering::Relaxed);
        let response = match Json::parse(&line) {
            Ok(req) => dispatch(&req, &mut session, &shared),
            Err(e) => err_response(&format!("bad request json: {e:#}")),
        };
        let mut out = response.to_string();
        out.push('\n');
        if writer.write_all(out.as_bytes()).is_err() {
            break;
        }
    }
    Ok(())
}
