//! Hyper-parameter search spaces (the paper's Appendix G spaces, adapted
//! to the single-LR MLP artifact: our train-step exposes lr / momentum /
//! nesterov / scheduler+γ as runtime scalars and hidden size as compiled
//! tiers, so the space covers the same axes — optimizer variant, LR,
//! schedule, capacity — with one LR group instead of four).

use crate::data::Dataset;
use crate::util::rng::Rng;

/// Scheduler choice inside the search space (cosine vs step-decay, as in
/// Appendix G's image space).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerChoice {
    Cosine,
    StepDecay,
}

/// One sampled configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct TrialConfig {
    pub lr: f64,
    pub momentum: f64,
    pub nesterov: bool,
    pub scheduler: SchedulerChoice,
    /// Step-decay γ (ignored by cosine).
    pub gamma: f64,
    pub hidden: usize,
}

/// The search space: continuous LR (log-uniform), momentum, γ, and
/// categorical nesterov / scheduler / hidden.
#[derive(Clone, Debug)]
pub struct HpoSpace {
    pub lr_range: (f64, f64),
    pub momentum_range: (f64, f64),
    pub gamma_range: (f64, f64),
    pub hidden_choices: Vec<usize>,
}

impl HpoSpace {
    /// Default space for a dataset: hidden tiers come from the manifest's
    /// compiled variants for that dataset (falling back to {128}).
    pub fn default_for(ds: &Dataset) -> HpoSpace {
        let hidden_choices = match ds.id {
            crate::data::DatasetId::Cifar10Like | crate::data::DatasetId::Trec6Like => {
                vec![64, 128, 256]
            }
            _ => vec![128],
        };
        HpoSpace {
            lr_range: (1e-3, 0.3),
            momentum_range: (0.5, 0.99),
            gamma_range: (0.05, 0.5),
            hidden_choices,
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> TrialConfig {
        TrialConfig {
            lr: rng.log_uniform(self.lr_range.0, self.lr_range.1),
            momentum: rng.range_f64(self.momentum_range.0, self.momentum_range.1),
            nesterov: rng.chance(0.5),
            scheduler: if rng.chance(0.5) {
                SchedulerChoice::Cosine
            } else {
                SchedulerChoice::StepDecay
            },
            gamma: rng.range_f64(self.gamma_range.0, self.gamma_range.1),
            hidden: self.hidden_choices[rng.below(self.hidden_choices.len())],
        }
    }

    /// A deterministic grid of `approx` configurations (used by the
    /// Kendall-τ ordering-retention analysis, which needs the *same* config
    /// list evaluated under every subset strategy — Table 9's 108-config
    /// protocol).
    pub fn grid(&self, approx: usize) -> Vec<TrialConfig> {
        // factor approx into lr × gamma resolution; categoricals fixed
        let cat = self.hidden_choices.len() * 2 * 2; // hidden × nesterov × sched
        let cont = (approx as f64 / cat as f64).ceil().max(1.0) as usize;
        let lr_steps = cont.clamp(1, 9);
        let mut out = Vec::new();
        for li in 0..lr_steps {
            let t = if lr_steps == 1 { 0.5 } else { li as f64 / (lr_steps - 1) as f64 };
            let lr = (self.lr_range.0.ln()
                + t * (self.lr_range.1.ln() - self.lr_range.0.ln()))
            .exp();
            for &hidden in &self.hidden_choices {
                for nesterov in [false, true] {
                    for scheduler in [SchedulerChoice::Cosine, SchedulerChoice::StepDecay] {
                        out.push(TrialConfig {
                            lr,
                            momentum: 0.9,
                            nesterov,
                            scheduler,
                            gamma: 0.1,
                            hidden,
                        });
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;

    #[test]
    fn samples_within_bounds() {
        let ds = DatasetId::Cifar10Like.generate(1);
        let space = HpoSpace::default_for(&ds);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let c = space.sample(&mut rng);
            assert!((space.lr_range.0..space.lr_range.1).contains(&c.lr));
            assert!((space.momentum_range.0..space.momentum_range.1).contains(&c.momentum));
            assert!(space.hidden_choices.contains(&c.hidden));
        }
    }

    #[test]
    fn grid_has_expected_structure() {
        let ds = DatasetId::Trec6Like.generate(1);
        let space = HpoSpace::default_for(&ds);
        let grid = space.grid(108);
        // 3 hidden × 2 nesterov × 2 sched = 12 per lr step
        assert_eq!(grid.len() % 12, 0);
        assert!(grid.len() >= 100, "grid size {}", grid.len());
        // deterministic
        assert_eq!(space.grid(108), grid);
        // all lr values within the space
        for c in &grid {
            assert!(c.lr >= space.lr_range.0 * 0.999 && c.lr <= space.lr_range.1 * 1.001);
        }
    }
}
