//! Tree-structured Parzen Estimator (Bergstra et al., NeurIPS 2011) —
//! the paper's second search algorithm for Fig. 7.
//!
//! Standard formulation: split observed trials into good (top γ fraction
//! by objective) and bad; model each with per-dimension Parzen windows
//! (Gaussian KDE for continuous dims, smoothed histograms for
//! categoricals); sample candidates from the good model and keep the one
//! maximizing l(x)/g(x).

use super::space::{HpoSpace, SchedulerChoice, TrialConfig};
use super::TrialResult;
use crate::util::rng::Rng;

/// TPE sampler state.
#[derive(Clone, Debug)]
pub struct TpeSampler {
    space: HpoSpace,
    /// Fraction of trials considered "good" (γ, typically 0.25).
    gamma: f64,
    /// Random trials before the model kicks in.
    pub n_startup: usize,
    /// Candidates scored per sample.
    pub n_candidates: usize,
}

impl TpeSampler {
    pub fn new(space: HpoSpace, gamma: f64) -> TpeSampler {
        TpeSampler { space, gamma, n_startup: 8, n_candidates: 24 }
    }

    /// Sample the next configuration given the history.
    pub fn sample(&mut self, history: &[TrialResult], rng: &mut Rng) -> TrialConfig {
        if history.len() < self.n_startup {
            return self.space.sample(rng);
        }
        // split into good/bad by val accuracy
        let mut sorted: Vec<&TrialResult> = history.iter().collect();
        sorted.sort_by(|a, b| b.val_accuracy.partial_cmp(&a.val_accuracy).unwrap());
        let n_good = ((sorted.len() as f64 * self.gamma).ceil() as usize)
            .clamp(2, sorted.len().saturating_sub(1).max(2));
        let good: Vec<&TrialConfig> = sorted[..n_good].iter().map(|t| &t.config).collect();
        let bad: Vec<&TrialConfig> = sorted[n_good..].iter().map(|t| &t.config).collect();
        if bad.is_empty() {
            return self.space.sample(rng);
        }

        let mut best: Option<(f64, TrialConfig)> = None;
        for _ in 0..self.n_candidates {
            let cand = self.sample_from_good(&good, rng);
            let score = self.log_density(&cand, &good) - self.log_density(&cand, &bad);
            if best.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
                best = Some((score, cand));
            }
        }
        best.map(|(_, c)| c).unwrap_or_else(|| self.space.sample(rng))
    }

    /// Draw a candidate from the good-set Parzen model: pick a random good
    /// point and jitter continuous dims; categoricals from the good
    /// histogram with +1 smoothing.
    fn sample_from_good(&self, good: &[&TrialConfig], rng: &mut Rng) -> TrialConfig {
        let anchor = good[rng.below(good.len())];
        let (lr_lo, lr_hi) = self.space.lr_range;
        let lr_bw = 0.25 * (lr_hi.ln() - lr_lo.ln()); // log-space bandwidth
        let lr = (anchor.lr.ln() + rng.normal() * lr_bw)
            .clamp(lr_lo.ln(), lr_hi.ln())
            .exp();
        let (m_lo, m_hi) = self.space.momentum_range;
        let momentum = (anchor.momentum + rng.normal() * 0.1 * (m_hi - m_lo)).clamp(m_lo, m_hi);
        let (g_lo, g_hi) = self.space.gamma_range;
        let gamma = (anchor.gamma + rng.normal() * 0.2 * (g_hi - g_lo)).clamp(g_lo, g_hi);
        // categorical dims: sample from smoothed good histogram
        let nesterov = sample_cat(good.iter().map(|c| c.nesterov), &[true, false], rng);
        let scheduler = sample_cat(
            good.iter().map(|c| c.scheduler),
            &[SchedulerChoice::Cosine, SchedulerChoice::StepDecay],
            rng,
        );
        let hidden = sample_cat(
            good.iter().map(|c| c.hidden),
            &self.space.hidden_choices,
            rng,
        );
        TrialConfig { lr, momentum, nesterov, scheduler, gamma, hidden }
    }

    /// Per-dimension log Parzen density of `c` under a trial set.
    fn log_density(&self, c: &TrialConfig, set: &[&TrialConfig]) -> f64 {
        let (lr_lo, lr_hi) = self.space.lr_range;
        let lr_bw = (0.25 * (lr_hi.ln() - lr_lo.ln())).max(1e-3);
        let lr_d = parzen_1d(
            c.lr.ln(),
            set.iter().map(|t| t.lr.ln()),
            lr_bw,
        );
        let (m_lo, m_hi) = self.space.momentum_range;
        let m_d = parzen_1d(
            c.momentum,
            set.iter().map(|t| t.momentum),
            (0.1 * (m_hi - m_lo)).max(1e-3),
        );
        let cat_d = |count: usize, total: usize, arms: usize| -> f64 {
            ((count + 1) as f64 / (total + arms) as f64).ln()
        };
        let n = set.len();
        let nes = cat_d(set.iter().filter(|t| t.nesterov == c.nesterov).count(), n, 2);
        let sch = cat_d(set.iter().filter(|t| t.scheduler == c.scheduler).count(), n, 2);
        let hid = cat_d(
            set.iter().filter(|t| t.hidden == c.hidden).count(),
            n,
            self.space.hidden_choices.len(),
        );
        lr_d.ln() + m_d.ln() + nes + sch + hid
    }
}

fn parzen_1d(x: f64, centers: impl Iterator<Item = f64>, bw: f64) -> f64 {
    let mut total = 0.0;
    let mut n = 0usize;
    for c in centers {
        let z = (x - c) / bw;
        total += (-0.5 * z * z).exp();
        n += 1;
    }
    (total / (n.max(1) as f64 * bw * (2.0 * std::f64::consts::PI).sqrt())).max(1e-300)
}

fn sample_cat<T: Copy + PartialEq>(
    observed: impl Iterator<Item = T>,
    arms: &[T],
    rng: &mut Rng,
) -> T {
    let obs: Vec<T> = observed.collect();
    let weights: Vec<f64> = arms
        .iter()
        .map(|a| (obs.iter().filter(|o| *o == a).count() + 1) as f64)
        .collect();
    arms[rng.weighted_index(&weights)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;

    fn mk_result(lr: f64, acc: f64, space: &HpoSpace) -> TrialResult {
        TrialResult {
            config: TrialConfig {
                lr,
                momentum: 0.9,
                nesterov: true,
                scheduler: SchedulerChoice::Cosine,
                gamma: 0.1,
                hidden: space.hidden_choices[0],
            },
            epochs: 5,
            val_accuracy: acc,
            train_secs: 1.0,
        }
    }

    #[test]
    fn startup_is_random_and_in_bounds() {
        let ds = DatasetId::Trec6Like.generate(1);
        let space = HpoSpace::default_for(&ds);
        let mut tpe = TpeSampler::new(space.clone(), 0.25);
        let mut rng = Rng::new(1);
        let c = tpe.sample(&[], &mut rng);
        assert!((space.lr_range.0..space.lr_range.1).contains(&c.lr));
    }

    #[test]
    fn tpe_concentrates_near_good_region() {
        // good trials cluster at lr ~ 0.1; bad at lr ~ 0.001.
        let ds = DatasetId::Trec6Like.generate(1);
        let space = HpoSpace::default_for(&ds);
        let mut history = Vec::new();
        for i in 0..10 {
            history.push(mk_result(0.1 * (1.0 + 0.01 * i as f64), 0.9, &space));
            history.push(mk_result(0.001 * (1.0 + 0.01 * i as f64), 0.2, &space));
        }
        let mut tpe = TpeSampler::new(space, 0.25);
        let mut rng = Rng::new(2);
        let mut near_good = 0;
        for _ in 0..50 {
            let c = tpe.sample(&history, &mut rng);
            if c.lr > 0.02 {
                near_good += 1;
            }
        }
        assert!(near_good > 35, "TPE sampled near good region only {near_good}/50");
    }

    #[test]
    fn parzen_density_positive_and_peaked() {
        let d_at_center = parzen_1d(0.0, [0.0f64, 0.1].into_iter(), 0.5);
        let d_far = parzen_1d(5.0, [0.0f64, 0.1].into_iter(), 0.5);
        assert!(d_at_center > d_far);
        assert!(d_far > 0.0);
    }
}
